//! Ablation benches for the design choices DESIGN.md §5.2 calls out:
//!
//!  1. batching a prefix stage's two ANDs into one opening round,
//!  2. skipping the dead P-update on the final stage,
//!  3. the §4.2 bitpacked wire format vs. sending full 64-bit words,
//!  4. bitpacking vs. *generic byte compression* of the share openings —
//!     the paper's §3 argument that secret shares are incompressible
//!     ("⟨x⟩ are random values fully occupying the N-bit space") while
//!     HummingBird's *semantic* bit selection compresses 8×,
//!  5. the **binary-share layout** (`--layout`): lane-per-u64 vs bitsliced
//!     (64 lanes per word through the DReLU circuit) across the paper's
//!     window widths — the local-compute axis; bytes and rounds are
//!     identical by construction (asserted here).
//!
//! Rows report bytes and rounds (the quantities the network model prices)
//! plus local wall time on the in-process hub.

use hummingbird::beaver::schedule::TripleSchedule;
use hummingbird::crypto::prg::Prg;
use hummingbird::gmw::adder::{self, AdderOptions};
use hummingbird::gmw::harness::{run_parties, run_parties_with_threaded};
use hummingbird::gmw::kernels::{BitslicedKernels, RustKernels};
use hummingbird::gmw::ReluPlan;
use hummingbird::sharing::{share_arith, share_binary};
use hummingbird::util::benchkit::{bench_threads, Bench};
use hummingbird::util::stats;

fn main() {
    let mut bench = Bench::new();
    let n = 16384usize;
    let w = 20u32;
    let mut prg = Prg::new(77, 0);
    let mask = hummingbird::ring::low_mask(w);
    let x: Vec<u64> = (0..n).map(|_| prg.next_u64() & mask).collect();
    let y: Vec<u64> = (0..n).map(|_| prg.next_u64() & mask).collect();
    let xs: Vec<Vec<u64>> = share_binary(&mut prg, &x, 2)
        .iter()
        .map(|s| s.iter().map(|v| v & mask).collect())
        .collect();
    let ys: Vec<Vec<u64>> = share_binary(&mut prg, &y, 2)
        .iter()
        .map(|s| s.iter().map(|v| v & mask).collect())
        .collect();

    println!("== adder design ablation (w={w}, n={n}) ==");
    for (label, opts) in [
        ("optimized (batched + last-P skipped)", AdderOptions::default()),
        ("no last-P skip", AdderOptions { skip_last_p: false, ..Default::default() }),
        ("unbatched stage ANDs", AdderOptions { batch_stage_ands: false, skip_last_p: false }),
    ] {
        let xs2 = xs.clone();
        let ys2 = ys.clone();
        let run = run_parties(2, 5, move |p| {
            let me = p.party();
            adder::ks_add_with(p, &xs2[me], &ys2[me], w, opts).unwrap()
        });
        println!(
            "{label:<40} {:>10} bytes {:>4} rounds",
            run.trace.total_bytes(),
            run.trace.total_rounds()
        );
        let xs3 = xs.clone();
        let ys3 = ys.clone();
        bench.bench_elems(&format!("ks_add_ablate/{label}/{n}"), n as u64, move || {
            let xs = xs3.clone();
            let ys = ys3.clone();
            run_parties(2, 5, move |p| {
                let me = p.party();
                adder::ks_add_with(p, &xs[me], &ys[me], w, opts).unwrap()
            });
        });
    }

    // Wire-format ablation: bitpacked vs full-word openings for one DReLU.
    println!("\n== wire format ablation (DReLU, window [4,12), n={n}) ==");
    let xa: Vec<u64> = (0..n).map(|_| prg.next_u64() % (1 << 16)).collect();
    let sh = share_arith(&mut prg, &xa, 2);
    let plan = ReluPlan::new(12, 4).unwrap();
    let sh2 = sh.clone();
    let run = run_parties(2, 9, move |p| {
        let me = p.party();
        p.drelu(&sh2[me], plan).unwrap()
    });
    let packed_bytes = run.trace.total_bytes();
    // Unpacked equivalent: every w-bit lane would ride a full u64 word.
    let unpacked_bytes: u64 = run
        .trace
        .rounds()
        .iter()
        .map(|r| {
            // bytes = ceil(lanes*w/8) -> lanes*8 when unpacked
            let lanes = r.bytes_sent * 8 / plan.width() as u64;
            lanes * 8
        })
        .sum();
    println!(
        "bitpacked: {}   full-word: {}   saving: {:.2}x",
        stats::fmt_bytes(packed_bytes),
        stats::fmt_bytes(unpacked_bytes),
        unpacked_bytes as f64 / packed_bytes as f64
    );

    // Incompressibility of raw shares (paper §3): entropy of share bytes is
    // ~8 bits/byte, so *no* generic compressor can do what bit selection
    // does. We report the byte-histogram entropy of actual share material.
    println!("\n== share incompressibility (paper §3) ==");
    let shares_bytes: Vec<u8> = sh[0].iter().flat_map(|v| v.to_le_bytes()).collect();
    let h = byte_entropy(&shares_bytes);
    println!(
        "secret-share bytes entropy: {h:.4} bits/byte (ideal random = 8.0) -> \
         generic compression gains ≤ {:.1}%; HummingBird's semantic window \
         selection cut DReLU bytes {:.2}x on the same tensor",
        (1.0 - h / 8.0) * 100.0,
        64.0 / plan.width() as f64
    );
    assert!(h > 7.9, "shares should be incompressible");

    // Layout ablation (the bitsliced-engine axis): the same DReLU through
    // both binary-share layouts, across the paper's window widths. Wire
    // bytes and rounds are pinned equal; the row pair quantifies the
    // local-compute win of 64-lanes-per-word at each width, single-
    // threaded and at the host's thread budget.
    println!("\n== layout ablation (DReLU, lane vs bitsliced, n={n}) ==");
    let threads = bench_threads();
    for (label, plan) in [
        ("w6", ReluPlan::new(10, 4).unwrap()),
        ("w8", ReluPlan::new(12, 4).unwrap()),
        ("w18", ReluPlan::new(18, 0).unwrap()),
        ("w64", ReluPlan::BASELINE),
    ] {
        let xa: Vec<u64> = (0..n).map(|_| prg.next_u64() % (1 << 16)).collect();
        let sh = share_arith(&mut prg, &xa, 2);
        // Plane-native triple accounting for this window: both layouts
        // consume the same dealer stream, so one run's TripleUsage
        // quantifies the PRG/storage material. `lane_words_equiv` is what
        // the legacy lane-form stream stored (one u64 per AND lane) — the
        // plane/lane ratio is the ~w/64 savings the perf-gate summary
        // tabulates.
        let parties = 2u64;
        let usage = run_parties(parties as usize, 31, |p| {
            let me = p.party();
            p.drelu(&sh[me], plan).unwrap();
            p.triple_usage()
        })
        .outputs[0];
        bench.note_metric(&format!("triples/plane_words/{label}"), usage.bin_plane_words as f64);
        bench.note_metric(
            &format!("triples/lane_words_equiv/{label}"),
            usage.bin_triple_lanes as f64,
        );
        // Binary-triple PRG draw only (2 plaintext + 3·(parties−1) split
        // words per plane word) — usage.prg_bytes() would also count the
        // daBit/arith draws, muting the w-scaling this metric exists to
        // show.
        let bin_prg_bytes = usage.bin_plane_words * (2 + 3 * (parties - 1)) * 8;
        bench.note_metric(&format!("triples/prg_bytes/{label}"), bin_prg_bytes as f64);
        for t in [1usize, threads] {
            let lane = run_parties_with_threaded(2, 31, t, |_| RustKernels::default(), |p| {
                let me = p.party();
                p.drelu(&sh[me], plan).unwrap()
            });
            let sliced =
                run_parties_with_threaded(2, 31, t, |_| BitslicedKernels::default(), |p| {
                    let me = p.party();
                    p.drelu(&sh[me], plan).unwrap()
                });
            assert_eq!(lane.outputs, sliced.outputs, "layouts diverged ({label})");
            assert_eq!(lane.trace.total_bytes(), sliced.trace.total_bytes());
            assert_eq!(lane.trace.total_rounds(), sliced.trace.total_rounds());
            bench.bench_elems(&format!("drelu_layout/lane/{label}/{n}/t{t}"), n as u64, || {
                run_parties_with_threaded(2, 31, t, |_| RustKernels::default(), |p| {
                    let me = p.party();
                    p.drelu(&sh[me], plan).unwrap()
                });
            });
            bench.bench_elems(
                &format!("drelu_layout/bitsliced/{label}/{n}/t{t}"),
                n as u64,
                || {
                    run_parties_with_threaded(2, 31, t, |_| BitslicedKernels::default(), |p| {
                        let me = p.party();
                        p.drelu(&sh[me], plan).unwrap()
                    });
                },
            );
            if threads == 1 {
                break; // single-core host: the t rows would be identical
            }
        }
    }

    // Offline/online split ablation: the same ReLU with triples expanded
    // synchronously inside the AND rounds vs prefetched on a background
    // producer (the online-phase view the paper's timing model assumes).
    // Outputs, wire bytes and TripleUsage are pinned equal; the row pair
    // quantifies what moving PRG expansion off the critical path buys at
    // each window. Both rows run PASSES ReLUs per iteration so the on-row's
    // one-time costs (producer spawn + wait_warm's first expansion) amortize
    // the same way a warm serving loop amortizes them — a cycling schedule
    // keeps the producer one pass ahead throughout, like the coordinator.
    // `triples/offline_prg_bytes/*` records the material the offline phase
    // provisions per ReLU batch.
    const PASSES: usize = 4;
    println!("\n== offline/online split (ReLU, prefetch on vs off, n={n}, {PASSES} passes) ==");
    for (label, plan) in [("w6", ReluPlan::new(10, 4).unwrap()), ("w64", ReluPlan::BASELINE)] {
        let xa: Vec<u64> = (0..n).map(|_| prg.next_u64() % (1 << 16)).collect();
        let sh = share_arith(&mut prg, &xa, 2);
        let schedule = TripleSchedule::for_relu(n, plan, 2);
        bench.note_metric(
            &format!("triples/offline_prg_bytes/{label}"),
            schedule.predicted_usage(2).prg_bytes() as f64,
        );
        let run_passes = |prefetch: bool| {
            run_parties(2, 63, |p| {
                if prefetch {
                    p.enable_prefetch(TripleSchedule::for_relu(n, plan, 2), true);
                }
                let me = p.party();
                let mut out = vec![0u64; n];
                for _ in 0..PASSES {
                    p.relu_into(&sh[me], plan, &mut out).unwrap();
                }
                if prefetch {
                    assert_eq!(
                        p.prefetch_stats().unwrap().fallback_ops,
                        0,
                        "online path expanded PRG material"
                    );
                }
                (out, p.triple_usage())
            })
        };
        let sync = run_passes(false);
        let pf = run_passes(true);
        assert_eq!(sync.outputs, pf.outputs, "prefetch diverged ({label})");
        assert_eq!(sync.trace.total_bytes(), pf.trace.total_bytes(), "bytes ({label})");
        assert_eq!(sync.trace.total_rounds(), pf.trace.total_rounds(), "rounds ({label})");
        println!(
            "{label:<6} {:>10} bytes {:>4} rounds  offline PRG material: {}",
            sync.trace.total_bytes(),
            sync.trace.total_rounds(),
            stats::fmt_bytes(schedule.predicted_usage(2).prg_bytes()),
        );
        let elems = (PASSES * n) as u64;
        bench.bench_elems(&format!("relu_prefetch/off/{label}/{n}"), elems, || {
            run_passes(false);
        });
        bench.bench_elems(&format!("relu_prefetch/on/{label}/{n}"), elems, || {
            run_passes(true);
        });
    }

    bench.dump_json("ablation");
}

/// Shannon entropy of the byte histogram, in bits per byte.
fn byte_entropy(data: &[u8]) -> f64 {
    let mut counts = [0u64; 256];
    for b in data {
        counts[*b as usize] += 1;
    }
    let n = data.len() as f64;
    counts
        .iter()
        .filter(|c| **c > 0)
        .map(|c| {
            let p = *c as f64 / n;
            -p * p.log2()
        })
        .sum()
}
