//! HummingBird's offline search engine (paper §4.1.2, Fig 6).
//!
//! Two strategies:
//!
//! * **HummingBird-eco** — discard only high-order bits, per group, such
//!   that no error is introduced (Theorem 1): k is derived from the
//!   observed pre-activation range on the validation set plus a safety
//!   margin, then verified by simulation against the exact baseline.
//! * **HummingBird-b** — given a bit budget (fraction of the baseline's
//!   Σ 64·elems), DFS over per-group width assignments with the paper's
//!   three optimizations: locally-optimal (k, m) per group (later groups
//!   optimistically left exact), early stop 1 (optimistic accuracy below
//!   an absolute threshold), early stop 2 (below the best complete
//!   configuration found so far), early stop 3 (budget exceeded), and
//!   prefix-activation checkpointing so each candidate evaluation only
//!   recomputes the network suffix.

use std::time::Instant;

use crate::error::{Error, Result};
use crate::gmw::ReluPlan;
use crate::hummingbird::{simulator, PlanSet};
use crate::model::graph::{ModelConfig, Op};
use crate::model::plain::PlainExecutor;
use crate::ring::FixedPoint;

/// Search strategy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Strategy {
    Eco,
    /// Budget as a fraction of baseline bits (paper: 8/64, 6/64).
    Budget(f64),
}

/// Tunables.
#[derive(Debug, Clone)]
pub struct SearchConfig {
    pub strategy: Strategy,
    /// Validation samples used during the search (paper used 1024).
    pub val_samples: usize,
    /// Evaluation batch size (should match the search artifact batch).
    pub batch: usize,
    /// Early stop 1: prune when optimistic accuracy drops more than this
    /// below the baseline.
    pub max_acc_drop: f64,
    /// Candidate widths tried per group (descending), for Budget search.
    pub widths: Vec<u32>,
    /// Max low-bit positions scanned for the locally-optimal m.
    pub max_m_scan: u32,
    /// Hard cap on candidate evaluations: when exceeded the DFS unwinds
    /// keeping the best complete configuration found so far (the paper's
    /// "coarser search" escape hatch for large models).
    pub max_evals: usize,
    pub seed: u64,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            strategy: Strategy::Budget(8.0 / 64.0),
            val_samples: 256,
            batch: 64,
            max_acc_drop: 0.10,
            widths: vec![12, 10, 8, 7, 6, 5, 4, 3],
            max_m_scan: 12,
            max_evals: 900,
            seed: 0xbeef,
        }
    }
}

/// Search output.
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub plans: PlanSet,
    pub baseline_acc: f64,
    pub final_acc: f64,
    pub search_time_s: f64,
    /// Number of candidate evaluations performed (Table 2 context).
    pub evals: usize,
    pub budget_fraction: f64,
}

/// The search engine: owns the plaintext executor (simulator) and a slice
/// of validation data.
type PrefixCkpts = Vec<(usize, usize, Vec<(usize, Vec<f32>)>)>;

pub struct SearchEngine<'a> {
    exec: &'a PlainExecutor,
    images: &'a [f32],
    labels: &'a [i32],
    sample_elems: usize,
    cfg: SearchConfig,
    evals: std::cell::Cell<usize>,
    /// Cached prefix activations for the current (group, prefix-plan) pair.
    prefix_cache: std::cell::RefCell<((usize, String), PrefixCkpts)>,
}

impl<'a> SearchEngine<'a> {
    pub fn new(
        exec: &'a PlainExecutor,
        images: &'a [f32],
        labels: &'a [i32],
        sample_elems: usize,
        cfg: SearchConfig,
    ) -> SearchEngine<'a> {
        SearchEngine {
            exec,
            images,
            labels,
            sample_elems,
            cfg,
            evals: 0.into(),
            prefix_cache: std::cell::RefCell::new(((usize::MAX, String::new()), Vec::new())),
        }
    }

    fn mcfg(&self) -> &ModelConfig {
        &self.exec.cfg
    }

    fn n(&self) -> usize {
        self.cfg.val_samples.min(self.labels.len())
    }

    /// Full (non-checkpointed) evaluation of a plan set.
    fn eval_full(&self, plans: &PlanSet) -> Result<f64> {
        self.evals.set(self.evals.get() + 1);
        simulator::evaluate_plans(
            self.exec,
            &self.images[..self.n() * self.sample_elems],
            &self.labels[..self.n()],
            self.sample_elems,
            self.cfg.batch,
            plans,
            self.cfg.seed,
        )
    }

    /// Run the configured search.
    pub fn run(&self) -> Result<SearchResult> {
        let t0 = Instant::now();
        let groups = self.mcfg().relu_groups;
        let baseline = PlanSet::baseline(groups);
        let baseline_acc = self.eval_full(&baseline)?;
        let mut result = match self.cfg.strategy {
            Strategy::Eco => self.search_eco(baseline_acc)?,
            Strategy::Budget(b) => self.search_budget(b, baseline_acc)?,
        };
        result.search_time_s = t0.elapsed().as_secs_f64();
        result.evals = self.evals.get();
        result.budget_fraction = result.plans.budget_fraction(self.mcfg());
        Ok(result)
    }

    // ------------------------------------------------------------------
    // HummingBird-eco.
    // ------------------------------------------------------------------

    fn search_eco(&self, baseline_acc: f64) -> Result<SearchResult> {
        let groups = self.mcfg().relu_groups;
        let fx = FixedPoint::new(self.mcfg().frac_bits);
        // Pass 1: record per-group max |pre-activation| over the val set.
        let mut max_abs = vec![0f64; groups];
        {
            let n = self.n();
            let mut lo = 0usize;
            while lo < n {
                let hi = (lo + self.cfg.batch).min(n);
                let x = &self.images[lo * self.sample_elems..hi * self.sample_elems];
                let mut hook = |_node: usize, group: usize, v: &mut [f32]| {
                    for e in v.iter_mut() {
                        let a = e.abs() as f64;
                        if a > max_abs[group] {
                            max_abs[group] = a;
                        }
                        if *e < 0.0 {
                            *e = 0.0;
                        }
                    }
                };
                self.exec.forward_with(x, hi - lo, &mut hook)?;
                lo = hi;
            }
        }
        // Theorem 1: need -2^(k-1) <= x < 2^(k-1) on the ring, i.e.
        // k > log2(|x|*2^f) + 1; add one extra safety bit for unseen data.
        let mut plans = PlanSet::baseline(groups);
        for (g, ma) in max_abs.iter().enumerate() {
            let ring_mag = (ma * fx.scale()).max(1.0);
            let k = (ring_mag.log2().floor() as u32 + 2 + 1).min(64);
            plans.set(g, ReluPlan::new(k, 0)?);
        }
        // Verify error-freeness on the val set; widen any group if the
        // simulated predictions deviate from baseline.
        let mut acc = self.eval_full(&plans)?;
        let mut guard = 0;
        while acc + 1e-9 < baseline_acc && guard < 8 {
            for g in 0..groups {
                let p = plans.plan_for(g);
                plans.set(g, ReluPlan::new((p.k + 1).min(64), 0)?);
            }
            acc = self.eval_full(&plans)?;
            guard += 1;
        }
        plans.meta.insert("strategy".into(), "eco".into());
        Ok(SearchResult {
            plans,
            baseline_acc,
            final_acc: acc,
            search_time_s: 0.0,
            evals: 0,
            budget_fraction: 0.0,
        })
    }

    // ------------------------------------------------------------------
    // HummingBird-b (budgeted DFS).
    // ------------------------------------------------------------------

    fn search_budget(&self, budget: f64, baseline_acc: f64) -> Result<SearchResult> {
        let mcfg = self.mcfg();
        let groups = mcfg.relu_groups;
        // Per-group element counts (budget weights) and the k cap from the
        // eco analysis (no point keeping bits above the value range).
        let eco = self.search_eco(baseline_acc)?;
        let k_cap: Vec<u32> = (0..groups).map(|g| eco.plans.plan_for(g).k).collect();
        let mut elems = vec![0u64; groups];
        for (_, g, e) in mcfg.relu_elems() {
            elems[g] += e as u64;
        }
        let total_baseline: u64 = elems.iter().map(|e| e * 64).sum();
        let budget_bits = (budget * total_baseline as f64).floor() as u64;

        // Group order: by node order (paper: "starting from the first ReLU
        // layer").
        let mut best: Option<(f64, PlanSet)> = None;
        let mut plans = PlanSet::baseline(groups);
        self.dfs(
            0,
            groups,
            &elems,
            &k_cap,
            budget_bits,
            0,
            baseline_acc,
            &mut plans,
            &mut best,
        )?;
        let (acc, plans) = best.ok_or_else(|| {
            Error::Search(format!(
                "no configuration within budget {budget} stays within max_acc_drop \
                 {} of the baseline — widen `widths`/`max_m_scan` or raise the drop \
                 threshold",
                self.cfg.max_acc_drop
            ))
        })?;
        let mut plans = plans;
        plans.meta.insert("strategy".into(), format!("budget:{budget:.4}"));
        Ok(SearchResult {
            plans,
            baseline_acc,
            final_acc: acc,
            search_time_s: 0.0,
            evals: 0,
            budget_fraction: 0.0,
        })
    }

    /// DFS over group `g`'s width assignment.
    #[allow(clippy::too_many_arguments)]
    fn dfs(
        &self,
        g: usize,
        groups: usize,
        elems: &[u64],
        k_cap: &[u32],
        budget_bits: u64,
        used_bits: u64,
        baseline_acc: f64,
        plans: &mut PlanSet,
        best: &mut Option<(f64, PlanSet)>,
    ) -> Result<()> {
        if g == groups {
            return Ok(()); // handled at leaf assignment below
        }
        // Eval-budget escape hatch: unwind keeping the best found so far.
        if self.evals.get() >= self.cfg.max_evals && best.is_some() {
            return Ok(());
        }
        // Minimal bits the remaining groups could use (width 0 = identity).
        let mut widths: Vec<u32> = self.cfg.widths.clone();
        widths.push(0);
        for &width in &widths {
            let cost = width as u64 * elems[g];
            // Early stop 3: budget exceeded (counting zero for the rest).
            if used_bits + cost > budget_bits {
                continue;
            }
            // Locally-optimal (k, m) for this width (later groups exact).
            let (plan, opt_acc) = self.best_km_for_width(g, width, k_cap[g], plans)?;
            if std::env::var("HB_SEARCH_DEBUG").is_ok() {
                eprintln!(
                    "[dfs] g={g} width={width} plan=[{},{}) used={used_bits} cost={cost} \
                     budget={budget_bits} opt_acc={opt_acc:.4} baseline={baseline_acc:.4} best={:?}",
                    plan.m,
                    plan.k,
                    best.as_ref().map(|b| b.0)
                );
            }
            // Early stop 1: hopeless branch.
            if opt_acc < baseline_acc - self.cfg.max_acc_drop {
                continue;
            }
            // Early stop 2: optimistic accuracy already below best found.
            if let Some((best_acc, _)) = best {
                if opt_acc <= *best_acc && g > 0 {
                    continue;
                }
            }
            plans.set(g, plan);
            if g + 1 == groups {
                // Complete assignment: opt_acc is the true accuracy.
                let better = match best {
                    Some((a, _)) => opt_acc > *a,
                    None => true,
                };
                if better {
                    *best = Some((opt_acc, plans.clone()));
                }
            } else {
                self.dfs(
                    g + 1,
                    groups,
                    elems,
                    k_cap,
                    budget_bits,
                    used_bits + cost,
                    baseline_acc,
                    plans,
                    best,
                )?;
            }
            plans.set(g, ReluPlan::BASELINE);
        }
        Ok(())
    }

    /// Scan m (with k = m + width, capped) for the locally-optimal window
    /// of group g, earlier groups fixed in `plans`, later groups exact.
    fn best_km_for_width(
        &self,
        g: usize,
        width: u32,
        k_cap: u32,
        plans: &PlanSet,
    ) -> Result<(ReluPlan, f64)> {
        if width == 0 {
            let plan = ReluPlan::new(0, 0)?; // identity
            let mut candidate = plans.clone();
            candidate.set(g, plan);
            for later in g + 1..self.mcfg().relu_groups {
                candidate.set(later, ReluPlan::BASELINE);
            }
            let acc = self.eval_suffix(g, &candidate)?;
            return Ok((plan, acc));
        }
        let mut best: Option<(ReluPlan, f64)> = None;
        // Anchor the scan near the eco-derived range cap: windows whose top
        // bit k sits far below the activation range flip signs wholesale
        // (Theorem 1 violated) and never win, so scanning them wastes
        // evaluations. We still probe a few positions below the cap to let
        // the optimizer trade range errors for pruning.
        let m_hi = self.cfg.max_m_scan.min(k_cap.saturating_sub(width));
        let m_lo = m_hi.saturating_sub(4);
        for m in m_lo..=m_hi {
            let k = (m + width).min(64);
            let plan = ReluPlan::new(k, m)?;
            let mut candidate = plans.clone();
            candidate.set(g, plan);
            for later in g + 1..self.mcfg().relu_groups {
                candidate.set(later, ReluPlan::BASELINE);
            }
            let acc = self.eval_suffix(g, &candidate)?;
            match &best {
                Some((_, b)) if acc <= *b => {}
                _ => best = Some((plan, acc)),
            }
        }
        best.ok_or_else(|| Error::Search("empty m scan".into()))
    }

    /// Evaluate with prefix checkpointing: groups < g are unchanged between
    /// sibling candidates, so cache the prefix activations per batch.
    fn eval_suffix(&self, g: usize, plans: &PlanSet) -> Result<f64> {
        self.evals.set(self.evals.get() + 1);
        let boundary = self.group_boundary(g);
        let fx = FixedPoint::new(self.mcfg().frac_bits);
        let classes = self.mcfg().num_classes;
        let n = self.n();
        let mut correct = 0usize;
        // Prefix cache keyed by the plans of groups < g (summarized).
        let prefix_key = (0..g)
            .map(|gg| {
                let p = plans.plan_for(gg);
                format!("{}:{}", p.k, p.m)
            })
            .collect::<Vec<_>>()
            .join(",");
        let mut cache = self.prefix_cache.borrow_mut();
        if cache.0 != (g, prefix_key.clone()) {
            // (Re)build the prefix checkpoints for every batch.
            let mut ckpts = Vec::new();
            let mut lo = 0usize;
            while lo < n {
                let hi = (lo + self.cfg.batch).min(n);
                let x = &self.images[lo * self.sample_elems..hi * self.sample_elems];
                let mut hook = simulator::plan_hook(plans, fx, self.cfg.seed, lo);
                let seeds = self.exec.prefix_acts(x, hi - lo, boundary, &mut hook)?;
                ckpts.push((lo, hi, seeds));
                lo = hi;
            }
            *cache = ((g, prefix_key), ckpts);
        }
        for (lo, hi, seeds) in &cache.1 {
            let mut hook = simulator::plan_hook(plans, fx, self.cfg.seed, *lo);
            let logits = self.exec.forward_from(boundary, seeds, hi - lo, &mut hook)?;
            correct += simulator::count_correct(&logits, &self.labels[*lo..*hi], classes);
        }
        Ok(correct as f64 / n as f64)
    }

    /// First ReLU node of group g (suffix re-evaluation boundary).
    fn group_boundary(&self, g: usize) -> usize {
        self.mcfg()
            .nodes
            .iter()
            .enumerate()
            .find_map(|(i, n)| match n {
                Op::Relu { group, .. } if *group == g => Some(i),
                _ => None,
            })
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    // End-to-end search tests (they need trained weights + artifacts) live
    // in rust/tests/search_e2e.rs; pure logic tests below.
    use super::*;

    #[test]
    fn default_config_is_sane() {
        let c = SearchConfig::default();
        assert!(matches!(c.strategy, Strategy::Budget(_)));
        assert!(c.widths.windows(2).all(|w| w[0] > w[1]), "widths descending");
    }
}
