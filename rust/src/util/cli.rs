//! Tiny command-line argument parser (clap is not available offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments.
//! Unknown flags are collected so subcommands can validate their own set.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments in order (subcommand name is positional 0).
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options; bare `--flag` maps to "true".
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    ///
    /// A `--key` followed by a token that does not start with `--` consumes
    /// it as the value; otherwise the key becomes a boolean flag.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if let Some(v) = iter.next_if(|n| !n.starts_with("--")) {
                    args.options.insert(rest.to_string(), v);
                } else {
                    args.options.insert(rest.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// The subcommand (first positional), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    /// Required string option.
    pub fn req(&self, key: &str) -> Result<&str> {
        self.options
            .get(key)
            .map(|s| s.as_str())
            .ok_or_else(|| Error::config(format!("missing required option --{key}")))
    }

    /// Optional string option.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Optional string with default.
    pub fn opt_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    /// Boolean flag (present, or explicitly true/false).
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.opt(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Typed option with default; errors if present but unparseable.
    pub fn opt_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.opt(key) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .map_err(|_| Error::config(format!("option --{key}: cannot parse '{s}'"))),
        }
    }

    /// An `on|off` toggle (also accepts true/false, 1/0, yes/no; a bare
    /// `--flag` means on). Used by `--prefetch`.
    pub fn on_off(&self, key: &str, default: bool) -> Result<bool> {
        match self.opt(key) {
            None => Ok(default),
            Some("on" | "true" | "1" | "yes") => Ok(true),
            Some("off" | "false" | "0" | "no") => Ok(false),
            Some(s) => {
                Err(Error::config(format!("option --{key}: expected on|off, got '{s}'")))
            }
        }
    }

    /// The shared `--threads` knob for the GMW engine's lane parallelism.
    /// `--threads 0` (or omitting the flag with `default0 = true` semantics
    /// at the call site) means "auto": use every available core. Results
    /// are bit-identical for any value; this only changes wall-clock.
    pub fn threads(&self, default: usize) -> Result<usize> {
        let t: usize = self.opt_parse("threads", default)?;
        Ok(if t == 0 { crate::util::threadpool::default_threads() } else { t })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string()))
    }

    #[test]
    fn positionals_and_options() {
        let a = parse("serve --model mini --batch 8 extra");
        assert_eq!(a.subcommand(), Some("serve"));
        assert_eq!(a.req("model").unwrap(), "mini");
        assert_eq!(a.opt_parse::<usize>("batch", 1).unwrap(), 8);
        assert_eq!(a.positional, vec!["serve", "extra"]);
    }

    #[test]
    fn equals_syntax_and_flags() {
        let a = parse("run --verbose --k=12 --neg -5");
        assert!(a.flag("verbose"));
        assert_eq!(a.opt_parse::<i32>("k", 0).unwrap(), 12);
        // "-5" does not start with --, so it is consumed as --neg's value
        assert_eq!(a.opt_parse::<i32>("neg", 0).unwrap(), -5);
    }

    #[test]
    fn threads_knob() {
        // Explicit value passes through.
        assert_eq!(parse("x --threads 3").threads(1).unwrap(), 3);
        // 0 resolves to all available cores.
        let auto = parse("x --threads 0").threads(1).unwrap();
        assert_eq!(auto, crate::util::threadpool::default_threads());
        assert!(auto >= 1);
        // Missing flag uses the caller's default.
        assert_eq!(parse("x").threads(1).unwrap(), 1);
        assert!(parse("x --threads banana").threads(1).is_err());
    }

    #[test]
    fn on_off_knob() {
        assert!(parse("x --prefetch on").on_off("prefetch", false).unwrap());
        assert!(!parse("x --prefetch off").on_off("prefetch", true).unwrap());
        // Bare flag means on; missing flag uses the default.
        assert!(parse("x --prefetch").on_off("prefetch", false).unwrap());
        assert!(!parse("x").on_off("prefetch", false).unwrap());
        assert!(parse("x").on_off("prefetch", true).unwrap());
        assert!(parse("x --prefetch maybe").on_off("prefetch", false).is_err());
    }

    #[test]
    fn missing_and_bad_values() {
        let a = parse("x");
        assert!(a.req("model").is_err());
        let a = parse("x --n abc");
        assert!(a.opt_parse::<usize>("n", 3).is_err());
        assert_eq!(parse("x").opt_parse::<usize>("n", 3).unwrap(), 3);
    }
}
