//! Differential kernel-equivalence harness (DESIGN.md §11): the explicit
//! AVX2 arm must be **bit-identical** to the always-available scalar
//! reference — per-primitive outputs, wire bytes and protocol traces —
//! for every registered backend pair (forced-scalar vs auto-dispatched vs
//! forced-SIMD, lane and bitsliced layouts), over a seeded PRG sweep of
//! window widths `w ∈ 1..=64`, ragged lane counts (`n ≢ 0 mod 64`),
//! segment offsets and thread counts 1/N.
//!
//! On a machine without AVX2 (or under `HB_KERNEL=scalar`) every arm
//! resolves to the portable loops and the sweep degenerates to
//! scalar-vs-scalar — still green, still pinning the dispatch plumbing.
//!
//! A failing case is fed to a shrinking minimizer that greedily reduces
//! `(seed, w, n, offset)` while the divergence reproduces, then prints a
//! one-line `KERNEL-DIFF repro: …` record before panicking, so a CI hit
//! on exotic hardware is immediately replayable from the log.

use hummingbird::bitpack;
use hummingbird::crypto::prg::Prg;
use hummingbird::gmw::harness::{run_parties_with_threaded, HarnessRun};
use hummingbird::gmw::kernels::{BitslicedKernels, KernelBackend, KernelChoice, RustKernels};
use hummingbird::gmw::{bitsliced, simd, ReluPlan};
use hummingbird::ring;
use hummingbird::sharing::share_arith;

/// One point of the sweep. `offset` doubles as the lane-primitive slice
/// offset and the wire segment's global `lane0`, so both the suffix-slice
/// kernel paths and the unaligned pack path get exercised.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Case {
    seed: u64,
    w: u32,
    n: usize,
    offset: usize,
}

type Check = std::result::Result<(), String>;

/// First-divergence report for two word buffers.
fn diff_words(label: &str, got: &[u64], want: &[u64]) -> Check {
    if got == want {
        return Ok(());
    }
    let i = got.iter().zip(want).position(|(a, b)| a != b).unwrap_or(0);
    Err(format!(
        "{label}: word {i} diverges (got {:#018x}, want {:#018x})",
        got.get(i).copied().unwrap_or(0),
        want.get(i).copied().unwrap_or(0)
    ))
}

/// First-divergence report for two wire-byte buffers.
fn diff_bytes(label: &str, got: &[u8], want: &[u8]) -> Check {
    if got == want {
        return Ok(());
    }
    let i = got.iter().zip(want).position(|(a, b)| a != b).unwrap_or(0);
    Err(format!(
        "{label}: wire byte {i} diverges (got {:#04x}, want {:#04x})",
        got.get(i).copied().unwrap_or(0),
        want.get(i).copied().unwrap_or(0)
    ))
}

/// The registered arms of one backend family: the always-scalar
/// reference, the auto-dispatched default, and — where the CPU allows the
/// construction — the forced-SIMD arm.
fn rust_arms() -> Vec<(&'static str, RustKernels)> {
    let mut arms =
        vec![("rust/scalar", RustKernels::scalar()), ("rust/auto", RustKernels::default())];
    if simd::available() {
        arms.push(("rust/simd", RustKernels::with_kernel(KernelChoice::Simd).unwrap()));
    }
    arms
}

fn bitsliced_arms() -> Vec<(&'static str, BitslicedKernels)> {
    let mut arms = vec![
        ("bitsliced/scalar", BitslicedKernels::scalar()),
        ("bitsliced/auto", BitslicedKernels::default()),
    ];
    if simd::available() {
        arms.push(("bitsliced/simd", BitslicedKernels::with_kernel(KernelChoice::Simd).unwrap()));
    }
    arms
}

/// Run every primitive of every registered arm against the forced-scalar
/// reference for one `(seed, w, n, offset)` point. Returns the first
/// divergence as an `Err` naming the primitive and arm.
fn check_case(c: Case) -> Check {
    let Case { seed, w, n, offset } = c;
    let mut prg = Prg::new(seed, 0xD1FF);
    let mask = ring::low_mask(w);
    let total = offset + n;
    let masked = |prg: &mut Prg| -> Vec<u64> {
        (0..total).map(|_| prg.next_u64() & mask).collect()
    };
    // Boolean operands (masked to the window) and arithmetic operands
    // (full ring). The kernels read suffix slices `[offset..]`, the same
    // shape the threaded split hands them in production.
    let g = masked(&mut prg);
    let p = masked(&mut prg);
    let ta = masked(&mut prg);
    let tb = masked(&mut prg);
    let tc = masked(&mut prg);
    let x = prg.vec_u64(total);
    let y = prg.vec_u64(total);
    let xa = prg.vec_u64(total);
    let xb = prg.vec_u64(total);
    let xc = prg.vec_u64(total);
    let (gs, ps) = (&g[offset..], &p[offset..]);
    let (tas, tbs, tcs) = (&ta[offset..], &tb[offset..], &tc[offset..]);
    let (xs, ys) = (&x[offset..], &y[offset..]);
    let (xas, xbs, xcs) = (&xa[offset..], &xb[offset..], &xc[offset..]);
    let stages: [(u32, bool); 4] =
        [(1, false), ((w / 2).max(1), false), (1, true), (w.saturating_sub(1).max(1), true)];

    // --- Lane-per-u64 family -------------------------------------------
    let mut reference = RustKernels::scalar();
    let mut want_open = vec![0u64; 2 * n];
    reference.and_open(gs, ps, tas, tbs, &mut want_open);
    let want_combine: Vec<Vec<u64>> = [false, true]
        .iter()
        .map(|&leader| {
            let mut out = vec![0u64; n];
            reference.and_combine(gs, ps, tas, tbs, tcs, leader, &mut out);
            out
        })
        .collect();
    let want_stage: Vec<(Vec<u64>, Vec<u64>)> = stages
        .iter()
        .map(|&(s, last)| {
            let halves = if last { 1 } else { 2 };
            let mut u = vec![0u64; halves * n];
            let mut v = vec![0u64; halves * n];
            reference.ks_stage_operands(gs, ps, s, w, last, &mut u, &mut v);
            (u, v)
        })
        .collect();
    let mut want_mopen = vec![0u64; 2 * n];
    reference.mult_open(xs, ys, xas, xbs, &mut want_mopen);
    let want_mcombine: Vec<Vec<u64>> = [false, true]
        .iter()
        .map(|&leader| {
            let mut out = vec![0u64; n];
            reference.mult_combine(xs, ys, xas, xbs, xcs, leader, &mut out);
            out
        })
        .collect();

    for threads in [1usize, 3] {
        for (name, proto) in rust_arms() {
            let mut k = proto.clone();
            k.set_threads(threads);
            let ctx = |prim: &str| format!("{name} {prim} t={threads} {c:?}");

            let mut out = vec![0u64; 2 * n];
            k.and_open(gs, ps, tas, tbs, &mut out);
            diff_words(&ctx("and_open"), &out, &want_open)?;

            for (li, &leader) in [false, true].iter().enumerate() {
                let mut out = vec![0u64; n];
                k.and_combine(gs, ps, tas, tbs, tcs, leader, &mut out);
                diff_words(&ctx(&format!("and_combine leader={leader}")), &out, &want_combine[li])?;
            }

            for (si, &(s, last)) in stages.iter().enumerate() {
                let halves = if last { 1 } else { 2 };
                let mut u = vec![0u64; halves * n];
                let mut v = vec![0u64; halves * n];
                k.ks_stage_operands(gs, ps, s, w, last, &mut u, &mut v);
                diff_words(&ctx(&format!("ks_stage u s={s} last={last}")), &u, &want_stage[si].0)?;
                diff_words(&ctx(&format!("ks_stage v s={s} last={last}")), &v, &want_stage[si].1)?;
            }

            let mut out = vec![0u64; 2 * n];
            k.mult_open(xs, ys, xas, xbs, &mut out);
            diff_words(&ctx("mult_open"), &out, &want_mopen)?;
            for (li, &leader) in [false, true].iter().enumerate() {
                let mut out = vec![0u64; n];
                k.mult_combine(xs, ys, xas, xbs, xcs, leader, &mut out);
                diff_words(
                    &ctx(&format!("mult_combine leader={leader}")),
                    &out,
                    &want_mcombine[li],
                )?;
            }
        }
    }

    // --- Bitsliced family ----------------------------------------------
    // Plane buffers built from the masked lanes (zero tail lanes, the
    // layout invariant every plane kernel assumes). The transpose pair
    // itself is the reference: planes must round-trip back to the lanes.
    let pl = bitsliced::plane_len(n, w);
    let to_planes = |lanes: &[u64]| -> Vec<u64> {
        let mut planes = vec![0u64; pl];
        bitsliced::lanes_to_planes(lanes, w, &mut planes, 1);
        planes
    };
    let (gp, pp) = (to_planes(gs), to_planes(ps));
    let (tap, tbp, tcp) = (to_planes(tas), to_planes(tbs), to_planes(tcs));
    let mut back = vec![0u64; n];
    bitsliced::planes_to_lanes(&gp, w, n, &mut back, 1);
    diff_words(&format!("plane round-trip {c:?}"), &back, gs)?;

    let mut reference = BitslicedKernels::scalar();
    let mut want_open = vec![0u64; 2 * pl];
    reference.and_open(&gp, &pp, &tap, &tbp, &mut want_open);
    let want_combine: Vec<Vec<u64>> = [false, true]
        .iter()
        .map(|&leader| {
            let mut out = vec![0u64; pl];
            reference.and_combine(&gp, &pp, &tap, &tbp, &tcp, leader, &mut out);
            out
        })
        .collect();
    let want_stage: Vec<(Vec<u64>, Vec<u64>)> = stages
        .iter()
        .map(|&(s, last)| {
            let halves = if last { 1 } else { 2 };
            let mut u = vec![0u64; halves * pl];
            let mut v = vec![0u64; halves * pl];
            reference.ks_stage_operands(&gp, &pp, s, w, last, &mut u, &mut v);
            (u, v)
        })
        .collect();

    for threads in [1usize, 3] {
        for (name, proto) in bitsliced_arms() {
            let mut k = proto.clone();
            k.set_threads(threads);
            let ctx = |prim: &str| format!("{name} {prim} t={threads} {c:?}");

            let mut out = vec![0u64; 2 * pl];
            k.and_open(&gp, &pp, &tap, &tbp, &mut out);
            diff_words(&ctx("and_open"), &out, &want_open)?;

            for (li, &leader) in [false, true].iter().enumerate() {
                let mut out = vec![0u64; pl];
                k.and_combine(&gp, &pp, &tap, &tbp, &tcp, leader, &mut out);
                diff_words(&ctx(&format!("and_combine leader={leader}")), &out, &want_combine[li])?;
            }

            for (si, &(s, last)) in stages.iter().enumerate() {
                let halves = if last { 1 } else { 2 };
                let mut u = vec![0u64; halves * pl];
                let mut v = vec![0u64; halves * pl];
                k.ks_stage_operands(&gp, &pp, s, w, last, &mut u, &mut v);
                diff_words(&ctx(&format!("ks_stage u s={s} last={last}")), &u, &want_stage[si].0)?;
                diff_words(&ctx(&format!("ks_stage v s={s} last={last}")), &v, &want_stage[si].1)?;
            }
        }
    }

    // --- Wire boundary --------------------------------------------------
    // The fused transpose pack/unpack with the explicit arm flag forced
    // both ways (function-level flags bypass `HB_KERNEL`, so this stays a
    // genuine scalar-vs-AVX2 diff whenever the CPU has AVX2). The segment
    // starts at global lane `offset`, covering both the aligned and the
    // bit-shift pack paths.
    let nbytes = bitpack::packed_bytes(offset + n, w) as usize;
    for threads in [1usize, 3] {
        let mut wire_scalar = vec![0u8; nbytes];
        bitsliced::pack_planes_xor_into_with(&gp, w, n, offset, &mut wire_scalar, threads, false);
        let mut wire_simd = vec![0u8; nbytes];
        bitsliced::pack_planes_xor_into_with(&gp, w, n, offset, &mut wire_simd, threads, true);
        diff_bytes(&format!("pack_planes t={threads} {c:?}"), &wire_simd, &wire_scalar)?;

        let mut planes_scalar = vec![0u64; pl];
        bitsliced::unpack_bytes_xor_into_planes_with(
            &wire_scalar,
            w,
            n,
            offset,
            &mut planes_scalar,
            threads,
            false,
        );
        let mut planes_simd = vec![0u64; pl];
        bitsliced::unpack_bytes_xor_into_planes_with(
            &wire_scalar,
            w,
            n,
            offset,
            &mut planes_simd,
            threads,
            true,
        );
        diff_words(&format!("unpack_planes t={threads} {c:?}"), &planes_simd, &planes_scalar)?;
        // Pack→unpack must reproduce the original planes exactly (the
        // wire held only this segment's lanes).
        diff_words(&format!("wire round-trip t={threads} {c:?}"), &planes_scalar, &gp)?;
    }

    // --- 64×64 transpose -------------------------------------------------
    let mut m = [0u64; 64];
    for v in m.iter_mut() {
        *v = prg.next_u64();
    }
    let mut scalar = m;
    bitsliced::transpose64(&mut scalar);
    let mut dispatched = m;
    if simd::transpose64(&mut dispatched) {
        diff_words(&format!("transpose64 {c:?}"), &dispatched, &scalar)?;
    }

    Ok(())
}

/// Greedily shrink a failing case one coordinate at a time while the
/// divergence reproduces, then print the canonical repro line and panic.
fn shrink_and_panic(mut cur: Case, mut err: String) -> ! {
    loop {
        let mut candidates: Vec<Case> = Vec::new();
        if cur.n > 1 {
            candidates.push(Case { n: cur.n / 2, ..cur });
            candidates.push(Case { n: cur.n - 1, ..cur });
        }
        if cur.offset > 0 {
            candidates.push(Case { offset: 0, ..cur });
            candidates.push(Case { offset: cur.offset / 2, ..cur });
            candidates.push(Case { offset: cur.offset - 1, ..cur });
        }
        if cur.w > 1 {
            candidates.push(Case { w: cur.w / 2, ..cur });
            candidates.push(Case { w: cur.w - 1, ..cur });
        }
        if cur.seed != 0 {
            candidates.push(Case { seed: 0, ..cur });
            candidates.push(Case { seed: cur.seed / 2, ..cur });
        }
        let step = candidates.into_iter().find_map(|cand| match check_case(cand) {
            Err(e) => Some((cand, e)),
            Ok(()) => None,
        });
        match step {
            Some((cand, e)) => {
                cur = cand;
                err = e;
            }
            None => break,
        }
    }
    println!(
        "KERNEL-DIFF repro: seed={} w={} n={} offset={}",
        cur.seed, cur.w, cur.n, cur.offset
    );
    panic!("kernel arms diverged at minimized case {cur:?}: {err}");
}

fn run_case(c: Case) {
    if let Err(e) = check_case(c) {
        eprintln!("kernel-diff case {c:?} failed ({e}); shrinking…");
        shrink_and_panic(c, e);
    }
}

/// The seeded randomized sweep: widths across the full `1..=64` range,
/// lane counts biased ragged (`n ≢ 0 mod 64`), offsets spanning aligned
/// (multiples of 64) and bit-shifted segments.
#[test]
fn randomized_kernel_arm_sweep() {
    let mut prg = Prg::new(0xD1FF_CA5E, 0);
    for i in 0..48u64 {
        let w = 1 + (prg.next_u64() % 64) as u32;
        let mut n = 1 + (prg.next_u64() % 200) as usize;
        if i % 4 != 0 && n % 64 == 0 {
            n += 1; // bias ragged: the tail-lane paths are where arms differ
        }
        let offset = match i % 3 {
            0 => 0,
            1 => 64 * (1 + (prg.next_u64() % 3) as usize),
            _ => 1 + (prg.next_u64() % 63) as usize,
        };
        run_case(Case { seed: prg.next_u64(), w, n, offset });
    }
}

/// Deterministic boundary cases, small enough to replay anywhere: the
/// degenerate window, full width, exact block multiples and the awkward
/// straddlers. Doubles as the quick smoke leg of the harness.
#[test]
fn boundary_kernel_arm_cases() {
    for c in [
        Case { seed: 1, w: 1, n: 1, offset: 0 },
        Case { seed: 2, w: 1, n: 64, offset: 0 },
        Case { seed: 3, w: 6, n: 65, offset: 64 },
        Case { seed: 4, w: 13, n: 30, offset: 7 },
        Case { seed: 5, w: 20, n: 129, offset: 1 },
        Case { seed: 6, w: 64, n: 64, offset: 0 },
        Case { seed: 7, w: 64, n: 67, offset: 63 },
        Case { seed: 8, w: 33, n: 128, offset: 128 },
    ] {
        run_case(c);
    }
}

/// The shrinking minimizer itself must converge and keep a genuinely
/// failing predicate failing (exercised against a synthetic predicate,
/// not a broken kernel): every shrink candidate re-runs `check_case`, so
/// a healthy build reaches this test only if all candidates pass — which
/// is exactly what we assert.
#[test]
fn shrinker_candidates_all_pass_on_healthy_build() {
    // The candidate cloud around a mid-size point: if shrinking were ever
    // needed, these are the cases it would probe first.
    let c = Case { seed: 99, w: 18, n: 100, offset: 32 };
    for cand in [
        c,
        Case { n: 50, ..c },
        Case { n: 99, ..c },
        Case { offset: 0, ..c },
        Case { offset: 16, ..c },
        Case { w: 9, ..c },
        Case { w: 17, ..c },
        Case { seed: 0, ..c },
    ] {
        check_case(cand).unwrap();
    }
}

/// Protocol-level differential: full ReLU runs must be bit-identical —
/// per-party output shares, wire bytes and round counts — between the
/// forced-scalar arm and the auto-dispatched arm, in both layouts, for
/// 2/3 parties and threads 1/N. This is the end-to-end closure of the
/// per-primitive sweep above: if a dispatch site were missed somewhere in
/// the engine, the traces would still agree (both arms are bit-exact),
/// and if an arm were wrong, the primitive sweep pins which one.
#[test]
fn protocol_relu_bit_identical_across_kernel_arms() {
    let plan = ReluPlan::new(12, 4).unwrap();
    let n = 195usize; // ragged on purpose: straddles three 64-lane blocks
    let mut prg = Prg::new(0xA11E, 3);
    let x: Vec<u64> = (0..n)
        .map(|i| {
            let v = prg.next_u64() % (1 << 11);
            if i % 2 == 0 {
                v
            } else {
                v.wrapping_neg()
            }
        })
        .collect();
    for parties in [2usize, 3] {
        let xs = share_arith(&mut prg, &x, parties);
        for threads in [1usize, 3] {
            macro_rules! relu_run {
                ($kf:expr) => {
                    run_parties_with_threaded(parties, 77, threads, $kf, |p| {
                        let me = p.party();
                        p.relu(&xs[me], plan).unwrap()
                    })
                };
            }
            let ctx = format!("parties={parties} threads={threads}");

            let scalar_lane = relu_run!(|_| RustKernels::scalar());
            let auto_lane = relu_run!(|_| RustKernels::default());
            assert_traces_equal(&scalar_lane, &auto_lane, &format!("lane scalar-vs-auto {ctx}"));

            let scalar_sliced = relu_run!(|_| BitslicedKernels::scalar());
            let auto_sliced = relu_run!(|_| BitslicedKernels::default());
            assert_traces_equal(
                &scalar_sliced,
                &auto_sliced,
                &format!("bitsliced scalar-vs-auto {ctx}"),
            );
            // Cross-layout equality is pinned in depth by
            // tests/bitsliced_layout.rs; assert the corner here so a
            // kernel-arm regression can't hide behind a layout diff.
            assert_traces_equal(&scalar_lane, &scalar_sliced, &format!("cross-layout {ctx}"));

            if simd::available() {
                let simd_lane =
                    relu_run!(|_| RustKernels::with_kernel(KernelChoice::Simd).unwrap());
                assert_traces_equal(
                    &scalar_lane,
                    &simd_lane,
                    &format!("lane scalar-vs-simd {ctx}"),
                );
                let simd_sliced =
                    relu_run!(|_| BitslicedKernels::with_kernel(KernelChoice::Simd).unwrap());
                assert_traces_equal(
                    &scalar_sliced,
                    &simd_sliced,
                    &format!("bitsliced scalar-vs-simd {ctx}"),
                );
            }
        }
    }
}

/// Share, wire-byte and round equality between two protocol runs.
fn assert_traces_equal<R: PartialEq + std::fmt::Debug>(
    a: &HarnessRun<R>,
    b: &HarnessRun<R>,
    ctx: &str,
) {
    assert_eq!(a.outputs, b.outputs, "per-party output shares differ: {ctx}");
    assert_eq!(a.trace.total_bytes(), b.trace.total_bytes(), "wire bytes differ: {ctx}");
    assert_eq!(a.trace.total_rounds(), b.trace.total_rounds(), "round counts differ: {ctx}");
}
