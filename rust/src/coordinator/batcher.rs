//! Request queue + dynamic batcher + party thread pool.
//!
//! # Degradation under faults (DESIGN.md §7)
//!
//! Party threads never take the process down: every fallible step reports
//! into the batcher over the output channel, a faulted batch answers its
//! requests with an error (counted in
//! [`Metrics`](super::metrics::Metrics)), and the batcher then tears the
//! party session down and spawns a fresh one from the retained
//! [`SessionSpec`] — the next batch is served by clean parties on the
//! same coordinator, accounting onto the same long-lived trace.
//!
//! # Overload, lifecycle, and drain (DESIGN.md §9)
//!
//! The serving core above the sessions is overload-safe: admission is
//! **bounded** (`--queue-depth` caps the request queue; a full queue
//! fast-fails with [`Error::Overloaded`]), queued requests carry an
//! optional **deadline** (`--request-timeout-ms`; the batcher sheds
//! expired requests at dequeue so a dead request never occupies a batch
//! slot), session respawn runs under a **crash-loop breaker**
//! (`--max-restarts` consecutive failures flip the coordinator to
//! `Degraded`, where a background probe retries the boot with capped
//! backoff), and shutdown **drains**: admission closes, queued work is
//! served until the drain deadline, then everything force-stops. The
//! lifecycle (`Serving → Degraded → Draining → Stopped`) and the
//! per-request disposition counters are surfaced by
//! [`Metrics::snapshot`](super::metrics::Metrics::snapshot).
//!
//! # WAN simulation and batch overlap (DESIGN.md §10)
//!
//! `--net-profile` wraps every party transport in a
//! [`SimTransport`] so each protocol round really waits out its modeled
//! `latency + bytes/bandwidth` wire time; `--overlap` keeps **two**
//! batches in flight — batch k+1 is filled, encoded, shared and
//! dispatched while batch k's latency-bound binary rounds are still on
//! the (simulated) wire, so serving throughput tracks
//! `max(compute, wire)` instead of their sum. Results are bit-identical
//! with overlap on or off: the schedule changes, the protocol does not.

use std::collections::VecDeque;
use std::sync::mpsc::{
    channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TryRecvError,
    TrySendError,
};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::beaver::schedule::TripleSchedule;
use crate::crypto::prg::Prg;
use crate::error::{Error, Result};
use crate::gmw::kernels::{self, BinLayout, BitslicedKernels, KernelChoice, RustKernels};
use crate::gmw::GmwParty;
use crate::hummingbird::PlanSet;
use crate::model::{Archive, ExecBreakdown, ModelConfig, PlainExecutor, ShareExecutor, ShareWeights};
use crate::net::accounting::{CommTrace, Phase};
use crate::net::fault::{FaultProfile, FaultyTransport};
use crate::net::local::hub_with;
use crate::net::profile::NetworkProfile;
use crate::net::sim::SimTransport;
use crate::net::{NetConfig, Transport};
use crate::ring::FixedPoint;
use crate::runtime::{Manifest, Runtime, XlaKernels};
use crate::sharing::share_arith;
use crate::tensor::TensorU64;

use super::breaker::{BreakerVerdict, ClockHandle, RestartBreaker};
use super::metrics::{LifecycleState, Metrics, MetricsSnapshot};

/// Default force-stop deadline for `shutdown()`/`Drop` (DESIGN.md §9).
pub const DEFAULT_DRAIN: Duration = Duration::from_secs(30);
/// How long an idle batcher waits per poll before rechecking lifecycle.
const IDLE_POLL: Duration = Duration::from_millis(250);
/// Degraded-state housekeeping quantum: queue drain + probe check cadence.
const DEGRADED_TICK: Duration = Duration::from_millis(20);

/// Serving options.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Repo root (contains artifacts/ and configs/).
    pub repo_root: std::path::PathBuf,
    /// Model config name, e.g. "miniresnet_synth10".
    pub model: String,
    /// Plan file name under configs/searched/, or None for baseline.
    pub plan: Option<PlanSet>,
    pub parties: usize,
    /// How long the batcher waits to fill a batch before flushing.
    pub batch_timeout: Duration,
    pub session_seed: u64,
    /// Kernel backend for the GMW engine: "rust" (default) or "xla".
    pub gmw_backend: String,
    /// Binary-share layout for the "rust" backend: lane-per-u64 (default)
    /// or bitsliced (64 lanes per word through the DReLU circuit). Results
    /// and wire bytes are bit-identical either way; the XLA backend only
    /// supports the lane layout. CLI flag `--layout`.
    pub layout: BinLayout,
    /// Plane-kernel dispatch arm for the "rust" backend (CLI flag
    /// `--kernel`, DESIGN.md §11): `auto` (default) takes the AVX2 arm
    /// when the CPU supports it, `scalar` pins the portable reference and
    /// `simd` fails boot on machines without AVX2. The `HB_KERNEL` env
    /// var overrides this field. Both arms are bit-identical — the boot
    /// selfcheck ([`kernels::selfcheck`]) enforces it before the service
    /// admits a request.
    pub kernel: KernelChoice,
    /// Lane-parallelism budget per party for local GMW compute (kernels +
    /// fused bitpack). 0 = auto: divide the machine's cores across the
    /// simulated parties. Results are bit-identical for any value.
    pub threads: usize,
    /// Offline/online phase split (CLI flag `--prefetch on|off`): when
    /// true, each party thread provisions its Beaver correlations on a
    /// background prefetcher sized from the model's per-batch draw
    /// schedule (`TripleSchedule::for_forward`), warmed before the party
    /// admits its first job and cycling one batch ahead thereafter — so no
    /// dealer PRG expansion happens inside the online AND rounds. Results,
    /// wire bytes and `TripleUsage` are bit-identical either way.
    pub prefetch: bool,
    /// Session-layer deadlines (`--round-timeout-ms` etc., DESIGN.md §7):
    /// a party thread that misses `net.round_timeout` fails its batch
    /// instead of wedging the coordinator.
    pub net: NetConfig,
    /// Deterministic fault injection for chaos testing (`--fault-profile`,
    /// see [`crate::net::fault`]). Applied to the *initial* party session
    /// only: a respawned session after the injected fault runs clean,
    /// which is exactly what the recovery tests assert (`bootfail:` boot
    /// failures are the exception — they are consumed one per spawn
    /// attempt). `None` in production.
    pub fault_profile: Option<FaultProfile>,
    /// Bounded admission (`--queue-depth`, DESIGN.md §9): at most this
    /// many requests wait in the queue; further submissions fast-fail
    /// with [`Error::Overloaded`]. Clamped to ≥ 1.
    pub queue_depth: usize,
    /// Per-request deadline (`--request-timeout-ms`, DESIGN.md §9):
    /// stamped at admission; the batcher sheds a request whose deadline
    /// expired while queued ([`Error::Deadline`]) and `infer()` stops
    /// waiting at the same instant. `None` = requests never expire.
    pub request_timeout: Option<Duration>,
    /// Crash-loop budget (`--max-restarts`, DESIGN.md §9): this many
    /// consecutive session failures inside `restart_window` flip the
    /// coordinator to `Degraded`.
    pub max_restarts: u32,
    /// Sliding window for the consecutive-failure count; failures farther
    /// apart than this never trip the breaker.
    pub restart_window: Duration,
    /// Time source for the crash-loop breaker. The default is the real
    /// monotonic clock; tests inject [`MockClock`](super::breaker::MockClock)
    /// so respawn-backoff timing is deterministic under parallel test
    /// threads.
    pub clock: ClockHandle,
    /// Simulated WAN link (`--net-profile`, DESIGN.md §10): wrap every
    /// party transport in a [`SimTransport`] so each protocol round
    /// really waits out its modeled `latency + bytes/bandwidth` wire
    /// time on the monotonic clock. `None` = plain in-process timing.
    /// Results and wire bytes are bit-identical either way; only time
    /// changes.
    pub net_profile: Option<NetworkProfile>,
    /// Pipelined serving (`--overlap on|off`, DESIGN.md §10): keep two
    /// batches in flight so batch k+1's fill/encode/share/dispatch
    /// overlaps batch k's latency-bound protocol rounds. Off = collect
    /// each batch before dispatching the next (the serial baseline).
    pub overlap: bool,
}

impl ServeOptions {
    pub fn new(repo_root: impl Into<std::path::PathBuf>, model: &str) -> Self {
        ServeOptions {
            repo_root: repo_root.into(),
            model: model.to_string(),
            plan: None,
            parties: 2,
            batch_timeout: Duration::from_millis(20),
            session_seed: 0x5e55_10,
            gmw_backend: "rust".into(),
            layout: BinLayout::default(),
            kernel: KernelChoice::default(),
            threads: 0,
            prefetch: false,
            net: NetConfig::default(),
            fault_profile: None,
            queue_depth: 256,
            request_timeout: None,
            max_restarts: 5,
            restart_window: Duration::from_secs(60),
            clock: ClockHandle::monotonic(),
            net_profile: None,
            overlap: false,
        }
    }
}

/// Resolve the `threads = 0` auto setting: split the machine's cores across
/// the co-located party threads (at least 1 each).
fn resolve_threads(threads: usize, parties: usize) -> usize {
    if threads == 0 {
        (crate::util::threadpool::default_threads() / parties.max(1)).max(1)
    } else {
        threads
    }
}

/// One inference answer.
#[derive(Debug, Clone)]
pub struct InferenceResult {
    pub logits: Vec<f32>,
    pub pred: usize,
    pub latency_s: f64,
    pub batch_size: usize,
}

struct Request {
    input: Vec<f32>,
    enqueued: Instant,
    /// Per-request deadline (DESIGN.md §9): the batcher sheds the request
    /// at dequeue once this instant passes, and `infer()` stops waiting.
    deadline: Option<Instant>,
    /// A faulted session answers with an error instead of never answering.
    resp: Sender<Result<InferenceResult>>,
}

impl Request {
    fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// Job sent to each party thread.
struct PartyJob {
    x_share: Vec<u64>,
    shape: Vec<usize>,
}

/// Output from a party thread: the job's output share, or the fault that
/// ended this party's session.
struct PartyOut {
    share: Vec<u64>,
    breakdown: ExecBreakdown,
}

/// Everything needed to (re)spawn a party session. Retained by the
/// batcher so a faulted session can be replaced without re-touching disk
/// state semantics: the same weights/config/plan clones boot every
/// incarnation, and party 0 of each incarnation accounts onto the same
/// long-lived trace.
struct SessionSpec {
    cfg: ModelConfig,
    weights: Archive,
    artifacts_root: std::path::PathBuf,
    model_art: crate::runtime::registry::ModelArtifacts,
    plans: PlanSet,
    parties: usize,
    seed: u64,
    backend: String,
    layout: BinLayout,
    kernel: KernelChoice,
    threads: usize,
    prefetch: bool,
    net: NetConfig,
    /// Taken by the first spawn: respawned sessions always run clean.
    fault: Option<FaultProfile>,
    /// Simulated WAN link (DESIGN.md §10): every incarnation's party
    /// transports are wrapped in a [`SimTransport`] pricing this profile.
    net_profile: Option<NetworkProfile>,
    /// Injected boot failures still owed (`bootfail:N` in the fault
    /// profile): consumed one per spawn attempt, *before* the round-level
    /// faults are taken, so the crash-loop breaker can be exercised
    /// deterministically.
    boot_fails: u32,
    trace: Arc<CommTrace>,
}

/// One incarnation of the party thread pool.
struct Session {
    job_txs: Vec<Sender<PartyJob>>,
    out_rx: Receiver<(usize, Result<PartyOut>)>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

/// Spawn a fresh party session from the spec, or fail its boot
/// (injected `bootfail:` budget — the crash-loop breaker's test hook).
/// The round-level fault profile (if any) is consumed by the first
/// *successful* spawn, so only that session misbehaves.
fn spawn_session(spec: &mut SessionSpec, metrics: &Arc<Metrics>) -> Result<Session> {
    if spec.boot_fails > 0 {
        spec.boot_fails -= 1;
        return Err(Error::runtime("injected session boot failure (bootfail)"));
    }
    let fault = spec.fault.take();
    let mut transports = hub_with(spec.parties, spec.net);
    transports[0].set_trace(Arc::clone(&spec.trace));
    let mut handles = Vec::new();
    let mut job_txs = Vec::new();
    let (out_tx, out_rx) = channel::<(usize, Result<PartyOut>)>();
    for t in transports {
        let (jtx, jrx) = channel::<PartyJob>();
        job_txs.push(jtx);
        let cfg = spec.cfg.clone();
        let weights = spec.weights.clone();
        let root = spec.artifacts_root.clone();
        let model_art = spec.model_art.clone();
        let plans = spec.plans.clone();
        let out_tx = out_tx.clone();
        let seed = spec.seed;
        let backend = spec.backend.clone();
        let layout = spec.layout;
        let kernel = spec.kernel;
        let threads = resolve_threads(spec.threads, spec.parties);
        let prefetch = spec.prefetch;
        let fault = fault.clone();
        let profile = spec.net_profile.clone();
        // The guard decrements Metrics::live_party_threads on any exit,
        // panics included (the soak's zero-orphans assertion reads it).
        let guard = metrics.party_thread_guard();
        handles.push(std::thread::spawn(move || {
            let _live = guard;
            // `--net-profile` wraps the hub endpoint in a SimTransport
            // (DESIGN.md §10); an injected fault profile wraps outermost
            // so faults are observed at simulated-WAN timing.
            match (fault, profile) {
                (Some(fp), Some(np)) => party_main(
                    FaultyTransport::new(SimTransport::new(t, np), &fp),
                    cfg,
                    weights,
                    root,
                    model_art,
                    plans,
                    jrx,
                    out_tx,
                    seed,
                    backend,
                    layout,
                    kernel,
                    threads,
                    prefetch,
                ),
                (Some(fp), None) => party_main(
                    FaultyTransport::new(t, &fp),
                    cfg,
                    weights,
                    root,
                    model_art,
                    plans,
                    jrx,
                    out_tx,
                    seed,
                    backend,
                    layout,
                    kernel,
                    threads,
                    prefetch,
                ),
                (None, Some(np)) => party_main(
                    SimTransport::new(t, np),
                    cfg,
                    weights,
                    root,
                    model_art,
                    plans,
                    jrx,
                    out_tx,
                    seed,
                    backend,
                    layout,
                    kernel,
                    threads,
                    prefetch,
                ),
                (None, None) => party_main(
                    t, cfg, weights, root, model_art, plans, jrx, out_tx, seed, backend, layout,
                    kernel, threads, prefetch,
                ),
            }
        }));
    }
    Ok(Session { job_txs, out_rx, handles })
}

/// Handle to a running service.
pub struct Coordinator {
    req_tx: Option<SyncSender<Request>>,
    pub metrics: Arc<Metrics>,
    pub trace: Arc<CommTrace>,
    batcher: Option<std::thread::JoinHandle<()>>,
    pub cfg: ModelConfig,
    request_timeout: Option<Duration>,
}

impl Coordinator {
    /// Boot the service: loads config/weights, spawns party + batcher
    /// threads, returns once ready.
    pub fn start(opts: ServeOptions) -> Result<Coordinator> {
        if opts.gmw_backend == "xla" && opts.layout == BinLayout::Bitsliced {
            return Err(Error::config(
                "--layout bitsliced requires the rust kernel backend (the XLA \
                 kernels are lane-per-u64)",
            ));
        }
        // Boot-time kernel cross-check (DESIGN.md §11): prove the
        // dispatched arm bit-identical to the forced-scalar reference on
        // every primitive before serving a single request. A mismatch (or
        // a forced-but-unavailable `simd`) is a typed `Error::Kernel` —
        // the coordinator fails fast instead of silently serving with a
        // diverging kernel.
        kernels::selfcheck(opts.kernel)?;
        let root = opts.repo_root.join("artifacts");
        let cfg = ModelConfig::load_named(&opts.repo_root, &opts.model)?;
        let weights = Archive::load(root.join("weights").join(&opts.model))?;
        let manifest = Manifest::load(&root)?;
        let model_art = manifest.model(&opts.model)?.clone();
        let batch = model_art.batch;
        let plans = opts.plan.clone().unwrap_or_else(|| PlanSet::baseline(cfg.relu_groups));

        // The trace outlives any single party session: every session's
        // party 0 accounts onto it (spawn_session), so byte/round numbers
        // keep accumulating across fault-triggered respawns.
        let trace = Arc::new(CommTrace::new());
        let boot_fails = opts.fault_profile.as_ref().map_or(0, |f| f.boot_fails);
        let spec = SessionSpec {
            cfg: cfg.clone(),
            weights,
            artifacts_root: root,
            model_art,
            plans,
            parties: opts.parties,
            seed: opts.session_seed,
            backend: opts.gmw_backend.clone(),
            layout: opts.layout,
            kernel: opts.kernel,
            threads: opts.threads,
            prefetch: opts.prefetch,
            net: opts.net,
            fault: opts.fault_profile.clone(),
            net_profile: opts.net_profile.clone(),
            boot_fails,
            trace: Arc::clone(&trace),
        };

        // Batcher thread: owns the session spec, the crash-loop breaker
        // and the lifecycle, and (re)spawns the party thread pool.
        let metrics = Arc::new(Metrics::new());
        let (req_tx, req_rx) = sync_channel::<Request>(opts.queue_depth.max(1));
        let m2 = Arc::clone(&metrics);
        let fx = FixedPoint::new(cfg.frac_bits);
        let input_shape = cfg.input;
        let classes = cfg.num_classes;
        let timeout = opts.batch_timeout;
        let trace2 = Arc::clone(&trace);
        let breaker = RestartBreaker::new(opts.max_restarts, opts.restart_window, opts.clock);
        let overlap = opts.overlap;
        let batcher = std::thread::spawn(move || {
            batcher_main(
                req_rx, spec, m2, fx, input_shape, classes, batch, timeout, trace2, breaker,
                overlap,
            );
        });

        Ok(Coordinator {
            req_tx: Some(req_tx),
            metrics,
            trace,
            batcher: Some(batcher),
            cfg,
            request_timeout: opts.request_timeout,
        })
    }

    /// Admission gate (DESIGN.md §9): refuse when degraded or draining,
    /// fast-fail on a full queue, otherwise stamp the request's deadline
    /// and enqueue it. Returns the response channel and the deadline.
    fn submit(
        &self,
        input: Vec<f32>,
    ) -> Result<(Receiver<Result<InferenceResult>>, Option<Instant>)> {
        let tx = self.req_tx.as_ref().ok_or_else(|| Error::unavailable("service stopped"))?;
        match self.metrics.state() {
            LifecycleState::Serving => {}
            LifecycleState::Degraded => {
                self.metrics.record_rejected_degraded();
                return Err(Error::overloaded(
                    "coordinator degraded: session boot is failing; retry later",
                ));
            }
            LifecycleState::Draining | LifecycleState::Stopped => {
                // Admission is closed while queued work drains. Counted
                // with the degraded refusals: both are pre-admission.
                self.metrics.record_rejected_degraded();
                return Err(Error::overloaded("coordinator draining: admission closed"));
            }
        }
        let now = Instant::now();
        let deadline = self.request_timeout.map(|d| now + d);
        let (rtx, rrx) = channel();
        match tx.try_send(Request { input, enqueued: now, deadline, resp: rtx }) {
            Ok(()) => {
                self.metrics.record_admitted();
                Ok((rrx, deadline))
            }
            Err(TrySendError::Full(_)) => {
                self.metrics.record_shed_queue_full();
                Err(Error::overloaded("request queue full (--queue-depth); retry later"))
            }
            Err(TrySendError::Disconnected(_)) => Err(Error::unavailable("service stopped")),
        }
    }

    /// Submit one inference and wait for the answer. A session fault
    /// surfaces as this job's error; the coordinator itself keeps serving.
    /// With `--request-timeout-ms` set, the wait honors the same deadline
    /// the batcher sheds on: an expired wait returns [`Error::Deadline`].
    pub fn infer(&self, input: Vec<f32>) -> Result<InferenceResult> {
        let (rx, deadline) = self.submit(input)?;
        match deadline {
            None => rx.recv().map_err(|_| Error::unavailable("service dropped request"))?,
            Some(d) => match rx.recv_timeout(d.saturating_duration_since(Instant::now())) {
                Ok(answer) => answer,
                Err(RecvTimeoutError::Timeout) => {
                    Err(Error::deadline("no answer before --request-timeout-ms"))
                }
                Err(RecvTimeoutError::Disconnected) => {
                    Err(Error::unavailable("service dropped request"))
                }
            },
        }
    }

    /// Submit asynchronously; returns the response channel (the payload is
    /// per-job: a faulted session answers `Err` rather than hanging up).
    pub fn infer_async(&self, input: Vec<f32>) -> Result<Receiver<Result<InferenceResult>>> {
        let (rx, _deadline) = self.submit(input)?;
        Ok(rx)
    }

    /// The single owner of teardown (DESIGN.md §9): closes admission,
    /// posts the drain deadline, and joins the batcher (which serves
    /// queued work until the deadline, then force-stops). Idempotent —
    /// `shutdown`, `shutdown_with_deadline` and `Drop` all land here.
    fn stop(&mut self, drain: Duration) {
        if self.req_tx.is_none() {
            return;
        }
        self.metrics.begin_drain(Instant::now() + drain);
        self.req_tx.take(); // closes the queue; batcher drains and exits
        if let Some(b) = self.batcher.take() {
            b.join().ok();
        }
    }

    /// Graceful shutdown (drains in-flight work, default deadline).
    pub fn shutdown(mut self) {
        self.stop(DEFAULT_DRAIN);
    }

    /// Graceful drain with an explicit force-stop deadline: admission
    /// closes immediately (new requests get [`Error::Overloaded`]),
    /// queued and in-flight work is served until `drain` elapses, then
    /// whatever is left is answered [`Error::Unavailable`] and counted as
    /// `drained`. Returns the final counters (state is `Stopped`).
    pub fn shutdown_with_deadline(mut self, drain: Duration) -> MetricsSnapshot {
        self.stop(drain);
        self.metrics.snapshot()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop(DEFAULT_DRAIN);
    }
}

/// Party thread entry point: boot failures and session faults drain into
/// the output channel (tagged with this party's id) instead of panicking —
/// the batcher turns them into per-job errors and a session respawn.
#[allow(clippy::too_many_arguments)]
fn party_main<T: Transport + 'static>(
    transport: T,
    cfg: ModelConfig,
    weights: Archive,
    artifacts_root: std::path::PathBuf,
    model_art: crate::runtime::registry::ModelArtifacts,
    plans: PlanSet,
    jobs: Receiver<PartyJob>,
    out: Sender<(usize, Result<PartyOut>)>,
    seed: u64,
    backend: String,
    layout: BinLayout,
    kernel: KernelChoice,
    threads: usize,
    prefetch: bool,
) {
    let me = transport.party();
    let boot = party_boot_and_loop(
        transport, cfg, weights, artifacts_root, model_art, plans, jobs, &out, seed, backend,
        layout, kernel, threads, prefetch,
    );
    if let Err(e) = boot {
        let _ = out.send((me, Err(e)));
    }
}

#[allow(clippy::too_many_arguments)]
fn party_boot_and_loop<T: Transport + 'static>(
    transport: T,
    cfg: ModelConfig,
    weights: Archive,
    artifacts_root: std::path::PathBuf,
    model_art: crate::runtime::registry::ModelArtifacts,
    plans: PlanSet,
    jobs: Receiver<PartyJob>,
    out: &Sender<(usize, Result<PartyOut>)>,
    seed: u64,
    backend: String,
    layout: BinLayout,
    kernel: KernelChoice,
    threads: usize,
    prefetch: bool,
) -> Result<()> {
    let me = transport.party();
    // Offline/online split: predict this model's per-batch dealer draws
    // (every job is padded to the full artifact batch, so one forward pass
    // repeats the same schedule) and hand them to a cycling background
    // prefetcher. `enable_prefetch` below also waits for the first buffers,
    // so the party is warm before it admits its first job.
    let schedule = prefetch.then(|| {
        TripleSchedule::for_forward(&cfg, &plans, model_art.batch, transport.parties())
    });
    let rt = Runtime::new(&artifacts_root)?;
    if !model_art.layers.is_empty() || backend == "xla" {
        // Linear layers (and the xla GMW kernel backend) will execute
        // PJRT artifacts: surface a missing or broken PJRT install at
        // boot, not at the first request.
        rt.ensure_client()?;
    }
    let sw = ShareWeights::prepare(&cfg, &weights)?;
    let mut exec = ShareExecutor::new(cfg, model_art, rt.clone(), sw);
    // The GMW engine: pure-Rust kernels (lane-per-u64 or bitsliced binary
    // layout per `--layout`), or the Pallas/PJRT backend for the full
    // three-layer path.
    if backend == "xla" {
        let manifest = Manifest::load(&artifacts_root)?;
        let kernels = XlaKernels::new(rt, manifest);
        let mut party = GmwParty::with_kernels(transport, seed, kernels);
        boot_party(&mut party, threads, schedule);
        party_loop(&mut exec, &mut party, &plans, jobs, out, me);
    } else if layout == BinLayout::Bitsliced {
        let mut party =
            GmwParty::with_kernels(transport, seed, BitslicedKernels::with_kernel(kernel)?);
        boot_party(&mut party, threads, schedule);
        party_loop(&mut exec, &mut party, &plans, jobs, out, me);
    } else {
        let mut party = GmwParty::with_kernels(transport, seed, RustKernels::with_kernel(kernel)?);
        boot_party(&mut party, threads, schedule);
        party_loop(&mut exec, &mut party, &plans, jobs, out, me);
    }
    Ok(())
}

/// Per-party engine knobs applied identically in every kernel branch.
/// `enable_prefetch` blocks until the first scheduled buffers are
/// expanded, so a prefetching party is warm before it admits its first
/// job.
fn boot_party<T: Transport, K: crate::gmw::kernels::KernelBackend>(
    party: &mut GmwParty<T, K>,
    threads: usize,
    schedule: Option<TripleSchedule>,
) {
    party.set_threads(threads);
    if let Some(s) = schedule {
        party.enable_prefetch(s, true);
    }
}

fn party_loop<T: Transport, K: crate::gmw::kernels::KernelBackend>(
    exec: &mut ShareExecutor,
    party: &mut GmwParty<T, K>,
    plans: &PlanSet,
    jobs: Receiver<PartyJob>,
    out: &Sender<(usize, Result<PartyOut>)>,
    me: usize,
) {
    // The executor and engine are long-lived: after the first batch warms
    // the activation pool, the scratch arena and the transport buffers,
    // steady-state batches reuse them all (ROADMAP "activation-buffer
    // reuse in model::ShareExecutor").
    while let Ok(job) = jobs.recv() {
        let result = TensorU64::new(job.shape.clone(), job.x_share)
            .and_then(|x| exec.forward(party, x, plans));
        match result {
            Ok((o, bd)) => {
                if out.send((me, Ok(PartyOut { share: o.data, breakdown: bd }))).is_err() {
                    return;
                }
            }
            Err(e) => {
                // An unrecovered fault (transparently recovered link drops
                // never reach here) leaves this session's round state
                // desynchronized from its peers: report and exit so the
                // batcher respawns the whole session.
                let _ = out.send((me, Err(e)));
                return;
            }
        }
    }
}

/// Retire a session whose batch failed: close its job queues so the party
/// threads drain out, but don't block serving on the join — a straggler
/// may take up to `round_timeout` to notice. Its handles move to the
/// graveyard and are reaped opportunistically (and joined at stop), so a
/// clean stop still guarantees zero orphaned party threads.
fn retire(session: Session, graveyard: &mut Vec<std::thread::JoinHandle<()>>) {
    let Session { job_txs, out_rx, handles } = session;
    drop(job_txs);
    drop(out_rx);
    graveyard.extend(handles);
}

/// Join whatever graveyard threads have already exited (keeps the
/// graveyard — and thus thread-handle memory — bounded during long runs).
fn reap(graveyard: &mut Vec<std::thread::JoinHandle<()>>) {
    let mut live = Vec::with_capacity(graveyard.len());
    for h in graveyard.drain(..) {
        if h.is_finished() {
            h.join().ok();
        } else {
            live.push(h);
        }
    }
    *graveyard = live;
}

/// Final teardown: join the live session (if any) and every graveyard
/// thread, then mark the lifecycle `Stopped`. After this returns there
/// are zero live party threads.
fn stop_all(
    session: Option<Session>,
    graveyard: Vec<std::thread::JoinHandle<()>>,
    metrics: &Metrics,
) {
    if let Some(s) = session {
        drop(s.job_txs);
        drop(s.out_rx);
        for h in s.handles {
            h.join().ok();
        }
    }
    for h in graveyard {
        h.join().ok();
    }
    metrics.set_state(LifecycleState::Stopped);
}

/// Acquire a session under the crash-loop breaker: spawn, and on boot
/// failure back off and retry until the breaker trips (→ `Degraded`,
/// returns `None`). `record_restart` marks replacement spawns so the
/// `sessions_restarted` counter excludes the initial boot.
fn ensure_session(
    spec: &mut SessionSpec,
    breaker: &mut RestartBreaker,
    metrics: &Arc<Metrics>,
    record_restart: bool,
) -> Option<Session> {
    loop {
        match spawn_session(spec, metrics) {
            Ok(s) => {
                if record_restart {
                    metrics.record_session_restart();
                }
                return Some(s);
            }
            Err(_) => match breaker.on_failure() {
                BreakerVerdict::Backoff(d) => breaker.clock().clone().sleep(d),
                BreakerVerdict::Trip => {
                    if metrics.state() == LifecycleState::Serving {
                        metrics.set_state(LifecycleState::Degraded);
                    }
                    return None;
                }
            },
        }
    }
}

/// Answer `pending` plus everything still buffered in the queue with
/// `Unavailable` and count them `drained` (the drain deadline expired
/// before they could be served).
fn drain_remaining(pending: &mut Vec<Request>, req_rx: &Receiver<Request>, metrics: &Metrics) {
    let mut n = 0u64;
    for r in pending.drain(..) {
        let _ = r.resp.send(Err(Error::unavailable("drain deadline expired")));
        n += 1;
    }
    while let Ok(r) = req_rx.try_recv() {
        let _ = r.resp.send(Err(Error::unavailable("drain deadline expired")));
        n += 1;
    }
    if n > 0 {
        metrics.record_drained(n);
    }
}

fn drain_expired(metrics: &Metrics) -> bool {
    metrics.drain_deadline().is_some_and(|dd| Instant::now() >= dd)
}

/// One dispatched batch awaiting its output shares (DESIGN.md §10).
struct InFlight {
    reqs: Vec<Request>,
    t0: Instant,
}

/// Answer a batch that can no longer be served (its session died while it
/// was queued behind an earlier batch's fault), keeping the §9 request
/// disposition identity: one failed job, `reqs.len()` failed requests.
fn fail_batch(fly: InFlight, metrics: &Metrics) {
    metrics.record_failed_batch(fly.reqs.len() as u64, false);
    for r in fly.reqs {
        let _ = r.resp.send(Err(Error::Runtime("inference failed: party session is down".into())));
    }
}

/// Force-stop path (§9): in-flight batches past the drain deadline are
/// answered `Unavailable` and counted `drained`, like queued requests.
fn drain_unserved_inflight(inflight: &mut VecDeque<InFlight>, metrics: &Metrics) {
    let mut n = 0u64;
    while let Some(fly) = inflight.pop_front() {
        for r in fly.reqs {
            let _ = r.resp.send(Err(Error::unavailable("drain deadline expired")));
            n += 1;
        }
    }
    if n > 0 {
        metrics.record_drained(n);
    }
}

/// Collect one in-flight batch's output shares and respond.
///
/// Every party sends exactly one message per job, in job order, but the
/// output channel is shared across parties: with `--overlap` a fast
/// party's report for batch k+1 can arrive before a slow party's for
/// batch k, so messages that outrun the batch being collected park in
/// per-party `carry` queues and are consumed first by the next
/// collection. On a fault, this batch's requests are answered with the
/// root cause and counted, and the error is returned so the caller can
/// retire the session and fail the rest of the pipeline.
#[allow(clippy::too_many_arguments)]
fn collect_one(
    cur: &Session,
    fly: InFlight,
    carry: &mut [VecDeque<Result<PartyOut>>],
    parties: usize,
    classes: usize,
    fx: FixedPoint,
    logits_ring: &mut [u64],
    metrics: &Metrics,
    trace: &CommTrace,
) -> Result<()> {
    let InFlight { reqs, t0 } = fly;
    let got = reqs.len();
    let mut outs: Vec<Option<PartyOut>> = (0..parties).map(|_| None).collect();
    let mut need = parties;
    let mut batch_err: Option<Error> = None;
    'collect: while need > 0 {
        // Parked messages first: the per-party FIFOs restore job order.
        let mut progressed = false;
        for (p, q) in carry.iter_mut().enumerate() {
            if outs[p].is_none() {
                if let Some(res) = q.pop_front() {
                    progressed = true;
                    match res {
                        Ok(o) => {
                            outs[p] = Some(o);
                            need -= 1;
                        }
                        Err(e) => {
                            batch_err = Some(e);
                            break 'collect;
                        }
                    }
                }
            }
        }
        if progressed {
            continue;
        }
        // The transports' own deadlines bound how long a faulted session
        // can take to report, so a plain blocking recv cannot wedge.
        match cur.out_rx.recv() {
            Ok((p, res)) => {
                if outs[p].is_some() {
                    // Outran this batch: park for the next collection.
                    carry[p].push_back(res);
                } else {
                    match res {
                        Ok(o) => {
                            outs[p] = Some(o);
                            need -= 1;
                        }
                        Err(e) => {
                            batch_err = Some(e);
                            break;
                        }
                    }
                }
            }
            Err(_) => {
                // All party threads are gone without a report.
                batch_err = Some(Error::Transport("party session died silently".into()));
                break;
            }
        }
    }
    if let Some(root_cause) = batch_err {
        // Graceful degradation (DESIGN.md §7): this batch failed — answer
        // its requests with the root cause and count it (one failed job,
        // `got` failed requests — the §9 identity).
        metrics.record_failed_batch(got as u64, matches!(root_cause, Error::Timeout(_)));
        let msg = format!("inference failed: {root_cause}");
        for r in reqs {
            let _ = r.resp.send(Err(Error::Runtime(msg.clone())));
        }
        return Err(root_cause);
    }
    // Party -> client output share movement (Data phase accounting).
    trace.record(Phase::Data, (logits_ring.len() * 8 * parties) as u64);
    logits_ring.fill(0);
    let mut bd = ExecBreakdown::default();
    let mut outs_n = 0;
    for o in outs.into_iter().flatten() {
        for (acc, v) in logits_ring.iter_mut().zip(&o.share) {
            *acc = acc.wrapping_add(*v);
        }
        // Parties run concurrently: the first party's breakdown stands in
        // for the batch (symmetric parties do symmetric work).
        if outs_n == 0 {
            bd = o.breakdown;
        }
        outs_n += 1;
    }
    let latency = t0.elapsed().as_secs_f64();
    metrics.record_batch(got, latency, &bd);
    // Respond.
    for (i, r) in reqs.into_iter().enumerate() {
        let row: Vec<f32> = logits_ring[i * classes..(i + 1) * classes]
            .iter()
            .map(|v| fx.decode(*v) as f32)
            .collect();
        let pred = PlainExecutor::argmax(&row, classes)[0];
        let wait_s = r.enqueued.elapsed().as_secs_f64();
        let _ = r.resp.send(Ok(InferenceResult {
            logits: row,
            pred,
            latency_s: wait_s,
            batch_size: got,
        }));
    }
    Ok(())
}

/// Pop and settle the oldest in-flight batch. On a collect fault the
/// pipeline behind it is doomed (the faulted party threads exited), so
/// fail the remaining in-flight batches, drop parked messages, retire
/// the session, and consult the crash-loop breaker — the same
/// degradation path as a serial batch fault (DESIGN.md §7).
#[allow(clippy::too_many_arguments)]
fn drain_one(
    inflight: &mut VecDeque<InFlight>,
    carry: &mut [VecDeque<Result<PartyOut>>],
    session: &mut Option<Session>,
    graveyard: &mut Vec<std::thread::JoinHandle<()>>,
    spec: &mut SessionSpec,
    breaker: &mut RestartBreaker,
    metrics: &Arc<Metrics>,
    clock: &ClockHandle,
    next_probe: &mut Duration,
    parties: usize,
    classes: usize,
    fx: FixedPoint,
    logits_ring: &mut [u64],
    trace: &CommTrace,
) {
    let Some(fly) = inflight.pop_front() else {
        return;
    };
    let Some(cur) = session.as_ref() else {
        fail_batch(fly, metrics);
        return;
    };
    match collect_one(cur, fly, carry, parties, classes, fx, logits_ring, metrics, trace) {
        Ok(()) => {
            // The batch succeeded: the session is healthy, close the breaker.
            breaker.on_success();
        }
        Err(_) => {
            while let Some(f) = inflight.pop_front() {
                fail_batch(f, metrics);
            }
            for q in carry.iter_mut() {
                q.clear();
            }
            if let Some(s) = session.take() {
                retire(s, graveyard);
            }
            match breaker.on_failure() {
                BreakerVerdict::Backoff(d) => {
                    clock.sleep(d);
                    *session = ensure_session(spec, breaker, metrics, true);
                    if session.is_none() {
                        *next_probe = clock.now();
                    }
                }
                BreakerVerdict::Trip => {
                    if metrics.state() == LifecycleState::Serving {
                        metrics.set_state(LifecycleState::Degraded);
                    }
                    *next_probe = clock.now();
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn batcher_main(
    req_rx: Receiver<Request>,
    mut spec: SessionSpec,
    metrics: Arc<Metrics>,
    fx: FixedPoint,
    input_shape: (usize, usize, usize),
    classes: usize,
    batch: usize,
    timeout: Duration,
    trace: Arc<CommTrace>,
    mut breaker: RestartBreaker,
    overlap: bool,
) {
    let parties = spec.parties;
    let per_sample = input_shape.0 * input_shape.1 * input_shape.2;
    let clock = breaker.clock().clone();
    let mut prg = Prg::from_entropy();
    let mut pending: Vec<Request> = Vec::new();
    let mut graveyard: Vec<std::thread::JoinHandle<()>> = Vec::new();
    // Initial boot runs under the same breaker as respawns: a
    // persistently failing boot lands in Degraded instead of looping.
    let mut session = ensure_session(&mut spec, &mut breaker, &metrics, false);
    let mut next_probe = clock.now();
    // Batch-sized staging buffers, reused across batches (the shares sent
    // to the party threads are still fresh vectors — they cross threads).
    let mut x_ring = vec![0u64; batch * per_sample];
    let mut logits_ring = vec![0u64; batch * classes];
    // Pipelined dispatch (DESIGN.md §10): a FIFO of dispatched batches
    // awaiting collection. Depth 1 (overlap off) reproduces the serial
    // dispatch-then-collect schedule; depth 2 lets batch k+1's
    // fill/encode/share/dispatch overlap batch k's protocol rounds.
    let depth = if overlap { 2 } else { 1 };
    let mut inflight: VecDeque<InFlight> = VecDeque::new();
    // Per-party reorder buffers for the shared output channel (see
    // `collect_one`). Cleared whenever a session is retired.
    let mut carry: Vec<VecDeque<Result<PartyOut>>> =
        (0..parties).map(|_| VecDeque::new()).collect();
    loop {
        reap(&mut graveyard);
        // Collect until the pipeline has room. Serial mode (depth 1)
        // settles the previous batch before filling the next window.
        while session.is_some() && inflight.len() >= depth {
            drain_one(
                &mut inflight,
                &mut carry,
                &mut session,
                &mut graveyard,
                &mut spec,
                &mut breaker,
                &metrics,
                &clock,
                &mut next_probe,
                parties,
                classes,
                fx,
                &mut logits_ring,
                &trace,
            );
        }
        // Degraded tick: no session. Answer queued work immediately,
        // probe the boot on the breaker's schedule, honor drain/stop.
        let cur = match session.take() {
            Some(s) => s,
            None => {
                loop {
                    match req_rx.try_recv() {
                        Ok(r) => {
                            if r.expired(Instant::now()) {
                                metrics.record_shed_deadline(1);
                                let _ = r.resp.send(Err(Error::deadline("expired while queued")));
                            } else {
                                // Admitted before (or racing) the trip:
                                // one terminal disposition, counted as a
                                // failed request to keep the §9 identity.
                                metrics.record_failed_requests(1);
                                let _ = r.resp.send(Err(Error::overloaded(
                                    "coordinator degraded: session boot is failing",
                                )));
                            }
                        }
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => {
                            stop_all(None, graveyard, &metrics);
                            return;
                        }
                    }
                }
                if drain_expired(&metrics) {
                    drain_remaining(&mut pending, &req_rx, &metrics);
                    stop_all(None, graveyard, &metrics);
                    return;
                }
                if clock.now() >= next_probe {
                    match spawn_session(&mut spec, &metrics) {
                        Ok(s) => {
                            breaker.on_success();
                            metrics.record_session_restart();
                            if metrics.state() == LifecycleState::Degraded {
                                metrics.set_state(LifecycleState::Serving);
                            }
                            session = Some(s);
                        }
                        Err(_) => next_probe = clock.now() + breaker.on_probe_failure(),
                    }
                } else {
                    clock.sleep(DEGRADED_TICK);
                }
                continue;
            }
        };
        session = Some(cur);

        // Fill the batch window.
        let fill_deadline = Instant::now() + timeout;
        while pending.len() < batch {
            let now = Instant::now();
            if drain_expired(&metrics) {
                drain_unserved_inflight(&mut inflight, &metrics);
                drain_remaining(&mut pending, &req_rx, &metrics);
                stop_all(session, graveyard, &metrics);
                return;
            }
            if !pending.is_empty() && now >= fill_deadline {
                break;
            }
            let mut wait = if pending.is_empty() {
                // With work in flight, poll briefly so a finished batch is
                // collected promptly instead of idling a full IDLE_POLL.
                if inflight.is_empty() { IDLE_POLL } else { DEGRADED_TICK }
            } else {
                fill_deadline.saturating_duration_since(now)
            };
            if let Some(dd) = metrics.drain_deadline() {
                wait = wait.min(dd.saturating_duration_since(now));
            }
            match req_rx.recv_timeout(wait) {
                Ok(r) => {
                    metrics.mark_start();
                    // Deadline shedding at dequeue (DESIGN.md §9): an
                    // expired request never occupies a batch slot.
                    if r.expired(Instant::now()) {
                        metrics.record_shed_deadline(1);
                        let _ = r.resp.send(Err(Error::deadline("expired while queued")));
                        continue;
                    }
                    pending.push(r);
                }
                Err(RecvTimeoutError::Timeout) => {
                    if pending.is_empty() && inflight.is_empty() {
                        continue;
                    }
                    break;
                }
                Err(RecvTimeoutError::Disconnected) => {
                    if pending.is_empty() {
                        // Graceful shutdown with an empty queue: settle
                        // anything still in flight, join the party
                        // threads and stop.
                        while !inflight.is_empty() {
                            drain_one(
                                &mut inflight,
                                &mut carry,
                                &mut session,
                                &mut graveyard,
                                &mut spec,
                                &mut breaker,
                                &metrics,
                                &clock,
                                &mut next_probe,
                                parties,
                                classes,
                                fx,
                                &mut logits_ring,
                                &trace,
                            );
                        }
                        stop_all(session, graveyard, &metrics);
                        return;
                    }
                    break;
                }
            }
        }
        // Shed anything that expired while the window filled, then form
        // the batch from what is still live.
        let now = Instant::now();
        let mut expired = 0u64;
        let mut live = Vec::with_capacity(pending.len());
        for r in pending.drain(..) {
            if r.expired(now) {
                expired += 1;
                let _ = r.resp.send(Err(Error::deadline("expired while queued")));
            } else {
                live.push(r);
            }
        }
        pending = live;
        if expired > 0 {
            metrics.record_shed_deadline(expired);
        }
        if pending.is_empty() {
            // Nothing to dispatch this round: use the gap to settle the
            // oldest in-flight batch so its clients are answered promptly.
            if !inflight.is_empty() {
                drain_one(
                    &mut inflight,
                    &mut carry,
                    &mut session,
                    &mut graveyard,
                    &mut spec,
                    &mut breaker,
                    &metrics,
                    &clock,
                    &mut next_probe,
                    parties,
                    classes,
                    fx,
                    &mut logits_ring,
                    &trace,
                );
            }
            continue;
        }
        let got = pending.len().min(batch);
        let reqs: Vec<Request> = pending.drain(..got).collect();
        let t0 = Instant::now();
        // The fill loop guarantees a session is present here.
        let Some(cur) = session.as_ref() else {
            continue;
        };

        // Encode + pad + share (zero the pad region left by the previous
        // batch before encoding this one).
        x_ring.fill(0);
        for (i, r) in reqs.iter().enumerate() {
            for (j, v) in r.input.iter().take(per_sample).enumerate() {
                x_ring[i * per_sample + j] = fx.encode(*v as f64);
            }
        }
        let shares = share_arith(&mut prg, &x_ring, parties);
        // Client -> party input share movement (Data phase accounting).
        trace.record(Phase::Data, (x_ring.len() * 8) as u64);
        let shape = vec![batch, input_shape.0, input_shape.1, input_shape.2];
        let mut batch_err: Option<Error> = None;
        for (tx, share) in cur.job_txs.iter().zip(shares) {
            if tx.send(PartyJob { x_share: share, shape: shape.clone() }).is_err() {
                batch_err = Some(Error::Transport("party session is down".into()));
                break;
            }
        }
        if let Some(root_cause) = batch_err {
            // A dispatch failure means the session is gone (DESIGN.md §7):
            // answer this batch with the root cause, fail everything else
            // in flight behind it, retire, and consult the breaker.
            metrics.record_failed_batch(got as u64, matches!(root_cause, Error::Timeout(_)));
            let msg = format!("inference failed: {root_cause}");
            for r in reqs {
                let _ = r.resp.send(Err(Error::Runtime(msg.clone())));
            }
            while let Some(f) = inflight.pop_front() {
                fail_batch(f, &metrics);
            }
            for q in carry.iter_mut() {
                q.clear();
            }
            if let Some(s) = session.take() {
                retire(s, &mut graveyard);
            }
            match breaker.on_failure() {
                BreakerVerdict::Backoff(d) => {
                    clock.sleep(d);
                    session = ensure_session(&mut spec, &mut breaker, &metrics, true);
                    if session.is_none() {
                        next_probe = clock.now();
                    }
                }
                BreakerVerdict::Trip => {
                    if metrics.state() == LifecycleState::Serving {
                        metrics.set_state(LifecycleState::Degraded);
                    }
                    next_probe = clock.now();
                }
            }
            continue;
        }
        // Dispatched: collection happens at the top of the loop once the
        // pipeline is full (immediately with overlap off).
        inflight.push_back(InFlight { reqs, t0 });
    }
}
