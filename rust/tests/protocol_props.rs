//! Randomized property tests over the protocol invariants (hand-rolled
//! generator sweep — proptest is not in the offline crate set).
//!
//! Each property runs many random trials across party counts, widths and
//! value ranges; failures print the offending seed for reproduction.

use hummingbird::crypto::prg::Prg;
use hummingbird::gmw::harness::run_parties;
use hummingbird::gmw::{adder, ReluPlan};
use hummingbird::net::accounting::Phase;
use hummingbird::ring;
use hummingbird::sharing::{
    reconstruct_arith, reconstruct_binary, share_arith, share_binary, PairwisePrgs,
};

/// Property: secure add on random widths/parties == plaintext add mod 2^w.
#[test]
fn prop_ks_add_random() {
    let mut meta = Prg::new(0xA11CE, 0);
    for trial in 0..24 {
        let parties = 2 + (meta.next_u64() % 2) as usize; // 2 or 3
        let w = 1 + (meta.next_u64() % 64) as u32; // 1..=64
        let n = 1 + (meta.next_u64() % 64) as usize;
        let seed = meta.next_u64();
        let mut prg = Prg::new(seed, 1);
        let mask = ring::low_mask(w);
        let x: Vec<u64> = (0..n).map(|_| prg.next_u64() & mask).collect();
        let y: Vec<u64> = (0..n).map(|_| prg.next_u64() & mask).collect();
        let xs: Vec<Vec<u64>> = share_binary(&mut prg, &x, parties)
            .iter()
            .map(|s| s.iter().map(|v| v & mask).collect())
            .collect();
        let ys: Vec<Vec<u64>> = share_binary(&mut prg, &y, parties)
            .iter()
            .map(|s| s.iter().map(|v| v & mask).collect())
            .collect();
        let run = run_parties(parties, seed, |p| {
            let me = p.party();
            adder::ks_add(p, &xs[me], &ys[me], w).unwrap()
        });
        let got = reconstruct_binary(&run.outputs);
        let expect: Vec<u64> =
            x.iter().zip(&y).map(|(a, b)| a.wrapping_add(*b) & mask).collect();
        assert_eq!(got, expect, "trial={trial} seed={seed} parties={parties} w={w}");
        // Round/byte accounting invariants.
        assert_eq!(
            run.trace.total_rounds(),
            adder::rounds_for_width(w) as u64,
            "round count w={w}"
        );
        if w > 1 && parties == 2 {
            assert_eq!(run.trace.total_bytes(), adder::bytes_for_add(n, w), "bytes w={w}");
        }
    }
}

/// Property: DReLU over a random window matches the scalar theory model
/// (sign of the windowed share sum) for every element.
#[test]
fn prop_drelu_window_matches_theory() {
    let mut meta = Prg::new(0xD3E1, 0);
    for trial in 0..16 {
        let w = 2 + (meta.next_u64() % 30) as u32;
        let m = (meta.next_u64() % 8) as u32;
        let k = (m + w).min(64);
        let plan = ReluPlan::new(k, m).unwrap();
        let seed = meta.next_u64();
        let mut prg = Prg::new(seed, 2);
        let n = 64;
        let x: Vec<u64> = (0..n).map(|_| prg.next_u64()).collect();
        let xs = share_arith(&mut prg, &x, 2);
        // Theory: windowed shares add mod 2^(k-m); DReLU = !msb.
        let expect: Vec<u64> = (0..n)
            .map(|i| {
                let a0 = ring::bit_window(xs[0][i], plan.k, plan.m);
                let a1 = ring::bit_window(xs[1][i], plan.k, plan.m);
                let t = a0.wrapping_add(a1) & ring::low_mask(plan.width());
                1 ^ ring::msb_w(t, plan.width())
            })
            .collect();
        let xs2 = xs.clone();
        let run = run_parties(2, seed, move |p| {
            let me = p.party();
            p.drelu(&xs2[me], plan).unwrap()
        });
        let got = reconstruct_arith(&run.outputs);
        assert_eq!(got, expect, "trial={trial} seed={seed} k={k} m={m}");
    }
}

/// Property: full ReLU with a window covering the value range acts exactly
/// as ReLU-then-prune (Theorems 1+2 combined), for random ranges.
#[test]
fn prop_relu_theorem_semantics() {
    let mut meta = Prg::new(0x7E02, 0);
    for trial in 0..12 {
        let k = 16 + (meta.next_u64() % 24) as u32; // 16..40
        let m = (meta.next_u64() % 6) as u32;
        let plan = ReluPlan::new(k, m).unwrap();
        let bound = 1u64 << (k - 1);
        let thresh = 1u64 << m;
        let seed = meta.next_u64();
        let mut prg = Prg::new(seed, 3);
        let n = 128;
        let x: Vec<u64> = (0..n)
            .map(|_| {
                let v = prg.next_u64() % bound;
                if prg.next_u64() & 1 == 0 {
                    v
                } else {
                    v.wrapping_neg()
                }
            })
            .collect();
        let xs = share_arith(&mut prg, &x, 2);
        let xs2 = xs.clone();
        let run = run_parties(2, seed, move |p| {
            let me = p.party();
            p.relu(&xs2[me], plan).unwrap()
        });
        let got = reconstruct_arith(&run.outputs);
        for (xi, zi) in x.iter().zip(&got) {
            if (*xi as i64) < 0 {
                assert_eq!(*zi, 0, "negative kept: x={} trial={trial}", *xi as i64);
            } else if *xi >= thresh {
                assert_eq!(zi, xi, "in-range positive dropped: x={xi} trial={trial}");
            } else {
                assert!(*zi == 0 || zi == xi, "invalid output for small x={xi}");
            }
        }
    }
}

/// Property: pairwise zero sharings always cancel, arithmetic and binary,
/// any party count, any interleaving of draws.
#[test]
fn prop_zero_sharing_cancels() {
    let mut meta = Prg::new(0x2E20, 0);
    for _ in 0..20 {
        let parties = 2 + (meta.next_u64() % 4) as usize; // 2..=5
        let seed = meta.next_u64();
        let mut prgs: Vec<PairwisePrgs> =
            (0..parties).map(|p| PairwisePrgs::new(seed, p, parties)).collect();
        for round in 0..4 {
            let n = 1 + (meta.next_u64() % 32) as usize;
            if round % 2 == 0 {
                let shares: Vec<Vec<u64>> = prgs.iter_mut().map(|p| p.zero_binary(n)).collect();
                assert_eq!(reconstruct_binary(&shares), vec![0u64; n]);
            } else {
                let shares: Vec<Vec<u64>> = prgs.iter_mut().map(|p| p.zero_arith(n)).collect();
                assert_eq!(reconstruct_arith(&shares), vec![0u64; n]);
            }
        }
    }
}

/// Property: communication accounting is identical across parties
/// (symmetric protocol).
#[test]
fn prop_symmetric_accounting() {
    let mut prg = Prg::new(5, 5);
    let n = 64;
    let x: Vec<u64> = prg.vec_u64(n);
    let xs = share_arith(&mut prg, &x, 3);
    let plan = ReluPlan::new(18, 2).unwrap();
    let traces = std::sync::Mutex::new(Vec::new());
    run_parties(3, 9, |p| {
        use hummingbird::net::Transport;
        let me = p.party();
        let out = p.relu(&xs[me], plan).unwrap();
        traces.lock().unwrap().push((
            p.transport.trace().total_bytes(),
            p.transport.trace().total_rounds(),
        ));
        out
    });
    let traces = traces.into_inner().unwrap();
    assert!(traces.windows(2).all(|w| w[0] == w[1]), "asymmetric accounting: {traces:?}");
}

/// Failure injection: a party that disappears mid-protocol must surface a
/// transport error on the peer, not a hang or a wrong answer.
#[test]
fn prop_party_drop_is_an_error() {
    use hummingbird::gmw::GmwParty;
    use hummingbird::net::local::hub;
    let mut transports = hub(2);
    let t1 = transports.pop().unwrap();
    let t0 = transports.pop().unwrap();
    // Party 1 exchanges once and exits; party 0 tries to keep going.
    let h1 = std::thread::spawn(move || {
        let mut p = GmwParty::new(t1, 1);
        let _ = p.open_binary(Phase::Circuit, &[1, 2, 3], 8);
        // drop
    });
    let h0 = std::thread::spawn(move || {
        let mut p = GmwParty::new(t0, 1);
        let _ = p.open_binary(Phase::Circuit, &[4, 5, 6], 8).unwrap();
        // Peer is gone now; the next exchange must error.
        p.open_binary(Phase::Circuit, &[7, 8, 9], 8)
    });
    h1.join().unwrap();
    let res = h0.join().unwrap();
    assert!(res.is_err(), "expected transport error after peer drop");
}

/// Property: every adder-option combination computes the same sum; the
/// optimizations only change bytes/rounds (monotonically downward).
#[test]
fn prop_adder_ablations_equivalent() {
    use hummingbird::gmw::adder::AdderOptions;
    let mut meta = Prg::new(0xAB1A, 0);
    for _ in 0..6 {
        let w = 2 + (meta.next_u64() % 62) as u32;
        let seed = meta.next_u64();
        let mut prg = Prg::new(seed, 4);
        let mask = ring::low_mask(w);
        let n = 48;
        let x: Vec<u64> = (0..n).map(|_| prg.next_u64() & mask).collect();
        let y: Vec<u64> = (0..n).map(|_| prg.next_u64() & mask).collect();
        let xs: Vec<Vec<u64>> = share_binary(&mut prg, &x, 2)
            .iter()
            .map(|s| s.iter().map(|v| v & mask).collect())
            .collect();
        let ys: Vec<Vec<u64>> = share_binary(&mut prg, &y, 2)
            .iter()
            .map(|s| s.iter().map(|v| v & mask).collect())
            .collect();
        let expect: Vec<u64> =
            x.iter().zip(&y).map(|(a, b)| a.wrapping_add(*b) & mask).collect();
        let mut costs = Vec::new();
        for opts in [
            AdderOptions { batch_stage_ands: false, skip_last_p: false },
            AdderOptions { batch_stage_ands: true, skip_last_p: false },
            AdderOptions::default(),
        ] {
            let xs2 = xs.clone();
            let ys2 = ys.clone();
            let run = run_parties(2, seed, move |p| {
                let me = p.party();
                adder::ks_add_with(p, &xs2[me], &ys2[me], w, opts).unwrap()
            });
            assert_eq!(reconstruct_binary(&run.outputs), expect, "w={w} opts={opts:?}");
            costs.push((run.trace.total_bytes(), run.trace.total_rounds()));
        }
        // Batched never costs more rounds; last-P skip never costs more bytes.
        assert!(costs[1].1 <= costs[0].1, "batching increased rounds: {costs:?}");
        assert!(costs[2].0 <= costs[1].0, "last-P skip increased bytes: {costs:?}");
    }
}

/// Property: beaver usage accounting matches the protocol's actual draws
/// (offline storage estimation must be trustworthy).
#[test]
fn prop_beaver_usage_accounting() {
    let mut prg = Prg::new(6, 6);
    let n = 50;
    let x: Vec<u64> = prg.vec_u64(n);
    let xs = share_arith(&mut prg, &x, 2);
    for (k, m) in [(64u32, 0u32), (16, 4)] {
        let plan = ReluPlan::new(k, m).unwrap();
        let xs2 = xs.clone();
        let run = run_parties(2, 11, move |p| {
            let me = p.party();
            p.relu(&xs2[me], plan).unwrap();
            p.triple_usage()
        });
        let u = run.outputs[0];
        // ReLU = a2b (1 + per-stage ANDs) + daBits + 1 arith mult.
        assert_eq!(u.arith_triples, n as u64, "one arith triple per element");
        assert_eq!(u.dabits, n as u64, "one daBit per element");
        assert!(u.bin_plane_words > 0);
        assert!(u.bin_triple_lanes > 0);
        assert!(u.prg_bytes() > 0, "PRG draw must be accounted");
        if k - m < 64 {
            // Plane-native stream: reduced windows store/draw less than one
            // word per AND lane (the legacy lane-form stream's cost).
            assert!(
                u.bin_plane_words < u.bin_triple_lanes,
                "w={} plane_words={} lanes={}",
                k - m,
                u.bin_plane_words,
                u.bin_triple_lanes
            );
        }
        assert_eq!(run.outputs[0], run.outputs[1], "usage symmetric");
    }
}
