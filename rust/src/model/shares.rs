//! Share-domain model executor: one party's view of the private inference.
//!
//! Linear layers run **locally** on this party's arithmetic shares against
//! the public quantized weights (shared-model setting, like the paper's
//! evaluation) through the AOT `share_*` HLO artifacts (Layer-2 graphs
//! calling the Layer-1 Pallas ring matmul). Non-linear layers go through
//! the GMW engine: ReLU per the active [`PlanSet`], truncation and public
//! scaling locally.
//!
//! Fixed-point discipline (f = frac_bits):
//!   activations/weights at scale 2^f → conv/fc product at 2^(2f) →
//!   add bias (encoded at 2^(2f)) → truncate by f → back to 2^f.
//!   GAP: sum (scale f) → × encode(1/hw) (scale 2f) → truncate.
//!
//! The executor also records a per-op timing breakdown so Fig 1/10's
//! {linear, ReLU-compute, ReLU-comm} split can be regenerated.

use std::time::Instant;

use crate::error::{Error, Result};
use crate::gmw::kernels::KernelBackend;
use crate::gmw::GmwParty;
use crate::hummingbird::PlanSet;
use crate::model::graph::{ModelConfig, Op};
use crate::model::weights::{conv_weight_to_mat, quantize, Archive};
use crate::net::Transport;
use crate::ring::FixedPoint;
use crate::runtime::{registry::ModelArtifacts, Runtime};
use crate::tensor::TensorU64;

/// Wall-clock breakdown of one forward pass (seconds).
#[derive(Debug, Default, Clone, Copy)]
pub struct ExecBreakdown {
    /// Linear layers (conv/fc artifacts + truncation + bias).
    pub linear_s: f64,
    /// ReLU protocol time, total (local compute + wire wait).
    pub relu_s: f64,
    /// Everything else (pool, add, reshape).
    pub other_s: f64,
}

impl ExecBreakdown {
    pub fn total(&self) -> f64 {
        self.linear_s + self.relu_s + self.other_s
    }
    pub fn add(&mut self, other: &ExecBreakdown) {
        self.linear_s += other.linear_s;
        self.relu_s += other.relu_s;
        self.other_s += other.other_s;
    }
}

/// Prepared (quantized) weights for the share executor.
pub struct ShareWeights {
    /// Per conv/fc node: im2col weight matrix on the ring.
    wmats: std::collections::BTreeMap<usize, TensorU64>,
    /// Per conv/fc node: bias at scale 2^(2f).
    biases: std::collections::BTreeMap<usize, Vec<u64>>,
}

impl ShareWeights {
    /// Quantize an f32 archive for `cfg`.
    pub fn prepare(cfg: &ModelConfig, weights: &Archive) -> Result<ShareWeights> {
        let fx = FixedPoint::new(cfg.frac_bits);
        let fx2 = FixedPoint::new(2 * cfg.frac_bits);
        let shapes = cfg.shapes();
        let mut wmats = std::collections::BTreeMap::new();
        let mut biases = std::collections::BTreeMap::new();
        for (i, node) in cfg.nodes.iter().enumerate() {
            match node {
                Op::Conv { src, out_ch, k, .. } => {
                    let cin = shapes[*src][0];
                    let w = weights.get(&format!("w{i}"))?.as_f32()?;
                    let mat = conv_weight_to_mat(w, *out_ch, cin, *k);
                    let q = quantize(&mat, fx);
                    wmats.insert(
                        i,
                        TensorU64::new(vec![cin * k * k, *out_ch], q)?,
                    );
                    let b = weights.get(&format!("b{i}"))?.as_f32()?;
                    biases.insert(i, b.iter().map(|v| fx2.encode(*v as f64)).collect());
                }
                Op::Fc { out, .. } => {
                    let w = weights.get(&format!("w{i}"))?.as_f32()?;
                    let in_dim = w.len() / out;
                    wmats.insert(i, TensorU64::new(vec![in_dim, *out], quantize(w, fx))?);
                    let b = weights.get(&format!("b{i}"))?.as_f32()?;
                    biases.insert(i, b.iter().map(|v| fx2.encode(*v as f64)).collect());
                }
                _ => {}
            }
        }
        Ok(ShareWeights { wmats, biases })
    }
}

/// Which linear-layer artifact variant to execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinearBackend {
    /// The Layer-1 Pallas kernel lowering (validated TPU-shaped path;
    /// slow under CPU interpret lowering).
    Pallas,
    /// The fused int64-dot lowering of the same ring math (CPU hot path;
    /// see EXPERIMENTS.md §Perf L2). Falls back to Pallas when the fast
    /// artifact is absent.
    Fast,
}

/// The share executor (per party, stateless across requests).
pub struct ShareExecutor {
    pub cfg: ModelConfig,
    pub artifacts: ModelArtifacts,
    rt: Runtime,
    weights: ShareWeights,
    pub linear: LinearBackend,
}

impl ShareExecutor {
    pub fn new(
        cfg: ModelConfig,
        artifacts: ModelArtifacts,
        rt: Runtime,
        weights: ShareWeights,
    ) -> ShareExecutor {
        ShareExecutor { cfg, artifacts, rt, weights, linear: LinearBackend::Fast }
    }

    pub fn with_linear(mut self, linear: LinearBackend) -> Self {
        self.linear = linear;
        self
    }

    /// Full private forward pass on this party's input share
    /// `x` ([batch, C, H, W] flattened). Returns (logit shares, breakdown).
    pub fn forward<T: Transport, K: KernelBackend>(
        &self,
        party: &mut GmwParty<T, K>,
        x: TensorU64,
        plans: &PlanSet,
    ) -> Result<(TensorU64, ExecBreakdown)> {
        let batch = self.artifacts.batch;
        let f = self.cfg.frac_bits;
        let shapes = self.cfg.shapes();
        let n_nodes = self.cfg.nodes.len();
        let mut acts: Vec<Option<TensorU64>> = vec![None; n_nodes];
        let mut bd = ExecBreakdown::default();
        if x.shape.first() != Some(&batch) {
            return Err(Error::shape(format!(
                "input batch {:?} != artifact batch {batch}",
                x.shape
            )));
        }
        acts[0] = Some(x);
        for i in 1..n_nodes {
            let node = &self.cfg.nodes[i];
            let t0 = Instant::now();
            let out = match node {
                Op::Input => unreachable!("input is node 0"),
                Op::Conv { src, .. } | Op::Fc { src, .. } => {
                    let layer = self
                        .artifacts
                        .layers
                        .get(&i)
                        .ok_or_else(|| Error::Model(format!("no artifact for node {i}")))?;
                    // Clone: residual graphs reuse a source for both the
                    // main path and the skip path.
                    let xin = acts[*src].clone().ok_or_else(|| miss(i))?;
                    let xin = if matches!(node, Op::Fc { .. }) {
                        // Flatten for fc.
                        let flat = xin.len() / batch;
                        xin.reshape(vec![batch, flat])?
                    } else {
                        xin
                    };
                    let wmat = &self.weights.wmats[&i];
                    let artifact = match (self.linear, &layer.share_fast) {
                        (LinearBackend::Fast, Some(fast)) => fast.as_str(),
                        _ => layer.share.as_str(),
                    };
                    let y = self
                        .rt
                        .run_u64(artifact, &[&xin, wmat])?
                        .into_iter()
                        .next()
                        .ok_or_else(|| Error::runtime("artifact returned no output"))?;
                    // Bias (public, leader-only) at scale 2f, then truncate.
                    let bias = &self.weights.biases[&i];
                    let mut y = y;
                    if party.is_leader() {
                        add_bias(&mut y, bias, batch)?;
                    }
                    let data = party.trunc(&y.data, f);
                    bd.linear_s += t0.elapsed().as_secs_f64();
                    TensorU64 { shape: y.shape, data }
                }
                Op::Relu { src, group } => {
                    let xin = acts[*src].clone().ok_or_else(|| miss(i))?;
                    let plan = plans.plan_for(*group);
                    let data = party.relu(&xin.data, plan)?;
                    bd.relu_s += t0.elapsed().as_secs_f64();
                    TensorU64 { shape: xin.shape, data }
                }
                Op::Add { a, b } => {
                    let va = acts[*a].clone().ok_or_else(|| miss(i))?;
                    let vb = acts[*b].as_ref().ok_or_else(|| miss(i))?;
                    let out = va.wrapping_add(vb)?;
                    bd.other_s += t0.elapsed().as_secs_f64();
                    out
                }
                Op::Gap { src } => {
                    let v = acts[*src].as_ref().ok_or_else(|| miss(i))?;
                    let s = &shapes[*src];
                    let (c, h, w) = (s[0], s[1], s[2]);
                    let mut sums = vec![0u64; batch * c];
                    for bi in 0..batch {
                        for ci in 0..c {
                            let base = (bi * c + ci) * h * w;
                            let mut acc = 0u64;
                            for e in &v.data[base..base + h * w] {
                                acc = acc.wrapping_add(*e);
                            }
                            sums[bi * c + ci] = acc;
                        }
                    }
                    // × encode(1/hw) (scale f) → 2f → truncate back to f.
                    let fx = FixedPoint::new(f);
                    let inv = fx.encode(1.0 / (h * w) as f64);
                    for e in sums.iter_mut() {
                        *e = e.wrapping_mul(inv);
                    }
                    let data = party.trunc(&sums, f);
                    bd.other_s += t0.elapsed().as_secs_f64();
                    TensorU64::new(vec![batch, c], data)?
                }
            };
            acts[i] = Some(out);
        }
        let out = acts[n_nodes - 1].take().ok_or_else(|| Error::Model("no output".into()))?;
        Ok((out, bd))
    }
}

fn miss(i: usize) -> Error {
    Error::Model(format!("node {i}: missing source activation"))
}

/// Add a public per-channel bias to a conv output [B,C,H,W] or fc [B,C].
fn add_bias(y: &mut TensorU64, bias: &[u64], batch: usize) -> Result<()> {
    let per = y.len() / batch;
    let c = bias.len();
    let spatial = per / c;
    if c * spatial != per {
        return Err(Error::shape("bias does not divide output"));
    }
    for bi in 0..batch {
        for ci in 0..c {
            let base = (bi * c + ci) * spatial;
            for e in &mut y.data[base..base + spatial] {
                *e = e.wrapping_add(bias[ci]);
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bias_broadcast_layout() {
        // [B=1, C=2, 2x1 spatial]
        let mut y = TensorU64::new(vec![1, 2, 2, 1], vec![0, 0, 0, 0]).unwrap();
        add_bias(&mut y, &[5, 9], 1).unwrap();
        assert_eq!(y.data, vec![5, 5, 9, 9]);
        // fc case: spatial = 1
        let mut y = TensorU64::new(vec![2, 2], vec![0; 4]).unwrap();
        add_bias(&mut y, &[1, 2], 2).unwrap();
        assert_eq!(y.data, vec![1, 2, 1, 2]);
    }
}
