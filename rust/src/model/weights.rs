//! Tensor-archive reader: the Rust half of `python/compile/dataio.py`.
//!
//! `<prefix>.json` (manifest) + `<prefix>.bin` (raw LE data). Weights are
//! stored f32; the share executor quantizes them to the fixed-point ring.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::ring::FixedPoint;
use crate::util::json;

/// One named tensor: f32 or i32 payload.
#[derive(Debug, Clone)]
pub enum ArchiveTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl ArchiveTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            ArchiveTensor::F32 { shape, .. } | ArchiveTensor::I32 { shape, .. } => shape,
        }
    }
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            ArchiveTensor::F32 { data, .. } => Ok(data),
            _ => Err(Error::Model("expected f32 tensor".into())),
        }
    }
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            ArchiveTensor::I32 { data, .. } => Ok(data),
            _ => Err(Error::Model("expected i32 tensor".into())),
        }
    }
}

/// A loaded archive (weights file or dataset file).
#[derive(Debug, Clone, Default)]
pub struct Archive {
    pub tensors: BTreeMap<String, ArchiveTensor>,
}

impl Archive {
    /// Load `<prefix>.json` + `<prefix>.bin`.
    pub fn load(prefix: impl AsRef<Path>) -> Result<Archive> {
        let prefix = prefix.as_ref();
        let manifest = json::parse_file(prefix.with_extension("json"))?;
        let raw = std::fs::read(prefix.with_extension("bin")).map_err(|e| {
            Error::Model(format!("reading {}.bin: {e}", prefix.display()))
        })?;
        let mut tensors = BTreeMap::new();
        for t in manifest.get("tensors")?.as_arr()? {
            let name = t.get_str("name")?.to_string();
            let shape: Vec<usize> = t
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<_>>()?;
            let count = t.get_usize("count")?;
            let offset = t.get_usize("offset")?;
            let dtype = t.get_str("dtype")?;
            let end = offset + count * 4;
            if end > raw.len() {
                return Err(Error::Model(format!("tensor {name} overruns archive")));
            }
            let bytes = &raw[offset..end];
            let tensor = match dtype {
                "f32" => ArchiveTensor::F32 {
                    shape,
                    data: bytes
                        .chunks_exact(4)
                        // LINT-ALLOW: unwrap — chunks_exact(4) yields 4-byte
                        // slices, so the array conversion cannot fail.
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                },
                "i32" => ArchiveTensor::I32 {
                    shape,
                    data: bytes
                        .chunks_exact(4)
                        // LINT-ALLOW: unwrap — chunks_exact(4) yields 4-byte
                        // slices, so the array conversion cannot fail.
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                },
                other => return Err(Error::Model(format!("unknown dtype {other}"))),
            };
            tensors.insert(name, tensor);
        }
        Ok(Archive { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&ArchiveTensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| Error::Model(format!("tensor '{name}' not in archive")))
    }

    /// Write an archive (used by tests and by the search engine's plan
    /// export of quantized weights).
    pub fn save(&self, prefix: impl AsRef<Path>) -> Result<()> {
        use crate::util::json::Json;
        let prefix = prefix.as_ref();
        if let Some(dir) = prefix.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut bin: Vec<u8> = Vec::new();
        let mut entries = Vec::new();
        for (name, t) in &self.tensors {
            let offset = bin.len();
            let (dtype, count) = match t {
                ArchiveTensor::F32 { data, .. } => {
                    for v in data {
                        bin.extend_from_slice(&v.to_le_bytes());
                    }
                    ("f32", data.len())
                }
                ArchiveTensor::I32 { data, .. } => {
                    for v in data {
                        bin.extend_from_slice(&v.to_le_bytes());
                    }
                    ("i32", data.len())
                }
            };
            entries.push(Json::obj(vec![
                ("name", Json::str(name.clone())),
                ("shape", Json::arr(t.shape().iter().map(|d| Json::Int(*d as i64)))),
                ("dtype", Json::str(dtype)),
                ("offset", Json::Int(offset as i64)),
                ("count", Json::Int(count as i64)),
            ]));
        }
        let manifest = Json::obj(vec![("tensors", Json::Arr(entries))]);
        std::fs::write(prefix.with_extension("json"), manifest.to_string_pretty())?;
        std::fs::write(prefix.with_extension("bin"), bin)?;
        Ok(())
    }
}

/// Quantize an f32 weight tensor to ring elements (fixed point).
pub fn quantize(data: &[f32], fx: FixedPoint) -> Vec<u64> {
    data.iter().map(|v| fx.encode(*v as f64)).collect()
}

/// Reshape an OIHW conv weight into the im2col matrix [Cin*k*k, Cout]
/// expected by the share_conv artifact (row order (c, ky, kx)).
pub fn conv_weight_to_mat(w: &[f32], cout: usize, cin: usize, k: usize) -> Vec<f32> {
    let kdim = cin * k * k;
    let mut out = vec![0f32; kdim * cout];
    for o in 0..cout {
        for c in 0..cin {
            for ky in 0..k {
                for kx in 0..k {
                    let src = ((o * cin + c) * k + ky) * k + kx;
                    let row = (c * k + ky) * k + kx;
                    out[row * cout + o] = w[src];
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_archive() {
        let dir = std::env::temp_dir().join(format!("hb_arch_{}", std::process::id()));
        let mut a = Archive::default();
        a.tensors.insert(
            "w".into(),
            ArchiveTensor::F32 { shape: vec![2, 3], data: vec![1.0, -2.0, 3.5, 0.0, 5.0, -6.25] },
        );
        a.tensors.insert(
            "y".into(),
            ArchiveTensor::I32 { shape: vec![3], data: vec![1, -2, 7] },
        );
        let prefix = dir.join("t");
        a.save(&prefix).unwrap();
        let b = Archive::load(&prefix).unwrap();
        assert_eq!(b.get("w").unwrap().as_f32().unwrap(), a.get("w").unwrap().as_f32().unwrap());
        assert_eq!(b.get("y").unwrap().as_i32().unwrap(), &[1, -2, 7]);
        assert!(b.get("zz").is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn conv_weight_layout_matches_im2col_order() {
        // cout=1, cin=2, k=2: weight w[o=0][c][ky][kx] = c*100 + ky*10 + kx
        let w: Vec<f32> = vec![0., 1., 10., 11., 100., 101., 110., 111.];
        let mat = conv_weight_to_mat(&w, 1, 2, 2);
        // rows ordered (c, ky, kx)
        assert_eq!(mat, vec![0., 1., 10., 11., 100., 101., 110., 111.]);
    }

    #[test]
    fn quantize_encodes_fixed_point() {
        let fx = FixedPoint::new(12);
        let q = quantize(&[1.0, -0.5], fx);
        assert_eq!(q[0], 4096);
        assert_eq!(q[1] as i64, -2048);
    }
}
