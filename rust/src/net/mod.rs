//! Party-to-party communication substrate.
//!
//! The GMW engine talks to an abstract [`Transport`]; two implementations
//! exist: [`local::LocalTransport`] (in-process channels — used by tests,
//! benches and the single-binary multi-party simulator) and
//! [`tcp::TcpTransport`] (real sockets for multi-process deployments).
//! Both feed the same [`accounting::CommTrace`], and simulated wall-clock
//! for arbitrary networks is projected by [`profile`] using the paper's own
//! methodology (measured bytes/rounds × analytic bandwidth/latency model).
//!
//! # `exchange_all` → `exchange_all_into` migration
//!
//! The original primitive, `exchange_all`, returned a fresh
//! `Vec<Vec<u8>>` per round — one allocation per peer per round, the last
//! per-round allocations left after the engine-side arena work (PR 1).
//! The required trait method is now [`Transport::exchange_all_into`],
//! which fills a caller-owned [`RecvBufs`]; `exchange_all` survives as a
//! provided default method that allocates a throwaway `RecvBufs` and
//! unwraps it, so existing callers and tests keep working unchanged. New
//! code (and the whole GMW hot path) should hold one `RecvBufs` per
//! session and pass it to every round.
//!
//! ## `RecvBufs` ownership rules
//!
//! * One `RecvBufs` per protocol session, owned by the caller (the GMW
//!   engine keeps one inside `GmwParty`), never shared across parties or
//!   threads.
//! * A call to `exchange_all_into` **fully overwrites** every peer slot:
//!   slot `q` holds exactly peer `q`'s payload for that round. The slot
//!   for `self.party()` has **unspecified contents** — the engine's folds
//!   seed from the caller's own shares and skip it (only the legacy
//!   `exchange_all` shim pays the echo copy). Contents are only valid
//!   until the next exchange.
//! * Slots keep their heap capacity across rounds; once a session has seen
//!   its largest payload, later rounds perform **zero receive-side
//!   allocations**. Transports must fill slots with
//!   [`RecvBufs::fill_slot`]-style resize-then-overwrite (never
//!   `clear` + `resize`, which would memset) and must not shrink
//!   capacity.

pub mod accounting;
pub mod local;
pub mod profile;
pub mod tcp;

use crate::error::{Error, Result};
use accounting::{CommTrace, Phase};
use std::sync::Arc;

/// Caller-owned, per-peer receive buffers for [`Transport::exchange_all_into`].
///
/// Slot `q` holds party `q`'s payload for the most recent round (the slot
/// for the caller's own id has unspecified contents — see the module
/// docs). Buffers are reused across rounds: lengths are reset to each
/// round's payload size but heap capacity is retained, so a warmed
/// `RecvBufs` makes the receive path allocation-free. See the module docs
/// for the full ownership rules.
#[derive(Debug)]
pub struct RecvBufs {
    bufs: Vec<Vec<u8>>,
}

impl RecvBufs {
    /// Empty buffer set for a session of `parties` parties.
    pub fn new(parties: usize) -> RecvBufs {
        RecvBufs { bufs: (0..parties).map(|_| Vec::new()).collect() }
    }

    /// Number of party slots.
    pub fn parties(&self) -> usize {
        self.bufs.len()
    }

    /// Payload received from party `q` in the most recent round.
    pub fn get(&self, q: usize) -> &[u8] {
        &self.bufs[q]
    }

    /// Mutable slot access for transport implementations. Transports must
    /// fully overwrite each slot (see module docs); protocol code should
    /// only read via [`RecvBufs::get`].
    pub fn slots_mut(&mut self) -> &mut [Vec<u8>] {
        &mut self.bufs
    }

    /// Copy `src` into `slot` without a memset: resize only when the
    /// length changes (growth within capacity allocates nothing), then
    /// overwrite every byte.
    pub fn fill_slot(slot: &mut Vec<u8>, src: &[u8]) {
        if slot.len() != src.len() {
            slot.clear();
            slot.reserve(src.len());
            // SAFETY-free path: extend from the source directly; capacity
            // is retained so the warm case never reallocates.
            slot.extend_from_slice(src);
        } else {
            slot.copy_from_slice(src);
        }
    }

    /// Consume into the legacy per-round `Vec<Vec<u8>>` shape (used by the
    /// `exchange_all` compatibility shim).
    pub fn into_vec(self) -> Vec<Vec<u8>> {
        self.bufs
    }
}

/// Abstract all-to-all exchange primitive for one party.
///
/// GMW only ever needs "every party sends a buffer to every other party and
/// receives theirs" (openings of masked values). One exchange call is one
/// communication **round**.
pub trait Transport: Send {
    /// This party's id in 0..parties.
    fn party(&self) -> usize;
    /// Total number of parties.
    fn parties(&self) -> usize;

    /// Send `data` to every other party; fill `recv` with each *other*
    /// party's payload. The caller's own slot is left with **unspecified
    /// contents** (the engine's fold loops seed from their own shares and
    /// skip it, so the hot path never pays an echo copy). The hot-path
    /// form: with a warmed `recv` the receive side allocates nothing.
    fn exchange_all_into(&mut self, phase: Phase, data: &[u8], recv: &mut RecvBufs)
        -> Result<()>;

    /// Legacy allocating form: returns a vec indexed by party id (entry
    /// for `self.party()` is the input `data` echoed back, so openings
    /// can simply fold over all). Default shim over
    /// [`Transport::exchange_all_into`]; kept for tests and non-hot-path
    /// callers.
    fn exchange_all(&mut self, phase: Phase, data: &[u8]) -> Result<Vec<Vec<u8>>> {
        let mut recv = RecvBufs::new(self.parties());
        self.exchange_all_into(phase, data, &mut recv)?;
        let me = self.party();
        RecvBufs::fill_slot(&mut recv.slots_mut()[me], data);
        Ok(recv.into_vec())
    }

    /// The accounting trace for this party.
    fn trace(&self) -> Arc<CommTrace>;
}

/// Helper: XOR-open a vector of packed binary share words. An empty slice
/// (degenerate 0-party open) folds to an empty vector rather than
/// panicking. (Shared by engine code and tests.)
pub fn fold_xor(bufs: &[Vec<u64>]) -> Vec<u64> {
    let Some(first) = bufs.first() else { return Vec::new() };
    let n = first.len();
    let mut out = vec![0u64; n];
    for b in bufs {
        debug_assert_eq!(b.len(), n);
        for (o, v) in out.iter_mut().zip(b) {
            *o ^= *v;
        }
    }
    out
}

/// Helper: additively open a vector of ring-element shares. Empty input
/// folds to an empty vector (1-party/degenerate-open case).
pub fn fold_add(bufs: &[Vec<u64>]) -> Vec<u64> {
    let Some(first) = bufs.first() else { return Vec::new() };
    let n = first.len();
    let mut out = vec![0u64; n];
    for b in bufs {
        debug_assert_eq!(b.len(), n);
        for (o, v) in out.iter_mut().zip(b) {
            *o = o.wrapping_add(*v);
        }
    }
    out
}

/// Serialize a u64 slice little-endian into a reusable buffer. Every byte
/// is overwritten, so a buffer already at the right length (the warm
/// arena-pooled path) is neither cleared nor reallocated. Hot-path form
/// used by the arithmetic openings.
pub fn u64s_to_bytes_into(v: &[u64], out: &mut Vec<u8>) {
    let nbytes = v.len() * 8;
    if out.len() != nbytes {
        out.clear();
        out.resize(nbytes, 0);
    }
    for (chunk, x) in out.chunks_exact_mut(8).zip(v) {
        chunk.copy_from_slice(&x.to_le_bytes());
    }
}

/// Serialize a u64 slice little-endian (wire format helper).
pub fn u64s_to_bytes(v: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 8);
    u64s_to_bytes_into(v, &mut out);
    out
}

/// Wrapping-add each little-endian u64 in `b` into `out` in place (the
/// receive-side fold of an arithmetic opening; no intermediate vector).
///
/// Hard wire check (all builds — peer data is untrusted): `b` must hold
/// exactly `out.len()` 8-byte words. A short, long or ragged payload is
/// truncation/corruption on the wire and must never be zero-padded into
/// plausible share data.
pub fn add_u64s_from_bytes(b: &[u8], out: &mut [u64]) -> Result<()> {
    if b.len() != out.len() * 8 {
        return Err(Error::wire(format!(
            "arithmetic opening expects {} bytes, got {}",
            out.len() * 8,
            b.len()
        )));
    }
    for (o, c) in out.iter_mut().zip(b.chunks_exact(8)) {
        *o = o.wrapping_add(u64::from_le_bytes(c.try_into().unwrap()));
    }
    Ok(())
}

/// Deserialize little-endian u64s.
///
/// Hard wire check (all builds): the payload must be a whole number of
/// 8-byte words. A trailing partial chunk is truncated/corrupt wire data;
/// zero-padding it (the old behavior) would silently launder it into
/// valid-looking shares.
pub fn bytes_to_u64s(b: &[u8]) -> Result<Vec<u64>> {
    if b.len() % 8 != 0 {
        return Err(Error::wire(format!(
            "u64 payload must be a multiple of 8 bytes, got {}",
            b.len()
        )));
    }
    Ok(b.chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u64_bytes_roundtrip() {
        let v = vec![0u64, 1, u64::MAX, 0x0102_0304_0506_0708];
        assert_eq!(bytes_to_u64s(&u64s_to_bytes(&v)).unwrap(), v);
    }

    #[test]
    fn add_fold_from_bytes_matches_wrapping_add() {
        let v = vec![1u64, u64::MAX, 7];
        let b = u64s_to_bytes(&v);
        let mut out = vec![1u64, 1, 1];
        add_u64s_from_bytes(&b, &mut out).unwrap();
        assert_eq!(out, vec![2, 0, 8]);
        let mut reused = Vec::new();
        u64s_to_bytes_into(&v, &mut reused);
        assert_eq!(reused, b);
    }

    #[test]
    fn folds() {
        let a = vec![vec![1u64, 2], vec![3u64, 4]];
        assert_eq!(fold_xor(&a), vec![2, 6]);
        assert_eq!(fold_add(&a), vec![4, 6]);
    }

    /// Degenerate opens (no parties contributed) fold to empty instead of
    /// panicking on `bufs[0]`.
    #[test]
    fn folds_empty_input_is_empty() {
        let empty: Vec<Vec<u64>> = Vec::new();
        assert_eq!(fold_xor(&empty), Vec::<u64>::new());
        assert_eq!(fold_add(&empty), Vec::<u64>::new());
        // Single-party "open": identity fold.
        let one = vec![vec![9u64, 4]];
        assert_eq!(fold_xor(&one), vec![9, 4]);
        assert_eq!(fold_add(&one), vec![9, 4]);
    }

    /// Regression: a trailing partial 8-byte chunk used to be zero-padded
    /// into a "valid" word, masking wire truncation. It is now a hard
    /// wire-format error in every build.
    #[test]
    fn ragged_u64_payload_is_rejected() {
        let good = u64s_to_bytes(&[1, 2, 3]);
        assert_eq!(bytes_to_u64s(&good).unwrap().len(), 3);
        let ragged = &good[..good.len() - 3];
        assert!(matches!(bytes_to_u64s(ragged), Err(crate::error::Error::Wire(_))));
        assert!(matches!(bytes_to_u64s(&[0u8; 7]), Err(crate::error::Error::Wire(_))));
    }

    /// Regression: the receive-side arithmetic fold must reject payloads
    /// whose length disagrees with the lane count instead of folding a
    /// zero-padded prefix.
    #[test]
    fn mismatched_arith_payload_is_rejected() {
        let b = u64s_to_bytes(&[5, 6]);
        let mut out = vec![0u64; 2];
        add_u64s_from_bytes(&b, &mut out).unwrap();
        assert_eq!(out, vec![5, 6]);
        // One lane short of the payload, and one lane long.
        let mut short = vec![0u64; 3];
        assert!(matches!(
            add_u64s_from_bytes(&b, &mut short),
            Err(crate::error::Error::Wire(_))
        ));
        let mut long = vec![0u64; 1];
        assert!(matches!(
            add_u64s_from_bytes(&b, &mut long),
            Err(crate::error::Error::Wire(_))
        ));
        // Untouched on error: no partial fold.
        assert_eq!(short, vec![0, 0, 0]);
    }

    #[test]
    fn fill_slot_reuses_capacity() {
        let mut slot = Vec::new();
        RecvBufs::fill_slot(&mut slot, &[1, 2, 3, 4]);
        assert_eq!(slot, vec![1, 2, 3, 4]);
        let cap = slot.capacity();
        let ptr = slot.as_ptr();
        // Same length: plain overwrite, same allocation.
        RecvBufs::fill_slot(&mut slot, &[9, 9, 9, 9]);
        assert_eq!(slot, vec![9, 9, 9, 9]);
        assert_eq!(slot.as_ptr(), ptr);
        // Shorter length: shrink without releasing capacity.
        RecvBufs::fill_slot(&mut slot, &[7]);
        assert_eq!(slot, vec![7]);
        assert!(slot.capacity() >= cap);
    }
}
