//! Paper-figure regeneration harness (`hummingbird figures --fig N`).
//!
//! Every table and figure of the paper's evaluation (§5) maps to one
//! generator here (see DESIGN.md §6 for the index). Results print as text
//! tables; `--json <path>` additionally dumps machine-readable output.
//!
//! ## Methodology (matches the paper's own; see EXPERIMENTS.md)
//!
//! * Communication (bytes, rounds, per-phase split) is **exact**: the
//!   transport records every protocol round.
//! * Network time is the paper's analytic projection:
//!   Σ_rounds (latency + bytes/bandwidth) for High-BW / LAN 10 Gbps /
//!   WAN 352 Mbps (§5.2 does the same for its WAN row).
//! * Compute time is measured on this testbed (wall − wire-wait) and
//!   scaled by a GPU profile **calibrated once** so the baseline's
//!   compute/communication ratio on LAN matches the paper's published
//!   breakdown (Fig 10: 93% comm on A100, 78% on V100). All *relative*
//!   results (speedups, crossovers, saturation) then follow from the
//!   exact communication trace.

use std::collections::BTreeMap;

use crate::crypto::prg::Prg;
use crate::error::{Error, Result};
use crate::gmw::harness::run_parties;
use crate::hummingbird::search::{SearchConfig, SearchEngine, Strategy};
use crate::hummingbird::{simulator, PlanSet};
use crate::model::{
    Archive, Backend, Dataset, ModelConfig, PlainExecutor, ShareExecutor, ShareWeights,
    WhichPlain,
};
use crate::net::profile::NetworkProfile;
use crate::ring::FixedPoint;
use crate::runtime::{Manifest, Runtime};
use crate::sharing::share_arith;
use crate::util::cli::Args;
use crate::util::json::{self, Json};
use crate::util::stats;

/// The paper's six benchmark combinations (model, dataset stand-ins).
pub const BENCHMARKS: [&str; 6] = [
    "miniresnet_synth10",
    "resnets18_synth10",
    "miniresnet_synth100",
    "resnets18_synth100",
    "miniresnet_synthtiny",
    "resnets18_synthtiny",
];

/// Plan variants evaluated in Figs 7–11.
pub const VARIANTS: [&str; 4] = ["baseline", "eco", "b8-64", "b6-64"];

/// One measured MPC inference run.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub model: String,
    pub variant: String,
    pub batch: usize,
    /// bytes by phase [Circuit, Others, Mult, B2A, Data, Setup].
    pub bytes_by_phase: [u64; 6],
    pub total_rounds: u64,
    /// Local compute seconds (wall − wire wait), per batch.
    pub compute_s: f64,
    pub wall_s: f64,
}

impl Measurement {
    /// Protocol bytes (excluding client I/O Data phase).
    pub fn protocol_bytes(&self) -> u64 {
        self.bytes_by_phase[0] + self.bytes_by_phase[1] + self.bytes_by_phase[2]
            + self.bytes_by_phase[3]
    }

    /// Analytic communication time on a network profile, with per-round
    /// bytes scaled by `byte_scale` (projection to the paper's batch 512:
    /// bytes grow linearly with batch, round count does not).
    pub fn comm_time(
        &self,
        net: &NetworkProfile,
        rounds_trace: &[(u64, u64)],
        byte_scale: u64,
    ) -> f64 {
        rounds_trace.iter().map(|(b, _)| net.round_time(*b * byte_scale)).sum()
    }
}

/// Full context for figure generation.
pub struct FigCtx {
    pub root: std::path::PathBuf,
    /// Calibrated A100 compute scale (see module docs).
    pub a100_scale: f64,
    pub v100_scale: f64,
    /// Cache of (model, variant) -> (measurement, per-round bytes).
    cache: BTreeMap<(String, String), (Measurement, Vec<(u64, u64)>)>,
    /// Cache of (model, variant) -> accuracy on the test split.
    acc_cache: BTreeMap<(String, String), f64>,
    pub out_json: BTreeMap<String, Json>,
    /// Samples used for accuracy evaluation (speed knob).
    pub acc_samples: usize,
    /// Batch the projections model (the paper evaluates batch 512; our
    /// artifacts run batch 4 — bytes scale linearly, rounds don't).
    pub proj_batch: usize,
}

impl FigCtx {
    pub fn new(root: std::path::PathBuf) -> FigCtx {
        FigCtx {
            root,
            a100_scale: 1.0,
            v100_scale: 3.7,
            cache: BTreeMap::new(),
            acc_cache: BTreeMap::new(),
            out_json: BTreeMap::new(),
            acc_samples: 512,
            proj_batch: 512,
        }
    }

    /// Per-round byte multiplier for projections (proj_batch / artifact batch).
    pub fn byte_scale(&self) -> u64 {
        (self.proj_batch / 4).max(1) as u64
    }

    fn artifacts(&self) -> std::path::PathBuf {
        self.root.join("artifacts")
    }

    /// Load (or search for) the plan of a variant.
    pub fn plan(&self, model: &str, variant: &str) -> Result<PlanSet> {
        let cfg = ModelConfig::load_named(&self.root, model)?;
        if variant == "baseline" {
            return Ok(PlanSet::baseline(cfg.relu_groups));
        }
        let path = self.root.join("configs/searched").join(format!("{model}_{variant}.json"));
        if path.exists() {
            return PlanSet::load(&path);
        }
        // Run the search on demand and persist the plan.
        eprintln!("[figures] plan {model}/{variant} missing; running search...");
        let strategy = match variant {
            "eco" => Strategy::Eco,
            "b8-64" => Strategy::Budget(8.0 / 64.0),
            "b6-64" => Strategy::Budget(6.0 / 64.0),
            other => return Err(Error::config(format!("unknown variant {other}"))),
        };
        let result = self.run_search(model, strategy)?;
        let mut plans = result.plans;
        plans.meta.insert("search_time_s".into(), format!("{:.2}", result.search_time_s));
        plans.meta.insert("evals".into(), format!("{}", result.evals));
        plans.meta.insert("baseline_acc".into(), format!("{:.4}", result.baseline_acc));
        plans.meta.insert("final_acc".into(), format!("{:.4}", result.final_acc));
        plans.save(&path)?;
        Ok(plans)
    }

    pub fn run_search(
        &self,
        model: &str,
        strategy: Strategy,
    ) -> Result<crate::hummingbird::search::SearchResult> {
        let cfg = ModelConfig::load_named(&self.root, model)?;
        let weights = Archive::load(self.artifacts().join("weights").join(model))?;
        let dataset = Dataset::load(self.artifacts(), &cfg.dataset)?;
        let manifest = Manifest::load(self.artifacts())?;
        let model_art = manifest.model(model)?.clone();
        let backend = Backend::Xla {
            rt: Runtime::new(self.artifacts())?,
            artifact_batch: model_art.search_batch,
            artifacts: model_art,
            which: WhichPlain::Search,
        };
        let exec = PlainExecutor::new(cfg, weights, backend);
        let scfg = SearchConfig { strategy, ..SearchConfig::default() };
        let n = scfg.val_samples.min(dataset.val.n);
        let engine = SearchEngine::new(
            &exec,
            &dataset.val.images,
            &dataset.val.labels[..n],
            dataset.val.sample_elems,
            scfg,
        );
        engine.run()
    }

    /// Like [`measure`](Self::measure) but always re-runs (benchmarks).
    pub fn measure_uncached(
        &mut self,
        model: &str,
        variant: &str,
    ) -> Result<(Measurement, Vec<(u64, u64)>)> {
        self.cache.remove(&(model.to_string(), variant.to_string()));
        self.measure(model, variant)
    }

    /// Measure one MPC inference batch (2 parties, local hub).
    // Offline figure regeneration: a failure inside the party closures
    // cannot cross the thread boundary as a Result, and aborting the run
    // with the original panic message is exactly what we want here.
    #[allow(clippy::unwrap_used, clippy::expect_used)]
    pub fn measure(
        &mut self,
        model: &str,
        variant: &str,
    ) -> Result<(Measurement, Vec<(u64, u64)>)> {
        let key = (model.to_string(), variant.to_string());
        if let Some(m) = self.cache.get(&key) {
            return Ok(m.clone());
        }
        let plans = self.plan(model, variant)?;
        let cfg = ModelConfig::load_named(&self.root, model)?;
        let weights = Archive::load(self.artifacts().join("weights").join(model))?;
        let dataset = Dataset::load(self.artifacts(), &cfg.dataset)?;
        let manifest = Manifest::load(self.artifacts())?;
        let batch = manifest.model(model)?.batch;
        let fx = FixedPoint::new(cfg.frac_bits);
        let x_ring = dataset.test.batch_ring(0, batch, fx);
        let mut prg = Prg::new(0xf16, 0);
        let xs = share_arith(&mut prg, &x_ring, 2);
        let (c, h, w) = cfg.input;
        let shape = vec![batch, c, h, w];

        let root = self.artifacts();
        let cfg2 = cfg.clone();
        let model_s = model.to_string();
        let t0 = std::time::Instant::now();
        let run = run_parties(2, 0xf00d, move |party| {
            use crate::net::Transport;
            let rt = Runtime::new(&root).unwrap();
            let manifest = Manifest::load(&root).unwrap();
            let art = manifest.model(&model_s).unwrap().clone();
            let sw = ShareWeights::prepare(&cfg2, &weights).unwrap();
            let mut exec = ShareExecutor::new(cfg2.clone(), art, rt, sw);
            let me = party.party();
            let x = crate::tensor::TensorU64::new(shape.clone(), xs[me].clone()).unwrap();
            // Warm the executable cache, then measure a clean pass.
            let _ = exec.forward(party, x.clone(), &plans).unwrap();
            party.transport.trace().reset();
            let t = std::time::Instant::now();
            let _ = exec.forward(party, x, &plans).unwrap();
            t.elapsed().as_secs_f64()
        });
        let wall = run.outputs[0];
        let _ = t0;
        let trace = run.trace;
        let rounds: Vec<(u64, u64)> =
            trace.rounds().iter().map(|r| (r.bytes_sent, 1)).collect();
        let m = Measurement {
            model: model.to_string(),
            variant: variant.to_string(),
            batch,
            bytes_by_phase: trace.bytes_by_phase(),
            total_rounds: trace.total_rounds(),
            compute_s: (wall - trace.wait_seconds()).max(1e-9),
            wall_s: wall,
        };
        self.cache.insert(key, (m.clone(), rounds.clone()));
        Ok((m, rounds))
    }

    /// Test-split accuracy under a variant's plan (simulator, XLA backend).
    pub fn accuracy(&mut self, model: &str, variant: &str) -> Result<f64> {
        let key = (model.to_string(), variant.to_string());
        if let Some(a) = self.acc_cache.get(&key) {
            return Ok(*a);
        }
        let plans = self.plan(model, variant)?;
        let cfg = ModelConfig::load_named(&self.root, model)?;
        let weights = Archive::load(self.artifacts().join("weights").join(model))?;
        let dataset = Dataset::load(self.artifacts(), &cfg.dataset)?;
        let manifest = Manifest::load(self.artifacts())?;
        let model_art = manifest.model(model)?.clone();
        let backend = Backend::Xla {
            rt: Runtime::new(self.artifacts())?,
            artifact_batch: model_art.search_batch,
            artifacts: model_art,
            which: WhichPlain::Search,
        };
        let exec = PlainExecutor::new(cfg, weights, backend);
        let n = self.acc_samples.min(dataset.test.n);
        let acc = simulator::evaluate_plans(
            &exec,
            &dataset.test.images[..n * dataset.test.sample_elems],
            &dataset.test.labels[..n],
            dataset.test.sample_elems,
            64,
            &plans,
            3,
        )?;
        self.acc_cache.insert(key, acc);
        Ok(acc)
    }

    /// Calibrate the A100 compute scale from the anchor benchmark's
    /// baseline so comm is 93% of LAN total (paper Figs 1/10), and V100 so
    /// comm is 78%.
    pub fn calibrate(&mut self) -> Result<()> {
        let (m, rounds) = self.measure("resnets18_synth10", "baseline")?;
        let lan = NetworkProfile::lan();
        let ctxscale = self.byte_scale();
        let comm: f64 = rounds.iter().map(|(b, _)| lan.round_time(*b * ctxscale)).sum();
        // Compute is also per-batch: scale it to the projection batch.
        // comm / (comm + a100*compute) = 0.93  =>  a100 = comm*(7/93)/compute
        let compute = m.compute_s * self.byte_scale() as f64;
        self.a100_scale = comm * (7.0 / 93.0) / compute;
        self.v100_scale = comm * (22.0 / 78.0) / compute;
        Ok(())
    }

    /// End-to-end projected time for a measurement.
    pub fn project(
        &self,
        m: &Measurement,
        rounds: &[(u64, u64)],
        net: &NetworkProfile,
        gpu_scale: f64,
    ) -> f64 {
        let ctxscale = self.byte_scale();
        let comm: f64 = rounds.iter().map(|(b, _)| net.round_time(*b * ctxscale)).sum();
        comm + m.compute_s * ctxscale as f64 * gpu_scale
    }
}

// =====================================================================
// Entry point
// =====================================================================

pub fn cmd_figures(args: &Args) -> Result<()> {
    let root = std::path::PathBuf::from(args.opt_or("root", env!("CARGO_MANIFEST_DIR")));
    let mut ctx = FigCtx::new(root);
    ctx.acc_samples = args.opt_parse("acc-samples", 512usize)?;
    let which = args.opt("fig").map(|s| s.to_string());
    let tab = args.opt("tab").map(|s| s.to_string());
    let all = args.flag("all") || (which.is_none() && tab.is_none());

    ctx.calibrate()?;
    println!(
        "(compute calibration: A100 scale {:.3e}, V100 scale {:.3e})\n",
        ctx.a100_scale, ctx.v100_scale
    );

    let figs: Vec<&str> = match &which {
        Some(f) => vec![f.as_str()],
        None if all => vec!["1", "3", "7", "8", "9", "10", "11", "12"],
        None => vec![],
    };
    let tabs: Vec<&str> = match &tab {
        Some(t) => vec![t.as_str()],
        None if all => vec!["1", "2", "3"],
        None => vec![],
    };
    for f in figs {
        match f {
            "1" => fig1(&mut ctx)?,
            "3" => fig3(&mut ctx)?,
            "7" => fig7_8(&mut ctx, "A100")?,
            "8" => fig7_8(&mut ctx, "V100")?,
            "9" => fig9(&mut ctx)?,
            "10" => fig10(&mut ctx)?,
            "11" => fig11(&mut ctx)?,
            "12" => fig12(&mut ctx)?,
            other => return Err(Error::config(format!("unknown figure {other}"))),
        }
    }
    for t in tabs {
        match t {
            "1" => tab1(&mut ctx)?,
            "2" => tab2(&mut ctx)?,
            "3" => tab3(&mut ctx)?,
            other => return Err(Error::config(format!("unknown table {other}"))),
        }
    }
    if let Some(path) = args.opt("json") {
        let j = Json::Obj(ctx.out_json.clone());
        std::fs::write(path, j.to_string_pretty())?;
        println!("\n(json written to {path})");
    }
    Ok(())
}

// =====================================================================
// Individual figures
// =====================================================================

const ANCHOR: &str = "resnets18_synth10";

/// Fig 1: latency breakdown + throughput for the anchor benchmark.
fn fig1(ctx: &mut FigCtx) -> Result<()> {
    println!("=== Figure 1: latency & throughput, {ANCHOR} (ResNet18/CIFAR10 stand-in), LAN+A100 ===");
    println!(
        "{:<12} {:>12} {:>12} {:>12} {:>12} {:>14}",
        "variant", "relu-comm", "compute", "total/batch", "samples/s", "accuracy"
    );
    let mut rows = Vec::new();
    let lan = NetworkProfile::lan();
    let ctxscale = ctx.byte_scale();
    for v in VARIANTS {
        let (m, rounds) = ctx.measure(ANCHOR, v)?;
        let comm: f64 = rounds.iter().map(|(b, _)| lan.round_time(*b * ctxscale)).sum();
        let compute = m.compute_s * ctxscale as f64 * ctx.a100_scale;
        let total = comm + compute;
        let acc = ctx.accuracy(ANCHOR, v)?;
        println!(
            "{:<12} {:>12} {:>12} {:>12} {:>12.1} {:>13.2}%",
            v,
            stats::fmt_secs(comm),
            stats::fmt_secs(compute),
            stats::fmt_secs(total),
            (m.batch as u64 * ctxscale) as f64 / total,
            acc * 100.0
        );
        rows.push(Json::obj(vec![
            ("variant", Json::str(v)),
            ("comm_s", Json::Num(comm)),
            ("compute_s", Json::Num(compute)),
            ("samples_per_s", Json::Num((m.batch as u64 * ctxscale) as f64 / total)),
            ("accuracy", Json::Num(acc)),
        ]));
    }
    ctx.out_json.insert("fig1".into(), Json::Arr(rows));
    println!();
    Ok(())
}

/// Fig 3: ReLU communication split of the baseline.
fn fig3(ctx: &mut FigCtx) -> Result<()> {
    println!("=== Figure 3: baseline ReLU communication split ({ANCHOR}) ===");
    let (m, _) = ctx.measure(ANCHOR, "baseline")?;
    let total = m.protocol_bytes() as f64;
    let names = ["Circuit", "Others", "Mult", "B2A"];
    let paper = [82.76, 6.9, 6.9, 3.45];
    let mut rows = Vec::new();
    for (i, name) in names.iter().enumerate() {
        let frac = 100.0 * m.bytes_by_phase[i] as f64 / total;
        println!(
            "{name:<8} {:>10} {:>7.2}%   (paper: {:.2}%)",
            stats::fmt_bytes(m.bytes_by_phase[i]),
            frac,
            paper[i]
        );
        rows.push(Json::obj(vec![
            ("phase", Json::str(*name)),
            ("bytes", Json::Int(m.bytes_by_phase[i] as i64)),
            ("fraction", Json::Num(frac / 100.0)),
        ]));
    }
    ctx.out_json.insert("fig3".into(), Json::Arr(rows));
    println!();
    Ok(())
}

/// Figs 7 & 8: per-benchmark speedups on LAN for a GPU profile.
fn fig7_8(ctx: &mut FigCtx, gpu: &str) -> Result<()> {
    let scale = if gpu == "A100" { ctx.a100_scale } else { ctx.v100_scale };
    let fig = if gpu == "A100" { "7" } else { "8" };
    println!("=== Figure {fig}: speedup over baseline, LAN + {gpu} ===");
    println!(
        "{:<24} {:>10} {:>10} {:>10}  (accuracy delta vs baseline)",
        "benchmark", "eco", "b8-64", "b6-64"
    );
    let lan = NetworkProfile::lan();
    let ctxscale = ctx.byte_scale();
    let mut rows = Vec::new();
    let mut speedups: BTreeMap<&str, Vec<f64>> = BTreeMap::new();
    for model in BENCHMARKS {
        let (mb, rb) = ctx.measure(model, "baseline")?;
        let tb: f64 = rb.iter().map(|(b, _)| lan.round_time(*b * ctxscale)).sum::<f64>()
            + mb.compute_s * ctxscale as f64 * scale;
        let base_acc = ctx.accuracy(model, "baseline")?;
        let mut cells = Vec::new();
        let mut deltas = Vec::new();
        for v in &VARIANTS[1..] {
            let (m, r) = ctx.measure(model, v)?;
            let t: f64 = r.iter().map(|(b, _)| lan.round_time(*b * ctxscale)).sum::<f64>()
                + m.compute_s * ctxscale as f64 * scale;
            let acc = ctx.accuracy(model, v)?;
            cells.push(tb / t);
            deltas.push((acc - base_acc) * 100.0);
            speedups.entry(v).or_default().push(tb / t);
        }
        println!(
            "{:<24} {:>9.2}x {:>9.2}x {:>9.2}x  ({:+.1}% / {:+.1}% / {:+.1}%)",
            model, cells[0], cells[1], cells[2], deltas[0], deltas[1], deltas[2]
        );
        rows.push(Json::obj(vec![
            ("model", Json::str(model)),
            ("speedups", Json::arr(cells.iter().map(|c| Json::Num(*c)))),
            ("acc_deltas", Json::arr(deltas.iter().map(|c| Json::Num(*c)))),
        ]));
    }
    for (v, s) in &speedups {
        println!("geomean {v}: {:.2}x", stats::geomean(s));
    }
    ctx.out_json.insert(format!("fig{fig}"), Json::Arr(rows));
    println!();
    Ok(())
}

/// Fig 9: geomean speedup per network profile.
fn fig9(ctx: &mut FigCtx) -> Result<()> {
    println!("=== Figure 9: geomean speedup across benchmarks per network (A100) ===");
    println!("{:<10} {:>10} {:>10} {:>10}", "network", "eco", "b8-64", "b6-64");
    let nets = [NetworkProfile::high_bw(), NetworkProfile::lan(), NetworkProfile::wan()];
    let ctxscale = ctx.byte_scale();
    let mut rows = Vec::new();
    for net in &nets {
        let mut per_variant = Vec::new();
        for v in &VARIANTS[1..] {
            let mut s = Vec::new();
            for model in BENCHMARKS {
                let (mb, rb) = ctx.measure(model, "baseline")?;
                let (m, r) = ctx.measure(model, v)?;
                let tb: f64 = rb.iter().map(|(b, _)| net.round_time(*b * ctxscale)).sum::<f64>()
                    + mb.compute_s * ctxscale as f64 * ctx.a100_scale;
                let t: f64 = r.iter().map(|(b, _)| net.round_time(*b * ctxscale)).sum::<f64>()
                    + m.compute_s * ctxscale as f64 * ctx.a100_scale;
                s.push(tb / t);
            }
            per_variant.push(stats::geomean(&s));
        }
        println!(
            "{:<10} {:>9.2}x {:>9.2}x {:>9.2}x",
            net.name, per_variant[0], per_variant[1], per_variant[2]
        );
        rows.push(Json::obj(vec![
            ("network", Json::str(net.name.clone())),
            ("geomean_speedups", Json::arr(per_variant.iter().map(|c| Json::Num(*c)))),
        ]));
    }
    ctx.out_json.insert("fig9".into(), Json::Arr(rows));
    println!();
    Ok(())
}

/// Fig 10: comm vs compute fraction, baseline vs b8-64, A100 + V100.
fn fig10(ctx: &mut FigCtx) -> Result<()> {
    println!("=== Figure 10: overhead breakdown (LAN), {ANCHOR} ===");
    println!("{:<22} {:>10} {:>10} {:>8}", "config", "comm", "compute", "comm%");
    let lan = NetworkProfile::lan();
    let ctxscale = ctx.byte_scale();
    let mut rows = Vec::new();
    for (gpu, scale) in [("A100", ctx.a100_scale), ("V100", ctx.v100_scale)] {
        for v in ["baseline", "b8-64"] {
            let (m, r) = ctx.measure(ANCHOR, v)?;
            let comm: f64 = r.iter().map(|(b, _)| lan.round_time(*b * ctxscale)).sum();
            let compute = m.compute_s * ctxscale as f64 * scale;
            let frac = 100.0 * comm / (comm + compute);
            println!(
                "{:<22} {:>10} {:>10} {:>7.1}%",
                format!("{gpu}/{v}"),
                stats::fmt_secs(comm),
                stats::fmt_secs(compute),
                frac
            );
            rows.push(Json::obj(vec![
                ("gpu", Json::str(gpu)),
                ("variant", Json::str(v)),
                ("comm_fraction", Json::Num(frac / 100.0)),
            ]));
        }
    }
    println!("(paper: baseline 93% / 78% comm on A100/V100; b8-64 78% / 39%)");
    ctx.out_json.insert("fig10".into(), Json::Arr(rows));
    println!();
    Ok(())
}

/// Fig 11: normalized bytes (bar) and rounds (line) per variant.
fn fig11(ctx: &mut FigCtx) -> Result<()> {
    println!("=== Figure 11: communicated bytes & rounds (normalized to baseline) ===");
    println!(
        "{:<24} {:>22} {:>22}",
        "benchmark", "bytes eco/b8/b6 (x less)", "rounds eco/b8/b6 (x less)"
    );
    let mut rows = Vec::new();
    let mut byte_ratios = Vec::new();
    let mut round_ratios = Vec::new();
    for model in BENCHMARKS {
        let (mb, _) = ctx.measure(model, "baseline")?;
        let mut bcells = Vec::new();
        let mut rcells = Vec::new();
        for v in &VARIANTS[1..] {
            let (m, _) = ctx.measure(model, v)?;
            bcells.push(mb.protocol_bytes() as f64 / m.protocol_bytes() as f64);
            rcells.push(mb.total_rounds as f64 / m.total_rounds as f64);
        }
        println!(
            "{:<24} {:>6.2}/{:>5.2}/{:>5.2} {:>12.2}/{:>5.2}/{:>5.2}",
            model, bcells[0], bcells[1], bcells[2], rcells[0], rcells[1], rcells[2]
        );
        byte_ratios.extend_from_slice(&bcells[1..]);
        round_ratios.extend_from_slice(&rcells[1..]);
        rows.push(Json::obj(vec![
            ("model", Json::str(model)),
            ("byte_reduction", Json::arr(bcells.iter().map(|c| Json::Num(*c)))),
            ("round_reduction", Json::arr(rcells.iter().map(|c| Json::Num(*c)))),
        ]));
    }
    println!(
        "byte reduction range {:.2}-{:.2}x (paper: 2.68-8.76x); rounds {:.2}-{:.2}x (paper: 1.12-1.56x)",
        byte_ratios.iter().cloned().fold(f64::MAX, f64::min),
        byte_ratios.iter().cloned().fold(0.0, f64::max),
        round_ratios.iter().cloned().fold(f64::MAX, f64::min),
        round_ratios.iter().cloned().fold(0.0, f64::max),
    );
    ctx.out_json.insert("fig11".into(), Json::Arr(rows));
    println!();
    Ok(())
}

/// Fig 12: retained/discarded bit map, naive-uniform vs searched (b8-64).
fn fig12(ctx: &mut FigCtx) -> Result<()> {
    println!("=== Figure 12: retained bits per ReLU group ({ANCHOR}, budget 8/64) ===");
    let cfg = ModelConfig::load_named(&ctx.root, ANCHOR)?;
    let searched = ctx.plan(ANCHOR, "b8-64")?;
    let naive = PlanSet::uniform(cfg.relu_groups, 8, 0)?;
    let render = |name: &str, plans: &PlanSet| {
        println!("{name}:");
        for g in 0..cfg.relu_groups {
            let p = plans.plan_for(g);
            let mut bar = String::with_capacity(64);
            for bit in (0..64).rev() {
                bar.push(if bit >= p.m && bit < p.k { '#' } else { '.' });
            }
            println!("  G{g} [{:>2},{:>2})  {bar}", p.m, p.k);
        }
    };
    render("naive (same bits everywhere)", &naive);
    render("HummingBird search", &searched);
    let naive_acc = {
        // evaluate naive plan accuracy for the ablation
        let weights = Archive::load(ctx.artifacts().join("weights").join(ANCHOR))?;
        let dataset = Dataset::load(ctx.artifacts(), &cfg.dataset)?;
        let manifest = Manifest::load(ctx.artifacts())?;
        let model_art = manifest.model(ANCHOR)?.clone();
        let backend = Backend::Xla {
            rt: Runtime::new(ctx.artifacts())?,
            artifact_batch: model_art.search_batch,
            artifacts: model_art,
            which: WhichPlain::Search,
        };
        let exec = PlainExecutor::new(cfg.clone(), weights, backend);
        let n = ctx.acc_samples.min(dataset.test.n);
        simulator::evaluate_plans(
            &exec,
            &dataset.test.images[..n * dataset.test.sample_elems],
            &dataset.test.labels[..n],
            dataset.test.sample_elems,
            64,
            &naive,
            3,
        )?
    };
    let searched_acc = ctx.accuracy(ANCHOR, "b8-64")?;
    let base_acc = ctx.accuracy(ANCHOR, "baseline")?;
    println!(
        "accuracy: baseline {:.2}%, searched {:.2}%, naive-uniform {:.2}% (search engine ablation)",
        base_acc * 100.0,
        searched_acc * 100.0,
        naive_acc * 100.0
    );
    ctx.out_json.insert(
        "fig12".into(),
        Json::obj(vec![
            ("baseline_acc", Json::Num(base_acc)),
            ("searched_acc", Json::Num(searched_acc)),
            ("naive_acc", Json::Num(naive_acc)),
            ("searched_plan", searched.to_json()),
        ]),
    );
    println!();
    Ok(())
}

/// Table 1: baseline accuracies.
fn tab1(ctx: &mut FigCtx) -> Result<()> {
    println!("=== Table 1: baseline model accuracy ===");
    let summary = json::parse_file(ctx.artifacts().join("train_summary.json")).ok();
    let mut rows = Vec::new();
    for model in BENCHMARKS {
        let acc = ctx.accuracy(model, "baseline")?;
        let train_acc = summary
            .as_ref()
            .and_then(|s| s.opt(model))
            .and_then(|m| m.opt("test_acc"))
            .and_then(|v| v.as_f64().ok());
        println!(
            "{model:<24} {:.2}%{}",
            acc * 100.0,
            train_acc
                .map(|t| format!("  (python eval: {:.2}%)", t * 100.0))
                .unwrap_or_default()
        );
        rows.push(Json::obj(vec![
            ("model", Json::str(model)),
            ("accuracy", Json::Num(acc)),
        ]));
    }
    ctx.out_json.insert("tab1".into(), Json::Arr(rows));
    println!();
    Ok(())
}

/// Table 2: search wall time per benchmark / budget.
fn tab2(ctx: &mut FigCtx) -> Result<()> {
    println!("=== Table 2: search time ===");
    println!("{:<24} {:>12} {:>12}", "benchmark", "8/64", "6/64");
    let mut rows = Vec::new();
    for model in BENCHMARKS {
        let mut cells = Vec::new();
        for v in ["b8-64", "b6-64"] {
            let plans = ctx.plan(model, v)?; // searches if missing
            let t = plans
                .meta
                .get("search_time_s")
                .and_then(|s| s.parse::<f64>().ok())
                .unwrap_or(f64::NAN);
            cells.push(t);
        }
        println!(
            "{:<24} {:>12} {:>12}",
            model,
            stats::fmt_secs(cells[0]),
            stats::fmt_secs(cells[1])
        );
        rows.push(Json::obj(vec![
            ("model", Json::str(model)),
            ("search_s", Json::arr(cells.iter().map(|c| Json::Num(*c)))),
        ]));
    }
    ctx.out_json.insert("tab2".into(), Json::Arr(rows));
    println!();
    Ok(())
}

/// Table 3: finetuning impact (reads python finetune outputs).
fn tab3(ctx: &mut FigCtx) -> Result<()> {
    println!("=== Table 3: accuracy before/after finetuning (HummingBird-6/64) ===");
    let mut rows = Vec::new();
    let mut any = false;
    for model in BENCHMARKS {
        let path = ctx.artifacts().join(format!("finetune_{model}.json"));
        if let Ok(j) = json::parse_file(&path) {
            let before = j.get_f64("acc_before_ft")?;
            let after = j.get_f64("acc_after_ft")?;
            println!(
                "{model:<24} before {:.2}%  after {:.2}%  ({:+.2}%)",
                before * 100.0,
                after * 100.0,
                (after - before) * 100.0
            );
            rows.push(Json::obj(vec![
                ("model", Json::str(model)),
                ("before", Json::Num(before)),
                ("after", Json::Num(after)),
            ]));
            any = true;
        }
    }
    if !any {
        println!("(no finetune results yet — run `make finetune`)");
    }
    ctx.out_json.insert("tab3".into(), Json::Arr(rows));
    println!();
    Ok(())
}
