//! `hblint` — HummingBird's repo-invariant linter (DESIGN.md §8).
//!
//! A dependency-free static analysis pass over `src/`, `benches/` and
//! `tests/` enforcing the four repo invariants clippy cannot express
//! (SAFETY comments on `unsafe`, the hot-path allocation gate, CommTrace
//! accounting on transports, the crate-wide unwrap wall). See
//! [`hummingbird::analysis`] for the rule semantics.
//!
//! Usage:
//!
//! ```text
//! cargo run --bin hblint                # scan the tree; exit 1 on findings
//! cargo run --bin hblint -- --self-test # verify rules against the fixture
//! cargo run --bin hblint -- <root>      # scan an explicit crate root
//! ```
//!
//! CI runs both modes as blocking steps: the self-test proves the rules
//! still *detect* the seeded violations in `tests/hblint_fixture/` (a lint
//! that silently goes blind is worse than none), then the tree scan proves
//! the crate is clean.

use std::path::PathBuf;
use std::process::ExitCode;

use hummingbird::analysis;

fn main() -> ExitCode {
    let mut self_test = false;
    let mut root: Option<PathBuf> = None;
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--self-test" => self_test = true,
            "--help" | "-h" => {
                println!("usage: hblint [--self-test] [crate-root]");
                return ExitCode::SUCCESS;
            }
            other => root = Some(PathBuf::from(other)),
        }
    }
    // Default to the crate root baked in at compile time, so the binary
    // works from any working directory (CI runs it from `rust/`).
    let root = root.unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")));

    if self_test {
        return match analysis::self_test(&root) {
            Ok(n) => {
                println!("hblint self-test: OK ({n} seeded violations reproduced exactly)");
                ExitCode::SUCCESS
            }
            Err(e) => {
                eprintln!("hblint self-test FAILED: {e}");
                ExitCode::FAILURE
            }
        };
    }

    match analysis::scan_tree(&root) {
        Ok(findings) if findings.is_empty() => {
            println!("hblint: clean (scanned {:?})", analysis::SCAN_DIRS);
            ExitCode::SUCCESS
        }
        Ok(findings) => {
            for f in &findings {
                println!("{f}");
            }
            eprintln!("hblint: {} finding(s)", findings.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("hblint: {e}");
            ExitCode::FAILURE
        }
    }
}
