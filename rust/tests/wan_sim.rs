//! Virtual-time WAN end-to-end test (DESIGN.md §10): chunked DReLU over a
//! [`SimTransport`] in virtual-time mode, so a 50 ms-RTT run completes in
//! microseconds of wall clock while the [`MockClock`] reads the exact
//! modeled time. This pins the §10 performance model deterministically:
//!
//! - serial schedule: every round pays one one-way latency
//!   → elapsed = rounds × L + total_tx
//! - overlapped schedule: one latency per lockstep *wave*
//!   → elapsed = waves × L + total_tx
//!
//! and the success metric — overlapped e2e ≤ 1.15 × max(compute, wire) —
//! holds with room to spare (compute is free on a virtual clock, so the
//! bound is the wire time itself), while serial is multiples of it.

use std::time::Duration;

use hummingbird::crypto::prg::Prg;
use hummingbird::gmw::{GmwParty, ReluPlan};
use hummingbird::net::local::hub;
use hummingbird::net::profile::NetworkProfile;
use hummingbird::net::sim::SimTransport;
use hummingbird::sharing::share_arith;

const N: usize = 512;
const CHUNKS: usize = 4;
const BW_BPS: f64 = 8e6; // 1 µs per byte: hand-checkable serialization time

fn approx(d: Duration, secs: f64) {
    assert!((d.as_secs_f64() - secs).abs() < 1e-6, "{d:?} !~ {secs}s");
}

/// One 2-party chunked DReLU with party 0 behind a virtual-time simulated
/// link. Returns (party 0 modeled elapsed, both output shares, rounds,
/// bytes). Party 1 runs unsimulated — the rendezvous exchanges keep the
/// protocol lockstep, and only party 0's clock is measured.
fn run_virtual(
    xs: &[Vec<u64>],
    plan: ReluPlan,
    lat_s: f64,
    overlap: bool,
) -> (Duration, Vec<Vec<u64>>, u64, u64) {
    let np = NetworkProfile::new("virt", lat_s, BW_BPS);
    let mut ts = hub(2);
    let t1 = ts.pop().unwrap();
    let t0 = ts.pop().unwrap();
    let trace = t0.trace();
    let (sim, mock) = SimTransport::virtual_time(t0, np);
    let (o0, o1) = std::thread::scope(|s| {
        let h1 = s.spawn(|| {
            let mut p = GmwParty::new(t1, 0x77);
            p.drelu_chunked(&xs[1], plan, CHUNKS, overlap).unwrap()
        });
        let mut p = GmwParty::new(sim, 0x77);
        let o0 = p.drelu_chunked(&xs[0], plan, CHUNKS, overlap).unwrap();
        (o0, h1.join().unwrap())
    });
    (mock.now(), vec![o0, o1], trace.total_rounds(), trace.total_bytes())
}

#[test]
fn serial_pays_latency_per_round_overlapped_per_wave() {
    let mut prg = Prg::new(0xC0, 1);
    let x: Vec<u64> = (0..N)
        .map(|i| if i % 2 == 0 { i as u64 } else { (i as u64).wrapping_neg() })
        .collect();
    let xs = share_arith(&mut prg, &x, 2);
    let plan = ReluPlan::new(12, 4).unwrap(); // w = 8: init + 3 stages + B2A
    let lat = 25e-3; // 50 ms RTT, one-way per round (net::profile convention)

    let (t_serial, o_serial, rounds, bytes) = run_virtual(&xs, plan, lat, false);
    let (t_overlap, o_overlap, rounds2, bytes2) = run_virtual(&xs, plan, lat, true);

    // Bit-identity on the virtual link too: shares, rounds and bytes.
    assert_eq!(o_serial, o_overlap, "schedules diverged on shares");
    assert_eq!((rounds, bytes), (rounds2, bytes2), "schedules diverged on the wire");

    // The §10 closed forms, computed from the actual trace.
    let tx = bytes as f64 * 8.0 / BW_BPS;
    assert_eq!(rounds % CHUNKS as u64, 0, "every chunk runs the same round program");
    let waves = rounds / CHUNKS as u64;
    assert!(waves >= 2, "need a multi-round circuit for the schedule to matter");
    let want_serial = rounds as f64 * lat + tx;
    let want_overlap = waves as f64 * lat + tx;
    approx(t_serial, want_serial);
    approx(t_overlap, want_overlap);

    // Success metric, pinned deterministically: overlapped ≤ 1.15 ×
    // max(compute, wire) (virtual compute is free → bound = wire), while
    // serial pays per-round latency and lands at a multiple of the bound.
    assert!(t_overlap.as_secs_f64() <= 1.15 * want_overlap);
    assert!(
        t_serial.as_secs_f64() > 2.0 * want_overlap,
        "serial {t_serial:?} should be several × the overlapped bound {want_overlap}"
    );
}

/// Low-RTT sanity: at sub-millisecond latency the two schedules are close
/// (the serialization term dominates), so overlap is a WAN optimization,
/// not a LAN regression.
#[test]
fn low_rtt_schedules_are_close() {
    let mut prg = Prg::new(0xC1, 1);
    let x: Vec<u64> = (0..N).map(|i| (i as u64).wrapping_mul(13)).collect();
    let xs = share_arith(&mut prg, &x, 2);
    let plan = ReluPlan::new(12, 4).unwrap();
    let lat = 0.5e-3; // 1 ms RTT

    let (t_serial, _, rounds, bytes) = run_virtual(&xs, plan, lat, false);
    let (t_overlap, _, _, _) = run_virtual(&xs, plan, lat, true);
    let tx = bytes as f64 * 8.0 / BW_BPS;
    approx(t_serial, rounds as f64 * lat + tx);
    // The gap is exactly (rounds − waves) × latency — small at low RTT.
    let waves = rounds / CHUNKS as u64;
    approx(t_overlap, waves as f64 * lat + tx);
    assert!(t_serial > t_overlap, "overlap never costs modeled time");
}
