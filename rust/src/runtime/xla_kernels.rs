//! [`KernelBackend`] implementation backed by the Pallas-lowered HLO
//! artifacts, executed via PJRT (the three-layer composition path).
//!
//! Inputs are padded to the smallest artifact bucket (or chunked above the
//! largest); padding lanes compute garbage that is sliced off. Scalars
//! (shift / mask / leader) travel as `[1]`-shaped i64 literals.
//!
//! Note on when to use this: for the small tensors of a single layer the
//! pure-Rust kernels win (PJRT dispatch ≈ 10–50 µs per call); the XLA path
//! exists to (a) prove L1→L3 composition end-to-end and (b) model the
//! accelerator deployment, where these kernels run on-device next to the
//! linear layers. `benches/gmw_micro.rs` quantifies the crossover.

use crate::gmw::kernels::KernelBackend;
use crate::ring;

use super::{literal_i64, Manifest, Runtime};

/// PJRT-backed kernels for one party.
pub struct XlaKernels {
    rt: Runtime,
    manifest: Manifest,
}

impl XlaKernels {
    pub fn new(rt: Runtime, manifest: Manifest) -> Self {
        XlaKernels { rt, manifest }
    }

    /// Run kernel `name` on vector operands (each length n) + scalar
    /// operands, returning `outputs` flat i64 vectors. Handles bucket
    /// padding and chunking.
    // This adapter runs only when AOT kernel artifacts are present (callers
    // gate on the registry); inside that envelope a missing or malformed
    // artifact is unrecoverable operator error, so it panics by design.
    #[allow(clippy::unwrap_used, clippy::expect_used)]
    fn run(
        &mut self,
        name: &str,
        vecs: &[&[u64]],
        scalars: &[i64],
        out_rows: usize,
    ) -> Vec<Vec<u64>> {
        let n = vecs[0].len();
        let largest = *self.manifest.kernel_buckets.last().unwrap();
        let mut outs: Vec<Vec<u64>> = (0..out_rows).map(|_| Vec::with_capacity(n)).collect();
        let mut start = 0usize;
        while start < n {
            let chunk = (n - start).min(largest);
            let bucket = self.manifest.bucket_for(chunk);
            let path = self
                .manifest
                .kernel_path(name, bucket)
                .unwrap_or_else(|e| panic!("{e}"))
                .to_string();
            let exe = self.rt.load(&path).expect("kernel artifact load");
            let mut lits = Vec::with_capacity(vecs.len() + scalars.len());
            for v in vecs {
                let mut padded: Vec<i64> = Vec::with_capacity(bucket);
                padded.extend(v[start..start + chunk].iter().map(|x| *x as i64));
                padded.resize(bucket, 0);
                lits.push(literal_i64(&padded, &[bucket]).expect("literal"));
            }
            for s in scalars {
                lits.push(literal_i64(&[*s], &[1]).expect("literal"));
            }
            let results = self.rt.execute(&exe, &lits).expect("kernel execute");
            // Outputs are either one [2, bucket] array (open kernels), one
            // [bucket] array (combine kernels) or two arrays (stage kernels);
            // flatten in row order and slice off padding.
            let mut row = 0usize;
            for lit in results {
                let data = lit.to_vec::<i64>().expect("output data");
                let rows_here = data.len() / bucket;
                for r in 0..rows_here {
                    outs[row + r]
                        .extend(data[r * bucket..r * bucket + chunk].iter().map(|x| *x as u64));
                }
                row += rows_here;
            }
            debug_assert_eq!(row, out_rows);
            start += chunk;
        }
        outs
    }
}

#[allow(clippy::too_many_arguments)]
impl KernelBackend for XlaKernels {
    fn and_open(&mut self, u: &[u64], v: &[u64], a: &[u64], b: &[u64], out: &mut [u64]) {
        let n = u.len();
        debug_assert_eq!(out.len(), 2 * n);
        let outs = self.run("and_open", &[u, v, a, b], &[], 2);
        out[..n].copy_from_slice(&outs[0]);
        out[n..].copy_from_slice(&outs[1]);
    }

    fn and_combine(
        &mut self,
        d: &[u64],
        e: &[u64],
        a: &[u64],
        b: &[u64],
        c: &[u64],
        leader: bool,
        out: &mut [u64],
    ) {
        let lead = if leader { -1i64 } else { 0 };
        let outs = self.run("and_combine", &[d, e, a, b, c], &[lead], 1);
        out.copy_from_slice(&outs[0]);
    }

    fn ks_stage_operands(
        &mut self,
        g: &[u64],
        p: &[u64],
        s: u32,
        w: u32,
        last: bool,
        u_out: &mut [u64],
        v_out: &mut [u64],
    ) {
        let n = g.len();
        let mask = ring::low_mask(w) as i64;
        let name = if last { "ks_stage_last" } else { "ks_stage_mid" };
        let rows = if last { 2 } else { 4 }; // u rows then v rows
        let outs = self.run(name, &[g, p], &[s as i64, mask], rows);
        if last {
            debug_assert!(u_out.len() == n && v_out.len() == n);
            u_out.copy_from_slice(&outs[0]);
            v_out.copy_from_slice(&outs[1]);
        } else {
            // outs = [u0, u1, v0, v1]; halves concatenate into the buffers.
            debug_assert!(u_out.len() == 2 * n && v_out.len() == 2 * n);
            u_out[..n].copy_from_slice(&outs[0]);
            u_out[n..].copy_from_slice(&outs[1]);
            v_out[..n].copy_from_slice(&outs[2]);
            v_out[n..].copy_from_slice(&outs[3]);
        }
    }

    fn mult_open(&mut self, x: &[u64], y: &[u64], a: &[u64], b: &[u64], out: &mut [u64]) {
        let n = x.len();
        debug_assert_eq!(out.len(), 2 * n);
        let outs = self.run("mult_open", &[x, y, a, b], &[], 2);
        out[..n].copy_from_slice(&outs[0]);
        out[n..].copy_from_slice(&outs[1]);
    }

    fn mult_combine(
        &mut self,
        d: &[u64],
        e: &[u64],
        a: &[u64],
        b: &[u64],
        c: &[u64],
        leader: bool,
        out: &mut [u64],
    ) {
        let lead = if leader { -1i64 } else { 0 };
        let outs = self.run("mult_combine", &[d, e, a, b, c], &[lead], 1);
        out.copy_from_slice(&outs[0]);
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}
