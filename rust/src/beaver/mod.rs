//! Beaver triple provisioning (paper §2.2, §5.1).
//!
//! The paper "does not model the overhead of generating Beaver triplets,
//! assuming they are generated and stored offline or sent by a trusted
//! third-party (TTP) asynchronously". We reproduce that accounting exactly:
//! a [`TtpDealer`] derives each party's share of every triple from a
//! deterministic dealer stream, so provisioning costs **zero protocol
//! communication** and is excluded from the timed online phase. The dealer
//! still *counts* what it hands out ([`TripleUsage`]) so the offline-storage
//! requirement — a real operational concern the paper mentions — can be
//! reported per run.
//!
//! Security note (see DESIGN.md §4): in a deployment the dealer streams
//! would be delivered per-party over private channels; this performance
//! testbed derives them from a session seed shared by the simulated
//! parties. The *online protocol* messages are identical either way.
//!
//! Three correlation types are produced:
//! * arithmetic triples  (⟨a⟩, ⟨b⟩, ⟨c⟩) with c = a·b  (ring mult / ReLU's Mult step)
//! * binary triples      (⟨a⟩, ⟨b⟩, ⟨c⟩) with c = a∧b  (AND gates in the adder circuit; one u64 = 64 bit-triples)
//! * daBits              (⟨r⟩^B, ⟨r⟩^A) for a random bit r (the 1-bit B2A conversion)

use crate::crypto::prg::Prg;

/// This party's slice of a batch of arithmetic triples.
#[derive(Debug, Clone)]
pub struct ArithTriples {
    pub a: Vec<u64>,
    pub b: Vec<u64>,
    pub c: Vec<u64>,
}

/// This party's slice of a batch of binary (AND) triples. Each u64 carries
/// 64 independent bit-triples; callers mask to their lane width.
#[derive(Debug, Clone)]
pub struct BinTriples {
    pub a: Vec<u64>,
    pub b: Vec<u64>,
    pub c: Vec<u64>,
}

/// This party's slice of a batch of daBits.
#[derive(Debug, Clone)]
pub struct DaBits {
    /// Binary share of r (one bit in the LSB of each u64 lane).
    pub r_bin: Vec<u64>,
    /// Arithmetic share of the same r.
    pub r_arith: Vec<u64>,
}

/// Cumulative count of correlations consumed (offline storage report).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct TripleUsage {
    pub arith_triples: u64,
    /// Counted in u64 *words* (64 bit-triples each).
    pub bin_triple_words: u64,
    pub dabits: u64,
}

impl TripleUsage {
    /// Bytes a party would need to store for this usage (3 u64 per arith
    /// triple, 3 u64 per binary word, 2 u64 + 1 bit per daBit — we round the
    /// daBit binary part up to a word per 64).
    pub fn storage_bytes(&self) -> u64 {
        self.arith_triples * 24 + self.bin_triple_words * 24 + self.dabits * 9
    }
}

/// Deterministic TTP dealer: every party constructs one with the same
/// session seed and its own party id, then pulls correlations in protocol
/// order. Stream synchronization is guaranteed by protocol determinism.
pub struct TtpDealer {
    party: usize,
    parties: usize,
    prg: Prg,
    usage: TripleUsage,
}

impl TtpDealer {
    pub fn new(session_seed: u64, party: usize, parties: usize) -> Self {
        assert!(parties >= 2 && party < parties);
        TtpDealer {
            party,
            parties,
            prg: Prg::new(session_seed ^ DEALER_DOMAIN, 0),
            usage: TripleUsage::default(),
        }
    }

    pub fn usage(&self) -> TripleUsage {
        self.usage
    }

    /// Draw arithmetic triples into caller-provided buffers (all the same
    /// length). Allocation-free: the zero-allocation hot path hands in
    /// arena-pooled buffers. Stream consumption is identical to
    /// [`TtpDealer::arith_triples`].
    pub fn arith_triples_into(&mut self, a: &mut [u64], b: &mut [u64], c: &mut [u64]) {
        let n = a.len();
        debug_assert!(b.len() == n && c.len() == n);
        self.usage.arith_triples += n as u64;
        for i in 0..n {
            // Dealer samples plaintext a, b and all share randomness from
            // the common stream; every party runs this same loop and keeps
            // only its own column.
            let pa = self.prg.next_u64();
            let pb = self.prg.next_u64();
            let pc = pa.wrapping_mul(pb);
            a[i] = self.split_arith(pa);
            b[i] = self.split_arith(pb);
            c[i] = self.split_arith(pc);
        }
    }

    /// Draw `n` arithmetic triples; returns this party's shares.
    pub fn arith_triples(&mut self, n: usize) -> ArithTriples {
        let mut out = ArithTriples { a: vec![0; n], b: vec![0; n], c: vec![0; n] };
        self.arith_triples_into(&mut out.a, &mut out.b, &mut out.c);
        out
    }

    /// Draw binary-triple words into caller-provided buffers, masking each
    /// share to `mask` as it is written (so shares of w-bit lanes stay
    /// w-bit lanes with no extra pass). Every party masks identically, so
    /// the XOR-reconstruction still satisfies `c = a ∧ b` on the masked
    /// lanes. Stream consumption is identical to [`TtpDealer::bin_triples`].
    pub fn bin_triples_into(&mut self, mask: u64, a: &mut [u64], b: &mut [u64], c: &mut [u64]) {
        let n = a.len();
        debug_assert!(b.len() == n && c.len() == n);
        self.usage.bin_triple_words += n as u64;
        for i in 0..n {
            let pa = self.prg.next_u64();
            let pb = self.prg.next_u64();
            let pc = pa & pb;
            a[i] = self.split_binary(pa) & mask;
            b[i] = self.split_binary(pb) & mask;
            c[i] = self.split_binary(pc) & mask;
        }
    }

    /// Draw `n` binary-triple words (64 bit-triples per word).
    pub fn bin_triples(&mut self, n: usize) -> BinTriples {
        let mut out = BinTriples { a: vec![0; n], b: vec![0; n], c: vec![0; n] };
        self.bin_triples_into(u64::MAX, &mut out.a, &mut out.b, &mut out.c);
        out
    }

    /// Draw daBits into caller-provided buffers. Stream consumption is
    /// identical to [`TtpDealer::dabits`].
    pub fn dabits_into(&mut self, r_bin: &mut [u64], r_arith: &mut [u64]) {
        let n = r_bin.len();
        debug_assert_eq!(r_arith.len(), n);
        self.usage.dabits += n as u64;
        for i in 0..n {
            let r = self.prg.next_u64() & 1;
            r_bin[i] = self.split_binary_masked(r, 1);
            r_arith[i] = self.split_arith(r);
        }
    }

    /// Draw `n` daBits.
    pub fn dabits(&mut self, n: usize) -> DaBits {
        let mut out = DaBits { r_bin: vec![0; n], r_arith: vec![0; n] };
        self.dabits_into(&mut out.r_bin, &mut out.r_arith);
        out
    }

    /// Split a dealer-known value arithmetically; return my share.
    /// Consumes `parties - 1` stream values regardless of `self.party` so
    /// all parties stay synchronized.
    #[inline]
    fn split_arith(&mut self, x: u64) -> u64 {
        let mut acc = 0u64;
        let mut mine = 0u64;
        for p in 0..self.parties - 1 {
            let r = self.prg.next_u64();
            acc = acc.wrapping_add(r);
            if p == self.party {
                mine = r;
            }
        }
        if self.party == self.parties - 1 {
            x.wrapping_sub(acc)
        } else {
            mine
        }
    }

    /// Split a dealer-known value in the XOR domain; return my share.
    #[inline]
    fn split_binary(&mut self, x: u64) -> u64 {
        self.split_binary_masked(x, u64::MAX)
    }

    /// XOR-domain split with share randomness restricted to `mask` (so
    /// shares of a w-bit lane stay w-bit lanes).
    #[inline]
    fn split_binary_masked(&mut self, x: u64, mask: u64) -> u64 {
        let mut acc = 0u64;
        let mut mine = 0u64;
        for p in 0..self.parties - 1 {
            let r = self.prg.next_u64() & mask;
            acc ^= r;
            if p == self.party {
                mine = r;
            }
        }
        if self.party == self.parties - 1 {
            x ^ acc
        } else {
            mine
        }
    }
}

/// Domain-separation constant (vs. pairwise zero-sharing streams).
const DEALER_DOMAIN: u64 = 0xbea7_e270_5eed_0002;

#[cfg(test)]
mod tests {
    use super::*;

    fn dealers(parties: usize) -> Vec<TtpDealer> {
        (0..parties).map(|p| TtpDealer::new(999, p, parties)).collect()
    }

    #[test]
    fn arith_triples_satisfy_c_eq_ab() {
        for parties in 2..=4 {
            let mut ds = dealers(parties);
            let batches: Vec<ArithTriples> = ds.iter_mut().map(|d| d.arith_triples(32)).collect();
            for i in 0..32 {
                let a: u64 = batches.iter().fold(0, |s, t| s.wrapping_add(t.a[i]));
                let b: u64 = batches.iter().fold(0, |s, t| s.wrapping_add(t.b[i]));
                let c: u64 = batches.iter().fold(0, |s, t| s.wrapping_add(t.c[i]));
                assert_eq!(c, a.wrapping_mul(b), "parties={parties} i={i}");
            }
        }
    }

    #[test]
    fn bin_triples_satisfy_c_eq_a_and_b() {
        for parties in 2..=4 {
            let mut ds = dealers(parties);
            let batches: Vec<BinTriples> = ds.iter_mut().map(|d| d.bin_triples(32)).collect();
            for i in 0..32 {
                let a: u64 = batches.iter().fold(0, |s, t| s ^ t.a[i]);
                let b: u64 = batches.iter().fold(0, |s, t| s ^ t.b[i]);
                let c: u64 = batches.iter().fold(0, |s, t| s ^ t.c[i]);
                assert_eq!(c, a & b, "parties={parties} i={i}");
            }
        }
    }

    #[test]
    fn dabits_are_consistent_bits() {
        for parties in 2..=3 {
            let mut ds = dealers(parties);
            let batches: Vec<DaBits> = ds.iter_mut().map(|d| d.dabits(64)).collect();
            for i in 0..64 {
                let r_b: u64 = batches.iter().fold(0, |s, t| s ^ t.r_bin[i]) & 1;
                let r_a: u64 = batches.iter().fold(0u64, |s, t| s.wrapping_add(t.r_arith[i]));
                assert_eq!(r_a, r_b, "daBit arith/binary mismatch i={i}");
            }
        }
    }

    #[test]
    fn usage_accounting() {
        let mut d = TtpDealer::new(1, 0, 2);
        d.arith_triples(10);
        d.bin_triples(5);
        d.dabits(3);
        let u = d.usage();
        assert_eq!(u.arith_triples, 10);
        assert_eq!(u.bin_triple_words, 5);
        assert_eq!(u.dabits, 3);
        assert!(u.storage_bytes() > 0);
    }

    #[test]
    fn streams_differ_between_sessions() {
        let mut d1 = TtpDealer::new(1, 0, 2);
        let mut d2 = TtpDealer::new(2, 0, 2);
        assert_ne!(d1.arith_triples(4).a, d2.arith_triples(4).a);
    }
}
