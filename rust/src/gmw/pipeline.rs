//! Overlapped round scheduling for chunked DReLU/ReLU (DESIGN.md §10).
//!
//! At WAN latencies the GMW online phase is round-bound: every AND round
//! pays one propagation delay whether it opens 64 lanes or 64k. The serial
//! driver evaluates a batch's chunks one after another, so `m` chunks pay
//! `m ×` the per-chunk round latency. The chunks are *independent*, though
//! — their rounds can share the wire. This module re-schedules the exact
//! serial round program in **lockstep waves**: every chunk's round `r` is
//! begun back-to-back with the split-phase transport API
//! ([`Transport::exchange_begin`] / `exchange_finish`), so the link
//! serializes `m` frames once and all `m` chunks share one propagation
//! window per wave.
//!
//! # Bit-identity invariant
//!
//! Overlap is a *schedule* change only. Shares, wire bytes and round
//! counts are bit-identical to the serial schedule (chunk-major loop over
//! [`GmwParty::relu_into`]); only the trace *ordering* of rounds differs
//! (wave-major instead of chunk-major). Two mechanisms guarantee it:
//!
//! 1. **Pre-drawn randomness in serial order.** All pairwise-PRG reshares
//!    and dealer correlations (binary triples, daBits, arithmetic triples)
//!    are drawn up front, instance-major — the exact order the serial
//!    driver would draw them — and queued per chunk. The lockstep waves
//!    then consume queued material only, so interleaving cannot permute
//!    any PRG stream. This is also what keeps [`PrefetchDealer`] schedules
//!    valid: the dealer stream is consumed in the same order either way.
//! 2. **The same round program.** The wave loop replays `ks_add`'s exact
//!    stage structure ([`AdderOptions::default`]: batched stage ANDs, last
//!    P skipped) plus the B2A and Mult rounds, per layout, using the same
//!    kernels, pack/unpack routines and wire layouts as the serial path.
//!
//! The equivalence is pinned across layout × prefetch × parties by
//! `tests/overlap_identity.rs`.
//!
//! # Hot-path discipline
//!
//! Everything per-wave comes from the party's arena; per-instance state
//! records are built once per call (setup), and in-flight wire buffers are
//! checked out at `exchange_begin` and recycled at `exchange_finish`.
//!
//! [`PrefetchDealer`]: crate::beaver::prefetch::PrefetchDealer
//! [`AdderOptions::default`]: super::adder::AdderOptions

use std::collections::VecDeque;

use super::bitsliced;
use super::kernels::{BinLayout, KernelBackend};
use super::{GmwParty, ReluPlan};
use crate::bitpack;
use crate::error::{Error, Result};
use crate::net::accounting::Phase;
use crate::net::{self, Transport};
use crate::ring;

fn ceil_log2(w: u32) -> u32 {
    if w <= 1 {
        0
    } else {
        32 - (w - 1).leading_zeros()
    }
}

/// Which AND wave is being run (selects operand source and combine target).
#[derive(Clone, Copy)]
enum AndKind {
    /// `G₀ = acc ∧ op` (Phase::OtherAnd in the serial adder).
    Init,
    /// Prefix stage at shift `s`; `last` stages skip the P half.
    Stage { s: u32, last: bool },
}

/// Per-chunk instance state. Binary state (`acc`, `p`, `g`, `op`, queued
/// reshares and triples) is lane-form (`nn` words) or plane-form
/// ([`bitsliced::plane_len`]`(nn, w)` words) per the party's layout; the
/// B2A/Mult material is always lane-form, as in the serial driver.
struct Inst {
    /// Binary accumulator (the running Kogge–Stone sum).
    acc: Vec<u64>,
    /// Pre-drawn reshare operands for parties `1..P`, front first.
    ops: VecDeque<Vec<u64>>,
    /// Pre-drawn AND-round triples, front = next wave's.
    triples: VecDeque<(Vec<u64>, Vec<u64>, Vec<u64>)>,
    r_bin: Vec<u64>,
    r_arith: Vec<u64>,
    /// Pre-drawn arithmetic triples (ReLU only).
    mul: Option<(Vec<u64>, Vec<u64>, Vec<u64>)>,
    /// DReLU arithmetic shares, held for the Mult wave (ReLU only).
    dshare: Vec<u64>,
    // Transient wave state (valid between a begin pass and its finish pass).
    p: Vec<u64>,
    g: Vec<u64>,
    op: Vec<u64>,
    tri: (Vec<u64>, Vec<u64>, Vec<u64>),
    de: Vec<u64>,
    wire: Vec<u8>,
}

impl<T: Transport, K: KernelBackend> GmwParty<T, K> {
    /// Chunked DReLU: split `arith` into `chunks` equal segments and
    /// evaluate [`GmwParty::drelu_into`] on each. With `overlap` set (and
    /// more than one chunk) the chunks' rounds are pipelined through the
    /// split-phase transport; results are bit-identical either way
    /// (DESIGN.md §10).
    pub fn drelu_chunked_into(
        &mut self,
        arith: &[u64],
        plan: ReluPlan,
        chunks: usize,
        overlap: bool,
        out: &mut [u64],
    ) -> Result<()> {
        validate_chunking(arith.len(), out.len(), chunks)?;
        if plan.is_identity() {
            return Err(Error::config("drelu on an identity plan (k == m) has no sign bit"));
        }
        let nn = arith.len() / chunks;
        if !overlap || chunks == 1 {
            // THE serial baseline the overlapped schedule is pinned against.
            for i in 0..chunks {
                let span = i * nn..(i + 1) * nn;
                // HOT-PATH-ALLOW: Range clone is a 16-byte stack copy, no heap.
                self.drelu_into(&arith[span.clone()], plan, &mut out[span])?;
            }
            return Ok(());
        }
        run_overlapped(self, arith, plan, chunks, false, out)
    }

    /// Chunked DReLU (allocating wrapper).
    pub fn drelu_chunked(
        &mut self,
        arith: &[u64],
        plan: ReluPlan,
        chunks: usize,
        overlap: bool,
    ) -> Result<Vec<u64>> {
        // HOT-PATH-ALLOW: by-value wrapper over `drelu_chunked_into`.
        let mut out = vec![0u64; arith.len()];
        self.drelu_chunked_into(arith, plan, chunks, overlap, &mut out)?;
        Ok(out)
    }

    /// Chunked ReLU: like [`GmwParty::drelu_chunked_into`] but each chunk
    /// finishes with its Beaver-mult round (Eq. 3), also pipelined.
    pub fn relu_chunked_into(
        &mut self,
        arith: &[u64],
        plan: ReluPlan,
        chunks: usize,
        overlap: bool,
        out: &mut [u64],
    ) -> Result<()> {
        validate_chunking(arith.len(), out.len(), chunks)?;
        if plan.is_identity() {
            out.copy_from_slice(arith);
            return Ok(());
        }
        let nn = arith.len() / chunks;
        if !overlap || chunks == 1 {
            for i in 0..chunks {
                let span = i * nn..(i + 1) * nn;
                // HOT-PATH-ALLOW: Range clone is a 16-byte stack copy, no heap.
                self.relu_into(&arith[span.clone()], plan, &mut out[span])?;
            }
            return Ok(());
        }
        run_overlapped(self, arith, plan, chunks, true, out)
    }

    /// Chunked ReLU (allocating wrapper).
    pub fn relu_chunked(
        &mut self,
        arith: &[u64],
        plan: ReluPlan,
        chunks: usize,
        overlap: bool,
    ) -> Result<Vec<u64>> {
        // HOT-PATH-ALLOW: by-value wrapper over `relu_chunked_into`.
        let mut out = vec![0u64; arith.len()];
        self.relu_chunked_into(arith, plan, chunks, overlap, &mut out)?;
        Ok(out)
    }
}

fn validate_chunking(n: usize, out_len: usize, chunks: usize) -> Result<()> {
    if chunks == 0 {
        return Err(Error::config("chunks must be >= 1"));
    }
    if n % chunks != 0 {
        return Err(Error::config(format!("{n} elements do not split into {chunks} equal chunks")));
    }
    if out_len != n {
        return Err(Error::config(format!("output length {out_len} != input length {n}")));
    }
    Ok(())
}

/// Draw one AND wave's triples in the serial dealer order (plane-native
/// stream, `(w, nn, halves)` shape) and queue them in the layout's
/// consumption form — the lane path converts with
/// [`bitsliced::planes_to_lanes`] exactly as `and_gates_lanes_seg_into`
/// does at use.
fn push_triples<T: Transport, K: KernelBackend>(
    party: &mut GmwParty<T, K>,
    w: u32,
    nn: usize,
    halves: usize,
    layout: BinLayout,
    q: &mut VecDeque<(Vec<u64>, Vec<u64>, Vec<u64>)>,
) -> Result<()> {
    let pl = bitsliced::plane_len(nn, w);
    let mut tap = party.arena.take_words(halves * pl);
    let mut tbp = party.arena.take_words(halves * pl);
    let mut tcp = party.arena.take_words(halves * pl);
    party.dealer.bin_triples_planes_into(w, nn, halves, &mut tap, &mut tbp, &mut tcp)?;
    match layout {
        BinLayout::Bitsliced => q.push_back((tap, tbp, tcp)),
        BinLayout::LanePerU64 => {
            let threads = party.threads;
            let mut ta = party.arena.take_words(halves * nn);
            let mut tb = party.arena.take_words(halves * nn);
            let mut tc = party.arena.take_words(halves * nn);
            for s in 0..halves {
                let ln = s * nn..(s + 1) * nn;
                let pn = s * pl..(s + 1) * pl;
                // HOT-PATH-ALLOW: Range clone is a 16-byte stack copy, no heap.
                bitsliced::planes_to_lanes(&tap[pn.clone()], w, nn, &mut ta[ln.clone()], threads);
                // HOT-PATH-ALLOW: Range clone is a 16-byte stack copy, no heap.
                bitsliced::planes_to_lanes(&tbp[pn.clone()], w, nn, &mut tb[ln.clone()], threads);
                bitsliced::planes_to_lanes(&tcp[pn], w, nn, &mut tc[ln], threads);
            }
            party.arena.put_words(tcp);
            party.arena.put_words(tbp);
            party.arena.put_words(tap);
            q.push_back((ta, tb, tc));
        }
    }
    Ok(())
}

/// Pre-draw one chunk's randomness (reshares, adder triples, daBits and —
/// for ReLU — arithmetic triples) in the **serial draw order** and build
/// its instance record.
fn predraw_inst<T: Transport, K: KernelBackend>(
    party: &mut GmwParty<T, K>,
    x: &[u64],
    plan: ReluPlan,
    with_mul: bool,
    layout: BinLayout,
) -> Result<Inst> {
    let nn = x.len();
    let w = plan.width();
    let mask = ring::low_mask(w);
    let threads = party.threads;
    let me = party.party();
    let parties = party.parties();
    let unit = match layout {
        BinLayout::LanePerU64 => nn,
        BinLayout::Bitsliced => bitsliced::plane_len(nn, w),
    };

    // Window extraction + the A2B input mask (both local, as in serial).
    let mut masked = party.arena.take_words(nn);
    for (mi, xi) in masked.iter_mut().zip(x) {
        *mi = ring::bit_window(*xi, plan.k, plan.m) & mask;
    }

    // Binary re-sharing of every party's operand — the same zero-sharing
    // stream draws, in the same j order, as the serial `a2b_into`.
    let mut ops = VecDeque::new();
    let mut acc = Vec::default();
    let mut lanes = party.arena.take_words(nn);
    for j in 0..parties {
        let value = if j == me { Some(&masked[..]) } else { None };
        party.pairwise.reshare_binary_into(value, &mut lanes);
        let mut dst = party.arena.take_words(unit);
        match layout {
            BinLayout::LanePerU64 => {
                for (di, li) in dst.iter_mut().zip(&lanes) {
                    *di = li & mask;
                }
            }
            BinLayout::Bitsliced => bitsliced::lanes_to_planes(&lanes, w, &mut dst, threads),
        }
        if j == 0 {
            acc = dst;
        } else {
            ops.push_back(dst);
        }
    }
    party.arena.put_words(lanes);
    party.arena.put_words(masked);

    // w == 1: addition mod 2 is XOR — fold the operands now, no waves.
    if w == 1 {
        while let Some(op) = ops.pop_front() {
            for (a, o) in acc.iter_mut().zip(&op) {
                *a ^= o;
            }
            party.arena.put_words(op);
        }
    }

    // Dealer draws, exactly as the serial chunk would issue them: per
    // fold-in j, the init AND then each prefix stage; then the daBits;
    // then (ReLU) the arithmetic triples.
    let mut triples = VecDeque::new();
    if w > 1 {
        let stages = ceil_log2(w);
        for _j in 1..parties {
            push_triples(party, w, nn, 1, layout, &mut triples)?;
            for idx in 0..stages {
                let last = idx + 1 == stages;
                let halves = if last { 1 } else { 2 };
                push_triples(party, w, nn, halves, layout, &mut triples)?;
            }
        }
    }
    let mut r_bin = party.arena.take_words(nn);
    let mut r_arith = party.arena.take_words(nn);
    party.dealer.dabits_into(&mut r_bin, &mut r_arith)?;
    let mul = if with_mul {
        let mut ta = party.arena.take_words(nn);
        let mut tb = party.arena.take_words(nn);
        let mut tc = party.arena.take_words(nn);
        party.dealer.arith_triples_into(&mut ta, &mut tb, &mut tc)?;
        Some((ta, tb, tc))
    } else {
        None
    };

    let (p, g) = if w > 1 {
        (party.arena.take_words(unit), party.arena.take_words(unit))
    } else {
        (Vec::default(), Vec::default())
    };
    Ok(Inst {
        acc,
        ops,
        triples,
        r_bin,
        r_arith,
        mul,
        dshare: if with_mul { party.arena.take_words(nn) } else { Vec::default() },
        p,
        g,
        op: Vec::default(),
        tri: <(Vec<u64>, Vec<u64>, Vec<u64>)>::default(),
        de: Vec::default(),
        wire: Vec::default(),
    })
}

/// One pipelined Beaver-AND wave across all instances: a begin pass
/// (masked opening + `exchange_begin` per chunk) followed by a finish pass
/// (`exchange_finish` + fold + combine per chunk, in begin order). The
/// wire bytes per chunk are byte-identical to the serial
/// `and_gates_{lanes_seg,planes}_into` round.
#[allow(clippy::too_many_arguments)]
fn and_round<T: Transport, K: KernelBackend>(
    party: &mut GmwParty<T, K>,
    phase: Phase,
    w: u32,
    nn: usize,
    unit: usize,
    halves: usize,
    layout: BinLayout,
    kind: AndKind,
    insts: &mut [Inst],
) -> Result<()> {
    let me = party.party();
    let leader = me == 0;
    let threads = party.threads;
    let ulen = halves * unit;
    let wire_len = bitpack::packed_bytes(2 * halves * nn, w) as usize;

    // Begin pass: every chunk's frame hits the wire back-to-back.
    for inst in insts.iter_mut() {
        let (ta, tb, tc) = inst
            .triples
            .pop_front()
            .ok_or_else(|| Error::config("pipeline internal: AND triple queue underflow"))?;
        let mut de = party.arena.take_words(2 * ulen);
        match kind {
            AndKind::Init => party.kernels.and_open(&inst.acc, &inst.op, &ta, &tb, &mut de),
            AndKind::Stage { s, last } => {
                let mut u = party.arena.take_words(ulen);
                let mut v = party.arena.take_words(ulen);
                party.kernels.ks_stage_operands(&inst.g, &inst.p, s, w, last, &mut u, &mut v);
                party.kernels.and_open(&u, &v, &ta, &tb, &mut de);
                party.arena.put_words(v);
                party.arena.put_words(u);
            }
        }
        let mut wire = party.arena.take_bytes(wire_len);
        match layout {
            BinLayout::LanePerU64 => bitpack::pack_bytes_into(&de, w, &mut wire, threads),
            BinLayout::Bitsliced => {
                // The fused pack XOR-merges segments: start from zeroes.
                if wire.len() != wire_len {
                    wire.clear();
                    wire.resize(wire_len, 0);
                } else {
                    wire.fill(0);
                }
                let simd = party.kernels.simd();
                for seg in 0..2 * halves {
                    bitsliced::pack_planes_xor_into_with(
                        &de[seg * unit..(seg + 1) * unit],
                        w,
                        nn,
                        seg * nn,
                        &mut wire,
                        threads,
                        simd,
                    );
                }
            }
        }
        party.transport.exchange_begin(phase, &wire)?;
        inst.tri = (ta, tb, tc);
        inst.de = de;
        inst.wire = wire;
    }

    // Finish pass, in begin order.
    for inst in insts.iter_mut() {
        party.transport.exchange_finish(phase, &inst.wire, &mut party.recv)?;
        let mut opened = party.arena.take_words(2 * ulen);
        opened.copy_from_slice(&inst.de);
        for q in 0..party.recv.parties() {
            if q == me {
                continue;
            }
            let buf = party.recv.get(q);
            if buf.len() != wire_len {
                return Err(Error::wire(format!(
                    "binary opening from party {q}: expected {wire_len} bytes, got {}",
                    buf.len()
                )));
            }
            match layout {
                BinLayout::LanePerU64 => {
                    bitpack::unpack_bytes_xor_into(buf, w, 2 * halves * nn, &mut opened, threads)
                }
                BinLayout::Bitsliced => {
                    let simd = party.kernels.simd();
                    for seg in 0..2 * halves {
                        bitsliced::unpack_bytes_xor_into_planes_with(
                            buf,
                            w,
                            nn,
                            seg * nn,
                            &mut opened[seg * unit..(seg + 1) * unit],
                            threads,
                            simd,
                        );
                    }
                }
            }
        }
        party.arena.put_bytes(std::mem::take(&mut inst.wire));
        party.arena.put_words(std::mem::take(&mut inst.de));
        let (ta, tb, tc) = std::mem::take(&mut inst.tri);
        let (d, e) = opened.split_at(ulen);
        match kind {
            AndKind::Init => party.kernels.and_combine(d, e, &ta, &tb, &tc, leader, &mut inst.g),
            AndKind::Stage { last, .. } => {
                let mut z = party.arena.take_words(ulen);
                party.kernels.and_combine(d, e, &ta, &tb, &tc, leader, &mut z);
                if last {
                    // z = P ∧ (G ≪ s)
                    for (gi, zi) in inst.g.iter_mut().zip(&z) {
                        *gi ^= *zi;
                    }
                } else {
                    let (zg, zp) = z.split_at(unit);
                    for (((gi, pi), zgi), zpi) in
                        inst.g.iter_mut().zip(inst.p.iter_mut()).zip(zg).zip(zp)
                    {
                        *gi ^= *zgi;
                        *pi = *zpi;
                    }
                }
                party.arena.put_words(z);
            }
        }
        party.arena.put_words(opened);
        party.arena.put_words(ta);
        party.arena.put_words(tb);
        party.arena.put_words(tc);
    }
    Ok(())
}

/// The overlapped chunked DReLU(+Mult) driver: pre-draw, then lockstep
/// waves. See the module docs for the scheduling and identity argument.
fn run_overlapped<T: Transport, K: KernelBackend>(
    party: &mut GmwParty<T, K>,
    arith: &[u64],
    plan: ReluPlan,
    chunks: usize,
    with_mul: bool,
    out: &mut [u64],
) -> Result<()> {
    let nn = arith.len() / chunks;
    let w = plan.width();
    let layout = party.bin_layout();
    let unit = match layout {
        BinLayout::LanePerU64 => nn,
        BinLayout::Bitsliced => bitsliced::plane_len(nn, w),
    };
    let mask = match layout {
        BinLayout::LanePerU64 => ring::low_mask(w),
        // Plane form has no mask: planes at or above w don't exist.
        BinLayout::Bitsliced => u64::MAX,
    };
    let parties = party.parties();
    let me = party.party();
    let leader = me == 0;
    let threads = party.threads;

    // Phase 1: pre-draw all randomness, instance-major (= serial order).
    // Setup-time only: one record per chunk; payload buffers are arena's.
    let mut insts = Vec::default();
    for i in 0..chunks {
        insts.push(predraw_inst(party, &arith[i * nn..(i + 1) * nn], plan, with_mul, layout)?);
    }

    // Phase 2: lockstep Kogge–Stone waves (w > 1). Round program =
    // serial `ks_add` with `AdderOptions::default()` (batched stage ANDs,
    // last P skipped) — the options `a2b_into` uses.
    if w > 1 {
        let stages = ceil_log2(w);
        for _j in 1..parties {
            for inst in insts.iter_mut() {
                let op = inst
                    .ops
                    .pop_front()
                    .ok_or_else(|| Error::config("pipeline internal: reshare queue underflow"))?;
                // P = x ⊕ y (the lane path masks; planes are mask-free).
                for ((pi, a), b) in inst.p.iter_mut().zip(&inst.acc).zip(&op) {
                    *pi = (a ^ b) & mask;
                }
                inst.op = op;
            }
            and_round(party, Phase::OtherAnd, w, nn, unit, 1, layout, AndKind::Init, &mut insts)?;
            let mut s = 1u32;
            for idx in 0..stages {
                let last = idx + 1 == stages;
                let halves = if last { 1 } else { 2 };
                and_round(
                    party,
                    Phase::Circuit,
                    w,
                    nn,
                    unit,
                    halves,
                    layout,
                    AndKind::Stage { s, last },
                    &mut insts,
                )?;
                s <<= 1;
            }
            // Epilogue: acc = x ⊕ y ⊕ (carries ≪ 1), in place.
            for inst in insts.iter_mut() {
                match layout {
                    BinLayout::LanePerU64 => {
                        for ((a, o), gi) in inst.acc.iter_mut().zip(&inst.op).zip(&inst.g) {
                            *a = (*a ^ o ^ (gi << 1)) & mask;
                        }
                    }
                    BinLayout::Bitsliced => {
                        // The lane shift-by-1 is a plane-index shift: sum
                        // plane b folds in carry plane b − 1.
                        let wu = w as usize;
                        for k in 0..unit / wu {
                            let base = k * wu;
                            inst.acc[base] ^= inst.op[base];
                            for b in 1..wu {
                                inst.acc[base + b] ^= inst.op[base + b] ^ inst.g[base + b - 1];
                            }
                        }
                    }
                }
                party.arena.put_words(std::mem::take(&mut inst.op));
            }
        }
    }

    // Phase 3: one pipelined B2A wave (MSB → masked 1-bit opening).
    let b2a_wire_len = bitpack::packed_bytes(nn, 1) as usize;
    for inst in insts.iter_mut() {
        let mut masked = party.arena.take_words(nn);
        match layout {
            BinLayout::LanePerU64 => {
                for (ml, (a, rb)) in masked.iter_mut().zip(inst.acc.iter().zip(&inst.r_bin)) {
                    let mut bit = (a >> (w - 1)) & 1;
                    if leader {
                        bit ^= 1;
                    }
                    *ml = (bit ^ rb) & 1;
                }
            }
            BinLayout::Bitsliced => {
                let mut msb = party.arena.take_words(nn);
                bitsliced::msb_lanes_from_planes(&inst.acc, w, nn, &mut msb);
                for (ml, (mb, rb)) in masked.iter_mut().zip(msb.iter().zip(&inst.r_bin)) {
                    let mut bit = *mb;
                    if leader {
                        bit ^= 1;
                    }
                    *ml = (bit ^ rb) & 1;
                }
                party.arena.put_words(msb);
            }
        }
        let mut wire = party.arena.take_bytes(b2a_wire_len);
        bitpack::pack_bytes_into(&masked, 1, &mut wire, threads);
        party.transport.exchange_begin(Phase::B2A, &wire)?;
        inst.de = masked;
        inst.wire = wire;
    }
    for (i, inst) in insts.iter_mut().enumerate() {
        party.transport.exchange_finish(Phase::B2A, &inst.wire, &mut party.recv)?;
        let mut z = party.arena.take_words(nn);
        z.copy_from_slice(&inst.de);
        for q in 0..party.recv.parties() {
            if q == me {
                continue;
            }
            let buf = party.recv.get(q);
            if buf.len() != b2a_wire_len {
                return Err(Error::wire(format!(
                    "binary opening from party {q}: expected {b2a_wire_len} bytes, got {}",
                    buf.len()
                )));
            }
            bitpack::unpack_bytes_xor_into(buf, 1, nn, &mut z, threads);
        }
        party.arena.put_bytes(std::mem::take(&mut inst.wire));
        party.arena.put_words(std::mem::take(&mut inst.de));
        // ⟨b⟩^A = z + ⟨r⟩^A − 2·z·⟨r⟩^A  (z public)
        let dst: &mut [u64] =
            if with_mul { &mut inst.dshare } else { &mut out[i * nn..(i + 1) * nn] };
        for ((o, zi), ra) in dst.iter_mut().zip(&z).zip(&inst.r_arith) {
            let mut v = ra.wrapping_sub(ra.wrapping_mul(2).wrapping_mul(*zi));
            if leader {
                v = v.wrapping_add(*zi);
            }
            *o = v;
        }
        party.arena.put_words(z);
        party.arena.put_words(std::mem::take(&mut inst.r_arith));
        party.arena.put_words(std::mem::take(&mut inst.r_bin));
    }

    // Phase 4 (ReLU only): one pipelined Beaver-mult wave.
    if with_mul {
        for (i, inst) in insts.iter_mut().enumerate() {
            let (ta, tb, tc) = inst
                .mul
                .take()
                .ok_or_else(|| Error::config("pipeline internal: mult triple queue underflow"))?;
            let mut de = party.arena.take_words(2 * nn);
            party.kernels.mult_open(&arith[i * nn..(i + 1) * nn], &inst.dshare, &ta, &tb, &mut de);
            let mut wire = party.arena.take_bytes(2 * nn * 8);
            net::u64s_to_bytes_into(&de, &mut wire);
            party.transport.exchange_begin(Phase::Mult, &wire)?;
            inst.tri = (ta, tb, tc);
            inst.de = de;
            inst.wire = wire;
        }
        for (i, inst) in insts.iter_mut().enumerate() {
            party.transport.exchange_finish(Phase::Mult, &inst.wire, &mut party.recv)?;
            let mut opened = party.arena.take_words(2 * nn);
            opened.copy_from_slice(&inst.de);
            for q in 0..party.recv.parties() {
                if q == me {
                    continue;
                }
                net::add_u64s_from_bytes(party.recv.get(q), &mut opened)?;
            }
            party.arena.put_bytes(std::mem::take(&mut inst.wire));
            party.arena.put_words(std::mem::take(&mut inst.de));
            let (ta, tb, tc) = std::mem::take(&mut inst.tri);
            let (d, e) = opened.split_at(nn);
            party.kernels.mult_combine(d, e, &ta, &tb, &tc, leader, &mut out[i * nn..(i + 1) * nn]);
            party.arena.put_words(opened);
            party.arena.put_words(ta);
            party.arena.put_words(tb);
            party.arena.put_words(tc);
        }
    }

    // Teardown: return per-instance state to the arena.
    for inst in insts {
        party.arena.put_words(inst.acc);
        if !inst.p.is_empty() {
            party.arena.put_words(inst.p);
        }
        if !inst.g.is_empty() {
            party.arena.put_words(inst.g);
        }
        if !inst.dshare.is_empty() {
            party.arena.put_words(inst.dshare);
        }
    }
    Ok(())
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::super::harness::run_parties;
    use super::super::ReluPlan;
    use crate::crypto::prg::Prg;
    use crate::sharing::{reconstruct_arith, share_arith};

    #[test]
    fn chunking_is_validated() {
        let plan = ReluPlan::new(12, 4).unwrap();
        let run = run_parties(2, 7, move |p| {
            let xs = [1u64, 2, 3];
            let mut out = [0u64; 3];
            // 0 chunks and non-dividing chunk counts are config errors.
            assert!(p.relu_chunked_into(&xs, plan, 0, true, &mut out).is_err());
            assert!(p.relu_chunked_into(&xs, plan, 2, true, &mut out).is_err());
            // Identity plans have no sign bit to extract.
            let id = ReluPlan::new(8, 8).unwrap();
            assert!(p.drelu_chunked_into(&xs, id, 1, false, &mut out).is_err());
            // ...but identity ReLU degenerates to a copy, chunked or not.
            p.relu_chunked_into(&xs, id, 3, true, &mut out).unwrap();
            assert_eq!(out, xs);
        });
        assert_eq!(run.outputs.len(), 2);
    }

    #[test]
    fn overlapped_relu_matches_serial_smoke() {
        // The full matrix (layouts × prefetch × parties) lives in
        // tests/overlap_identity.rs; this is the in-tree smoke version.
        let n = 256;
        let chunks = 4;
        let plan = ReluPlan::new(12, 4).unwrap();
        let mut prg = Prg::new(0x91, 0);
        let x: Vec<u64> = (0..n)
            .map(|i| {
                let v = prg.next_u64() % 2000;
                if i % 3 == 0 {
                    v
                } else {
                    v.wrapping_neg()
                }
            })
            .collect();
        let mut prg = Prg::new(0xdead, 0xbeef);
        let xs = share_arith(&mut prg, &x, 2);

        let serial = run_parties(2, 42, |p| {
            let me = p.party();
            p.relu_chunked(&xs[me], plan, chunks, false).unwrap()
        });
        let overlapped = run_parties(2, 42, |p| {
            let me = p.party();
            p.relu_chunked(&xs[me], plan, chunks, true).unwrap()
        });
        assert_eq!(serial.outputs, overlapped.outputs, "overlap must be bit-identical");
        assert_eq!(serial.trace.total_bytes(), overlapped.trace.total_bytes());
        assert_eq!(serial.trace.total_rounds(), overlapped.trace.total_rounds());
        assert_eq!(serial.trace.bytes_by_phase(), overlapped.trace.bytes_by_phase());

        // Semantics: the chunked schedules agree with the unchunked engine
        // (clear values only — chunking changes how the PRG streams are
        // apportioned per element, so share values legitimately differ).
        let whole = run_parties(2, 42, |p| {
            let me = p.party();
            p.relu(&xs[me], plan).unwrap()
        });
        assert_eq!(reconstruct_arith(&overlapped.outputs), reconstruct_arith(&whole.outputs));
    }
}
