//! Doc-reference check (run in CI alongside `cargo doc -D warnings`):
//! every `DESIGN.md §N` citation in the Rust sources must resolve to a
//! §-numbered heading actually present in the repo-root `DESIGN.md`, and
//! the root `README.md` must exist. Keeps the design doc and the code
//! citing it from drifting apart — the repo shipped for four PRs with
//! five citations of a DESIGN.md that did not exist.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

/// Parse the maximal `[0-9.]` run starting at `text[start..]`, trimming
/// trailing dots (so "§5.2," yields "5.2" and "§4." yields "4").
fn section_token(text: &str, start: usize) -> String {
    let tok: String = text[start..]
        .chars()
        .take_while(|c| c.is_ascii_digit() || *c == '.')
        .collect();
    tok.trim_end_matches('.').to_string()
}

/// All §-tokens appearing in markdown heading lines (`#`-prefixed).
fn heading_tokens(markdown: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for line in markdown.lines() {
        if !line.trim_start().starts_with('#') {
            continue;
        }
        for (idx, _) in line.match_indices('§') {
            let tok = section_token(line, idx + '§'.len_utf8());
            if !tok.is_empty() {
                out.insert(tok);
            }
        }
    }
    out
}

/// `(token, line_number)` for every `DESIGN.md §N` citation in `text`.
fn citations(text: &str) -> Vec<(String, usize)> {
    const PAT: &str = "DESIGN.md §";
    let mut out = Vec::new();
    for (idx, _) in text.match_indices(PAT) {
        let line_no = text[..idx].matches('\n').count() + 1;
        let tok = section_token(text, idx + PAT.len());
        // A bare "DESIGN.md §" with no number is itself a dangling
        // reference; surface it as an empty token.
        out.push((tok, line_no));
    }
    out
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs")
            && path.file_name().is_some_and(|n| n != "doc_refs.rs")
        {
            // This checker's own pattern literals and test fixtures are
            // not citations; skip self.
            out.push(path);
        }
    }
}

#[test]
fn design_doc_citations_resolve() {
    let crate_root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let repo_root = crate_root.parent().expect("crate lives under the repo root");
    assert!(
        repo_root.join("README.md").is_file(),
        "README.md must exist at the repo root"
    );
    let design = std::fs::read_to_string(repo_root.join("DESIGN.md"))
        .expect("DESIGN.md must exist at the repo root");
    let headings = heading_tokens(&design);
    assert!(
        !headings.is_empty(),
        "DESIGN.md has no §-numbered headings to cite"
    );

    let mut files = Vec::new();
    for sub in ["src", "benches", "tests", "examples"] {
        collect_rs_files(&crate_root.join(sub), &mut files);
    }
    assert!(!files.is_empty(), "no Rust sources found under {}", crate_root.display());

    let mut total = 0usize;
    let mut dangling = Vec::new();
    for file in &files {
        let text = std::fs::read_to_string(file).unwrap_or_default();
        for (tok, line) in citations(&text) {
            total += 1;
            if tok.is_empty() || !headings.contains(&tok) {
                dangling.push(format!(
                    "{}:{line}: cites DESIGN.md §{tok} but DESIGN.md has no such heading \
                     (headings present: {headings:?})",
                    file.display()
                ));
            }
        }
    }
    assert!(dangling.is_empty(), "dangling DESIGN.md citations:\n{}", dangling.join("\n"));
    // The five pre-existing citations (beaver, sharing, adder, figures,
    // ablation bench) plus the offline/online split's: if this count ever
    // drops to zero the scan itself has broken.
    assert!(total >= 5, "expected at least 5 DESIGN.md citations, scanned {total}");
}

#[test]
fn token_parsing() {
    assert_eq!(section_token("5.2, blah", 0), "5.2");
    assert_eq!(section_token("4. End", 0), "4");
    assert_eq!(section_token("6 for the index", 0), "6");
    let heads = heading_tokens("# T\n## §4 · Dealer\n### §5.2 · Adder\nno § here");
    assert_eq!(heads, ["4", "5.2"].iter().map(|s| s.to_string()).collect());
    let cites = citations("x\nsee DESIGN.md §4, and\nDESIGN.md §5.2 documents");
    assert_eq!(cites, vec![("4".into(), 2), ("5.2".into(), 3)]);
}
