//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. Parses `artifacts/manifest.json` and answers "which HLO
//! file implements layer i of model M / kernel K at bucket n, and with what
//! shapes".

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::util::json::{self, Json};

/// Kernel artifact entry (one per bucket size).
#[derive(Debug, Clone)]
pub struct KernelArtifact {
    pub n: usize,
    pub path: String,
}

/// Per-layer artifact entry.
#[derive(Debug, Clone)]
pub struct LayerArtifact {
    /// "conv" or "fc".
    pub op: String,
    /// Path of the share-domain (int64) artifact (Pallas-kernel variant).
    pub share: String,
    /// Fused-dot fast variant of the same ring math (None in manifests
    /// produced before the perf pass).
    pub share_fast: Option<String>,
    /// Path of the plain f32 artifact at MPC batch.
    pub plain: String,
    /// Path of the plain f32 artifact at search batch.
    pub search: String,
    /// conv: [C,H,W] input; fc: unused.
    pub in_shape: Vec<usize>,
    /// conv: [C,H,W] output.
    pub out_shape: Vec<usize>,
    /// conv: im2col weight shape [Cin*k*k, Cout]; fc: [In, Out].
    pub wmat_shape: Vec<usize>,
    /// conv: original weight shape [Cout, Cin, k, k].
    pub w_shape: Vec<usize>,
    /// fc: flattened input dim.
    pub in_dim: usize,
    pub out_dim: usize,
}

/// Per-model manifest section.
#[derive(Debug, Clone)]
pub struct ModelArtifacts {
    pub batch: usize,
    pub search_batch: usize,
    pub frac_bits: u32,
    /// Keyed by node index.
    pub layers: BTreeMap<usize, LayerArtifact>,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub kernel_buckets: Vec<usize>,
    pub kernels: BTreeMap<String, Vec<KernelArtifact>>,
    pub models: BTreeMap<String, ModelArtifacts>,
}

impl Manifest {
    pub fn load(artifacts_root: impl AsRef<Path>) -> Result<Manifest> {
        let path = artifacts_root.as_ref().join("manifest.json");
        let j = json::parse_file(&path)?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        let kernel_buckets = j
            .get("kernel_buckets")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<Vec<_>>>()?;
        let mut kernels = BTreeMap::new();
        for (name, arr) in j.get("kernels")?.as_obj()? {
            let mut entries = Vec::new();
            for e in arr.as_arr()? {
                entries.push(KernelArtifact {
                    n: e.get_usize("n")?,
                    path: e.get_str("path")?.to_string(),
                });
            }
            entries.sort_by_key(|e| e.n);
            kernels.insert(name.clone(), entries);
        }
        let mut models = BTreeMap::new();
        for (name, m) in j.get("models")?.as_obj()? {
            let mut layers = BTreeMap::new();
            for (idx, l) in m.get("layers")?.as_obj()? {
                let idx: usize = idx
                    .parse()
                    .map_err(|_| Error::config(format!("bad layer index {idx}")))?;
                let shape_vec = |key: &str| -> Vec<usize> {
                    l.opt(key)
                        .and_then(|v| v.as_arr().ok().map(|a| {
                            a.iter().filter_map(|x| x.as_usize().ok()).collect()
                        }))
                        .unwrap_or_default()
                };
                layers.insert(
                    idx,
                    LayerArtifact {
                        op: l.get_str("op")?.to_string(),
                        share: l.get_str("share")?.to_string(),
                        share_fast: l
                            .opt("share_fast")
                            .and_then(|v| v.as_str().ok())
                            .map(|s| s.to_string()),
                        plain: l.get_str("plain")?.to_string(),
                        search: l.get_str("search")?.to_string(),
                        in_shape: shape_vec("in_shape"),
                        out_shape: shape_vec("out_shape"),
                        wmat_shape: shape_vec("wmat_shape"),
                        w_shape: shape_vec("w_shape"),
                        in_dim: l.opt("in_dim").and_then(|v| v.as_usize().ok()).unwrap_or(0),
                        out_dim: l.opt("out_dim").and_then(|v| v.as_usize().ok()).unwrap_or(0),
                    },
                );
            }
            models.insert(
                name.clone(),
                ModelArtifacts {
                    batch: m.get_usize("batch")?,
                    search_batch: m.get_usize("search_batch")?,
                    frac_bits: m.get_usize("frac_bits")? as u32,
                    layers,
                },
            );
        }
        Ok(Manifest { kernel_buckets, kernels, models })
    }

    /// Pick the smallest kernel bucket that fits `n` elements, or the
    /// largest bucket (caller chunks) if none fits.
    pub fn bucket_for(&self, n: usize) -> usize {
        for b in &self.kernel_buckets {
            if *b >= n {
                return *b;
            }
        }
        // LINT-ALLOW: unwrap — manifest loading rejects empty bucket lists
        // before a registry is ever handed out.
        *self.kernel_buckets.last().expect("no kernel buckets")
    }

    /// Resolve a kernel artifact path for (name, bucket).
    pub fn kernel_path(&self, name: &str, bucket: usize) -> Result<&str> {
        self.kernels
            .get(name)
            .and_then(|entries| entries.iter().find(|e| e.n == bucket))
            .map(|e| e.path.as_str())
            .ok_or_else(|| Error::config(format!("no kernel artifact {name}@{bucket}")))
    }

    pub fn model(&self, name: &str) -> Result<&ModelArtifacts> {
        self.models.get(name).ok_or_else(|| {
            Error::config(format!("model '{name}' not in manifest (run `make artifacts`)"))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let j = json::parse(
            r#"{
          "kernel_buckets": [1024, 8192],
          "kernels": {"and_open": [{"n":1024,"path":"kernels/a.hlo.txt"},
                                    {"n":8192,"path":"kernels/b.hlo.txt"}]},
          "models": {"m": {"batch":4, "search_batch":64, "frac_bits":12,
            "layers": {"1": {"op":"conv","share":"s","plain":"p","search":"q",
                             "in_shape":[3,16,16],"out_shape":[8,16,16],
                             "wmat_shape":[27,8],"w_shape":[8,3,3,3],
                             "k":3,"stride":1,"pad":1}}}}}"#,
        )
        .unwrap();
        let m = Manifest::from_json(&j).unwrap();
        assert_eq!(m.bucket_for(500), 1024);
        assert_eq!(m.bucket_for(2000), 8192);
        assert_eq!(m.bucket_for(100_000), 8192); // chunking case
        assert_eq!(m.kernel_path("and_open", 1024).unwrap(), "kernels/a.hlo.txt");
        assert!(m.kernel_path("nope", 1024).is_err());
        let model = m.model("m").unwrap();
        assert_eq!(model.layers[&1].wmat_shape, vec![27, 8]);
        assert!(m.model("zz").is_err());
    }
}
