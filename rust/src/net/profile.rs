//! Network & compute profiles + analytic latency projection (paper §5.1,
//! Figs 7–10).
//!
//! The paper reports three network setups (High-BW ≈ NVLink 16 Tbps, LAN
//! 10 Gbps, WAN 352 Mbps) and two GPUs (A100, V100). Its WAN row is itself
//! an analytic projection: "we separately measured the communication time
//! from the High-BW setup and scaled it according to the assumed bandwidth".
//! We apply that same methodology uniformly: the protocol run yields an
//! exact per-round byte trace ([`CommTrace`]) and a measured local compute
//! time; a profile then prices the trace as
//! `Σ_rounds (latency + bytes/bandwidth)` and scales compute.
//!
//! The same `latency + bytes/bandwidth` model also drives the *measured*
//! WAN path: [`super::sim::SimTransport`] delays real frame delivery per
//! round instead of pricing a finished trace, so serial and overlapped
//! schedules become distinguishable wall-clock (DESIGN.md §10). The two
//! must agree on a serial schedule — `tests` pins that below.
//!
//! # Latency convention
//!
//! `latency_s` is **one one-way propagation delay per round**, not an RTT
//! and not per-message. The convention matches the actual round structure:
//! a GMW open is a symmetric all-to-all exchange in which every party
//! sends concurrently over full-duplex links, so a round completes one
//! one-way flight after the last byte is serialized — peers' sends overlap
//! with ours rather than queueing behind them. What serializes is this
//! party's own uplink: `bytes_sent` in a [`CommTrace`] round record is
//! `payload × (parties − 1)`, and the round costs
//! `latency_s + bytes_sent·8/bandwidth_bps`. A request/response protocol
//! would pay 2× latency per exchange; GMW's simultaneous exchange pays 1×,
//! which is exactly why WAN time is *round-count*-bound (DESIGN.md §10).

use super::accounting::CommTrace;
use crate::error::{Error, Result};
use crate::util::json::Json;

/// A network profile: per-round latency plus per-byte cost. See the module
/// docs for the one-way-per-round latency convention.
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkProfile {
    pub name: String,
    /// One **one-way** propagation delay in seconds, applied once per
    /// round (all parties send concurrently; see module docs — this is
    /// RTT/2, not RTT, and not per-message).
    pub latency_s: f64,
    /// Link bandwidth in bits per second (per direction, full duplex).
    pub bandwidth_bps: f64,
}

impl NetworkProfile {
    pub fn new(name: &str, latency_s: f64, bandwidth_bps: f64) -> Self {
        NetworkProfile { name: name.to_string(), latency_s, bandwidth_bps }
    }

    /// The paper's three setups (§5.1 / Fig 9).
    pub fn high_bw() -> Self {
        // Two GPUs on one node; paper cites up to 16 Tbps NVLink. Observed
        // usage "did not exceed 20 Gbps"; latency is PCIe/NVLink-scale.
        NetworkProfile::new("High-BW", 5e-6, 16e12)
    }
    pub fn lan() -> Self {
        NetworkProfile::new("LAN", 50e-6, 10e9)
    }
    pub fn wan() -> Self {
        // 352 Mbps per prior work [15] (Cheetah); WAN RTT ~40 ms -> one-way 20ms.
        NetworkProfile::new("WAN", 20e-3, 352e6)
    }

    /// Parse the `--net-profile` CLI grammar (DESIGN.md §10):
    /// `high-bw` | `lan` | `wan` | `lat:<ms>,bw:<mbps>` (both parts
    /// required, either order). The custom form names itself after its
    /// parameters, e.g. `lat:25ms,bw:100mbps`.
    pub fn parse_cli(spec: &str) -> Result<Self> {
        match spec {
            "high-bw" => return Ok(NetworkProfile::high_bw()),
            "lan" => return Ok(NetworkProfile::lan()),
            "wan" => return Ok(NetworkProfile::wan()),
            _ => {}
        }
        let mut lat_ms: Option<f64> = None;
        let mut bw_mbps: Option<f64> = None;
        for part in spec.split(',') {
            let bad = || {
                Error::config(format!(
                    "bad --net-profile part {part:?} in {spec:?}: expected \
                     high-bw|lan|wan|lat:<ms>,bw:<mbps>"
                ))
            };
            let (key, val) = part.split_once(':').ok_or_else(bad)?;
            let val = val.trim();
            match key.trim() {
                "lat" => {
                    let v = val.strip_suffix("ms").unwrap_or(val).trim();
                    lat_ms = Some(v.parse().map_err(|_| bad())?);
                }
                "bw" => {
                    let v = val.strip_suffix("mbps").unwrap_or(val).trim();
                    bw_mbps = Some(v.parse().map_err(|_| bad())?);
                }
                _ => return Err(bad()),
            }
        }
        let (Some(lat), Some(bw)) = (lat_ms, bw_mbps) else {
            return Err(Error::config(format!(
                "--net-profile {spec:?} must give both lat:<ms> and bw:<mbps>"
            )));
        };
        if !lat.is_finite() || lat < 0.0 || !bw.is_finite() || bw <= 0.0 {
            return Err(Error::config(format!(
                "--net-profile {spec:?}: latency must be >= 0 and bandwidth > 0"
            )));
        }
        Ok(NetworkProfile::new(&format!("lat{lat}ms-bw{bw}mbps"), lat * 1e-3, bw * 1e6))
    }

    /// Time to push `bytes` through the link plus the round latency.
    pub fn round_time(&self, bytes: u64) -> f64 {
        self.latency_s + (bytes as f64 * 8.0) / self.bandwidth_bps
    }

    /// Price a whole trace: Σ_rounds (latency + bytes/bw).
    pub fn comm_time(&self, trace: &CommTrace) -> f64 {
        trace.rounds().iter().map(|r| self.round_time(r.bytes_sent)).sum()
    }

    pub fn to_json(&self) -> Json {
        // HOT-PATH-ALLOW: reporting — serialization is off the wire path.
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("latency_s", Json::Num(self.latency_s)),
            ("bandwidth_bps", Json::Num(self.bandwidth_bps)),
        ])
    }

    pub fn from_json(j: &Json) -> crate::error::Result<Self> {
        Ok(NetworkProfile {
            name: j.get_str("name")?.to_string(),
            latency_s: j.get_f64("latency_s")?,
            bandwidth_bps: j.get_f64("bandwidth_bps")?,
        })
    }
}

/// A compute profile: scales measured local compute time so the A100/V100
/// contrast of Figs 7/8/10 can be reproduced on this CPU testbed. The scale
/// is relative to an abstract "A100-class" device = 1.0.
#[derive(Debug, Clone, PartialEq)]
pub struct ComputeProfile {
    pub name: String,
    /// Multiplier on measured local (linear + protocol-local) compute time.
    pub scale: f64,
}

impl ComputeProfile {
    pub fn a100() -> Self {
        ComputeProfile { name: "A100".into(), scale: 1.0 }
    }
    /// V100 ≈ 2.4× slower for the fp/int tensor work in this pipeline
    /// (ratio of the paper's CrypTen baseline compute fractions across
    /// Figs 7/8: compute goes from ~7% on A100 to ~22% on V100 at similar
    /// totals).
    pub fn v100() -> Self {
        ComputeProfile { name: "V100".into(), scale: 2.4 }
    }

    pub fn from_json(j: &Json) -> crate::error::Result<Self> {
        Ok(ComputeProfile { name: j.get_str("name")?.to_string(), scale: j.get_f64("scale")? })
    }
}

/// End-to-end projection of one measured run onto a (network, compute)
/// profile pair.
#[derive(Debug, Clone)]
pub struct Projection {
    pub network: String,
    pub compute: String,
    pub comm_time_s: f64,
    pub compute_time_s: f64,
}

impl Projection {
    pub fn total_s(&self) -> f64 {
        self.comm_time_s + self.compute_time_s
    }
}

/// Project a run: `compute_time_s` is the *measured* local compute time of
/// the protocol run (everything except waiting on the wire).
pub fn project(
    trace: &CommTrace,
    compute_time_s: f64,
    net: &NetworkProfile,
    gpu: &ComputeProfile,
) -> Projection {
    Projection {
        // HOT-PATH-ALLOW: reporting — labels cloned once per projection.
        network: net.name.clone(),
        compute: gpu.name.clone(),
        comm_time_s: net.comm_time(trace),
        compute_time_s: compute_time_s * gpu.scale,
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::net::accounting::Phase;

    #[test]
    fn round_time_has_latency_floor() {
        let lan = NetworkProfile::lan();
        assert!(lan.round_time(0) == 50e-6);
        // 10 Gbps: 125 MB/s per 0.1s -> 1.25e9 B/s
        let t = lan.round_time(1_250_000);
        assert!((t - (50e-6 + 1e-3)).abs() < 1e-9, "{t}");
    }

    #[test]
    fn wan_slower_than_lan_slower_than_highbw() {
        let trace = CommTrace::new();
        for _ in 0..100 {
            trace.record(Phase::Circuit, 10_000);
        }
        let hb = NetworkProfile::high_bw().comm_time(&trace);
        let lan = NetworkProfile::lan().comm_time(&trace);
        let wan = NetworkProfile::wan().comm_time(&trace);
        assert!(hb < lan && lan < wan, "{hb} {lan} {wan}");
    }

    #[test]
    fn projection_combines_compute_and_comm() {
        let trace = CommTrace::new();
        trace.record(Phase::Mult, 1000);
        let p = project(&trace, 2.0, &NetworkProfile::lan(), &ComputeProfile::v100());
        assert!(p.compute_time_s == 4.8);
        assert!(p.total_s() > 4.8);
    }

    #[test]
    fn json_roundtrip() {
        let lan = NetworkProfile::lan();
        let back = NetworkProfile::from_json(&lan.to_json()).unwrap();
        assert_eq!(lan, back);
    }

    /// Latency-convention regression (DESIGN.md §10): a known two-round
    /// protocol trace prices to exactly 2 × one-way latency plus the
    /// serialization of this party's uplink bytes — one latency per round
    /// (simultaneous all-to-all exchange), never 2× (request/response) and
    /// never per-message. Pinned with a hand-computable profile:
    /// 10 ms one-way, 8 Mbps (= 1 byte/µs).
    #[test]
    fn two_round_trace_prices_one_latency_per_round() {
        let trace = CommTrace::new();
        // Round 1: 1000 bytes on my uplink; round 2: 3000 bytes. (These
        // are already payload × (parties − 1), as CommTrace records.)
        trace.record(Phase::Circuit, 1000);
        trace.record(Phase::B2A, 3000);
        let net = NetworkProfile::new("pin", 10e-3, 8e6);
        let got = net.comm_time(&trace);
        // 2 rounds × 10 ms latency + 4000 bytes × 8 bits / 8e6 bps = 24 ms.
        let want = 2.0 * 10e-3 + 4000.0 * 8.0 / 8e6;
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
        // The same trace against a request/response (2× latency) reading
        // would be 34 ms — the convention must stay one-way-per-round.
        assert!((got - 24e-3).abs() < 1e-12, "{got}");
    }

    /// `--net-profile` grammar: presets, the custom lat/bw form (either
    /// order), and rejection of malformed or non-physical specs.
    #[test]
    fn parse_cli_grammar() {
        assert_eq!(NetworkProfile::parse_cli("high-bw").unwrap(), NetworkProfile::high_bw());
        assert_eq!(NetworkProfile::parse_cli("lan").unwrap(), NetworkProfile::lan());
        assert_eq!(NetworkProfile::parse_cli("wan").unwrap(), NetworkProfile::wan());
        let p = NetworkProfile::parse_cli("lat:25,bw:100").unwrap();
        assert!((p.latency_s - 25e-3).abs() < 1e-12);
        assert!((p.bandwidth_bps - 100e6).abs() < 1e-3);
        let q = NetworkProfile::parse_cli("bw:100,lat:25").unwrap();
        assert_eq!((q.latency_s, q.bandwidth_bps), (p.latency_s, p.bandwidth_bps));
        // Unit suffixes are accepted (and optional).
        let r = NetworkProfile::parse_cli("lat:25ms,bw:100mbps").unwrap();
        assert_eq!((r.latency_s, r.bandwidth_bps), (p.latency_s, p.bandwidth_bps));
        for bad in ["dsl", "lat:25", "bw:10", "lat:x,bw:10", "lat:-1,bw:10", "lat:1,bw:0"] {
            assert!(NetworkProfile::parse_cli(bad).is_err(), "{bad} should fail");
        }
    }
}
