//! The four `hblint` rules and their scope masks (DESIGN.md §8).
//!
//! Every rule works on the [`Stripped`] views produced by
//! [`strip`](crate::analysis::strip::strip):
//!
//! | rule | tag | scope | requirement |
//! |------|-----|-------|-------------|
//! | [`rule_safety`] | `S` | src + benches + tests | every `unsafe` token is immediately preceded by a `// SAFETY:` comment block |
//! | [`rule_hot_alloc`] | `A` | hot-path modules | no allocating calls outside `// HOT-PATH-ALLOW:` sites |
//! | [`rule_comm_trace`] | `T` | src | every `exchange_all_into` impl records `CommTrace` or delegates |
//! | [`rule_unwrap_wall`] | `U` | src | no `.unwrap()` / `.expect(` outside test modules, `#[allow]` scopes or `// LINT-ALLOW: unwrap` sites |
//! | [`rule_metrics_surface`] | `M` | src | every `pub struct *Counters` is a field of `MetricsSnapshot` in the same file |
//!
//! Scope masks keep the rules honest about *where* they apply: `#[cfg(test)]`
//! modules are exempt from `A`/`T`/`U` (tests allocate and unwrap freely),
//! and `#[allow(clippy::unwrap_used)]` / `#![allow(…)]` attributes are
//! honored by `U` so the linter never disagrees with clippy's walls.

use super::strip::Stripped;
use super::{Finding, Rule, ALLOC_TOKENS};

/// True when `line` contains `word` delimited by non-identifier characters.
fn contains_word(line: &str, word: &str) -> bool {
    let bytes = line.as_bytes();
    let mut start = 0;
    while let Some(pos) = line[start..].find(word) {
        let at = start + pos;
        let end = at + word.len();
        let before_ok = at == 0 || !is_ident(bytes[at - 1]);
        let after_ok = end >= bytes.len() || !is_ident(bytes[end]);
        if before_ok && after_ok {
            return true;
        }
        start = at + word.len();
    }
    false
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn is_mod_decl(line: &str) -> bool {
    let t = line.trim_start();
    let t = t.strip_prefix("pub ").unwrap_or(t).trim_start();
    t.starts_with("mod ")
}

/// Index of the line on which the brace block opened at/after `start`
/// closes (falls back to the last line for unbalanced input).
fn brace_block_end(code: &[String], start: usize) -> usize {
    let mut depth = 0i64;
    let mut started = false;
    let mut k = start;
    while k < code.len() {
        for ch in code[k].chars() {
            match ch {
                '{' => {
                    depth += 1;
                    started = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if started && depth <= 0 {
            return k;
        }
        k += 1;
    }
    code.len().saturating_sub(1)
}

/// Per-line mask: true inside a `#[cfg(test)]`-gated `mod` (including
/// `#[cfg(all(test, …))]` variants, and tolerating further attributes
/// between the cfg and the `mod` line).
pub fn test_mod_mask(code: &[String]) -> Vec<bool> {
    let mut mask = vec![false; code.len()];
    let mut i = 0;
    while i < code.len() {
        let line = code[i].trim();
        if line.starts_with("#[cfg(") && line.contains("test") {
            let mut j = i + 1;
            while j < code.len() && code[j].trim().starts_with("#[") {
                j += 1;
            }
            if j < code.len() && is_mod_decl(&code[j]) {
                let end = brace_block_end(code, j);
                for m in mask.iter_mut().take(end + 1).skip(i) {
                    *m = true;
                }
                i = end + 1;
                continue;
            }
        }
        i += 1;
    }
    mask
}

/// Per-line mask: true inside the item scope of an `#[allow(…)]` attribute
/// whose argument list contains `what` (e.g. `unwrap_used`). A crate/module
/// level `#![allow(…)]` covers the whole file.
pub fn allow_attr_mask(code: &[String], what: &str) -> Vec<bool> {
    let mut mask = vec![false; code.len()];
    for (i, raw) in code.iter().enumerate() {
        let s = raw.trim();
        if s.starts_with("#![") && s.contains("allow") && s.contains(what) {
            return vec![true; code.len()];
        }
        if s.starts_with("#[") && s.contains("allow") && s.contains(what) {
            let mut depth = 0i64;
            let mut started = false;
            let mut k = i;
            while k < code.len() {
                for ch in code[k].chars() {
                    match ch {
                        '{' => {
                            depth += 1;
                            started = true;
                        }
                        '}' => depth -= 1,
                        _ => {}
                    }
                }
                mask[k] = true;
                if started && depth <= 0 {
                    break;
                }
                // A braceless item (`fn f(…);`, `use …;`) ends at `;`.
                if !started && k > i && code[k].contains(';') {
                    break;
                }
                k += 1;
            }
        }
    }
    mask
}

/// True when the annotation `tag` appears in a comment on line `i` or on
/// one of the two preceding lines (trailing comment or a short preamble).
pub fn annotated(comment: &[String], i: usize, tag: &str) -> bool {
    (i.saturating_sub(2)..=i).any(|j| comment.get(j).is_some_and(|c| c.contains(tag)))
}

/// True when the contiguous comment block directly above line `i` (or the
/// trailing comment on line `i` itself) contains `tag`. A blank line or a
/// code line terminates the block — the comment must be *immediately*
/// preceding, per the `SAFETY:` convention.
pub fn preceding_comment_has(s: &Stripped, i: usize, tag: &str) -> bool {
    if s.comment[i].contains(tag) {
        return true;
    }
    let mut j = i;
    while j > 0 {
        j -= 1;
        if !s.code[j].trim().is_empty() {
            return false;
        }
        if s.comment[j].trim().is_empty() {
            return false;
        }
        if s.comment[j].contains(tag) {
            return true;
        }
    }
    false
}

/// Rule `S`: every `unsafe` block/impl/fn needs an immediately preceding
/// `// SAFETY:` comment. Applies everywhere, including tests and benches —
/// the proof obligation does not vanish in test code.
pub fn rule_safety(rel: &str, s: &Stripped) -> Vec<Finding> {
    let mut out = Vec::new();
    for (i, cl) in s.code.iter().enumerate() {
        if contains_word(cl, "unsafe") && !preceding_comment_has(s, i, "SAFETY:") {
            out.push(Finding {
                file: rel.to_string(),
                line: i + 1,
                rule: Rule::Safety,
                msg: "`unsafe` without an immediately preceding `// SAFETY:` comment".to_string(),
            });
        }
    }
    out
}

/// Rule `A`: no allocating calls in the declared hot-path modules outside
/// `// HOT-PATH-ALLOW:` annotated sites. The runtime arena counters prove
/// the steady state allocates nothing; this rule makes every *potential*
/// allocation in those modules a reviewed, annotated decision.
pub fn rule_hot_alloc(rel: &str, s: &Stripped, tmask: &[bool]) -> Vec<Finding> {
    let mut out = Vec::new();
    for (i, cl) in s.code.iter().enumerate() {
        if tmask[i] {
            continue;
        }
        for tok in ALLOC_TOKENS {
            if cl.contains(tok) && !annotated(&s.comment, i, "HOT-PATH-ALLOW:") {
                out.push(Finding {
                    file: rel.to_string(),
                    line: i + 1,
                    rule: Rule::HotAlloc,
                    msg: format!(
                        "allocating call `{}` in a hot-path module without `// HOT-PATH-ALLOW:`",
                        tok.trim_end_matches(['(', '['])
                    ),
                });
            }
        }
    }
    out
}

/// Rule `T`: every `exchange_all_into` implementation must either record
/// into the session's `CommTrace` (`.record(`) or visibly delegate — to an
/// inner transport (`.exchange_all_into`) or to its own split-phase send
/// half (`.exchange_begin`, which records; see DESIGN.md §10) — so
/// wire-byte accounting can never silently drop a transport.
pub fn rule_comm_trace(rel: &str, s: &Stripped, tmask: &[bool]) -> Vec<Finding> {
    let mut out = Vec::new();
    for (i, cl) in s.code.iter().enumerate() {
        if tmask[i] || !cl.contains("fn exchange_all_into") {
            continue;
        }
        let mut depth = 0i64;
        let mut started = false;
        let mut bodyless = false;
        let mut body = String::new();
        let mut k = i;
        while k < s.code.len() {
            for ch in s.code[k].chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        started = true;
                    }
                    '}' => depth -= 1,
                    ';' if !started => bodyless = true,
                    _ => {}
                }
            }
            if bodyless {
                break;
            }
            body.push_str(&s.code[k]);
            body.push('\n');
            if started && depth <= 0 {
                break;
            }
            k += 1;
        }
        // Trait declarations (`fn exchange_all_into(…) -> Result<()>;`)
        // carry no body and nothing to account.
        if bodyless {
            continue;
        }
        if !body.contains(".record(")
            && !body.contains(".exchange_all_into")
            && !body.contains(".exchange_begin")
        {
            out.push(Finding {
                file: rel.to_string(),
                line: i + 1,
                rule: Rule::CommTrace,
                msg: "`exchange_all_into` impl neither records CommTrace nor delegates"
                    .to_string(),
            });
        }
    }
    out
}

/// Rule `U`: crate-wide `.unwrap()` / `.expect(` wall for non-test code.
/// Honors `#[allow(clippy::unwrap_used)]` / `expect_used` scopes (so the
/// linter and clippy's module walls agree) and `// LINT-ALLOW: unwrap`
/// annotations for individually reviewed sites.
pub fn rule_unwrap_wall(rel: &str, s: &Stripped, tmask: &[bool]) -> Vec<Finding> {
    let amask_u = allow_attr_mask(&s.code, "unwrap_used");
    let amask_e = allow_attr_mask(&s.code, "expect_used");
    let mut out = Vec::new();
    for (i, cl) in s.code.iter().enumerate() {
        if tmask[i] {
            continue;
        }
        let hit_u = cl.contains(".unwrap()");
        let hit_e = cl.contains(".expect(");
        if !(hit_u || hit_e) {
            continue;
        }
        if annotated(&s.comment, i, "LINT-ALLOW: unwrap") {
            continue;
        }
        if hit_u && amask_u[i] && (!hit_e || amask_e[i]) {
            continue;
        }
        if hit_e && amask_e[i] && !hit_u {
            continue;
        }
        let what = if hit_u { ".unwrap()" } else { ".expect(…)" };
        out.push(Finding {
            file: rel.to_string(),
            line: i + 1,
            rule: Rule::UnwrapWall,
            msg: format!("`{what}` outside a test module without `// LINT-ALLOW: unwrap`"),
        });
    }
    out
}

/// Rule `M`: every `pub struct <X>Counters` must appear inside the
/// `struct MetricsSnapshot { … }` block of the same file. Counter blocks
/// that never reach the snapshot are invisible to operators and to the
/// soak's accounting identity (DESIGN.md §9) — the rule makes "add a
/// counter group" and "surface it" one reviewable step.
pub fn rule_metrics_surface(rel: &str, s: &Stripped, tmask: &[bool]) -> Vec<Finding> {
    // Gather the body of every `struct MetricsSnapshot { … }` block (there
    // is normally at most one per file).
    let mut snapshot_body = String::new();
    for (i, cl) in s.code.iter().enumerate() {
        if cl.contains("struct MetricsSnapshot") {
            let end = brace_block_end(&s.code, i);
            for line in &s.code[i..=end] {
                snapshot_body.push_str(line);
                snapshot_body.push('\n');
            }
        }
    }
    let mut out = Vec::new();
    for (i, cl) in s.code.iter().enumerate() {
        if tmask[i] {
            continue;
        }
        let Some(pos) = cl.find("pub struct ") else {
            continue;
        };
        let rest = &cl[pos + "pub struct ".len()..];
        let name: String =
            rest.chars().take_while(|c| c.is_ascii_alphanumeric() || *c == '_').collect();
        if !name.ends_with("Counters") || name == "Counters" {
            continue;
        }
        if !contains_word(&snapshot_body, &name) {
            out.push(Finding {
                file: rel.to_string(),
                line: i + 1,
                rule: Rule::MetricsSurface,
                msg: format!("`{name}` is not surfaced as a `MetricsSnapshot` field in this file"),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::strip::strip;
    use super::super::{FileClass, Rule};
    use super::*;

    fn lines(src: &str) -> Stripped {
        strip(src)
    }

    #[test]
    fn safety_rule_accepts_immediate_comment_rejects_detached() {
        let ok = lines("// SAFETY: disjoint writes\nunsafe { foo() }\n");
        assert!(rule_safety("src/x.rs", &ok).is_empty());
        let multi = lines("// SAFETY: part one\n// and part two\nunsafe impl Send for X {}\n");
        assert!(rule_safety("src/x.rs", &multi).is_empty());
        let detached = lines("// SAFETY: stale\n\nunsafe { foo() }\n");
        assert_eq!(rule_safety("src/x.rs", &detached).len(), 1);
        let missing = lines("let x = 1;\nunsafe { foo() }\n");
        let f = rule_safety("src/x.rs", &missing);
        assert_eq!((f.len(), f[0].line), (1, 2));
    }

    #[test]
    fn safety_rule_ignores_prose_and_identifiers() {
        let s = lines("// unsafe is discussed here only\nlet unsafe_count = 1;\n");
        assert!(rule_safety("src/x.rs", &s).is_empty());
        let s = lines("let msg = \"unsafe in a string\";\n");
        assert!(rule_safety("src/x.rs", &s).is_empty());
    }

    #[test]
    fn hot_alloc_rule_requires_annotation() {
        let src = "fn setup() {\n    let v: Vec<u64> = Vec::new();\n}\n";
        let s = lines(src);
        let t = test_mod_mask(&s.code);
        assert_eq!(rule_hot_alloc("src/gmw/x.rs", &s, &t).len(), 1);
        let src = "fn setup() {\n    // HOT-PATH-ALLOW: setup\n    let v = Vec::new();\n}\n";
        let s = lines(src);
        let t = test_mod_mask(&s.code);
        assert!(rule_hot_alloc("src/gmw/x.rs", &s, &t).is_empty());
    }

    #[test]
    fn hot_alloc_rule_exempts_test_mods() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f() { let v = vec![1]; }\n}\n";
        let s = lines(src);
        let t = test_mod_mask(&s.code);
        assert!(rule_hot_alloc("src/gmw/x.rs", &s, &t).is_empty());
    }

    #[test]
    fn comm_trace_rule_accepts_record_and_delegation() {
        let rec = "fn exchange_all_into(&mut self) {\n    self.trace.record(p, n);\n}\n";
        let s = lines(rec);
        let t = test_mod_mask(&s.code);
        assert!(rule_comm_trace("src/net/x.rs", &s, &t).is_empty());
        let del = "fn exchange_all_into(&mut self) {\n    self.inner.exchange_all_into(p)\n}\n";
        let s = lines(del);
        let t = test_mod_mask(&s.code);
        assert!(rule_comm_trace("src/net/x.rs", &s, &t).is_empty());
        // Split-phase serial form: delegation to the recording send half.
        let split = "fn exchange_all_into(&mut self) {\n    self.exchange_begin(p, d)?;\n    \
                     self.exchange_finish(p, d, r)\n}\n";
        let s = lines(split);
        let t = test_mod_mask(&s.code);
        assert!(rule_comm_trace("src/net/x.rs", &s, &t).is_empty());
        let bare = "fn exchange_all_into(&mut self) -> Result<()> {\n    Ok(())\n}\n";
        let s = lines(bare);
        let t = test_mod_mask(&s.code);
        assert_eq!(rule_comm_trace("src/net/x.rs", &s, &t).len(), 1);
        let decl = "fn exchange_all_into(&mut self, phase: Phase)\n    -> Result<()>;\n";
        let s = lines(decl);
        let t = test_mod_mask(&s.code);
        assert!(rule_comm_trace("src/net/x.rs", &s, &t).is_empty());
    }

    #[test]
    fn unwrap_wall_honors_allow_attrs_and_lint_allow() {
        let bare = "fn f() { x.unwrap(); }\n";
        let s = lines(bare);
        let t = test_mod_mask(&s.code);
        assert_eq!(rule_unwrap_wall("src/x.rs", &s, &t).len(), 1);
        let attr = "#[allow(clippy::unwrap_used)]\nfn f() {\nx.unwrap();\n}\nfn g() { y.unwrap() }";
        let s = lines(attr);
        let t = test_mod_mask(&s.code);
        let f = rule_unwrap_wall("src/x.rs", &s, &t);
        assert_eq!((f.len(), f[0].line), (1, 5), "scope must end with f's braces");
        let ann = "fn f() {\n    // LINT-ALLOW: unwrap - reviewed\n    x.unwrap();\n}\n";
        let s = lines(ann);
        let t = test_mod_mask(&s.code);
        assert!(rule_unwrap_wall("src/x.rs", &s, &t).is_empty());
        let test_mod = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { x.unwrap(); }\n}\n";
        let s = lines(test_mod);
        let t = test_mod_mask(&s.code);
        assert!(rule_unwrap_wall("src/x.rs", &s, &t).is_empty());
    }

    #[test]
    fn unwrap_wall_ignores_warn_walls() {
        // A `#![warn(clippy::unwrap_used)]` module wall is a *stricter*
        // stance, not an exemption — it must not blanket-allow the file.
        let src = "#![warn(clippy::unwrap_used, clippy::expect_used)]\nfn f() { x.unwrap(); }\n";
        let s = lines(src);
        let t = test_mod_mask(&s.code);
        assert_eq!(rule_unwrap_wall("src/x.rs", &s, &t).len(), 1);
    }

    #[test]
    fn unwrap_wall_expect_needs_expect_scope() {
        let src = "#[allow(clippy::unwrap_used)]\nfn f() {\n    x.expect(\"msg\");\n}\n";
        let s = lines(src);
        let t = test_mod_mask(&s.code);
        assert_eq!(rule_unwrap_wall("src/x.rs", &s, &t).len(), 1);
        let src = "#[allow(clippy::expect_used)]\nfn f() {\n    x.expect(\"msg\");\n}\n";
        let s = lines(src);
        let t = test_mod_mask(&s.code);
        assert!(rule_unwrap_wall("src/x.rs", &s, &t).is_empty());
    }

    #[test]
    fn metrics_surface_requires_snapshot_field() {
        let orphan = "pub struct LostCounters {\n    pub a: u64,\n}\n";
        let s = lines(orphan);
        let t = test_mod_mask(&s.code);
        let f = rule_metrics_surface("src/x.rs", &s, &t);
        assert_eq!((f.len(), f[0].line), (1, 1));
        let surfaced = "pub struct OkCounters {\n    pub a: u64,\n}\n\
                        pub struct MetricsSnapshot {\n    pub ok: OkCounters,\n}\n";
        let s = lines(surfaced);
        let t = test_mod_mask(&s.code);
        assert!(rule_metrics_surface("src/x.rs", &s, &t).is_empty());
        // A name that merely *contains* a surfaced one is not covered.
        let prefix = "pub struct OkCountersExtra {\n    pub a: u64,\n}\n\
                      pub struct MetricsSnapshot {\n    pub ok: OkCounters,\n}\n";
        let s = lines(prefix);
        let t = test_mod_mask(&s.code);
        assert!(rule_metrics_surface("src/x.rs", &s, &t).is_empty(), "suffix rule only");
        let near = "pub struct SubCounters {\n    pub a: u64,\n}\n\
                    pub struct MetricsSnapshot {\n    pub ok: SubCountersView,\n}\n";
        let s = lines(near);
        let t = test_mod_mask(&s.code);
        assert_eq!(rule_metrics_surface("src/x.rs", &s, &t).len(), 1, "word match required");
    }

    #[test]
    fn check_file_composes_rules_by_class() {
        let src = "fn f() {\n    let v = vec![1];\n    unsafe { g() }\n}\n";
        let class = FileClass { hot: true, walled: true };
        let hot = super::super::check_file("src/gmw/x.rs", src, class);
        assert!(hot.iter().any(|f| f.rule == Rule::HotAlloc));
        assert!(hot.iter().any(|f| f.rule == Rule::Safety));
        let class = FileClass { hot: false, walled: true };
        let cold = super::super::check_file("src/model/x.rs", src, class);
        assert!(!cold.iter().any(|f| f.rule == Rule::HotAlloc));
        assert!(cold.iter().any(|f| f.rule == Rule::Safety));
        let class = FileClass { hot: false, walled: false };
        let bench = super::super::check_file("benches/x.rs", src, class);
        assert_eq!(bench.len(), 1, "benches only get the SAFETY rule");
    }
}
