//! Scoped data-parallel helpers (rayon is not available offline).
//!
//! Built on `std::thread::scope`. The pool size defaults to the number of
//! available CPUs; on single-core testbeds the helpers degrade gracefully to
//! sequential execution with zero spawn overhead.

/// Number of worker threads to use for data-parallel loops.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(chunk_index, item_range)` over `n` items split into contiguous
/// chunks across up to `threads` OS threads. `f` must be `Send + Sync`.
///
/// Returns after all chunks complete (scoped threads). With `threads <= 1`
/// or tiny `n` this runs inline on the caller's thread.
pub fn par_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Send + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n < 2 {
        f(0, 0..n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(t, lo..hi));
        }
    });
}

/// Map `f` over `items` in parallel, preserving order.
pub fn par_map<T, U, F>(items: &[T], threads: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send + Default + Clone,
    F: Fn(&T) -> U + Send + Sync,
{
    let mut out = vec![U::default(); items.len()];
    {
        let out_ptr = SyncSlice(out.as_mut_ptr());
        let out_ref = &out_ptr;
        par_chunks(items.len(), threads, move |_, range| {
            for i in range {
                // SAFETY: each index is written by exactly one chunk.
                unsafe { *out_ref.ptr().add(i) = f(&items[i]) };
            }
        });
    }
    out
}

/// Wrapper to allow sharing a raw pointer across scoped threads when the
/// access pattern is provably disjoint (each index written once).
struct SyncSlice<U>(*mut U);
impl<U> SyncSlice<U> {
    fn ptr(&self) -> *mut U {
        self.0
    }
}
unsafe impl<U> Sync for SyncSlice<U> {}
unsafe impl<U> Send for SyncSlice<U> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_all_indices_exactly_once() {
        let n = 1037;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        par_chunks(n, 4, |_, range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<usize> = (0..501).collect();
        let out = par_map(&items, 3, |x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn degenerate_sizes() {
        par_chunks(0, 4, |_, r| assert!(r.is_empty()));
        let out = par_map::<usize, usize, _>(&[], 4, |x| *x);
        assert!(out.is_empty());
        let out = par_map(&[7usize], 4, |x| x + 1);
        assert_eq!(out, vec![8]);
    }
}
