//! Deterministic fault injection for the chaos suite (DESIGN.md §7).
//!
//! [`FaultyTransport`] wraps any [`Transport`] and injects faults at
//! chosen protocol rounds from a seeded, fully reproducible schedule — a
//! [`FaultProfile`] parsed from the `--fault-profile` CLI knob or built in
//! tests. Rounds where the schedule is empty pass straight through to the
//! inner transport, so a profile with no entries is byte- and
//! round-identical to the bare transport.
//!
//! # Profile grammar
//!
//! A profile is a comma-separated list of directives:
//!
//! ```text
//! drop@3            sever the link before round 3 (reconnect-and-resend)
//! crash@5           this party dies at round 5 (fatal; peers time out)
//! delay:20ms@2      sleep 20 ms before round 2 (latency blip, no error)
//! short@4           truncate the received frame of round 4 (Error::Wire)
//! drop@?8           like drop@k with k drawn from the PRG, k < 8
//! seed:42           PRG seed for the @? draws (default 0)
//! party:1           only party 1 injects; others run clean (default 0)
//! bootfail:3        the next 3 session (re)boots fail before spawning
//! ```
//!
//! `bootfail:` is consumed by the coordinator's `spawn_session`, not by
//! the transport wrapper: each spawn attempt fails outright until the
//! budget is spent, which is how the crash-loop breaker (DESIGN.md §9)
//! is driven into `Degraded` — and out again — deterministically.
//!
//! e.g. `--fault-profile "party:1,seed:7,drop@?10"` makes party 1 sever a
//! link at a pseudo-random round below 10, reproducibly across runs.
//!
//! Faults are injected *before* the round's exchange. `drop` asks the
//! inner transport to sever a real socket ([`Transport::inject_peer_drop`])
//! so both endpoints observe a genuine link fault; on transports without a
//! severable link (the in-process hub) it synthesizes a retryable
//! connection-reset error, which the coordinator degrades into a per-job
//! failure.

use std::str::FromStr;
use std::sync::Arc;
use std::time::Duration;

use super::accounting::{CommTrace, Phase};
use super::{RecvBufs, Transport};
use crate::crypto::prg::Prg;
use crate::error::{Error, Result};

/// What to inject at a scheduled round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Sleep this many milliseconds before the exchange (no error).
    Delay(u64),
    /// Sever the link to the lowest-ranked peer before the exchange.
    Drop,
    /// This party dies: the exchange (and every later one) fails fatally.
    Crash,
    /// Truncate the frame received from the lowest-ranked peer by one
    /// byte, so share decoding downstream rejects it as [`Error::Wire`].
    ShortFrame,
}

/// One scheduled fault: inject `kind` before round `round`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScheduledFault {
    pub round: u64,
    pub kind: FaultKind,
}

/// A deterministic fault schedule. Parse one from the CLI grammar above,
/// or build it directly in tests.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultProfile {
    /// Which party injects; every other party's wrapper is a no-op.
    pub party: usize,
    /// Seed for the `@?` randomized round draws.
    pub seed: u64,
    /// How many session (re)boots fail before spawning (`bootfail:N`).
    /// Consumed by the coordinator one per spawn attempt; the round-level
    /// faults below only arm once a session actually boots.
    pub boot_fails: u32,
    pub faults: Vec<ScheduledFault>,
}

impl FaultProfile {
    /// Schedule a single fault at a fixed round (test convenience).
    pub fn single(party: usize, round: u64, kind: FaultKind) -> Self {
        // HOT-PATH-ALLOW: constructor — one-element schedule, built once.
        FaultProfile {
            party,
            seed: 0,
            boot_fails: 0,
            faults: vec![ScheduledFault { round, kind }],
        }
    }

    /// A profile that only fails the next `n` session boots (test
    /// convenience for the crash-loop breaker).
    pub fn boot_failures(n: u32) -> Self {
        FaultProfile { boot_fails: n, ..FaultProfile::default() }
    }
}

impl FromStr for FaultProfile {
    type Err = String;

    fn from_str(s: &str) -> std::result::Result<Self, String> {
        let mut profile = FaultProfile::default();
        // Two passes so `seed:`/`party:` apply regardless of position.
        // HOT-PATH-ALLOW: CLI parsing — runs once per profile string.
        let directives: Vec<&str> =
            s.split(',').map(str::trim).filter(|d| !d.is_empty()).collect();
        for d in &directives {
            if let Some(v) = d.strip_prefix("seed:") {
                profile.seed = v.parse().map_err(|e| format!("bad seed '{v}': {e}"))?;
            } else if let Some(v) = d.strip_prefix("party:") {
                profile.party = v.parse().map_err(|e| format!("bad party '{v}': {e}"))?;
            } else if let Some(v) = d.strip_prefix("bootfail:") {
                profile.boot_fails = v.parse().map_err(|e| format!("bad bootfail '{v}': {e}"))?;
            }
        }
        let mut prg = Prg::new(profile.seed, 0xfa01);
        for d in &directives {
            if d.starts_with("seed:") || d.starts_with("party:") || d.starts_with("bootfail:") {
                continue;
            }
            let (head, at) = d
                .split_once('@')
                .ok_or_else(|| format!("directive '{d}' needs '@<round>' or '@?<bound>'"))?;
            let kind = match head {
                "drop" => FaultKind::Drop,
                "crash" => FaultKind::Crash,
                "short" => FaultKind::ShortFrame,
                _ => {
                    let ms = head
                        .strip_prefix("delay:")
                        .and_then(|v| v.strip_suffix("ms"))
                        .ok_or_else(|| format!("unknown fault kind '{head}'"))?;
                    FaultKind::Delay(ms.parse().map_err(|e| format!("bad delay '{ms}': {e}"))?)
                }
            };
            let round = match at.strip_prefix('?') {
                Some(bound) => {
                    let b: u64 =
                        bound.parse().map_err(|e| format!("bad round bound '{bound}': {e}"))?;
                    if b == 0 {
                        return Err(format!("round bound in '{d}' must be > 0"));
                    }
                    prg.next_below(b)
                }
                None => at.parse().map_err(|e| format!("bad round '{at}': {e}"))?,
            };
            profile.faults.push(ScheduledFault { round, kind });
        }
        Ok(profile)
    }
}

/// A [`Transport`] wrapper that injects the profile's faults at the
/// scheduled exchange rounds. Wrap only the party named by the profile
/// (or use [`FaultyTransport::new`], which checks for you).
pub struct FaultyTransport<T: Transport> {
    inner: T,
    faults: Vec<ScheduledFault>,
    armed: bool,
    round: u64,
    /// Peer whose link the `Drop`/`ShortFrame` faults target.
    victim: usize,
    crashed: bool,
}

impl<T: Transport> FaultyTransport<T> {
    /// Wrap `inner`. The schedule only arms when `inner.party()` matches
    /// `profile.party`, so every party can be wrapped uniformly.
    pub fn new(inner: T, profile: &FaultProfile) -> Self {
        let armed = inner.party() == profile.party;
        // Target the lowest-ranked peer: deterministic and always valid.
        let victim = if inner.party() == 0 { 1 } else { 0 };
        FaultyTransport {
            inner,
            // HOT-PATH-ALLOW: constructor — copies the schedule once.
            faults: profile.faults.clone(),
            armed,
            round: 0,
            victim,
            crashed: false,
        }
    }

    pub fn inner(&self) -> &T {
        &self.inner
    }

    pub fn into_inner(self) -> T {
        self.inner
    }

    fn take_fault(&mut self, round: u64) -> Option<FaultKind> {
        if !self.armed {
            return None;
        }
        let pos = self.faults.iter().position(|f| f.round == round)?;
        Some(self.faults.swap_remove(pos).kind)
    }
}

impl<T: Transport> Transport for FaultyTransport<T> {
    fn party(&self) -> usize {
        self.inner.party()
    }
    fn parties(&self) -> usize {
        self.inner.parties()
    }

    fn exchange_all_into(
        &mut self,
        phase: Phase,
        data: &[u8],
        recv: &mut RecvBufs,
    ) -> Result<()> {
        if self.crashed {
            return Err(Error::Transport("injected party crash (still down)".into()));
        }
        let round = self.round;
        self.round += 1;
        let mut truncate_victim = false;
        match self.take_fault(round) {
            None => {}
            Some(FaultKind::Delay(ms)) => std::thread::sleep(Duration::from_millis(ms)),
            Some(FaultKind::Crash) => {
                self.crashed = true;
                return Err(Error::Transport(format!("injected party crash at round {round}")));
            }
            Some(FaultKind::Drop) => {
                if !self.inner.inject_peer_drop(self.victim) {
                    // No severable link (in-process hub): surface the same
                    // class of error a reset socket would produce.
                    return Err(Error::Io(std::io::Error::new(
                        std::io::ErrorKind::ConnectionReset,
                        format!("injected connection drop at round {round}"),
                    )));
                }
                // Link severed for real — the inner exchange below now
                // exercises the genuine reconnect-and-resend path.
            }
            Some(FaultKind::ShortFrame) => truncate_victim = true,
        }
        self.inner.exchange_all_into(phase, data, recv)?;
        if truncate_victim {
            // Corrupt the received copy after a successful exchange: the
            // ragged buffer must be rejected downstream (Error::Wire), not
            // silently zero-padded into "valid" shares.
            let slot = &mut recv.slots_mut()[self.victim];
            slot.pop();
        }
        Ok(())
    }

    fn trace(&self) -> Arc<CommTrace> {
        self.inner.trace()
    }

    fn inject_peer_drop(&mut self, peer: usize) -> bool {
        self.inner.inject_peer_drop(peer)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::net::local::hub;

    #[test]
    fn profile_grammar_round_trip() {
        let p: FaultProfile = "party:1, seed:42, drop@3, delay:20ms@2, crash@5, short@4"
            .parse()
            .unwrap();
        assert_eq!(p.party, 1);
        assert_eq!(p.seed, 42);
        assert_eq!(p.faults.len(), 4);
        assert!(p.faults.contains(&ScheduledFault { round: 3, kind: FaultKind::Drop }));
        assert!(p.faults.contains(&ScheduledFault { round: 2, kind: FaultKind::Delay(20) }));
        assert!(p.faults.contains(&ScheduledFault { round: 5, kind: FaultKind::Crash }));
        assert!(p.faults.contains(&ScheduledFault { round: 4, kind: FaultKind::ShortFrame }));
    }

    /// `@?` rounds are drawn from the seeded PRG: the same profile string
    /// always yields the same schedule, different seeds may differ.
    #[test]
    fn randomized_rounds_are_deterministic() {
        let a: FaultProfile = "seed:7,drop@?100,crash@?100".parse().unwrap();
        let b: FaultProfile = "seed:7,drop@?100,crash@?100".parse().unwrap();
        assert_eq!(a, b);
        for f in &a.faults {
            assert!(f.round < 100);
        }
    }

    #[test]
    fn bad_profiles_are_rejected() {
        for bad in ["drop", "drop@x", "explode@3", "delay:5@1", "seed:abc,drop@1", "drop@?0"] {
            assert!(bad.parse::<FaultProfile>().is_err(), "{bad} should not parse");
        }
        assert!("bootfail:x".parse::<FaultProfile>().is_err());
    }

    /// `bootfail:` sets the boot-failure budget without scheduling any
    /// round-level fault, and composes with the round directives.
    #[test]
    fn bootfail_directive_parses() {
        let p: FaultProfile = "bootfail:3".parse().unwrap();
        assert_eq!(p.boot_fails, 3);
        assert!(p.faults.is_empty());
        let q: FaultProfile = "party:1,bootfail:2,crash@4".parse().unwrap();
        assert_eq!(q.boot_fails, 2);
        assert_eq!(q.faults, vec![ScheduledFault { round: 4, kind: FaultKind::Crash }]);
        assert_eq!(FaultProfile::boot_failures(5).boot_fails, 5);
    }

    /// An injected crash is fatal and sticky: the first exchange at the
    /// scheduled round fails, and so does every later one.
    #[test]
    fn crash_is_sticky() {
        let mut transports = hub(2);
        let t1 = transports.pop().unwrap();
        let _t0 = transports.pop().unwrap();
        let mut faulty = FaultyTransport::new(t1, &FaultProfile::single(1, 0, FaultKind::Crash));
        let mut recv = RecvBufs::new(2);
        let e0 = faulty.exchange_all_into(Phase::Circuit, b"x", &mut recv).unwrap_err();
        assert!(!e0.is_retryable());
        let e1 = faulty.exchange_all_into(Phase::Circuit, b"x", &mut recv).unwrap_err();
        assert!(matches!(e1, Error::Transport(_)), "crash must be sticky: {e1}");
    }

    /// On a transport without a severable link, `drop` degrades to a
    /// retryable synthesized reset — the coordinator turns that into a
    /// per-job failure.
    #[test]
    fn drop_on_hub_synthesizes_retryable_reset() {
        let mut transports = hub(2);
        let _t1 = transports.pop().unwrap();
        let t0 = transports.pop().unwrap();
        let mut faulty = FaultyTransport::new(t0, &FaultProfile::single(0, 0, FaultKind::Drop));
        let mut recv = RecvBufs::new(2);
        let err = faulty.exchange_all_into(Phase::Circuit, b"x", &mut recv).unwrap_err();
        assert!(err.is_retryable(), "synthesized drop must classify retryable: {err}");
    }

    /// A party whose id differs from the profile's target runs clean.
    #[test]
    fn unarmed_party_passes_through() {
        let mut transports = hub(2);
        let t1 = transports.pop().unwrap();
        let t0 = transports.pop().unwrap();
        let profile = FaultProfile::single(1, 0, FaultKind::Crash);
        let mut f0 = FaultyTransport::new(t0, &profile); // party 0: unarmed
        {
            let mut f1 = FaultyTransport::new(t1, &profile);
            let mut recv = RecvBufs::new(2);
            f1.exchange_all_into(Phase::Circuit, b"from1", &mut recv).unwrap_err();
            // f1 drops here, closing its hub endpoint like a dead thread.
        }
        let mut recv = RecvBufs::new(2);
        // Party 0 is clean but its peer crashed: the hub surfaces a
        // closed-channel/timeout error rather than wedging.
        let err = f0.exchange_all_into(Phase::Circuit, b"from0", &mut recv).unwrap_err();
        assert!(matches!(err, Error::Timeout(_) | Error::Transport(_)), "got {err}");
    }
}
