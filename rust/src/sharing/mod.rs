//! Secret sharing over Z/2^64 (arithmetic) and GF(2) (binary / XOR),
//! for any number of parties p >= 2 (paper §2.2).
//!
//! * Arithmetic shares: Σ_p ⟨x⟩_p ≡ x (mod 2^64).
//! * Binary shares: ⊕_p ⟨x⟩_p = x, one w-bit lane per u64.
//! * [`PairwisePrgs`] implements CrypTen's communication-free local
//!   re-sharing: parties holding pairwise PRG seeds can generate identical
//!   zero-sharings, so converting a locally-held value into a (binary or
//!   arithmetic) sharing costs **no communication** — the property that
//!   makes A2B's first step free (paper §2.2).

use crate::crypto::prg::Prg;

/// Dealer-side helper: split plaintext `x` into `p` arithmetic shares.
pub fn share_arith(prg: &mut Prg, x: &[u64], parties: usize) -> Vec<Vec<u64>> {
    assert!(parties >= 2);
    let n = x.len();
    let mut shares = vec![vec![0u64; n]; parties];
    for i in 0..n {
        let mut acc = 0u64;
        for share in shares.iter_mut().take(parties - 1) {
            let r = prg.next_u64();
            share[i] = r;
            acc = acc.wrapping_add(r);
        }
        shares[parties - 1][i] = x[i].wrapping_sub(acc);
    }
    shares
}

/// Dealer-side helper: split plaintext `x` into `p` binary (XOR) shares.
pub fn share_binary(prg: &mut Prg, x: &[u64], parties: usize) -> Vec<Vec<u64>> {
    assert!(parties >= 2);
    let n = x.len();
    let mut shares = vec![vec![0u64; n]; parties];
    for i in 0..n {
        let mut acc = 0u64;
        for share in shares.iter_mut().take(parties - 1) {
            let r = prg.next_u64();
            share[i] = r;
            acc ^= r;
        }
        shares[parties - 1][i] = x[i] ^ acc;
    }
    shares
}

/// Reconstruct arithmetic shares: element-wise wrapping sum.
pub fn reconstruct_arith(shares: &[Vec<u64>]) -> Vec<u64> {
    let n = shares[0].len();
    let mut out = vec![0u64; n];
    for s in shares {
        for (o, v) in out.iter_mut().zip(s) {
            *o = o.wrapping_add(*v);
        }
    }
    out
}

/// Reconstruct binary shares: element-wise XOR.
pub fn reconstruct_binary(shares: &[Vec<u64>]) -> Vec<u64> {
    let n = shares[0].len();
    let mut out = vec![0u64; n];
    for s in shares {
        for (o, v) in out.iter_mut().zip(s) {
            *o ^= *v;
        }
    }
    out
}

/// Per-party pairwise PRGs for zero-sharings (CrypTen's PRG trick).
///
/// Party `me` holds one PRG per other party, keyed by the unordered pair
/// (min, max) so both endpoints derive the *same* stream. Protocol
/// determinism keeps the streams synchronized: every party consumes the
/// same number of values from each pairwise stream at the same protocol
/// step, without any runtime coordination.
pub struct PairwisePrgs {
    me: usize,
    parties: usize,
    /// `prgs[q]` is the stream shared with party q (entry `me` unused).
    prgs: Vec<Prg>,
}

impl PairwisePrgs {
    /// Derive the pairwise streams from a public session seed. In a real
    /// deployment each pair would exchange a fresh seed at session setup;
    /// here the honest-but-curious performance testbed derives them from
    /// the session seed (see DESIGN.md §4, TTP substitution).
    pub fn new(session_seed: u64, me: usize, parties: usize) -> Self {
        assert!(me < parties);
        let prgs = (0..parties)
            .map(|q| {
                let (lo, hi) = (me.min(q) as u64, me.max(q) as u64);
                // stream id unique per unordered pair
                Prg::new(session_seed ^ PAIRWISE_DOMAIN, (lo << 32) | hi)
            })
            .collect();
        PairwisePrgs { me, parties, prgs }
    }

    /// Binary zero-sharing written into `out` (⊕ over parties = 0).
    /// Allocation-free; stream consumption identical to
    /// [`PairwisePrgs::zero_binary`].
    pub fn zero_binary_into(&mut self, out: &mut [u64]) {
        out.iter_mut().for_each(|o| *o = 0);
        for q in 0..self.parties {
            if q == self.me {
                continue;
            }
            let prg = &mut self.prgs[q];
            for o in out.iter_mut() {
                *o ^= prg.next_u64();
            }
        }
    }

    /// Binary zero-sharing: returns this party's share of a fresh sharing
    /// of 0 in the XOR domain (⊕ over parties = 0).
    pub fn zero_binary(&mut self, n: usize) -> Vec<u64> {
        let mut out = vec![0u64; n];
        self.zero_binary_into(&mut out);
        out
    }

    /// Arithmetic zero-sharing: returns this party's share of a fresh
    /// sharing of 0 (Σ over parties = 0 mod 2^64). The pairwise mask is
    /// added by the lower-indexed endpoint and subtracted by the higher.
    pub fn zero_arith(&mut self, n: usize) -> Vec<u64> {
        let mut out = vec![0u64; n];
        for q in 0..self.parties {
            if q == self.me {
                continue;
            }
            let prg = &mut self.prgs[q];
            if self.me < q {
                for o in out.iter_mut() {
                    *o = o.wrapping_add(prg.next_u64());
                }
            } else {
                for o in out.iter_mut() {
                    *o = o.wrapping_sub(prg.next_u64());
                }
            }
        }
        out
    }

    /// Locally convert a value held in full by this party into a binary
    /// sharing, written into `out`: my share = value ⊕ zero-share; everyone
    /// else's is their zero-share (they call this with `value = None`).
    /// Allocation-free (the GMW A2B hot path hands in arena buffers).
    pub fn reshare_binary_into(&mut self, value: Option<&[u64]>, out: &mut [u64]) {
        self.zero_binary_into(out);
        if let Some(v) = value {
            assert_eq!(v.len(), out.len());
            for (zi, vi) in out.iter_mut().zip(v) {
                *zi ^= *vi;
            }
        }
    }

    /// Locally convert a value held in full by this party into a binary
    /// sharing (allocating wrapper).
    pub fn reshare_binary(&mut self, value: Option<&[u64]>, n: usize) -> Vec<u64> {
        let mut z = vec![0u64; n];
        self.reshare_binary_into(value, &mut z);
        z
    }
}

/// Domain-separation constant for pairwise streams (vs. dealer streams).
const PAIRWISE_DOMAIN: u64 = 0x7a11_57ee_5eed_0001;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arith_share_reconstructs() {
        let mut prg = Prg::new(1, 0);
        let x: Vec<u64> = vec![0, 1, u64::MAX, 0x1234_5678_9abc_def0];
        for p in 2..=4 {
            let shares = share_arith(&mut prg, &x, p);
            assert_eq!(reconstruct_arith(&shares), x);
            // Individual shares look nothing like x (prob. check).
            assert_ne!(shares[0], x);
        }
    }

    #[test]
    fn binary_share_reconstructs() {
        let mut prg = Prg::new(2, 0);
        let x: Vec<u64> = vec![0b1011, u64::MAX, 42];
        for p in 2..=4 {
            let shares = share_binary(&mut prg, &x, p);
            assert_eq!(reconstruct_binary(&shares), x);
        }
    }

    #[test]
    fn pairwise_zero_sharing_sums_to_zero() {
        for parties in 2..=4 {
            let mut prgs: Vec<PairwisePrgs> =
                (0..parties).map(|p| PairwisePrgs::new(77, p, parties)).collect();
            let shares: Vec<Vec<u64>> = prgs.iter_mut().map(|p| p.zero_binary(8)).collect();
            assert_eq!(reconstruct_binary(&shares), vec![0u64; 8]);
            let shares: Vec<Vec<u64>> = prgs.iter_mut().map(|p| p.zero_arith(8)).collect();
            assert_eq!(reconstruct_arith(&shares), vec![0u64; 8]);
            // Streams stay synchronized across multiple calls.
            let shares: Vec<Vec<u64>> = prgs.iter_mut().map(|p| p.zero_binary(5)).collect();
            assert_eq!(reconstruct_binary(&shares), vec![0u64; 5]);
        }
    }

    #[test]
    fn local_reshare_binary() {
        let parties = 3;
        let value: Vec<u64> = vec![0xdead_beef, 7];
        let mut prgs: Vec<PairwisePrgs> =
            (0..parties).map(|p| PairwisePrgs::new(123, p, parties)).collect();
        let shares: Vec<Vec<u64>> = prgs
            .iter_mut()
            .enumerate()
            .map(|(p, prg)| prg.reshare_binary(if p == 1 { Some(&value) } else { None }, 2))
            .collect();
        assert_eq!(reconstruct_binary(&shares), value);
    }
}
