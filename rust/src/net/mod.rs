//! Party-to-party communication substrate.
//!
//! The GMW engine talks to an abstract [`Transport`]; two implementations
//! exist: [`local::LocalTransport`] (in-process channels — used by tests,
//! benches and the single-binary multi-party simulator) and
//! [`tcp::TcpTransport`] (real sockets for multi-process deployments).
//! Both feed the same [`accounting::CommTrace`], and simulated wall-clock
//! for arbitrary networks is projected by [`profile`] using the paper's own
//! methodology (measured bytes/rounds × analytic bandwidth/latency model).

pub mod accounting;
pub mod local;
pub mod profile;
pub mod tcp;

use crate::error::Result;
use accounting::{CommTrace, Phase};
use std::sync::Arc;

/// Abstract all-to-all exchange primitive for one party.
///
/// GMW only ever needs "every party sends a buffer to every other party and
/// receives theirs" (openings of masked values). One `exchange_all` call is
/// one communication **round**.
pub trait Transport: Send {
    /// This party's id in 0..parties.
    fn party(&self) -> usize;
    /// Total number of parties.
    fn parties(&self) -> usize;

    /// Send `data` to every other party; receive each other party's buffer.
    /// Returns a vec indexed by party id (entry for `self.party()` is the
    /// input `data` echoed back, so openings can simply fold over all).
    fn exchange_all(&mut self, phase: Phase, data: &[u8]) -> Result<Vec<Vec<u8>>>;

    /// The accounting trace for this party.
    fn trace(&self) -> Arc<CommTrace>;
}

/// Helper: XOR-open a vector of packed binary share words.
/// (Shared by engine code and tests.)
pub fn fold_xor(bufs: &[Vec<u64>]) -> Vec<u64> {
    let n = bufs[0].len();
    let mut out = vec![0u64; n];
    for b in bufs {
        debug_assert_eq!(b.len(), n);
        for (o, v) in out.iter_mut().zip(b) {
            *o ^= *v;
        }
    }
    out
}

/// Helper: additively open a vector of ring-element shares.
pub fn fold_add(bufs: &[Vec<u64>]) -> Vec<u64> {
    let n = bufs[0].len();
    let mut out = vec![0u64; n];
    for b in bufs {
        debug_assert_eq!(b.len(), n);
        for (o, v) in out.iter_mut().zip(b) {
            *o = o.wrapping_add(*v);
        }
    }
    out
}

/// Serialize a u64 slice little-endian into a reusable buffer. Every byte
/// is overwritten, so a buffer already at the right length (the warm
/// arena-pooled path) is neither cleared nor reallocated. Hot-path form
/// used by the arithmetic openings.
pub fn u64s_to_bytes_into(v: &[u64], out: &mut Vec<u8>) {
    let nbytes = v.len() * 8;
    if out.len() != nbytes {
        out.clear();
        out.resize(nbytes, 0);
    }
    for (chunk, x) in out.chunks_exact_mut(8).zip(v) {
        chunk.copy_from_slice(&x.to_le_bytes());
    }
}

/// Serialize a u64 slice little-endian (wire format helper).
pub fn u64s_to_bytes(v: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 8);
    u64s_to_bytes_into(v, &mut out);
    out
}

/// Wrapping-add each little-endian u64 in `b` into `out` in place (the
/// receive-side fold of an arithmetic opening; no intermediate vector).
pub fn add_u64s_from_bytes(b: &[u8], out: &mut [u64]) {
    for (o, c) in out.iter_mut().zip(b.chunks(8)) {
        let mut buf = [0u8; 8];
        buf[..c.len()].copy_from_slice(c);
        *o = o.wrapping_add(u64::from_le_bytes(buf));
    }
}

/// Deserialize little-endian u64s.
pub fn bytes_to_u64s(b: &[u8]) -> Vec<u64> {
    b.chunks(8)
        .map(|c| {
            let mut buf = [0u8; 8];
            buf[..c.len()].copy_from_slice(c);
            u64::from_le_bytes(buf)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u64_bytes_roundtrip() {
        let v = vec![0u64, 1, u64::MAX, 0x0102_0304_0506_0708];
        assert_eq!(bytes_to_u64s(&u64s_to_bytes(&v)), v);
    }

    #[test]
    fn add_fold_from_bytes_matches_wrapping_add() {
        let v = vec![1u64, u64::MAX, 7];
        let b = u64s_to_bytes(&v);
        let mut out = vec![1u64, 1, 1];
        add_u64s_from_bytes(&b, &mut out);
        assert_eq!(out, vec![2, 0, 8]);
        let mut reused = Vec::new();
        u64s_to_bytes_into(&v, &mut reused);
        assert_eq!(reused, b);
    }

    #[test]
    fn folds() {
        let a = vec![vec![1u64, 2], vec![3u64, 4]];
        assert_eq!(fold_xor(&a), vec![2, 6]);
        assert_eq!(fold_add(&a), vec![4, 6]);
    }
}
