//! The full HummingBird workflow of Fig 5 in one program:
//!
//!   offline:  search (eco + budgeted DFS) on the MPC simulator
//!   online:   deploy both plans and measure real MPC runs
//!
//! Run: `cargo run --release --example search_and_deploy -- [model]`
//! (default micronet_synth10; requires `make artifacts` + `make train`)

use hummingbird::figures::FigCtx;
use hummingbird::hummingbird::search::{SearchConfig, SearchEngine, Strategy};
use hummingbird::model::{Archive, Backend, Dataset, ModelConfig, PlainExecutor, WhichPlain};
use hummingbird::net::profile::NetworkProfile;
use hummingbird::runtime::{Manifest, Runtime};
use hummingbird::util::stats;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).map(|s| s.as_str()).unwrap_or("micronet_synth10");
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));

    let cfg = ModelConfig::load_named(&root, model)?;
    let weights = Archive::load(root.join("artifacts/weights").join(model))?;
    let dataset = Dataset::load(root.join("artifacts"), &cfg.dataset)?;
    let manifest = Manifest::load(root.join("artifacts"))?;
    let model_art = manifest.model(model)?.clone();
    let exec = PlainExecutor::new(
        cfg.clone(),
        weights,
        Backend::Xla {
            rt: Runtime::new(root.join("artifacts"))?,
            artifact_batch: model_art.search_batch,
            artifacts: model_art,
            which: WhichPlain::Search,
        },
    );

    println!("=== offline phase: HummingBird search on {model} ===\n");
    let mut plans = Vec::new();
    for (label, strategy) in [
        ("eco", Strategy::Eco),
        ("budget 8/64", Strategy::Budget(8.0 / 64.0)),
        ("budget 6/64", Strategy::Budget(6.0 / 64.0)),
    ] {
        let scfg = SearchConfig { strategy, val_samples: 192, ..SearchConfig::default() };
        let n = scfg.val_samples.min(dataset.val.n);
        let engine = SearchEngine::new(
            &exec,
            &dataset.val.images,
            &dataset.val.labels[..n],
            dataset.val.sample_elems,
            scfg,
        );
        let r = engine.run()?;
        println!(
            "{label:<12} plan {:<40} acc {:.2}% -> {:.2}%  ({} evals, {})",
            r.plans.summary(),
            r.baseline_acc * 100.0,
            r.final_acc * 100.0,
            r.evals,
            stats::fmt_secs(r.search_time_s),
        );
        plans.push((label, r.plans));
    }

    println!("\n=== online phase: deploy each plan in a real 2-party MPC run ===\n");
    let mut ctx = FigCtx::new(root);
    let lan = NetworkProfile::lan();
    // Baseline measurement for the speedup column.
    let (mb, rb) = ctx.measure(model, "baseline")?;
    let tb: f64 = rb.iter().map(|(b, _)| lan.round_time(*b)).sum::<f64>() + mb.compute_s;
    println!("{:<12} {:>12} {:>8} {:>12}", "plan", "bytes", "rounds", "LAN speedup");
    println!(
        "{:<12} {:>12} {:>8} {:>12}",
        "baseline",
        stats::fmt_bytes(mb.protocol_bytes()),
        mb.total_rounds,
        "1.00x"
    );
    for (label, plan) in plans {
        // Save as a temp named variant so the ctx cache key is stable.
        let name = format!("ex_{}", label.replace([' ', '/'], "_"));
        let path = ctx.root.join("configs/searched").join(format!("{model}_{name}.json"));
        plan.save(&path)?;
        let (m, r) = ctx.measure(model, &name)?;
        let t: f64 = r.iter().map(|(b, _)| lan.round_time(*b)).sum::<f64>() + m.compute_s;
        println!(
            "{:<12} {:>12} {:>8} {:>11.2}x",
            label,
            stats::fmt_bytes(m.protocol_bytes()),
            m.total_rounds,
            tb / t
        );
    }
    println!(
        "\n(speedups here use raw CPU compute; `hummingbird figures` applies the\n \
         calibrated GPU-profile methodology described in EXPERIMENTS.md)"
    );
    Ok(())
}
