//! Microbenchmarks of the GMW engine's building blocks: AND gates, the
//! Kogge–Stone adder, A2B, B2A, Beaver mult — across ring widths. These are
//! the per-operation numbers behind every end-to-end figure; run with
//! `cargo bench --bench gmw_micro` (HB_BENCH_QUICK=1 for a fast pass).

use hummingbird::crypto::prg::Prg;
use hummingbird::gmw::harness::{run_parties, run_parties_threaded};
use hummingbird::gmw::{adder, ReluPlan};
use hummingbird::sharing::{share_arith, share_binary};
use hummingbird::util::benchkit::{bench_threads, Bench};

fn main() {
    let mut bench = Bench::new();
    let n = 16384usize;
    let mut prg = Prg::new(1, 1);
    let x: Vec<u64> = prg.vec_u64(n);
    let xs_a = share_arith(&mut prg, &x, 2);
    let xs_b = share_binary(&mut prg, &x, 2);
    let ys_b = share_binary(&mut prg, &x, 2);

    // Secure AND on full words.
    {
        let xs = xs_b.clone();
        let ys = ys_b.clone();
        bench.bench_elems(&format!("and_gates/64bit/{n}"), n as u64, || {
            let xs = xs.clone();
            let ys = ys.clone();
            run_parties(2, 3, move |p| {
                let me = p.party();
                p.and_gates(
                    hummingbird::net::accounting::Phase::Circuit,
                    &xs[me],
                    &ys[me],
                    64,
                )
                .unwrap()
            });
        });
    }

    // Kogge–Stone adder across widths (the O(w log w) law).
    for w in [64u32, 20, 8, 6] {
        let mask = hummingbird::ring::low_mask(w);
        let xs: Vec<Vec<u64>> =
            xs_b.iter().map(|s| s.iter().map(|v| v & mask).collect()).collect();
        let ys: Vec<Vec<u64>> =
            ys_b.iter().map(|s| s.iter().map(|v| v & mask).collect()).collect();
        bench.bench_elems(&format!("ks_add/w{w}/{n}"), n as u64, || {
            let xs = xs.clone();
            let ys = ys.clone();
            run_parties(2, 4, move |p| {
                let me = p.party();
                adder::ks_add(p, &xs[me], &ys[me], w).unwrap()
            });
        });
    }

    // Full DReLU at paper-relevant windows.
    for (label, plan) in [
        ("baseline64", ReluPlan::BASELINE),
        ("eco18", ReluPlan::new(18, 0).unwrap()),
        ("hb8", ReluPlan::new(12, 4).unwrap()),
        ("hb6", ReluPlan::new(10, 4).unwrap()),
    ] {
        let xs = xs_a.clone();
        bench.bench_elems(&format!("drelu/{label}/{n}"), n as u64, || {
            let xs = xs.clone();
            run_parties(2, 5, move |p| {
                let me = p.party();
                p.drelu(&xs[me], plan).unwrap()
            });
        });
    }

    // Plane-native Beaver triple expansion (the offline dealer cost): the
    // stream draws only the w live bit-planes per 64-lane block, so the
    // w6 row should run ~10x the w64 row's throughput.
    {
        use hummingbird::beaver::TtpDealer;
        use hummingbird::gmw::bitsliced::plane_len;
        use hummingbird::util::benchkit::black_box;
        for w in [6u32, 64] {
            let pl = plane_len(n, w);
            let mut a = vec![0u64; pl];
            let mut b = vec![0u64; pl];
            let mut c = vec![0u64; pl];
            let mut dealer = TtpDealer::new(3, 0, 2);
            bench.bench_elems(&format!("bin_triples_planes/w{w}/{n}"), n as u64, || {
                dealer.bin_triples_planes_into(w, n, 1, &mut a, &mut b, &mut c);
                black_box(&c);
            });
        }
    }

    // Beaver arithmetic multiplication (the incompressible Mult phase).
    {
        let xs = xs_a.clone();
        let ys = share_arith(&mut prg, &x, 2);
        bench.bench_elems(&format!("beaver_mult/{n}"), n as u64, || {
            let xs = xs.clone();
            let ys = ys.clone();
            run_parties(2, 6, move |p| {
                let me = p.party();
                p.mul(&xs[me], &ys[me]).unwrap()
            });
        });
    }

    // B2A via daBits.
    {
        let bits: Vec<u64> = x.iter().map(|v| v & 1).collect();
        let bs = share_binary(&mut prg, &bits, 2);
        let bs: Vec<Vec<u64>> = bs.iter().map(|s| s.iter().map(|v| v & 1).collect()).collect();
        bench.bench_elems(&format!("b2a_bit/{n}"), n as u64, || {
            let bs = bs.clone();
            run_parties(2, 7, move |p| {
                let me = p.party();
                p.b2a_bit(&bs[me]).unwrap()
            });
        });
    }

    // Hot path at scale: n = 65536, single-threaded vs multi-threaded
    // (the zero-allocation arena + parallel kernels + fused bitpack path;
    // perf target: >= 1.5x at this size on multi-core hosts, no regression
    // at the small sizes above, which all run t=1).
    {
        let n_big = 65536usize;
        let threads = bench_threads();
        let xb: Vec<u64> = (0..n_big).map(|_| prg.next_u64() % (1 << 16)).collect();
        let xs_big = share_arith(&mut prg, &xb, 2);
        let ub = share_binary(&mut prg, &xb, 2);
        let vb = share_binary(&mut prg, &xb, 2);
        let plan = ReluPlan::new(12, 4).unwrap();
        for t in [1usize, threads] {
            // Shares are borrowed, not cloned, inside the timed closures:
            // a per-iteration multi-MB memcpy would dilute the t1-vs-tN
            // comparison these rows exist to make.
            bench.bench_elems(&format!("and_gates/64bit/{n_big}/t{t}"), n_big as u64, || {
                run_parties_threaded(2, 21, t, |p| {
                    let me = p.party();
                    p.and_gates(
                        hummingbird::net::accounting::Phase::Circuit,
                        &ub[me],
                        &vb[me],
                        64,
                    )
                    .unwrap()
                });
            });
            bench.bench_elems(&format!("relu/hb8/{n_big}/t{t}"), n_big as u64, || {
                run_parties_threaded(2, 22, t, |p| {
                    let me = p.party();
                    p.relu(&xs_big[me], plan).unwrap()
                });
            });
            if threads == 1 {
                break; // single-core host: the two rows would be identical
            }
        }
    }

    // Kernel-arm differential rows (DESIGN.md §11): the same hot
    // primitive on the forced-scalar arm (`RustKernels::scalar`, immune
    // to CLI/env) and the dispatched arm (`::default`, AVX2 where the
    // CPU has it), plus the 64×64 transpose and the fused wire pack. The
    // whole section is gated on runtime AVX2: without it the two arms
    // are the same code and the ratio table would be noise. CI greps the
    // markdown table below into the job summary.
    if hummingbird::gmw::simd::available() {
        use hummingbird::bitpack::packed_bytes;
        use hummingbird::gmw::bitsliced::{self, plane_len};
        use hummingbird::gmw::kernels::{KernelBackend, RustKernels};
        use hummingbird::util::benchkit::black_box;

        let nk = 16384usize;
        let d = prg.vec_u64(nk);
        let e = prg.vec_u64(nk);
        let a = prg.vec_u64(nk);
        let b = prg.vec_u64(nk);
        let c = prg.vec_u64(nk);
        let mut scalar = RustKernels::scalar();
        let mut dispatched = RustKernels::default();
        let mut rows: Vec<(&'static str, f64, f64)> = Vec::new();

        {
            let mut out = vec![0u64; 2 * nk];
            let s = bench
                .bench_elems(&format!("simd_and_open/scalar/{nk}"), nk as u64, || {
                    scalar.and_open(&d, &e, &a, &b, &mut out);
                    black_box(&out);
                })
                .median();
            let v = bench
                .bench_elems(&format!("simd_and_open/dispatch/{nk}"), nk as u64, || {
                    dispatched.and_open(&d, &e, &a, &b, &mut out);
                    black_box(&out);
                })
                .median();
            rows.push(("and_open (xor)", s, v));
        }

        {
            let mut out = vec![0u64; nk];
            let s = bench
                .bench_elems(&format!("simd_and_combine/scalar/{nk}"), nk as u64, || {
                    scalar.and_combine(&d, &e, &a, &b, &c, true, &mut out);
                    black_box(&out);
                })
                .median();
            let v = bench
                .bench_elems(&format!("simd_and_combine/dispatch/{nk}"), nk as u64, || {
                    dispatched.and_combine(&d, &e, &a, &b, &c, true, &mut out);
                    black_box(&out);
                })
                .median();
            rows.push(("and_combine", s, v));
        }

        {
            let w = 20u32;
            let mask = hummingbird::ring::low_mask(w);
            let g: Vec<u64> = d.iter().map(|v| v & mask).collect();
            let p: Vec<u64> = e.iter().map(|v| v & mask).collect();
            let mut u_out = vec![0u64; 2 * nk];
            let mut v_out = vec![0u64; 2 * nk];
            let s = bench
                .bench_elems(&format!("simd_ks_stage/scalar/w{w}/{nk}"), nk as u64, || {
                    scalar.ks_stage_operands(&g, &p, 2, w, false, &mut u_out, &mut v_out);
                    black_box(&v_out);
                })
                .median();
            let v = bench
                .bench_elems(&format!("simd_ks_stage/dispatch/w{w}/{nk}"), nk as u64, || {
                    dispatched.ks_stage_operands(&g, &p, 2, w, false, &mut u_out, &mut v_out);
                    black_box(&v_out);
                })
                .median();
            rows.push(("ks_stage_operands", s, v));
        }

        {
            let mut m = [0u64; 64];
            for v in m.iter_mut() {
                *v = prg.next_u64();
            }
            let s = bench
                .bench_elems("simd_transpose64/scalar", 64, || {
                    bitsliced::transpose64(&mut m);
                    black_box(&m);
                })
                .median();
            let v = bench
                .bench_elems("simd_transpose64/dispatch", 64, || {
                    hummingbird::gmw::simd::transpose64(&mut m);
                    black_box(&m);
                })
                .median();
            rows.push(("transpose64", s, v));
        }

        {
            let w = 12u32;
            let mask = hummingbird::ring::low_mask(w);
            let lanes: Vec<u64> = d.iter().map(|v| v & mask).collect();
            let mut planes = vec![0u64; plane_len(nk, w)];
            bitsliced::lanes_to_planes(&lanes, w, &mut planes, 1);
            let mut wire = vec![0u8; packed_bytes(nk, w) as usize];
            let s = bench
                .bench_elems(&format!("simd_pack_planes/scalar/w{w}/{nk}"), nk as u64, || {
                    bitsliced::pack_planes_xor_into_with(&planes, w, nk, 0, &mut wire, 1, false);
                    black_box(&wire);
                })
                .median();
            let v = bench
                .bench_elems(&format!("simd_pack_planes/dispatch/w{w}/{nk}"), nk as u64, || {
                    bitsliced::pack_planes_xor_into_with(&planes, w, nk, 0, &mut wire, 1, true);
                    black_box(&wire);
                })
                .median();
            rows.push(("pack_planes_xor", s, v));
        }

        println!();
        println!("| gmw_micro kernel row | scalar | dispatched | speedup |");
        println!("|---|---:|---:|---:|");
        for (name, s, v) in &rows {
            println!("| {name} | {s:.3e} s | {v:.3e} s | {:.2}x |", s / v);
        }
    }

    bench.dump_json("gmw_micro");
}
