//! ChaCha20 stream cipher core (RFC 7539), implemented from scratch.
//!
//! Used exclusively as a PRG for secret-sharing randomness, Beaver triple
//! generation (TTP role) and the pairwise zero-sharing seeds — the offline
//! crate set has no vetted crypto crates, and the honest-but-curious model of
//! the paper only needs a cryptographically strong PRG, which ChaCha20
//! provides. Verified against the RFC 7539 §2.3.2 test vector.

/// ChaCha20 block function state.
#[derive(Clone)]
pub struct ChaCha20 {
    /// Key + constants + counter + nonce, per RFC 7539 state layout.
    key: [u32; 8],
    nonce: [u32; 3],
    counter: u32,
    /// Buffered keystream block and read offset within it.
    block: [u8; 64],
    offset: usize,
}

#[inline(always)]
fn quarter_round(state: &mut [u32; 16], a: usize, b: usize, c: usize, d: usize) {
    state[a] = state[a].wrapping_add(state[b]);
    state[d] = (state[d] ^ state[a]).rotate_left(16);
    state[c] = state[c].wrapping_add(state[d]);
    state[b] = (state[b] ^ state[c]).rotate_left(12);
    state[a] = state[a].wrapping_add(state[b]);
    state[d] = (state[d] ^ state[a]).rotate_left(8);
    state[c] = state[c].wrapping_add(state[d]);
    state[b] = (state[b] ^ state[c]).rotate_left(7);
}

impl ChaCha20 {
    /// Construct from a 256-bit key and 96-bit nonce, counter starting at 0.
    pub fn new(key: &[u8; 32], nonce: &[u8; 12]) -> Self {
        let mut k = [0u32; 8];
        for (i, chunk) in key.chunks_exact(4).enumerate() {
            // LINT-ALLOW: unwrap — chunks_exact(4) slices are 4 bytes.
            k[i] = u32::from_le_bytes(chunk.try_into().unwrap());
        }
        let mut n = [0u32; 3];
        for (i, chunk) in nonce.chunks_exact(4).enumerate() {
            // LINT-ALLOW: unwrap — chunks_exact(4) slices are 4 bytes.
            n[i] = u32::from_le_bytes(chunk.try_into().unwrap());
        }
        ChaCha20 { key: k, nonce: n, counter: 0, block: [0u8; 64], offset: 64 }
    }

    /// Convenience: derive a cipher from a 64-bit seed and 64-bit stream id
    /// (seed expanded into the key; stream id into the nonce). This is the
    /// form the sharing layer uses for deterministic per-session PRGs.
    pub fn from_seed(seed: u64, stream: u64) -> Self {
        let mut key = [0u8; 32];
        // Simple domain-separated expansion of the seed into the key.
        for (i, chunk) in key.chunks_exact_mut(8).enumerate() {
            let v = seed
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .wrapping_add((i as u64 + 1).wrapping_mul(0xBF58_476D_1CE4_E5B9));
            chunk.copy_from_slice(&v.to_le_bytes());
        }
        let mut nonce = [0u8; 12];
        nonce[..8].copy_from_slice(&stream.to_le_bytes());
        ChaCha20::new(&key, &nonce)
    }

    /// Generate the next 64-byte keystream block into `self.block`.
    fn refill(&mut self) {
        const SIGMA: [u32; 4] = [0x6170_7865, 0x3320_646e, 0x7962_2d32, 0x6b20_6574];
        let mut s = [0u32; 16];
        s[0..4].copy_from_slice(&SIGMA);
        s[4..12].copy_from_slice(&self.key);
        s[12] = self.counter;
        s[13..16].copy_from_slice(&self.nonce);
        let mut w = s;
        for _ in 0..10 {
            // column rounds
            quarter_round(&mut w, 0, 4, 8, 12);
            quarter_round(&mut w, 1, 5, 9, 13);
            quarter_round(&mut w, 2, 6, 10, 14);
            quarter_round(&mut w, 3, 7, 11, 15);
            // diagonal rounds
            quarter_round(&mut w, 0, 5, 10, 15);
            quarter_round(&mut w, 1, 6, 11, 12);
            quarter_round(&mut w, 2, 7, 8, 13);
            quarter_round(&mut w, 3, 4, 9, 14);
        }
        for i in 0..16 {
            let v = w[i].wrapping_add(s[i]);
            self.block[i * 4..i * 4 + 4].copy_from_slice(&v.to_le_bytes());
        }
        self.counter = self.counter.wrapping_add(1);
        self.offset = 0;
    }

    /// Fill `out` with keystream bytes.
    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        let mut pos = 0;
        while pos < out.len() {
            if self.offset == 64 {
                self.refill();
            }
            let n = (out.len() - pos).min(64 - self.offset);
            out[pos..pos + n].copy_from_slice(&self.block[self.offset..self.offset + n]);
            self.offset += n;
            pos += n;
        }
    }

    /// Next uniform u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        if self.offset + 8 > 64 {
            self.refill();
        }
        // LINT-ALLOW: unwrap — the slice is exactly 8 bytes by construction.
        let v = u64::from_le_bytes(self.block[self.offset..self.offset + 8].try_into().unwrap());
        self.offset += 8;
        v
    }

    /// Next uniform u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        if self.offset + 4 > 64 {
            self.refill();
        }
        // LINT-ALLOW: unwrap — the slice is exactly 4 bytes by construction.
        let v = u32::from_le_bytes(self.block[self.offset..self.offset + 4].try_into().unwrap());
        self.offset += 4;
        v
    }

    /// Fill a u64 slice with uniform values (bulk path used by sharing).
    pub fn fill_u64(&mut self, out: &mut [u64]) {
        for v in out.iter_mut() {
            *v = self.next_u64();
        }
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) by rejection (unbiased).
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// RFC 7539 §2.3.2 test vector: key 00..1f, nonce 00 00 00 09 00 00 00 4a
    /// 00 00 00 00, counter = 1.
    #[test]
    fn rfc7539_block_vector() {
        let mut key = [0u8; 32];
        for (i, b) in key.iter_mut().enumerate() {
            *b = i as u8;
        }
        let nonce = [0, 0, 0, 9, 0, 0, 0, 0x4a, 0, 0, 0, 0];
        let mut c = ChaCha20::new(&key, &nonce);
        c.counter = 1; // vector uses counter 1
        let mut out = [0u8; 64];
        c.fill_bytes(&mut out);
        let expected: [u8; 64] = [
            0x10, 0xf1, 0xe7, 0xe4, 0xd1, 0x3b, 0x59, 0x15, 0x50, 0x0f, 0xdd, 0x1f, 0xa3, 0x20,
            0x71, 0xc4, 0xc7, 0xd1, 0xf4, 0xc7, 0x33, 0xc0, 0x68, 0x03, 0x04, 0x22, 0xaa, 0x9a,
            0xc3, 0xd4, 0x6c, 0x4e, 0xd2, 0x82, 0x64, 0x46, 0x07, 0x9f, 0xaa, 0x09, 0x14, 0xc2,
            0xd7, 0x05, 0xd9, 0x8b, 0x02, 0xa2, 0xb5, 0x12, 0x9c, 0xd1, 0xde, 0x16, 0x4e, 0xb9,
            0xcb, 0xd0, 0x83, 0xe8, 0xa2, 0x50, 0x3c, 0x4e,
        ];
        assert_eq!(out, expected);
    }

    #[test]
    fn deterministic_and_stream_separated() {
        let mut a = ChaCha20::from_seed(42, 0);
        let mut b = ChaCha20::from_seed(42, 0);
        let mut c = ChaCha20::from_seed(42, 1);
        let mut d = ChaCha20::from_seed(43, 0);
        let (va, vb, vc, vd) = (a.next_u64(), b.next_u64(), c.next_u64(), d.next_u64());
        assert_eq!(va, vb);
        assert_ne!(va, vc);
        assert_ne!(va, vd);
    }

    #[test]
    fn fill_bytes_across_block_boundaries() {
        let mut a = ChaCha20::from_seed(7, 7);
        let mut whole = vec![0u8; 200];
        a.fill_bytes(&mut whole);
        let mut b = ChaCha20::from_seed(7, 7);
        let mut parts = vec![0u8; 200];
        let (p1, rest) = parts.split_at_mut(13);
        let (p2, p3) = rest.split_at_mut(64);
        b.fill_bytes(p1);
        b.fill_bytes(p2);
        b.fill_bytes(p3);
        assert_eq!(whole, parts);
    }

    #[test]
    fn next_below_is_in_range_and_hits_all_residues() {
        let mut c = ChaCha20::from_seed(1, 2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = c.next_below(7);
            assert!(v < 7);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|s| *s));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut c = ChaCha20::from_seed(9, 9);
        for _ in 0..1000 {
            let f = c.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }
}
