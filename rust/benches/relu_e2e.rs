//! End-to-end ReLU layer benchmark across plan variants and backends —
//! the per-layer numbers behind Figs 1/7/8, plus the Rust-vs-XLA kernel
//! backend ablation (DESIGN.md §6 indexes where each figure's numbers
//! come from).

use hummingbird::crypto::prg::Prg;
use hummingbird::gmw::harness::{run_parties, run_parties_threaded, run_parties_with};
use hummingbird::gmw::ReluPlan;
use hummingbird::runtime::{Manifest, Runtime, XlaKernels};
use hummingbird::sharing::share_arith;
use hummingbird::util::benchkit::{bench_threads, Bench};

fn main() {
    let mut bench = Bench::new();
    let mut prg = Prg::new(2, 2);

    for n in [4096usize, 16384] {
        let x: Vec<u64> = (0..n).map(|_| prg.next_u64() % (1 << 16)).collect();
        let xs = share_arith(&mut prg, &x, 2);
        for (label, plan) in [
            ("baseline64", ReluPlan::BASELINE),
            ("eco18", ReluPlan::new(18, 0).unwrap()),
            ("hb8", ReluPlan::new(12, 4).unwrap()),
            ("hb6", ReluPlan::new(10, 4).unwrap()),
        ] {
            let xs = xs.clone();
            bench.bench_elems(&format!("relu/rust/{label}/{n}"), n as u64, || {
                let xs = xs.clone();
                run_parties(2, 8, move |p| {
                    let me = p.party();
                    p.relu(&xs[me], plan).unwrap()
                });
            });
        }
    }

    // Scale + threading: the arena/parallel-kernel/fused-bitpack hot path
    // at n = 65536 (perf target: >= 1.5x multi-threaded over t=1 here; the
    // small-n rows above all run single-threaded and must not regress).
    {
        let n = 65536usize;
        let threads = bench_threads();
        let x: Vec<u64> = (0..n).map(|_| prg.next_u64() % (1 << 16)).collect();
        let xs = share_arith(&mut prg, &x, 2);
        for (label, plan) in
            [("baseline64", ReluPlan::BASELINE), ("hb8", ReluPlan::new(12, 4).unwrap())]
        {
            for t in [1usize, threads] {
                // Borrow the shares (no per-iteration clone) so the t1-vs-tN
                // comparison measures the protocol, not a memcpy.
                bench.bench_elems(&format!("relu/rust/{label}/{n}/t{t}"), n as u64, || {
                    run_parties_threaded(2, 8, t, |p| {
                        let me = p.party();
                        p.relu(&xs[me], plan).unwrap()
                    });
                });
                if threads == 1 {
                    break; // single-core host: the rows would be identical
                }
            }
        }
    }

    // Steady-state serving shape: amortize party setup over several warm
    // `relu_into` rounds so the row reflects the pooled hot path (arena +
    // RecvBufs + transport payload pool all warm after round 1).
    {
        let n = 16384usize;
        let rounds = 4u64;
        let x: Vec<u64> = (0..n).map(|_| prg.next_u64() % (1 << 16)).collect();
        let xs = share_arith(&mut prg, &x, 2);
        let plan = ReluPlan::new(12, 4).unwrap();
        bench.bench_elems(&format!("relu/rust/hb8/{n}/warm{rounds}"), rounds * n as u64, || {
            run_parties(2, 8, |p| {
                let me = p.party();
                let mut out = vec![0u64; n];
                for _ in 0..rounds {
                    p.relu_into(&xs[me], plan, &mut out).unwrap();
                }
            });
        });
    }

    // Backend ablation: the same ReLU through the Pallas/PJRT kernels.
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if root.join("manifest.json").exists() {
        let n = 16384usize;
        let x: Vec<u64> = (0..n).map(|_| prg.next_u64() % (1 << 16)).collect();
        let xs = share_arith(&mut prg, &x, 2);
        let plan = ReluPlan::new(12, 4).unwrap();
        let root2 = root.clone();
        bench.bench_elems(&format!("relu/xla/hb8/{n}"), n as u64, || {
            let xs = xs.clone();
            let root3 = root2.clone();
            run_parties_with(
                2,
                8,
                move |_pid| {
                    let rt = Runtime::new(&root3).unwrap();
                    let manifest = Manifest::load(&root3).unwrap();
                    XlaKernels::new(rt, manifest)
                },
                move |p| {
                    let me = p.party();
                    p.relu(&xs[me], plan).unwrap()
                },
            );
        });
    } else {
        eprintln!("(skipping xla backend bench: run `make artifacts`)");
    }
    bench.dump_json("relu_e2e");
}
