//! Small statistics helpers shared by the bench harness, the search engine
//! and the figure generator (mean / median / stddev / geomean / percentiles).

/// Arithmetic mean. Returns 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator). 0.0 if fewer than 2 samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Geometric mean; all inputs must be positive (non-positive inputs are
/// skipped, matching how speedup geomeans are reported).
pub fn geomean(xs: &[f64]) -> f64 {
    let logs: Vec<f64> = xs.iter().filter(|x| **x > 0.0).map(|x| x.ln()).collect();
    if logs.is_empty() {
        return 0.0;
    }
    (logs.iter().sum::<f64>() / logs.len() as f64).exp()
}

/// Percentile via linear interpolation on the sorted copy, p in [0,100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Format a byte count human-readably (e.g. "1.50 MiB").
pub fn fmt_bytes(bytes: u64) -> String {
    const UNITS: &[&str] = &["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a duration in seconds human-readably (ns/µs/ms/s).
pub fn fmt_secs(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{secs:.2} s")
    } else {
        format!("{}m {:.0}s", (secs / 60.0) as u64, secs % 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - 1.2909944487).abs() < 1e-9);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn geomean_matches_hand_calc() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 0.0, 4.0]) - 2.0).abs() < 1e-12); // zero skipped
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert_eq!(percentile(&xs, 50.0), 25.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(1536), "1.50 KiB");
        assert_eq!(fmt_secs(0.0000005), "500.0 ns");
        assert_eq!(fmt_secs(0.25), "250.00 ms");
        assert_eq!(fmt_secs(150.0), "2m 30s");
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[1.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
