"""Model architecture definitions shared between the Python build path and
the Rust runtime.

A model is a flat DAG of nodes (JSON-serializable); both `model.py` (JAX) and
`rust/src/model/graph.rs` interpret the same schema:

    {"op": "input"}                                       node 0, always
    {"op": "conv", "in": [i], "out_ch": C, "k": K, "stride": S, "pad": P}
    {"op": "relu", "in": [i], "group": G}
    {"op": "add",  "in": [i, j]}
    {"op": "gap",  "in": [i]}            # global average pool
    {"op": "fc",   "in": [i], "out": N}  # flattens its input

ReLU `group` ids implement the paper's ReLU grouping (§4.1.2): all ReLUs in
a group share one (k, m) plan during search and inference. Following the
paper we use five groups for the ResNet-style models (stem + 4 stages).
"""

import json
import os

# (dataset -> (channels, height/width, num_classes))
DATASETS = {
    "synth10": (3, 16, 10),
    "synth100": (3, 16, 100),
    "synthtiny": (3, 24, 50),
}


def micronet(in_hw: int, num_classes: int) -> list:
    """4-conv plain CNN (quickstart-sized); 4 ReLU groups."""
    nodes = [{"op": "input"}]

    def conv(src, out_ch, stride=1):
        nodes.append({"op": "conv", "in": [src], "out_ch": out_ch, "k": 3,
                      "stride": stride, "pad": 1})
        return len(nodes) - 1

    def relu(src, group):
        nodes.append({"op": "relu", "in": [src], "group": group})
        return len(nodes) - 1

    x = conv(0, 8)
    x = relu(x, 0)
    x = conv(x, 16, stride=2)
    x = relu(x, 1)
    x = conv(x, 16)
    x = relu(x, 2)
    x = conv(x, 32, stride=2)
    x = relu(x, 3)
    nodes.append({"op": "gap", "in": [x]})
    nodes.append({"op": "fc", "in": [len(nodes) - 1], "out": num_classes})
    return nodes


def _resnet(in_hw: int, num_classes: int, stage_blocks, widths) -> list:
    """Basic-block ResNet, avg-pool downsampling on the skip path (the paper
    replaces max pooling with average pooling; our skips use stride-2 1x1
    convs like standard CIFAR ResNets). 5 ReLU groups: stem + one per stage.
    """
    nodes = [{"op": "input"}]

    def conv(src, out_ch, k=3, stride=1, pad=1):
        nodes.append({"op": "conv", "in": [src], "out_ch": out_ch, "k": k,
                      "stride": stride, "pad": pad})
        return len(nodes) - 1

    def relu(src, group):
        nodes.append({"op": "relu", "in": [src], "group": group})
        return len(nodes) - 1

    x = conv(0, widths[0])
    x = relu(x, 0)  # stem = group 0
    in_ch = widths[0]
    for stage, (blocks, width) in enumerate(zip(stage_blocks, widths)):
        group = min(stage + 1, 4)
        for b in range(blocks):
            stride = 2 if (b == 0 and stage > 0) else 1
            y = conv(x, width, stride=stride)
            y = relu(y, group)
            y = conv(y, width)
            if stride != 1 or in_ch != width:
                skip = conv(x, width, k=1, stride=stride, pad=0)
            else:
                skip = x
            nodes.append({"op": "add", "in": [y, skip]})
            x = relu(len(nodes) - 1, group)
            in_ch = width
    nodes.append({"op": "gap", "in": [x]})
    nodes.append({"op": "fc", "in": [len(nodes) - 1], "out": num_classes})
    return nodes


def miniresnet(in_hw: int, num_classes: int) -> list:
    """2-stage ResNet (ResNet18 stand-in for quick runs); 5 ReLU layers."""
    return _resnet(in_hw, num_classes, stage_blocks=[1, 1], widths=[16, 32])


def resnets18(in_hw: int, num_classes: int) -> list:
    """[2,2,2,2] basic-block ResNet (the paper's ResNet18 shape, width-scaled
    for our small synthetic inputs / single-core testbed); 17 ReLUs in 5
    groups."""
    return _resnet(in_hw, num_classes, stage_blocks=[2, 2, 2, 2],
                   widths=[8, 16, 32, 64])


MODELS = {
    "micronet": micronet,
    "miniresnet": miniresnet,
    "resnets18": resnets18,
}

# Model/dataset pairs mirroring the paper's 6 benchmark combinations
# (ResNet18/ResNet50 x CIFAR10/CIFAR100/TinyImageNet).
BENCHMARKS = [
    ("miniresnet", "synth10"),
    ("resnets18", "synth10"),
    ("miniresnet", "synth100"),
    ("resnets18", "synth100"),
    ("miniresnet", "synthtiny"),
    ("resnets18", "synthtiny"),
]

# Extra pair used by the quickstart and unit tests.
EXTRA = [("micronet", "synth10")]


def config_name(model: str, dataset: str) -> str:
    return f"{model}_{dataset}"


def build_config(model: str, dataset: str, batch: int = 4) -> dict:
    ch, hw, ncls = DATASETS[dataset]
    nodes = MODELS[model](hw, ncls)
    n_groups = 1 + max(n.get("group", 0) for n in nodes if n["op"] == "relu")
    return {
        "name": config_name(model, dataset),
        "model": model,
        "dataset": dataset,
        "input": [ch, hw, hw],
        "num_classes": ncls,
        "batch": batch,
        "frac_bits": 12,
        "relu_groups": n_groups,
        "nodes": nodes,
    }


def write_all_configs(out_dir: str) -> list:
    """Write every benchmark config; returns the list of paths."""
    os.makedirs(out_dir, exist_ok=True)
    paths = []
    for model, dataset in BENCHMARKS + EXTRA:
        cfg = build_config(model, dataset)
        path = os.path.join(out_dir, cfg["name"] + ".json")
        with open(path, "w") as f:
            json.dump(cfg, f, indent=1)
        paths.append(path)
    return paths


if __name__ == "__main__":
    import sys
    out = sys.argv[1] if len(sys.argv) > 1 else "../configs/models"
    for p in write_all_configs(out):
        print("wrote", p)
