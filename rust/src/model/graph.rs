//! Model graph: the Rust-side interpreter of the shared config schema
//! (`configs/models/*.json`, produced by `python/compile/archs.py`).

use std::path::Path;

use crate::error::{Error, Result};
use crate::util::json::{self, Json};

/// One graph node.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    Input,
    Conv { src: usize, out_ch: usize, k: usize, stride: usize, pad: usize },
    Relu { src: usize, group: usize },
    Add { a: usize, b: usize },
    /// Global average pool.
    Gap { src: usize },
    Fc { src: usize, out: usize },
}

/// Parsed model configuration.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub model: String,
    pub dataset: String,
    /// Input (C, H, W).
    pub input: (usize, usize, usize),
    pub num_classes: usize,
    pub batch: usize,
    pub frac_bits: u32,
    pub relu_groups: usize,
    pub nodes: Vec<Op>,
}

impl ModelConfig {
    pub fn load(path: impl AsRef<Path>) -> Result<ModelConfig> {
        Self::from_json(&json::parse_file(path)?)
    }

    /// Load `configs/models/<name>.json` relative to a repo root.
    pub fn load_named(root: impl AsRef<Path>, name: &str) -> Result<ModelConfig> {
        Self::load(root.as_ref().join("configs/models").join(format!("{name}.json")))
    }

    pub fn from_json(j: &Json) -> Result<ModelConfig> {
        let input = j.get("input")?.as_arr()?;
        if input.len() != 3 {
            return Err(Error::config("input must be [C,H,W]"));
        }
        let mut nodes = Vec::new();
        for (i, n) in j.get("nodes")?.as_arr()?.iter().enumerate() {
            let op = n.get_str("op")?;
            let src = |key: &str, at: usize| -> Result<usize> {
                let arr = n.get("in")?.as_arr()?;
                arr.get(at)
                    .ok_or_else(|| Error::config(format!("node {i}: missing input {at}")))?
                    .as_usize()
                    .and_then(|s| {
                        if s >= i {
                            Err(Error::config(format!("node {i}: forward ref {s}")))
                        } else {
                            Ok(s)
                        }
                    })
                    .map_err(|e| Error::config(format!("node {i} {key}: {e}")))
            };
            nodes.push(match op {
                "input" => Op::Input,
                "conv" => Op::Conv {
                    src: src("in", 0)?,
                    out_ch: n.get_usize("out_ch")?,
                    k: n.get_usize("k")?,
                    stride: n.get_usize("stride")?,
                    pad: n.get_usize("pad")?,
                },
                "relu" => Op::Relu { src: src("in", 0)?, group: n.get_usize("group")? },
                "add" => Op::Add { a: src("in", 0)?, b: src("in", 1)? },
                "gap" => Op::Gap { src: src("in", 0)? },
                "fc" => Op::Fc { src: src("in", 0)?, out: n.get_usize("out")? },
                other => return Err(Error::config(format!("node {i}: unknown op {other}"))),
            });
        }
        if nodes.first() != Some(&Op::Input) {
            return Err(Error::config("node 0 must be input"));
        }
        Ok(ModelConfig {
            name: j.get_str("name")?.to_string(),
            model: j.get_str("model")?.to_string(),
            dataset: j.get_str("dataset")?.to_string(),
            input: (input[0].as_usize()?, input[1].as_usize()?, input[2].as_usize()?),
            num_classes: j.get_usize("num_classes")?,
            batch: j.get_usize("batch")?,
            frac_bits: j.get_usize("frac_bits")? as u32,
            relu_groups: j.get_usize("relu_groups")?,
            nodes,
        })
    }

    /// Static per-node shapes (channels-first; fc/gap produce flat dims).
    pub fn shapes(&self) -> Vec<Vec<usize>> {
        let mut shapes: Vec<Vec<usize>> = Vec::with_capacity(self.nodes.len());
        for node in &self.nodes {
            let s = match node {
                Op::Input => vec![self.input.0, self.input.1, self.input.2],
                Op::Conv { src, out_ch, k, stride, pad } => {
                    let s = &shapes[*src];
                    let ho = (s[1] + 2 * pad - k) / stride + 1;
                    let wo = (s[2] + 2 * pad - k) / stride + 1;
                    vec![*out_ch, ho, wo]
                }
                Op::Relu { src, .. } | Op::Gap { src } => match &self.nodes[*src] {
                    _ => {
                        if matches!(node, Op::Gap { .. }) {
                            vec![shapes[*src][0]]
                        } else {
                            shapes[*src].clone()
                        }
                    }
                },
                Op::Add { a, .. } => shapes[*a].clone(),
                Op::Fc { out, .. } => vec![*out],
            };
            shapes.push(s);
        }
        shapes
    }

    /// Element count per ReLU node (used by budget accounting), keyed by
    /// node index, for one sample (no batch dim).
    pub fn relu_elems(&self) -> Vec<(usize, usize, usize)> {
        let shapes = self.shapes();
        self.nodes
            .iter()
            .enumerate()
            .filter_map(|(i, n)| match n {
                Op::Relu { group, .. } => {
                    Some((i, *group, shapes[i].iter().product::<usize>()))
                }
                _ => None,
            })
            .collect()
    }

    /// Number of ReLU nodes.
    pub fn num_relus(&self) -> usize {
        self.nodes.iter().filter(|n| matches!(n, Op::Relu { .. })).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelConfig {
        let j = json::parse(
            r#"{
          "name":"t","model":"t","dataset":"d","input":[3,8,8],
          "num_classes":4,"batch":2,"frac_bits":12,"relu_groups":2,
          "nodes":[
            {"op":"input"},
            {"op":"conv","in":[0],"out_ch":4,"k":3,"stride":1,"pad":1},
            {"op":"relu","in":[1],"group":0},
            {"op":"conv","in":[2],"out_ch":8,"k":3,"stride":2,"pad":1},
            {"op":"relu","in":[3],"group":1},
            {"op":"add","in":[4,4]},
            {"op":"gap","in":[5]},
            {"op":"fc","in":[6],"out":4}
          ]}"#,
        )
        .unwrap();
        ModelConfig::from_json(&j).unwrap()
    }

    #[test]
    fn parses_and_shapes() {
        let cfg = tiny_cfg();
        let shapes = cfg.shapes();
        assert_eq!(shapes[1], vec![4, 8, 8]);
        assert_eq!(shapes[3], vec![8, 4, 4]);
        assert_eq!(shapes[6], vec![8]);
        assert_eq!(shapes[7], vec![4]);
        assert_eq!(cfg.num_relus(), 2);
        let relus = cfg.relu_elems();
        assert_eq!(relus, vec![(2, 0, 4 * 8 * 8), (4, 1, 8 * 4 * 4)]);
    }

    #[test]
    fn rejects_bad_graphs() {
        let j = json::parse(
            r#"{"name":"t","model":"t","dataset":"d","input":[3,8,8],
                "num_classes":4,"batch":2,"frac_bits":12,"relu_groups":1,
                "nodes":[{"op":"input"},{"op":"relu","in":[5],"group":0}]}"#,
        )
        .unwrap();
        assert!(ModelConfig::from_json(&j).is_err()); // forward reference
    }
}
