//! # HummingBird
//!
//! A from-scratch reproduction of *"Approximating ReLU on a Reduced Ring for
//! Efficient MPC-based Private Inference"* (Maeng & Suh, 2023) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the MPC coordinator: secret sharing, the GMW
//!   protocol engine, HummingBird's reduced-ring approximate ReLU, the
//!   bitpacked wire format, Beaver-triple provisioning, network transports
//!   with exact byte/round accounting, the offline (k, m) search engine and a
//!   batching inference server.
//! * **Layer 2** — JAX per-layer compute graphs (`python/compile/model.py`),
//!   AOT-lowered to HLO text and executed through [`runtime`] (PJRT CPU).
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`) for the share
//!   matmul and the circuit-adder stage primitives, validated against
//!   pure-jnp oracles at build time.
//!
//! See `DESIGN.md` for the complete system inventory and the experiment
//! index, and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod error;

pub mod util {
    pub mod arena;
    pub mod benchkit;
    pub mod cli;
    pub mod json;
    pub mod stats;
    pub mod threadpool;
    pub mod tuning;
}

pub mod crypto {
    pub mod chacha;
    pub mod prg;
}

pub mod analysis;
pub mod beaver;
pub mod bitpack;
pub mod coordinator;
pub mod figures;
pub mod gmw;
pub mod hummingbird;
pub mod model;
pub mod net;
pub mod ring;
pub mod runtime;
pub mod sharing;
pub mod tensor;

pub use error::{Error, Result};
