//! Criterion-style micro/e2e benchmark harness (criterion is not available
//! offline). Used by the `[[bench]]` targets with `harness = false`.
//!
//! Features: warmup, adaptive iteration count targeting a measurement time,
//! mean/median/stddev/p95 reporting, throughput annotation, scalar side
//! metrics (e.g. triples-PRG byte counts), and machine-readable JSON output
//! so EXPERIMENTS.md numbers can be regenerated.
//!
//! The module also hosts the **trajectory comparison** logic behind the CI
//! perf gate (the `bench_diff` bin): [`diff_suite`] matches a run's
//! `BENCH_<suite>.json` rows against a committed baseline by row name and
//! flags median regressions beyond a threshold; [`markdown_suite_table`]
//! and [`markdown_layout_table`] render the result for
//! `$GITHUB_STEP_SUMMARY`, including the lane-vs-bitsliced layout ratios
//! and the plane-native-triples PRG savings when the suite carries them.
//! Baselines marked `"bootstrap": true` (or missing) are reported but
//! never gate — that is how the repo bootstraps before the first
//! toolchain-equipped bench run lands real numbers.

use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats;

/// One benchmark's collected samples and metadata.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Seconds per iteration, one entry per sample.
    pub samples: Vec<f64>,
    /// Optional elements-processed-per-iteration for throughput reporting.
    pub throughput_elems: Option<u64>,
    /// Optional bytes-processed-per-iteration for throughput reporting.
    pub throughput_bytes: Option<u64>,
}

impl BenchResult {
    pub fn mean(&self) -> f64 {
        stats::mean(&self.samples)
    }
    pub fn median(&self) -> f64 {
        stats::median(&self.samples)
    }
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::str(self.name.clone())),
            ("mean_s", Json::Num(self.mean())),
            ("median_s", Json::Num(self.median())),
            ("stddev_s", Json::Num(stats::stddev(&self.samples))),
            ("p95_s", Json::Num(stats::percentile(&self.samples, 95.0))),
            ("samples", Json::Int(self.samples.len() as i64)),
        ];
        if let Some(e) = self.throughput_elems {
            pairs.push(("elems_per_s", Json::Num(e as f64 / self.mean())));
        }
        if let Some(b) = self.throughput_bytes {
            pairs.push(("bytes_per_s", Json::Num(b as f64 / self.mean())));
        }
        Json::obj(pairs)
    }
}

/// Benchmark runner: collects results, prints a criterion-like report and
/// optionally dumps JSON (for EXPERIMENTS.md regeneration).
pub struct Bench {
    /// Target total measurement time per benchmark.
    pub measure_time: Duration,
    /// Warmup time per benchmark.
    pub warmup_time: Duration,
    /// Number of samples to split the measurement into.
    pub sample_count: usize,
    results: Vec<BenchResult>,
    /// Named scalar side metrics (deterministic quantities a suite wants in
    /// its trajectory file next to the timing rows — byte counts, ratios).
    metrics: Vec<(String, f64)>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        // Honor a quick mode for CI-ish runs: HB_BENCH_QUICK=1.
        let quick = std::env::var("HB_BENCH_QUICK").ok().as_deref() == Some("1");
        Bench {
            measure_time: if quick { Duration::from_millis(300) } else { Duration::from_secs(2) },
            warmup_time: if quick {
                Duration::from_millis(100)
            } else {
                Duration::from_millis(500)
            },
            sample_count: if quick { 10 } else { 30 },
            results: Vec::new(),
            metrics: Vec::new(),
        }
    }

    /// Record a named scalar metric into the suite's trajectory file
    /// (`metrics` object in `BENCH_<suite>.json`). Deterministic values
    /// only — the perf gate treats timing rows statistically but prints
    /// metrics verbatim (e.g. `triples/prg_bytes/w6`).
    pub fn note_metric(&mut self, name: &str, value: f64) {
        println!("{name:<44} metric: {value}");
        self.metrics.push((name.to_string(), value));
    }

    /// Run one benchmark. `f` is invoked `iters` times per sample; the
    /// per-iteration time is recorded.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.bench_annotated(name, None, None, &mut f)
    }

    /// Benchmark with elements-per-iteration throughput annotation.
    pub fn bench_elems<F: FnMut()>(&mut self, name: &str, elems: u64, mut f: F) -> &BenchResult {
        self.bench_annotated(name, Some(elems), None, &mut f)
    }

    /// Benchmark with bytes-per-iteration throughput annotation.
    pub fn bench_bytes<F: FnMut()>(&mut self, name: &str, bytes: u64, mut f: F) -> &BenchResult {
        self.bench_annotated(name, None, Some(bytes), &mut f)
    }

    fn bench_annotated(
        &mut self,
        name: &str,
        elems: Option<u64>,
        bytes: Option<u64>,
        f: &mut dyn FnMut(),
    ) -> &BenchResult {
        // Warmup and calibration: find iters/sample so one sample is
        // measure_time / sample_count.
        let warmup_end = Instant::now() + self.warmup_time;
        let mut calib_iters: u64 = 0;
        let calib_start = Instant::now();
        while Instant::now() < warmup_end {
            f();
            calib_iters += 1;
        }
        let per_iter = calib_start.elapsed().as_secs_f64() / calib_iters.max(1) as f64;
        let per_sample = self.measure_time.as_secs_f64() / self.sample_count as f64;
        let iters = ((per_sample / per_iter.max(1e-9)).ceil() as u64).max(1);

        let mut samples = Vec::with_capacity(self.sample_count);
        for _ in 0..self.sample_count {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(t0.elapsed().as_secs_f64() / iters as f64);
        }

        let result = BenchResult {
            name: name.to_string(),
            samples,
            throughput_elems: elems,
            throughput_bytes: bytes,
        };
        Self::print_result(&result);
        self.results.push(result);
        // LINT-ALLOW: unwrap — non-empty: pushed on the line above.
        self.results.last().unwrap()
    }

    fn print_result(r: &BenchResult) {
        let mut line = format!(
            "{:<44} time: [{} {} {}]",
            r.name,
            stats::fmt_secs(stats::percentile(&r.samples, 5.0)),
            stats::fmt_secs(r.median()),
            stats::fmt_secs(stats::percentile(&r.samples, 95.0)),
        );
        if let Some(e) = r.throughput_elems {
            line.push_str(&format!("  thrpt: {:.3e} elem/s", e as f64 / r.mean()));
        }
        if let Some(b) = r.throughput_bytes {
            let per_s = stats::fmt_bytes((b as f64 / r.mean()) as u64);
            line.push_str(&format!("  thrpt: {per_s}/s"));
        }
        println!("{line}");
    }

    /// Write all collected results as JSON: the historical per-run dump at
    /// `target/bench-results/<suite>.json`, plus the machine-readable
    /// trajectory file `BENCH_<suite>.json` at the repository root so PRs
    /// can commit before/after numbers and future sessions can diff them.
    pub fn dump_json(&self, suite: &str) {
        let results = Json::arr(self.results.iter().map(|r| r.to_json()));

        let dir = std::path::Path::new("target/bench-results");
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(format!("{suite}.json"));
        if std::fs::write(&path, results.to_string_pretty()).is_ok() {
            println!("(results written to {})", path.display());
        }

        // Trajectory file: results wrapped with enough environment metadata
        // to compare runs across machines and PRs. Destination resolves at
        // run time (HB_BENCH_DIR override, then the build-time repo root if
        // it still exists, then cwd) so a relocated binary still lands the
        // file somewhere visible — and failures are reported, not dropped.
        let metrics =
            Json::obj(self.metrics.iter().map(|(k, v)| (k.as_str(), Json::Num(*v))).collect());
        let doc = Json::obj(vec![
            ("suite", Json::str(suite)),
            ("quick", Json::Bool(std::env::var("HB_BENCH_QUICK").ok().as_deref() == Some("1"))),
            ("host_threads", Json::Int(crate::util::threadpool::default_threads() as i64)),
            ("sample_count", Json::Int(self.sample_count as i64)),
            ("metrics", metrics),
            ("results", results),
        ]);
        let root = std::env::var_os("HB_BENCH_DIR")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(|| {
                let manifest_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
                let repo = manifest_dir.parent().unwrap_or(manifest_dir);
                if repo.is_dir() {
                    repo.to_path_buf()
                } else {
                    std::path::PathBuf::from(".")
                }
            });
        let bench_path = root.join(format!("BENCH_{suite}.json"));
        match std::fs::write(&bench_path, doc.to_string_pretty()) {
            Ok(()) => println!("(trajectory written to {})", bench_path.display()),
            Err(e) => eprintln!("warning: could not write {}: {e}", bench_path.display()),
        }
    }
}

// ---------------------------------------------------------------------------
// Trajectory comparison — the CI perf gate (driven by `bin/bench_diff`).
// ---------------------------------------------------------------------------

/// One timing row matched by name across a baseline and a current
/// `BENCH_<suite>.json`.
#[derive(Debug, Clone)]
pub struct RowDiff {
    pub name: String,
    pub baseline_median_s: f64,
    pub current_median_s: f64,
}

impl RowDiff {
    /// current / baseline — above 1.0 means slower than the baseline.
    pub fn ratio(&self) -> f64 {
        self.current_median_s / self.baseline_median_s
    }
}

/// Comparison of one suite's trajectory file against its committed
/// baseline.
#[derive(Debug, Clone)]
pub struct SuiteDiff {
    pub suite: String,
    /// True when the baseline is absent or flagged `"bootstrap": true`:
    /// the diff is reported but never gates. This is how the repo
    /// bootstraps — commit placeholder baselines first, replace them with
    /// a real bench-smoke artifact when one exists.
    pub bootstrap: bool,
    pub rows: Vec<RowDiff>,
    /// Row names present on only one side (renames/additions — surfaced
    /// in the report, never gated).
    pub only_in_baseline: Vec<String>,
    pub only_in_current: Vec<String>,
}

impl SuiteDiff {
    /// Rows whose median regressed beyond `threshold` (0.25 = +25%).
    /// Empty for bootstrap baselines.
    pub fn regressions(&self, threshold: f64) -> Vec<&RowDiff> {
        if self.bootstrap {
            return Vec::new();
        }
        self.rows.iter().filter(|r| r.ratio() > 1.0 + threshold).collect()
    }
}

/// Extract `(name, median_s)` pairs from a trajectory document, skipping
/// malformed rows (the gate must degrade to "no match", not panic, on a
/// hand-edited baseline).
fn medians_by_name(doc: &Json) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    let Some(results) = doc.opt("results") else { return out };
    let Ok(rows) = results.as_arr() else { return out };
    for r in rows {
        if let (Ok(name), Ok(median)) = (r.get_str("name"), r.get_f64("median_s")) {
            out.push((name.to_string(), median));
        }
    }
    out
}

/// Match `current` (a parsed `BENCH_<suite>.json`) against `baseline`
/// (same format, `None` = no committed baseline). Rows match by exact
/// name; rows with a non-positive baseline median are dropped (no
/// meaningful ratio).
pub fn diff_suite(suite: &str, baseline: Option<&Json>, current: &Json) -> SuiteDiff {
    let bootstrap = match baseline {
        None => true,
        Some(b) => b.opt("bootstrap").and_then(|v| v.as_bool().ok()).unwrap_or(false),
    };
    let base_rows = baseline.map(medians_by_name).unwrap_or_default();
    let cur_rows = medians_by_name(current);
    let mut rows = Vec::new();
    let mut only_in_current = Vec::new();
    for (name, cur) in &cur_rows {
        match base_rows.iter().find(|(b, _)| b == name) {
            Some((_, base)) if *base > 0.0 => rows.push(RowDiff {
                name: name.clone(),
                baseline_median_s: *base,
                current_median_s: *cur,
            }),
            Some(_) => {}
            None => only_in_current.push(name.clone()),
        }
    }
    let only_in_baseline = base_rows
        .iter()
        .filter(|(b, _)| !cur_rows.iter().any(|(c, _)| c == b))
        .map(|(b, _)| b.clone())
        .collect();
    SuiteDiff { suite: suite.to_string(), bootstrap, rows, only_in_baseline, only_in_current }
}

/// Render one suite's diff as a GitHub-flavoured markdown section (the CI
/// job appends these to `$GITHUB_STEP_SUMMARY`).
pub fn markdown_suite_table(d: &SuiteDiff, threshold: f64) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(out, "### `{}`", d.suite);
    if d.bootstrap {
        let _ = writeln!(
            out,
            "_bootstrap baseline — informational only, not gating; commit a real \
             bench-smoke artifact to arm the gate_\n"
        );
    }
    if d.rows.is_empty() {
        let _ = writeln!(out, "(no matched rows)\n");
    } else {
        let _ = writeln!(out, "| row | baseline | current | ratio | verdict |");
        let _ = writeln!(out, "|---|---:|---:|---:|---|");
        for r in &d.rows {
            let verdict = if d.bootstrap {
                "—"
            } else if r.ratio() > 1.0 + threshold {
                "**REGRESSED**"
            } else {
                "ok"
            };
            let _ = writeln!(
                out,
                "| `{}` | {} | {} | {:.2}× | {} |",
                r.name,
                crate::util::stats::fmt_secs(r.baseline_median_s),
                crate::util::stats::fmt_secs(r.current_median_s),
                r.ratio(),
                verdict
            );
        }
        let _ = writeln!(out);
    }
    if !d.only_in_current.is_empty() {
        let _ = writeln!(out, "new rows (no baseline): {}\n", d.only_in_current.join(", "));
    }
    if !d.only_in_baseline.is_empty() {
        let _ = writeln!(out, "rows missing vs baseline: {}\n", d.only_in_baseline.join(", "));
    }
    out
}

/// Render the lane-vs-bitsliced layout ratio table plus the plane-native
/// triples PRG table from a suite document that carries them (the
/// ablation suite). Returns `None` when the document has neither.
pub fn markdown_layout_table(doc: &Json) -> Option<String> {
    use std::fmt::Write as _;
    let rows = medians_by_name(doc);
    let mut out = String::new();
    let mut pairs = Vec::new();
    for (name, lane_median) in &rows {
        if let Some(rest) = name.find("/lane/").map(|i| (i, &name[i + 6..])) {
            let sliced_name = format!("{}/bitsliced/{}", &name[..rest.0], rest.1);
            if let Some((_, sliced_median)) = rows.iter().find(|(n, _)| *n == sliced_name) {
                pairs.push((name.clone(), *lane_median, *sliced_median));
            }
        }
    }
    if !pairs.is_empty() {
        let _ = writeln!(out, "#### lane vs bitsliced (median speedup of bitsliced)");
        let _ = writeln!(out, "| row (lane form) | lane | bitsliced | lane/bitsliced |");
        let _ = writeln!(out, "|---|---:|---:|---:|");
        for (name, lane, sliced) in &pairs {
            let _ = writeln!(
                out,
                "| `{}` | {} | {} | {:.2}× |",
                name,
                crate::util::stats::fmt_secs(*lane),
                crate::util::stats::fmt_secs(*sliced),
                lane / sliced.max(1e-12)
            );
        }
        let _ = writeln!(out);
    }
    // Plane-native triple stream: PRG material vs the legacy lane-form
    // stream (one word per AND lane), per window label.
    if let Some(metrics) = doc.opt("metrics").and_then(|m| m.as_obj().ok()) {
        let mut trows = Vec::new();
        for (k, v) in metrics {
            if let Some(label) = k.strip_prefix("triples/plane_words/") {
                let plane = v.as_f64().unwrap_or(0.0);
                let lanes = metrics
                    .get(&format!("triples/lane_words_equiv/{label}"))
                    .and_then(|j| j.as_f64().ok())
                    .unwrap_or(0.0);
                if plane > 0.0 && lanes > 0.0 {
                    trows.push((label.to_string(), plane, lanes));
                }
            }
        }
        if !trows.is_empty() {
            let _ = writeln!(out, "#### Beaver triple PRG material (plane-native stream)");
            let _ = writeln!(out, "| window | plane words | lane-form words | plane/lane |");
            let _ = writeln!(out, "|---|---:|---:|---:|");
            for (label, plane, lanes) in &trows {
                let _ =
                    writeln!(out, "| {label} | {plane} | {lanes} | {:.3} |", plane / lanes);
            }
            let _ = writeln!(out);
        }
    }
    if out.is_empty() {
        None
    } else {
        Some(out)
    }
}

/// Shared `HB_THREADS` knob for the multi-threaded bench rows (default:
/// all cores). One definition so every suite's committed trajectory rows
/// stay consistent.
pub fn bench_threads() -> usize {
    std::env::var("HB_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|t| *t >= 1)
        .unwrap_or_else(crate::util::threadpool::default_threads)
}

/// Prevent the optimizer from eliding a computed value (stable-rust
/// equivalent of `std::hint::black_box`, which is stable since 1.66 —
/// re-exported here for a single import site).
pub use std::hint::black_box;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples_and_reports() {
        let mut b = Bench {
            measure_time: Duration::from_millis(20),
            warmup_time: Duration::from_millis(5),
            sample_count: 5,
            results: Vec::new(),
            metrics: Vec::new(),
        };
        let mut acc = 0u64;
        let r = b.bench_elems("noop", 1, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert_eq!(r.samples.len(), 5);
        assert!(r.mean() > 0.0);
        let j = r.to_json();
        assert!(j.get_f64("mean_s").unwrap() > 0.0);
    }

    fn doc(rows: &[(&str, f64)], bootstrap: bool) -> Json {
        let mut src = String::from("{");
        if bootstrap {
            src.push_str("\"bootstrap\": true,");
        }
        src.push_str("\"results\":[");
        for (i, (name, median)) in rows.iter().enumerate() {
            if i > 0 {
                src.push(',');
            }
            src.push_str(&format!("{{\"name\":\"{name}\",\"median_s\":{median}}}"));
        }
        src.push_str("]}");
        crate::util::json::parse(&src).unwrap()
    }

    /// The perf gate's core decision: >threshold median growth on a
    /// name-matched row is a regression; faster/equal rows and unmatched
    /// rows are not.
    #[test]
    fn diff_flags_regressions_beyond_threshold() {
        let base = doc(&[("a/1", 1.0), ("b/1", 1.0), ("c/1", 1.0), ("gone", 1.0)], false);
        let cur = doc(&[("a/1", 1.20), ("b/1", 1.30), ("c/1", 0.5), ("new", 9.0)], false);
        let d = diff_suite("micro", Some(&base), &cur);
        assert!(!d.bootstrap);
        assert_eq!(d.rows.len(), 3);
        let regs = d.regressions(0.25);
        assert_eq!(regs.len(), 1, "only the +30% row regresses at 25%");
        assert_eq!(regs[0].name, "b/1");
        assert_eq!(d.only_in_current, vec!["new".to_string()]);
        assert_eq!(d.only_in_baseline, vec!["gone".to_string()]);
        // Exactly-at-threshold is not a regression (strictly greater) —
        // pinned with exactly-representable values (2.5/2.0 = 1.25).
        let base = doc(&[("edge", 2.0)], false);
        let cur = doc(&[("edge", 2.5)], false);
        let d = diff_suite("micro", Some(&base), &cur);
        assert!(d.regressions(0.25).is_empty());
        assert_eq!(d.regressions(0.2).len(), 1);
    }

    /// Bootstrap (or absent) baselines report but never gate.
    #[test]
    fn diff_bootstrap_baselines_never_gate() {
        let base = doc(&[("a/1", 0.0001)], true);
        let cur = doc(&[("a/1", 99.0)], false);
        let d = diff_suite("micro", Some(&base), &cur);
        assert!(d.bootstrap);
        assert!(d.regressions(0.25).is_empty());
        let d = diff_suite("micro", None, &cur);
        assert!(d.bootstrap && d.rows.is_empty());
        // Malformed baselines degrade to "no match", not a panic.
        let junk = crate::util::json::parse("{\"results\": \"oops\"}").unwrap();
        let d = diff_suite("micro", Some(&junk), &cur);
        assert!(d.rows.is_empty());
        assert_eq!(d.only_in_current.len(), 1);
    }

    /// The markdown report carries the verdicts and the layout/PRG ratio
    /// tables the bench-smoke job posts to the step summary.
    #[test]
    fn markdown_report_renders_verdicts_and_ratio_tables() {
        let base = doc(&[("x", 1.0)], false);
        let cur = doc(&[("x", 2.0)], false);
        let d = diff_suite("micro", Some(&base), &cur);
        let md = markdown_suite_table(&d, 0.25);
        assert!(md.contains("REGRESSED"), "{md}");
        assert!(md.contains("2.00×"), "{md}");

        let abl = crate::util::json::parse(
            r#"{
              "metrics": {
                "triples/plane_words/w6": 1536.0,
                "triples/lane_words_equiv/w6": 16384.0
              },
              "results": [
                {"name": "drelu_layout/lane/w6/16384/t1", "median_s": 0.010},
                {"name": "drelu_layout/bitsliced/w6/16384/t1", "median_s": 0.004}
              ]
            }"#,
        )
        .unwrap();
        let md = markdown_layout_table(&abl).expect("layout table");
        assert!(md.contains("2.50×"), "{md}");
        assert!(md.contains("0.094"), "plane/lane ratio 1536/16384: {md}");
        // A doc with neither pairs nor metrics yields no table.
        assert!(markdown_layout_table(&doc(&[("plain", 1.0)], false)).is_none());
    }
}
