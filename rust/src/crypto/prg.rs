//! PRG abstraction over the ChaCha20 core, plus Gaussian sampling and
//! OS-entropy seeding for session setup.

use super::chacha::ChaCha20;

/// Pseudo-random generator handle. Cheap to clone (clones the stream state).
#[derive(Clone)]
pub struct Prg {
    core: ChaCha20,
    /// Cached second Box-Muller output.
    gauss_spare: Option<f64>,
    /// u64 words handed out via [`Prg::next_u64`] / [`Prg::fill_u64`] —
    /// the units the Beaver dealer draws in. Lets consumers (e.g.
    /// `beaver::TripleUsage`) report exactly how much PRG material a
    /// protocol expanded, which is the quantity the plane-native triple
    /// stream shrinks by ~w/64.
    drawn_u64s: u64,
}

impl Prg {
    /// Deterministic PRG from (seed, stream). Parties derive pairwise PRGs
    /// as `Prg::new(shared_seed, stream_id)` so both ends generate identical
    /// masks without communication.
    pub fn new(seed: u64, stream: u64) -> Self {
        Prg { core: ChaCha20::from_seed(seed, stream), gauss_spare: None, drawn_u64s: 0 }
    }

    /// Seed from OS entropy (`/dev/urandom`); falls back to a time-derived
    /// seed if unavailable (tests / exotic sandboxes).
    pub fn from_entropy() -> Self {
        let seed = os_entropy_u64().unwrap_or_else(|| {
            let t = std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_nanos() as u64)
                .unwrap_or(0x5eed);
            t ^ (std::process::id() as u64).rotate_left(32)
        });
        Prg::new(seed, 0)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.drawn_u64s += 1;
        self.core.next_u64()
    }
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        self.core.next_u32()
    }
    pub fn fill_u64(&mut self, out: &mut [u64]) {
        self.drawn_u64s += out.len() as u64;
        self.core.fill_u64(out)
    }

    /// Total u64 words drawn through [`Prg::next_u64`] / [`Prg::fill_u64`]
    /// since construction (clones inherit the count of their source).
    pub fn u64s_drawn(&self) -> u64 {
        self.drawn_u64s
    }
    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        self.core.fill_bytes(out)
    }
    pub fn next_f64(&mut self) -> f64 {
        self.core.next_f64()
    }
    pub fn next_below(&mut self, n: u64) -> u64 {
        self.core.next_below(n)
    }

    /// Uniform vector of `n` ring elements.
    pub fn vec_u64(&mut self, n: usize) -> Vec<u64> {
        let mut v = vec![0u64; n];
        self.fill_u64(&mut v);
        v
    }

    /// Random bit vector packed one bit per u64-lane LSB (used for daBits).
    pub fn vec_bits(&mut self, n: usize) -> Vec<u64> {
        (0..n).map(|_| self.next_u64() & 1).collect()
    }

    /// Standard normal via Box-Muller (used by synthetic data generation).
    pub fn next_gauss(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }
}

fn os_entropy_u64() -> Option<u64> {
    use std::io::Read;
    let mut f = std::fs::File::open("/dev/urandom").ok()?;
    let mut buf = [0u8; 8];
    f.read_exact(&mut buf).ok()?;
    Some(u64::from_le_bytes(buf))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Prg::new(5, 1);
        let mut b = Prg::new(5, 1);
        assert_eq!(a.vec_u64(16), b.vec_u64(16));
    }

    #[test]
    fn entropy_seeds_differ() {
        let mut a = Prg::from_entropy();
        let mut b = Prg::from_entropy();
        // Overwhelmingly likely to differ.
        assert_ne!(a.vec_u64(4), b.vec_u64(4));
    }

    #[test]
    fn bits_are_bits() {
        let mut p = Prg::new(3, 3);
        let bits = p.vec_bits(256);
        assert!(bits.iter().all(|b| *b <= 1));
        let ones: u64 = bits.iter().sum();
        assert!(ones > 64 && ones < 192, "suspicious bit balance: {ones}");
    }

    #[test]
    fn draw_counter_tracks_u64_words() {
        let mut p = Prg::new(4, 4);
        assert_eq!(p.u64s_drawn(), 0);
        p.next_u64();
        let mut buf = [0u64; 7];
        p.fill_u64(&mut buf);
        assert_eq!(p.u64s_drawn(), 8);
        // Clones carry the count forward independently.
        let mut q = p.clone();
        q.next_u64();
        assert_eq!(q.u64s_drawn(), 9);
        assert_eq!(p.u64s_drawn(), 8);
    }

    #[test]
    fn gauss_moments_roughly_standard() {
        let mut p = Prg::new(11, 0);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| p.next_gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
