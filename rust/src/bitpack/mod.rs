//! Bitpacking wire library (paper §4.2).
//!
//! HummingBird's online phase "efficiently packs and unpacks the subset of
//! bits into a 64-bit tensor before and after each communication". This
//! module is that library: `n` lanes of `w`-bit values (stored one value per
//! u64, low bits) are packed into `ceil(n*w/64)` dense u64 words for the
//! wire, and unpacked on receipt. This is the hot path of every AND-gate
//! opening in the reduced-ring circuit adder and of the 1-bit B2A openings,
//! so it has a carefully optimized implementation plus a naive reference
//! used by tests.

/// Number of u64 words needed to pack `n` lanes of `w` bits.
#[inline]
pub fn packed_len(n: usize, w: u32) -> usize {
    ((n as u64 * w as u64).div_ceil(64)) as usize
}

/// Exact number of *bytes* on the wire for `n` lanes of `w` bits.
///
/// Byte-granular (not word-granular) so communication accounting matches
/// the paper's "bits communicated" model as closely as possible.
#[inline]
pub fn packed_bytes(n: usize, w: u32) -> u64 {
    (n as u64 * w as u64).div_ceil(8)
}

/// Pack `src` (one w-bit value per u64 lane, low bits; high bits MUST be
/// zero) into dense u64 words, little-endian bit order.
pub fn pack(src: &[u64], w: u32, dst: &mut Vec<u64>) {
    debug_assert!(w >= 1 && w <= 64);
    dst.clear();
    dst.resize(packed_len(src.len(), w), 0);
    if w == 64 {
        dst.copy_from_slice(src);
        return;
    }
    let mut acc: u64 = 0; // bits accumulated, LSB-first
    let mut nbits: u32 = 0; // how many bits of acc are valid
    let mut out = 0usize;
    for &v in src {
        debug_assert_eq!(v >> w, 0, "lane has bits above width {w}");
        acc |= v << nbits;
        let take = 64 - nbits;
        if w >= take {
            // acc is full: flush and keep the remainder of v.
            dst[out] = acc;
            out += 1;
            acc = if take == 64 { 0 } else { v >> take };
            nbits = w - take;
        } else {
            nbits += w;
        }
    }
    if nbits > 0 {
        dst[out] = acc;
    }
}

/// Unpack `n` lanes of `w`-bit values from dense words (inverse of [`pack`]).
pub fn unpack(src: &[u64], w: u32, n: usize, dst: &mut Vec<u64>) {
    debug_assert!(w >= 1 && w <= 64);
    debug_assert!(src.len() >= packed_len(n, w), "packed buffer too short");
    dst.clear();
    dst.resize(n, 0);
    if w == 64 {
        dst.copy_from_slice(&src[..n]);
        return;
    }
    let mask = if w == 64 { u64::MAX } else { (1u64 << w) - 1 };
    let needed = packed_len(n, w);
    assert!(src.len() >= needed);
    let mut word = 0usize;
    let mut bit: u32 = 0;
    for d in dst.iter_mut() {
        let avail = 64 - bit;
        // SAFETY: `word` stays < needed <= src.len(); the straddle read at
        // word+1 only happens while bits remain, i.e. word+1 < needed.
        let cur = unsafe { *src.get_unchecked(word) };
        let lo = cur >> bit;
        let v = if w <= avail {
            lo & mask
        } else {
            let next = unsafe { *src.get_unchecked(word + 1) };
            (lo | (next << avail)) & mask
        };
        *d = v;
        bit += w;
        if bit >= 64 {
            bit -= 64;
            word += 1;
        }
    }
}

/// Pack directly to a byte buffer (the wire format). Trailing partial byte
/// is zero-padded.
pub fn pack_bytes(src: &[u64], w: u32) -> Vec<u8> {
    let mut words = Vec::new();
    pack(src, w, &mut words);
    let nbytes = packed_bytes(src.len(), w) as usize;
    // Words are little-endian on the wire: a straight LE byte dump of the
    // word buffer, truncated to the exact byte count.
    let mut out = Vec::with_capacity(words.len() * 8);
    for wd in &words {
        out.extend_from_slice(&wd.to_le_bytes());
    }
    out.truncate(nbytes);
    out
}

/// Unpack from a byte buffer produced by [`pack_bytes`].
pub fn unpack_bytes(src: &[u8], w: u32, n: usize) -> Vec<u64> {
    let nwords = packed_len(n, w);
    let mut words = vec![0u64; nwords];
    for (i, &b) in src.iter().enumerate() {
        let word = i / 8;
        if word >= nwords {
            break;
        }
        words[word] |= (b as u64) << ((i % 8) * 8);
    }
    let mut out = Vec::new();
    unpack(&words, w, n, &mut out);
    out
}

/// Naive bit-at-a-time reference implementation (tests compare against it).
pub mod reference {
    use super::packed_len;

    pub fn pack_ref(src: &[u64], w: u32) -> Vec<u64> {
        let mut dst = vec![0u64; packed_len(src.len(), w)];
        let mut pos = 0u64;
        for &v in src {
            for b in 0..w {
                let bit = (v >> b) & 1;
                dst[(pos / 64) as usize] |= bit << (pos % 64);
                pos += 1;
            }
        }
        dst
    }

    pub fn unpack_ref(src: &[u64], w: u32, n: usize) -> Vec<u64> {
        let mut out = vec![0u64; n];
        let mut pos = 0u64;
        for v in out.iter_mut() {
            for b in 0..w {
                let bit = (src[(pos / 64) as usize] >> (pos % 64)) & 1;
                *v |= bit << b;
                pos += 1;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::prg::Prg;

    fn random_lanes(n: usize, w: u32, seed: u64) -> Vec<u64> {
        let mut prg = Prg::new(seed, w as u64);
        let mask = if w == 64 { u64::MAX } else { (1u64 << w) - 1 };
        (0..n).map(|_| prg.next_u64() & mask).collect()
    }

    #[test]
    fn roundtrip_all_widths() {
        for w in 1..=64u32 {
            for n in [0usize, 1, 7, 64, 129] {
                let src = random_lanes(n, w, 42);
                let mut packed = Vec::new();
                pack(&src, w, &mut packed);
                let mut back = Vec::new();
                unpack(&packed, w, n, &mut back);
                assert_eq!(src, back, "w={w} n={n}");
            }
        }
    }

    #[test]
    fn matches_reference() {
        for w in [1u32, 3, 5, 8, 13, 21, 31, 32, 33, 48, 63, 64] {
            let src = random_lanes(1000, w, 7);
            let mut fast = Vec::new();
            pack(&src, w, &mut fast);
            let slow = reference::pack_ref(&src, w);
            assert_eq!(fast, slow, "pack w={w}");
            let mut un = Vec::new();
            unpack(&fast, w, src.len(), &mut un);
            assert_eq!(un, reference::unpack_ref(&slow, w, src.len()), "unpack w={w}");
        }
    }

    #[test]
    fn byte_roundtrip_and_size() {
        for w in [1u32, 6, 12, 17, 64] {
            let src = random_lanes(333, w, 3);
            let bytes = pack_bytes(&src, w);
            assert_eq!(bytes.len() as u64, packed_bytes(333, w));
            let back = unpack_bytes(&bytes, w, 333);
            assert_eq!(src, back, "w={w}");
        }
    }

    #[test]
    fn density_is_optimal() {
        // 100 lanes of 6 bits = 600 bits = 10 words (not 100).
        assert_eq!(packed_len(100, 6), 10);
        assert_eq!(packed_bytes(100, 6), 75);
        assert_eq!(packed_len(0, 17), 0);
    }

    #[test]
    fn compression_ratio_vs_full_ring() {
        // The paper's 8/64 budget: packing must be exactly 8x denser.
        let n = 4096;
        assert_eq!(packed_bytes(n, 64) / packed_bytes(n, 8), 8);
    }
}
