//! Line-preserving source stripper for the [`analysis`](crate::analysis)
//! lint pass.
//!
//! The rules operate on two parallel per-line views of a Rust source file:
//!
//! * a **code view**, with every comment removed and every string/char
//!   literal replaced by an empty literal (`""` / `' '`), so token searches
//!   (`unsafe`, `.unwrap()`, `vec![`) never match inside prose or data;
//! * a **comment view**, holding only comment text, so annotation searches
//!   (`SAFETY:`, `HOT-PATH-ALLOW:`, `LINT-ALLOW:`) never match inside code.
//!
//! Both views keep the original line structure (multi-line strings and block
//! comments emit one entry per source line), so a finding's line number is
//! the real one. The stripper is a hand-rolled state machine in the spirit
//! of `util/json.rs` — no regex crate, no syn, no proc-macros — and handles
//! line comments, (nested) block comments, normal strings with escapes, raw
//! strings (`r"…"`, `r#"…"#`), and char literals vs. lifetime ticks.

/// A source file split into per-line code and comment views (same length,
/// one entry per source line — see the module docs).
#[derive(Debug)]
pub struct Stripped {
    /// Per-line source code with comments removed and literals blanked.
    pub code: Vec<String>,
    /// Per-line comment text (without the `//` / `/*` markers).
    pub comment: Vec<String>,
}

/// Split `text` into the code and comment views.
pub fn strip(text: &str) -> Stripped {
    let chars: Vec<char> = text.chars().collect();
    let n = chars.len();
    let mut code = String::new();
    let mut comment = String::new();
    let mut block_depth = 0usize;
    let mut i = 0;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            code.push('\n');
            comment.push('\n');
            i += 1;
            continue;
        }
        if block_depth > 0 {
            if starts_with(&chars, i, "*/") {
                block_depth -= 1;
                i += 2;
            } else if starts_with(&chars, i, "/*") {
                block_depth += 1;
                i += 2;
            } else {
                comment.push(c);
                i += 1;
            }
            continue;
        }
        if starts_with(&chars, i, "//") {
            let mut j = i + 2;
            while j < n && chars[j] != '\n' {
                comment.push(chars[j]);
                j += 1;
            }
            i = j;
            continue;
        }
        if starts_with(&chars, i, "/*") {
            block_depth += 1;
            i += 2;
            continue;
        }
        if c == '"' {
            code.push_str("\"\"");
            i = consume_string(&chars, i + 1, &mut code, &mut comment);
            continue;
        }
        if c == 'r' {
            if let Some(next) = consume_raw_string(&chars, i, &mut code, &mut comment) {
                i = next;
                continue;
            }
        }
        if c == '\'' {
            if let Some(len) = char_literal_len(&chars[i..]) {
                code.push_str("' '");
                i += len;
                continue;
            }
        }
        code.push(c);
        i += 1;
    }
    let split = |s: &str| s.split('\n').map(str::to_string).collect();
    Stripped { code: split(&code), comment: split(&comment) }
}

fn starts_with(chars: &[char], i: usize, pat: &str) -> bool {
    pat.chars().enumerate().all(|(k, p)| chars.get(i + k) == Some(&p))
}

/// Consume a normal string body starting after the opening quote; returns
/// the index after the closing quote. Inner newlines (multi-line strings,
/// `\`-continuations) are mirrored into both views to keep lines in sync.
fn consume_string(chars: &[char], mut j: usize, code: &mut String, comment: &mut String) -> usize {
    let n = chars.len();
    while j < n {
        match chars[j] {
            '\\' => {
                if chars.get(j + 1) == Some(&'\n') {
                    code.push('\n');
                    comment.push('\n');
                }
                j += 2;
            }
            '"' => return j + 1,
            '\n' => {
                code.push('\n');
                comment.push('\n');
                j += 1;
            }
            _ => j += 1,
        }
    }
    n
}

/// Try to consume a raw string (`r"…"` / `r#"…"#`) starting at the `r` at
/// index `i`; returns the index after the closing delimiter, or `None` when
/// this `r` is just an identifier character.
fn consume_raw_string(
    chars: &[char],
    i: usize,
    code: &mut String,
    comment: &mut String,
) -> Option<usize> {
    let n = chars.len();
    let mut hashes = 0;
    let mut k = i + 1;
    while k < n && chars[k] == '#' {
        hashes += 1;
        k += 1;
    }
    if k >= n || chars[k] != '"' {
        return None;
    }
    code.push_str("\"\"");
    let mut j = k + 1;
    while j < n {
        if chars[j] == '\n' {
            code.push('\n');
            comment.push('\n');
        } else if chars[j] == '"' {
            let mut h = 0;
            while h < hashes && chars.get(j + 1 + h) == Some(&'#') {
                h += 1;
            }
            if h == hashes {
                return Some(j + 1 + hashes);
            }
        }
        j += 1;
    }
    Some(n)
}

/// Length (in chars, including quotes) of a char literal starting at
/// `chars[0] == '\''`, or `None` when the tick is a lifetime.
fn char_literal_len(chars: &[char]) -> Option<usize> {
    if chars.len() < 3 {
        return None;
    }
    if chars[1] == '\\' {
        // Escaped form: '\n', '\x41', '\u{1F600}', … — scan to the closing
        // quote on the same line.
        let mut j = 3;
        while j < chars.len() && chars[j] != '\'' && chars[j] != '\n' {
            j += 1;
        }
        if j < chars.len() && chars[j] == '\'' {
            return Some(j + 1);
        }
        return None;
    }
    if chars[1] != '\'' && chars[2] == '\'' {
        return Some(3);
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_go_to_comment_view() {
        let s = strip("let x = 1; // SAFETY: fine\n/* block */ let y = 2;\n");
        assert_eq!(s.code[0], "let x = 1; ");
        assert!(s.comment[0].contains("SAFETY: fine"));
        assert_eq!(s.code[1].trim(), "let y = 2;");
        assert!(s.comment[1].contains("block"));
    }

    #[test]
    fn strings_are_blanked_in_code_view() {
        let s = strip("let u = \"call .unwrap() or unsafe\"; foo();\n");
        assert!(!s.code[0].contains("unwrap"));
        assert!(!s.code[0].contains("unsafe"));
        assert!(s.code[0].contains("foo()"));
        // String contents never leak into the comment view either.
        assert_eq!(s.comment[0], "");
    }

    #[test]
    fn escaped_quotes_do_not_end_strings() {
        let s = strip("let a = \"x\\\"y\"; let b = 1;\n");
        assert!(s.code[0].contains("let b = 1;"));
    }

    #[test]
    fn raw_strings_including_hashes_and_newlines() {
        let s = strip("let r = r#\"line .unwrap()\nline \"quoted\" unsafe\"#; end();\n");
        assert_eq!(s.code.len(), 3);
        assert!(!s.code[0].contains("unwrap"));
        assert!(!s.code[1].contains("unsafe"));
        assert!(s.code[1].contains("end()"));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        // '"' must be treated as a char literal, not a string opener.
        let s = strip("let q = '\"'; let l: &'static str = \"\"; done();\n");
        assert!(s.code[0].contains("done()"));
        // Lifetimes survive as code without swallowing the rest of the line.
        let s = strip("fn f<'a>(x: &'a u64) -> &'a u64 { x }\n");
        assert!(s.code[0].contains("fn f<"));
        assert!(s.code[0].contains("{ x }"));
    }

    #[test]
    fn nested_block_comments() {
        let s = strip("/* outer /* inner */ still comment */ code();\n");
        assert!(s.code[0].contains("code()"));
        assert!(!s.code[0].contains("inner"));
        assert!(s.comment[0].contains("still comment"));
    }

    #[test]
    fn line_counts_match_source() {
        let src = "a\nb\n/* c\nd */\ne \"f\ng\"\n";
        let s = strip(src);
        assert_eq!(s.code.len(), s.comment.len());
        assert_eq!(s.code.len(), src.split('\n').count());
    }
}
