//! Real TCP transport for multi-process deployments (`hummingbird party`).
//!
//! Framing: each message is `[seq: u64 le][len: u64 le][payload]`. The
//! mesh is fully connected; party i listens for connections from parties
//! j > i and dials parties j < i, so an n-party mesh needs no coordinator.
//!
//! The receive path reads frames directly into the caller's [`RecvBufs`]
//! slots (`read_frame_into`): once a session has seen its largest frame,
//! steady-state rounds perform zero receive-side allocations. The send
//! path writes the caller's payload straight to the socket and never
//! allocates (the retained resend frame below is arena-pooled).
//!
//! # Fault tolerance (DESIGN.md §7)
//!
//! Every blocking call is bounded by [`NetConfig`]: dialing backs off
//! exponentially up to `connect_timeout`, the identify handshake has its
//! own per-message deadline, and each round's socket reads/writes carry
//! `round_timeout`. A deadline expiry is **fatal** ([`Error::Timeout`]) —
//! a hung peer cannot be repaired by reconnecting.
//!
//! A *link* fault (reset / EOF / broken pipe) is **retryable**: the
//! endpoint re-establishes the connection and runs a resync handshake.
//! Every handshake message — initial connect and reconnect alike — is the
//! 24-byte triple `[party][session_id][next_recv_seq]` in both
//! directions. On reconnect, each side compares the peer's
//! `next_recv_seq` against the sequence number of its own *retained last
//! frame* (the send path keeps one pooled copy of the most recent
//! payload): if the peer still needs it, the frame is resent verbatim.
//! Rounds are a deterministic function of the parties' shares, so
//! recovery is **bit-identical** to a fault-free run — the chaos suite
//! (`tests/fault_injection.rs`) pins this. Resent bytes are counted in
//! [`NetStats`], not in the protocol [`CommTrace`], so byte accounting
//! stays identical between faulty and fault-free runs.
//!
//! Not handled (explicit non-goals, see DESIGN.md §7): Byzantine peers,
//! simultaneous multi-link failures racing the same listener, and
//! recovery of a crashed (rather than disconnected) party.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::accounting::{CommTrace, Phase};
use super::{NetConfig, NetStats, RecvBufs, Transport};
use crate::error::{Error, Result};
use crate::util::arena::Arena;

/// Sequence number a fresh endpoint expects first (handshake field value
/// on initial connect).
const FRESH: u64 = 0;

/// A bound-but-not-yet-connected endpoint. Splitting `bind` from
/// `establish` lets callers bind port 0 and learn the kernel-assigned
/// address (`local_addr`) before the peers dial in — the tests use this
/// to stay collision-free under parallel runs.
pub struct BoundListener {
    party: usize,
    listener: TcpListener,
}

impl BoundListener {
    /// Bind this party's listen socket (e.g. `"127.0.0.1:0"` for an
    /// ephemeral port).
    pub fn bind(party: usize, addr: &str) -> Result<BoundListener> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::Transport(format!("bind {addr}: {e}")))?;
        Ok(BoundListener { party, listener })
    }

    /// The actual bound address (resolves port 0).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Connect the mesh: dial lower-ranked peers, accept higher-ranked
    /// ones, all bounded by `cfg.connect_timeout`. `addrs[q]` is party
    /// q's listen address; `addrs[self.party]` is ignored (this listener
    /// is already bound). All parties must pass the same `session_id`.
    pub fn establish(
        self,
        addrs: &[String],
        session_id: u64,
        cfg: NetConfig,
    ) -> Result<TcpTransport> {
        let parties = addrs.len();
        let party = self.party;
        if party >= parties || parties < 2 {
            return Err(Error::config(format!("bad party id {party} for {parties} parties")));
        }
        // The accept path polls (no native accept timeout), so the
        // listener stays non-blocking for the transport's lifetime.
        self.listener.set_nonblocking(true)?;
        let mut t = TcpTransport {
            party,
            parties,
            // HOT-PATH-ALLOW: session establishment — per-peer slot table.
            streams: (0..parties).map(|_| None).collect(),
            listener: self.listener,
            // HOT-PATH-ALLOW: session establishment — address book copy.
            addrs: addrs.to_vec(),
            session_id,
            seq: 0,
            last_seq: 0,
            last_frame: None,
            pool: Arena::new(),
            cfg,
            stats: Arc::new(NetStats::default()),
            trace: Arc::new(CommTrace::new()),
        };
        for q in 0..party {
            let (s, _peer_next) = t.dial_handshake(q, FRESH)?;
            t.streams[q] = Some(s);
        }
        for _ in party + 1..parties {
            let (q, s, _peer_next) = t.accept_handshake(None, FRESH)?;
            if t.streams[q].is_some() {
                return Err(Error::Transport(format!("duplicate connection from party {q}")));
            }
            t.streams[q] = Some(s);
        }
        Ok(t)
    }
}

/// TCP endpoint for one party.
pub struct TcpTransport {
    party: usize,
    parties: usize,
    /// Peer streams indexed by party id (entry for self is None).
    streams: Vec<Option<TcpStream>>,
    /// Kept for the transport's lifetime so the accept side can
    /// re-establish a dropped link mid-session.
    listener: TcpListener,
    addrs: Vec<String>,
    session_id: u64,
    seq: u64,
    /// Sequence number of the retained frame below.
    last_seq: u64,
    /// Pooled copy of the most recent round's payload (identical for all
    /// peers), resent after a resync handshake when the peer still needs
    /// it.
    last_frame: Option<Vec<u8>>,
    pool: Arena,
    cfg: NetConfig,
    stats: Arc<NetStats>,
    trace: Arc<CommTrace>,
}

impl TcpTransport {
    /// Connect the mesh with default deadlines and session id 0. `addrs[p]`
    /// is the listen address of party p (e.g. "127.0.0.1:9001"). Blocks
    /// until all links are up (bounded by `NetConfig::connect_timeout`).
    pub fn connect(party: usize, addrs: &[String]) -> Result<TcpTransport> {
        TcpTransport::connect_with(party, addrs, 0, NetConfig::default())
    }

    /// [`TcpTransport::connect`] with explicit deadlines and session id
    /// (the resync handshake rejects peers from a different session).
    pub fn connect_with(
        party: usize,
        addrs: &[String],
        session_id: u64,
        cfg: NetConfig,
    ) -> Result<TcpTransport> {
        let parties = addrs.len();
        if party >= parties || parties < 2 {
            return Err(Error::config(format!("bad party id {party} for {parties} parties")));
        }
        BoundListener::bind(party, &addrs[party])?.establish(addrs, session_id, cfg)
    }

    /// Fault/recovery counters for this endpoint.
    pub fn net_stats(&self) -> Arc<NetStats> {
        Arc::clone(&self.stats)
    }

    fn stream_mut(&mut self, q: usize) -> Result<&mut TcpStream> {
        self.streams
            .get_mut(q)
            .and_then(|s| s.as_mut())
            .ok_or_else(|| Error::Transport(format!("no link to party {q}")))
    }

    /// Arm both socket deadlines (`None` is never used: every blocking
    /// socket call in this transport is bounded).
    fn arm_deadlines(s: &TcpStream, d: Duration) -> Result<()> {
        s.set_read_timeout(Some(d))?;
        s.set_write_timeout(Some(d))?;
        Ok(())
    }

    /// Dial peer `q` with exponential backoff, then run the handshake:
    /// send `[party][session][want_recv]`, read the peer's triple back.
    fn dial_handshake(&self, q: usize, want_recv: u64) -> Result<(TcpStream, u64)> {
        let addr = &self.addrs[q];
        let deadline = Instant::now() + self.cfg.connect_timeout;
        let mut backoff = self.cfg.backoff.max(Duration::from_millis(1));
        let mut s = loop {
            match TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(e) => {
                    self.stats.note_retry();
                    if Instant::now() + backoff > deadline {
                        self.stats.note_timeout();
                        return Err(Error::timeout(format!(
                            "dial {addr}: {e} (gave up after {:?})",
                            self.cfg.connect_timeout
                        )));
                    }
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(Duration::from_secs(1));
                }
            }
        };
        s.set_nodelay(true).ok();
        Self::arm_deadlines(&s, self.cfg.handshake_timeout)?;
        write_hello(&mut s, self.party as u64, self.session_id, want_recv)?;
        let (peer, session, peer_next) = read_hello(&mut s)?;
        if peer != q as u64 {
            return Err(Error::protocol(format!("dialed party {q}, got party {peer}")));
        }
        if session != self.session_id {
            return Err(Error::protocol(format!(
                "session mismatch with party {q}: ours {}, theirs {session}",
                self.session_id
            )));
        }
        Self::arm_deadlines(&s, self.cfg.round_timeout)?;
        Ok((s, peer_next))
    }

    /// Accept one inbound connection (polling the non-blocking listener up
    /// to `connect_timeout`), validate its hello and reply with ours.
    /// `expect` pins the peer id during reconnect; `None` (initial mesh
    /// bring-up) admits any higher-ranked party.
    fn accept_handshake(
        &self,
        expect: Option<usize>,
        want_recv: u64,
    ) -> Result<(usize, TcpStream, u64)> {
        let deadline = Instant::now() + self.cfg.connect_timeout;
        let mut s = loop {
            match self.listener.accept() {
                Ok((s, _)) => break s,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() > deadline {
                        self.stats.note_timeout();
                        return Err(Error::timeout(format!(
                            "party {}: no inbound connection within {:?}",
                            self.party, self.cfg.connect_timeout
                        )));
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => return Err(Error::Transport(format!("accept: {e}"))),
            }
        };
        s.set_nonblocking(false)?;
        s.set_nodelay(true).ok();
        Self::arm_deadlines(&s, self.cfg.handshake_timeout)?;
        let (peer, session, peer_next) = read_hello(&mut s)?;
        let q = peer as usize;
        if q >= self.parties || q == self.party || expect.is_some_and(|want| want != q) {
            return Err(Error::protocol(format!(
                "unexpected peer id {peer} (expected {expect:?})"
            )));
        }
        if session != self.session_id {
            return Err(Error::protocol(format!(
                "session mismatch with party {q}: ours {}, theirs {session}",
                self.session_id
            )));
        }
        write_hello(&mut s, self.party as u64, self.session_id, want_recv)?;
        Self::arm_deadlines(&s, self.cfg.round_timeout)?;
        Ok((q, s, peer_next))
    }

    /// Re-establish the link to `q` after a retryable fault and resync:
    /// tell the peer which seq we still need (`want_recv`), learn which
    /// seq it needs, and resend our retained frame if that is it. Dialer
    /// and acceptor roles are fixed by rank, as at mesh bring-up.
    fn recover_link(&mut self, q: usize, want_recv: u64) -> Result<()> {
        let mut last_err = Error::Transport(format!("link to party {q} lost"));
        for _ in 0..self.cfg.retries.max(1) {
            match self.try_recover(q, want_recv) {
                Ok(()) => {
                    self.stats.note_reconnect();
                    return Ok(());
                }
                Err(e) => last_err = e,
            }
        }
        Err(last_err)
    }

    fn try_recover(&mut self, q: usize, want_recv: u64) -> Result<()> {
        self.streams[q] = None; // drop the dead socket first
        let (s, peer_next) = if q < self.party {
            self.dial_handshake(q, want_recv)?
        } else {
            let (peer, s, peer_next) = self.accept_handshake(Some(q), want_recv)?;
            debug_assert_eq!(peer, q);
            (s, peer_next)
        };
        self.streams[q] = Some(s);
        if peer_next == self.last_seq {
            // The peer never got (all of) our last frame: resend it
            // verbatim. Counted in NetStats, not CommTrace — protocol
            // byte accounting must stay identical to a fault-free run.
            let Some(frame) = self.last_frame.take() else {
                return Err(Error::protocol(format!(
                    "resync with party {q}: peer needs seq {peer_next} but no frame is retained"
                )));
            };
            let r = write_frame(self.stream_mut(q)?, self.last_seq, &frame);
            self.last_frame = Some(frame);
            r?;
            self.stats.note_resend();
        } else if peer_next != self.last_seq + 1 {
            return Err(Error::protocol(format!(
                "resync with party {q} diverged: peer expects seq {peer_next}, \
                 our last sent seq is {}",
                self.last_seq
            )));
        }
        Ok(())
    }

    /// Keep a pooled copy of this round's payload for resend-after-resync.
    fn retain_frame(&mut self, data: &[u8], seq: u64) {
        if let Some(old) = self.last_frame.take() {
            self.pool.put_bytes(old);
        }
        let mut buf = self.pool.take_bytes(data.len());
        RecvBufs::fill_slot(&mut buf, data);
        self.last_frame = Some(buf);
        self.last_seq = seq;
    }

    /// Map a deadline expiry on the socket to the fatal [`Error::Timeout`]
    /// (counting it); pass every other error through.
    fn map_deadline(&self, q: usize, e: Error) -> Error {
        if let Error::Io(io) = &e {
            if matches!(io.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
            {
                self.stats.note_timeout();
                return Error::timeout(format!(
                    "party {}: round {} with peer {q} exceeded {:?}",
                    self.party, self.seq, self.cfg.round_timeout
                ));
            }
        }
        e
    }

    fn send_with_recovery(&mut self, q: usize, seq: u64, data: &[u8]) -> Result<()> {
        match write_frame(self.stream_mut(q)?, seq, data) {
            Ok(()) => Ok(()),
            Err(e) if e.is_retryable() => {
                // Recovery resends the retained frame iff the peer still
                // needs it, so the caller must NOT rewrite (a double send
                // would desequence the stream).
                self.recover_link(q, seq)
            }
            Err(e) => Err(self.map_deadline(q, e)),
        }
    }

    fn read_with_recovery(&mut self, q: usize, seq: u64, out: &mut Vec<u8>) -> Result<()> {
        let max = self.cfg.max_frame_len;
        match read_frame_into(self.stream_mut(q)?, seq, out, max) {
            Ok(()) => Ok(()),
            Err(e) if e.is_retryable() => {
                self.recover_link(q, seq)?;
                read_frame_into(self.stream_mut(q)?, seq, out, max)
                    .map_err(|e| self.map_deadline(q, e))
            }
            Err(e) => Err(self.map_deadline(q, e)),
        }
    }
}

/// 24-byte handshake triple `[party][session_id][next_recv_seq]`, used in
/// both directions on connect and reconnect.
fn write_hello(s: &mut TcpStream, party: u64, session: u64, next_recv: u64) -> Result<()> {
    let mut buf = [0u8; 24];
    buf[0..8].copy_from_slice(&party.to_le_bytes());
    buf[8..16].copy_from_slice(&session.to_le_bytes());
    buf[16..24].copy_from_slice(&next_recv.to_le_bytes());
    s.write_all(&buf)?;
    Ok(())
}

fn read_hello(s: &mut TcpStream) -> Result<(u64, u64, u64)> {
    let mut buf = [0u8; 24];
    s.read_exact(&mut buf)?;
    let word = |i: usize| {
        let mut w = [0u8; 8];
        w.copy_from_slice(&buf[i * 8..i * 8 + 8]);
        u64::from_le_bytes(w)
    };
    Ok((word(0), word(1), word(2)))
}

fn write_frame(s: &mut TcpStream, seq: u64, payload: &[u8]) -> Result<()> {
    s.write_all(&seq.to_le_bytes())?;
    s.write_all(&(payload.len() as u64).to_le_bytes())?;
    s.write_all(payload)?;
    Ok(())
}

/// Read one frame into `out` without a memset (the `RecvBufs` fill
/// contract): overwrite the already-initialized prefix in place, then
/// append any remainder — `Take::read_to_end` fills spare capacity
/// directly, so growth within capacity neither allocates nor pre-zeroes.
///
/// Error classification (DESIGN.md §7): a length header above `max_len`
/// is [`Error::Wire`] (fatal — rejected *before* any allocation), an
/// out-of-order seq is [`Error::Transport`] (fatal protocol divergence),
/// and a connection that closes mid-frame surfaces as a retryable
/// `UnexpectedEof` I/O error so the session layer can reconnect-and-resend.
fn read_frame_into(
    s: &mut TcpStream,
    want_seq: u64,
    out: &mut Vec<u8>,
    max_len: usize,
) -> Result<()> {
    let mut hdr = [0u8; 16];
    s.read_exact(&mut hdr)?;
    let seq = u64::from_le_bytes([
        hdr[0], hdr[1], hdr[2], hdr[3], hdr[4], hdr[5], hdr[6], hdr[7],
    ]);
    if seq != want_seq {
        return Err(Error::Transport(format!("out-of-order frame: got {seq}, want {want_seq}")));
    }
    let len64 = u64::from_le_bytes([
        hdr[8], hdr[9], hdr[10], hdr[11], hdr[12], hdr[13], hdr[14], hdr[15],
    ]);
    if len64 > max_len as u64 {
        return Err(Error::wire(format!(
            "frame length {len64} exceeds max_frame_len {max_len}"
        )));
    }
    let len = len64 as usize;
    if out.len() > len {
        out.truncate(len);
    }
    let prefix = out.len();
    s.read_exact(&mut out[..prefix])?;
    if len > prefix {
        let appended = s.by_ref().take((len - prefix) as u64).read_to_end(out)?;
        if appended != len - prefix {
            return Err(Error::Io(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                format!("connection closed mid-frame: got {} of {len} bytes", prefix + appended),
            )));
        }
    }
    Ok(())
}

impl Transport for TcpTransport {
    fn party(&self) -> usize {
        self.party
    }
    fn parties(&self) -> usize {
        self.parties
    }

    fn exchange_all_into(
        &mut self,
        phase: Phase,
        data: &[u8],
        recv: &mut RecvBufs,
    ) -> Result<()> {
        if recv.parties() != self.parties {
            return Err(Error::Transport(format!(
                "RecvBufs sized for {} parties, mesh has {}",
                recv.parties(),
                self.parties
            )));
        }
        if data.len() > self.cfg.max_frame_len {
            return Err(Error::wire(format!(
                "payload of {} bytes exceeds max_frame_len {}",
                data.len(),
                self.cfg.max_frame_len
            )));
        }
        let t0 = Instant::now();
        let seq = self.seq;
        self.seq += 1;
        // Retain before the first write: a fault at any point in the round
        // can then always resync from the retained copy.
        self.retain_frame(data, seq);
        // Write to all peers, then read from all peers. Per-link frames are
        // small enough that the kernel buffers absorb the write side; a
        // full-duplex implementation with writer threads is unnecessary at
        // our message sizes (< 16 MiB) and socket buffer tuning.
        for q in 0..self.parties {
            if q == self.party {
                continue;
            }
            self.send_with_recovery(q, seq, data)?;
        }
        for q in 0..self.parties {
            if q == self.party {
                continue;
            }
            // Split the slot out so the `&mut self` recovery path and the
            // slot fill don't alias.
            let mut slot = std::mem::take(&mut recv.slots_mut()[q]);
            let r = self.read_with_recovery(q, seq, &mut slot);
            recv.slots_mut()[q] = slot;
            r?;
        }
        self.trace.record(phase, (data.len() * (self.parties - 1)) as u64);
        self.trace.record_wait(t0.elapsed());
        Ok(())
    }

    fn trace(&self) -> Arc<CommTrace> {
        Arc::clone(&self.trace)
    }

    /// Chaos hook (see [`Transport::inject_peer_drop`]): severing the
    /// socket makes *both* ends observe a real link fault, so the next
    /// exchange exercises the genuine reconnect-and-resend machinery.
    fn inject_peer_drop(&mut self, peer: usize) -> bool {
        match self.streams.get(peer) {
            Some(Some(s)) => {
                s.shutdown(std::net::Shutdown::Both).ok();
                true
            }
            _ => false,
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    /// Bind party 0 on an ephemeral port and return (transport-0-builder,
    /// addrs) so tests never race on hardcoded ports.
    fn ephemeral_pair_addrs() -> (BoundListener, Vec<String>) {
        let l0 = BoundListener::bind(0, "127.0.0.1:0").unwrap();
        let addr0 = format!("127.0.0.1:{}", l0.local_addr().unwrap().port());
        // Party 1 is the highest rank: it dials everyone and accepts no
        // one, so its own listen address can be any bindable port.
        (l0, vec![addr0, "127.0.0.1:0".to_string()])
    }

    /// Two parties over loopback sockets exchange several rounds
    /// (ephemeral ports — collision-free under parallel test runs).
    #[test]
    fn two_party_loopback() {
        let (l0, addrs) = ephemeral_pair_addrs();
        let a1 = addrs.clone();
        let h = std::thread::spawn(move || {
            let mut t = TcpTransport::connect_with(1, &a1, 7, NetConfig::default()).unwrap();
            for r in 0..5u8 {
                let got = t.exchange_all(Phase::Circuit, &[r, 1]).unwrap();
                assert_eq!(got[0], vec![r, 0]);
            }
            t.trace().total_bytes()
        });
        let mut t = l0.establish(&addrs, 7, NetConfig::default()).unwrap();
        for r in 0..5u8 {
            let got = t.exchange_all(Phase::Circuit, &[r, 0]).unwrap();
            assert_eq!(got[1], vec![r, 1]);
        }
        assert_eq!(h.join().unwrap(), 10);
        assert_eq!(t.trace().total_rounds(), 5);
    }

    /// The into-variant over loopback: slots are filled per round and the
    /// slot allocations stay put once warm (pointer-stable across rounds).
    #[test]
    fn loopback_exchange_into_reuses_slots() {
        let (l0, addrs) = ephemeral_pair_addrs();
        let a1 = addrs.clone();
        let h = std::thread::spawn(move || {
            let mut t = TcpTransport::connect_with(1, &a1, 0, NetConfig::default()).unwrap();
            let mut recv = RecvBufs::new(2);
            for r in 0..6u8 {
                let payload = vec![r, 1, 1, 1];
                t.exchange_all_into(Phase::Circuit, &payload, &mut recv).unwrap();
                assert_eq!(recv.get(0), [r, 0, 0, 0]);
            }
        });
        let mut t = l0.establish(&addrs, 0, NetConfig::default()).unwrap();
        let mut recv = RecvBufs::new(2);
        let mut warm_ptr = None;
        for r in 0..6u8 {
            let payload = vec![r, 0, 0, 0];
            t.exchange_all_into(Phase::Circuit, &payload, &mut recv).unwrap();
            assert_eq!(recv.get(1), [r, 1, 1, 1]);
            let ptr = recv.get(1).as_ptr();
            match warm_ptr {
                None => warm_ptr = Some(ptr),
                Some(p) => assert_eq!(p, ptr, "warm slot must not reallocate (round {r})"),
            }
        }
        h.join().unwrap();
    }

    /// A severed link mid-session recovers transparently through the
    /// resync handshake: later rounds see exactly the bytes a fault-free
    /// run would, and the recovery counters record the reconnect.
    #[test]
    fn reconnect_and_resend_recovers_round() {
        let (l0, addrs) = ephemeral_pair_addrs();
        let a1 = addrs.clone();
        let h = std::thread::spawn(move || {
            let mut t = TcpTransport::connect_with(1, &a1, 9, NetConfig::default()).unwrap();
            for r in 0..6u8 {
                if r == 3 {
                    // Sever the link right before round 3's exchange; both
                    // ends must recover via reconnect-and-resend.
                    assert!(t.inject_peer_drop(0));
                }
                let got = t.exchange_all(Phase::Circuit, &[r, 1]).unwrap();
                assert_eq!(got[0], vec![r, 0], "round {r}");
            }
            let stats = t.net_stats().snapshot();
            (stats.reconnects, t.trace().total_bytes())
        });
        let mut t = l0.establish(&addrs, 9, NetConfig::default()).unwrap();
        for r in 0..6u8 {
            let got = t.exchange_all(Phase::Circuit, &[r, 0]).unwrap();
            assert_eq!(got[1], vec![r, 1], "round {r}");
        }
        let (reconnects, bytes1) = h.join().unwrap();
        assert!(reconnects >= 1, "faulted side must have reconnected");
        assert!(t.net_stats().snapshot().reconnects >= 1, "accept side must have reconnected");
        // Protocol byte accounting is identical to a fault-free run
        // (resends are counted in NetStats, not CommTrace).
        assert_eq!(bytes1, 12);
        assert_eq!(t.trace().total_bytes(), 12);
    }

    /// Satellite: the oversized-frame guard fires on the declared length,
    /// *before* any allocation (the old `1 << 32` guard admitted 4 GiB).
    #[test]
    fn oversized_frame_rejected_before_allocation() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(&0u64.to_le_bytes()).unwrap(); // seq
            s.write_all(&(1u64 << 62).to_le_bytes()).unwrap(); // absurd len
            s.flush().unwrap();
            // Hold the socket open until the reader has decided.
            std::thread::sleep(Duration::from_millis(100));
        });
        let (mut s, _) = l.accept().unwrap();
        let mut out = Vec::new();
        let err = read_frame_into(&mut s, 0, &mut out, 1 << 20).unwrap_err();
        assert!(matches!(err, Error::Wire(_)), "got {err}");
        assert!(!err.is_retryable(), "a corrupt length header is not a link fault");
        assert_eq!(out.capacity(), 0, "guard must fire before allocating");
        h.join().unwrap();
    }

    /// Out-of-order sequence numbers are fatal protocol divergence, not a
    /// retryable link fault.
    #[test]
    fn out_of_order_seq_is_fatal() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            write_frame(&mut s, 7, b"zzz").unwrap();
            std::thread::sleep(Duration::from_millis(100));
        });
        let (mut s, _) = l.accept().unwrap();
        let mut out = Vec::new();
        let err = read_frame_into(&mut s, 0, &mut out, 1 << 20).unwrap_err();
        assert!(matches!(err, Error::Transport(_)), "got {err}");
        assert!(!err.is_retryable());
        h.join().unwrap();
    }

    /// A connection that closes mid-frame surfaces as a *retryable* EOF
    /// (the session layer may reconnect-and-resend), distinct from the
    /// fatal wire/protocol errors above.
    #[test]
    fn short_frame_is_retryable_eof() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(&0u64.to_le_bytes()).unwrap(); // seq
            s.write_all(&100u64.to_le_bytes()).unwrap(); // claims 100 bytes
            s.write_all(&[0xab; 10]).unwrap(); // delivers 10
            // Dropping the stream closes the connection mid-frame.
        });
        let (mut s, _) = l.accept().unwrap();
        let mut out = Vec::new();
        let err = read_frame_into(&mut s, 0, &mut out, 1 << 20).unwrap_err();
        assert!(err.is_retryable(), "mid-frame close must classify retryable: {err}");
        h.join().unwrap();
    }
}
