//! Offline stub of the `anyhow` crate, covering the slice of its API the
//! `hummingbird` binary uses: [`Error`] (a boxed dynamic error), [`Result`],
//! the [`bail!`] macro, and the [`Context`] extension trait. Like the real
//! crate, [`Error`] deliberately does NOT implement `std::error::Error` so
//! the blanket `From<E: std::error::Error>` conversion can exist.

use std::fmt;

/// Boxed dynamic error with a display-oriented API.
pub struct Error(Box<dyn std::error::Error + Send + Sync + 'static>);

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error(message.to_string().into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&self.0, f)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error(Box::new(e))
    }
}

/// `anyhow`-style result alias with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach human-readable context to an error as it crosses a boundary.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| {
            let inner = e.into();
            Error::msg(format!("{ctx}: {inner}"))
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => {
                let inner = e.into();
                Err(Error::msg(format!("{}: {inner}", f())))
            }
        }
    }
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::Error::msg(format!($($arg)*)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        let io: std::io::Result<()> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "boom"));
        io.context("reading config")?;
        Ok(())
    }

    #[test]
    fn context_wraps_and_displays() {
        let err = fails().err().unwrap();
        let s = format!("{err:#}");
        assert!(s.contains("reading config"), "{s}");
        assert!(s.contains("boom"), "{s}");
    }

    #[test]
    fn bail_formats() {
        fn f(x: i32) -> Result<()> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(())
        }
        assert!(f(1).is_ok());
        assert_eq!(f(-2).err().unwrap().to_string(), "negative: -2");
    }
}
