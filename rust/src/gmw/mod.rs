//! GMW protocol engine (paper §2.2) with HummingBird's reduced-ring
//! approximate ReLU (paper §3, Eq. 3).
//!
//! One [`GmwParty`] object per party drives the whole online protocol:
//!
//! * [`GmwParty::and_gates`] — Beaver-masked AND on w-bit lanes (1 round,
//!   2·w bits/elem, bit-packed).
//! * [`adder`] — the Kogge–Stone prefix adder used by A2B.
//! * [`GmwParty::a2b`] — arithmetic→binary conversion: free local
//!   re-sharing (PRG zero-sharing) + circuit addition.
//! * [`GmwParty::b2a_bit`] — 1-bit binary→arithmetic via daBits.
//! * [`GmwParty::drelu`] / [`GmwParty::relu`] — the paper's Equations 1–3;
//!   `ReluPlan { k, m }` selects the bit window (k=64, m=0 is the CrypTen
//!   baseline; anything else is HummingBird).
//! * [`GmwParty::mul`] — Beaver multiplication over Z/2^64 (the "Mult"
//!   phase HummingBird cannot shrink).
//!
//! Local tensor math is factored behind [`kernels::KernelBackend`] so the
//! same protocol can run on pure-Rust kernels or on the Pallas-lowered HLO
//! kernels through PJRT (see `runtime::XlaKernels`).

pub mod adder;
pub mod harness;
pub mod kernels;

use crate::beaver::TtpDealer;
use crate::bitpack;
use crate::error::{Error, Result};
use crate::net::accounting::Phase;
use crate::net::{self, Transport};
use crate::ring;
use crate::sharing::PairwisePrgs;

use kernels::{KernelBackend, RustKernels};

/// Per-layer ReLU evaluation plan: use bits [m, k) of the secret share.
///
/// * `k = 64, m = 0` — exact CrypTen-equivalent baseline (Eq. 2).
/// * `k < 64, m = 0` — HummingBird-eco (error-free if |x| < 2^(k-1), Thm 1).
/// * `m > 0` — adds magnitude pruning below 2^m (Thm 2).
/// * `k == m` — zero bits: the ReLU degenerates to identity (paper §4.1.2,
///   the generalization of ReLU culling).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReluPlan {
    pub k: u32,
    pub m: u32,
}

impl ReluPlan {
    /// Full-ring exact baseline.
    pub const BASELINE: ReluPlan = ReluPlan { k: 64, m: 0 };

    pub fn new(k: u32, m: u32) -> Result<Self> {
        if k > 64 || m > k {
            return Err(Error::config(format!("invalid ReluPlan k={k} m={m}")));
        }
        Ok(ReluPlan { k, m })
    }

    /// Window width in bits (0 = identity layer).
    pub fn width(&self) -> u32 {
        self.k - self.m
    }

    pub fn is_identity(&self) -> bool {
        self.k == self.m
    }

    pub fn is_baseline(&self) -> bool {
        *self == Self::BASELINE
    }
}

/// One party's protocol engine.
pub struct GmwParty<T: Transport, K: KernelBackend = RustKernels> {
    pub transport: T,
    pub dealer: TtpDealer,
    pub pairwise: PairwisePrgs,
    kernels: K,
}

impl<T: Transport> GmwParty<T, RustKernels> {
    /// Engine with the portable Rust kernels.
    pub fn new(transport: T, session_seed: u64) -> Self {
        GmwParty::with_kernels(transport, session_seed, RustKernels)
    }
}

impl<T: Transport, K: KernelBackend> GmwParty<T, K> {
    pub fn with_kernels(transport: T, session_seed: u64, kernels: K) -> Self {
        let party = transport.party();
        let parties = transport.parties();
        GmwParty {
            transport,
            dealer: TtpDealer::new(session_seed, party, parties),
            pairwise: PairwisePrgs::new(session_seed, party, parties),
            kernels,
        }
    }

    #[inline]
    pub fn party(&self) -> usize {
        self.transport.party()
    }
    #[inline]
    pub fn parties(&self) -> usize {
        self.transport.parties()
    }
    #[inline]
    pub fn is_leader(&self) -> bool {
        self.party() == 0
    }
    pub fn kernel_name(&self) -> &'static str {
        self.kernels.name()
    }
    pub(crate) fn kernels_mut(&mut self) -> &mut K {
        &mut self.kernels
    }

    // ------------------------------------------------------------------
    // Openings (the only communication primitives).
    // ------------------------------------------------------------------

    /// Open binary shares of w-bit lanes: bit-pack, exchange, fold-XOR.
    pub fn open_binary(&mut self, phase: Phase, shares: &[u64], w: u32) -> Result<Vec<u64>> {
        let bytes = bitpack::pack_bytes(shares, w);
        let bufs = self.transport.exchange_all(phase, &bytes)?;
        let mut out = vec![0u64; shares.len()];
        for (q, buf) in bufs.iter().enumerate() {
            let vals = if q == self.party() {
                shares.to_vec()
            } else {
                bitpack::unpack_bytes(buf, w, shares.len())
            };
            for (o, v) in out.iter_mut().zip(&vals) {
                *o ^= *v;
            }
        }
        Ok(out)
    }

    /// Open arithmetic shares (full 64-bit words on the wire).
    pub fn open_arith(&mut self, phase: Phase, shares: &[u64]) -> Result<Vec<u64>> {
        let bytes = net::u64s_to_bytes(shares);
        let bufs = self.transport.exchange_all(phase, &bytes)?;
        let mut out = vec![0u64; shares.len()];
        for (q, buf) in bufs.iter().enumerate() {
            let vals =
                if q == self.party() { shares.to_vec() } else { net::bytes_to_u64s(buf) };
            for (o, v) in out.iter_mut().zip(&vals) {
                *o = o.wrapping_add(*v);
            }
        }
        Ok(out)
    }

    // ------------------------------------------------------------------
    // Beaver AND on w-bit lanes.
    // ------------------------------------------------------------------

    /// Secure AND of two binary-shared vectors of w-bit lanes.
    /// Cost: one round, 2·w bits per element on the wire.
    pub fn and_gates(&mut self, phase: Phase, u: &[u64], v: &[u64], w: u32) -> Result<Vec<u64>> {
        debug_assert_eq!(u.len(), v.len());
        let n = u.len();
        let mask = ring::low_mask(w);
        let mut t = self.dealer.bin_triples(n);
        // Triples are 64-bit words; mask to the lane width in place (no
        // extra allocation — §Perf L3).
        if w < 64 {
            for v in t.a.iter_mut() {
                *v &= mask;
            }
            for v in t.b.iter_mut() {
                *v &= mask;
            }
            for v in t.c.iter_mut() {
                *v &= mask;
            }
        }
        let de_shares = self.kernels.and_open(u, v, &t.a, &t.b);
        let de = self.open_binary(phase, &de_shares, w)?;
        let (d, e) = de.split_at(n);
        let leader = self.is_leader();
        Ok(self.kernels.and_combine(d, e, &t.a, &t.b, &t.c, leader))
    }

    // ------------------------------------------------------------------
    // Conversions.
    // ------------------------------------------------------------------

    /// A2B: convert arithmetic shares of w-bit values (one lane per u64,
    /// high bits ignored) into binary shares of the same values.
    ///
    /// Step 1 is communication-free (PRG re-sharing); step 2 runs p−1
    /// circuit additions ([`adder::ks_add`]).
    pub fn a2b(&mut self, arith: &[u64], w: u32) -> Result<Vec<u64>> {
        let n = arith.len();
        let mask = ring::low_mask(w);
        let me = self.party();
        let parties = self.parties();
        // Binary re-sharing of every party's arithmetic share (operand j
        // belongs to party j). All parties generate the same zero-sharing
        // streams, so no communication happens here.
        let mut operands: Vec<Vec<u64>> = Vec::with_capacity(parties);
        for j in 0..parties {
            let masked: Vec<u64>;
            let value = if j == me {
                masked = arith.iter().map(|x| x & mask).collect();
                Some(masked.as_slice())
            } else {
                None
            };
            let mut share = self.pairwise.reshare_binary(value, n);
            for s in share.iter_mut() {
                *s &= mask;
            }
            operands.push(share);
        }
        // Circuit-add all operands pairwise.
        let mut acc = operands.remove(0);
        for op in operands {
            acc = adder::ks_add(self, &acc, &op, w)?;
        }
        Ok(acc)
    }

    /// B2A of single-bit lanes via daBits: one round, 1 bit per element.
    pub fn b2a_bit(&mut self, bits: &[u64]) -> Result<Vec<u64>> {
        let n = bits.len();
        let dab = self.dealer.dabits(n);
        let masked: Vec<u64> = bits.iter().zip(&dab.r_bin).map(|(b, r)| (b ^ r) & 1).collect();
        let z = self.open_binary(Phase::B2A, &masked, 1)?;
        // ⟨b⟩^A = z + ⟨r⟩^A − 2·z·⟨r⟩^A  (z public)
        let leader = self.is_leader();
        let out = z
            .iter()
            .zip(&dab.r_arith)
            .map(|(z, ra)| {
                let mut v = ra.wrapping_sub(ra.wrapping_mul(2).wrapping_mul(*z));
                if leader {
                    v = v.wrapping_add(*z);
                }
                v
            })
            .collect();
        Ok(out)
    }

    // ------------------------------------------------------------------
    // Arithmetic ops.
    // ------------------------------------------------------------------

    /// Beaver multiplication of two arithmetically-shared vectors.
    /// Cost: one round, 2×64 bits per element (HummingBird cannot shrink
    /// this — paper Fig 3 "Mult").
    pub fn mul(&mut self, x: &[u64], y: &[u64]) -> Result<Vec<u64>> {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let t = self.dealer.arith_triples(n);
        let de_shares = self.kernels.mult_open(x, y, &t.a, &t.b);
        let de = self.open_arith(Phase::Mult, &de_shares)?;
        let (d, e) = de.split_at(n);
        let leader = self.is_leader();
        Ok(self.kernels.mult_combine(d, e, &t.a, &t.b, &t.c, leader))
    }

    /// Local truncation of shares by 2^f (CrypTen-style; see
    /// [`ring::trunc_share`]).
    pub fn trunc(&self, shares: &[u64], f: u32) -> Vec<u64> {
        let me = self.party();
        shares.iter().map(|s| ring::trunc_share(*s, f, me)).collect()
    }

    /// Add a public constant vector (leader adds; others pass through).
    pub fn add_public(&self, shares: &[u64], consts: &[u64]) -> Vec<u64> {
        if self.is_leader() {
            shares.iter().zip(consts).map(|(s, c)| s.wrapping_add(*c)).collect()
        } else {
            shares.to_vec()
        }
    }

    // ------------------------------------------------------------------
    // DReLU / ReLU (Equations 1–3).
    // ------------------------------------------------------------------

    /// DReLU on the bit window [m, k): returns arithmetic shares of
    /// 1{x ≥ 0} evaluated on the reduced ring Z/2^(k−m).
    pub fn drelu(&mut self, arith: &[u64], plan: ReluPlan) -> Result<Vec<u64>> {
        let w = plan.width();
        debug_assert!(w >= 1, "drelu needs at least one bit");
        // Local bit extraction ⟨x⟩[k:m] (free).
        let windows: Vec<u64> =
            arith.iter().map(|x| ring::bit_window(*x, plan.k, plan.m)).collect();
        // A2B on the reduced ring.
        let sum_bits = self.a2b(&windows, w)?;
        // Sign bit (bit w−1) is a binary share of the MSB; DReLU = ¬MSB.
        let leader = self.is_leader();
        let msb: Vec<u64> = sum_bits
            .iter()
            .map(|s| {
                let bit = (s >> (w - 1)) & 1;
                if leader {
                    bit ^ 1
                } else {
                    bit
                }
            })
            .collect();
        // 1-bit B2A.
        self.b2a_bit(&msb)
    }

    /// ReLU per the plan: Eq. 2 when baseline, Eq. 3 otherwise.
    pub fn relu(&mut self, arith: &[u64], plan: ReluPlan) -> Result<Vec<u64>> {
        if plan.is_identity() {
            return Ok(arith.to_vec());
        }
        let d = self.drelu(arith, plan)?;
        self.mul(arith, &d)
    }
}
