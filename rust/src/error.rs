//! Error types for the HummingBird library.
//!
//! The library uses a single [`Error`] enum so that protocol, I/O, config and
//! runtime failures compose across module boundaries without boxing. Binaries
//! and examples convert into `anyhow::Error` at the edge.

use std::fmt;

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Library-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Malformed or inconsistent configuration.
    #[error("config error: {0}")]
    Config(String),

    /// JSON parse / serialize failure (our hand-rolled parser).
    #[error("json error at byte {offset}: {msg}")]
    Json { offset: usize, msg: String },

    /// Secret-sharing / protocol invariant violation.
    #[error("protocol error: {0}")]
    Protocol(String),

    /// Transport-level failure (channel closed, socket error, framing).
    #[error("transport error: {0}")]
    Transport(String),

    /// Beaver-triple store exhausted or mismatched.
    #[error("beaver error: {0}")]
    Beaver(String),

    /// Shape mismatch in tensor ops or model graph wiring.
    #[error("shape error: {0}")]
    Shape(String),

    /// Model graph / weights problem.
    #[error("model error: {0}")]
    Model(String),

    /// PJRT / XLA runtime failure.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Search engine failure (budget infeasible, no candidates, ...).
    #[error("search error: {0}")]
    Search(String),

    /// Underlying I/O error.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl Error {
    /// Shorthand constructor used pervasively in the protocol code.
    pub fn protocol(msg: impl fmt::Display) -> Self {
        Error::Protocol(msg.to_string())
    }
    /// Shorthand constructor for configuration errors.
    pub fn config(msg: impl fmt::Display) -> Self {
        Error::Config(msg.to_string())
    }
    /// Shorthand constructor for shape errors.
    pub fn shape(msg: impl fmt::Display) -> Self {
        Error::Shape(msg.to_string())
    }
    /// Shorthand constructor for runtime errors.
    pub fn runtime(msg: impl fmt::Display) -> Self {
        Error::Runtime(msg.to_string())
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(format!("xla: {e}"))
    }
}
