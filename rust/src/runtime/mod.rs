//! PJRT runtime: loads the AOT-compiled HLO artifacts and executes them
//! from the Rust hot path (Python never runs at serving time).
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Artifacts are lowered with `return_tuple=True`, so results unwrap with
//! `to_tuple1` (single output) or stay tuples (multi output).

pub mod registry;
pub mod xla_kernels;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::tensor::TensorU64;

pub use registry::Manifest;
pub use xla_kernels::XlaKernels;

/// Shared PJRT CPU client + executable cache. Cloneable handle; compiled
/// executables are cached per artifact path (compilation is the expensive
/// part, ~ms–100ms each).
///
/// The client is created **lazily** on the first artifact load: a runtime
/// handle can be constructed (and an executor with no linear-layer
/// artifacts can run) even where PJRT is unavailable, e.g. under the
/// offline `vendor/xla` stub.
#[derive(Clone)]
pub struct Runtime {
    inner: Arc<RuntimeInner>,
}

struct RuntimeInner {
    /// Lazily-created PJRT client; `Err` caches the creation failure so a
    /// stubbed build fails at the same call sites every time.
    client: std::sync::OnceLock<std::result::Result<xla::PjRtClient, String>>,
    root: PathBuf,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Create a runtime rooted at the artifacts directory. Never touches
    /// PJRT; the client comes up on the first [`Runtime::load`].
    pub fn new(artifacts_root: impl AsRef<Path>) -> Result<Runtime> {
        Ok(Runtime {
            inner: Arc::new(RuntimeInner {
                client: std::sync::OnceLock::new(),
                root: artifacts_root.as_ref().to_path_buf(),
                cache: Mutex::new(HashMap::new()),
            }),
        })
    }

    pub fn artifacts_root(&self) -> &Path {
        &self.inner.root
    }

    fn client(&self) -> Result<&xla::PjRtClient> {
        self.inner
            .client
            .get_or_init(|| xla::PjRtClient::cpu().map_err(|e| e.to_string()))
            .as_ref()
            .map_err(|e| Error::runtime(format!("pjrt client: {e}")))
    }

    /// Force client creation now. Servers that will execute artifacts call
    /// this at boot so a missing/broken PJRT install fails fast at startup
    /// instead of panicking a worker thread at first traffic.
    pub fn ensure_client(&self) -> Result<()> {
        self.client().map(|_| ())
    }

    /// Load + compile an HLO text artifact (cached).
    pub fn load(&self, rel_path: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        // A poisoned cache mutex only means another thread panicked after
        // a lookup or insert; the map itself is still consistent.
        if let Some(exe) =
            self.inner.cache.lock().unwrap_or_else(|p| p.into_inner()).get(rel_path)
        {
            return Ok(Arc::clone(exe));
        }
        let full = self.inner.root.join(rel_path);
        let proto = xla::HloModuleProto::from_text_file(&full).map_err(|e| {
            Error::runtime(format!("loading {}: {e}", full.display()))
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client()?.compile(&comp)?);
        self.inner
            .cache
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .insert(rel_path.to_string(), Arc::clone(&exe));
        Ok(exe)
    }

    /// Execute an artifact on i64 tensor inputs; returns the tuple elements
    /// as literals.
    pub fn execute(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let mut result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        let elems = result.decompose_tuple()?;
        Ok(elems)
    }

    /// Convenience: run artifact at `rel_path` on u64 ring tensors, return
    /// u64 ring tensors (bit-cast through i64).
    pub fn run_u64(&self, rel_path: &str, inputs: &[&TensorU64]) -> Result<Vec<TensorU64>> {
        let exe = self.load(rel_path)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| literal_i64(&t.as_i64_vec(), &t.shape))
            .collect::<Result<_>>()?;
        let outs = self.execute(&exe, &lits)?;
        outs.into_iter().map(literal_to_u64).collect()
    }

    /// Convenience: run on f32 tensors.
    pub fn run_f32(
        &self,
        rel_path: &str,
        inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<(Vec<f32>, Vec<usize>)>> {
        let exe = self.load(rel_path)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, shape)| literal_f32(data, shape))
            .collect::<Result<_>>()?;
        let outs = self.execute(&exe, &lits)?;
        outs.into_iter().map(literal_to_f32).collect()
    }

    /// Number of compiled executables currently cached.
    pub fn cached_executables(&self) -> usize {
        self.inner.cache.lock().unwrap_or_else(|p| p.into_inner()).len()
    }
}

/// Build an i64 literal of the given shape.
pub fn literal_i64(data: &[i64], shape: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

/// Build an f32 literal of the given shape.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

/// Convert a PJRT output literal (s64) into a ring tensor.
pub fn literal_to_u64(lit: xla::Literal) -> Result<TensorU64> {
    let shape = literal_dims(&lit)?;
    let data = lit.to_vec::<i64>()?;
    TensorU64::from_i64(shape, data)
}

/// Convert a PJRT output literal (f32).
pub fn literal_to_f32(lit: xla::Literal) -> Result<(Vec<f32>, Vec<usize>)> {
    let shape = literal_dims(&lit)?;
    let data = lit.to_vec::<f32>()?;
    Ok((data, shape))
}

fn literal_dims(lit: &xla::Literal) -> Result<Vec<usize>> {
    let shape = lit.array_shape()?;
    Ok(shape.dims().iter().map(|d| *d as usize).collect())
}

#[cfg(test)]
mod tests {
    // Runtime tests live in rust/tests/runtime_xla.rs (they need the
    // artifacts directory built by `make artifacts`).
}
