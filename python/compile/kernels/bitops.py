"""Layer-1 Pallas kernels: the GMW engine's elementwise hot path.

Every kernel here is the local compute of one protocol step (masked Beaver
openings and combines, Kogge-Stone stage operand construction). They lower
with ``interpret=True`` so the CPU PJRT plugin can execute the resulting
HLO (real-TPU Pallas lowering emits Mosaic custom-calls the CPU client
cannot run — see DESIGN.md §Hardware-Adaptation).

TPU mapping notes (what the BlockSpecs express):
  * These are VPU-shaped lane-wise ops on int64 — we tile the flat element
    axis into (BLOCK,) chunks sized so that all operands of one grid step
    fit VMEM comfortably: 6 operands x BLOCK x 8 B = 384 KiB at
    BLOCK = 8192, ~2.4% of a v5 core's 16 MiB VMEM, leaving room for
    double-buffering the HBM->VMEM pipeline.
  * Scalars (shift amount, lane mask, leader mask) ride in SMEM via scalar
    prefetch (here: plain operands broadcast by the index_map returning the
    same block for every grid step).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

I64 = jnp.int64
BLOCK = 8192


def _block(n):
    """Tile size for a flat length-n array (small buckets use one tile)."""
    return min(n, BLOCK)


def _flat_spec(n):
    return pl.BlockSpec((_block(n),), lambda i: (i,))


def _scalar_spec():
    # One (1,)-shaped block, same for every grid step.
    return pl.BlockSpec((1,), lambda i: (0,))


def _row2_spec(n):
    # Output rows [d; e]: block covers both rows for the current column tile.
    return pl.BlockSpec((2, _block(n)), lambda i: (0, i))


def _grid(n):
    b = _block(n)
    assert n % b == 0, f"bucket size {n} must be a multiple of {b}"
    return (n // b,)


# ---------------------------------------------------------------------------
# Beaver-AND opening / combine.
# ---------------------------------------------------------------------------

def _and_open_kernel(u_ref, v_ref, a_ref, b_ref, de_ref):
    de_ref[0, :] = u_ref[...] ^ a_ref[...]
    de_ref[1, :] = v_ref[...] ^ b_ref[...]


def and_open(u, v, a, b):
    """de[0] = u ^ a, de[1] = v ^ b  (shape [2, n])."""
    n = u.shape[0]
    return pl.pallas_call(
        _and_open_kernel,
        grid=_grid(n),
        in_specs=[_flat_spec(n)] * 4,
        out_specs=_row2_spec(n),
        out_shape=jax.ShapeDtypeStruct((2, n), I64),
        interpret=True,
    )(u, v, a, b)


def _and_combine_kernel(d_ref, e_ref, a_ref, b_ref, c_ref, lead_ref, z_ref):
    d = d_ref[...]
    e = e_ref[...]
    lead = lead_ref[0]
    z_ref[...] = ((d & e) & lead) ^ (d & b_ref[...]) ^ (e & a_ref[...]) ^ c_ref[...]


def and_combine(d, e, a, b, c, leader_mask):
    """z = (leader? d&e) ^ d&b ^ e&a ^ c. leader_mask: int64 [1] (0 or -1)."""
    n = d.shape[0]
    return pl.pallas_call(
        _and_combine_kernel,
        grid=_grid(n),
        in_specs=[_flat_spec(n)] * 5 + [_scalar_spec()],
        out_specs=_flat_spec(n),
        out_shape=jax.ShapeDtypeStruct((n,), I64),
        interpret=True,
    )(d, e, a, b, c, leader_mask)


# ---------------------------------------------------------------------------
# Kogge-Stone stage operands.
# ---------------------------------------------------------------------------

def _ks_stage_mid_kernel(g_ref, p_ref, s_ref, m_ref, u_ref, v_ref):
    p = p_ref[...]
    s = s_ref[0]
    mask = m_ref[0]
    u_ref[0, :] = p
    u_ref[1, :] = p
    v_ref[0, :] = (g_ref[...] << s) & mask
    v_ref[1, :] = (p << s) & mask


def ks_stage_mid(g, p, s, mask):
    """Mid-stage operands: u=[p;p], v=[(g<<s)&mask;(p<<s)&mask]."""
    n = g.shape[0]
    return pl.pallas_call(
        _ks_stage_mid_kernel,
        grid=_grid(n),
        in_specs=[_flat_spec(n), _flat_spec(n), _scalar_spec(), _scalar_spec()],
        out_specs=[_row2_spec(n), _row2_spec(n)],
        out_shape=[jax.ShapeDtypeStruct((2, n), I64)] * 2,
        interpret=True,
    )(g, p, s, mask)


def _ks_stage_last_kernel(g_ref, p_ref, s_ref, m_ref, u_ref, v_ref):
    u_ref[...] = p_ref[...]
    v_ref[...] = (g_ref[...] << s_ref[0]) & m_ref[0]


def ks_stage_last(g, p, s, mask):
    """Final-stage operands: u=p, v=(g<<s)&mask (the P update is skipped)."""
    n = g.shape[0]
    return pl.pallas_call(
        _ks_stage_last_kernel,
        grid=_grid(n),
        in_specs=[_flat_spec(n), _flat_spec(n), _scalar_spec(), _scalar_spec()],
        out_specs=[_flat_spec(n), _flat_spec(n)],
        out_shape=[jax.ShapeDtypeStruct((n,), I64)] * 2,
        interpret=True,
    )(g, p, s, mask)


# ---------------------------------------------------------------------------
# Beaver arithmetic multiplication.
# ---------------------------------------------------------------------------

def _mult_open_kernel(x_ref, y_ref, a_ref, b_ref, de_ref):
    de_ref[0, :] = x_ref[...] - a_ref[...]
    de_ref[1, :] = y_ref[...] - b_ref[...]


def mult_open(x, y, a, b):
    """de[0] = x - a, de[1] = y - b (mod 2^64)."""
    n = x.shape[0]
    return pl.pallas_call(
        _mult_open_kernel,
        grid=_grid(n),
        in_specs=[_flat_spec(n)] * 4,
        out_specs=_row2_spec(n),
        out_shape=jax.ShapeDtypeStruct((2, n), I64),
        interpret=True,
    )(x, y, a, b)


def _mult_combine_kernel(d_ref, e_ref, a_ref, b_ref, c_ref, lead_ref, z_ref):
    d = d_ref[...]
    e = e_ref[...]
    z_ref[...] = (
        c_ref[...] + d * b_ref[...] + e * a_ref[...] + (d * e) * (lead_ref[0] & 1)
    )


def mult_combine(d, e, a, b, c, leader_mask):
    """z = c + d*b + e*a + (leader? d*e) (mod 2^64)."""
    n = d.shape[0]
    return pl.pallas_call(
        _mult_combine_kernel,
        grid=_grid(n),
        in_specs=[_flat_spec(n)] * 5 + [_scalar_spec()],
        out_specs=_flat_spec(n),
        out_shape=jax.ShapeDtypeStruct((n,), I64),
        interpret=True,
    )(d, e, a, b, c, leader_mask)


# Names -> (callable, number of vector operands) for the AOT driver.
KERNELS = {
    "and_open": (and_open, 4),
    "and_combine": (and_combine, 5),  # + leader scalar
    "ks_stage_mid": (ks_stage_mid, 2),  # + s, mask scalars
    "ks_stage_last": (ks_stage_last, 2),  # + s, mask scalars
    "mult_open": (mult_open, 4),
    "mult_combine": (mult_combine, 5),  # + leader scalar
}
