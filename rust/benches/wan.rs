//! WAN round-scheduling benchmark (DESIGN.md §10): serial vs overlapped
//! chunked DReLU over a real-clock [`SimTransport`] at RTT ∈ {1, 20, 50} ms.
//!
//! The success metric for the overlapped scheduler: at 50 ms RTT the
//! overlapped end-to-end time should approach `max(compute, wire)` (within
//! ~1.15×), while the serial schedule pays ≈ their sum — every one of its
//! `rounds` pays a full one-way latency, versus once per lockstep *wave*
//! for the overlapped schedule. Rows land in `BENCH_wan.json` as
//! `wan/rtt<ms>/{serial,overlapped}_s` plus the shared
//! `wan/{compute_s,rounds,waves,bytes}` scalars; see benchmarks/README.md.

use std::sync::Arc;
use std::time::Instant;

use hummingbird::crypto::prg::Prg;
use hummingbird::gmw::{GmwParty, ReluPlan};
use hummingbird::net::accounting::CommTrace;
use hummingbird::net::local::hub;
use hummingbird::net::profile::NetworkProfile;
use hummingbird::net::sim::SimTransport;
use hummingbird::net::Transport;
use hummingbird::sharing::share_arith;
use hummingbird::util::benchkit::Bench;

const PARTIES: usize = 2;
const CHUNKS: usize = 8;
const SEED: u64 = 0x5117;

fn drive<T: Transport + 'static>(t: T, share: &[u64], plan: ReluPlan, overlap: bool) {
    let mut party = GmwParty::new(t, SEED);
    party.drelu_chunked(share, plan, CHUNKS, overlap).unwrap();
}

/// One 2-party chunked DReLU run; endpoints are wrapped in a real-clock
/// [`SimTransport`] when `profile` is set. Returns wall seconds and
/// party 0's trace.
fn run(
    xs: &[Vec<u64>],
    plan: ReluPlan,
    profile: Option<&NetworkProfile>,
    overlap: bool,
) -> (f64, Arc<CommTrace>) {
    let mut ts = hub(PARTIES);
    let t1 = ts.pop().unwrap();
    let t0 = ts.pop().unwrap();
    let trace = t0.trace();
    let start = Instant::now();
    std::thread::scope(|s| {
        for (t, share) in [t0, t1].into_iter().zip(xs) {
            s.spawn(move || match profile {
                Some(np) => drive(SimTransport::new(t, np.clone()), share, plan, overlap),
                None => drive(t, share, plan, overlap),
            });
        }
    });
    (start.elapsed().as_secs_f64(), trace)
}

fn main() {
    let mut bench = Bench::new();
    let quick = std::env::var("HB_BENCH_QUICK").ok().as_deref() == Some("1");
    let n = if quick { 4096 } else { 16384 };
    let plan = ReluPlan::new(12, 4).unwrap(); // w = 8 window bits
    let mut prg = Prg::new(3, 3);
    let x: Vec<u64> = (0..n).map(|_| prg.next_u64() % (1 << 16)).collect();
    let xs = share_arith(&mut prg, &x, PARTIES);

    // Compute-only floor: the same chunked schedule over the raw
    // in-process hub (best of 3 to shed scheduler noise). The trace gives
    // the exact round/byte counts for the analytic wire bounds.
    let mut compute_s = f64::MAX;
    let (s0, trace) = run(&xs, plan, None, false);
    compute_s = compute_s.min(s0);
    for _ in 0..2 {
        let (s, _) = run(&xs, plan, None, false);
        compute_s = compute_s.min(s);
    }
    let rounds = trace.total_rounds();
    let bytes = trace.total_bytes();
    // The overlapped schedule runs the serial per-chunk round program in
    // lockstep waves across all chunks: one latency per wave, not per round.
    let waves = rounds / CHUNKS as u64;
    bench.note_metric("wan/rounds", rounds as f64);
    bench.note_metric("wan/waves", waves as f64);
    bench.note_metric("wan/bytes", bytes as f64);
    bench.note_metric("wan/compute_s", compute_s);

    println!();
    println!(
        "chunked DReLU, n={n}, chunks={CHUNKS}, w={}, {rounds} rounds in {waves} waves",
        plan.k - plan.m
    );
    println!(
        "| RTT ms | serial | overlapped | wire(serial) | wire(overlap) | \
         overlap/max | serial/max |"
    );
    println!(
        "|-------:|-------:|-----------:|-------------:|--------------:|\
         ------------:|-----------:|"
    );
    for rtt_ms in [1u64, 20, 50] {
        // One-way latency = RTT/2 (see net::profile's latency convention);
        // 352 Mbps is the paper's WAN bandwidth.
        let np =
            NetworkProfile::new(&format!("rtt{rtt_ms}ms"), rtt_ms as f64 * 1e-3 / 2.0, 352e6);
        let tx = bytes as f64 * 8.0 / np.bandwidth_bps;
        let wire_serial = rounds as f64 * np.latency_s + tx;
        let wire_overlap = waves as f64 * np.latency_s + tx;
        let (serial_s, _) = run(&xs, plan, Some(&np), false);
        let (overlap_s, _) = run(&xs, plan, Some(&np), true);
        // The §10 bound: overlapped e2e should approach max(compute, wire);
        // serial pays ≈ compute + wire_serial.
        let bound = compute_s.max(wire_overlap);
        let overlap_ratio = overlap_s / bound;
        let serial_ratio = serial_s / bound;
        println!(
            "| {rtt_ms:>6} | {serial_s:>6.3} | {overlap_s:>10.3} | {wire_serial:>12.3} | \
             {wire_overlap:>13.3} | {overlap_ratio:>11.2} | {serial_ratio:>10.2} |"
        );
        bench.note_metric(&format!("wan/rtt{rtt_ms}/serial_s"), serial_s);
        bench.note_metric(&format!("wan/rtt{rtt_ms}/overlapped_s"), overlap_s);
        bench.note_metric(&format!("wan/rtt{rtt_ms}/wire_overlap_s"), wire_overlap);
        bench.note_metric(&format!("wan/rtt{rtt_ms}/overlap_over_max"), overlap_ratio);
    }
    println!("(target: overlapped <= 1.15 x max(compute, wire) at 50 ms RTT; DESIGN.md §10)");
    bench.dump_json("wan");
}
