//! Layout-equivalence suite: the bitsliced binary engine must be
//! **bit-identical** to the lane-per-u64 reference — per-party output
//! shares, wire byte counts and round counts — for every window width,
//! lane count (including non-multiples of 64, which exercise the
//! unaligned transpose-pack path), party count and thread count. The
//! byte-level identity of the transpose-fused wire boundary itself is
//! pinned by the unit tests in `gmw::bitsliced`; here we pin the protocol
//! built on top of it, plus the zero-allocation steady state.

use hummingbird::crypto::prg::Prg;
use hummingbird::gmw::harness::{run_parties_with, run_parties_with_threaded, HarnessRun};
use hummingbird::gmw::kernels::{BitslicedKernels, RustKernels};
use hummingbird::gmw::{adder, bitsliced, ReluPlan};
use hummingbird::net::accounting::Phase;
use hummingbird::ring;
use hummingbird::sharing::{reconstruct_arith, reconstruct_binary, share_arith, share_binary};

/// Run the same protocol body under both kernel backends. The closure
/// literal is expanded twice so each copy monomorphizes against its own
/// party type — the engine API is layout-agnostic (lane-form in/out), so
/// one body serves both.
macro_rules! run_both_layouts {
    ($parties:expr, $seed:expr, $threads:expr, $body:expr) => {{
        let lane =
            run_parties_with_threaded($parties, $seed, $threads, |_| RustKernels::default(), $body);
        let sliced = run_parties_with_threaded(
            $parties,
            $seed,
            $threads,
            |_| BitslicedKernels::default(),
            $body,
        );
        (lane, sliced)
    }};
}

/// Per-party outputs and communication accounting must match exactly.
fn assert_runs_equal<R: PartialEq + std::fmt::Debug>(
    lane: &HarnessRun<R>,
    sliced: &HarnessRun<R>,
    ctx: &str,
) {
    assert_eq!(lane.outputs, sliced.outputs, "per-party outputs differ: {ctx}");
    assert_eq!(
        lane.trace.total_bytes(),
        sliced.trace.total_bytes(),
        "wire bytes differ: {ctx}"
    );
    assert_eq!(
        lane.trace.total_rounds(),
        sliced.trace.total_rounds(),
        "round counts differ: {ctx}"
    );
}

/// ks_add across the full width sweep and awkward lane counts: outputs,
/// bytes and rounds identical across layouts, and correct vs plaintext.
#[test]
fn ks_add_bitsliced_matches_lane_layout() {
    for parties in [2usize, 3] {
        for w in [1u32, 2, 3, 5, 6, 8, 13, 16, 21, 32, 48, 64] {
            for n in [1usize, 40, 65, 130] {
                let mut prg = Prg::new(1000 + w as u64, n as u64 + parties as u64);
                let mask = ring::low_mask(w);
                let x: Vec<u64> = (0..n).map(|_| prg.next_u64() & mask).collect();
                let y: Vec<u64> = (0..n).map(|_| prg.next_u64() & mask).collect();
                let xs: Vec<Vec<u64>> = share_binary(&mut prg, &x, parties)
                    .iter()
                    .map(|s| s.iter().map(|v| v & mask).collect())
                    .collect();
                let ys: Vec<Vec<u64>> = share_binary(&mut prg, &y, parties)
                    .iter()
                    .map(|s| s.iter().map(|v| v & mask).collect())
                    .collect();
                let ctx = format!("ks_add parties={parties} w={w} n={n}");
                let (lane, sliced) = run_both_layouts!(parties, 7, 1, |p| {
                    let me = p.party();
                    adder::ks_add(p, &xs[me], &ys[me], w).unwrap()
                });
                assert_runs_equal(&lane, &sliced, &ctx);
                let z = reconstruct_binary(&lane.outputs);
                let expect: Vec<u64> =
                    x.iter().zip(&y).map(|(a, b)| a.wrapping_add(*b) & mask).collect();
                assert_eq!(z, expect, "{ctx}");
            }
        }
    }
}

/// DReLU and ReLU across the paper's (k, m) windows — including w = 1
/// (k = m + 1), the full-ring baseline and pruning windows — at lane
/// counts that straddle block boundaries and several thread counts.
#[test]
fn relu_bit_identical_across_layouts_and_threads() {
    let windows = [
        ReluPlan::BASELINE,
        ReluPlan::new(20, 0).unwrap(),
        ReluPlan::new(12, 4).unwrap(),
        ReluPlan::new(10, 4).unwrap(),
        ReluPlan::new(8, 7).unwrap(), // w = 1
        ReluPlan::new(6, 0).unwrap(),
    ];
    let default_threads = hummingbird::util::threadpool::default_threads();
    for parties in [2usize, 3] {
        for plan in windows {
            for n in [33usize, 256, 321] {
                let mut prg = Prg::new(9 + plan.k as u64 * 67 + plan.m as u64, n as u64);
                let x: Vec<u64> = (0..n)
                    .map(|i| {
                        let v = prg.next_u64() % (1u64 << (plan.k.max(2) - 1));
                        if i % 2 == 0 {
                            v
                        } else {
                            v.wrapping_neg()
                        }
                    })
                    .collect();
                let xs = share_arith(&mut prg, &x, parties);
                for threads in [1usize, 2, default_threads] {
                    let ctx = format!(
                        "relu parties={parties} k={} m={} n={n} threads={threads}",
                        plan.k, plan.m
                    );
                    let (lane, sliced) = run_both_layouts!(parties, 5, threads, |p| {
                        let me = p.party();
                        let d = p.drelu(&xs[me], plan).unwrap();
                        let r = p.relu(&xs[me], plan).unwrap();
                        (d, r)
                    });
                    assert_runs_equal(&lane, &sliced, &ctx);
                }
            }
        }
    }
}

/// Kernel axis (DESIGN.md §11): the forced-scalar arm and the
/// auto-dispatched arm (AVX2 where the CPU has it) are bit-identical
/// through DReLU + ReLU — shares, wire bytes, rounds — in both layouts,
/// for 2/3 parties and 1/N threads. On hardware without AVX2 (or under
/// `HB_KERNEL=scalar`) the arms coincide and this pins the dispatch
/// plumbing instead; the per-primitive sweep lives in
/// `tests/kernel_diff.rs`.
#[test]
fn relu_kernel_arms_bit_identical_across_layouts() {
    let plan = ReluPlan::new(12, 4).unwrap();
    let n = 193usize; // straddles three 64-lane blocks
    for parties in [2usize, 3] {
        let mut prg = Prg::new(0x5EED, parties as u64);
        let x: Vec<u64> = (0..n)
            .map(|i| {
                let v = prg.next_u64() % (1 << 11);
                if i % 2 == 0 {
                    v
                } else {
                    v.wrapping_neg()
                }
            })
            .collect();
        let xs = share_arith(&mut prg, &x, parties);
        for threads in [1usize, 2] {
            let ctx = format!("kernel-axis parties={parties} threads={threads}");
            let (lane_auto, sliced_auto) = run_both_layouts!(parties, 5, threads, |p| {
                let me = p.party();
                (p.drelu(&xs[me], plan).unwrap(), p.relu(&xs[me], plan).unwrap())
            });
            let lane_scalar =
                run_parties_with_threaded(parties, 5, threads, |_| RustKernels::scalar(), |p| {
                    let me = p.party();
                    (p.drelu(&xs[me], plan).unwrap(), p.relu(&xs[me], plan).unwrap())
                });
            let sliced_scalar = run_parties_with_threaded(
                parties,
                5,
                threads,
                |_| BitslicedKernels::scalar(),
                |p| {
                    let me = p.party();
                    (p.drelu(&xs[me], plan).unwrap(), p.relu(&xs[me], plan).unwrap())
                },
            );
            assert_runs_equal(&lane_scalar, &lane_auto, &format!("{ctx} lane scalar-vs-auto"));
            assert_runs_equal(
                &sliced_scalar,
                &sliced_auto,
                &format!("{ctx} bitsliced scalar-vs-auto"),
            );
            assert_runs_equal(&lane_scalar, &sliced_scalar, &format!("{ctx} cross-layout"));
        }
    }
}

/// A2B equivalence: the layout branch in `a2b_into` (planes + final
/// back-transpose) returns the very same binary lane shares.
#[test]
fn a2b_bitsliced_matches_lane_layout() {
    for parties in [2usize, 3] {
        for w in [4u32, 9, 16, 33, 64] {
            let n = 100usize;
            let mut prg = Prg::new(300 + w as u64, parties as u64);
            let x: Vec<u64> = prg.vec_u64(n);
            let xs = share_arith(&mut prg, &x, parties);
            let ctx = format!("a2b parties={parties} w={w}");
            let (lane, sliced) = run_both_layouts!(parties, 1234, 1, |p| {
                let me = p.party();
                p.a2b(&xs[me], w).unwrap()
            });
            assert_runs_equal(&lane, &sliced, &ctx);
            let mask = ring::low_mask(w);
            let expect: Vec<u64> = x.iter().map(|v| v & mask).collect();
            assert_eq!(reconstruct_binary(&lane.outputs), expect, "{ctx}");
        }
    }
}

/// Adder design knobs (ablation paths) behave identically in both
/// layouts: unbatched stages and kept last-P produce the same shares and
/// the same (larger) byte/round counts.
#[test]
fn adder_options_equivalent_across_layouts() {
    use adder::AdderOptions;
    let w = 12u32;
    let n = 77usize;
    let mut prg = Prg::new(55, 0);
    let mask = ring::low_mask(w);
    let x: Vec<u64> = (0..n).map(|_| prg.next_u64() & mask).collect();
    let y: Vec<u64> = (0..n).map(|_| prg.next_u64() & mask).collect();
    let xs: Vec<Vec<u64>> = share_binary(&mut prg, &x, 2)
        .iter()
        .map(|s| s.iter().map(|v| v & mask).collect())
        .collect();
    let ys: Vec<Vec<u64>> = share_binary(&mut prg, &y, 2)
        .iter()
        .map(|s| s.iter().map(|v| v & mask).collect())
        .collect();
    for opts in [
        AdderOptions::default(),
        AdderOptions { skip_last_p: false, ..Default::default() },
        AdderOptions { batch_stage_ands: false, skip_last_p: false },
    ] {
        let ctx = format!("adder opts={opts:?}");
        let (lane, sliced) = run_both_layouts!(2, 21, 1, |p| {
            let me = p.party();
            adder::ks_add_with(p, &xs[me], &ys[me], w, opts).unwrap()
        });
        assert_runs_equal(&lane, &sliced, &ctx);
        let expect: Vec<u64> = x.iter().zip(&y).map(|(a, b)| a.wrapping_add(*b) & mask).collect();
        assert_eq!(reconstruct_binary(&lane.outputs), expect, "{ctx}");
    }
}

/// The lane-form public AND API keeps its classic semantics on a
/// bitsliced party (element-wise ops are layout-agnostic), so mixed use
/// is safe.
#[test]
fn lane_form_and_gates_work_on_bitsliced_party() {
    let n = 64usize;
    let mut prg = Prg::new(10, 0);
    let x: Vec<u64> = prg.vec_u64(n);
    let y: Vec<u64> = prg.vec_u64(n);
    let xs = share_binary(&mut prg, &x, 2);
    let ys = share_binary(&mut prg, &y, 2);
    let run = run_parties_with(2, 42, |_| BitslicedKernels::default(), |p| {
        let me = p.party();
        p.and_gates(Phase::Circuit, &xs[me], &ys[me], 64).unwrap()
    });
    let z = reconstruct_binary(&run.outputs);
    let expect: Vec<u64> = x.iter().zip(&y).map(|(a, b)| a & b).collect();
    assert_eq!(z, expect);
}

/// Plane-native triple equivalence (the shared dealer stream): per-party
/// output shares, wire bytes, round counts *and* the full `TripleUsage`
/// (plane words, lanes served, PRG words drawn) are identical across
/// layouts — for the paper-relevant widths incl. w = 1 and w = 64, lane
/// counts that are not block multiples, 2/3 parties and 1/N threads.
/// Equality is pinned layout-vs-layout rather than against golden values:
/// the plane-native stream intentionally differs from the old lane-form
/// dealer stream.
#[test]
fn plane_native_triples_equivalent_across_layouts() {
    let default_threads = hummingbird::util::threadpool::default_threads();
    for parties in [2usize, 3] {
        for w in [1u32, 6, 18, 64] {
            for n in [1usize, 65, 321] {
                let mut prg = Prg::new(4000 + w as u64, n as u64 + parties as u64);
                let mask = ring::low_mask(w);
                let x: Vec<u64> = (0..n).map(|_| prg.next_u64() & mask).collect();
                let y: Vec<u64> = (0..n).map(|_| prg.next_u64() & mask).collect();
                let xs: Vec<Vec<u64>> = share_binary(&mut prg, &x, parties)
                    .iter()
                    .map(|s| s.iter().map(|v| v & mask).collect())
                    .collect();
                let ys: Vec<Vec<u64>> = share_binary(&mut prg, &y, parties)
                    .iter()
                    .map(|s| s.iter().map(|v| v & mask).collect())
                    .collect();
                for threads in [1usize, default_threads] {
                    let ctx = format!("triples parties={parties} w={w} n={n} threads={threads}");
                    let (lane, sliced) = run_both_layouts!(parties, 17, threads, |p| {
                        let me = p.party();
                        let sum = adder::ks_add(p, &xs[me], &ys[me], w).unwrap();
                        (sum, p.triple_usage())
                    });
                    // Outputs include each party's TripleUsage snapshot, so
                    // this pins identical stream consumption per party.
                    assert_runs_equal(&lane, &sliced, &ctx);
                    let sums: Vec<Vec<u64>> =
                        lane.outputs.iter().map(|(s, _)| s.clone()).collect();
                    let expect: Vec<u64> =
                        x.iter().zip(&y).map(|(a, b)| a.wrapping_add(*b) & mask).collect();
                    assert_eq!(reconstruct_binary(&sums), expect, "{ctx}");
                    let usage = lane.outputs[0].1;
                    if w > 1 {
                        assert!(usage.bin_triple_lanes > 0, "{ctx}");
                        // The PRG-savings invariant: reduced widths draw
                        // fewer plane words than AND lanes served.
                        if w < 64 && n >= 65 {
                            assert!(
                                usage.bin_plane_words < usage.bin_triple_lanes,
                                "{ctx}: plane_words={} lanes={}",
                                usage.bin_plane_words,
                                usage.bin_triple_lanes
                            );
                        }
                    }
                    if threads == default_threads && default_threads == 1 {
                        break;
                    }
                }
            }
        }
    }
}

/// Steady-state pin for the tentpole's deleted work: with the dealer
/// emitting triples in packed wire order, a warm bitsliced DReLU performs
/// exactly `parties` lane→plane conversions per call (the A2B operand
/// staging) and **zero** triple transposes at AND round boundaries. The
/// counter is thread-local and each party runs on its own thread, so the
/// delta is exact even with other tests running concurrently.
#[test]
fn bitsliced_and_path_performs_zero_triple_transposes() {
    for parties in [2usize, 3] {
        let n = 321usize;
        let mut prg = Prg::new(90, parties as u64);
        let x: Vec<u64> = (0..n).map(|_| prg.next_u64() % (1 << 16)).collect();
        let xs = share_arith(&mut prg, &x, parties);
        let plan = ReluPlan::new(12, 4).unwrap();
        run_parties_with(parties, 11, |_| BitslicedKernels::default(), |p| {
            let me = p.party();
            let mut out = vec![0u64; n];
            // Warmup fills the arena pools.
            p.drelu_into(&xs[me], plan, &mut out).unwrap();
            let t0 = bitsliced::thread_transpose_ops();
            p.drelu_into(&xs[me], plan, &mut out).unwrap();
            let steady = bitsliced::thread_transpose_ops() - t0;
            assert_eq!(
                steady, parties as u64,
                "bitsliced DReLU must transpose only the {parties} A2B operands \
                 (zero triple transposes), got {steady}"
            );
            out
        });
    }
}

/// The zero-allocation steady state holds in the bitsliced layout too:
/// after one warmup ReLU, further rounds miss neither the scratch arena
/// (plane buffers included) nor the transport pools, and check every
/// buffer back in — the same invariants `relu_steady_state_is_allocation_free`
/// pins for the lane layout.
#[test]
fn bitsliced_relu_steady_state_is_allocation_free() {
    let parties = 2;
    let mut prg = Prg::new(40, 0);
    let n = 512;
    let x: Vec<u64> = (0..n).map(|_| prg.next_u64() % (1 << 16)).collect();
    let xs = share_arith(&mut prg, &x, parties);
    let plan = ReluPlan::new(12, 4).unwrap();
    let run = run_parties_with(parties, 6, |_| BitslicedKernels::default(), |p| {
        let me = p.party();
        let mut out = vec![0u64; n];
        p.relu_into(&xs[me], plan, &mut out).unwrap();
        let warm = p.arena_stats();
        let warm_net = p.transport.pool_stats();
        assert_eq!(warm.checkouts, warm.returns, "buffers leaked during warmup");
        assert_eq!(warm_net.checkouts, warm_net.returns, "transport payloads leaked");
        for round in 0..3 {
            p.relu_into(&xs[me], plan, &mut out).unwrap();
            let s = p.arena_stats();
            assert_eq!(
                s.alloc_misses, warm.alloc_misses,
                "steady-state bitsliced relu allocated (round {round})"
            );
            assert_eq!(s.checkouts, s.returns, "unbalanced checkout (round {round})");
            let t = p.transport.pool_stats();
            assert_eq!(
                t.alloc_misses, warm_net.alloc_misses,
                "steady-state bitsliced relu allocated a transport payload (round {round})"
            );
            assert_eq!(t.checkouts, t.returns, "unbalanced payload checkout (round {round})");
        }
        out
    });
    let z = reconstruct_arith(&run.outputs);
    for (xi, zi) in x.iter().zip(&z) {
        assert!(*zi == 0 || zi == xi);
    }
}
