//! Crash-loop breaker for session respawn (DESIGN.md §9).
//!
//! PR 6 made the batcher respawn a faulted party session
//! *unconditionally*: a deterministic boot failure (bad artifact path,
//! bind failure, a poisoned prefetcher) became a hot respawn loop. The
//! [`RestartBreaker`] gives respawn a budget: each consecutive session
//! failure inside a sliding window earns an exponentially growing
//! backoff, and once `max_restarts` consecutive failures accumulate the
//! breaker **trips** — the coordinator enters the `Degraded` lifecycle
//! state (answering [`Overloaded`](crate::error::Error::Overloaded)
//! immediately) while a background probe retries the boot with capped
//! backoff. The first successful boot closes the breaker and returns
//! the service to `Serving`.
//!
//! All timing flows through the injected [`Clock`] so the chaos suite
//! pins breaker behaviour deterministically (a [`MockClock`] advances
//! only when the test says so — no wall-clock sleeps in assertions, see
//! `tests/fault_injection.rs` and `tests/soak.rs`).

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// First respawn backoff; doubles per consecutive failure.
pub const RESTART_BACKOFF_BASE: Duration = Duration::from_millis(50);
/// Respawn backoff cap.
pub const RESTART_BACKOFF_CAP: Duration = Duration::from_secs(2);
/// First degraded-probe backoff; doubles per failed probe.
pub const PROBE_BACKOFF_BASE: Duration = Duration::from_millis(100);
/// Degraded-probe backoff cap.
pub const PROBE_BACKOFF_CAP: Duration = Duration::from_secs(5);

/// Time source for the breaker. `now()` is a monotonic offset from an
/// arbitrary origin; `sleep(d)` blocks (or, for a mock, advances or
/// yields) for `d`. Injected via [`ClockHandle`] so tests control time.
pub trait Clock: Send + Sync {
    /// Monotonic time since this clock's origin.
    fn now(&self) -> Duration;
    /// Wait out `d` on this clock's notion of time.
    fn sleep(&self, d: Duration);
}

/// Shared, cloneable handle to a [`Clock`] (lives in `ServeOptions`).
#[derive(Clone)]
pub struct ClockHandle(Arc<dyn Clock>);

impl ClockHandle {
    /// The production clock: real monotonic time, real sleeps.
    pub fn monotonic() -> ClockHandle {
        ClockHandle(Arc::new(MonotonicClock { origin: Instant::now() }))
    }

    /// A test-controlled clock plus the handle that advances it.
    pub fn mock() -> (ClockHandle, Arc<MockClock>) {
        let mock = Arc::new(MockClock::default());
        (ClockHandle(Arc::clone(&mock) as Arc<dyn Clock>), mock)
    }

    pub fn now(&self) -> Duration {
        self.0.now()
    }

    pub fn sleep(&self, d: Duration) {
        self.0.sleep(d)
    }
}

impl fmt::Debug for ClockHandle {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ClockHandle(now={:?})", self.0.now())
    }
}

impl Default for ClockHandle {
    fn default() -> Self {
        ClockHandle::monotonic()
    }
}

struct MonotonicClock {
    origin: Instant,
}

impl Clock for MonotonicClock {
    fn now(&self) -> Duration {
        self.origin.elapsed()
    }
    fn sleep(&self, d: Duration) {
        std::thread::sleep(d);
    }
}

/// Deterministic test clock: time advances **only** via
/// [`MockClock::advance`]. `sleep` yields the thread without advancing,
/// so a batcher waiting on a mock clock spins cooperatively until the
/// test moves time forward — breaker timing becomes a pure function of
/// the test script, not the scheduler.
#[derive(Default)]
pub struct MockClock {
    now_ns: AtomicU64,
}

impl MockClock {
    /// Move the clock forward by `d`.
    pub fn advance(&self, d: Duration) {
        self.now_ns.fetch_add(d.as_nanos() as u64, Ordering::SeqCst);
    }
}

impl Clock for MockClock {
    fn now(&self) -> Duration {
        Duration::from_nanos(self.now_ns.load(Ordering::SeqCst))
    }
    fn sleep(&self, _d: Duration) {
        std::thread::yield_now();
    }
}

/// What to do after a session failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerVerdict {
    /// Respawn after this backoff.
    Backoff(Duration),
    /// The budget is exhausted: enter `Degraded` and probe instead.
    Trip,
}

/// Exponential backoff: `base << n`, saturating at `cap`.
fn exp_backoff(base: Duration, n: u32, cap: Duration) -> Duration {
    let mult = 1u32.checked_shl(n.min(16)).unwrap_or(u32::MAX);
    base.checked_mul(mult).map_or(cap, |d| d.min(cap))
}

/// Consecutive-failure budget + backoff schedule for session respawn.
pub struct RestartBreaker {
    max_restarts: u32,
    window: Duration,
    clock: ClockHandle,
    consecutive: u32,
    window_start: Option<Duration>,
    probe_failures: u32,
}

impl RestartBreaker {
    /// `max_restarts` consecutive failures inside `window` trip the
    /// breaker. `max_restarts` is clamped to ≥ 1.
    pub fn new(max_restarts: u32, window: Duration, clock: ClockHandle) -> RestartBreaker {
        RestartBreaker {
            max_restarts: max_restarts.max(1),
            window,
            clock,
            consecutive: 0,
            window_start: None,
            probe_failures: 0,
        }
    }

    pub fn clock(&self) -> &ClockHandle {
        &self.clock
    }

    /// Record one session failure (boot failure or failed batch).
    /// Failures separated by more than `window` restart the count — only
    /// *consecutive in-window* failures trip the breaker.
    pub fn on_failure(&mut self) -> BreakerVerdict {
        let now = self.clock.now();
        match self.window_start {
            Some(t0) if now.saturating_sub(t0) <= self.window => {}
            _ => {
                self.window_start = Some(now);
                self.consecutive = 0;
            }
        }
        self.consecutive += 1;
        if self.consecutive >= self.max_restarts {
            BreakerVerdict::Trip
        } else {
            BreakerVerdict::Backoff(exp_backoff(
                RESTART_BACKOFF_BASE,
                self.consecutive - 1,
                RESTART_BACKOFF_CAP,
            ))
        }
    }

    /// A session served a batch successfully (or a degraded probe
    /// booted): close the breaker and reset every budget.
    pub fn on_success(&mut self) {
        self.consecutive = 0;
        self.window_start = None;
        self.probe_failures = 0;
    }

    /// A degraded-state probe failed to boot: returns how long to wait
    /// before the next probe (exponential, capped).
    pub fn on_probe_failure(&mut self) -> Duration {
        let d = exp_backoff(PROBE_BACKOFF_BASE, self.probe_failures, PROBE_BACKOFF_CAP);
        self.probe_failures = self.probe_failures.saturating_add(1);
        d
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn mock_breaker(max: u32, window: Duration) -> (RestartBreaker, Arc<MockClock>) {
        let (clock, mock) = ClockHandle::mock();
        (RestartBreaker::new(max, window, clock), mock)
    }

    /// Consecutive failures earn doubling backoffs, then trip exactly at
    /// `max_restarts` — all on the mock clock, zero wall-clock sleeps.
    #[test]
    fn trips_after_max_consecutive_failures() {
        let (mut b, _mock) = mock_breaker(3, Duration::from_secs(30));
        assert_eq!(b.on_failure(), BreakerVerdict::Backoff(RESTART_BACKOFF_BASE));
        assert_eq!(b.on_failure(), BreakerVerdict::Backoff(RESTART_BACKOFF_BASE * 2));
        assert_eq!(b.on_failure(), BreakerVerdict::Trip);
        // Tripped state is sticky until a success.
        assert_eq!(b.on_failure(), BreakerVerdict::Trip);
        b.on_success();
        assert_eq!(b.on_failure(), BreakerVerdict::Backoff(RESTART_BACKOFF_BASE));
    }

    /// A failure outside the sliding window restarts the count: sparse
    /// failures never trip the breaker.
    #[test]
    fn window_expiry_resets_consecutive_count() {
        let (mut b, mock) = mock_breaker(2, Duration::from_secs(10));
        assert!(matches!(b.on_failure(), BreakerVerdict::Backoff(_)));
        mock.advance(Duration::from_secs(11));
        assert!(matches!(b.on_failure(), BreakerVerdict::Backoff(_)), "window must have reset");
        // Inside the fresh window the second failure trips.
        mock.advance(Duration::from_secs(1));
        assert_eq!(b.on_failure(), BreakerVerdict::Trip);
    }

    /// Backoffs cap instead of overflowing, for both schedules.
    #[test]
    fn backoffs_are_capped() {
        let (mut b, _mock) = mock_breaker(100, Duration::from_secs(3600));
        let mut last = Duration::ZERO;
        for _ in 0..40 {
            if let BreakerVerdict::Backoff(d) = b.on_failure() {
                assert!(d <= RESTART_BACKOFF_CAP);
                last = d;
            }
        }
        assert_eq!(last, RESTART_BACKOFF_CAP);
        let mut probe = Duration::ZERO;
        for _ in 0..40 {
            probe = b.on_probe_failure();
            assert!(probe <= PROBE_BACKOFF_CAP);
        }
        assert_eq!(probe, PROBE_BACKOFF_CAP);
        assert_eq!(
            exp_backoff(Duration::from_millis(1), 80, Duration::from_secs(5)),
            Duration::from_secs(5)
        );
    }

    /// `max_restarts = 0` is clamped to 1 (first failure trips) rather
    /// than wrapping into never-trip.
    #[test]
    fn zero_budget_trips_immediately() {
        let (mut b, _mock) = mock_breaker(0, Duration::from_secs(1));
        assert_eq!(b.on_failure(), BreakerVerdict::Trip);
    }

    /// The mock clock advances only explicitly; the monotonic clock
    /// actually moves.
    #[test]
    fn clocks_behave() {
        let (clock, mock) = ClockHandle::mock();
        let t0 = clock.now();
        clock.sleep(Duration::from_secs(5));
        assert_eq!(clock.now(), t0, "mock sleep must not advance time");
        mock.advance(Duration::from_millis(250));
        assert_eq!(clock.now() - t0, Duration::from_millis(250));

        let real = ClockHandle::monotonic();
        let a = real.now();
        real.sleep(Duration::from_millis(2));
        assert!(real.now() > a);
    }
}
