"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles (bit-exact).

Hypothesis sweeps shapes and values; because everything is integer ring
math, equality is exact (no allclose tolerance needed).
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bitops, matmul as kmm, ref

I64_MIN, I64_MAX = -(2**63), 2**63 - 1


def i64_array(n, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(I64_MIN, I64_MAX, n, dtype=np.int64))


# Bucket sizes the AOT driver lowers; kernels must be exact for all.
BUCKETS = [1024, 8192, 32768]


@pytest.mark.parametrize("n", BUCKETS)
def test_and_open_matches_ref(n):
    u, v, a, b = (i64_array(n, s) for s in range(4))
    got = bitops.and_open(u, v, a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.and_open(u, v, a, b)))


@pytest.mark.parametrize("n", BUCKETS)
@pytest.mark.parametrize("leader", [0, -1])
def test_and_combine_matches_ref(n, leader):
    d, e, a, b, c = (i64_array(n, s) for s in range(5))
    lead = jnp.asarray([leader], dtype=jnp.int64)
    got = bitops.and_combine(d, e, a, b, c, lead)
    want = ref.and_combine(d, e, a, b, c, lead[0])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n", [1024])
@pytest.mark.parametrize("w", [2, 6, 8, 20, 63, 64])
@pytest.mark.parametrize("s", [1, 2, 4, 16, 32])
def test_ks_stage_operands_match_ref(n, w, s):
    if s >= w:
        pytest.skip("stage shift always < width")
    g, p = i64_array(n, 10), i64_array(n, 11)
    mask = jnp.asarray([(1 << w) - 1 if w < 64 else -1], dtype=jnp.int64)
    sv = jnp.asarray([s], dtype=jnp.int64)
    # mask lanes as the engine does
    g = g & mask[0]
    p = p & mask[0]
    u, v = bitops.ks_stage_mid(g, p, sv, mask)
    ru, rv = ref.ks_stage_operands(g, p, sv[0], mask[0], last=False)
    np.testing.assert_array_equal(np.asarray(u), np.asarray(ru))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(rv))
    u, v = bitops.ks_stage_last(g, p, sv, mask)
    ru, rv = ref.ks_stage_operands(g, p, sv[0], mask[0], last=True)
    np.testing.assert_array_equal(np.asarray(u), np.asarray(ru[0]))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(rv[0]))


@pytest.mark.parametrize("n", BUCKETS)
def test_mult_open_combine_match_ref(n):
    x, y, a, b, c = (i64_array(n, 20 + s) for s in range(5))
    de = bitops.mult_open(x, y, a, b)
    np.testing.assert_array_equal(np.asarray(de), np.asarray(ref.mult_open(x, y, a, b)))
    for leader in (0, -1):
        lead = jnp.asarray([leader], dtype=jnp.int64)
        got = bitops.mult_combine(x, y, a, b, c, lead)
        want = ref.mult_combine(x, y, a, b, c, lead[0])
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_beaver_and_identity_end_to_end():
    """Plaintext sanity: open+combine across two simulated parties = AND."""
    n = 1024
    rng = np.random.default_rng(3)

    def r():
        return rng.integers(I64_MIN, I64_MAX, n, dtype=np.int64)

    x, y = r(), r()
    # share x, y, and a beaver triple
    x0, y0 = r(), r()
    x1, y1 = x ^ x0, y ^ y0
    a, b = r(), r()
    c = a & b
    a0, b0, c0 = r(), r(), r()
    a1, b1, c1 = a ^ a0, b ^ b0, c ^ c0
    j = jnp.asarray
    de0 = bitops.and_open(j(x0), j(y0), j(a0), j(b0))
    de1 = bitops.and_open(j(x1), j(y1), j(a1), j(b1))
    de = np.asarray(de0) ^ np.asarray(de1)  # public opening
    d, e = j(de[0]), j(de[1])
    z0 = bitops.and_combine(d, e, j(a0), j(b0), j(c0), j(np.asarray([-1], np.int64)))
    z1 = bitops.and_combine(d, e, j(a1), j(b1), j(c1), j(np.asarray([0], np.int64)))
    np.testing.assert_array_equal(np.asarray(z0) ^ np.asarray(z1), x & y)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 40),
    n=st.integers(1, 24),
    seed=st.integers(0, 2**31),
)
def test_share_matmul_matches_ref_hypothesis(m, k, n, seed):
    """Hypothesis sweep of arbitrary (unpadded) matmul shapes."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(I64_MIN, I64_MAX, (m, k), dtype=np.int64))
    w = jnp.asarray(rng.integers(I64_MIN, I64_MAX, (k, n), dtype=np.int64))
    got = kmm.share_matmul(x, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.share_matmul(x, w)))


def test_share_matmul_wraps_mod_2_64():
    x = jnp.asarray([[2**62, 2**62]], dtype=jnp.int64)
    w = jnp.asarray([[4], [4]], dtype=jnp.int64)
    got = np.asarray(kmm.share_matmul(x, w))
    # 2^64 + 2^64 = 0 (mod 2^64)
    assert got[0, 0] == 0
