//! Model substrate: config-driven CNN graphs, weight archives, the
//! plaintext executor (search / verification) and the share-domain
//! executor (the MPC inference path).

pub mod graph;
pub mod plain;
pub mod shares;
pub mod weights;

pub use graph::{ModelConfig, Op};
pub use plain::{Backend, PlainExecutor, WhichPlain};
pub use shares::{ExecBreakdown, ShareExecutor, ShareWeights};
pub use weights::Archive;

use crate::error::Result;
use crate::ring::FixedPoint;

/// A labeled dataset split loaded from `artifacts/data/<name>`.
#[derive(Debug, Clone)]
pub struct Split {
    /// [N, C, H, W] flattened.
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub n: usize,
    /// Per-sample element count (C*H*W).
    pub sample_elems: usize,
}

/// Dataset with train/val/test splits (we only load val/test in Rust).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub val: Split,
    pub test: Split,
}

impl Dataset {
    pub fn load(artifacts_root: impl AsRef<std::path::Path>, name: &str) -> Result<Dataset> {
        let prefix = artifacts_root.as_ref().join("data").join(name);
        let archive = Archive::load(&prefix)?;
        let split = |x: &str, y: &str| -> Result<Split> {
            let images_t = archive.get(x)?;
            let shape = images_t.shape().to_vec();
            let images = images_t.as_f32()?.to_vec();
            let labels = archive.get(y)?.as_i32()?.to_vec();
            let n = shape[0];
            Ok(Split { images, labels, n, sample_elems: shape[1..].iter().product() })
        };
        Ok(Dataset { val: split("val_x", "val_y")?, test: split("test_x", "test_y")? })
    }
}

impl Split {
    /// Borrow sample range [lo, hi) as a flat f32 slice.
    pub fn batch(&self, lo: usize, hi: usize) -> &[f32] {
        &self.images[lo * self.sample_elems..hi * self.sample_elems]
    }

    /// Quantize a batch to the ring.
    pub fn batch_ring(&self, lo: usize, hi: usize, fx: FixedPoint) -> Vec<u64> {
        self.batch(lo, hi).iter().map(|v| fx.encode(*v as f64)).collect()
    }
}
