//! In-process transport: parties run on threads in one process and exchange
//! buffers over `std::sync::mpsc` channels. This is the default testbed —
//! it gives *exact* byte/round accounting with zero serialization noise,
//! mirroring the paper's High-BW (single-node) setup; LAN/WAN numbers are
//! projected from the recorded trace (see [`super::profile`]) or measured
//! directly by wrapping each endpoint in [`super::sim::SimTransport`]
//! (DESIGN.md §10).
//!
//! # Send-buffer circulation
//!
//! Channel messages own their payload `Vec<u8>`, so a naive hub allocates
//! one payload per peer per round. Instead each endpoint keeps a
//! size-classed pool of payload buffers (the shared
//! [`Arena`](crate::util::arena::Arena)): sends check a buffer out of the
//! pool, and every payload *received* is recycled into the receiver's pool
//! after its bytes are copied into the caller's [`RecvBufs`]. Because the
//! protocol is symmetric (all parties send the same sizes every round),
//! buffers circulate around the hub and the steady state allocates
//! nothing; [`LocalTransport::pool_stats`] exposes the counters that pin
//! this in tests.

use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;

use super::accounting::{CommTrace, Phase};
use super::{NetConfig, RecvBufs, Transport};
use crate::error::{Error, Result};
use crate::util::arena::{Arena, ArenaStats};

/// Message envelope: (sender, sequence number, payload).
type Msg = (usize, u64, Vec<u8>);

/// One party's endpoint of the in-process hub.
pub struct LocalTransport {
    party: usize,
    parties: usize,
    /// senders[q] sends to party q (entry for self unused).
    senders: Vec<Option<Sender<Msg>>>,
    receiver: Receiver<Msg>,
    /// Per-peer reorder buffer: messages that arrived early.
    pending: Vec<Vec<(u64, Vec<u8>)>>,
    /// Next expected sequence number per peer.
    next_seq: Vec<u64>,
    /// My send sequence number (same for all peers; one round = one seq).
    seq: u64,
    /// Begun-but-unfinished rounds, oldest first: (seq, begin instant).
    /// `Copy` metadata only — payloads live on the channels, so pipelining
    /// adds no per-frame allocation.
    inflight: VecDeque<(u64, std::time::Instant)>,
    /// Size-classed pool of payload buffers (see module docs).
    pool: Arena,
    cfg: NetConfig,
    trace: Arc<CommTrace>,
}

/// Create a fully-connected hub of `parties` endpoints with default
/// deadlines.
pub fn hub(parties: usize) -> Vec<LocalTransport> {
    hub_with(parties, NetConfig::default())
}

/// Create a fully-connected hub with explicit deadlines: a peer thread that
/// fails to produce a round's bytes within `cfg.round_timeout` yields the
/// fatal [`Error::Timeout`] instead of wedging the caller (DESIGN.md §7).
pub fn hub_with(parties: usize, cfg: NetConfig) -> Vec<LocalTransport> {
    assert!(parties >= 2);
    // txs[q] feeds party q's receiver.
    let (txs, rxs): (Vec<Sender<Msg>>, Vec<Receiver<Msg>>) =
        (0..parties).map(|_| std::sync::mpsc::channel::<Msg>()).unzip();
    rxs.into_iter()
        .enumerate()
        .map(|(p, receiver)| LocalTransport {
            party: p,
            parties,
            senders: txs
                .iter()
                .enumerate()
                // HOT-PATH-ALLOW: session setup — one Sender per peer.
                .map(|(q, tx)| if q == p { None } else { Some(tx.clone()) })
                .collect(),
            receiver,
            // HOT-PATH-ALLOW: session setup — empty per-peer queues.
            pending: (0..parties).map(|_| Vec::new()).collect(),
            next_seq: vec![0; parties],
            seq: 0,
            inflight: VecDeque::new(),
            pool: Arena::new(),
            cfg,
            trace: Arc::new(CommTrace::new()),
        })
        // HOT-PATH-ALLOW: session setup — one transport struct per party.
        .collect()
}

impl LocalTransport {
    /// Allocation counters of the send-payload pool (steady-state rounds
    /// must not add `alloc_misses`).
    pub fn pool_stats(&self) -> ArenaStats {
        self.pool.stats()
    }

    /// Replace this endpoint's trace with a shared one. The coordinator
    /// uses this when it respawns a session after a fault so byte/round
    /// accounting keeps accumulating on the long-lived trace handed to
    /// the metrics layer.
    pub fn set_trace(&mut self, trace: Arc<CommTrace>) {
        self.trace = trace;
    }

    /// Check a payload buffer out of the pool, filled with `data` (a warm
    /// pool hit comes back sized to `data.len()` from its last round, so
    /// the fill is a plain overwrite).
    fn pool_take_filled(&mut self, data: &[u8]) -> Vec<u8> {
        let mut b = self.pool.take_bytes(data.len());
        RecvBufs::fill_slot(&mut b, data);
        b
    }

    fn recv_from(&mut self, peer: usize, want_seq: u64) -> Result<Vec<u8>> {
        // Check the reorder buffer first.
        if let Some(pos) = self.pending[peer].iter().position(|(s, _)| *s == want_seq) {
            return Ok(self.pending[peer].swap_remove(pos).1);
        }
        loop {
            let (from, seq, payload) = match self.receiver.recv_timeout(self.cfg.round_timeout) {
                Ok(msg) => msg,
                // A silent peer is a deadline expiry (fatal, DESIGN.md §7):
                // the job fails instead of wedging this thread.
                Err(RecvTimeoutError::Timeout) => {
                    return Err(Error::timeout(format!(
                        "party {}: no round data from peer {peer} within {:?}",
                        self.party, self.cfg.round_timeout
                    )))
                }
                // All senders gone: the peer threads crashed or shut down.
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(Error::Transport(format!(
                        "party {}: hub channels closed (peer threads gone)",
                        self.party
                    )))
                }
            };
            if from == peer && seq == want_seq {
                return Ok(payload);
            }
            self.pending[from].push((seq, payload));
        }
    }
}

impl Transport for LocalTransport {
    fn party(&self) -> usize {
        self.party
    }
    fn parties(&self) -> usize {
        self.parties
    }

    fn exchange_all_into(
        &mut self,
        phase: Phase,
        data: &[u8],
        recv: &mut RecvBufs,
    ) -> Result<()> {
        // Validate before anything hits the wire, so a mis-sized RecvBufs
        // fails without leaving a half-sent round behind.
        if recv.parties() != self.parties {
            return Err(Error::Transport(format!(
                "RecvBufs sized for {} parties, hub has {}",
                recv.parties(),
                self.parties
            )));
        }
        // Serial form = begin + finish back-to-back (DESIGN.md §10).
        // Accounting delegates to `exchange_begin`'s `.record(` call.
        self.exchange_begin(phase, data)?;
        self.exchange_finish(phase, data, recv)
    }

    fn exchange_begin(&mut self, phase: Phase, data: &[u8]) -> Result<()> {
        let t0 = std::time::Instant::now();
        let seq = self.seq;
        self.seq += 1;
        // Send to all peers (non-blocking). Payload buffers come from the
        // pool; receivers recycle them into *their* pool, so buffers
        // circulate around the symmetric hub.
        for q in 0..self.parties {
            if q == self.party {
                continue;
            }
            let payload = self.pool_take_filled(data);
            let Some(tx) = self.senders[q].as_ref() else {
                return Err(Error::Transport(format!("no hub channel to party {q}")));
            };
            tx.send((self.party, seq, payload))
                .map_err(|_| Error::Transport(format!("party {q} hung up")))?;
        }
        // One exchange = one round; bytes = what this party pushed to each
        // peer (the per-link number — the projection model scales by the
        // topology).
        self.trace.record(phase, (data.len() * (self.parties - 1)) as u64);
        self.inflight.push_back((seq, t0));
        Ok(())
    }

    fn exchange_finish(&mut self, _phase: Phase, _data: &[u8], recv: &mut RecvBufs) -> Result<()> {
        if recv.parties() != self.parties {
            return Err(Error::Transport(format!(
                "RecvBufs sized for {} parties, hub has {}",
                recv.parties(),
                self.parties
            )));
        }
        let Some((seq, t0)) = self.inflight.pop_front() else {
            return Err(Error::Transport(format!(
                "party {}: exchange_finish without a matching exchange_begin",
                self.party
            )));
        };
        for q in 0..self.parties {
            if q == self.party {
                continue;
            }
            let payload = self.recv_from(q, seq)?;
            self.next_seq[q] = seq + 1;
            // Copy-then-recycle rather than swapping the payload into the
            // slot: the copy makes every round return a buffer of exactly
            // the class it checked out *within the same round* (the
            // symmetric peer payload has the same size), which is what
            // makes one warm-up pass provably miss-free. A slot swap would
            // delay each return by a round, and consecutive same-size
            // rounds (the Kogge–Stone stages) could then still miss on the
            // second pass.
            RecvBufs::fill_slot(&mut recv.slots_mut()[q], &payload);
            self.pool.put_bytes(payload);
        }
        self.trace.record_wait(t0.elapsed());
        Ok(())
    }

    fn trace(&self) -> Arc<CommTrace> {
        Arc::clone(&self.trace)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    /// A hub with a short deadline surfaces a silent peer as the fatal
    /// `Error::Timeout` instead of blocking for the default 30 s.
    #[test]
    fn silent_peer_times_out() {
        let cfg = NetConfig {
            round_timeout: std::time::Duration::from_millis(50),
            ..NetConfig::default()
        };
        let mut transports = hub_with(2, cfg);
        let _t1 = transports.pop().unwrap(); // never exchanges, never drops
        let mut t0 = transports.pop().unwrap();
        let err = t0.exchange_all(Phase::Circuit, b"hello").unwrap_err();
        assert!(matches!(err, Error::Timeout(_)), "got {err}");
        assert!(!err.is_retryable());
    }

    #[test]
    fn two_party_exchange() {
        let mut hub = hub(2);
        let mut t1 = hub.pop().unwrap();
        let mut t0 = hub.pop().unwrap();
        let h0 = std::thread::spawn(move || {
            let got = t0.exchange_all(Phase::Circuit, b"from0").unwrap();
            assert_eq!(got[1], b"from1");
            assert_eq!(got[0], b"from0");
            t0.trace().total_bytes()
        });
        let got = t1.exchange_all(Phase::Circuit, b"from1").unwrap();
        assert_eq!(got[0], b"from0");
        let b0 = h0.join().unwrap();
        assert_eq!(b0, 5);
        assert_eq!(t1.trace().total_rounds(), 1);
    }

    #[test]
    fn three_party_many_rounds() {
        let transports = hub(3);
        let handles: Vec<_> = transports
            .into_iter()
            .map(|mut t| {
                std::thread::spawn(move || {
                    for round in 0..50u64 {
                        let me = t.party();
                        let msg = format!("r{round}p{me}");
                        let got = t.exchange_all(Phase::Mult, msg.as_bytes()).unwrap();
                        for (q, buf) in got.iter().enumerate() {
                            assert_eq!(buf, format!("r{round}p{q}").as_bytes());
                        }
                    }
                    t.trace().total_rounds()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 50);
        }
    }

    /// Steady-state rounds through `exchange_all_into` must not allocate:
    /// the first round warms the payload pool and the receive slots; later
    /// same-size rounds check every payload out of the pool.
    #[test]
    fn pooled_exchange_is_allocation_free_when_warm() {
        let transports = hub(3);
        let handles: Vec<_> = transports
            .into_iter()
            .map(|mut t| {
                std::thread::spawn(move || {
                    let me = t.party();
                    let mut recv = RecvBufs::new(t.parties());
                    let payload = vec![me as u8; 1024];
                    // Warmup round.
                    t.exchange_all_into(Phase::Circuit, &payload, &mut recv).unwrap();
                    let warm = t.pool_stats();
                    for round in 0..5 {
                        t.exchange_all_into(Phase::Circuit, &payload, &mut recv).unwrap();
                        for q in (0..t.parties()).filter(|q| *q != me) {
                            assert_eq!(recv.get(q), vec![q as u8; 1024], "round {round}");
                        }
                        let s = t.pool_stats();
                        assert_eq!(
                            s.alloc_misses, warm.alloc_misses,
                            "steady-state round {round} allocated a payload"
                        );
                        assert_eq!(s.checkouts, s.returns, "payloads leaked (round {round})");
                    }
                    t.trace().total_rounds()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 6);
        }
    }

    /// Split-phase pipelining: several begun rounds in flight at once;
    /// finishes (in begin order) deliver each round's payloads with no
    /// cross-round mixing, and the trace counts the same rounds/bytes as
    /// the serial schedule would.
    #[test]
    fn split_phase_pipelines_rounds() {
        let transports = hub(2);
        let handles: Vec<_> = transports
            .into_iter()
            .map(|mut t| {
                std::thread::spawn(move || {
                    let me = t.party();
                    let peer = 1 - me;
                    let mut recv = RecvBufs::new(t.parties());
                    let msgs: Vec<String> = (0..4).map(|r| format!("r{r}p{me}")).collect();
                    for m in &msgs {
                        t.exchange_begin(Phase::Circuit, m.as_bytes()).unwrap();
                    }
                    for (r, m) in msgs.iter().enumerate() {
                        t.exchange_finish(Phase::Circuit, m.as_bytes(), &mut recv).unwrap();
                        assert_eq!(recv.get(peer), format!("r{r}p{peer}").as_bytes());
                    }
                    // A finish with nothing in flight is a hard error.
                    assert!(t.exchange_finish(Phase::Circuit, b"", &mut recv).is_err());
                    (t.trace().total_rounds(), t.trace().total_bytes())
                })
            })
            .collect();
        for h in handles {
            let (rounds, bytes) = h.join().unwrap();
            assert_eq!(rounds, 4);
            assert_eq!(bytes, 16, "4 rounds x 4-byte payload x 1 peer");
        }
    }

    /// Mis-sized RecvBufs is a hard transport error, not a silent resize.
    #[test]
    fn mismatched_recvbufs_rejected() {
        let mut transports = hub(2);
        let mut t0 = transports.remove(0);
        let mut recv = RecvBufs::new(3);
        assert!(t0.exchange_all_into(Phase::Circuit, b"x", &mut recv).is_err());
    }
}
