//! Seeded-violation fixture for `hblint --self-test` (DESIGN.md §8).
//!
//! This file is **not** compiled by cargo (only top-level `tests/*.rs`
//! become test binaries) and is skipped by normal `hblint` scans. The
//! self-test scans it with every rule forced on (hot + walled) and
//! requires the findings to match the `// EXPECT: <rule>` markers below
//! *exactly* — a rule that goes blind fails CI just like a rule that
//! misfires. Sites without a marker are negative controls: correctly
//! annotated code every rule must accept.

// --- Rule S: `unsafe` must carry an immediately preceding SAFETY comment --

pub fn unsound_read(p: *const u64) -> u64 {
    unsafe { *p } // EXPECT: S
}

// SAFETY: negative control — the caller guarantees `p` is valid, aligned
// and unaliased for the duration of the call.
pub unsafe fn sound_read(p: *const u64) -> u64 {
    *p
}

// A SAFETY comment placed *above* a `#[target_feature]` attribute does
// not cover the `unsafe fn` line below it: the attribute is a code line
// and breaks the contiguous comment block, so Rule S still fires. The
// comment must sit between the attribute and the fn — the `gmw/simd.rs`
// convention for intrinsic kernels.
// SAFETY: stale position — must NOT satisfy Rule S.
#[target_feature(enable = "avx2")]
pub unsafe fn undocumented_intrinsic_call() { // EXPECT: S
    core::arch::x86_64::_mm256_setzero_si256();
}

#[target_feature(enable = "avx2")]
// SAFETY: negative control — the comment sits between the attribute and
// the `unsafe fn` line; the caller must have verified AVX2 support.
pub unsafe fn documented_intrinsic_call() {
    core::arch::x86_64::_mm256_setzero_si256();
}

// --- Rule A: allocations in hot-path modules need HOT-PATH-ALLOW ----------

pub fn leaky_hot_path(n: usize) -> Vec<u64> {
    let mut v = Vec::new(); // EXPECT: A
    v.resize(n, 0u64);
    let w = v.to_vec(); // EXPECT: A
    w
}

pub fn annotated_hot_path(n: usize) -> Vec<u64> {
    // HOT-PATH-ALLOW: negative control — setup-time buffer, reused after.
    vec![0u64; n]
}

// --- Rule T: exchange_all_into must record CommTrace or delegate ----------

pub struct SilentTransport;

impl SilentTransport {
    pub fn exchange_all_into(&mut self, data: &[u8]) -> usize { // EXPECT: T
        data.len()
    }
}

pub struct TraceStub(u64);

impl TraceStub {
    pub fn record(&mut self, _phase: u8, bytes: u64) {
        self.0 += bytes;
    }
}

pub struct RecordingTransport {
    trace: TraceStub,
}

impl RecordingTransport {
    // Negative control: accounts every byte into the trace.
    pub fn exchange_all_into(&mut self, data: &[u8]) -> usize {
        self.trace.record(0, data.len() as u64);
        data.len()
    }
}

pub struct DelegatingTransport {
    inner: RecordingTransport,
}

impl DelegatingTransport {
    // Negative control: visibly delegates to the inner transport.
    pub fn exchange_all_into(&mut self, data: &[u8]) -> usize {
        self.inner.exchange_all_into(data)
    }
}

// --- Rule U: no unwrap/expect outside tests, allow scopes or LINT-ALLOW ---

pub fn sloppy(v: Option<u64>) -> u64 {
    v.unwrap() // EXPECT: U
}

pub fn sloppy_expect(v: Option<u64>) -> u64 {
    v.expect("fixture") // EXPECT: U
}

pub fn reviewed(v: Option<u64>) -> u64 {
    // LINT-ALLOW: unwrap — negative control: reviewed, infallible by
    // construction in the caller.
    v.unwrap()
}

#[allow(clippy::unwrap_used)]
pub fn clippy_walled(v: Option<u64>) -> u64 {
    v.unwrap()
}

// --- Rule M: every *Counters group must be a MetricsSnapshot field --------

pub struct OrphanCounters { // EXPECT: M
    pub lost: u64,
}

// Negative control: surfaced in the snapshot block below.
pub struct GoodCounters {
    pub seen: u64,
}

pub struct MetricsSnapshot {
    pub good: GoodCounters,
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_may_unwrap_and_allocate() {
        let v = vec![Some(1u64)];
        assert_eq!(v[0].unwrap(), 1);
    }
}
