"""Offline phase, part 1: synthetic datasets + model training + finetuning.

The paper trains ResNet18/50 on CIFAR10/CIFAR100/TinyImageNet. None of
those are available in this offline environment, so we substitute
procedurally-generated image datasets with a real accuracy/pruning
trade-off (see DESIGN.md §4): each class has a smooth random prototype;
samples are prototype + smoothed noise + random shift + contrast jitter.
Class count and noise level are tuned so baseline accuracies land in the
same bands as the paper's Table 1 (high / mid / low).

Everything here is build-time Python (the paper's offline phase). Outputs:

    artifacts/data/<dataset>            — train/val/test tensor archives
    artifacts/weights/<config>          — trained weights archive
    artifacts/train_summary.json        — Table 1 source (baseline accuracy)

Finetuning (paper §4.1.3): ``--finetune <plan.json>`` re-trains with the
searched approximate-ReLU plan using a straight-through gradient and writes
``artifacts/weights/<config>__ft``.
"""

import argparse
import functools
import json
import os
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from . import archs, dataio, model as M

ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), "..", ".."))
ART = os.path.join(ROOT, "artifacts")

# Per-dataset sample counts and difficulty levels, tuned so the baseline
# accuracies land in the paper's Table-1 bands (high / mid / low ≈
# 93% / 78% / 65%). Feature noise keeps the task non-trivial; label noise
# pins the accuracy ceiling at ~(1-flip) + flip/classes (feature-noise-only
# difficulty cliffs between trivial and unlearnable at this scale).
DATA_SPEC = {
    #            train  val  test  noise  proto_scale  label_flip
    "synth10": (3072, 512, 1024, 0.65, 1.0, 0.06),
    "synth100": (6144, 512, 1024, 0.30, 1.5, 0.20),
    "synthtiny": (6144, 512, 1024, 0.35, 1.3, 0.33),
}

TRAIN_EPOCHS = {"micronet": 14, "miniresnet": 18, "resnets18": 16}


# ---------------------------------------------------------------------------
# Synthetic data.
# ---------------------------------------------------------------------------

def _smooth(key, shape, passes=2):
    """Low-frequency random field: gaussian noise box-blurred a few times."""
    x = jax.random.normal(key, shape, jnp.float32)
    kern = jnp.ones((3, 3), jnp.float32) / 9.0
    kern = kern[None, None].repeat(shape[0], axis=0)  # depthwise
    for _ in range(passes):
        x = jax.lax.conv_general_dilated(
            x[None], kern, (1, 1), [(1, 1), (1, 1)],
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
            feature_group_count=shape[0],
        )[0]
    return x


def _name_seed(name: str) -> int:
    """Deterministic per-dataset seed (NOT python hash(), which is
    randomized per process via PYTHONHASHSEED)."""
    import zlib
    return zlib.crc32(name.encode())


def make_dataset(name: str, seed: int = 0):
    """Returns dict of train/val/test images [N,C,H,W] f32 + labels i32."""
    ch, hw, ncls = archs.DATASETS[name]
    n_train, n_val, n_test, noise, proto_scale, label_flip = DATA_SPEC[name]
    key = jax.random.PRNGKey(seed + _name_seed(name) % 2**31)
    key, pkey = jax.random.split(key)
    protos = jnp.stack(
        [_smooth(jax.random.fold_in(pkey, c), (ch, hw, hw)) * proto_scale
         for c in range(ncls)]
    )  # [ncls, C, H, W]

    def gen_split(key, n):
        key, lkey, nkey, skey, ckey, fkey, rkey = jax.random.split(key, 7)
        labels = jax.random.randint(lkey, (n,), 0, ncls)
        base = protos[labels]
        # Label noise (applied after images are generated from the true
        # class): flips a fraction of labels to uniform-random classes.
        flip = jax.random.uniform(fkey, (n,)) < label_flip
        rand_labels = jax.random.randint(rkey, (n,), 0, ncls)
        noisy_labels = jnp.where(flip, rand_labels, labels)
        noise_field = jax.vmap(
            lambda k: _smooth(k, (ch, hw, hw), passes=1)
        )(jax.random.split(nkey, n))
        # Shift by at most 1 pixel: mild translation jitter (full-range
        # rolls destroy the phase information GAP-style CNNs rely on and
        # make the many-class variants unlearnable at this scale).
        shifts = jax.random.randint(skey, (n, 2), -1, 2)
        contrast = 1.0 + 0.15 * jax.random.normal(ckey, (n, 1, 1, 1))
        imgs = base * contrast + noise * noise_field
        imgs = jax.vmap(lambda im, s: jnp.roll(im, s, axis=(1, 2)))(imgs, shifts)
        # Normalize to roughly unit scale (keeps ring encodings small).
        imgs = jnp.clip(imgs, -3.0, 3.0)
        return np.asarray(imgs, np.float32), np.asarray(noisy_labels, np.int32)

    k1, k2, k3 = jax.random.split(key, 3)
    tr = gen_split(k1, n_train)
    va = gen_split(k2, n_val)
    te = gen_split(k3, n_test)
    return {
        "train_x": tr[0], "train_y": tr[1],
        "val_x": va[0], "val_y": va[1],
        "test_x": te[0], "test_y": te[1],
    }


def save_dataset(name: str, data: dict) -> None:
    dataio.save_tensors(os.path.join(ART, "data", name), data)


def load_or_make_dataset(name: str) -> dict:
    """Prefer the archived dataset (the realization every trained model and
    the Rust side use); regenerate + save only if absent."""
    prefix = os.path.join(ART, "data", name)
    if os.path.exists(prefix + ".json"):
        return dataio.load_tensors(prefix)
    data = make_dataset(name)
    save_dataset(name, data)
    return data


# ---------------------------------------------------------------------------
# Training.
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def accuracy(cfg, params, x, y, batch=256, relu_fn=None):
    correct = 0
    fwd = jax.jit(functools.partial(M.forward_plain, cfg), static_argnums=())
    for i in range(0, len(x), batch):
        xb = jnp.asarray(x[i:i + batch])
        logits = M.forward_plain(cfg, params, xb, relu_fn=relu_fn)
        correct += int(jnp.sum(jnp.argmax(logits, axis=1) == jnp.asarray(y[i:i + batch])))
    return correct / len(x)


def train_model(cfg, data, epochs, lr=0.04, batch=128, seed=0,
                params=None, plan_by_group=None, log=print):
    """SGD-momentum training; optionally with an approximate-ReLU plan
    (finetune mode, straight-through gradient)."""
    key = jax.random.PRNGKey(seed)
    if params is None:
        key, ikey = jax.random.split(key)
        params = M.init_params(cfg, ikey)
    momentum = jax.tree.map(jnp.zeros_like, params)
    frac_bits = cfg["frac_bits"]

    def loss_fn(p, xb, yb, rngkey):
        relu_fn = None
        if plan_by_group is not None:
            relu_fn = M.make_approx_relu_fn(plan_by_group, frac_bits, rngkey)
        logits = M.forward_plain(cfg, p, xb, relu_fn=relu_fn)
        l2 = sum(jnp.sum(w * w) for n, w in p.items() if n.startswith("w"))
        return cross_entropy(logits, yb) + 5e-4 * l2

    @jax.jit
    def step(p, mom, xb, yb, lr_t, rngkey):
        loss, grads = jax.value_and_grad(loss_fn)(p, xb, yb, rngkey)
        mom = jax.tree.map(lambda m, g: 0.9 * m + g, mom, grads)
        p = jax.tree.map(lambda w, m: w - lr_t * m, p, mom)
        return p, mom, loss

    n = len(data["train_x"])
    steps_per_epoch = max(1, n // batch)
    total_steps = epochs * steps_per_epoch
    t0 = time.time()
    it = 0
    for epoch in range(epochs):
        key, pkey = jax.random.split(key)
        perm = np.asarray(jax.random.permutation(pkey, n))
        losses = []
        for s in range(steps_per_epoch):
            idx = perm[s * batch:(s + 1) * batch]
            xb = jnp.asarray(data["train_x"][idx])
            yb = jnp.asarray(data["train_y"][idx])
            # Cosine LR decay.
            lr_t = jnp.float32(lr * 0.5 * (1 + np.cos(np.pi * it / total_steps)))
            key, skey = jax.random.split(key)
            params, momentum, loss = step(params, momentum, xb, yb, lr_t, skey)
            losses.append(float(loss))
            it += 1
        relu_fn = None
        if plan_by_group is not None:
            relu_fn = M.make_approx_relu_fn(plan_by_group, frac_bits,
                                            jax.random.PRNGKey(123))
        val_acc = accuracy(cfg, params, data["val_x"], data["val_y"],
                           relu_fn=relu_fn)
        log(f"  epoch {epoch + 1}/{epochs} loss={np.mean(losses):.4f} "
            f"val={val_acc * 100:.2f}% ({time.time() - t0:.0f}s)")
    return params, val_acc


def export_params(cfg, params, path_prefix):
    tensors = {}
    for name, arr in params.items():
        tensors[name] = np.asarray(arr, np.float32)
    dataio.save_tensors(path_prefix, tensors)


# ---------------------------------------------------------------------------
# Driver.
# ---------------------------------------------------------------------------

def train_all(quick=False):
    os.makedirs(ART, exist_ok=True)
    archs.write_all_configs(os.path.join(ROOT, "configs", "models"))
    summary = {}
    datasets = {}
    for ds in archs.DATASETS:
        print(f"[data] generating {ds}")
        data = make_dataset(ds)
        save_dataset(ds, data)
        datasets[ds] = data
    for m, ds in archs.BENCHMARKS + archs.EXTRA:
        cfg = archs.build_config(m, ds)
        epochs = 2 if quick else TRAIN_EPOCHS[m]
        print(f"[train] {cfg['name']} ({epochs} epochs)")
        params, val_acc = train_model(cfg, datasets[ds], epochs)
        test_acc = accuracy(cfg, params, datasets[ds]["test_x"], datasets[ds]["test_y"])
        print(f"[train] {cfg['name']}: val={val_acc*100:.2f}% test={test_acc*100:.2f}%")
        export_params(cfg, params, os.path.join(ART, "weights", cfg["name"]))
        summary[cfg["name"]] = {"val_acc": val_acc, "test_acc": test_acc,
                                "epochs": epochs}
        with open(os.path.join(ART, "train_summary.json"), "w") as f:
            json.dump(summary, f, indent=1)
    print("[train] summary written to artifacts/train_summary.json")


def finetune(config_name: str, plan_path: str, epochs: int = 4, lr: float = None):
    """Finetune a trained model under a searched HummingBird plan.

    Straight-through gradients through aggressive bit windows are noisy;
    deep models (resnets18) need a much gentler learning rate than the
    shallow ones or they diverge.
    """
    with open(os.path.join(ROOT, "configs", "models", config_name + ".json")) as f:
        cfg = json.load(f)
    with open(plan_path) as f:
        plan = json.load(f)
    plan_by_group = {int(g): (int(km["k"]), int(km["m"]))
                     for g, km in plan["groups"].items()}
    data = load_or_make_dataset(cfg["dataset"])
    weights = dataio.load_tensors(os.path.join(ART, "weights", config_name))
    params = {k: jnp.asarray(v) for k, v in weights.items()}
    relu_fn = M.make_approx_relu_fn(plan_by_group, cfg["frac_bits"],
                                    jax.random.PRNGKey(7))
    before = accuracy(cfg, params, data["test_x"], data["test_y"], relu_fn=relu_fn)
    print(f"[finetune] {config_name} before: {before*100:.2f}%")
    if lr is None:
        lr = 0.0012 if cfg["model"] == "resnets18" else 0.008
    params, _ = train_model(cfg, data, epochs, lr=lr, params=params,
                            plan_by_group=plan_by_group)
    after = accuracy(cfg, params, data["test_x"], data["test_y"], relu_fn=relu_fn)
    print(f"[finetune] {config_name} after: {after*100:.2f}%")
    export_params(cfg, params, os.path.join(ART, "weights", config_name + "__ft"))
    result = {"config": config_name, "plan": plan_path,
              "acc_before_ft": before, "acc_after_ft": after}
    out = os.path.join(ART, f"finetune_{config_name}.json")
    with open(out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"[finetune] wrote {out}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--all", action="store_true", help="train all benchmarks")
    ap.add_argument("--quick", action="store_true", help="2-epoch smoke run")
    ap.add_argument("--finetune", help="path to searched plan JSON")
    ap.add_argument("--config", help="config name for finetune")
    ap.add_argument("--epochs", type=int, default=4)
    args = ap.parse_args()
    if args.finetune:
        assert args.config, "--finetune requires --config"
        finetune(args.config, args.finetune, args.epochs)
    else:
        train_all(quick=args.quick)


if __name__ == "__main__":
    main()
