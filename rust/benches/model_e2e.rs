//! Whole-model private-inference benchmark (the Fig 1/7/8 end-to-end
//! number): one 2-party MPC batch through the full stack per plan variant.
//! Requires `make artifacts` + trained weights.
//!
//! Note: `FigCtx::measure` runs a warm-up pass before the timed pass, so
//! these rows measure the *steady-state* serving path — activation pool,
//! engine arena, transport payload pool and `RecvBufs` all warm.

use hummingbird::figures::FigCtx;
use hummingbird::util::benchkit::Bench;
use hummingbird::util::stats;

fn main() {
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    if !root.join("artifacts/manifest.json").exists()
        || !root.join("artifacts/weights/micronet_synth10.json").exists()
    {
        eprintln!("skipping model_e2e: run `make artifacts && make train` first");
        return;
    }
    let mut bench = Bench::new();
    // Batched MPC inference is seconds-scale; trim the measurement budget.
    bench.measure_time = std::time::Duration::from_secs(1);
    bench.warmup_time = std::time::Duration::from_millis(10);
    bench.sample_count = 3;

    let mut ctx = FigCtx::new(root);
    for model in ["micronet_synth10", "miniresnet_synth10"] {
        for variant in ["baseline", "eco", "b8-64"] {
            // measure() caches; call once to warm and to get comm stats.
            let (m, _) = match ctx.measure(model, variant) {
                Ok(v) => v,
                Err(e) => {
                    eprintln!("skipping {model}/{variant}: {e}");
                    continue;
                }
            };
            println!(
                "{model}/{variant}: {} protocol bytes, {} rounds, {} compute",
                stats::fmt_bytes(m.protocol_bytes()),
                m.total_rounds,
                stats::fmt_secs(m.compute_s)
            );
            let batch = m.batch as u64;
            let mut c2 = FigCtx::new(ctx.root.clone());
            let model = model.to_string();
            let variant = variant.to_string();
            bench.bench_elems(&format!("mpc_forward/{model}/{variant}"), batch, move || {
                let _ = c2.measure_uncached(&model, &variant).unwrap();
            });
        }
    }
    bench.dump_json("model_e2e");
}
