//! Request queue + dynamic batcher + party thread pool.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::beaver::schedule::TripleSchedule;
use crate::crypto::prg::Prg;
use crate::error::{Error, Result};
use crate::gmw::kernels::{BinLayout, BitslicedKernels, RustKernels};
use crate::gmw::GmwParty;
use crate::hummingbird::PlanSet;
use crate::model::{Archive, ExecBreakdown, ModelConfig, PlainExecutor, ShareExecutor, ShareWeights};
use crate::net::accounting::{CommTrace, Phase};
use crate::net::local::hub;
use crate::net::Transport;
use crate::ring::FixedPoint;
use crate::runtime::{Manifest, Runtime, XlaKernels};
use crate::sharing::share_arith;
use crate::tensor::TensorU64;

use super::metrics::Metrics;

/// Serving options.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Repo root (contains artifacts/ and configs/).
    pub repo_root: std::path::PathBuf,
    /// Model config name, e.g. "miniresnet_synth10".
    pub model: String,
    /// Plan file name under configs/searched/, or None for baseline.
    pub plan: Option<PlanSet>,
    pub parties: usize,
    /// How long the batcher waits to fill a batch before flushing.
    pub batch_timeout: Duration,
    pub session_seed: u64,
    /// Kernel backend for the GMW engine: "rust" (default) or "xla".
    pub gmw_backend: String,
    /// Binary-share layout for the "rust" backend: lane-per-u64 (default)
    /// or bitsliced (64 lanes per word through the DReLU circuit). Results
    /// and wire bytes are bit-identical either way; the XLA backend only
    /// supports the lane layout. CLI flag `--layout`.
    pub layout: BinLayout,
    /// Lane-parallelism budget per party for local GMW compute (kernels +
    /// fused bitpack). 0 = auto: divide the machine's cores across the
    /// simulated parties. Results are bit-identical for any value.
    pub threads: usize,
    /// Offline/online phase split (CLI flag `--prefetch on|off`): when
    /// true, each party thread provisions its Beaver correlations on a
    /// background prefetcher sized from the model's per-batch draw
    /// schedule (`TripleSchedule::for_forward`), warmed before the party
    /// admits its first job and cycling one batch ahead thereafter — so no
    /// dealer PRG expansion happens inside the online AND rounds. Results,
    /// wire bytes and `TripleUsage` are bit-identical either way.
    pub prefetch: bool,
}

impl ServeOptions {
    pub fn new(repo_root: impl Into<std::path::PathBuf>, model: &str) -> Self {
        ServeOptions {
            repo_root: repo_root.into(),
            model: model.to_string(),
            plan: None,
            parties: 2,
            batch_timeout: Duration::from_millis(20),
            session_seed: 0x5e55_10,
            gmw_backend: "rust".into(),
            layout: BinLayout::default(),
            threads: 0,
            prefetch: false,
        }
    }
}

/// Resolve the `threads = 0` auto setting: split the machine's cores across
/// the co-located party threads (at least 1 each).
fn resolve_threads(threads: usize, parties: usize) -> usize {
    if threads == 0 {
        (crate::util::threadpool::default_threads() / parties.max(1)).max(1)
    } else {
        threads
    }
}

/// One inference answer.
#[derive(Debug, Clone)]
pub struct InferenceResult {
    pub logits: Vec<f32>,
    pub pred: usize,
    pub latency_s: f64,
    pub batch_size: usize,
}

struct Request {
    input: Vec<f32>,
    enqueued: Instant,
    resp: Sender<InferenceResult>,
}

/// Job sent to each party thread.
struct PartyJob {
    x_share: Vec<u64>,
    shape: Vec<usize>,
}

/// Output from a party thread.
struct PartyOut {
    share: Vec<u64>,
    breakdown: ExecBreakdown,
}

/// Handle to a running service.
pub struct Coordinator {
    req_tx: Option<Sender<Request>>,
    pub metrics: Arc<Metrics>,
    pub trace: Arc<CommTrace>,
    batcher: Option<std::thread::JoinHandle<()>>,
    parties: Vec<std::thread::JoinHandle<()>>,
    pub cfg: ModelConfig,
}

impl Coordinator {
    /// Boot the service: loads config/weights, spawns party + batcher
    /// threads, returns once ready.
    pub fn start(opts: ServeOptions) -> Result<Coordinator> {
        if opts.gmw_backend == "xla" && opts.layout == BinLayout::Bitsliced {
            return Err(Error::config(
                "--layout bitsliced requires the rust kernel backend (the XLA \
                 kernels are lane-per-u64)",
            ));
        }
        let root = opts.repo_root.join("artifacts");
        let cfg = ModelConfig::load_named(&opts.repo_root, &opts.model)?;
        let weights = Archive::load(root.join("weights").join(&opts.model))?;
        let manifest = Manifest::load(&root)?;
        let model_art = manifest.model(&opts.model)?.clone();
        let batch = model_art.batch;
        let plans = opts.plan.clone().unwrap_or_else(|| PlanSet::baseline(cfg.relu_groups));

        let transports = hub(opts.parties);
        let trace = transports[0].trace();

        // Party threads.
        let mut parties = Vec::new();
        let mut job_txs: Vec<Sender<PartyJob>> = Vec::new();
        let (out_tx, out_rx) = channel::<(usize, PartyOut)>();
        for t in transports {
            let (jtx, jrx) = channel::<PartyJob>();
            job_txs.push(jtx);
            let cfg = cfg.clone();
            let weights = weights.clone();
            let root = root.clone();
            let model_art = model_art.clone();
            let plans = plans.clone();
            let out_tx = out_tx.clone();
            let seed = opts.session_seed;
            let backend = opts.gmw_backend.clone();
            let layout = opts.layout;
            let threads = resolve_threads(opts.threads, opts.parties);
            let prefetch = opts.prefetch;
            parties.push(std::thread::spawn(move || {
                party_main(
                    t, cfg, weights, root, model_art, plans, jrx, out_tx, seed, backend, layout,
                    threads, prefetch,
                );
            }));
        }

        // Batcher thread.
        let metrics = Arc::new(Metrics::new());
        let (req_tx, req_rx) = channel::<Request>();
        let m2 = Arc::clone(&metrics);
        let fx = FixedPoint::new(cfg.frac_bits);
        let input_shape = cfg.input;
        let classes = cfg.num_classes;
        let parties_n = opts.parties;
        let timeout = opts.batch_timeout;
        let trace2 = Arc::clone(&trace);
        let batcher = std::thread::spawn(move || {
            batcher_main(
                req_rx, job_txs, out_rx, m2, fx, input_shape, classes, batch, parties_n,
                timeout, trace2,
            );
        });

        Ok(Coordinator {
            req_tx: Some(req_tx),
            metrics,
            trace,
            batcher: Some(batcher),
            parties,
            cfg,
        })
    }

    /// Submit one inference and wait for the answer.
    pub fn infer(&self, input: Vec<f32>) -> Result<InferenceResult> {
        let (tx, rx) = channel();
        self.req_tx
            .as_ref()
            .expect("service running")
            .send(Request { input, enqueued: Instant::now(), resp: tx })
            .map_err(|_| Error::Transport("service stopped".into()))?;
        rx.recv().map_err(|_| Error::Transport("service dropped request".into()))
    }

    /// Submit asynchronously; returns the response channel.
    pub fn infer_async(&self, input: Vec<f32>) -> Result<Receiver<InferenceResult>> {
        let (tx, rx) = channel();
        self.req_tx
            .as_ref()
            .expect("service running")
            .send(Request { input, enqueued: Instant::now(), resp: tx })
            .map_err(|_| Error::Transport("service stopped".into()))?;
        Ok(rx)
    }

    /// Graceful shutdown (drains in-flight work).
    pub fn shutdown(mut self) {
        self.req_tx.take(); // closes the queue; batcher exits; parties exit
        if let Some(b) = self.batcher.take() {
            b.join().ok();
        }
        for p in self.parties.drain(..) {
            p.join().ok();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.req_tx.take();
        if let Some(b) = self.batcher.take() {
            b.join().ok();
        }
        for p in self.parties.drain(..) {
            p.join().ok();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn party_main(
    transport: crate::net::local::LocalTransport,
    cfg: ModelConfig,
    weights: Archive,
    artifacts_root: std::path::PathBuf,
    model_art: crate::runtime::registry::ModelArtifacts,
    plans: PlanSet,
    jobs: Receiver<PartyJob>,
    out: Sender<(usize, PartyOut)>,
    seed: u64,
    backend: String,
    layout: BinLayout,
    threads: usize,
    prefetch: bool,
) {
    let me = transport.party();
    // Offline/online split: predict this model's per-batch dealer draws
    // (every job is padded to the full artifact batch, so one forward pass
    // repeats the same schedule) and hand them to a cycling background
    // prefetcher. `enable_prefetch` below also waits for the first buffers,
    // so the party is warm before it admits its first job.
    let schedule = prefetch.then(|| {
        TripleSchedule::for_forward(&cfg, &plans, model_art.batch, transport.parties())
    });
    let rt = Runtime::new(&artifacts_root).expect("runtime handle");
    if !model_art.layers.is_empty() || backend == "xla" {
        // Linear layers (and the xla GMW kernel backend) will execute
        // PJRT artifacts: surface a missing or broken PJRT install at
        // boot, not at the first request.
        rt.ensure_client().expect("pjrt client");
    }
    let sw = ShareWeights::prepare(&cfg, &weights).expect("weights");
    let mut exec = ShareExecutor::new(cfg, model_art, rt.clone(), sw);
    // The GMW engine: pure-Rust kernels (lane-per-u64 or bitsliced binary
    // layout per `--layout`), or the Pallas/PJRT backend for the full
    // three-layer path.
    if backend == "xla" {
        let manifest = Manifest::load(&artifacts_root).expect("manifest");
        let kernels = XlaKernels::new(rt, manifest);
        let mut party = GmwParty::with_kernels(transport, seed, kernels);
        boot_party(&mut party, threads, schedule);
        party_loop(&mut exec, &mut party, &plans, jobs, out, me);
    } else if layout == BinLayout::Bitsliced {
        let mut party = GmwParty::with_kernels(transport, seed, BitslicedKernels::default());
        boot_party(&mut party, threads, schedule);
        party_loop(&mut exec, &mut party, &plans, jobs, out, me);
    } else {
        let mut party = GmwParty::with_kernels(transport, seed, RustKernels::default());
        boot_party(&mut party, threads, schedule);
        party_loop(&mut exec, &mut party, &plans, jobs, out, me);
    }
}

/// Per-party engine knobs applied identically in every kernel branch.
/// `enable_prefetch` blocks until the first scheduled buffers are
/// expanded, so a prefetching party is warm before it admits its first
/// job.
fn boot_party<T: Transport, K: crate::gmw::kernels::KernelBackend>(
    party: &mut GmwParty<T, K>,
    threads: usize,
    schedule: Option<TripleSchedule>,
) {
    party.set_threads(threads);
    if let Some(s) = schedule {
        party.enable_prefetch(s, true);
    }
}

fn party_loop<T: Transport, K: crate::gmw::kernels::KernelBackend>(
    exec: &mut ShareExecutor,
    party: &mut GmwParty<T, K>,
    plans: &PlanSet,
    jobs: Receiver<PartyJob>,
    out: Sender<(usize, PartyOut)>,
    me: usize,
) {
    // The executor and engine are long-lived: after the first batch warms
    // the activation pool, the scratch arena and the transport buffers,
    // steady-state batches reuse them all (ROADMAP "activation-buffer
    // reuse in model::ShareExecutor").
    while let Ok(job) = jobs.recv() {
        let x = TensorU64::new(job.shape.clone(), job.x_share).expect("share shape");
        let (o, bd) = exec.forward(party, x, plans).expect("party forward");
        if out.send((me, PartyOut { share: o.data, breakdown: bd })).is_err() {
            break;
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn batcher_main(
    req_rx: Receiver<Request>,
    job_txs: Vec<Sender<PartyJob>>,
    out_rx: Receiver<(usize, PartyOut)>,
    metrics: Arc<Metrics>,
    fx: FixedPoint,
    input_shape: (usize, usize, usize),
    classes: usize,
    batch: usize,
    parties: usize,
    timeout: Duration,
    trace: Arc<CommTrace>,
) {
    let per_sample = input_shape.0 * input_shape.1 * input_shape.2;
    let mut prg = Prg::from_entropy();
    let mut pending: Vec<Request> = Vec::new();
    // Batch-sized staging buffers, reused across batches (the shares sent
    // to the party threads are still fresh vectors — they cross threads).
    let mut x_ring = vec![0u64; batch * per_sample];
    let mut logits_ring = vec![0u64; batch * classes];
    loop {
        // Fill the batch window.
        let deadline = Instant::now() + timeout;
        while pending.len() < batch {
            let now = Instant::now();
            if !pending.is_empty() && now >= deadline {
                break;
            }
            let wait = if pending.is_empty() {
                Duration::from_millis(250)
            } else {
                deadline.saturating_duration_since(now)
            };
            match req_rx.recv_timeout(wait) {
                Ok(r) => {
                    metrics.mark_start();
                    pending.push(r);
                }
                Err(RecvTimeoutError::Timeout) => {
                    if pending.is_empty() {
                        continue;
                    }
                    break;
                }
                Err(RecvTimeoutError::Disconnected) => {
                    if pending.is_empty() {
                        return; // graceful shutdown
                    }
                    break;
                }
            }
        }
        let got = pending.len().min(batch);
        let reqs: Vec<Request> = pending.drain(..got).collect();
        let t0 = Instant::now();

        // Encode + pad + share (zero the pad region left by the previous
        // batch before encoding this one).
        x_ring.fill(0);
        for (i, r) in reqs.iter().enumerate() {
            for (j, v) in r.input.iter().take(per_sample).enumerate() {
                x_ring[i * per_sample + j] = fx.encode(*v as f64);
            }
        }
        let shares = share_arith(&mut prg, &x_ring, parties);
        // Client -> party input share movement (Data phase accounting).
        trace.record(Phase::Data, (x_ring.len() * 8) as u64);
        let shape = vec![batch, input_shape.0, input_shape.1, input_shape.2];
        for (tx, share) in job_txs.iter().zip(shares) {
            if tx.send(PartyJob { x_share: share, shape: shape.clone() }).is_err() {
                return;
            }
        }
        // Collect output shares.
        let mut outs: Vec<Option<PartyOut>> = (0..parties).map(|_| None).collect();
        for _ in 0..parties {
            match out_rx.recv() {
                Ok((p, o)) => outs[p] = Some(o),
                Err(_) => return,
            }
        }
        trace.record(Phase::Data, (batch * classes * 8 * parties) as u64);
        logits_ring.fill(0);
        let mut bd = ExecBreakdown::default();
        let mut outs_n = 0;
        for o in outs.into_iter().flatten() {
            for (acc, v) in logits_ring.iter_mut().zip(&o.share) {
                *acc = acc.wrapping_add(*v);
            }
            // Parties run concurrently: breakdown = max over parties, but
            // averaging is close enough for symmetric parties; take party
            // max via simple max-merge on totals.
            if outs_n == 0 {
                bd = o.breakdown;
            }
            outs_n += 1;
        }
        let latency = t0.elapsed().as_secs_f64();
        metrics.record_batch(got, latency, &bd);
        // Respond.
        for (i, r) in reqs.into_iter().enumerate() {
            let row: Vec<f32> = logits_ring[i * classes..(i + 1) * classes]
                .iter()
                .map(|v| fx.decode(*v) as f32)
                .collect();
            let pred = PlainExecutor::argmax(&row, classes)[0];
            let wait_s = r.enqueued.elapsed().as_secs_f64();
            let _ = r.resp.send(InferenceResult {
                logits: row,
                pred,
                latency_s: wait_s,
                batch_size: got,
            });
        }
    }
}
