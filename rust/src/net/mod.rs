//! Party-to-party communication substrate.
//!
//! The GMW engine talks to an abstract [`Transport`]; two implementations
//! exist: [`local::LocalTransport`] (in-process channels — used by tests,
//! benches and the single-binary multi-party simulator) and
//! [`tcp::TcpTransport`] (real sockets for multi-process deployments).
//! Both feed the same [`accounting::CommTrace`]. Arbitrary networks are
//! covered twice over: [`profile`] *projects* wall-clock analytically from
//! a recorded trace (the paper's own methodology — measured bytes/rounds ×
//! bandwidth/latency model), and [`sim::SimTransport`] *measures* it by
//! delaying frame delivery per the same cost model on a real or virtual
//! clock, which is what makes overlapped round schedules observable as
//! wall-clock instead of byte counts (DESIGN.md §10).
//!
//! # Split-phase exchanges
//!
//! [`Transport::exchange_begin`] / [`Transport::exchange_finish`] split one
//! round into "put my payload on the wire" and "block until the peers'
//! payloads are in". A scheduler that begins several independent rounds
//! before finishing the first pays each link's serialization back-to-back
//! but the propagation latency only once — the WAN overlap win (DESIGN.md
//! §10). The defaults degrade to the serial [`Transport::exchange_all_into`]
//! so every transport stays correct (and bit-identical) without opting in.
//!
//! # `exchange_all` → `exchange_all_into` migration
//!
//! The original primitive, `exchange_all`, returned a fresh
//! `Vec<Vec<u8>>` per round — one allocation per peer per round, the last
//! per-round allocations left after the engine-side arena work (PR 1).
//! The required trait method is now [`Transport::exchange_all_into`],
//! which fills a caller-owned [`RecvBufs`]; `exchange_all` survives as a
//! provided default method that allocates a throwaway `RecvBufs` and
//! unwraps it, so existing callers and tests keep working unchanged. New
//! code (and the whole GMW hot path) should hold one `RecvBufs` per
//! session and pass it to every round.
//!
//! ## `RecvBufs` ownership rules
//!
//! * One `RecvBufs` per protocol session, owned by the caller (the GMW
//!   engine keeps one inside `GmwParty`), never shared across parties or
//!   threads.
//! * A call to `exchange_all_into` **fully overwrites** every peer slot:
//!   slot `q` holds exactly peer `q`'s payload for that round. The slot
//!   for `self.party()` has **unspecified contents** — the engine's folds
//!   seed from the caller's own shares and skip it (only the legacy
//!   `exchange_all` shim pays the echo copy). Contents are only valid
//!   until the next exchange.
//! * Slots keep their heap capacity across rounds; once a session has seen
//!   its largest payload, later rounds perform **zero receive-side
//!   allocations**. Transports must fill slots with
//!   [`RecvBufs::fill_slot`]-style resize-then-overwrite (never
//!   `clear` + `resize`, which would memset) and must not shrink
//!   capacity.
//!
//! # Failure model
//!
//! Every blocking operation in this layer carries a deadline from
//! [`NetConfig`], and faults are split into *retryable* link errors
//! (answered by the TCP transport's reconnect + resync-and-resend pass)
//! and *fatal* errors (deadline expiry, wire corruption, protocol
//! divergence) that fail the in-flight job. The full fault taxonomy, the
//! resync handshake and the explicit non-goals (Byzantine peers, network
//! partitions) are documented in DESIGN.md §7; deterministic fault
//! injection for tests lives in [`fault::FaultyTransport`].

// The serving layer must not be able to panic on a peer-controlled input:
// unwrap/expect are lint errors throughout `net` (tests are allow-listed).
#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod accounting;
pub mod fault;
pub mod local;
pub mod profile;
pub mod sim;
pub mod tcp;

use crate::error::{Error, Result};
use accounting::{CommTrace, Phase};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Deadlines and bounds for every blocking operation in the session layer
/// (DESIGN.md §7). Threaded through [`tcp::TcpTransport`] (dial, accept,
/// identify handshake, per-round read/write deadlines, reconnect budget),
/// [`local::hub_with`] (round deadline) and the coordinator's
/// `ServeOptions`. The defaults match the pre-deadline behavior (30 s
/// dial/round budgets) so existing deployments see no policy change —
/// they just stop hanging forever.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NetConfig {
    /// Overall budget for bringing a link up: dialing (with backoff) or
    /// waiting for an inbound connection, including during reconnect.
    pub connect_timeout: Duration,
    /// Per-message deadline inside the identify/resync handshake.
    pub handshake_timeout: Duration,
    /// Deadline for one round's bytes from one peer. Expiry is **fatal**
    /// ([`Error::Timeout`]): a hung peer cannot be repaired by
    /// reconnecting (see DESIGN.md §7).
    pub round_timeout: Duration,
    /// Maximum accepted frame payload, enforced *before* allocation. The
    /// protocol's messages are documented < 16 MiB, so the default (16
    /// MiB) admits every legal frame while rejecting the 4 GiB garbage a
    /// corrupt length header used to let through.
    pub max_frame_len: usize,
    /// Reconnect attempts per link fault before giving up on a session.
    pub retries: u32,
    /// Initial dial backoff; doubles per failed attempt (capped at 1 s),
    /// replacing the old fixed 50 ms poll.
    pub backoff: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            connect_timeout: Duration::from_secs(30),
            handshake_timeout: Duration::from_secs(5),
            round_timeout: Duration::from_secs(30),
            max_frame_len: 16 << 20,
            retries: 3,
            backoff: Duration::from_millis(10),
        }
    }
}

impl NetConfig {
    /// Parse the shared CLI knobs (`--connect-timeout-ms`,
    /// `--handshake-timeout-ms`, `--round-timeout-ms`, `--max-frame-len`,
    /// `--retries`, `--backoff-ms`) over the defaults. Used by the
    /// `infer`/`serve`/`party` subcommands.
    pub fn from_args(args: &crate::util::cli::Args) -> Result<NetConfig> {
        let d = NetConfig::default();
        let ms = |v: u64| Duration::from_millis(v);
        Ok(NetConfig {
            connect_timeout: ms(args
                .opt_parse("connect-timeout-ms", d.connect_timeout.as_millis() as u64)?),
            handshake_timeout: ms(args
                .opt_parse("handshake-timeout-ms", d.handshake_timeout.as_millis() as u64)?),
            round_timeout: ms(args
                .opt_parse("round-timeout-ms", d.round_timeout.as_millis() as u64)?),
            max_frame_len: args.opt_parse("max-frame-len", d.max_frame_len)?,
            retries: args.opt_parse("retries", d.retries)?,
            backoff: ms(args.opt_parse("backoff-ms", d.backoff.as_millis() as u64)?),
        })
    }
}

/// Fault/recovery counters for one transport endpoint (shared `Arc`, like
/// [`CommTrace`]). The chaos suite asserts recovery happened through the
/// real machinery by reading these; the coordinator folds them into its
/// serving metrics.
#[derive(Debug, Default)]
pub struct NetStats {
    timeouts: AtomicU64,
    retries: AtomicU64,
    reconnects: AtomicU64,
    resends: AtomicU64,
}

/// Plain-value snapshot of [`NetStats`].
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct NetStatsSnapshot {
    /// Round/handshake deadlines that expired (each is a fatal error).
    pub timeouts: u64,
    /// Failed dial attempts that were retried with backoff.
    pub retries: u64,
    /// Links torn down and successfully re-established mid-session.
    pub reconnects: u64,
    /// Retained frames resent after a resync handshake.
    pub resends: u64,
}

impl NetStats {
    pub fn note_timeout(&self) {
        self.timeouts.fetch_add(1, Ordering::Relaxed);
    }
    pub fn note_retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
    }
    pub fn note_reconnect(&self) {
        self.reconnects.fetch_add(1, Ordering::Relaxed);
    }
    pub fn note_resend(&self) {
        self.resends.fetch_add(1, Ordering::Relaxed);
    }
    pub fn snapshot(&self) -> NetStatsSnapshot {
        NetStatsSnapshot {
            timeouts: self.timeouts.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            reconnects: self.reconnects.load(Ordering::Relaxed),
            resends: self.resends.load(Ordering::Relaxed),
        }
    }
}

/// Caller-owned, per-peer receive buffers for [`Transport::exchange_all_into`].
///
/// Slot `q` holds party `q`'s payload for the most recent round (the slot
/// for the caller's own id has unspecified contents — see the module
/// docs). Buffers are reused across rounds: lengths are reset to each
/// round's payload size but heap capacity is retained, so a warmed
/// `RecvBufs` makes the receive path allocation-free. See the module docs
/// for the full ownership rules.
#[derive(Debug)]
pub struct RecvBufs {
    bufs: Vec<Vec<u8>>,
}

impl RecvBufs {
    /// Empty buffer set for a session of `parties` parties.
    pub fn new(parties: usize) -> RecvBufs {
        // HOT-PATH-ALLOW: constructor — empty slots; rounds reuse capacity.
        RecvBufs { bufs: (0..parties).map(|_| Vec::new()).collect() }
    }

    /// Number of party slots.
    pub fn parties(&self) -> usize {
        self.bufs.len()
    }

    /// Payload received from party `q` in the most recent round.
    pub fn get(&self, q: usize) -> &[u8] {
        &self.bufs[q]
    }

    /// Mutable slot access for transport implementations. Transports must
    /// fully overwrite each slot (see module docs); protocol code should
    /// only read via [`RecvBufs::get`].
    pub fn slots_mut(&mut self) -> &mut [Vec<u8>] {
        &mut self.bufs
    }

    /// Copy `src` into `slot` without a memset: resize only when the
    /// length changes (growth within capacity allocates nothing), then
    /// overwrite every byte.
    pub fn fill_slot(slot: &mut Vec<u8>, src: &[u8]) {
        if slot.len() != src.len() {
            slot.clear();
            slot.reserve(src.len());
            // SAFETY-free path: extend from the source directly; capacity
            // is retained so the warm case never reallocates.
            slot.extend_from_slice(src);
        } else {
            slot.copy_from_slice(src);
        }
    }

    /// Consume into the legacy per-round `Vec<Vec<u8>>` shape (used by the
    /// `exchange_all` compatibility shim).
    pub fn into_vec(self) -> Vec<Vec<u8>> {
        self.bufs
    }
}

/// Abstract all-to-all exchange primitive for one party.
///
/// GMW only ever needs "every party sends a buffer to every other party and
/// receives theirs" (openings of masked values). One exchange call is one
/// communication **round**.
pub trait Transport: Send {
    /// This party's id in 0..parties.
    fn party(&self) -> usize;
    /// Total number of parties.
    fn parties(&self) -> usize;

    /// Send `data` to every other party; fill `recv` with each *other*
    /// party's payload. The caller's own slot is left with **unspecified
    /// contents** (the engine's fold loops seed from their own shares and
    /// skip it, so the hot path never pays an echo copy). The hot-path
    /// form: with a warmed `recv` the receive side allocates nothing.
    fn exchange_all_into(&mut self, phase: Phase, data: &[u8], recv: &mut RecvBufs)
        -> Result<()>;

    /// Split-phase send half: put `data` on the wire for every peer and
    /// return without waiting for theirs. Callers must pair every begin
    /// with exactly one later [`Transport::exchange_finish`] carrying the
    /// **same** `phase`/`data`, and must finish rounds in begin order.
    /// Several rounds may be in flight at once — that is the point: a
    /// pipelined schedule pays the link latency once across the batch
    /// (DESIGN.md §10). The default is a no-op so non-overlapping
    /// transports degrade to a fully serial (still bit-identical)
    /// schedule via the default `exchange_finish`.
    fn exchange_begin(&mut self, _phase: Phase, _data: &[u8]) -> Result<()> {
        Ok(())
    }

    /// Split-phase receive half: block until every peer's payload for the
    /// oldest in-flight begun round is in `recv`. `phase` and `data` must
    /// match the paired [`Transport::exchange_begin`] call (the default
    /// implementation replays them through the serial
    /// [`Transport::exchange_all_into`], which is what makes the default
    /// pair correct for transports that never opted in).
    fn exchange_finish(&mut self, phase: Phase, data: &[u8], recv: &mut RecvBufs) -> Result<()> {
        self.exchange_all_into(phase, data, recv)
    }

    /// Legacy allocating form: returns a vec indexed by party id (entry
    /// for `self.party()` is the input `data` echoed back, so openings
    /// can simply fold over all). Default shim over
    /// [`Transport::exchange_all_into`]; kept for tests and non-hot-path
    /// callers.
    fn exchange_all(&mut self, phase: Phase, data: &[u8]) -> Result<Vec<Vec<u8>>> {
        let mut recv = RecvBufs::new(self.parties());
        self.exchange_all_into(phase, data, &mut recv)?;
        let me = self.party();
        RecvBufs::fill_slot(&mut recv.slots_mut()[me], data);
        Ok(recv.into_vec())
    }

    /// The accounting trace for this party.
    fn trace(&self) -> Arc<CommTrace>;

    /// Chaos hook used by [`fault::FaultyTransport`]: forcibly sever the
    /// link to `peer` so the *next* exchange observes a real link fault
    /// (and, for transports with recovery, exercises the real
    /// reconnect-and-resend machinery — see DESIGN.md §7). Returns `true`
    /// if a real fault was injected; the default (`false`) tells the
    /// wrapper to synthesize a connection-reset error instead.
    fn inject_peer_drop(&mut self, _peer: usize) -> bool {
        false
    }
}

/// Helper: XOR-open a vector of packed binary share words. An empty slice
/// (degenerate 0-party open) folds to an empty vector rather than
/// panicking. (Shared by engine code and tests.)
pub fn fold_xor(bufs: &[Vec<u64>]) -> Vec<u64> {
    // HOT-PATH-ALLOW: by-value open helper — engine rounds fold in place.
    let Some(first) = bufs.first() else { return Vec::new() };
    let n = first.len();
    // HOT-PATH-ALLOW: output vector of the by-value API.
    let mut out = vec![0u64; n];
    for b in bufs {
        debug_assert_eq!(b.len(), n);
        for (o, v) in out.iter_mut().zip(b) {
            *o ^= *v;
        }
    }
    out
}

/// Helper: additively open a vector of ring-element shares. Empty input
/// folds to an empty vector (1-party/degenerate-open case).
pub fn fold_add(bufs: &[Vec<u64>]) -> Vec<u64> {
    // HOT-PATH-ALLOW: by-value open helper — engine rounds fold in place.
    let Some(first) = bufs.first() else { return Vec::new() };
    let n = first.len();
    // HOT-PATH-ALLOW: output vector of the by-value API.
    let mut out = vec![0u64; n];
    for b in bufs {
        debug_assert_eq!(b.len(), n);
        for (o, v) in out.iter_mut().zip(b) {
            *o = o.wrapping_add(*v);
        }
    }
    out
}

/// Serialize a u64 slice little-endian into a reusable buffer. Every byte
/// is overwritten, so a buffer already at the right length (the warm
/// arena-pooled path) is neither cleared nor reallocated. Hot-path form
/// used by the arithmetic openings.
pub fn u64s_to_bytes_into(v: &[u64], out: &mut Vec<u8>) {
    let nbytes = v.len() * 8;
    if out.len() != nbytes {
        out.clear();
        out.resize(nbytes, 0);
    }
    for (chunk, x) in out.chunks_exact_mut(8).zip(v) {
        chunk.copy_from_slice(&x.to_le_bytes());
    }
}

/// Serialize a u64 slice little-endian (wire format helper).
pub fn u64s_to_bytes(v: &[u64]) -> Vec<u8> {
    // HOT-PATH-ALLOW: by-value wrapper — rounds use `u64s_to_bytes_into`.
    let mut out = Vec::with_capacity(v.len() * 8);
    u64s_to_bytes_into(v, &mut out);
    out
}

/// Wrapping-add each little-endian u64 in `b` into `out` in place (the
/// receive-side fold of an arithmetic opening; no intermediate vector).
///
/// Hard wire check (all builds — peer data is untrusted): `b` must hold
/// exactly `out.len()` 8-byte words. A short, long or ragged payload is
/// truncation/corruption on the wire and must never be zero-padded into
/// plausible share data.
pub fn add_u64s_from_bytes(b: &[u8], out: &mut [u64]) -> Result<()> {
    if b.len() != out.len() * 8 {
        return Err(Error::wire(format!(
            "arithmetic opening expects {} bytes, got {}",
            out.len() * 8,
            b.len()
        )));
    }
    for (o, c) in out.iter_mut().zip(b.chunks_exact(8)) {
        *o = o.wrapping_add(le_u64(c));
    }
    Ok(())
}

/// `u64::from_le_bytes` over a `chunks_exact(8)` chunk: the conversion is
/// infallible by construction, so the lint-exempt unwrap is confined here.
#[allow(clippy::unwrap_used)]
fn le_u64(chunk: &[u8]) -> u64 {
    u64::from_le_bytes(chunk.try_into().unwrap())
}

/// Deserialize little-endian u64s.
///
/// Hard wire check (all builds): the payload must be a whole number of
/// 8-byte words. A trailing partial chunk is truncated/corrupt wire data;
/// zero-padding it (the old behavior) would silently launder it into
/// valid-looking shares.
pub fn bytes_to_u64s(b: &[u8]) -> Result<Vec<u64>> {
    if b.len() % 8 != 0 {
        return Err(Error::wire(format!(
            "u64 payload must be a multiple of 8 bytes, got {}",
            b.len()
        )));
    }
    // HOT-PATH-ALLOW: by-value wrapper — rounds fold bytes in place.
    Ok(b.chunks_exact(8).map(le_u64).collect())
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn u64_bytes_roundtrip() {
        let v = vec![0u64, 1, u64::MAX, 0x0102_0304_0506_0708];
        assert_eq!(bytes_to_u64s(&u64s_to_bytes(&v)).unwrap(), v);
    }

    #[test]
    fn add_fold_from_bytes_matches_wrapping_add() {
        let v = vec![1u64, u64::MAX, 7];
        let b = u64s_to_bytes(&v);
        let mut out = vec![1u64, 1, 1];
        add_u64s_from_bytes(&b, &mut out).unwrap();
        assert_eq!(out, vec![2, 0, 8]);
        let mut reused = Vec::new();
        u64s_to_bytes_into(&v, &mut reused);
        assert_eq!(reused, b);
    }

    #[test]
    fn folds() {
        let a = vec![vec![1u64, 2], vec![3u64, 4]];
        assert_eq!(fold_xor(&a), vec![2, 6]);
        assert_eq!(fold_add(&a), vec![4, 6]);
    }

    /// Degenerate opens (no parties contributed) fold to empty instead of
    /// panicking on `bufs[0]`.
    #[test]
    fn folds_empty_input_is_empty() {
        let empty: Vec<Vec<u64>> = Vec::new();
        assert_eq!(fold_xor(&empty), Vec::<u64>::new());
        assert_eq!(fold_add(&empty), Vec::<u64>::new());
        // Single-party "open": identity fold.
        let one = vec![vec![9u64, 4]];
        assert_eq!(fold_xor(&one), vec![9, 4]);
        assert_eq!(fold_add(&one), vec![9, 4]);
    }

    /// Regression: a trailing partial 8-byte chunk used to be zero-padded
    /// into a "valid" word, masking wire truncation. It is now a hard
    /// wire-format error in every build.
    #[test]
    fn ragged_u64_payload_is_rejected() {
        let good = u64s_to_bytes(&[1, 2, 3]);
        assert_eq!(bytes_to_u64s(&good).unwrap().len(), 3);
        let ragged = &good[..good.len() - 3];
        assert!(matches!(bytes_to_u64s(ragged), Err(crate::error::Error::Wire(_))));
        assert!(matches!(bytes_to_u64s(&[0u8; 7]), Err(crate::error::Error::Wire(_))));
    }

    /// Regression: the receive-side arithmetic fold must reject payloads
    /// whose length disagrees with the lane count instead of folding a
    /// zero-padded prefix.
    #[test]
    fn mismatched_arith_payload_is_rejected() {
        let b = u64s_to_bytes(&[5, 6]);
        let mut out = vec![0u64; 2];
        add_u64s_from_bytes(&b, &mut out).unwrap();
        assert_eq!(out, vec![5, 6]);
        // One lane short of the payload, and one lane long.
        let mut short = vec![0u64; 3];
        assert!(matches!(
            add_u64s_from_bytes(&b, &mut short),
            Err(crate::error::Error::Wire(_))
        ));
        let mut long = vec![0u64; 1];
        assert!(matches!(
            add_u64s_from_bytes(&b, &mut long),
            Err(crate::error::Error::Wire(_))
        ));
        // Untouched on error: no partial fold.
        assert_eq!(short, vec![0, 0, 0]);
    }

    /// CLI knobs overlay the defaults field-by-field, and the default
    /// frame cap sits at the documented 16 MiB message ceiling — far below
    /// the 4 GiB the old guard admitted.
    #[test]
    fn net_config_from_args_and_defaults() {
        let d = NetConfig::default();
        assert_eq!(d.max_frame_len, 16 << 20);
        let args = crate::util::cli::Args::parse(
            ["--round-timeout-ms", "250", "--retries", "5", "--max-frame-len", "1024"]
                .iter()
                .map(|s| s.to_string()),
        );
        let c = NetConfig::from_args(&args).unwrap();
        assert_eq!(c.round_timeout, Duration::from_millis(250));
        assert_eq!(c.retries, 5);
        assert_eq!(c.max_frame_len, 1024);
        assert_eq!(c.connect_timeout, d.connect_timeout);
        let bad = crate::util::cli::Args::parse(
            ["--round-timeout-ms", "soon"].iter().map(|s| s.to_string()),
        );
        assert!(NetConfig::from_args(&bad).is_err());

        let stats = NetStats::default();
        stats.note_reconnect();
        stats.note_resend();
        let snap = stats.snapshot();
        assert_eq!((snap.reconnects, snap.resends, snap.timeouts, snap.retries), (1, 1, 0, 0));
    }

    #[test]
    fn fill_slot_reuses_capacity() {
        let mut slot = Vec::new();
        RecvBufs::fill_slot(&mut slot, &[1, 2, 3, 4]);
        assert_eq!(slot, vec![1, 2, 3, 4]);
        let cap = slot.capacity();
        let ptr = slot.as_ptr();
        // Same length: plain overwrite, same allocation.
        RecvBufs::fill_slot(&mut slot, &[9, 9, 9, 9]);
        assert_eq!(slot, vec![9, 9, 9, 9]);
        assert_eq!(slot.as_ptr(), ptr);
        // Shorter length: shrink without releasing capacity.
        RecvBufs::fill_slot(&mut slot, &[7]);
        assert_eq!(slot, vec![7]);
        assert!(slot.capacity() >= cap);
    }
}
