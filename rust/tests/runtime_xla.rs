//! Integration: the PJRT runtime executes the Pallas-lowered HLO artifacts
//! and matches the pure-Rust kernels bit-for-bit, and the XLA kernel
//! backend drives the full GMW protocol to the same results as the Rust
//! backend. Requires `make artifacts` (skips cleanly if absent).

use hummingbird::crypto::prg::Prg;
use hummingbird::gmw::harness::{run_parties, run_parties_with};
use hummingbird::gmw::kernels::{KernelBackend, RustKernels};
use hummingbird::gmw::ReluPlan;
use hummingbird::ring;
use hummingbird::runtime::{Manifest, Runtime, XlaKernels};
use hummingbird::sharing::{reconstruct_arith, share_arith};

fn artifacts_root() -> Option<std::path::PathBuf> {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if root.join("manifest.json").exists() {
        Some(root)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn xla_kernels_match_rust_kernels() {
    let Some(root) = artifacts_root() else { return };
    let rt = Runtime::new(&root).unwrap();
    let manifest = Manifest::load(&root).unwrap();
    let mut xla = XlaKernels::new(rt, manifest);
    let mut rust = RustKernels::default();
    let mut prg = Prg::new(42, 0);
    // Cover: smaller than a bucket, exact bucket, between buckets, above
    // the largest bucket (chunking).
    for n in [100usize, 1024, 5000, 40000] {
        let u = prg.vec_u64(n);
        let v = prg.vec_u64(n);
        let a = prg.vec_u64(n);
        let b = prg.vec_u64(n);
        let c = prg.vec_u64(n);
        let mut de_x = vec![0u64; 2 * n];
        let mut de_r = vec![0u64; 2 * n];
        xla.and_open(&u, &v, &a, &b, &mut de_x);
        rust.and_open(&u, &v, &a, &b, &mut de_r);
        assert_eq!(de_x, de_r, "and_open n={n}");
        let mut z_x = vec![0u64; n];
        let mut z_r = vec![0u64; n];
        for leader in [true, false] {
            xla.and_combine(&u, &v, &a, &b, &c, leader, &mut z_x);
            rust.and_combine(&u, &v, &a, &b, &c, leader, &mut z_r);
            assert_eq!(z_x, z_r, "and_combine n={n}");
            xla.mult_combine(&u, &v, &a, &b, &c, leader, &mut z_x);
            rust.mult_combine(&u, &v, &a, &b, &c, leader, &mut z_r);
            assert_eq!(z_x, z_r, "mult_combine n={n}");
        }
        xla.mult_open(&u, &v, &a, &b, &mut de_x);
        rust.mult_open(&u, &v, &a, &b, &mut de_r);
        assert_eq!(de_x, de_r, "mult_open n={n}");
        for w in [6u32, 20, 64] {
            let mask = ring::low_mask(w);
            let g: Vec<u64> = u.iter().map(|x| x & mask).collect();
            let p: Vec<u64> = v.iter().map(|x| x & mask).collect();
            for (s, last) in [(1u32, false), (4, true)] {
                let halves = if last { 1 } else { 2 };
                let mut xu = vec![0u64; halves * n];
                let mut xv = vec![0u64; halves * n];
                let mut ru = vec![0u64; halves * n];
                let mut rv = vec![0u64; halves * n];
                xla.ks_stage_operands(&g, &p, s, w, last, &mut xu, &mut xv);
                rust.ks_stage_operands(&g, &p, s, w, last, &mut ru, &mut rv);
                assert_eq!(xu, ru, "stage u n={n} w={w} s={s} last={last}");
                assert_eq!(xv, rv, "stage v n={n} w={w} s={s} last={last}");
            }
        }
    }
}

#[test]
fn full_relu_protocol_on_xla_backend() {
    let Some(root) = artifacts_root() else { return };
    let parties = 2;
    let mut prg = Prg::new(7, 7);
    let n = 300;
    let x: Vec<u64> = (0..n)
        .map(|i| {
            let v = prg.next_u64() % (1 << 20);
            if i % 2 == 0 {
                v
            } else {
                v.wrapping_neg()
            }
        })
        .collect();
    let xs = share_arith(&mut prg, &x, parties);
    let plan = ReluPlan::new(24, 4).unwrap();

    // Rust backend reference run.
    let rust_run = run_parties(parties, 99, |p| {
        let me = p.party();
        p.relu(&xs[me], plan).unwrap()
    });
    let expect = reconstruct_arith(&rust_run.outputs);

    // XLA backend run (per-party runtime built in-thread).
    let root2 = root.clone();
    let xla_run = run_parties_with(
        parties,
        99,
        move |_pid| {
            let rt = Runtime::new(&root2).unwrap();
            let manifest = Manifest::load(&root2).unwrap();
            XlaKernels::new(rt, manifest)
        },
        |p| {
            let me = p.party();
            assert_eq!(p.kernel_name(), "xla");
            p.relu(&xs[me], plan).unwrap()
        },
    );
    let got = reconstruct_arith(&xla_run.outputs);
    assert_eq!(got, expect, "XLA-backend protocol output differs from Rust backend");
    // Same protocol => identical communication trace shape.
    assert_eq!(rust_run.trace.total_rounds(), xla_run.trace.total_rounds());
    assert_eq!(rust_run.trace.total_bytes(), xla_run.trace.total_bytes());
}
