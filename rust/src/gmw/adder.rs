//! Kogge–Stone prefix adder on binary shares (paper §2.2: "the addition …
//! is performed using a series of AND and XOR operations, as it would be
//! done by an adder circuit (e.g., carry-lookahead adder)").
//!
//! Lane layout: each element is an independent w-bit value stored in the
//! low bits of a u64; the adder is vectorized across elements, and the AND
//! gates of all elements in a stage are opened in **one** round.
//!
//! Cost model (the paper's O(N·logN) → O(w·log w) claim):
//!   * 1 initial AND round  (G₀ = x∧y)            — tagged `Phase::OtherAnd`
//!   * ⌈log₂ w⌉ stage rounds, 2 ANDs each batched — tagged `Phase::Circuit`
//!     (the final stage only updates G: 1 AND)
//! Per round each party sends 2·w bits per element per AND, bit-packed.

use super::kernels::KernelBackend;
use super::GmwParty;
use crate::error::Result;
use crate::net::accounting::Phase;
use crate::net::Transport;
use crate::ring;

/// Number of communication rounds `ks_add` will use for width `w`
/// (initial AND + prefix stages). Used by cost estimators and tests.
pub fn rounds_for_width(w: u32) -> u32 {
    if w <= 1 {
        0
    } else {
        1 + (32 - (w - 1).leading_zeros()) // 1 + ceil(log2(w))
    }
}

/// Bytes each party sends during one `ks_add` over `n` elements of width
/// `w` (exact, matching the bit-packed wire format).
pub fn bytes_for_add(n: usize, w: u32) -> u64 {
    if w <= 1 {
        return 0;
    }
    let mut total = crate::bitpack::packed_bytes(2 * n, w); // initial AND: d||e
    let stages = ceil_log2(w);
    for idx in 0..stages {
        let last = idx + 1 == stages;
        let ands = if last { 1 } else { 2 };
        total += crate::bitpack::packed_bytes(2 * ands * n, w);
    }
    total
}

fn ceil_log2(w: u32) -> u32 {
    if w <= 1 {
        0
    } else {
        32 - (w - 1).leading_zeros()
    }
}

/// Adder design knobs (defaults = the optimized protocol). The ablation
/// bench (`benches/ablation.rs`) measures what each optimization buys;
/// DESIGN.md §5.2 documents the choices.
#[derive(Debug, Clone, Copy)]
pub struct AdderOptions {
    /// Batch a stage's two ANDs (G and P updates) into one opening round.
    /// Off: two rounds per stage (the naive circuit-walker layout).
    pub batch_stage_ands: bool,
    /// Skip the P update on the final stage (its output is never read),
    /// halving the last round's bytes.
    pub skip_last_p: bool,
}

impl Default for AdderOptions {
    fn default() -> Self {
        AdderOptions { batch_stage_ands: true, skip_last_p: true }
    }
}

/// Secure addition of two binary-shared vectors of w-bit lanes; returns
/// binary shares of (x + y) mod 2^w.
pub fn ks_add<T: Transport, K: KernelBackend>(
    party: &mut GmwParty<T, K>,
    x: &[u64],
    y: &[u64],
    w: u32,
) -> Result<Vec<u64>> {
    ks_add_with(party, x, y, w, AdderOptions::default())
}

/// [`ks_add`] with explicit design knobs (ablations).
pub fn ks_add_with<T: Transport, K: KernelBackend>(
    party: &mut GmwParty<T, K>,
    x: &[u64],
    y: &[u64],
    w: u32,
    opts: AdderOptions,
) -> Result<Vec<u64>> {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let mask = ring::low_mask(w);

    // w == 1: addition mod 2 is XOR; no carries, no communication.
    if w == 1 {
        return Ok(x.iter().zip(y).map(|(a, b)| (a ^ b) & 1).collect());
    }

    // P = x ⊕ y (local), G = x ∧ y (one AND round, "Others" in Fig 3).
    let mut p: Vec<u64> = x.iter().zip(y).map(|(a, b)| (a ^ b) & mask).collect();
    let mut g = party.and_gates(Phase::OtherAnd, x, y, w)?;

    // Prefix stages ("Circuit" in Fig 3).
    let stages = ceil_log2(w);
    let mut s = 1u32;
    for idx in 0..stages {
        let last = opts.skip_last_p && idx + 1 == stages;
        if opts.batch_stage_ands || last {
            let (u, v) = party.kernels_stage_operands(&g, &p, s, w, last);
            let z = party.and_gates(Phase::Circuit, &u, &v, w)?;
            if last {
                // z = P ∧ (G ≪ s)
                for i in 0..n {
                    g[i] ^= z[i];
                }
            } else {
                let (zg, zp) = z.split_at(n);
                for i in 0..n {
                    g[i] ^= zg[i];
                    p[i] = zp[i];
                }
            }
        } else {
            // Naive layout: one opening round per AND.
            let gv: Vec<u64> = g.iter().map(|gi| (gi << s) & mask).collect();
            let pv: Vec<u64> = p.iter().map(|pi| (pi << s) & mask).collect();
            let zg = party.and_gates(Phase::Circuit, &p, &gv, w)?;
            let zp = party.and_gates(Phase::Circuit, &p, &pv, w)?;
            for i in 0..n {
                g[i] ^= zg[i];
                p[i] = zp[i];
            }
        }
        s <<= 1;
    }

    // Sum = x ⊕ y ⊕ (carries ≪ 1); carries into bit i are G[i−1].
    let out = x
        .iter()
        .zip(y)
        .zip(&g)
        .map(|((a, b), gi)| (a ^ b ^ (gi << 1)) & mask)
        .collect();
    Ok(out)
}

impl<T: Transport, K: KernelBackend> GmwParty<T, K> {
    /// Expose the kernel's stage-operand builder to the adder (keeps the
    /// `kernels` field private to `gmw::mod`).
    pub(crate) fn kernels_stage_operands(
        &mut self,
        g: &[u64],
        p: &[u64],
        s: u32,
        w: u32,
        last: bool,
    ) -> (Vec<u64>, Vec<u64>) {
        self.kernels_mut().ks_stage_operands(g, p, s, w, last)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_counts() {
        assert_eq!(rounds_for_width(1), 0);
        assert_eq!(rounds_for_width(2), 2); // init + 1 stage
        assert_eq!(rounds_for_width(8), 4); // init + 3
        assert_eq!(rounds_for_width(64), 7); // init + 6
        // The paper's round-reduction claim: 6 bits vs 64 bits
        assert!(rounds_for_width(6) < rounds_for_width(64));
    }

    #[test]
    fn byte_costs_scale_superlinearly_in_width() {
        let n = 1000;
        let b64 = bytes_for_add(n, 64);
        let b8 = bytes_for_add(n, 8);
        // O(w log w): 64→8 bits should shrink bytes by more than 8×.
        assert!(b64 / b8 >= 8, "b64={b64} b8={b8}");
        assert_eq!(bytes_for_add(n, 1), 0);
    }
}
