//! Offline/online split suite: the background-prefetch provisioning path
//! must be **bit-identical** to the synchronous dealer — per-party output
//! shares, wire bytes, round counts and `TripleUsage` — across layouts
//! (lane / bitsliced), thread counts and party counts; the schedule
//! prediction must match the protocol's actual draws (pinned through a
//! recording dry run); and the steady state must stay allocation-free
//! with clean mid-stream cancellation. Dealer-level stream equality is
//! pinned by the unit tests in `beaver::prefetch`; here we pin the
//! protocol built on top.

use hummingbird::beaver::schedule::{Recorder, TripleSchedule};
use hummingbird::beaver::TtpDealer;
use hummingbird::crypto::prg::Prg;
use hummingbird::gmw::harness::run_parties_with_threaded;
use hummingbird::gmw::kernels::{BitslicedKernels, RustKernels};
use hummingbird::gmw::{bitsliced, ReluPlan};
use hummingbird::sharing::{reconstruct_arith, share_arith};

fn relu_inputs(n: usize, plan: ReluPlan, seed: u64) -> Vec<u64> {
    let mut prg = Prg::new(seed, n as u64);
    (0..n)
        .map(|i| {
            let v = prg.next_u64() % (1u64 << (plan.k.max(2) - 1));
            if i % 2 == 0 {
                v
            } else {
                v.wrapping_neg()
            }
        })
        .collect()
}

/// The acceptance pin: with prefetch on, per-party shares, wire bytes,
/// rounds and `TripleUsage` equal the synchronous run — for both layouts,
/// 1/N threads, 2/3 parties, windows including w = 1, the full-width
/// baseline and the identity plan — and **every draw is served from
/// pre-filled buffers** (zero fallback expansions inside the online path).
#[test]
fn prefetch_relu_bit_identical_across_layouts_and_threads() {
    let default_threads = hummingbird::util::threadpool::default_threads();
    let plans = [
        ReluPlan::new(12, 4).unwrap(),  // w = 8, the paper's regime
        ReluPlan::new(8, 7).unwrap(),   // w = 1: adder-free DReLU
        ReluPlan::new(20, 0).unwrap(),  // eco window
        ReluPlan::new(10, 10).unwrap(), // identity: draw-free
    ];
    for parties in [2usize, 3] {
        for plan in plans {
            let n = 321usize;
            let x = relu_inputs(n, plan, 9 + plan.k as u64 * 67 + plan.m as u64);
            let mut prg = Prg::new(1000, parties as u64);
            let xs = share_arith(&mut prg, &x, parties);
            for threads in [1usize, default_threads] {
                let ctx = format!(
                    "parties={parties} k={} m={} threads={threads}",
                    plan.k, plan.m
                );
                let run_lane_sync = run_parties_with_threaded(
                    parties,
                    17,
                    threads,
                    |_| RustKernels::default(),
                    |p| {
                        let me = p.party();
                        let r = p.relu(&xs[me], plan).unwrap();
                        (r, p.triple_usage())
                    },
                );
                let run_lane_pf = run_parties_with_threaded(
                    parties,
                    17,
                    threads,
                    |_| RustKernels::default(),
                    |p| {
                        p.enable_prefetch(TripleSchedule::for_relu(n, plan, p.parties()), false);
                        let me = p.party();
                        let r = p.relu(&xs[me], plan).unwrap();
                        let st = p.prefetch_stats().expect("prefetcher installed");
                        assert_eq!(st.fallback_ops, 0, "online path expanded PRG material");
                        (r, p.triple_usage())
                    },
                );
                assert_eq!(run_lane_sync.outputs, run_lane_pf.outputs, "lane shares: {ctx}");
                assert_eq!(
                    run_lane_sync.trace.total_bytes(),
                    run_lane_pf.trace.total_bytes(),
                    "lane wire bytes: {ctx}"
                );
                assert_eq!(
                    run_lane_sync.trace.total_rounds(),
                    run_lane_pf.trace.total_rounds(),
                    "lane rounds: {ctx}"
                );

                let run_sliced_sync = run_parties_with_threaded(
                    parties,
                    17,
                    threads,
                    |_| BitslicedKernels::default(),
                    |p| {
                        let me = p.party();
                        let r = p.relu(&xs[me], plan).unwrap();
                        (r, p.triple_usage())
                    },
                );
                let run_sliced_pf = run_parties_with_threaded(
                    parties,
                    17,
                    threads,
                    |_| BitslicedKernels::default(),
                    |p| {
                        p.enable_prefetch(TripleSchedule::for_relu(n, plan, p.parties()), false);
                        let me = p.party();
                        let r = p.relu(&xs[me], plan).unwrap();
                        let st = p.prefetch_stats().expect("prefetcher installed");
                        assert_eq!(st.fallback_ops, 0, "online path expanded PRG material");
                        (r, p.triple_usage())
                    },
                );
                assert_eq!(
                    run_sliced_sync.outputs, run_sliced_pf.outputs,
                    "bitsliced shares: {ctx}"
                );
                assert_eq!(
                    run_sliced_sync.trace.total_bytes(),
                    run_sliced_pf.trace.total_bytes(),
                    "bitsliced wire bytes: {ctx}"
                );
                assert_eq!(
                    run_sliced_sync.trace.total_rounds(),
                    run_sliced_pf.trace.total_rounds(),
                    "bitsliced rounds: {ctx}"
                );
                // And across layouts (prefetch preserves the PR 4 invariant).
                assert_eq!(run_lane_pf.outputs, run_sliced_pf.outputs, "cross-layout: {ctx}");

                // Still a ReLU.
                let shares: Vec<Vec<u64>> =
                    run_lane_pf.outputs.iter().map(|(s, _)| s.clone()).collect();
                let z = reconstruct_arith(&shares);
                if plan.is_identity() {
                    assert_eq!(z, x, "{ctx}");
                } else {
                    for (xi, zi) in x.iter().zip(&z) {
                        assert!(*zi == 0 || zi == xi, "{ctx}");
                    }
                }
                if default_threads == 1 {
                    break;
                }
            }
        }
    }
}

/// Recording dry run: the draws a real ReLU performs — in both layouts —
/// are exactly the predicted `TripleSchedule`, for every party.
#[test]
fn schedule_predicts_actual_relu_draws() {
    for parties in [2usize, 3] {
        for plan in
            [ReluPlan::new(12, 4).unwrap(), ReluPlan::new(8, 7).unwrap(), ReluPlan::BASELINE]
        {
            let n = 130usize;
            let x = relu_inputs(n, plan, 77);
            let mut prg = Prg::new(2000, parties as u64);
            let xs = share_arith(&mut prg, &x, parties);
            let want = TripleSchedule::for_relu(n, plan, parties).ops;
            let lane = run_parties_with_threaded(
                parties,
                21,
                1,
                |_| RustKernels::default(),
                |p| {
                    let (rec, log) = Recorder::new(TtpDealer::new(21, p.party(), p.parties()));
                    p.set_triple_source(Box::new(rec));
                    let me = p.party();
                    p.relu(&xs[me], plan).unwrap();
                    log.lock().unwrap().clone()
                },
            );
            for (party, got) in lane.outputs.iter().enumerate() {
                assert_eq!(
                    got, &want,
                    "lane parties={parties} k={} m={} party={party}",
                    plan.k, plan.m
                );
            }
            // The bitsliced engine draws the identical schedule (same
            // (w, n_seg, segs) at every AND round — the PR 4 invariant the
            // prefetcher relies on).
            let sliced = run_parties_with_threaded(
                parties,
                21,
                1,
                |_| BitslicedKernels::default(),
                |p| {
                    let (rec, log) = Recorder::new(TtpDealer::new(21, p.party(), p.parties()));
                    p.set_triple_source(Box::new(rec));
                    let me = p.party();
                    p.relu(&xs[me], plan).unwrap();
                    log.lock().unwrap().clone()
                },
            );
            for (party, got) in sliced.outputs.iter().enumerate() {
                assert_eq!(
                    got, &want,
                    "bitsliced parties={parties} k={} m={} party={party}",
                    plan.k, plan.m
                );
            }
        }
    }
}

/// Steady state with a cycling prefetcher: the engine arena and transport
/// pools stay allocation-free exactly as with the synchronous dealer, no
/// draw ever falls back to inline expansion, and the producer's own
/// allocations are bounded by the circulating lookahead buffers — not by
/// the number of passes.
#[test]
fn prefetch_steady_state_stays_allocation_free() {
    let parties = 2;
    let n = 512usize;
    let plan = ReluPlan::new(12, 4).unwrap();
    let x = relu_inputs(n, plan, 40);
    let mut prg = Prg::new(3000, 0);
    let xs = share_arith(&mut prg, &x, parties);
    run_parties_with_threaded(
        parties,
        6,
        1,
        |_| RustKernels::default(),
        |p| {
            let schedule = TripleSchedule::for_relu(n, plan, parties);
            let bufs_per_cycle: u64 = schedule
                .ops
                .iter()
                .map(|op| match op {
                    hummingbird::beaver::schedule::DrawOp::DaBits { .. } => 2u64,
                    _ => 3,
                })
                .sum();
            let cycles = 6u64;
            p.enable_prefetch(schedule, true);
            let me = p.party();
            let mut out = vec![0u64; n];
            // Two warm passes populate every pool (engine, transport and
            // the producer's circulating sets).
            p.relu_into(&xs[me], plan, &mut out).unwrap();
            p.relu_into(&xs[me], plan, &mut out).unwrap();
            let warm = p.arena_stats();
            let warm_net = p.transport.pool_stats();
            assert_eq!(warm.checkouts, warm.returns, "engine buffers leaked during warmup");
            for round in 0..cycles - 2 {
                p.relu_into(&xs[me], plan, &mut out).unwrap();
                let s = p.arena_stats();
                assert_eq!(
                    s.alloc_misses, warm.alloc_misses,
                    "steady-state prefetched relu allocated in the engine (round {round})"
                );
                assert_eq!(s.checkouts, s.returns, "unbalanced checkout (round {round})");
                let t = p.transport.pool_stats();
                assert_eq!(
                    t.alloc_misses,
                    warm_net.alloc_misses,
                    "steady-state prefetched relu allocated a transport payload (round {round})"
                );
            }
            let st = p.prefetch_stats().expect("prefetcher installed");
            assert_eq!(st.fallback_ops, 0, "a draw fell back to inline expansion");
            // Producer allocations bounded by lookahead (~3 op-sets in
            // flight), independent of how many passes ran.
            assert!(
                st.producer_arena.alloc_misses <= 3 * bufs_per_cycle,
                "producer allocates per pass: {:?} (bufs/cycle = {bufs_per_cycle})",
                st.producer_arena
            );
            out
        },
    );
}

/// Mid-stream cancel through the engine: a cycling prefetcher provisioned
/// for endless ReLUs is cancelled while mid-cycle (the DReLU consumed the
/// binary draws but not the Mult triple) — the party drop must join the
/// producer cleanly, with no hang and no panic.
#[test]
fn prefetch_cancels_cleanly_mid_stream() {
    let parties = 2;
    let n = 256usize;
    let plan = ReluPlan::new(12, 4).unwrap();
    let x = relu_inputs(n, plan, 50);
    let mut prg = Prg::new(4000, 0);
    let xs = share_arith(&mut prg, &x, parties);
    let run = run_parties_with_threaded(
        parties,
        9,
        1,
        |_| RustKernels::default(),
        |p| {
            p.enable_prefetch(TripleSchedule::for_relu(n, plan, parties), true);
            let me = p.party();
            // DReLU only: leaves the cycle's Arith op (and the whole next
            // cycle) unconsumed; the party is dropped right after.
            p.drelu(&xs[me], plan).unwrap()
        },
    );
    // And it still computed a DReLU (0/1 per element).
    let z = reconstruct_arith(&run.outputs);
    assert!(z.iter().all(|v| *v == 0 || *v == 1));
}

/// w = 1 sanity at the plane layer: the first scheduled draw of a w = 1
/// ReLU is the daBit batch (the adder is XOR-only), and prefetching it
/// still satisfies the engine.
#[test]
fn prefetch_w1_schedule_has_no_binary_draws() {
    let plan = ReluPlan::new(8, 7).unwrap();
    let s = TripleSchedule::for_relu(64, plan, 2);
    assert!(s
        .ops
        .iter()
        .all(|op| !matches!(op, hummingbird::beaver::schedule::DrawOp::BinPlanes { .. })));
    // plane_len is still well-defined at w = 1 (used by buf_shape).
    assert_eq!(bitsliced::plane_len(64, 1), 1);
}
