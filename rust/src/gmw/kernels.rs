//! Local-compute kernels of the GMW engine.
//!
//! Every *local* tensor computation the protocol performs between
//! communication rounds is factored behind [`KernelBackend`], with two
//! implementations:
//!
//! * [`RustKernels`] — portable scalar Rust (this file). The reference
//!   implementation every test validates against, and the fastest choice
//!   for small tensors where dispatch overhead dominates.
//! * `runtime::XlaKernels` — the same five primitives lowered from the
//!   Layer-1 **Pallas kernels** (`python/compile/kernels/bitops.py`) to HLO
//!   and executed on the PJRT CPU client. This is the path that proves the
//!   three-layer composition, and the one a TPU/GPU deployment would use.
//!
//! The five primitives map 1:1 onto the Pallas kernels and onto the
//! protocol's communication structure: each `*_open` produces exactly the
//! masked values that go on the wire, and each `*_combine` consumes exactly
//! what came back.

/// Masked-open / combine primitives for one party.
///
/// Deliberately NOT `Send`: the PJRT client (XLA backend) is thread-local,
/// so each party thread constructs its own backend in-thread (see
/// `gmw::harness::run_parties_with`).
pub trait KernelBackend {
    /// Beaver-AND open: given share vectors u, v and triple shares a, b
    /// (all w-bit lanes), produce the concatenated masked opening
    /// `d || e` = `(u ⊕ a) || (v ⊕ b)` (length 2n).
    fn and_open(&mut self, u: &[u64], v: &[u64], a: &[u64], b: &[u64]) -> Vec<u64>;

    /// Beaver-AND combine: given *public* opened d, e and triple shares
    /// a, b, c, produce this party's share of u ∧ v:
    /// `z = [leader] d∧e ⊕ d∧b ⊕ e∧a ⊕ c`.
    fn and_combine(
        &mut self,
        d: &[u64],
        e: &[u64],
        a: &[u64],
        b: &[u64],
        c: &[u64],
        leader: bool,
    ) -> Vec<u64>;

    /// One Kogge–Stone stage's local prep: from prefix state (g, p) produce
    /// the two AND operand pairs `(u, v)` for this stage:
    /// `u = p || p`, `v = (g ≪ s) || (p ≪ s)` (all masked to w bits).
    /// `last` skips the `p` half (the final stage only needs g).
    fn ks_stage_operands(&mut self, g: &[u64], p: &[u64], s: u32, w: u32, last: bool)
        -> (Vec<u64>, Vec<u64>);

    /// Beaver arithmetic-multiply open: `d || e` = `(x − a) || (y − b)`
    /// over Z/2^64.
    fn mult_open(&mut self, x: &[u64], y: &[u64], a: &[u64], b: &[u64]) -> Vec<u64>;

    /// Beaver arithmetic-multiply combine:
    /// `z = c + d·b + e·a + [leader] d·e` over Z/2^64.
    fn mult_combine(
        &mut self,
        d: &[u64],
        e: &[u64],
        a: &[u64],
        b: &[u64],
        c: &[u64],
        leader: bool,
    ) -> Vec<u64>;

    /// Human-readable backend name (for metrics / bench labels).
    fn name(&self) -> &'static str;
}

/// Portable scalar implementation.
#[derive(Debug, Default, Clone)]
pub struct RustKernels;

impl KernelBackend for RustKernels {
    fn and_open(&mut self, u: &[u64], v: &[u64], a: &[u64], b: &[u64]) -> Vec<u64> {
        debug_assert!(u.len() == v.len() && v.len() == a.len() && a.len() == b.len());
        let n = u.len();
        let mut out = vec![0u64; 2 * n];
        for i in 0..n {
            out[i] = u[i] ^ a[i];
            out[n + i] = v[i] ^ b[i];
        }
        out
    }

    fn and_combine(
        &mut self,
        d: &[u64],
        e: &[u64],
        a: &[u64],
        b: &[u64],
        c: &[u64],
        leader: bool,
    ) -> Vec<u64> {
        let n = d.len();
        let mut out = vec![0u64; n];
        for i in 0..n {
            let mut z = (d[i] & b[i]) ^ (e[i] & a[i]) ^ c[i];
            if leader {
                z ^= d[i] & e[i];
            }
            out[i] = z;
        }
        out
    }

    fn ks_stage_operands(
        &mut self,
        g: &[u64],
        p: &[u64],
        s: u32,
        w: u32,
        last: bool,
    ) -> (Vec<u64>, Vec<u64>) {
        let mask = crate::ring::low_mask(w);
        let n = g.len();
        let halves = if last { 1 } else { 2 };
        let mut u = vec![0u64; halves * n];
        let mut v = vec![0u64; halves * n];
        for i in 0..n {
            u[i] = p[i];
            v[i] = (g[i] << s) & mask;
        }
        if !last {
            for i in 0..n {
                u[n + i] = p[i];
                v[n + i] = (p[i] << s) & mask;
            }
        }
        (u, v)
    }

    fn mult_open(&mut self, x: &[u64], y: &[u64], a: &[u64], b: &[u64]) -> Vec<u64> {
        let n = x.len();
        let mut out = vec![0u64; 2 * n];
        for i in 0..n {
            out[i] = x[i].wrapping_sub(a[i]);
            out[n + i] = y[i].wrapping_sub(b[i]);
        }
        out
    }

    fn mult_combine(
        &mut self,
        d: &[u64],
        e: &[u64],
        a: &[u64],
        b: &[u64],
        c: &[u64],
        leader: bool,
    ) -> Vec<u64> {
        let n = d.len();
        let mut out = vec![0u64; n];
        for i in 0..n {
            let mut z = c[i]
                .wrapping_add(d[i].wrapping_mul(b[i]))
                .wrapping_add(e[i].wrapping_mul(a[i]));
            if leader {
                z = z.wrapping_add(d[i].wrapping_mul(e[i]));
            }
            out[i] = z;
        }
        out
    }

    fn name(&self) -> &'static str {
        "rust"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One-party-world sanity: with "shares" equal to plaintext and a zero
    /// triple, open/combine reduce to plain AND / MUL.
    #[test]
    fn degenerate_open_combine_is_plain_and() {
        let mut k = RustKernels;
        let u = vec![0b1100u64];
        let v = vec![0b1010u64];
        let zero = vec![0u64];
        let de = k.and_open(&u, &v, &zero, &zero);
        assert_eq!(de, vec![0b1100, 0b1010]);
        let z = k.and_combine(&de[..1], &de[1..], &zero, &zero, &zero, true);
        assert_eq!(z, vec![0b1000]);
    }

    #[test]
    fn degenerate_mult_is_plain_mul() {
        let mut k = RustKernels;
        let x = vec![7u64];
        let y = vec![6u64.wrapping_neg()]; // -6
        let zero = vec![0u64];
        let de = k.mult_open(&x, &y, &zero, &zero);
        let z = k.mult_combine(&de[..1], &de[1..], &zero, &zero, &zero, true);
        assert_eq!(z[0] as i64, -42);
    }

    #[test]
    fn stage_operands_shift_and_mask() {
        let mut k = RustKernels;
        let g = vec![0b1000u64];
        let p = vec![0b1111u64];
        let (u, v) = k.ks_stage_operands(&g, &p, 1, 4, false);
        assert_eq!(u, vec![0b1111, 0b1111]);
        assert_eq!(v, vec![0b0000, 0b1110]); // g<<1 overflows the 4-bit lane
        let (u, v) = k.ks_stage_operands(&g, &p, 2, 6, true);
        assert_eq!(u, vec![0b1111]);
        assert_eq!(v, vec![0b100000]);
    }
}
