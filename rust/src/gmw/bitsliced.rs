//! Bitsliced (bit-plane) layout for binary shares — 64 lanes per word.
//!
//! The classic engine layout stores **one w-bit lane per u64**, so every
//! word-wide AND/XOR in the Kogge–Stone adder wastes `64 − w` of the ALU's
//! 64 bits — at the paper's windows (w ≈ 6–8) that is ~90% waste. The
//! bitsliced layout transposes each **block of 64 lanes** into `w`
//! *bit-plane* words: plane `b` of block `k` is a u64 whose bit `j` is bit
//! `b` of lane `64k + j`. One word-wide boolean op then processes 64 lanes
//! at once, and the resulting plain `u64` loops autovectorize to SSE2/AVX2
//! without arch-specific intrinsics.
//!
//! # Representation
//!
//! A vector of `n` lanes of width `w` occupies [`plane_len`]`(n, w) =
//! ceil(n/64)·w` words, **block-major**: block `k`'s planes are the
//! contiguous words `[k·w, (k+1)·w)`, plane index = bit index. Two
//! invariants every producer maintains and every consumer may assume:
//!
//! * **implicit masking** — only planes `0..w` exist, so "`& low_mask(w)`"
//!   is free (there is nothing above bit `w−1` to mask off);
//! * **zero tail lanes** — lanes `n..64·ceil(n/64)` of the final block are
//!   zero in every plane. XOR/AND/plane-shifts preserve this, and the wire
//!   pack relies on it for byte-exact tail bytes.
//!
//! Within the engine, round buffers are often **segmented**: the
//! concatenation of `segs` independent `n`-lane vectors (e.g. the adder's
//! stage operand `u = p ‖ p`). Because `n` need not be a multiple of 64, a
//! segment's plane blocks do *not* coincide with the blocks of the
//! concatenated lane vector — so the wire functions below take the
//! segment's global starting lane (`lane0`) and place bits exactly where
//! the classic packer would.
//!
//! # The transpose-fused wire boundary
//!
//! The wire format is **byte-for-byte identical** to the classic
//! [`crate::bitpack`] stream (lane-major, w bits per lane, little-endian
//! bit order): [`pack_planes_xor_into`] turns a bit-plane block into wire
//! words with one Hacker's-Delight 64×64 bit-matrix transpose per block,
//! written straight into the (arena-pooled, pre-zeroed) wire byte buffer,
//! and [`unpack_bytes_xor_into_planes`] reverses it, XOR-folding a peer's
//! bytes directly into plane form. No intermediate lane vector exists on
//! either side — this subsumes the "SIMD in `bitpack::packed_word`"
//! roadmap lever: the per-word lane gather is replaced by a transpose
//! whose inner loops are fixed-trip-count word ops.
//!
//! Threading: all block loops split across the persistent worker pool
//! above [`tuning::par_min_blocks`] blocks; per-block outputs are disjoint
//! (block-major planes / word-aligned wire ranges), so results are
//! bit-identical at any thread count.
//!
//! # Verification (DESIGN.md §8)
//!
//! The raw-pointer chunking behind those block loops ([`SendPtr`] +
//! [`par_chunks`]) is exactly what `hblint`'s `// SAFETY:` wall and the
//! CI Miri job police: the full-width sweeps below run natively, and the
//! `*_miri_sized` replicas re-run the same pointer paths (threaded)
//! under the interpreter, where a wrong provenance or an overlapping
//! chunk is a hard error rather than silent corruption.

use crate::bitpack::{self, lane_from_words, packed_word, word_at};
use crate::ring::low_mask;
use crate::util::threadpool::{par_chunks, SendPtr};
use crate::util::tuning;

/// Lanes per bit-plane block (the machine word width).
pub const LANES_PER_BLOCK: usize = 64;

thread_local! {
    /// Whole-buffer lane↔plane transpose operations performed by this
    /// thread (see [`thread_transpose_ops`]).
    static TRANSPOSE_OPS: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// Number of whole-buffer layout conversions ([`lanes_to_planes`] /
/// [`planes_to_lanes`] calls) performed by the calling thread since it
/// started. Diagnostic counter for the steady-state tests that pin the
/// plane-native triple path: with the dealer emitting triples in packed
/// wire order, a bitsliced AND round must perform **zero** of these —
/// only the A2B operand staging transposes remain on the DReLU hot path.
/// Each party runs on its own harness thread, so deltas of this counter
/// are per-party and immune to concurrent tests.
pub fn thread_transpose_ops() -> u64 {
    TRANSPOSE_OPS.with(|c| c.get())
}

#[inline]
fn note_transpose_op() {
    TRANSPOSE_OPS.with(|c| c.set(c.get() + 1));
}

/// Number of 64-lane blocks needed for `n` lanes.
#[inline]
pub fn blocks(n: usize) -> usize {
    n.div_ceil(LANES_PER_BLOCK)
}

/// Words in the bit-plane representation of `n` lanes of width `w`.
#[inline]
pub fn plane_len(n: usize, w: u32) -> usize {
    blocks(n) * w as usize
}

/// In-place 64×64 bit-matrix transpose (Hacker's Delight §7-3, recursive
/// block swap), LSB-first convention: after the call, bit `p` of `a[r]` is
/// what bit `r` of `a[p]` was. The transform is an involution.
///
/// This is the always-available scalar arm; the block loops below route
/// through [`transpose64_dispatch`], which substitutes the explicit AVX2
/// transpose from [`super::simd`] when the resolved kernel arm allows it
/// (DESIGN.md §11). Both arms are bit-identical.
pub fn transpose64(a: &mut [u64; 64]) {
    let mut s = 32usize;
    let mut m = 0x0000_0000_FFFF_FFFFu64;
    while s != 0 {
        let mut k = 0usize;
        while k < 64 {
            let t = ((a[k] >> s) ^ a[k + s]) & m;
            a[k] ^= t << s;
            a[k + s] ^= t;
            k = (k + s + 1) & !s; // next index with (k & s) == 0
        }
        s >>= 1;
        m ^= m << s;
    }
}

/// Run the AVX2 transpose when `simd` is set (and the CPU cooperates),
/// the scalar one otherwise.
#[inline]
fn transpose64_dispatch(a: &mut [u64; 64], simd: bool) {
    if simd && super::simd::transpose64(a) {
        return;
    }
    transpose64(a);
}

/// Resolve the block-loop thread budget: below the tuning threshold the
/// loop stays inline on the caller's thread.
#[inline]
fn eff_threads(nblocks: usize, threads: usize) -> usize {
    if nblocks >= tuning::par_min_blocks() {
        threads.max(1)
    } else {
        1
    }
}

/// Transpose lane-per-u64 data into bit-plane form. Bits at or above `w`
/// are discarded (masking to the lane width is free here) and tail lanes
/// of the final block come out zero, establishing both representation
/// invariants. `planes.len()` must be [`plane_len`]`(lanes.len(), w)`.
pub fn lanes_to_planes(lanes: &[u64], w: u32, planes: &mut [u64], threads: usize) {
    debug_assert!(w >= 1 && w <= 64);
    note_transpose_op();
    let n = lanes.len();
    let nblocks = blocks(n);
    let wu = w as usize;
    debug_assert_eq!(planes.len(), nblocks * wu);
    let simd = super::kernels::auto_simd();
    let out = SendPtr(planes.as_mut_ptr());
    let out_ref = &out;
    par_chunks(nblocks, eff_threads(nblocks, threads), move |_, range| {
        for k in range {
            let mut buf = [0u64; 64];
            let lo = k * LANES_PER_BLOCK;
            let r = (n - lo).min(LANES_PER_BLOCK);
            buf[..r].copy_from_slice(&lanes[lo..lo + r]);
            transpose64_dispatch(&mut buf, simd);
            // SAFETY: block k writes only its own plane words [k·w, k·w+w),
            // disjoint per block, and the caller blocks until all chunks
            // complete.
            unsafe {
                std::ptr::copy_nonoverlapping(buf.as_ptr(), out_ref.get().add(k * wu), wu);
            }
        }
    });
}

/// Transpose bit-plane data back to lane-per-u64 form (`n` lanes, low `w`
/// bits set, high bits zero). Inverse of [`lanes_to_planes`].
pub fn planes_to_lanes(planes: &[u64], w: u32, n: usize, lanes: &mut [u64], threads: usize) {
    debug_assert!(w >= 1 && w <= 64);
    note_transpose_op();
    let nblocks = blocks(n);
    let wu = w as usize;
    debug_assert_eq!(planes.len(), nblocks * wu);
    debug_assert_eq!(lanes.len(), n);
    let simd = super::kernels::auto_simd();
    let out = SendPtr(lanes.as_mut_ptr());
    let out_ref = &out;
    par_chunks(nblocks, eff_threads(nblocks, threads), move |_, range| {
        for k in range {
            let mut buf = [0u64; 64];
            buf[..wu].copy_from_slice(&planes[k * wu..(k + 1) * wu]);
            transpose64_dispatch(&mut buf, simd);
            let lo = k * LANES_PER_BLOCK;
            let r = (n - lo).min(LANES_PER_BLOCK);
            // SAFETY: block k writes only lanes [lo, lo + r), disjoint per
            // block; the caller blocks until all chunks complete.
            unsafe {
                std::ptr::copy_nonoverlapping(buf.as_ptr(), out_ref.get().add(lo), r);
            }
        }
    });
}

/// Fused transpose-pack: XOR the wire bytes of an `n`-lane plane-form
/// segment into `dst`, with the segment's lanes occupying global lane
/// indices `[lane0, lane0 + n)` of the (classic, lane-major) packed
/// stream. The result is byte-for-byte what [`bitpack::pack_bytes_into`]
/// would have produced for those lanes.
///
/// `dst` is the *whole* round's wire buffer and must be zeroed before the
/// first segment is packed; segments of one round are bit-disjoint, so
/// XOR-merging them is order-independent. When `lane0` is a multiple of 64
/// the segment's blocks land on word boundaries of the stream and the pack
/// parallelizes across blocks; other offsets (tail segments after a
/// non-multiple-of-64 segment) take a scalar bit-shift path.
pub fn pack_planes_xor_into(
    planes: &[u64],
    w: u32,
    n: usize,
    lane0: usize,
    dst: &mut [u8],
    threads: usize,
) {
    pack_planes_xor_into_with(planes, w, n, lane0, dst, threads, super::kernels::auto_simd());
}

/// [`pack_planes_xor_into`] with an explicit kernel-arm flag: the engine
/// passes its backend's resolved [`KernelBackend::simd`] flag here, so a
/// forced-scalar session is scalar through the wire boundary too
/// (DESIGN.md §11). Both arms produce identical bytes.
///
/// [`KernelBackend::simd`]: super::kernels::KernelBackend::simd
#[allow(clippy::too_many_arguments)]
pub fn pack_planes_xor_into_with(
    planes: &[u64],
    w: u32,
    n: usize,
    lane0: usize,
    dst: &mut [u8],
    threads: usize,
    simd: bool,
) {
    debug_assert!(w >= 1 && w <= 64);
    let nblocks = blocks(n);
    let wu = w as usize;
    debug_assert_eq!(planes.len(), nblocks * wu);
    debug_assert!(
        dst.len() as u64 >= bitpack::packed_bytes(lane0 + n, w),
        "wire buffer too short for segment at lane {lane0}"
    );
    if lane0 % LANES_PER_BLOCK == 0 {
        // Aligned: block k of the segment owns stream words
        // [word0 + k·w, word0 + (k+1)·w) — disjoint byte ranges.
        let word0 = lane0 * wu / 64;
        let nbytes = dst.len();
        let out = SendPtr(dst.as_mut_ptr());
        let out_ref = &out;
        par_chunks(nblocks, eff_threads(nblocks, threads), move |_, range| {
            for k in range {
                let mut buf = [0u64; 64];
                buf[..wu].copy_from_slice(&planes[k * wu..(k + 1) * wu]);
                transpose64_dispatch(&mut buf, simd);
                for t in 0..wu {
                    let word = packed_word(&buf, w, t);
                    if word == 0 {
                        continue; // zero tail bits: XOR would be a no-op
                    }
                    let lo = (word0 + k * wu + t) * 8;
                    // A nonzero word implies in-range bits (lo < nbytes) —
                    // but that rests on the zero-tail-lanes invariant, so
                    // fail safe rather than let a violated invariant turn
                    // into an out-of-bounds write.
                    let Some(rem) = nbytes.checked_sub(lo) else {
                        debug_assert!(false, "packed word past the wire end (dirty tail lanes?)");
                        continue;
                    };
                    let nb = rem.min(8);
                    let bytes = word.to_le_bytes();
                    // SAFETY: stream word (word0 + k·w + t) is unique per
                    // (k, t) in this call, so its byte range [lo, lo + nb)
                    // is written by exactly one chunk; lo + nb <= nbytes.
                    unsafe {
                        let p = out_ref.get().add(lo);
                        for (q, b) in bytes.iter().take(nb).enumerate() {
                            *p.add(q) ^= *b;
                        }
                    }
                }
            }
        });
    } else {
        // Unaligned: stage each packed word through a u128 shift and XOR
        // it in byte-wise. Adjacent blocks share boundary bytes, so this
        // path stays single-threaded (XOR keeps it order-independent).
        for k in 0..nblocks {
            let mut buf = [0u64; 64];
            buf[..wu].copy_from_slice(&planes[k * wu..(k + 1) * wu]);
            transpose64_dispatch(&mut buf, simd);
            for t in 0..wu {
                let word = packed_word(&buf, w, t);
                if word == 0 {
                    continue;
                }
                let bit = (lane0 + k * LANES_PER_BLOCK) as u64 * w as u64 + 64 * t as u64;
                let byte = (bit / 8) as usize;
                let sh = (bit % 8) as u32;
                let v = (word as u128) << sh;
                for q in 0..9 {
                    let idx = byte + q;
                    if idx >= dst.len() {
                        break; // only zero bits can spill past the stream
                    }
                    dst[idx] ^= (v >> (8 * q as u32)) as u8;
                }
            }
        }
    }
}

/// Fused unpack-and-fold, the receive side of [`pack_planes_xor_into`]:
/// extract the `n` lanes at global lane indices `[lane0, lane0 + n)` from
/// the wire bytes `src` and XOR their plane form into `out` (a plane
/// buffer of exactly this segment). Bit-exact with the classic
/// [`bitpack::unpack_bytes_xor_into`] followed by a transpose, for every
/// width, offset and thread count.
pub fn unpack_bytes_xor_into_planes(
    src: &[u8],
    w: u32,
    n: usize,
    lane0: usize,
    out: &mut [u64],
    threads: usize,
) {
    let simd = super::kernels::auto_simd();
    unpack_bytes_xor_into_planes_with(src, w, n, lane0, out, threads, simd);
}

/// [`unpack_bytes_xor_into_planes`] with an explicit kernel-arm flag (see
/// [`pack_planes_xor_into_with`]). Both arms fold identical plane words.
#[allow(clippy::too_many_arguments)]
pub fn unpack_bytes_xor_into_planes_with(
    src: &[u8],
    w: u32,
    n: usize,
    lane0: usize,
    out: &mut [u64],
    threads: usize,
    simd: bool,
) {
    debug_assert!(w >= 1 && w <= 64);
    let nblocks = blocks(n);
    let wu = w as usize;
    debug_assert_eq!(out.len(), nblocks * wu);
    debug_assert!(
        src.len() as u64 >= bitpack::packed_bytes(lane0 + n, w),
        "wire buffer too short for segment at lane {lane0}"
    );
    let mask = low_mask(w);
    let dst = SendPtr(out.as_mut_ptr());
    let dst_ref = &dst;
    par_chunks(nblocks, eff_threads(nblocks, threads), move |_, range| {
        for k in range {
            let mut buf = [0u64; 64];
            let lo = k * LANES_PER_BLOCK;
            let r = (n - lo).min(LANES_PER_BLOCK);
            for (i, b) in buf.iter_mut().take(r).enumerate() {
                *b = lane_from_words(|j| word_at(src, j), w, mask, lane0 + lo + i);
            }
            transpose64_dispatch(&mut buf, simd);
            // SAFETY: block k updates only its own plane words
            // [k·w, k·w+w), disjoint per block.
            unsafe {
                let p = dst_ref.get().add(k * wu);
                for (b, v) in buf.iter().take(wu).enumerate() {
                    *p.add(b) ^= *v;
                }
            }
        }
    });
}

/// Plane-form equivalent of the classic per-lane `(x << s) & low_mask(w)`:
/// plane `b` of the result is plane `b − s` of `src` (zero for `b < s`).
/// The mask is implicit — planes at or above `w` simply don't exist.
/// Splits across the worker pool above [`tuning::par_min_blocks`] blocks
/// (blocks are independent shifted copies).
pub fn plane_shl_into(src: &[u64], w: u32, s: u32, dst: &mut [u64], threads: usize) {
    debug_assert!(w >= 1 && w <= 64);
    let wu = w as usize;
    debug_assert_eq!(src.len() % wu, 0);
    debug_assert_eq!(dst.len(), src.len());
    let nblocks = src.len() / wu;
    let su = (s as usize).min(wu);
    let t = eff_threads(nblocks, threads);
    if t <= 1 {
        for (db, sb) in dst.chunks_exact_mut(wu).zip(src.chunks_exact(wu)) {
            db[..su].fill(0);
            db[su..].copy_from_slice(&sb[..wu - su]);
        }
        return;
    }
    let out = SendPtr(dst.as_mut_ptr());
    let out_ref = &out;
    par_chunks(nblocks, t, move |_, range| {
        for k in range {
            // SAFETY: block k writes only its own plane words
            // [k·w, (k+1)·w), disjoint per block; the caller blocks until
            // all chunks complete, and src/dst never alias (distinct
            // engine buffers).
            unsafe {
                let d = out_ref.get().add(k * wu);
                std::ptr::write_bytes(d, 0, su);
                std::ptr::copy_nonoverlapping(src.as_ptr().add(k * wu), d.add(su), wu - su);
            }
        }
    });
}

/// Extract the sign plane (plane `w − 1`) of an `n`-lane plane-form vector
/// into one-bit-per-u64 lane form — the DReLU driver's MSB read. Plane
/// `w−1` of block `k` already holds 64 lanes' sign bits in one word; this
/// just spreads them back to lanes for the (cheap, 1-bit) B2A step.
pub fn msb_lanes_from_planes(planes: &[u64], w: u32, n: usize, out: &mut [u64]) {
    debug_assert!(w >= 1 && w <= 64);
    let wu = w as usize;
    debug_assert_eq!(planes.len(), blocks(n) * wu);
    debug_assert_eq!(out.len(), n);
    for (k, chunk) in out.chunks_mut(LANES_PER_BLOCK).enumerate() {
        let sign = planes[k * wu + wu - 1];
        for (i, o) in chunk.iter_mut().enumerate() {
            *o = (sign >> i) & 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::prg::Prg;

    fn random_lanes(n: usize, w: u32, seed: u64) -> Vec<u64> {
        let mut prg = Prg::new(seed, w as u64);
        let mask = low_mask(w);
        (0..n).map(|_| prg.next_u64() & mask).collect()
    }

    /// transpose64 against a naive bit-by-bit transpose, plus involution.
    #[test]
    fn transpose_matches_naive_and_is_involution() {
        let mut prg = Prg::new(3, 0);
        let mut a = [0u64; 64];
        for v in a.iter_mut() {
            *v = prg.next_u64();
        }
        let orig = a;
        let mut naive = [0u64; 64];
        for (r, row) in naive.iter_mut().enumerate() {
            for p in 0..64 {
                *row |= ((orig[p] >> r) & 1) << p;
            }
        }
        transpose64(&mut a);
        assert_eq!(a, naive);
        transpose64(&mut a);
        assert_eq!(a, orig, "transpose must be an involution");
    }

    /// Round trip at every width, with odd lane counts (tail blocks) and
    /// several thread counts; also pins the implicit-masking behaviour.
    #[test]
    #[cfg_attr(miri, ignore = "64-width × lane-count × thread sweep is too slow interpreted")]
    fn lanes_planes_roundtrip_all_widths() {
        for w in 1..=64u32 {
            for n in [1usize, 3, 63, 64, 65, 127, 128, 200] {
                let src = random_lanes(n, w, 100 + w as u64);
                for threads in [1usize, 2, 4] {
                    let mut planes = vec![0u64; plane_len(n, w)];
                    lanes_to_planes(&src, w, &mut planes, threads);
                    let mut back = vec![0u64; n];
                    planes_to_lanes(&planes, w, n, &mut back, threads);
                    assert_eq!(src, back, "w={w} n={n} threads={threads}");
                }
            }
        }
        // High bits above w are discarded by the forward transpose — the
        // free masking the plane form provides.
        let dirty: Vec<u64> = (0..70u64).map(|i| i | (i << 40) | (1 << 63)).collect();
        let w = 6u32;
        let mut planes = vec![0u64; plane_len(dirty.len(), w)];
        lanes_to_planes(&dirty, w, &mut planes, 1);
        let mut back = vec![0u64; dirty.len()];
        planes_to_lanes(&planes, w, dirty.len(), &mut back, 1);
        let masked: Vec<u64> = dirty.iter().map(|v| v & low_mask(w)).collect();
        assert_eq!(back, masked);
    }

    /// Tail lanes of the final block are zero in every plane (the wire
    /// pack and plane-shift ops rely on this invariant).
    #[test]
    fn tail_lanes_are_zero() {
        let w = 5u32;
        let n = 70usize; // 2 blocks, 6 live lanes in the tail block
        let src = vec![low_mask(w); n];
        let mut planes = vec![0u64; plane_len(n, w)];
        lanes_to_planes(&src, w, &mut planes, 1);
        for b in 0..w as usize {
            let tail_plane = planes[w as usize + b];
            assert_eq!(tail_plane >> 6, 0, "plane {b} has nonzero tail lanes");
            assert_eq!(tail_plane & 0x3f, 0x3f);
        }
    }

    /// Single-segment fused pack is byte-identical to the classic packer,
    /// for every width, tail shape and thread count.
    #[test]
    #[cfg_attr(miri, ignore = "64-width sweep against the classic packer is too slow interpreted")]
    fn pack_matches_classic_bitpack() {
        for w in 1..=64u32 {
            for n in [1usize, 3, 63, 64, 65, 129, 333] {
                let src = random_lanes(n, w, 500 + w as u64);
                let classic = bitpack::pack_bytes(&src, w);
                let mut planes = vec![0u64; plane_len(n, w)];
                lanes_to_planes(&src, w, &mut planes, 1);
                for threads in [1usize, 2] {
                    let mut wire = vec![0u8; classic.len()];
                    pack_planes_xor_into(&planes, w, n, 0, &mut wire, threads);
                    assert_eq!(wire, classic, "w={w} n={n} threads={threads}");
                }
            }
        }
    }

    /// Segmented pack (the adder's `u = p ‖ p` shape): per-segment plane
    /// packs at lane offsets reproduce the classic pack of the
    /// concatenated lane vector — including non-multiple-of-64 segment
    /// sizes, which exercise the unaligned scalar path.
    #[test]
    #[cfg_attr(miri, ignore = "width × lane × segment sweep is too slow interpreted")]
    fn segmented_pack_matches_concatenated_classic_pack() {
        for w in [1u32, 5, 6, 8, 13, 31, 64] {
            for n in [1usize, 7, 64, 100, 130] {
                for segs in [1usize, 2, 4] {
                    let mut lanes_all = Vec::new();
                    let mut seg_planes = Vec::new();
                    for s in 0..segs {
                        let seg = random_lanes(n, w, 900 + w as u64 + s as u64);
                        let mut planes = vec![0u64; plane_len(n, w)];
                        lanes_to_planes(&seg, w, &mut planes, 1);
                        seg_planes.push(planes);
                        lanes_all.extend_from_slice(&seg);
                    }
                    let classic = bitpack::pack_bytes(&lanes_all, w);
                    let mut wire = vec![0u8; classic.len()];
                    for (s, planes) in seg_planes.iter().enumerate() {
                        pack_planes_xor_into(planes, w, n, s * n, &mut wire, 2);
                    }
                    assert_eq!(wire, classic, "w={w} n={n} segs={segs}");
                }
            }
        }
    }

    /// Unpack-fold into planes agrees with classic unpack + transpose, at
    /// segment offsets and across thread counts; folding twice cancels.
    #[test]
    #[cfg_attr(miri, ignore = "width × lane × segment sweep is too slow interpreted")]
    fn unpack_matches_classic_then_transpose() {
        for w in [1u32, 6, 12, 33, 64] {
            for n in [1usize, 65, 128, 130] {
                for segs in [1usize, 3] {
                    let lanes_all = random_lanes(segs * n, w, 40 + w as u64);
                    let wire = bitpack::pack_bytes(&lanes_all, w);
                    for s in 0..segs {
                        let seg_lanes = &lanes_all[s * n..(s + 1) * n];
                        let mut expect = vec![0u64; plane_len(n, w)];
                        lanes_to_planes(seg_lanes, w, &mut expect, 1);
                        for threads in [1usize, 2] {
                            let mut got = vec![0u64; plane_len(n, w)];
                            unpack_bytes_xor_into_planes(&wire, w, n, s * n, &mut got, threads);
                            assert_eq!(got, expect, "w={w} n={n} seg={s} threads={threads}");
                            unpack_bytes_xor_into_planes(&wire, w, n, s * n, &mut got, threads);
                            assert!(got.iter().all(|v| *v == 0), "double fold must cancel");
                        }
                    }
                }
            }
        }
    }

    /// plane_shl_into equals the classic per-lane `(x << s) & mask`.
    #[test]
    fn plane_shift_matches_lane_shift() {
        for w in [2u32, 6, 9, 64] {
            for s in [1u32, 2, 4, w - 1, w, w + 3] {
                let n = 97usize;
                let src = random_lanes(n, w, 7 + w as u64);
                let mut planes = vec![0u64; plane_len(n, w)];
                lanes_to_planes(&src, w, &mut planes, 1);
                let mut shifted = vec![0u64; planes.len()];
                plane_shl_into(&planes, w, s, &mut shifted, 1);
                let mut back = vec![0u64; n];
                planes_to_lanes(&shifted, w, n, &mut back, 1);
                let mask = low_mask(w);
                let expect: Vec<u64> = src
                    .iter()
                    .map(|v| if s >= 64 { 0 } else { (v << s) & mask })
                    .collect();
                assert_eq!(back, expect, "w={w} s={s}");
            }
        }
    }

    /// MSB plane extraction equals the classic per-lane sign-bit read.
    #[test]
    fn msb_extraction_matches_lane_read() {
        for w in [1u32, 6, 17] {
            let n = 131usize;
            let src = random_lanes(n, w, 60 + w as u64);
            let mut planes = vec![0u64; plane_len(n, w)];
            lanes_to_planes(&src, w, &mut planes, 1);
            let mut msb = vec![0u64; n];
            msb_lanes_from_planes(&planes, w, n, &mut msb);
            let expect: Vec<u64> = src.iter().map(|v| (v >> (w - 1)) & 1).collect();
            assert_eq!(msb, expect, "w={w}");
        }
    }

    /// Miri-sized replica of the lane↔plane round trip: a few widths and
    /// one tail shape, threaded, so the interpreter validates the
    /// `SendPtr` chunking in both transpose directions (DESIGN.md §8).
    /// The full-width sweep above covers the rest natively.
    #[test]
    fn lanes_planes_roundtrip_miri_sized() {
        for w in [1u32, 6, 64] {
            for n in [1usize, 65] {
                let src = random_lanes(n, w, 100 + w as u64);
                let mut planes = vec![0u64; plane_len(n, w)];
                lanes_to_planes(&src, w, &mut planes, 2);
                let mut back = vec![0u64; n];
                planes_to_lanes(&planes, w, n, &mut back, 2);
                assert_eq!(src, back, "w={w} n={n}");
            }
        }
    }

    /// Miri-sized replica of the fused wire boundary: pack from planes and
    /// unpack-fold back at one representative width/tail shape, checked
    /// byte-for-byte against the classic packer (DESIGN.md §8).
    #[test]
    fn fused_wire_roundtrip_miri_sized() {
        let (w, n) = (6u32, 65usize);
        let src = random_lanes(n, w, 77);
        let classic = bitpack::pack_bytes(&src, w);
        let mut planes = vec![0u64; plane_len(n, w)];
        lanes_to_planes(&src, w, &mut planes, 2);
        let mut wire = vec![0u8; classic.len()];
        pack_planes_xor_into(&planes, w, n, 0, &mut wire, 2);
        assert_eq!(wire, classic);
        let mut got = vec![0u64; plane_len(n, w)];
        unpack_bytes_xor_into_planes(&wire, w, n, 0, &mut got, 2);
        assert_eq!(got, planes);
        unpack_bytes_xor_into_planes(&wire, w, n, 0, &mut got, 2);
        assert!(got.iter().all(|v| *v == 0), "double fold must cancel");
    }

    /// The explicit-arm wire entry points are byte-identical across the
    /// scalar and (where available) AVX2 transposes, at aligned and
    /// unaligned segment offsets. Sized to also run under Miri, where the
    /// `simd=true` arm exercises the clean-refusal dispatch path
    /// (DESIGN.md §11).
    #[test]
    fn wire_with_kernel_arms_agree_miri_sized() {
        for (w, n, lane0) in [(6u32, 65usize, 0usize), (6, 65, 64), (13, 30, 7)] {
            let src = random_lanes(n, w, 31 + w as u64);
            let mut planes = vec![0u64; plane_len(n, w)];
            lanes_to_planes(&src, w, &mut planes, 1);
            let nbytes = bitpack::packed_bytes(lane0 + n, w) as usize;
            let mut wire_s = vec![0u8; nbytes];
            let mut wire_v = vec![0u8; nbytes];
            pack_planes_xor_into_with(&planes, w, n, lane0, &mut wire_s, 2, false);
            pack_planes_xor_into_with(&planes, w, n, lane0, &mut wire_v, 2, true);
            assert_eq!(wire_s, wire_v, "pack w={w} n={n} lane0={lane0}");
            let mut got_s = vec![0u64; planes.len()];
            let mut got_v = vec![0u64; planes.len()];
            unpack_bytes_xor_into_planes_with(&wire_s, w, n, lane0, &mut got_s, 2, false);
            unpack_bytes_xor_into_planes_with(&wire_s, w, n, lane0, &mut got_v, 2, true);
            assert_eq!(got_s, got_v, "unpack w={w} n={n} lane0={lane0}");
            assert_eq!(got_s, planes, "unpack must invert pack");
        }
    }
}
