//! Communication accounting (paper §2.3, Figs 3 & 11).
//!
//! Every protocol message is tagged with a [`Phase`] so the figure harness
//! can regenerate the paper's communication breakdowns exactly: bytes per
//! phase (Fig 3), total bytes and round counts per configuration (Fig 11),
//! and the analytic latency projection across network profiles (Fig 9).

use std::sync::Mutex;

/// Which part of the protocol a message belongs to. Matches the paper's
/// Fig 3 categories plus bookkeeping phases.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// AND gates of the Kogge–Stone prefix stages during A2B ("Circuit").
    Circuit,
    /// AND gates inside A2B not part of the prefix stages ("Others").
    OtherAnd,
    /// The final share × DReLU multiplication ("Mult").
    Mult,
    /// The 1-bit binary→arithmetic conversion ("B2A").
    B2A,
    /// Input/output share movement (client ↔ parties).
    Data,
    /// Session setup (seed exchange etc.).
    Setup,
}

pub const ALL_PHASES: [Phase; 6] =
    [Phase::Circuit, Phase::OtherAnd, Phase::Mult, Phase::B2A, Phase::Data, Phase::Setup];

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Circuit => "Circuit",
            Phase::OtherAnd => "Others",
            Phase::Mult => "Mult",
            Phase::B2A => "B2A",
            Phase::Data => "Data",
            Phase::Setup => "Setup",
        }
    }
    fn index(&self) -> usize {
        match self {
            Phase::Circuit => 0,
            Phase::OtherAnd => 1,
            Phase::Mult => 2,
            Phase::B2A => 3,
            Phase::Data => 4,
            Phase::Setup => 5,
        }
    }
}

/// One communication round: all parties exchange in parallel; `bytes_sent`
/// is the number of bytes *this party* sent in the round (symmetric
/// protocols send the same amount everywhere, which we assert in tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RoundRecord {
    pub phase: Phase,
    pub bytes_sent: u64,
}

/// Per-party communication trace. Collected by the transport; read by the
/// metrics/figure layer. Interior mutability so the transport can log from
/// `&self` while the protocol holds `&mut` elsewhere.
#[derive(Debug, Default)]
pub struct CommTrace {
    rounds: Mutex<Vec<RoundRecord>>,
    /// Wall time spent blocked inside `exchange_all_into` (and the
    /// `exchange_all` shim), in nanoseconds. On the in-process hub this is
    /// thread-sync overhead; on TCP it is real wire time. Used to split
    /// measured wall-clock into compute vs. wait.
    wait_nanos: std::sync::atomic::AtomicU64,
}

impl CommTrace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Lock the round log, recovering from poisoning (a panicked party
    /// thread must not take the shared trace down with it — the records
    /// themselves are append-only and stay consistent).
    fn lock_rounds(&self) -> std::sync::MutexGuard<'_, Vec<RoundRecord>> {
        self.rounds.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn record(&self, phase: Phase, bytes_sent: u64) {
        self.lock_rounds().push(RoundRecord { phase, bytes_sent });
    }

    /// Accumulate blocked-on-the-wire time.
    pub fn record_wait(&self, dur: std::time::Duration) {
        self.wait_nanos
            .fetch_add(dur.as_nanos() as u64, std::sync::atomic::Ordering::Relaxed);
    }

    /// Total time spent blocked in exchanges, in seconds.
    pub fn wait_seconds(&self) -> f64 {
        self.wait_nanos.load(std::sync::atomic::Ordering::Relaxed) as f64 * 1e-9
    }

    /// Snapshot of all rounds so far.
    pub fn rounds(&self) -> Vec<RoundRecord> {
        // HOT-PATH-ALLOW: reporting API — snapshots the trace by value.
        self.lock_rounds().clone()
    }

    /// Clear the trace (e.g. to exclude setup from a measurement window).
    pub fn reset(&self) {
        self.lock_rounds().clear();
        self.wait_nanos.store(0, std::sync::atomic::Ordering::Relaxed);
    }

    /// Aggregate: total bytes sent by this party.
    pub fn total_bytes(&self) -> u64 {
        self.lock_rounds().iter().map(|r| r.bytes_sent).sum()
    }

    /// Aggregate: number of rounds.
    pub fn total_rounds(&self) -> u64 {
        self.lock_rounds().len() as u64
    }

    /// Bytes grouped per phase, in `ALL_PHASES` order.
    pub fn bytes_by_phase(&self) -> [u64; 6] {
        let mut out = [0u64; 6];
        for r in self.lock_rounds().iter() {
            out[r.phase.index()] += r.bytes_sent;
        }
        out
    }

    /// Rounds grouped per phase, in `ALL_PHASES` order.
    pub fn rounds_by_phase(&self) -> [u64; 6] {
        let mut out = [0u64; 6];
        for r in self.lock_rounds().iter() {
            out[r.phase.index()] += 1;
        }
        out
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn aggregates() {
        let t = CommTrace::new();
        t.record(Phase::Circuit, 100);
        t.record(Phase::Circuit, 50);
        t.record(Phase::Mult, 8);
        assert_eq!(t.total_bytes(), 158);
        assert_eq!(t.total_rounds(), 3);
        let by = t.bytes_by_phase();
        assert_eq!(by[Phase::Circuit.index()], 150);
        assert_eq!(by[Phase::Mult.index()], 8);
        assert_eq!(t.rounds_by_phase()[Phase::Circuit.index()], 2);
        t.reset();
        assert_eq!(t.total_rounds(), 0);
    }

    #[test]
    fn phase_names_cover_fig3_categories() {
        let names: Vec<&str> = ALL_PHASES.iter().map(|p| p.name()).collect();
        for expect in ["Circuit", "Mult", "B2A", "Others"] {
            assert!(names.contains(&expect), "{expect} missing");
        }
    }
}
