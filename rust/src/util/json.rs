//! Minimal JSON parser / serializer.
//!
//! The build environment is fully offline and `serde` is not in the vendored
//! crate set, so the config system, artifact manifests, searched-plan files
//! and metrics dumps all go through this hand-rolled implementation. It
//! supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) plus two pragmatic extensions used by our config
//! files: `// line comments` and trailing commas.
//!
//! Numbers are kept as `f64` plus a lossless `i64` fast path (`Json::Int`),
//! because ring constants and element counts exceed f64's 2^53 integer range.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Integer that fits i64 exactly (no decimal point / exponent in input).
    Int(i64),
    /// Any other number.
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps key order deterministic for serialization + diffing.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ------------------------------------------------------------------
    // Accessors. All return Result with a descriptive path-free message;
    // callers add context where it matters.
    // ------------------------------------------------------------------

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(Error::config(format!("expected bool, got {}", other.kind()))),
        }
    }
    pub fn as_i64(&self) -> Result<i64> {
        match self {
            Json::Int(i) => Ok(*i),
            Json::Num(f) if f.fract() == 0.0 && f.abs() < 9.0e15 => Ok(*f as i64),
            other => Err(Error::config(format!("expected int, got {}", other.kind()))),
        }
    }
    pub fn as_usize(&self) -> Result<usize> {
        let v = self.as_i64()?;
        usize::try_from(v).map_err(|_| Error::config(format!("expected usize, got {v}")))
    }
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Int(i) => Ok(*i as f64),
            Json::Num(f) => Ok(*f),
            other => Err(Error::config(format!("expected number, got {}", other.kind()))),
        }
    }
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(Error::config(format!("expected string, got {}", other.kind()))),
        }
    }
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(Error::config(format!("expected array, got {}", other.kind()))),
        }
    }
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(Error::config(format!("expected object, got {}", other.kind()))),
        }
    }
    /// Field access with a helpful error message.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| Error::config(format!("missing field '{key}'")))
    }
    /// Optional field access.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }
    /// `get(key)` then `as_i64`, with the key named in the error.
    pub fn get_i64(&self, key: &str) -> Result<i64> {
        self.get(key)?.as_i64().map_err(|e| Error::config(format!("field '{key}': {e}")))
    }
    pub fn get_usize(&self, key: &str) -> Result<usize> {
        self.get(key)?.as_usize().map_err(|e| Error::config(format!("field '{key}': {e}")))
    }
    pub fn get_f64(&self, key: &str) -> Result<f64> {
        self.get(key)?.as_f64().map_err(|e| Error::config(format!("field '{key}': {e}")))
    }
    pub fn get_str(&self, key: &str) -> Result<&str> {
        self.get(key)?.as_str().map_err(|e| Error::config(format!("field '{key}': {e}")))
    }

    fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Int(_) | Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    // ------------------------------------------------------------------
    // Builders (used by metrics / manifest writers).
    // ------------------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ------------------------------------------------------------------
    // Serialization.
    // ------------------------------------------------------------------

    /// Compact single-line serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty-printed serialization with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(f) => {
                if f.is_finite() {
                    let _ = write!(out, "{f}");
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ----------------------------------------------------------------------
// Parser.
// ----------------------------------------------------------------------

/// Parse a JSON document (with `//` comments and trailing commas allowed).
pub fn parse(input: &str) -> Result<Json> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing garbage after document"));
    }
    Ok(v)
}

/// Parse a JSON file from disk.
pub fn parse_file(path: impl AsRef<std::path::Path>) -> Result<Json> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::config(format!("reading {}: {e}", path.display())))?;
    parse(&text).map_err(|e| Error::config(format!("parsing {}: {e}", path.display())))
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> Error {
        Error::Json { offset: self.i, msg: msg.into() }
    }

    fn skip_ws(&mut self) {
        loop {
            while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
                self.i += 1;
            }
            // `//` line comment extension
            if self.i + 1 < self.b.len() && self.b[self.i] == b'/' && self.b[self.i + 1] == b'/' {
                while self.i < self.b.len() && self.b[self.i] != b'\n' {
                    self.i += 1;
                }
                continue;
            }
            break;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect_byte(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek().ok_or_else(|| self.err("unexpected end of input"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(format!("unexpected character '{}'", c as char))),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("invalid literal, expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect_byte(b'{')?;
        let mut map = BTreeMap::new();
        loop {
            self.skip_ws();
            if self.peek() == Some(b'}') {
                self.i += 1;
                return Ok(Json::Obj(map));
            }
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {}
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect_byte(b'[')?;
        let mut items = Vec::new();
        loop {
            self.skip_ws();
            if self.peek() == Some(b']') {
                self.i += 1;
                return Ok(Json::Arr(items));
            }
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {}
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect_byte(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("unterminated escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Handle surrogate pairs.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| self.err("invalid \\u escape"))?);
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control character in string")),
                c => {
                    // Re-assemble UTF-8 multibyte sequences byte-wise.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("invalid hex"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("invalid hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        // LINT-ALLOW: unwrap — the scanner above only advanced over ASCII
        // digit/sign/exponent bytes, which are always valid UTF-8.
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(format!("invalid number '{text}'")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Int(42));
        assert_eq!(parse("-7").unwrap(), Json::Int(-7));
        assert_eq!(parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_large_i64_losslessly() {
        let v = parse("9223372036854775807").unwrap();
        assert_eq!(v, Json::Int(i64::MAX));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\Aé");
        // surrogate pair: U+1F600
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
        // raw multibyte utf-8 passthrough
        let v = parse("\"héllo\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo");
    }

    #[test]
    fn comments_and_trailing_commas() {
        let v = parse("// header\n{\"a\": 1, // inline\n \"b\": [1,2,],}").unwrap();
        assert_eq!(v.get_i64("a").unwrap(), 1);
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]x").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":true,"d":null},"e":-9223372036854775808}"#;
        let v = parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(parse(&out).unwrap(), v);
        // pretty form also roundtrips
        let pretty = v.to_string_pretty();
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn accessor_errors_name_field() {
        let v = parse(r#"{"a":1}"#).unwrap();
        let e = v.get_str("a").unwrap_err().to_string();
        assert!(e.contains("'a'"), "{e}");
        let e = v.get("zz").unwrap_err().to_string();
        assert!(e.contains("zz"), "{e}");
    }
}
