//! Coordinator integration: the batching service answers requests
//! correctly, batches them, accounts communication, and shuts down
//! cleanly. Requires artifacts + micronet weights (skips otherwise).

use hummingbird::coordinator::{Coordinator, ServeOptions};
use hummingbird::gmw::kernels::BinLayout;
use hummingbird::hummingbird::PlanSet;
use hummingbird::model::{Archive, Backend, Dataset, ModelConfig, PlainExecutor};

const MODEL: &str = "micronet_synth10";

fn ready() -> Option<std::path::PathBuf> {
    let repo = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).to_path_buf();
    if repo.join("artifacts/manifest.json").exists()
        && repo.join(format!("artifacts/weights/{MODEL}.json")).exists()
    {
        Some(repo)
    } else {
        eprintln!("skipping: artifacts/weights missing");
        None
    }
}

#[test]
fn serve_batches_and_matches_plaintext() {
    let Some(repo) = ready() else { return };
    let cfg = ModelConfig::load_named(&repo, MODEL).unwrap();
    let dataset = Dataset::load(repo.join("artifacts"), &cfg.dataset).unwrap();
    let weights = Archive::load(repo.join("artifacts/weights").join(MODEL)).unwrap();
    let plain = PlainExecutor::new(cfg.clone(), weights, Backend::Naive);

    let mut opts = ServeOptions::new(&repo, MODEL);
    opts.plan = Some(PlanSet::baseline(cfg.relu_groups));
    opts.batch_timeout = std::time::Duration::from_millis(10);
    let svc = Coordinator::start(opts).unwrap();

    // Submit an uneven number of requests (forces a padded tail batch).
    let n = 10usize;
    let mut rxs = Vec::new();
    for i in 0..n {
        rxs.push((i, svc.infer_async(dataset.test.batch(i, i + 1).to_vec()).unwrap()));
    }
    let mut batch_sizes = Vec::new();
    for (i, rx) in rxs {
        let r = rx.recv().unwrap().unwrap();
        let want = plain.forward(dataset.test.batch(i, i + 1), 1).unwrap();
        let want_pred = PlainExecutor::argmax(&want, cfg.num_classes)[0];
        assert_eq!(r.pred, want_pred, "sample {i} prediction mismatch vs plaintext");
        assert_eq!(r.logits.len(), cfg.num_classes);
        assert!(r.latency_s > 0.0);
        batch_sizes.push(r.batch_size);
    }
    // Requests submitted together must have been batched (micronet batch=4).
    assert!(batch_sizes.iter().any(|b| *b > 1), "no batching occurred: {batch_sizes:?}");
    assert!(svc.metrics.samples_done() >= n as u64);
    assert!(svc.trace.total_bytes() > 0);
    let bd = svc.metrics.breakdown();
    assert!(bd.relu_s > 0.0 && bd.linear_s > 0.0);
    svc.shutdown();
}

/// The `--layout bitsliced` service produces the same predictions and the
/// same protocol bytes as the default lane layout (end-to-end through the
/// batcher, executor and GMW engine).
#[test]
fn serve_bitsliced_layout_matches_lane_layout() {
    let Some(repo) = ready() else { return };
    let cfg = ModelConfig::load_named(&repo, MODEL).unwrap();
    let dataset = Dataset::load(repo.join("artifacts"), &cfg.dataset).unwrap();

    let run = |layout: BinLayout| {
        let mut opts = ServeOptions::new(&repo, MODEL);
        opts.plan = Some(PlanSet::uniform(cfg.relu_groups, 14, 6).unwrap());
        opts.layout = layout;
        let svc = Coordinator::start(opts).unwrap();
        let mut rxs = Vec::new();
        for i in 0..4 {
            rxs.push(svc.infer_async(dataset.test.batch(i, i + 1).to_vec()).unwrap());
        }
        let preds: Vec<usize> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap().pred).collect();
        let by = svc.trace.bytes_by_phase();
        let protocol: u64 = by[..4].iter().sum();
        svc.shutdown();
        (preds, protocol)
    };
    let (lane_preds, lane_bytes) = run(BinLayout::LanePerU64);
    let (sliced_preds, sliced_bytes) = run(BinLayout::Bitsliced);
    assert_eq!(lane_preds, sliced_preds, "layout changed predictions");
    assert_eq!(lane_bytes, sliced_bytes, "layout changed protocol bytes");
}

/// `--prefetch on` serving (background offline-phase provisioning, warmed
/// before the party threads admit work) produces the same predictions and
/// the same protocol bytes as the synchronous dealer, end to end through
/// the batcher and executor.
#[test]
fn serve_prefetch_matches_sync_dealer() {
    let Some(repo) = ready() else { return };
    let cfg = ModelConfig::load_named(&repo, MODEL).unwrap();
    let dataset = Dataset::load(repo.join("artifacts"), &cfg.dataset).unwrap();

    let run = |prefetch: bool| {
        let mut opts = ServeOptions::new(&repo, MODEL);
        opts.plan = Some(PlanSet::uniform(cfg.relu_groups, 14, 6).unwrap());
        opts.prefetch = prefetch;
        let svc = Coordinator::start(opts).unwrap();
        let mut rxs = Vec::new();
        for i in 0..6 {
            rxs.push(svc.infer_async(dataset.test.batch(i, i + 1).to_vec()).unwrap());
        }
        let preds: Vec<usize> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap().pred).collect();
        let by = svc.trace.bytes_by_phase();
        let protocol: u64 = by[..4].iter().sum();
        svc.shutdown();
        (preds, protocol)
    };
    let (sync_preds, sync_bytes) = run(false);
    let (pf_preds, pf_bytes) = run(true);
    assert_eq!(sync_preds, pf_preds, "prefetch changed predictions");
    assert_eq!(sync_bytes, pf_bytes, "prefetch changed protocol bytes");
}

/// The XLA kernel backend is lane-per-u64 only; asking for the bitsliced
/// layout on it must fail fast at boot (config error, before any artifact
/// loading — so this runs without the artifacts directory).
#[test]
fn xla_backend_rejects_bitsliced_layout() {
    let repo = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).to_path_buf();
    let mut opts = ServeOptions::new(&repo, MODEL);
    opts.gmw_backend = "xla".into();
    opts.layout = BinLayout::Bitsliced;
    match Coordinator::start(opts) {
        Ok(_) => panic!("xla + bitsliced must be rejected at boot"),
        Err(e) => {
            let msg = e.to_string();
            assert!(msg.contains("layout"), "unexpected error: {msg}");
        }
    }
}

#[test]
fn serve_with_hummingbird_plan_reduces_bytes() {
    let Some(repo) = ready() else { return };
    let cfg = ModelConfig::load_named(&repo, MODEL).unwrap();
    let dataset = Dataset::load(repo.join("artifacts"), &cfg.dataset).unwrap();

    let run = |plan: PlanSet| {
        let mut opts = ServeOptions::new(&repo, MODEL);
        opts.plan = Some(plan);
        let svc = Coordinator::start(opts).unwrap();
        let mut rxs = Vec::new();
        for i in 0..4 {
            rxs.push(svc.infer_async(dataset.test.batch(i, i + 1).to_vec()).unwrap());
        }
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let by = svc.trace.bytes_by_phase();
        let protocol: u64 = by[..4].iter().sum();
        svc.shutdown();
        protocol
    };
    let base = run(PlanSet::baseline(cfg.relu_groups));
    let hb = run(PlanSet::uniform(cfg.relu_groups, 14, 6).unwrap());
    assert!(
        base as f64 / hb as f64 > 2.5,
        "expected >2.5x byte cut through the service: {base} -> {hb}"
    );
}
