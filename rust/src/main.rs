//! `hummingbird` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   infer    — run private inference over test samples, report accuracy,
//!              latency, and communication (single-process simulation).
//!   serve    — boot the batching service and drive it with a synthetic
//!              open-loop client; report throughput (Fig 1 mode).
//!   search   — run the offline search engine (eco or --budget) and write
//!              the plan JSON to configs/searched/.
//!   figures  — regenerate every paper table/figure (see EXPERIMENTS.md).
//!   party    — run one party of a multi-process TCP deployment.
//!   selftest — quick protocol sanity check.
//!
//! Examples:
//!   hummingbird search --model miniresnet_synth10 --budget 8/64
//!   hummingbird infer --model miniresnet_synth10 \
//!       --plan configs/searched/miniresnet_synth10_b8-64.json --samples 64
//!   hummingbird infer --model miniresnet_synth10 --layout bitsliced
//!   hummingbird figures --fig 11
//!
//! GMW engine knobs shared by infer/serve/party: `--threads N` (lane
//! parallelism, 0 = all cores), `--layout lane|bitsliced` (binary-share
//! layout; bitsliced runs 64 lanes per word through DReLU),
//! `--kernel scalar|simd|auto` (plane-kernel dispatch, DESIGN.md §11:
//! `auto` takes the AVX2 arm when the CPU has it, `simd` errors out if it
//! does not, `scalar` pins the portable reference; `HB_KERNEL` overrides
//! all of them) and `--prefetch on|off` (offline/online split: provision
//! Beaver triples on a background thread instead of expanding them inside
//! the online AND rounds). All are bit-exact: they change wall-clock,
//! never results or wire bytes.
//!
//! Session-layer knobs (DESIGN.md §7): `--connect-timeout-ms`,
//! `--handshake-timeout-ms`, `--round-timeout-ms`, `--max-frame-len`,
//! `--retries`, `--backoff-ms` bound every blocking network step, and
//! `--fault-profile` (serve/party) injects deterministic faults for chaos
//! testing, e.g. `--fault-profile drop@3,seed:7` or `crash@5,party:1`.
//!
//! Serving-lifecycle knobs (infer/serve, DESIGN.md §9): `--queue-depth`
//! bounds admission (a full queue answers `Overloaded`),
//! `--request-timeout-ms` stamps each request with a deadline (expired
//! queued requests are shed), `--max-restarts` budgets the crash-loop
//! breaker, and `--drain-timeout-ms` (serve) bounds the graceful drain at
//! shutdown.
//!
//! WAN-scheduling knobs (infer/serve, DESIGN.md §10): `--net-profile
//! high-bw|lan|wan|lat:<ms>,bw:<mbps>` runs every party transport behind
//! a simulated WAN link — each protocol round really waits out its
//! modeled `latency + bytes/bandwidth` wire time — and `--overlap on|off`
//! keeps two batches in flight so batch k+1's dispatch overlaps batch
//! k's latency-bound rounds. Both are bit-exact: results and wire bytes
//! never change, only timing.

use anyhow::{bail, Context, Result};

use hummingbird::coordinator::ServeOptions;
use hummingbird::figures;
use hummingbird::gmw::kernels::{BinLayout, KernelChoice};
use hummingbird::hummingbird::search::{SearchConfig, SearchEngine, Strategy};
use hummingbird::hummingbird::{simulator, PlanSet};
use hummingbird::model::{Archive, Backend, Dataset, ModelConfig, PlainExecutor, WhichPlain};
use hummingbird::net::fault::FaultProfile;
use hummingbird::net::profile::{ComputeProfile, NetworkProfile};
use hummingbird::net::NetConfig;
use hummingbird::runtime::{Manifest, Runtime};
use hummingbird::util::cli::Args;
use hummingbird::util::stats;

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn repo_root(args: &Args) -> std::path::PathBuf {
    std::path::PathBuf::from(args.opt_or("root", env!("CARGO_MANIFEST_DIR")))
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand() {
        Some("infer") => cmd_infer(args),
        Some("serve") => cmd_serve(args),
        Some("search") => cmd_search(args),
        Some("figures") => figures::cmd_figures(args).map_err(Into::into),
        Some("party") => cmd_party(args),
        Some("selftest") => cmd_selftest(args),
        _ => {
            eprintln!(
                "usage: hummingbird <infer|serve|search|figures|party|selftest> [--options]\n\
                 see README.md for details"
            );
            bail!("missing or unknown subcommand")
        }
    }
}

/// `--fault-profile drop@3,seed:7` etc. (see `net::fault` for the
/// grammar). `None` when the flag is absent — the production default.
fn load_fault_profile(args: &Args) -> Result<Option<FaultProfile>> {
    match args.opt("fault-profile") {
        None => Ok(None),
        Some(s) => {
            Ok(Some(s.parse::<FaultProfile>().map_err(|e| anyhow::anyhow!("{e}"))?))
        }
    }
}

/// Serving-lifecycle knobs shared by infer/serve (DESIGN.md §9):
/// `--queue-depth` (bounded admission), `--request-timeout-ms` (0 = no
/// per-request deadline) and `--max-restarts` (crash-loop budget).
fn apply_lifecycle_knobs(args: &Args, opts: &mut ServeOptions, default_queue: usize) -> Result<()> {
    opts.queue_depth = args.opt_parse("queue-depth", default_queue)?;
    let ms: u64 = args.opt_parse("request-timeout-ms", 0u64)?;
    if ms > 0 {
        opts.request_timeout = Some(std::time::Duration::from_millis(ms));
    }
    opts.max_restarts = args.opt_parse("max-restarts", opts.max_restarts)?;
    Ok(())
}

/// WAN-scheduling knobs shared by infer/serve (DESIGN.md §10):
/// `--net-profile` wraps every party transport in a simulated link
/// ([`NetworkProfile::parse_cli`] grammar) and `--overlap on|off`
/// pipelines batch k+1's dispatch under batch k's protocol rounds.
fn apply_wan_knobs(args: &Args, opts: &mut ServeOptions) -> Result<()> {
    if let Some(spec) = args.opt("net-profile") {
        opts.net_profile =
            Some(NetworkProfile::parse_cli(spec).map_err(|e| anyhow::anyhow!("{e}"))?);
    }
    opts.overlap = args.on_off("overlap", false)?;
    Ok(())
}

fn load_plan(args: &Args, cfg: &ModelConfig) -> Result<PlanSet> {
    match args.opt("plan") {
        None | Some("baseline") => Ok(PlanSet::baseline(cfg.relu_groups)),
        Some(path) => Ok(PlanSet::load(path).context("loading plan")?),
    }
}

// ---------------------------------------------------------------------
// infer
// ---------------------------------------------------------------------

fn cmd_infer(args: &Args) -> Result<()> {
    use hummingbird::coordinator::Coordinator;
    let root = repo_root(args);
    let model = args.req("model")?;
    let cfg = ModelConfig::load_named(&root, model)?;
    let plan = load_plan(args, &cfg)?;
    let samples: usize = args.opt_parse("samples", 32)?;
    let backend = args.opt_or("gmw-backend", "rust").to_string();

    let dataset = Dataset::load(root.join("artifacts"), &cfg.dataset)?;
    let mut opts = ServeOptions::new(&root, model);
    opts.plan = Some(plan.clone());
    opts.parties = args.opt_parse("parties", 2)?;
    opts.gmw_backend = backend;
    // --threads: lane parallelism per party (0 = auto-split the cores).
    opts.threads = args.opt_parse("threads", 0)?;
    // --layout: binary-share layout (lane-per-u64 or bitsliced).
    opts.layout = args.opt_parse("layout", BinLayout::default())?;
    // --kernel: plane-kernel dispatch arm (DESIGN.md §11).
    opts.kernel = args.opt_parse("kernel", KernelChoice::default())?;
    // --prefetch: offline-phase background triple provisioning.
    opts.prefetch = args.on_off("prefetch", false)?;
    // Session deadlines (bound every blocking network step, DESIGN.md §7).
    opts.net = NetConfig::from_args(args)?;
    // The infer driver submits every sample asynchronously up front, so
    // default the bounded queue (DESIGN.md §9) to hold them all.
    apply_lifecycle_knobs(args, &mut opts, samples.max(256))?;
    // --net-profile / --overlap: simulated WAN + pipelined dispatch (§10).
    apply_wan_knobs(args, &mut opts)?;
    println!(
        "booting {} ({} parties, plan: {}, layout: {}, kernel: {} (simd: {}), prefetch: {})",
        model,
        opts.parties,
        plan.summary(),
        opts.layout,
        opts.kernel.effective().label(),
        if opts.kernel.resolve_simd() { "on" } else { "off" },
        if opts.prefetch { "on" } else { "off" }
    );
    let svc = Coordinator::start(opts)?;

    let n = samples.min(dataset.test.n);
    let t0 = std::time::Instant::now();
    let mut correct = 0usize;
    let mut latencies = Vec::new();
    // Submit all requests, then collect (lets the batcher fill batches).
    let mut rxs = Vec::new();
    for i in 0..n {
        let x = dataset.test.batch(i, i + 1).to_vec();
        rxs.push((i, svc.infer_async(x)?));
    }
    for (i, rx) in rxs {
        let r = rx.recv()??;
        if r.pred == dataset.test.labels[i] as usize {
            correct += 1;
        }
        latencies.push(r.latency_s);
    }
    let wall = t0.elapsed().as_secs_f64();
    let trace = &svc.trace;
    println!("samples:        {n}");
    println!("accuracy:       {:.2}%", 100.0 * correct as f64 / n as f64);
    println!("wall time:      {} ({:.1} samples/s)", stats::fmt_secs(wall), n as f64 / wall);
    println!("p50 latency:    {}", stats::fmt_secs(stats::median(&latencies)));
    println!("comm bytes:     {} (party0 sent)", stats::fmt_bytes(trace.total_bytes()));
    println!("comm rounds:    {}", trace.total_rounds());
    let by = trace.bytes_by_phase();
    println!(
        "  circuit {} / others {} / mult {} / b2a {} / data {}",
        stats::fmt_bytes(by[0]),
        stats::fmt_bytes(by[1]),
        stats::fmt_bytes(by[2]),
        stats::fmt_bytes(by[3]),
        stats::fmt_bytes(by[4])
    );
    // Projection onto the paper's network profiles.
    let bd = svc.metrics.breakdown();
    for net in [NetworkProfile::high_bw(), NetworkProfile::lan(), NetworkProfile::wan()] {
        let p =
            hummingbird::net::profile::project(trace, bd.total(), &net, &ComputeProfile::a100());
        println!(
            "  projected {:8}: {:10} ({} comm + {} compute)",
            p.network,
            stats::fmt_secs(p.total_s()),
            stats::fmt_secs(p.comm_time_s),
            stats::fmt_secs(p.compute_time_s)
        );
    }
    svc.shutdown();
    Ok(())
}

// ---------------------------------------------------------------------
// serve
// ---------------------------------------------------------------------

fn cmd_serve(args: &Args) -> Result<()> {
    use hummingbird::coordinator::Coordinator;
    let root = repo_root(args);
    let model = args.req("model")?;
    let cfg = ModelConfig::load_named(&root, model)?;
    let plan = load_plan(args, &cfg)?;
    let duration: f64 = args.opt_parse("seconds", 20.0)?;
    let dataset = Dataset::load(root.join("artifacts"), &cfg.dataset)?;

    let mut opts = ServeOptions::new(&root, model);
    opts.plan = Some(plan.clone());
    opts.gmw_backend = args.opt_or("gmw-backend", "rust").to_string();
    opts.threads = args.opt_parse("threads", 0)?;
    opts.layout = args.opt_parse("layout", BinLayout::default())?;
    opts.kernel = args.opt_parse("kernel", KernelChoice::default())?;
    opts.prefetch = args.on_off("prefetch", false)?;
    opts.net = NetConfig::from_args(args)?;
    // --fault-profile: deterministic chaos testing — the injected fault
    // fails its batch, the coordinator respawns the session and keeps
    // serving (watch failed_jobs/sessions_restarted in the metrics line).
    opts.fault_profile = load_fault_profile(args)?;
    // Overload / lifecycle knobs (DESIGN.md §9).
    apply_lifecycle_knobs(args, &mut opts, 256)?;
    // --net-profile / --overlap: simulated WAN + pipelined dispatch (§10).
    apply_wan_knobs(args, &mut opts)?;
    let drain_ms: u64 = args.opt_parse("drain-timeout-ms", 30_000u64)?;
    let prefetch = if opts.prefetch { "on" } else { "off" };
    let svc = Coordinator::start(opts)?;
    println!(
        "serving {model} (plan: {}, prefetch: {prefetch}), open-loop for {duration}s",
        plan.summary()
    );

    let t0 = std::time::Instant::now();
    let mut sent = 0usize;
    let mut rxs = std::collections::VecDeque::new();
    let mut correct = 0usize;
    let mut done = 0usize;
    let mut failed = 0usize;
    // A faulted party session answers its jobs with errors while the
    // coordinator respawns and keeps serving — so the client loop counts
    // failures instead of aborting on the first one (DESIGN.md §7).
    let mut settle =
        |i: usize, r: hummingbird::error::Result<hummingbird::coordinator::InferenceResult>| {
            match r {
                Ok(r) => {
                    done += 1;
                    correct += (r.pred == dataset.test.labels[i] as usize) as usize;
                }
                Err(e) => {
                    failed += 1;
                    eprintln!("request failed: {e}");
                }
            }
        };
    let mut shed = 0usize;
    while t0.elapsed().as_secs_f64() < duration {
        let i = sent % dataset.test.n;
        // Bounded admission (DESIGN.md §9): an overloaded (or degraded)
        // coordinator sheds the submission — the open-loop client counts
        // it and keeps the load coming rather than aborting.
        match svc.infer_async(dataset.test.batch(i, i + 1).to_vec()) {
            Ok(rx) => rxs.push_back((i, rx)),
            Err(e) if e.client_should_retry() => shed += 1,
            Err(e) => return Err(e.into()),
        }
        sent += 1;
        // Keep a bounded number in flight.
        while rxs.len() >= 64 {
            let Some((i, rx)) = rxs.pop_front() else { break };
            settle(i, rx.recv()?);
        }
    }
    for (i, rx) in rxs {
        settle(i, rx.recv()?);
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served {done} samples ({failed} failed, {shed} shed at admission) in {wall:.1}s \
         = {:.2} samples/s",
        done as f64 / wall
    );
    println!("accuracy {:.2}%", 100.0 * correct as f64 / done.max(1) as f64);
    println!("metrics: {}", svc.metrics.to_json().to_string());
    // Graceful drain (DESIGN.md §9): stop admission, serve what is
    // queued until --drain-timeout-ms, then force-stop.
    let snap = svc.shutdown_with_deadline(std::time::Duration::from_millis(drain_ms));
    println!(
        "final state: {} (admitted {}, completed {}, drained {}, live party threads {})",
        snap.state,
        snap.admission.admitted,
        snap.admission.completed,
        snap.admission.drained,
        snap.live_party_threads
    );
    Ok(())
}

// ---------------------------------------------------------------------
// search
// ---------------------------------------------------------------------

fn cmd_search(args: &Args) -> Result<()> {
    let root = repo_root(args);
    let model = args.req("model")?;
    let cfg = ModelConfig::load_named(&root, model)?;
    let weights = Archive::load(root.join("artifacts/weights").join(model))?;
    let dataset = Dataset::load(root.join("artifacts"), &cfg.dataset)?;

    let mut scfg = SearchConfig::default();
    scfg.val_samples = args.opt_parse("val-samples", 256)?;
    scfg.seed = args.opt_parse("seed", 0xbeefu64)?;
    scfg.max_acc_drop = args.opt_parse("max-drop", scfg.max_acc_drop)?;
    scfg.max_evals = args.opt_parse("max-evals", scfg.max_evals)?;
    let strategy = match args.opt("budget") {
        None => {
            scfg.strategy = Strategy::Eco;
            "eco".to_string()
        }
        Some(b) => {
            let frac = parse_budget(b)?;
            scfg.strategy = Strategy::Budget(frac);
            format!("b{}", b.replace('/', "-"))
        }
    };

    // Plain executor on the fast XLA search artifacts (naive fallback).
    let manifest = Manifest::load(root.join("artifacts"))?;
    let model_art = manifest.model(model)?.clone();
    let backend = if args.flag("naive") {
        Backend::Naive
    } else {
        Backend::Xla {
            rt: Runtime::new(root.join("artifacts"))?,
            artifact_batch: model_art.search_batch,
            artifacts: model_art,
            which: WhichPlain::Search,
        }
    };
    let exec = PlainExecutor::new(cfg.clone(), weights, backend);
    let n = scfg.val_samples.min(dataset.val.n);
    let engine = SearchEngine::new(
        &exec,
        &dataset.val.images,
        &dataset.val.labels[..n],
        dataset.val.sample_elems,
        scfg,
    );
    println!("searching {model} ({strategy}) on {n} validation samples...");
    let result = engine.run()?;
    println!("baseline acc:   {:.2}%", result.baseline_acc * 100.0);
    println!("searched acc:   {:.2}%", result.final_acc * 100.0);
    println!("plan:           {}", result.plans.summary());
    println!("budget used:    {:.4} of baseline bits", result.budget_fraction);
    println!("evals:          {}", result.evals);
    println!("search time:    {}", stats::fmt_secs(result.search_time_s));

    let mut plans = result.plans.clone();
    plans.meta.insert("model".into(), model.to_string());
    plans.meta.insert("baseline_acc".into(), format!("{:.4}", result.baseline_acc));
    plans.meta.insert("final_acc".into(), format!("{:.4}", result.final_acc));
    plans.meta.insert("search_time_s".into(), format!("{:.2}", result.search_time_s));
    plans.meta.insert("evals".into(), format!("{}", result.evals));
    let out = args
        .opt("out")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| root.join("configs/searched").join(format!("{model}_{strategy}.json")));
    plans.save(&out)?;
    println!("plan written to {}", out.display());

    // Final verification on the test split.
    let test_acc = simulator::evaluate_plans(
        &exec,
        &dataset.test.images,
        &dataset.test.labels,
        dataset.test.sample_elems,
        64,
        &plans,
        1,
    )?;
    println!("test acc under plan: {:.2}%", test_acc * 100.0);
    Ok(())
}

fn parse_budget(s: &str) -> Result<f64> {
    if let Some((a, b)) = s.split_once('/') {
        let a: f64 = a.parse()?;
        let b: f64 = b.parse()?;
        if a <= 0.0 || b <= 0.0 {
            bail!("budget must be positive");
        }
        Ok(a / b)
    } else {
        Ok(s.parse()?)
    }
}

// ---------------------------------------------------------------------
// party (multi-process TCP mode)
// ---------------------------------------------------------------------

fn cmd_party(args: &Args) -> Result<()> {
    use hummingbird::beaver::schedule::TripleSchedule;
    use hummingbird::gmw::kernels::{BitslicedKernels, KernelBackend, RustKernels};
    use hummingbird::gmw::{GmwParty, ReluPlan};
    use hummingbird::net::fault::FaultyTransport;
    use hummingbird::net::tcp::TcpTransport;
    use hummingbird::net::Transport;
    let rank: usize = args.opt_parse("rank", 0)?;
    let addrs: Vec<String> =
        args.req("addrs")?.split(',').map(|s| s.trim().to_string()).collect();
    let n: usize = args.opt_parse("elems", 4096)?;
    let k: u32 = args.opt_parse("k", 64)?;
    let m: u32 = args.opt_parse("m", 0)?;
    let layout: BinLayout = args.opt_parse("layout", BinLayout::default())?;
    // --kernel: plane-kernel dispatch arm (DESIGN.md §11). `simd` fails
    // fast here — before the dial — if this host has no AVX2.
    let kernel: KernelChoice = args.opt_parse("kernel", KernelChoice::default())?;
    kernel.require().map_err(anyhow::Error::from)?;
    let seed: u64 = args.opt_parse("seed", 7u64)?;
    // Session deadlines + retry budget (DESIGN.md §7): every dial,
    // handshake and round below is bounded, and retryable link faults
    // trigger the reconnect-and-resend path instead of an error. The
    // shared --seed doubles as the session id the resync handshake pins.
    let net = NetConfig::from_args(args)?;
    let fault = load_fault_profile(args)?;
    println!("party {rank}/{} connecting...", addrs.len());
    let transport = TcpTransport::connect_with(rank, &addrs, seed, net)?;
    // Real deployments own the whole machine: default --threads to all cores.
    let threads = args.threads(0)?;
    // --prefetch on: provision this ReLU's triples on a background thread
    // before/while the online protocol runs (a per-party decision — peers
    // may stay synchronous; results and wire bytes are identical).
    let prefetch = args.on_off("prefetch", false)?;
    // Each party holds a random share vector; run ReLU over TCP. All
    // parties must pass the same --layout (it is bit-exact, but the lane
    // budget differs); the wire bytes are identical either way.
    let plan = ReluPlan::new(k, m).map_err(anyhow::Error::from)?;
    fn run_relu<T: Transport, K: KernelBackend>(
        mut party: GmwParty<T, K>,
        shares: &[u64],
        plan: ReluPlan,
        threads: usize,
        prefetch: bool,
        label: &str,
    ) -> Result<()> {
        party.set_threads(threads);
        if prefetch {
            let schedule = TripleSchedule::for_relu(shares.len(), plan, party.parties());
            party.enable_prefetch(schedule, false);
        }
        let t0 = std::time::Instant::now();
        let _out = party.relu(shares, plan)?;
        let trace = party.transport.trace();
        println!(
            "relu({} elems, window [{},{})) over TCP [{label}]: {} in {}, {} rounds",
            shares.len(),
            plan.m,
            plan.k,
            stats::fmt_bytes(trace.total_bytes()),
            stats::fmt_secs(t0.elapsed().as_secs_f64()),
            trace.total_rounds()
        );
        Ok(())
    }
    // Dispatch over (fault injection on/off) x (binary layout): the chaos
    // wrapper and the layouts are all bit-exact on the wire, so every
    // combination interoperates with every other.
    #[allow(clippy::too_many_arguments)]
    fn run_layout<T: Transport>(
        transport: T,
        layout: BinLayout,
        kernel: KernelChoice,
        seed: u64,
        shares: &[u64],
        plan: ReluPlan,
        threads: usize,
        prefetch: bool,
    ) -> Result<()> {
        match layout {
            BinLayout::Bitsliced => run_relu(
                GmwParty::with_kernels(
                    transport,
                    seed,
                    BitslicedKernels::with_kernel(kernel).map_err(anyhow::Error::from)?,
                ),
                shares,
                plan,
                threads,
                prefetch,
                "bitsliced",
            ),
            BinLayout::LanePerU64 => run_relu(
                GmwParty::with_kernels(
                    transport,
                    seed,
                    RustKernels::with_kernel(kernel).map_err(anyhow::Error::from)?,
                ),
                shares,
                plan,
                threads,
                prefetch,
                "lane",
            ),
        }
    }
    let mut prg = hummingbird::crypto::prg::Prg::new(100 + rank as u64, 0);
    let shares = prg.vec_u64(n);
    match fault {
        Some(profile) => run_layout(
            FaultyTransport::new(transport, &profile),
            layout,
            kernel,
            seed,
            &shares,
            plan,
            threads,
            prefetch,
        ),
        None => run_layout(transport, layout, kernel, seed, &shares, plan, threads, prefetch),
    }
}

// ---------------------------------------------------------------------
// selftest
// ---------------------------------------------------------------------

fn cmd_selftest(_args: &Args) -> Result<()> {
    use hummingbird::gmw::harness::{run_parties, run_parties_with};
    use hummingbird::gmw::kernels::{self, BitslicedKernels, RustKernels};
    use hummingbird::gmw::ReluPlan;
    use hummingbird::sharing::{reconstruct_arith, share_arith};
    // Kernel dispatch cross-check (DESIGN.md §11): drive every primitive
    // the auto-dispatched arm would use against the forced-scalar
    // reference before trusting it with protocol state. A divergence is a
    // typed `Error::Kernel` — selftest fails fast instead of reporting
    // plausible-looking but wrong protocol numbers.
    kernels::selfcheck(KernelChoice::Auto).map_err(anyhow::Error::from)?;
    println!(
        "kernel selfcheck: auto arm (simd: {}) matches scalar reference",
        if kernels::auto_simd() { "on" } else { "off" }
    );
    let mut prg = hummingbird::crypto::prg::Prg::new(1, 1);
    let x: Vec<u64> = (0..1000)
        .map(|i| if i % 2 == 0 { i as u64 } else { (i as u64).wrapping_neg() })
        .collect();
    let xs = share_arith(&mut prg, &x, 2);
    for (name, plan) in [
        ("baseline 64-bit", ReluPlan::BASELINE),
        // LINT-ALLOW: unwrap — selftest demo with known-valid plans.
        ("eco 20-bit", ReluPlan::new(20, 0).unwrap()),
        ("hummingbird [2,10)", ReluPlan::new(10, 2).unwrap()),
    ] {
        let xs_run = xs.clone();
        let run = run_parties(2, 3, move |p| {
            let me = p.party();
            // LINT-ALLOW: unwrap — selftest panics on protocol failure.
            p.relu(&xs_run[me], plan).unwrap()
        });
        let out = reconstruct_arith(&run.outputs);
        let errs = out
            .iter()
            .zip(&x)
            .filter(|(o, xi)| {
                let expect = if (**xi as i64) < 0 { 0 } else { **xi };
                **o != expect
            })
            .count();
        // Same circuit through the bitsliced layout: per-party shares and
        // wire accounting must match the lane layout exactly.
        let xs_run = xs.clone();
        let sliced = run_parties_with(2, 3, |_| BitslicedKernels::default(), move |p| {
            let me = p.party();
            // LINT-ALLOW: unwrap — selftest panics on protocol failure.
            p.relu(&xs_run[me], plan).unwrap()
        });
        let layouts_match = sliced.outputs == run.outputs
            && sliced.trace.total_bytes() == run.trace.total_bytes()
            && sliced.trace.total_rounds() == run.trace.total_rounds();
        // End-to-end kernel cross-check: the same circuit under the
        // forced-scalar reference arm must reproduce the auto-dispatched
        // run bit-for-bit (shares, wire bytes and round count).
        let xs_run = xs.clone();
        let scalar = run_parties_with(2, 3, |_| RustKernels::scalar(), move |p| {
            let me = p.party();
            // LINT-ALLOW: unwrap — selftest panics on protocol failure.
            p.relu(&xs_run[me], plan).unwrap()
        });
        let kernels_match = scalar.outputs == run.outputs
            && scalar.trace.total_bytes() == run.trace.total_bytes()
            && scalar.trace.total_rounds() == run.trace.total_rounds();
        println!(
            "{name:<24} bytes={:<10} rounds={:<4} deviations={errs} \
             layouts-match={layouts_match} kernels-match={kernels_match}",
            run.trace.total_bytes(),
            run.trace.total_rounds()
        );
        if !layouts_match {
            bail!("bitsliced layout diverged from lane layout on {name}");
        }
        if !kernels_match {
            return Err(hummingbird::error::Error::kernel(format!(
                "auto-dispatched kernel diverged from forced scalar on {name}"
            ))
            .into());
        }
    }
    println!("selftest done");
    Ok(())
}
