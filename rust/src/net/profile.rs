//! Network & compute profiles + analytic latency projection (paper §5.1,
//! Figs 7–10).
//!
//! The paper reports three network setups (High-BW ≈ NVLink 16 Tbps, LAN
//! 10 Gbps, WAN 352 Mbps) and two GPUs (A100, V100). Its WAN row is itself
//! an analytic projection: "we separately measured the communication time
//! from the High-BW setup and scaled it according to the assumed bandwidth".
//! We apply that same methodology uniformly: the protocol run yields an
//! exact per-round byte trace ([`CommTrace`]) and a measured local compute
//! time; a profile then prices the trace as
//! `Σ_rounds (latency + bytes/bandwidth)` and scales compute.

use super::accounting::CommTrace;
use crate::util::json::Json;

/// A network profile: per-round latency plus per-byte cost.
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkProfile {
    pub name: String,
    /// One-way per-message latency in seconds (applied once per round).
    pub latency_s: f64,
    /// Link bandwidth in bits per second (per direction, full duplex).
    pub bandwidth_bps: f64,
}

impl NetworkProfile {
    pub fn new(name: &str, latency_s: f64, bandwidth_bps: f64) -> Self {
        NetworkProfile { name: name.to_string(), latency_s, bandwidth_bps }
    }

    /// The paper's three setups (§5.1 / Fig 9).
    pub fn high_bw() -> Self {
        // Two GPUs on one node; paper cites up to 16 Tbps NVLink. Observed
        // usage "did not exceed 20 Gbps"; latency is PCIe/NVLink-scale.
        NetworkProfile::new("High-BW", 5e-6, 16e12)
    }
    pub fn lan() -> Self {
        NetworkProfile::new("LAN", 50e-6, 10e9)
    }
    pub fn wan() -> Self {
        // 352 Mbps per prior work [15] (Cheetah); WAN RTT ~40 ms -> one-way 20ms.
        NetworkProfile::new("WAN", 20e-3, 352e6)
    }

    /// Time to push `bytes` through the link plus the round latency.
    pub fn round_time(&self, bytes: u64) -> f64 {
        self.latency_s + (bytes as f64 * 8.0) / self.bandwidth_bps
    }

    /// Price a whole trace: Σ_rounds (latency + bytes/bw).
    pub fn comm_time(&self, trace: &CommTrace) -> f64 {
        trace.rounds().iter().map(|r| self.round_time(r.bytes_sent)).sum()
    }

    pub fn to_json(&self) -> Json {
        // HOT-PATH-ALLOW: reporting — serialization is off the wire path.
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("latency_s", Json::Num(self.latency_s)),
            ("bandwidth_bps", Json::Num(self.bandwidth_bps)),
        ])
    }

    pub fn from_json(j: &Json) -> crate::error::Result<Self> {
        Ok(NetworkProfile {
            name: j.get_str("name")?.to_string(),
            latency_s: j.get_f64("latency_s")?,
            bandwidth_bps: j.get_f64("bandwidth_bps")?,
        })
    }
}

/// A compute profile: scales measured local compute time so the A100/V100
/// contrast of Figs 7/8/10 can be reproduced on this CPU testbed. The scale
/// is relative to an abstract "A100-class" device = 1.0.
#[derive(Debug, Clone, PartialEq)]
pub struct ComputeProfile {
    pub name: String,
    /// Multiplier on measured local (linear + protocol-local) compute time.
    pub scale: f64,
}

impl ComputeProfile {
    pub fn a100() -> Self {
        ComputeProfile { name: "A100".into(), scale: 1.0 }
    }
    /// V100 ≈ 2.4× slower for the fp/int tensor work in this pipeline
    /// (ratio of the paper's CrypTen baseline compute fractions across
    /// Figs 7/8: compute goes from ~7% on A100 to ~22% on V100 at similar
    /// totals).
    pub fn v100() -> Self {
        ComputeProfile { name: "V100".into(), scale: 2.4 }
    }

    pub fn from_json(j: &Json) -> crate::error::Result<Self> {
        Ok(ComputeProfile { name: j.get_str("name")?.to_string(), scale: j.get_f64("scale")? })
    }
}

/// End-to-end projection of one measured run onto a (network, compute)
/// profile pair.
#[derive(Debug, Clone)]
pub struct Projection {
    pub network: String,
    pub compute: String,
    pub comm_time_s: f64,
    pub compute_time_s: f64,
}

impl Projection {
    pub fn total_s(&self) -> f64 {
        self.comm_time_s + self.compute_time_s
    }
}

/// Project a run: `compute_time_s` is the *measured* local compute time of
/// the protocol run (everything except waiting on the wire).
pub fn project(
    trace: &CommTrace,
    compute_time_s: f64,
    net: &NetworkProfile,
    gpu: &ComputeProfile,
) -> Projection {
    Projection {
        // HOT-PATH-ALLOW: reporting — labels cloned once per projection.
        network: net.name.clone(),
        compute: gpu.name.clone(),
        comm_time_s: net.comm_time(trace),
        compute_time_s: compute_time_s * gpu.scale,
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::net::accounting::Phase;

    #[test]
    fn round_time_has_latency_floor() {
        let lan = NetworkProfile::lan();
        assert!(lan.round_time(0) == 50e-6);
        // 10 Gbps: 125 MB/s per 0.1s -> 1.25e9 B/s
        let t = lan.round_time(1_250_000);
        assert!((t - (50e-6 + 1e-3)).abs() < 1e-9, "{t}");
    }

    #[test]
    fn wan_slower_than_lan_slower_than_highbw() {
        let trace = CommTrace::new();
        for _ in 0..100 {
            trace.record(Phase::Circuit, 10_000);
        }
        let hb = NetworkProfile::high_bw().comm_time(&trace);
        let lan = NetworkProfile::lan().comm_time(&trace);
        let wan = NetworkProfile::wan().comm_time(&trace);
        assert!(hb < lan && lan < wan, "{hb} {lan} {wan}");
    }

    #[test]
    fn projection_combines_compute_and_comm() {
        let trace = CommTrace::new();
        trace.record(Phase::Mult, 1000);
        let p = project(&trace, 2.0, &NetworkProfile::lan(), &ComputeProfile::v100());
        assert!(p.compute_time_s == 4.8);
        assert!(p.total_s() > 4.8);
    }

    #[test]
    fn json_roundtrip() {
        let lan = NetworkProfile::lan();
        let back = NetworkProfile::from_json(&lan.to_json()).unwrap();
        assert_eq!(lan, back);
    }
}
