//! Bitpacking wire-library throughput (paper §4.2). The pack/unpack pair
//! sits on every AND opening, so its throughput must far exceed link
//! bandwidth to keep the protocol communication-bound.

use hummingbird::bitpack;
use hummingbird::crypto::prg::Prg;
use hummingbird::util::benchkit::{black_box, Bench};

fn main() {
    let mut bench = Bench::new();
    let n = 1 << 18; // 256k lanes
    let mut prg = Prg::new(9, 9);
    for w in [1u32, 6, 8, 12, 20, 32, 63] {
        let mask = hummingbird::ring::low_mask(w);
        let src: Vec<u64> = (0..n).map(|_| prg.next_u64() & mask).collect();
        let mut packed = Vec::new();
        bitpack::pack(&src, w, &mut packed);
        let bytes = bitpack::packed_bytes(n, w);

        let mut dst = Vec::new();
        bench.bench_bytes(&format!("pack/w{w}/{n}"), bytes, || {
            bitpack::pack(black_box(&src), w, &mut dst);
            black_box(&dst);
        });
        let mut out = Vec::new();
        bench.bench_bytes(&format!("unpack/w{w}/{n}"), bytes, || {
            bitpack::unpack(black_box(&packed), w, n, &mut out);
            black_box(&out);
        });
    }
    // Byte-granular wire format used by the transport.
    let src: Vec<u64> = (0..n).map(|_| prg.next_u64() & 0x3f).collect();
    bench.bench_bytes("pack_bytes/w6", bitpack::packed_bytes(n, 6), || {
        black_box(bitpack::pack_bytes(black_box(&src), 6));
    });

    // Fused hot-path pair (allocation-free, thread-scalable): pack straight
    // into a reused wire buffer, unpack-XOR straight into the lane buffer.
    let threads = hummingbird::util::benchkit::bench_threads();
    let bytes6 = bitpack::packed_bytes(n, 6);
    for t in [1usize, threads] {
        let mut wire = Vec::new();
        bench.bench_bytes(&format!("pack_bytes_into/w6/{n}/t{t}"), bytes6, || {
            bitpack::pack_bytes_into(black_box(&src), 6, &mut wire, t);
            black_box(&wire);
        });
        let mut out = vec![0u64; n];
        bench.bench_bytes(&format!("unpack_xor_into/w6/{n}/t{t}"), bytes6, || {
            bitpack::unpack_bytes_xor_into(black_box(&wire), 6, n, &mut out, t);
            black_box(&out);
        });
        if threads == 1 {
            break;
        }
    }
    bench.dump_json("bitpack");
}
