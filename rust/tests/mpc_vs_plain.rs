//! End-to-end correctness: the two-party MPC inference (share executor over
//! the GMW engine + PJRT artifacts) reconstructs to the plaintext model's
//! outputs within fixed-point tolerance, for both the exact baseline and
//! HummingBird plans; and HummingBird plans cut the measured communication
//! (the mechanism behind every figure in the paper).
//!
//! Requires `make artifacts` + trained weights (skips cleanly otherwise).

use hummingbird::crypto::prg::Prg;
use hummingbird::gmw::harness::run_parties;
use hummingbird::hummingbird::PlanSet;
use hummingbird::model::{
    Archive, Backend, Dataset, ModelConfig, PlainExecutor, ShareExecutor, ShareWeights,
};
use hummingbird::ring::FixedPoint;
use hummingbird::runtime::{Manifest, Runtime};
use hummingbird::sharing::{reconstruct_arith, share_arith};

const MODEL: &str = "micronet_synth10";

struct Env {
    root: std::path::PathBuf,
    cfg: ModelConfig,
    weights: Archive,
    dataset: Dataset,
}

fn env() -> Option<Env> {
    let repo = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let root = repo.join("artifacts");
    let weights_prefix = root.join("weights").join(MODEL);
    if !root.join("manifest.json").exists() || !weights_prefix.with_extension("json").exists() {
        eprintln!("skipping: artifacts or weights missing (run `make artifacts && make train`)");
        return None;
    }
    let cfg = ModelConfig::load_named(repo, MODEL).ok()?;
    let weights = Archive::load(&weights_prefix).ok()?;
    let dataset = Dataset::load(&root, &cfg.dataset).ok()?;
    Some(Env { root, cfg, weights, dataset })
}

/// Run a 2-party MPC inference on one test batch; returns (decoded logits,
/// total bytes, total rounds).
fn mpc_run(e: &Env, plans: &PlanSet, lo: usize, seed: u64) -> (Vec<f64>, u64, u64) {
    let manifest = Manifest::load(&e.root).unwrap();
    let model_art = manifest.model(MODEL).unwrap();
    let batch = model_art.batch;
    let fx = FixedPoint::new(e.cfg.frac_bits);
    let x_ring = e.dataset.test.batch_ring(lo, lo + batch, fx);
    let mut prg = Prg::new(seed, 0);
    let xs = share_arith(&mut prg, &x_ring, 2);
    let (c, h, w) = e.cfg.input;
    let shape = vec![batch, c, h, w];

    let root = e.root.clone();
    let cfg = e.cfg.clone();
    let weights = e.weights.clone();
    let run = run_parties(2, seed ^ 0xabc, move |party| {
        // Per-party runtime (the PJRT client is thread-local).
        let rt = Runtime::new(&root).unwrap();
        let manifest = Manifest::load(&root).unwrap();
        let art = manifest.model(MODEL).unwrap().clone();
        let sw = ShareWeights::prepare(&cfg, &weights).unwrap();
        let mut exec = ShareExecutor::new(cfg.clone(), art, rt, sw);
        let me = party.party();
        let x = hummingbird::tensor::TensorU64::new(shape.clone(), xs[me].clone()).unwrap();
        let (out, _bd) = exec.forward(party, x, plans).unwrap();
        out.data
    });
    let logits_ring = reconstruct_arith(&run.outputs);
    let logits = logits_ring.iter().map(|v| fx.decode(*v)).collect();
    (logits, run.trace.total_bytes(), run.trace.total_rounds())
}

#[test]
fn mpc_baseline_matches_plaintext_logits() {
    let Some(e) = env() else { return };
    let plans = PlanSet::baseline(e.cfg.relu_groups);
    let (got, _, _) = mpc_run(&e, &plans, 0, 1234);

    let plain = PlainExecutor::new(e.cfg.clone(), e.weights.clone(), Backend::Naive);
    let batch = 4;
    let want = plain.forward(e.dataset.test.batch(0, batch), batch).unwrap();
    assert_eq!(got.len(), want.len());
    // Fixed-point truncation error accumulates per layer; tolerance a few
    // dozen ulps at f=12.
    for (g, w) in got.iter().zip(&want) {
        assert!((g - *w as f64).abs() < 5e-2, "logit mismatch: mpc={g} plain={w}");
    }
    let classes = e.cfg.num_classes;
    let got_f32: Vec<f32> = got.iter().map(|v| *v as f32).collect();
    assert_eq!(
        PlainExecutor::argmax(&got_f32, classes),
        PlainExecutor::argmax(&want, classes),
        "baseline MPC must preserve predictions"
    );
}

#[test]
fn mpc_eco_plan_preserves_predictions() {
    let Some(e) = env() else { return };
    // Generous eco plan: 22 bits comfortably covers the activation range
    // at f=12 (|x| < 2^9).
    let plans = PlanSet::uniform(e.cfg.relu_groups, 22, 0).unwrap();
    let (got, _, _) = mpc_run(&e, &plans, 0, 77);
    let plain = PlainExecutor::new(e.cfg.clone(), e.weights.clone(), Backend::Naive);
    let batch = 4;
    let want = plain.forward(e.dataset.test.batch(0, batch), batch).unwrap();
    let classes = e.cfg.num_classes;
    let got_f32: Vec<f32> = got.iter().map(|v| *v as f32).collect();
    assert_eq!(
        PlainExecutor::argmax(&got_f32, classes),
        PlainExecutor::argmax(&want, classes),
        "Theorem 1: eco plan must not change predictions"
    );
}

#[test]
fn hummingbird_plan_reduces_model_communication() {
    let Some(e) = env() else { return };
    let baseline = PlanSet::baseline(e.cfg.relu_groups);
    let hb8 = PlanSet::uniform(e.cfg.relu_groups, 8, 2).unwrap();
    let hb6 = PlanSet::uniform(e.cfg.relu_groups, 6, 2).unwrap();
    let (_, b0, r0) = mpc_run(&e, &baseline, 0, 42);
    let (_, b8, r8) = mpc_run(&e, &hb8, 0, 42);
    let (_, b6, _) = mpc_run(&e, &hb6, 0, 42);
    // Paper Fig 11: bytes shrink 2.68–8.76x and saturate (Mult floor);
    // rounds shrink 1.12–1.56x.
    let ratio8 = b0 as f64 / b8 as f64;
    let ratio6 = b0 as f64 / b6 as f64;
    assert!(ratio8 > 2.5, "8-bit plan only cut bytes {ratio8:.2}x ({b0} -> {b8})");
    assert!(ratio6 > ratio8, "6-bit must cut more than 8-bit");
    assert!(ratio6 < 64.0, "saturation: Mult bytes cannot be compressed");
    assert!(r0 > r8, "rounds must shrink ({r0} -> {r8})");
}
