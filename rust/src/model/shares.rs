//! Share-domain model executor: one party's view of the private inference.
//!
//! Linear layers run **locally** on this party's arithmetic shares against
//! the public quantized weights (shared-model setting, like the paper's
//! evaluation) through the AOT `share_*` HLO artifacts (Layer-2 graphs
//! calling the Layer-1 Pallas ring matmul). Non-linear layers go through
//! the GMW engine: ReLU per the active [`PlanSet`], truncation and public
//! scaling locally.
//!
//! Fixed-point discipline (f = frac_bits):
//!   activations/weights at scale 2^f → conv/fc product at 2^(2f) →
//!   add bias (encoded at 2^(2f)) → truncate by f → back to 2^f.
//!   GAP: sum (scale f) → × encode(1/hw) (scale 2f) → truncate.
//!
//! # Steady-state activation reuse
//!
//! The executor owns a size-classed activation pool (the same
//! [`Arena`] the GMW engine uses for round temporaries) plus a per-node
//! consumer refcount derived from the graph:
//!
//! * a *mutating* consumer (the residual add's accumulator) **claims** its
//!   source activation — moving it on the last use, copying into a
//!   pool-recycled buffer otherwise; read-only consumers (linear layers,
//!   ReLU, GAP) borrow the stored tensor and just drop their refcount, so
//!   fan-out never copies for them;
//! * once a node's last consumer has run, its activation buffer goes back
//!   to the pool instead of staying alive for the whole pass;
//! * ReLU rounds write through [`GmwParty::relu_into`] into pooled
//!   buffers, truncation is in place, and residual adds accumulate in
//!   place.
//!
//! After one warm-up pass the pool holds a buffer for every activation
//! size class, so a steady-state [`ShareExecutor::forward`] performs zero
//! data-buffer allocations in activation handling (linear-layer artifact
//! *outputs* are allocated by the PJRT runtime, but are recycled into the
//! pool when consumed; tiny shape vectors are not pooled). Long-running
//! serving loops that keep the logits on this thread can hand the output
//! buffer back via [`ShareExecutor::recycle`] to make the pass fully
//! miss-free; [`ShareExecutor::pool_stats`] exposes the counters that pin
//! this in tests.
//!
//! The executor also records a per-op timing breakdown so Fig 1/10's
//! {linear, ReLU-compute, ReLU-comm} split can be regenerated.

use std::time::Instant;

use crate::error::{Error, Result};
use crate::gmw::arena::{Arena, ArenaStats};
use crate::gmw::kernels::KernelBackend;
use crate::gmw::GmwParty;
use crate::hummingbird::PlanSet;
use crate::model::graph::{ModelConfig, Op};
use crate::model::weights::{conv_weight_to_mat, quantize, Archive};
use crate::net::Transport;
use crate::ring::FixedPoint;
use crate::runtime::{registry::ModelArtifacts, Runtime};
use crate::tensor::TensorU64;

/// Wall-clock breakdown of one forward pass (seconds).
#[derive(Debug, Default, Clone, Copy)]
pub struct ExecBreakdown {
    /// Linear layers (conv/fc artifacts + truncation + bias).
    pub linear_s: f64,
    /// ReLU protocol time, total (local compute + wire wait).
    pub relu_s: f64,
    /// Everything else (pool, add, reshape).
    pub other_s: f64,
}

impl ExecBreakdown {
    pub fn total(&self) -> f64 {
        self.linear_s + self.relu_s + self.other_s
    }
    pub fn add(&mut self, other: &ExecBreakdown) {
        self.linear_s += other.linear_s;
        self.relu_s += other.relu_s;
        self.other_s += other.other_s;
    }
}

/// Prepared (quantized) weights for the share executor.
pub struct ShareWeights {
    /// Per conv/fc node: im2col weight matrix on the ring.
    wmats: std::collections::BTreeMap<usize, TensorU64>,
    /// Per conv/fc node: bias at scale 2^(2f).
    biases: std::collections::BTreeMap<usize, Vec<u64>>,
}

impl ShareWeights {
    /// Quantize an f32 archive for `cfg`.
    pub fn prepare(cfg: &ModelConfig, weights: &Archive) -> Result<ShareWeights> {
        let fx = FixedPoint::new(cfg.frac_bits);
        let fx2 = FixedPoint::new(2 * cfg.frac_bits);
        let shapes = cfg.shapes();
        let mut wmats = std::collections::BTreeMap::new();
        let mut biases = std::collections::BTreeMap::new();
        for (i, node) in cfg.nodes.iter().enumerate() {
            match node {
                Op::Conv { src, out_ch, k, .. } => {
                    let cin = shapes[*src][0];
                    let w = weights.get(&format!("w{i}"))?.as_f32()?;
                    let mat = conv_weight_to_mat(w, *out_ch, cin, *k);
                    let q = quantize(&mat, fx);
                    wmats.insert(
                        i,
                        TensorU64::new(vec![cin * k * k, *out_ch], q)?,
                    );
                    let b = weights.get(&format!("b{i}"))?.as_f32()?;
                    biases.insert(i, b.iter().map(|v| fx2.encode(*v as f64)).collect());
                }
                Op::Fc { out, .. } => {
                    let w = weights.get(&format!("w{i}"))?.as_f32()?;
                    let in_dim = w.len() / out;
                    wmats.insert(i, TensorU64::new(vec![in_dim, *out], quantize(w, fx))?);
                    let b = weights.get(&format!("b{i}"))?.as_f32()?;
                    biases.insert(i, b.iter().map(|v| fx2.encode(*v as f64)).collect());
                }
                _ => {}
            }
        }
        Ok(ShareWeights { wmats, biases })
    }
}

/// Which linear-layer artifact variant to execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinearBackend {
    /// The Layer-1 Pallas kernel lowering (validated TPU-shaped path;
    /// slow under CPU interpret lowering).
    Pallas,
    /// The fused int64-dot lowering of the same ring math (CPU hot path;
    /// see EXPERIMENTS.md §Perf L2). Falls back to Pallas when the fast
    /// artifact is absent.
    Fast,
}

/// The share executor (per party; owns the reusable activation state, so
/// one executor serves one party thread across many requests).
pub struct ShareExecutor {
    pub cfg: ModelConfig,
    pub artifacts: ModelArtifacts,
    rt: Runtime,
    weights: ShareWeights,
    pub linear: LinearBackend,
    /// Size-classed activation-buffer pool (see module docs).
    pool: Arena,
    /// Static per-node shapes (computed once; `cfg` is immutable).
    shapes: Vec<Vec<usize>>,
    /// Static consumer count per node (how many later nodes read it).
    uses: Vec<usize>,
    /// Per-pass remaining-consumer counts (reset from `uses`).
    remaining: Vec<usize>,
    /// Per-pass activation slots (kept across passes to avoid re-allocating
    /// the slot vector; tensors are recycled as their last consumer runs).
    acts: Vec<Option<TensorU64>>,
}

impl ShareExecutor {
    pub fn new(
        cfg: ModelConfig,
        artifacts: ModelArtifacts,
        rt: Runtime,
        weights: ShareWeights,
    ) -> ShareExecutor {
        let n = cfg.nodes.len();
        let mut uses = vec![0usize; n];
        for node in &cfg.nodes {
            match node {
                Op::Input => {}
                Op::Conv { src, .. }
                | Op::Fc { src, .. }
                | Op::Relu { src, .. }
                | Op::Gap { src } => uses[*src] += 1,
                Op::Add { a, b } => {
                    uses[*a] += 1;
                    uses[*b] += 1;
                }
            }
        }
        let shapes = cfg.shapes();
        ShareExecutor {
            cfg,
            artifacts,
            rt,
            weights,
            linear: LinearBackend::Fast,
            pool: Arena::new(),
            shapes,
            uses,
            remaining: vec![0; n],
            acts: (0..n).map(|_| None).collect(),
        }
    }

    pub fn with_linear(mut self, linear: LinearBackend) -> Self {
        self.linear = linear;
        self
    }

    /// Counters of the activation pool (checkouts / returns / allocation
    /// misses). Steady-state forward passes must not add misses; the
    /// warm-path invariant is pinned by `forward_steady_state_reuses_buffers`.
    pub fn pool_stats(&self) -> ArenaStats {
        self.pool.stats()
    }

    /// Hand an output tensor's buffer back to the activation pool (serving
    /// loops that consume the logits on this thread call this to make the
    /// next pass fully miss-free).
    pub fn recycle(&mut self, t: TensorU64) {
        self.pool.put_words(t.data);
    }

    /// Claim node `src`'s activation for one consumer: moves the tensor on
    /// its last use, otherwise copies it into a pool-recycled buffer. The
    /// input buffer (node 0) is never moved into the dataflow — it is
    /// copied and dropped, so the caller-owned `Vec` can't sneak into the
    /// bounded pool through a downstream release (see [`Self::release`]).
    fn claim(&mut self, src: usize) -> Result<TensorU64> {
        let t = match self.remaining[src] {
            0 => return Err(miss(src)),
            1 if src != 0 => self.acts[src].take().ok_or_else(|| miss(src))?,
            1 => {
                let t = self.acts[src].take().ok_or_else(|| miss(src))?;
                let mut data = self.pool.take_words(t.len());
                data.copy_from_slice(&t.data);
                TensorU64 { shape: t.shape, data }
            }
            _ => {
                let t = self.acts[src].as_ref().ok_or_else(|| miss(src))?;
                let mut data = self.pool.take_words(t.len());
                data.copy_from_slice(&t.data);
                TensorU64 { shape: t.shape.clone(), data }
            }
        };
        self.remaining[src] -= 1;
        Ok(t)
    }

    /// Mark one read of node `src` done; recycles its buffer after the
    /// last consumer. The *input* buffer (node 0) is dropped instead of
    /// pooled: it arrives as a fresh caller-owned `Vec` every request, so
    /// pooling it would grow the pool by one foreign buffer per request —
    /// for conv models its size class is never checked out again, and the
    /// dead buffers would eventually crowd live classes out of the
    /// bounded pool.
    fn release(&mut self, src: usize) {
        debug_assert!(self.remaining[src] > 0, "release past refcount (node {src})");
        self.remaining[src] -= 1;
        if self.remaining[src] == 0 {
            if let Some(t) = self.acts[src].take() {
                if src != 0 {
                    self.pool.put_words(t.data);
                }
            }
        }
    }

    /// Full private forward pass on this party's input share
    /// `x` ([batch, C, H, W] flattened). Returns (logit shares, breakdown).
    /// Steady-state allocation behavior is described in the module docs.
    pub fn forward<T: Transport, K: KernelBackend>(
        &mut self,
        party: &mut GmwParty<T, K>,
        x: TensorU64,
        plans: &PlanSet,
    ) -> Result<(TensorU64, ExecBreakdown)> {
        let batch = self.artifacts.batch;
        let f = self.cfg.frac_bits;
        let n_nodes = self.cfg.nodes.len();
        let mut bd = ExecBreakdown::default();
        if x.shape.first() != Some(&batch) {
            return Err(Error::shape(format!(
                "input batch {:?} != artifact batch {batch}",
                x.shape
            )));
        }
        // Reset per-pass state; leftover activations (dead nodes, aborted
        // passes) recycle into the pool instead of dropping — except the
        // previous input buffer, which is dropped (see `release`).
        self.remaining.copy_from_slice(&self.uses);
        for (idx, slot) in self.acts.iter_mut().enumerate() {
            if let Some(t) = slot.take() {
                if idx != 0 {
                    self.pool.put_words(t.data);
                }
            }
        }
        self.acts[0] = Some(x);
        for i in 1..n_nodes {
            // Clone the op descriptor (a few words) so `self` stays free
            // for the claim/release bookkeeping below.
            let node = self.cfg.nodes[i].clone();
            let t0 = Instant::now();
            let out = match node {
                Op::Input => unreachable!("input is node 0"),
                Op::Conv { src, .. } | Op::Fc { src, .. } => {
                    // The artifact only *reads* its input, so shared
                    // sources need no copy: take the tensor out of its
                    // slot for the call (swapping in the flattened fc
                    // shape if needed) and put it back unless this was
                    // its last consumer.
                    let mut xin = self.acts[src].take().ok_or_else(|| miss(i))?;
                    let orig_shape = if matches!(node, Op::Fc { .. }) {
                        // Flatten for fc (with the same validation the old
                        // reshape() performed).
                        if xin.len() % batch != 0 {
                            return Err(Error::shape(format!(
                                "fc node {i}: input of {} elems not divisible by batch {batch}",
                                xin.len()
                            )));
                        }
                        let flat = xin.len() / batch;
                        Some(std::mem::replace(&mut xin.shape, vec![batch, flat]))
                    } else {
                        None
                    };
                    let layer = self
                        .artifacts
                        .layers
                        .get(&i)
                        .ok_or_else(|| Error::Model(format!("no artifact for node {i}")))?;
                    let wmat = &self.weights.wmats[&i];
                    let artifact = match (self.linear, &layer.share_fast) {
                        (LinearBackend::Fast, Some(fast)) => fast.as_str(),
                        _ => layer.share.as_str(),
                    };
                    let mut y = self
                        .rt
                        .run_u64(artifact, &[&xin, wmat])?
                        .into_iter()
                        .next()
                        .ok_or_else(|| Error::runtime("artifact returned no output"))?;
                    // Restore the tensor (and its original shape), then
                    // drop this consumer's refcount — release() recycles
                    // the buffer if this was the last consumer.
                    if let Some(shape) = orig_shape {
                        xin.shape = shape;
                    }
                    self.acts[src] = Some(xin);
                    self.release(src);
                    // Bias (public, leader-only) at scale 2f, then truncate
                    // in place — the artifact's output buffer becomes the
                    // activation with no further copies.
                    let bias = &self.weights.biases[&i];
                    if party.is_leader() {
                        add_bias(&mut y, bias, batch)?;
                    }
                    party.trunc_in_place(&mut y.data, f);
                    bd.linear_s += t0.elapsed().as_secs_f64();
                    y
                }
                Op::Relu { src, group } => {
                    let plan = plans.plan_for(group);
                    let (shape, data) = {
                        let xin = self.acts[src].as_ref().ok_or_else(|| miss(i))?;
                        let mut out = self.pool.take_words(xin.len());
                        party.relu_into(&xin.data, plan, &mut out)?;
                        (xin.shape.clone(), out)
                    };
                    self.release(src);
                    bd.relu_s += t0.elapsed().as_secs_f64();
                    TensorU64 { shape, data }
                }
                Op::Add { a, b } => {
                    let mut va = self.claim(a)?;
                    {
                        let vb = self.acts[b].as_ref().ok_or_else(|| miss(i))?;
                        va.wrapping_add_assign(vb)?;
                    }
                    self.release(b);
                    bd.other_s += t0.elapsed().as_secs_f64();
                    va
                }
                Op::Gap { src } => {
                    let s = &self.shapes[src];
                    let (c, h, w) = (s[0], s[1], s[2]);
                    let mut sums = self.pool.take_words(batch * c);
                    {
                        let v = self.acts[src].as_ref().ok_or_else(|| miss(i))?;
                        for bi in 0..batch {
                            for ci in 0..c {
                                let base = (bi * c + ci) * h * w;
                                let mut acc = 0u64;
                                for e in &v.data[base..base + h * w] {
                                    acc = acc.wrapping_add(*e);
                                }
                                sums[bi * c + ci] = acc;
                            }
                        }
                    }
                    self.release(src);
                    // × encode(1/hw) (scale f) → 2f → truncate back to f.
                    let fx = FixedPoint::new(f);
                    let inv = fx.encode(1.0 / (h * w) as f64);
                    for e in sums.iter_mut() {
                        *e = e.wrapping_mul(inv);
                    }
                    party.trunc_in_place(&mut sums, f);
                    bd.other_s += t0.elapsed().as_secs_f64();
                    TensorU64::new(vec![batch, c], sums)?
                }
            };
            self.acts[i] = Some(out);
        }
        let out = self.acts[n_nodes - 1].take().ok_or_else(|| Error::Model("no output".into()))?;
        Ok((out, bd))
    }
}

fn miss(i: usize) -> Error {
    Error::Model(format!("node {i}: missing source activation"))
}

/// Add a public per-channel bias to a conv output [B,C,H,W] or fc [B,C].
fn add_bias(y: &mut TensorU64, bias: &[u64], batch: usize) -> Result<()> {
    if batch == 0 {
        return Err(Error::shape("add_bias: batch must be non-zero"));
    }
    if bias.is_empty() {
        return Err(Error::shape("add_bias: empty bias"));
    }
    if y.len() % batch != 0 {
        return Err(Error::shape(format!(
            "add_bias: output len {} not divisible by batch {batch}",
            y.len()
        )));
    }
    let per = y.len() / batch;
    let c = bias.len();
    let spatial = per / c;
    if spatial == 0 || c * spatial != per {
        return Err(Error::shape("bias does not divide output"));
    }
    for bi in 0..batch {
        for ci in 0..c {
            let base = (bi * c + ci) * spatial;
            for e in &mut y.data[base..base + spatial] {
                *e = e.wrapping_add(bias[ci]);
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmw::harness::run_parties;
    use crate::ring;
    use crate::sharing::{reconstruct_arith, share_arith};
    use crate::util::json;

    #[test]
    fn bias_broadcast_layout() {
        // [B=1, C=2, 2x1 spatial]
        let mut y = TensorU64::new(vec![1, 2, 2, 1], vec![0, 0, 0, 0]).unwrap();
        add_bias(&mut y, &[5, 9], 1).unwrap();
        assert_eq!(y.data, vec![5, 5, 9, 9]);
        // fc case: spatial = 1
        let mut y = TensorU64::new(vec![2, 2], vec![0; 4]).unwrap();
        add_bias(&mut y, &[1, 2], 2).unwrap();
        assert_eq!(y.data, vec![1, 2, 1, 2]);
    }

    /// Degenerate shapes are shape errors, not divide-by-zero panics.
    #[test]
    fn bias_zero_guards() {
        let mut y = TensorU64::new(vec![1, 2], vec![0, 0]).unwrap();
        assert!(matches!(add_bias(&mut y, &[1, 2], 0), Err(Error::Shape(_))));
        assert!(matches!(add_bias(&mut y, &[], 1), Err(Error::Shape(_))));
        // More channels than output elements: spatial would truncate to 0.
        let mut y = TensorU64::new(vec![1, 2], vec![0, 0]).unwrap();
        assert!(matches!(add_bias(&mut y, &[1, 2, 3], 1), Err(Error::Shape(_))));
        // Batch not dividing the output length.
        let mut y = TensorU64::new(vec![3], vec![0, 0, 0]).unwrap();
        assert!(matches!(add_bias(&mut y, &[1], 2), Err(Error::Shape(_))));
    }

    /// A linear-free graph (input → relu → residual add → relu → gap) that
    /// exercises every pooled path of the executor without PJRT artifacts.
    fn pooled_cfg() -> ModelConfig {
        let j = json::parse(
            r#"{
          "name":"pooltest","model":"pooltest","dataset":"synthetic",
          "input":[2,4,4],"num_classes":2,"batch":3,"frac_bits":8,
          "relu_groups":1,
          "nodes":[
            {"op":"input"},
            {"op":"relu","in":[0],"group":0},
            {"op":"add","in":[1,1]},
            {"op":"relu","in":[2],"group":0},
            {"op":"gap","in":[3]}
          ]}"#,
        )
        .unwrap();
        ModelConfig::from_json(&j).unwrap()
    }

    fn pooled_exec() -> ShareExecutor {
        let cfg = pooled_cfg();
        let artifacts = ModelArtifacts {
            batch: cfg.batch,
            search_batch: 1,
            frac_bits: cfg.frac_bits,
            layers: std::collections::BTreeMap::new(),
        };
        // No conv/fc nodes → the runtime is never touched (lazy client)
        // and the weight set is empty.
        let rt = Runtime::new("unused-artifacts-root").unwrap();
        let sw = ShareWeights::prepare(&cfg, &Archive::default()).unwrap();
        ShareExecutor::new(cfg, artifacts, rt, sw)
    }

    /// The serving-path warm invariant, pinned (acceptance criterion):
    /// after one warm-up forward pass, further passes add **zero**
    /// allocation misses in the activation pool, the engine arena and the
    /// transport payload pool, and produce bit-identical outputs.
    #[test]
    fn forward_steady_state_reuses_buffers() {
        let batch = 3usize;
        let elems = batch * 2 * 4 * 4;
        let fx = FixedPoint::new(8);
        // Mixed positive/negative activations at scale 2^8.
        let x_ring: Vec<u64> = (0..elems)
            .map(|i| {
                let v = fx.encode((i as f64 * 0.37).sin() * 3.0);
                if i % 3 == 0 {
                    v.wrapping_neg()
                } else {
                    v
                }
            })
            .collect();
        let mut prg = crate::crypto::prg::Prg::new(77, 0);
        let xs = share_arith(&mut prg, &x_ring, 2);
        let plans = PlanSet::baseline(1);
        let shape = vec![batch, 2, 4, 4];

        let run = run_parties(2, 0xa110c, |p| {
            let mut exec = pooled_exec();
            let me = p.party();
            let mk_x =
                || TensorU64::new(shape.clone(), xs[me].clone()).unwrap();
            let mut passes: Vec<Vec<u64>> = Vec::new();
            // Warm-up pass fills every pool size class.
            let (out0, _) = exec.forward(p, mk_x(), &plans).unwrap();
            passes.push(out0.data.clone());
            exec.recycle(out0);
            let warm_pool = exec.pool_stats();
            let warm_arena = p.arena_stats();
            let warm_net = p.transport.pool_stats();
            // Two further warm passes: no new misses anywhere.
            for pass in 0..2 {
                let before = exec.pool_stats();
                let (out, _) = exec.forward(p, mk_x(), &plans).unwrap();
                passes.push(out.data.clone());
                exec.recycle(out);
                let s = exec.pool_stats();
                assert_eq!(
                    s.alloc_misses, warm_pool.alloc_misses,
                    "steady-state pass {pass} allocated an activation buffer"
                );
                // The checkout pattern replays identically each pass.
                assert_eq!(
                    s.checkouts - before.checkouts,
                    warm_pool.checkouts,
                    "pass {pass} changed its checkout pattern"
                );
                assert_eq!(
                    p.arena_stats().alloc_misses,
                    warm_arena.alloc_misses,
                    "steady-state pass {pass} allocated in the engine arena"
                );
                assert_eq!(
                    p.transport.pool_stats().alloc_misses,
                    warm_net.alloc_misses,
                    "steady-state pass {pass} allocated a transport payload"
                );
            }
            passes
        });

        // Every pass (warm-up and steady-state) still computes the right
        // thing: r1 = relu(x); a = 2*r1; r2 = relu(a) = a; gap = mean(a)
        // (±trunc slack — the share randomness differs per pass, so passes
        // agree in value, not in share bits).
        for pass in 0..3 {
            let shares =
                vec![run.outputs[0][pass].clone(), run.outputs[1][pass].clone()];
            let got = reconstruct_arith(&shares);
            assert_eq!(got.len(), batch * 2);
            for bi in 0..batch {
                for ci in 0..2 {
                    let base = (bi * 2 + ci) * 16;
                    let mean: f64 = (0..16)
                        .map(|k| {
                            let v = x_ring[base + k];
                            if ring::is_negative(v) {
                                0.0
                            } else {
                                fx.decode(v)
                            }
                        })
                        .sum::<f64>()
                        * 2.0
                        / 16.0;
                    let g = fx.decode(got[bi * 2 + ci]);
                    assert!(
                        (g - mean).abs() < 0.1,
                        "pass {pass} gap[{bi},{ci}]: got {g}, want ~{mean}"
                    );
                }
            }
        }

        // Bit-identical at any `--threads` value: same session seed → same
        // protocol randomness, so a multi-threaded first pass must equal
        // the single-threaded one share-for-share (acceptance criterion).
        let base_pass0: Vec<Vec<u64>> =
            run.outputs.iter().map(|passes| passes[0].clone()).collect();
        for threads in [2usize, 4] {
            let run_t =
                crate::gmw::harness::run_parties_threaded(2, 0xa110c, threads, |p| {
                    let mut exec = pooled_exec();
                    let me = p.party();
                    let x = TensorU64::new(shape.clone(), xs[me].clone()).unwrap();
                    let (out, _) = exec.forward(p, x, &plans).unwrap();
                    out.data
                });
            assert_eq!(run_t.outputs, base_pass0, "threads={threads}");
        }
    }

    /// Offline/online split through the executor: the per-pass draw
    /// schedule predicted by `TripleSchedule::for_forward` is exactly what
    /// a real forward pass draws (recording dry run), and a coordinator-
    /// style cycling prefetcher produces bit-identical output shares and
    /// `TripleUsage` across two serving passes — with zero inline
    /// expansions on the online path.
    #[test]
    fn forward_prefetch_matches_sync_and_predicted_schedule() {
        use crate::beaver::schedule::{Recorder, TripleSchedule};
        use crate::beaver::TtpDealer;

        let cfg = pooled_cfg();
        let batch = cfg.batch;
        let elems = batch * 2 * 4 * 4;
        let fx = FixedPoint::new(cfg.frac_bits);
        let x_ring: Vec<u64> = (0..elems)
            .map(|i| {
                let v = fx.encode((i as f64 * 0.59).cos() * 2.0);
                if i % 4 == 0 {
                    v.wrapping_neg()
                } else {
                    v
                }
            })
            .collect();
        let mut prg = crate::crypto::prg::Prg::new(91, 0);
        let xs = share_arith(&mut prg, &x_ring, 2);
        let plans = PlanSet::uniform(1, 12, 4).unwrap();
        let shape = vec![batch, 2, 4, 4];
        let seed = 0x0ff1;

        // Two synchronous passes: the reference outputs and usage.
        let sync = run_parties(2, seed, |p| {
            let mut exec = pooled_exec();
            let me = p.party();
            let mk = || TensorU64::new(shape.clone(), xs[me].clone()).unwrap();
            let (o1, _) = exec.forward(p, mk(), &plans).unwrap();
            let (o2, _) = exec.forward(p, mk(), &plans).unwrap();
            (o1.data, o2.data, p.triple_usage())
        });

        // Recording dry run: actual draws == the predicted per-pass
        // schedule, replayed identically on the second pass.
        let want = TripleSchedule::for_forward(&cfg, &plans, batch, 2).ops;
        let recorded = run_parties(2, seed, |p| {
            let (rec, log) = Recorder::new(TtpDealer::new(seed, p.party(), p.parties()));
            p.set_triple_source(Box::new(rec));
            let mut exec = pooled_exec();
            let me = p.party();
            let mk = || TensorU64::new(shape.clone(), xs[me].clone()).unwrap();
            let (o1, _) = exec.forward(p, mk(), &plans).unwrap();
            exec.forward(p, mk(), &plans).unwrap();
            (o1.data, log.lock().unwrap().clone())
        });
        for (party, (out1, ops)) in recorded.outputs.iter().enumerate() {
            assert_eq!(out1, &sync.outputs[party].0, "recorder changed the stream (p{party})");
            assert_eq!(ops.len(), 2 * want.len(), "two passes replay the schedule (p{party})");
            assert_eq!(&ops[..want.len()], &want[..], "pass 1 draws (p{party})");
            assert_eq!(&ops[want.len()..], &want[..], "pass 2 draws (p{party})");
        }

        // Prefetched serving: cycling one batch ahead, bit-identical.
        let pf = run_parties(2, seed, |p| {
            let sched = TripleSchedule::for_forward(&cfg, &plans, batch, p.parties());
            p.enable_prefetch(sched, true);
            let mut exec = pooled_exec();
            let me = p.party();
            let mk = || TensorU64::new(shape.clone(), xs[me].clone()).unwrap();
            let (o1, _) = exec.forward(p, mk(), &plans).unwrap();
            let (o2, _) = exec.forward(p, mk(), &plans).unwrap();
            let st = p.prefetch_stats().expect("prefetcher installed");
            assert_eq!(st.fallback_ops, 0, "online forward expanded PRG material");
            (o1.data, o2.data, p.triple_usage())
        });
        assert_eq!(pf.outputs, sync.outputs, "prefetched forward diverged");
        assert_eq!(
            pf.trace.total_bytes(),
            sync.trace.total_bytes(),
            "prefetched forward changed wire bytes"
        );
        assert_eq!(pf.trace.total_rounds(), sync.trace.total_rounds());
    }

    /// Residual fan-out bookkeeping: a source consumed by two nodes must
    /// survive its first consumer and be recycled after its second.
    #[test]
    fn refcounts_keep_shared_sources_alive() {
        let mut exec = pooled_exec();
        // uses: input=1 (relu1), relu1=2 (add reads it twice), add=1, relu3=1, gap=0.
        assert_eq!(exec.uses, vec![1, 2, 1, 1, 0]);
        // Claim-twice semantics on a fan-out node.
        exec.remaining.copy_from_slice(&exec.uses.clone());
        exec.acts[1] = Some(TensorU64::from_vec(vec![1, 2, 3]));
        let first = exec.claim(1).unwrap();
        assert_eq!(first.data, vec![1, 2, 3]);
        assert!(exec.acts[1].is_some(), "shared source must survive first claim");
        let second = exec.claim(1).unwrap();
        assert_eq!(second.data, vec![1, 2, 3]);
        assert!(exec.acts[1].is_none(), "last claim must move the tensor");
        assert!(exec.claim(1).is_err(), "claims past the refcount must fail");
    }
}
