//! Serving metrics: request latency, throughput, communication, the
//! compute/communication breakdown used by Figs 1 & 10, the fault
//! counters of the degradation path (DESIGN.md §7), and the lifecycle /
//! admission accounting of the overload-safe serving core (DESIGN.md §9).

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::model::ExecBreakdown;
use crate::util::json::Json;
use crate::util::stats;

/// Coordinator lifecycle state (DESIGN.md §9).
///
/// ```text
/// Serving ──breaker trips──▶ Degraded ──probe boots──▶ Serving
///    │                          │
///    └────── shutdown ──────────┴──▶ Draining ──deadline/empty──▶ Stopped
/// ```
///
/// `Stopped` is terminal: [`Metrics::set_state`] refuses to leave it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum LifecycleState {
    /// Admitting and serving requests normally.
    Serving = 0,
    /// Crash-loop breaker open: new requests are answered `Overloaded`
    /// immediately while a background probe retries the session boot.
    Degraded = 1,
    /// Admission closed; queued and in-flight work is being served until
    /// the drain deadline.
    Draining = 2,
    /// All party threads joined; the service will never serve again.
    Stopped = 3,
}

impl LifecycleState {
    fn from_u8(v: u8) -> LifecycleState {
        match v {
            0 => LifecycleState::Serving,
            1 => LifecycleState::Degraded,
            2 => LifecycleState::Draining,
            _ => LifecycleState::Stopped,
        }
    }

    /// Lowercase name, as printed by the serve CLI and `to_json`.
    pub fn as_str(&self) -> &'static str {
        match self {
            LifecycleState::Serving => "serving",
            LifecycleState::Degraded => "degraded",
            LifecycleState::Draining => "draining",
            LifecycleState::Stopped => "stopped",
        }
    }
}

impl std::fmt::Display for LifecycleState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Accumulated serving metrics (thread-safe).
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
    /// `LifecycleState` as u8 — atomic so the admission fast path reads it
    /// without taking the accumulator lock.
    state: AtomicU8,
    /// Gauge of live party threads (incremented at spawn, decremented by a
    /// [`PartyThreadGuard`] drop, so panicking threads still decrement).
    live_party_threads: AtomicU64,
    /// Force-stop deadline while `Draining` (set by `begin_drain`).
    drain_deadline: Mutex<Option<Instant>>,
}

#[derive(Debug, Default)]
struct Inner {
    request_latencies_s: Vec<f64>,
    batch_sizes: Vec<usize>,
    samples_done: u64,
    batches_done: u64,
    breakdown: ExecBreakdown,
    started: Option<Instant>,
    finished: Option<Instant>,
    faults: FaultCounters,
    admission: AdmissionCounters,
}

/// Failure counters of the graceful-degradation path (DESIGN.md §7): a
/// faulted session fails its in-flight batch — counted here — while the
/// coordinator respawns the party session and keeps serving.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct FaultCounters {
    /// Batches that answered their requests with an error because a party
    /// session faulted mid-flight. One failed batch = one increment,
    /// regardless of batch size.
    pub failed_jobs: u64,
    /// Failed batches whose root cause was a deadline expiry
    /// (`Error::Timeout`) — a hung peer, as opposed to a crash.
    pub timeouts: u64,
    /// Transport-level retry attempts absorbed without failing a job
    /// (from `NetStats` on deployments that report them).
    pub retries: u64,
    /// Transport-level reconnects absorbed without failing a job.
    pub reconnects: u64,
    /// Times the coordinator tore down a faulted party session and
    /// spawned a fresh one (including the probe boot that leaves
    /// `Degraded`).
    pub sessions_restarted: u64,
}

/// Per-request disposition counters of the admission/lifecycle layer
/// (DESIGN.md §9). Every **admitted** request receives exactly one
/// terminal disposition from the batcher, so the identity
///
/// ```text
/// admitted == completed + shed_deadline + failed_requests + drained
/// ```
///
/// holds *exactly* once the coordinator reaches `Stopped`
/// ([`MetricsSnapshot::balanced`]). `shed_queue_full` and
/// `rejected_degraded` count refusals **before** admission and sit
/// outside the identity.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct AdmissionCounters {
    /// Requests accepted into the bounded queue.
    pub admitted: u64,
    /// Admitted requests answered with a successful inference result.
    pub completed: u64,
    /// Requests refused at admission because the queue was full
    /// (`Error::Overloaded`). Never admitted.
    pub shed_queue_full: u64,
    /// Requests refused at admission because the coordinator was
    /// `Degraded` (`Error::Overloaded`). Never admitted.
    pub rejected_degraded: u64,
    /// Admitted requests shed by the batcher because their per-request
    /// deadline expired while queued (`Error::Deadline`) — they never
    /// occupied a batch slot.
    pub shed_deadline: u64,
    /// Admitted requests answered with an error: their batch failed on a
    /// session fault, or the coordinator entered `Degraded` after they
    /// were queued.
    pub failed_requests: u64,
    /// Admitted requests answered `Error::Unavailable` because the drain
    /// deadline expired before they could be served.
    pub drained: u64,
}

/// Point-in-time view of the counters, for assertions and dashboards.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricsSnapshot {
    pub samples_done: u64,
    pub batches_done: u64,
    pub faults: FaultCounters,
    pub admission: AdmissionCounters,
    /// Lifecycle state at snapshot time.
    pub state: LifecycleState,
    /// Live party threads at snapshot time (0 after a clean stop).
    pub live_party_threads: u64,
}

impl Default for MetricsSnapshot {
    fn default() -> Self {
        MetricsSnapshot {
            samples_done: 0,
            batches_done: 0,
            faults: FaultCounters::default(),
            admission: AdmissionCounters::default(),
            state: LifecycleState::Serving,
            live_party_threads: 0,
        }
    }
}

impl MetricsSnapshot {
    /// The per-request accounting identity of DESIGN.md §9: every admitted
    /// request got exactly one terminal disposition. The chaos soak
    /// asserts this holds *exactly* after `Stopped`.
    pub fn balanced(&self) -> bool {
        let a = &self.admission;
        a.admitted == a.completed + a.shed_deadline + a.failed_requests + a.drained
    }
}

/// RAII gauge for a live party thread: created on spawn, moved into the
/// thread closure, decrements [`Metrics::live_party_threads`] on drop —
/// including panic unwinds, so the soak's zero-orphans assertion cannot
/// be fooled by a crashed party.
#[derive(Debug)]
pub struct PartyThreadGuard {
    metrics: Arc<Metrics>,
}

impl Drop for PartyThreadGuard {
    fn drop(&mut self) {
        self.metrics.live_party_threads.fetch_sub(1, Ordering::SeqCst);
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Lock the accumulator, recovering from poisoning: metrics must stay
    /// readable even if a thread panicked mid-update (counters are plain
    /// integers/vectors and stay consistent).
    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    // ---- lifecycle -------------------------------------------------------

    /// Current lifecycle state (lock-free).
    pub fn state(&self) -> LifecycleState {
        LifecycleState::from_u8(self.state.load(Ordering::SeqCst))
    }

    /// Transition the lifecycle state. `Stopped` is terminal — once there,
    /// every further transition is ignored.
    pub fn set_state(&self, s: LifecycleState) {
        let _ = self.state.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |cur| {
            if LifecycleState::from_u8(cur) == LifecycleState::Stopped {
                None
            } else {
                Some(s as u8)
            }
        });
    }

    /// Enter `Draining` with a force-stop deadline (no-op once `Stopped`).
    pub fn begin_drain(&self, deadline: Instant) {
        *self.drain_deadline.lock().unwrap_or_else(|e| e.into_inner()) = Some(deadline);
        self.set_state(LifecycleState::Draining);
    }

    /// The force-stop deadline, if a drain has begun.
    pub fn drain_deadline(&self) -> Option<Instant> {
        *self.drain_deadline.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Register a spawned party thread; move the returned guard into the
    /// thread closure so its drop decrements the gauge.
    pub fn party_thread_guard(self: &Arc<Self>) -> PartyThreadGuard {
        self.live_party_threads.fetch_add(1, Ordering::SeqCst);
        PartyThreadGuard { metrics: Arc::clone(self) }
    }

    /// Live party threads right now (0 after a clean stop).
    pub fn live_party_threads(&self) -> u64 {
        self.live_party_threads.load(Ordering::SeqCst)
    }

    // ---- admission / disposition ----------------------------------------

    /// A request was accepted into the bounded queue.
    pub fn record_admitted(&self) {
        self.lock().admission.admitted += 1;
    }

    /// A request was refused at admission: the queue was full.
    pub fn record_shed_queue_full(&self) {
        self.lock().admission.shed_queue_full += 1;
    }

    /// A request was refused at admission: the coordinator is `Degraded`.
    pub fn record_rejected_degraded(&self) {
        self.lock().admission.rejected_degraded += 1;
    }

    /// `n` queued requests were shed at dequeue because their per-request
    /// deadline had expired.
    pub fn record_shed_deadline(&self, n: u64) {
        self.lock().admission.shed_deadline += n;
    }

    /// `n` queued requests were answered `Unavailable` because the drain
    /// deadline expired before they could be served.
    pub fn record_drained(&self, n: u64) {
        self.lock().admission.drained += n;
    }

    /// `n` already-admitted requests were answered with an error outside a
    /// batch (e.g. the coordinator entered `Degraded` while they were
    /// queued). Keeps the §9 identity exact without counting a failed
    /// batch.
    pub fn record_failed_requests(&self, n: u64) {
        self.lock().admission.failed_requests += n;
    }

    pub fn mark_start(&self) {
        let mut m = self.lock();
        if m.started.is_none() {
            m.started = Some(Instant::now());
        }
    }

    pub fn record_batch(&self, batch: usize, latency_s: f64, bd: &ExecBreakdown) {
        let mut m = self.lock();
        m.batch_sizes.push(batch);
        m.samples_done += batch as u64;
        m.batches_done += 1;
        m.admission.completed += batch as u64;
        m.breakdown.add(bd);
        m.finished = Some(Instant::now());
        for _ in 0..batch {
            m.request_latencies_s.push(latency_s);
        }
    }

    /// A batch of `requests` failed: a party session faulted and every
    /// request in it was answered with an error. One failed batch = one
    /// `failed_jobs` increment; each member counts into
    /// `failed_requests` so the §9 identity stays exact. `was_timeout`
    /// marks a deadline-expiry root cause (vs. a crash/link fault).
    pub fn record_failed_batch(&self, requests: u64, was_timeout: bool) {
        let mut m = self.lock();
        m.faults.failed_jobs += 1;
        m.admission.failed_requests += requests;
        if was_timeout {
            m.faults.timeouts += 1;
        }
    }

    /// The coordinator replaced a faulted party session with a fresh one.
    pub fn record_session_restart(&self) {
        self.lock().faults.sessions_restarted += 1;
    }

    /// Fold in transport-level recovery counters (retries/reconnects that
    /// were absorbed without failing a job).
    pub fn record_net_recovery(&self, retries: u64, reconnects: u64) {
        let mut m = self.lock();
        m.faults.retries += retries;
        m.faults.reconnects += reconnects;
    }

    /// Assertable point-in-time counters (the chaos suite pins these).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.lock();
        MetricsSnapshot {
            samples_done: m.samples_done,
            batches_done: m.batches_done,
            faults: m.faults,
            admission: m.admission,
            state: self.state(),
            live_party_threads: self.live_party_threads(),
        }
    }

    pub fn samples_done(&self) -> u64 {
        self.lock().samples_done
    }

    /// Wall-clock between first and last batch.
    pub fn wall_seconds(&self) -> f64 {
        let m = self.lock();
        match (m.started, m.finished) {
            (Some(s), Some(f)) => f.duration_since(s).as_secs_f64(),
            _ => 0.0,
        }
    }

    pub fn throughput(&self) -> f64 {
        let w = self.wall_seconds();
        if w <= 0.0 {
            0.0
        } else {
            self.samples_done() as f64 / w
        }
    }

    pub fn breakdown(&self) -> ExecBreakdown {
        self.lock().breakdown
    }

    pub fn to_json(&self) -> Json {
        let m = self.lock();
        Json::obj(vec![
            ("samples", Json::Int(m.samples_done as i64)),
            ("batches", Json::Int(m.batches_done as i64)),
            ("p50_latency_s", Json::Num(stats::median(&m.request_latencies_s))),
            ("p95_latency_s", Json::Num(stats::percentile(&m.request_latencies_s, 95.0))),
            ("linear_s", Json::Num(m.breakdown.linear_s)),
            ("relu_s", Json::Num(m.breakdown.relu_s)),
            ("other_s", Json::Num(m.breakdown.other_s)),
            ("failed_jobs", Json::Int(m.faults.failed_jobs as i64)),
            ("timeouts", Json::Int(m.faults.timeouts as i64)),
            ("retries", Json::Int(m.faults.retries as i64)),
            ("reconnects", Json::Int(m.faults.reconnects as i64)),
            ("sessions_restarted", Json::Int(m.faults.sessions_restarted as i64)),
            ("state", Json::str(self.state().as_str())),
            ("admitted", Json::Int(m.admission.admitted as i64)),
            ("completed", Json::Int(m.admission.completed as i64)),
            ("shed_queue_full", Json::Int(m.admission.shed_queue_full as i64)),
            ("rejected_degraded", Json::Int(m.admission.rejected_degraded as i64)),
            ("shed_deadline", Json::Int(m.admission.shed_deadline as i64)),
            ("failed_requests", Json::Int(m.admission.failed_requests as i64)),
            ("drained", Json::Int(m.admission.drained as i64)),
            ("live_party_threads", Json::Int(self.live_party_threads() as i64)),
        ])
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let m = Metrics::new();
        m.mark_start();
        let bd = ExecBreakdown { linear_s: 0.5, relu_s: 1.0, other_s: 0.1 };
        m.record_batch(4, 0.2, &bd);
        m.record_batch(2, 0.4, &bd);
        assert_eq!(m.samples_done(), 6);
        let total = m.breakdown();
        assert!((total.relu_s - 2.0).abs() < 1e-12);
        let j = m.to_json();
        assert_eq!(j.get_i64("batches").unwrap(), 2);
    }

    /// The fault counters are independent of the throughput counters and
    /// show up in both the snapshot and the JSON export.
    #[test]
    fn fault_counters_snapshot() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().faults, FaultCounters::default());
        m.record_failed_batch(1, false);
        m.record_failed_batch(1, true);
        m.record_session_restart();
        m.record_net_recovery(3, 1);
        let s = m.snapshot();
        assert_eq!(s.faults.failed_jobs, 2);
        assert_eq!(s.faults.timeouts, 1);
        assert_eq!(s.faults.retries, 3);
        assert_eq!(s.faults.reconnects, 1);
        assert_eq!(s.faults.sessions_restarted, 1);
        assert_eq!(s.samples_done, 0, "failures must not count as served samples");
        let j = m.to_json();
        assert_eq!(j.get_i64("failed_jobs").unwrap(), 2);
        assert_eq!(j.get_i64("sessions_restarted").unwrap(), 1);
    }

    /// The lifecycle state machine: free transitions between live states,
    /// `Stopped` terminal.
    #[test]
    fn lifecycle_state_machine() {
        let m = Metrics::new();
        assert_eq!(m.state(), LifecycleState::Serving);
        m.set_state(LifecycleState::Degraded);
        assert_eq!(m.state(), LifecycleState::Degraded);
        m.set_state(LifecycleState::Serving);
        m.begin_drain(Instant::now());
        assert_eq!(m.state(), LifecycleState::Draining);
        assert!(m.drain_deadline().is_some());
        m.set_state(LifecycleState::Stopped);
        m.set_state(LifecycleState::Serving);
        assert_eq!(m.state(), LifecycleState::Stopped, "Stopped must be terminal");
        assert_eq!(m.to_json().get_str("state").unwrap(), "stopped");
    }

    /// Every admitted request gets exactly one terminal disposition; the
    /// §9 identity holds and the pre-admission refusals sit outside it.
    #[test]
    fn admission_identity() {
        let m = Metrics::new();
        for _ in 0..10 {
            m.record_admitted();
        }
        m.record_shed_queue_full();
        m.record_rejected_degraded();
        let bd = ExecBreakdown::default();
        m.record_batch(4, 0.1, &bd); // 4 completed
        m.record_shed_deadline(2);
        m.record_failed_batch(2, false);
        m.record_failed_requests(1);
        m.record_drained(1);
        let s = m.snapshot();
        assert_eq!(s.admission.admitted, 10);
        assert_eq!(s.admission.completed, 4);
        assert_eq!(s.admission.shed_deadline, 2);
        assert_eq!(s.admission.failed_requests, 3);
        assert_eq!(s.admission.drained, 1);
        assert!(s.balanced(), "identity must hold: {:?}", s.admission);
        assert_eq!(s.admission.shed_queue_full, 1);
        assert_eq!(s.admission.rejected_degraded, 1);
        m.record_admitted();
        assert!(!m.snapshot().balanced(), "an undisposed admit must unbalance");
    }

    /// The live-thread gauge decrements on guard drop, panics included.
    #[test]
    fn party_thread_gauge() {
        let m = Arc::new(Metrics::new());
        let g1 = m.party_thread_guard();
        let g2 = m.party_thread_guard();
        assert_eq!(m.live_party_threads(), 2);
        drop(g1);
        assert_eq!(m.live_party_threads(), 1);
        let m2 = Arc::clone(&m);
        let h = std::thread::spawn(move || {
            let _g = m2.party_thread_guard();
            panic!("simulated party crash");
        });
        assert!(h.join().is_err());
        assert_eq!(m.live_party_threads(), 1, "panicking thread must still decrement");
        drop(g2);
        assert_eq!(m.snapshot().live_party_threads, 0);
    }
}
