//! Scratch arena: a size-classed buffer pool for the serving hot path.
//!
//! Every per-round temporary the protocol engine needs — masked openings,
//! triple shares, opened values, Kogge–Stone stage operands, wire byte
//! buffers — is checked out of this pool and returned when the step
//! finishes. Buffers are bucketed by their **exact requested size**, and a
//! protocol step always requests the same sizes in the same order for a
//! given input shape. That makes the steady-state guarantee provable: the
//! warmup round allocates one buffer per size class per peak-concurrency
//! slot, and every later round replays the identical checkout sequence, so
//! each checkout finds a free buffer in its class — **zero heap
//! allocations** per steady-state round.
//!
//! The same pool type backs all three allocation-free layers of the stack:
//! the GMW engine's round temporaries (`gmw::GmwParty`), the local
//! transport's circulating send payloads (`net::local::LocalTransport`)
//! and the share executor's activation buffers (`model::ShareExecutor`).
//!
//! # Ownership rules
//!
//! * One arena per owner (party engine / transport endpoint / executor),
//!   same thread as its owner (no locking).
//! * `take_*` transfers ownership of a plain `Vec` to the caller, so
//!   checked-out buffers borrow-check like any local and can be passed to
//!   kernels, the transport and `&mut self` protocol methods freely.
//! * Callers return buffers with `put_*` when the protocol step that
//!   checked them out completes, **without changing their length** (the
//!   length is the bucket key). Buffers escaping on early error returns
//!   merely shrink the pool — correctness is unaffected.
//! * Buffers live across rounds but never cross parties or threads;
//!   parallel kernels borrow slices of a checked-out buffer, they never
//!   check out their own.
//!
//! [`ArenaStats`] counts checkouts, returns and allocation misses; the
//! harness test `relu_steady_state_is_allocation_free` pins the
//! zero-allocation claim by asserting that a warmed `relu_into` round adds
//! no misses and balances checkouts against returns.

use std::collections::BTreeMap;

/// Counters describing arena traffic (monotonic over the arena's life).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ArenaStats {
    /// Buffers handed out by `take_words` / `take_bytes`.
    pub checkouts: u64,
    /// Buffers given back via `put_words` / `put_bytes`.
    pub returns: u64,
    /// Checkouts that had to allocate (no free buffer in the size class).
    pub alloc_misses: u64,
}

/// Pool of reusable `Vec<u64>` lane buffers and `Vec<u8>` wire buffers,
/// bucketed by exact requested size.
#[derive(Debug, Default)]
pub struct Arena {
    words: BTreeMap<usize, Vec<Vec<u64>>>,
    bytes: BTreeMap<usize, Vec<Vec<u8>>>,
    pooled_words: usize,
    pooled_bytes: usize,
    stats: ArenaStats,
}

/// Upper bound on pooled buffers per kind; excess returns are dropped so a
/// one-off huge batch cannot pin memory forever.
const MAX_POOLED: usize = 256;

impl Arena {
    pub fn new() -> Self {
        Arena::default()
    }

    /// Check out a `u64` buffer of exactly `len` elements. **Contents are
    /// unspecified** (stale data from the previous round on the warm path):
    /// every engine call site fully overwrites its buffer, so the arena
    /// does not pay a memset per checkout. Callers that need zeros must
    /// zero themselves (as `reshare_binary_into` does).
    pub fn take_words(&mut self, len: usize) -> Vec<u64> {
        self.stats.checkouts += 1;
        if let Some(b) = self.words.get_mut(&len).and_then(|bucket| bucket.pop()) {
            self.pooled_words -= 1;
            return b;
        }
        self.stats.alloc_misses += 1;
        vec![0u64; len]
    }

    /// Return a `u64` buffer to the pool (length must be unchanged since
    /// `take_words` — it is the bucket key).
    pub fn put_words(&mut self, buf: Vec<u64>) {
        self.stats.returns += 1;
        if self.pooled_words < MAX_POOLED && !buf.is_empty() {
            self.pooled_words += 1;
            self.words.entry(buf.len()).or_default().push(buf);
        }
    }

    /// Check out a byte buffer for a wire payload of `len` bytes. On the
    /// warm path the buffer comes back still sized to `len` from its last
    /// round (stale contents — the packer/serializer overwrites every byte
    /// and its resize-to-wire-size is then a no-op, avoiding a memset per
    /// round); on a miss it is empty with capacity `len`.
    pub fn take_bytes(&mut self, len: usize) -> Vec<u8> {
        self.stats.checkouts += 1;
        if let Some(b) = self.bytes.get_mut(&len).and_then(|bucket| bucket.pop()) {
            self.pooled_bytes -= 1;
            return b;
        }
        self.stats.alloc_misses += 1;
        Vec::with_capacity(len)
    }

    /// Return a byte buffer to the pool; its current length (the wire size
    /// it was filled to) is the bucket key.
    pub fn put_bytes(&mut self, buf: Vec<u8>) {
        self.stats.returns += 1;
        if self.pooled_bytes < MAX_POOLED && !buf.is_empty() {
            self.pooled_bytes += 1;
            self.bytes.entry(buf.len()).or_default().push(buf);
        }
    }

    /// Snapshot of the traffic counters.
    pub fn stats(&self) -> ArenaStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warm_pool_stops_allocating() {
        let mut a = Arena::new();
        // Cold: every take is a miss.
        let b1 = a.take_words(100);
        let b2 = a.take_words(200);
        assert_eq!(a.stats().alloc_misses, 2);
        a.put_words(b1);
        a.put_words(b2);
        // Warm: same shapes come from the pool.
        let b1 = a.take_words(100);
        let b2 = a.take_words(200);
        let s = a.stats();
        assert_eq!(s.alloc_misses, 2, "warm takes must not allocate");
        assert_eq!(s.checkouts, 4);
        a.put_words(b1);
        a.put_words(b2);
        assert_eq!(a.stats().returns, 4);
    }

    #[test]
    fn take_words_preserves_length_not_contents() {
        let mut a = Arena::new();
        let mut b = a.take_words(8);
        assert!(b.iter().all(|v| *v == 0), "cold take is freshly allocated");
        b.iter_mut().for_each(|v| *v = u64::MAX);
        a.put_words(b);
        // Warm take: correct length, contents unspecified (no memset).
        let b = a.take_words(8);
        assert_eq!(b.len(), 8);
    }

    /// Size classes never cross: a small request must not consume a big
    /// buffer that a later (steady-state) big request depends on.
    #[test]
    fn size_classes_are_exact() {
        let mut a = Arena::new();
        let big = a.take_words(1000);
        a.put_words(big);
        let small = a.take_words(10);
        assert_eq!(a.stats().alloc_misses, 2, "small take must not steal the big buffer");
        a.put_words(small);
        let big = a.take_words(1000);
        assert_eq!(a.stats().alloc_misses, 2, "big class must still be warm");
        assert_eq!(big.len(), 1000);
        a.put_words(big);
    }

    #[test]
    fn byte_pool_keys_on_filled_length() {
        let mut a = Arena::new();
        let mut b = a.take_bytes(4096);
        b.resize(4096, 7); // simulate the packer filling the wire buffer
        a.put_bytes(b);
        // Warm take: still sized to the wire length (packer's resize is a
        // no-op), no allocation.
        let b = a.take_bytes(4096);
        assert_eq!(b.len(), 4096);
        assert_eq!(a.stats().alloc_misses, 1);
        a.put_bytes(b);
    }

    /// Interleaved takes/puts replaying the same sequence twice never miss
    /// on the second pass (the steady-state argument in miniature).
    #[test]
    fn replayed_sequence_is_miss_free() {
        let mut a = Arena::new();
        let sequence = |a: &mut Arena| {
            let x = a.take_words(16);
            let y = a.take_words(32);
            let z = a.take_words(16);
            a.put_words(y);
            let w = a.take_words(32);
            a.put_words(x);
            a.put_words(z);
            a.put_words(w);
        };
        sequence(&mut a);
        let warm = a.stats();
        sequence(&mut a);
        let s = a.stats();
        assert_eq!(s.alloc_misses, warm.alloc_misses);
        assert_eq!(s.checkouts, s.returns);
    }
}
