//! Explicit AVX2 kernels for the hot bit-plane loops (DESIGN.md §11).
//!
//! The portable loops in [`super::kernels`] and [`super::bitsliced`] are
//! written so LLVM *can* autovectorize them, but the codegen is at the
//! mercy of the default `x86-64` baseline (SSE2). This module provides the
//! same inner loops as explicit AVX2 intrinsics — 4 × u64 per instruction —
//! selected at **runtime** via [`available`] (an `is_x86_feature_detected!`
//! probe, cached per process), so one generic binary uses AVX2 where the
//! CPU has it and falls back to the portable loops everywhere else.
//!
//! # Dispatch contract
//!
//! Every public function here is a *safe* wrapper returning `bool`:
//! `true` means the AVX2 arm ran and the output is complete; `false` means
//! nothing was touched and the caller must run its scalar path. Callers
//! ([`super::kernels`]'s backends, [`super::bitsliced`]'s transpose sites)
//! gate on the resolved [`super::kernels::KernelChoice`] and the
//! [`crate::util::tuning::simd_min_words`] floor, so forced-scalar runs
//! (`--kernel scalar` / `HB_KERNEL=scalar`) never enter this module and
//! machines without AVX2 lose nothing but speed. Bit-for-bit equality of
//! the two arms is pinned by `tests/kernel_diff.rs` and the in-module
//! tests below.
//!
//! # Safety rationale (the `// SAFETY:` wall, hblint rule S)
//!
//! Three intrinsic families are used, each with one proof obligation:
//!
//! * **Unaligned load/store** (`_mm256_loadu_si256` / `_mm256_storeu_si256`)
//!   — require only that the 32-byte window be in-bounds of the slice.
//!   Every loop processes `len - len % 4` words in exact 4-word steps after
//!   asserting the slice lengths, so `i + 4 <= len` at every access; the
//!   `loadu`/`storeu` forms have no alignment requirement.
//! * **Lane-wise logic/shift** (`_mm256_{xor,and,sll,srl}_…`,
//!   `_mm256_set1_epi64x`, `_mm_cvtsi64_si128`) — operate on register
//!   values only; they are `unsafe` purely because they require the AVX2
//!   (resp. SSE2) target feature.
//! * **`#[target_feature(enable = "avx2")]`** — calling such a function is
//!   sound iff the CPU actually has AVX2. Every call site is guarded by
//!   [`available`], which caches a runtime `is_x86_feature_detected!`
//!   probe; there is no other path into the `avx2` module.
//!
//! The in-place [`transpose64`] additionally relies on the two 4-word
//! windows of each butterfly being disjoint: the vectorized passes have
//! `s ∈ {32, 16, 8, 4}` and pair `a[k..k+4]` with `a[k+s..k+s+4]`, so the
//! windows are `s ≥ 4` words apart. The final `s ∈ {2, 1}` passes run
//! scalar (their butterflies interleave below register width).
//!
//! # Miri
//!
//! [`available`] is compiled to return `false` under Miri, so interpreted
//! runs always take the portable arm — the dispatch *logic* is still
//! exercised (see the `*_miri_sized` tests below), while the intrinsics
//! themselves are vouched for by the native differential sweeps.

#[cfg(all(target_arch = "x86_64", not(miri)))]
fn detect() -> bool {
    is_x86_feature_detected!("avx2")
}

#[cfg(any(not(target_arch = "x86_64"), miri))]
fn detect() -> bool {
    false
}

/// True when the AVX2 arm can run on this CPU (runtime-detected once and
/// cached; always `false` off x86-64 and under Miri). This is the *only*
/// gate the `unsafe` intrinsic paths rely on — see the module docs.
pub fn available() -> bool {
    static AVX2: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *AVX2.get_or_init(detect)
}

/// AVX2 `out[i] = x[i] ^ y[i]` over `out.len()` words. Returns `false`
/// (output untouched) when AVX2 is unavailable. `x`/`y` may be longer than
/// `out` (the threaded kernels pass suffix slices).
pub fn xor_into(out: &mut [u64], x: &[u64], y: &[u64]) -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        if available() {
            assert!(x.len() >= out.len() && y.len() >= out.len());
            // SAFETY: AVX2 verified by `available()`; slice bounds asserted
            // above cover every 4-word window the callee touches.
            unsafe { avx2::xor_into(out, x, y) };
            return true;
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = (out, x, y);
    false
}

/// AVX2 Beaver-AND combine:
/// `out[i] = [leader](d[i] & e[i]) ^ (d[i] & b[i]) ^ (e[i] & a[i]) ^ c[i]`.
/// Returns `false` (output untouched) when AVX2 is unavailable.
pub fn and_combine_into(
    out: &mut [u64],
    d: &[u64],
    e: &[u64],
    a: &[u64],
    b: &[u64],
    c: &[u64],
    leader: bool,
) -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        if available() {
            let n = out.len();
            assert!(
                d.len() >= n && e.len() >= n && a.len() >= n && b.len() >= n && c.len() >= n
            );
            // SAFETY: AVX2 verified by `available()`; slice bounds asserted
            // above cover every 4-word window the callee touches.
            unsafe { avx2::and_combine_into(out, d, e, a, b, c, leader) };
            return true;
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = (out, d, e, a, b, c, leader);
    false
}

/// AVX2 lane shift-and-mask: `out[i] = (src[i] << s) & mask` — the
/// Kogge–Stone `v`-operand build in the lane-per-u64 layout. Requires
/// `s < 64` (as the scalar path does). Returns `false` (output untouched)
/// when AVX2 is unavailable.
pub fn shl_mask_into(out: &mut [u64], src: &[u64], s: u32, mask: u64) -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        if available() {
            debug_assert!(s < 64);
            assert!(src.len() >= out.len());
            // SAFETY: AVX2 verified by `available()`; slice bounds asserted
            // above cover every 4-word window the callee touches.
            unsafe { avx2::shl_mask_into(out, src, s, mask) };
            return true;
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = (out, src, s, mask);
    false
}

/// AVX2 in-place 64×64 bit-matrix transpose, bit-identical to the scalar
/// [`super::bitsliced::transpose64`] (Hacker's Delight §7-3): the
/// `s ∈ {32, 16, 8, 4}` butterfly passes run 4 rows per instruction, the
/// final `s ∈ {2, 1}` passes run scalar. Returns `false` (matrix
/// untouched) when AVX2 is unavailable.
pub fn transpose64(a: &mut [u64; 64]) -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        if available() {
            // SAFETY: AVX2 verified by `available()`; the callee only
            // touches in-bounds 4-word windows of the fixed 64-word array.
            unsafe { avx2::transpose64(a) };
            return true;
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = a;
    false
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! The intrinsic bodies. Nothing in here is reachable without passing
    //! the [`super::available`] gate — see the module-level safety
    //! rationale (DESIGN.md §11).

    use core::arch::x86_64::{
        __m256i, _mm256_and_si256, _mm256_loadu_si256, _mm256_set1_epi64x, _mm256_sll_epi64,
        _mm256_srl_epi64, _mm256_storeu_si256, _mm256_xor_si256, _mm_cvtsi64_si128,
    };

    #[target_feature(enable = "avx2")]
    // SAFETY: caller contract — AVX2 support verified and
    // `x.len() >= out.len()` and `y.len() >= out.len()`.
    pub(super) unsafe fn xor_into(out: &mut [u64], x: &[u64], y: &[u64]) {
        let n = out.len();
        let main = n - n % 4;
        let mut i = 0;
        while i < main {
            // SAFETY: i + 4 <= main <= n and the caller asserted
            // x.len(), y.len() >= n; unaligned load/store.
            unsafe {
                let xv = _mm256_loadu_si256(x.as_ptr().add(i).cast::<__m256i>());
                let yv = _mm256_loadu_si256(y.as_ptr().add(i).cast::<__m256i>());
                let o = out.as_mut_ptr().add(i).cast::<__m256i>();
                _mm256_storeu_si256(o, _mm256_xor_si256(xv, yv));
            }
            i += 4;
        }
        for k in main..n {
            out[k] = x[k] ^ y[k];
        }
    }

    #[target_feature(enable = "avx2")]
    // SAFETY: caller contract — AVX2 support verified and every input
    // slice is at least `out.len()` long.
    pub(super) unsafe fn and_combine_into(
        out: &mut [u64],
        d: &[u64],
        e: &[u64],
        a: &[u64],
        b: &[u64],
        c: &[u64],
        leader: bool,
    ) {
        let n = out.len();
        let main = n - n % 4;
        // All-ones when leader: the d∧e term is folded in branch-free by
        // masking it with this register (zero ⇒ XOR no-op).
        // SAFETY: register-only lane op; AVX2 verified by the caller.
        let lead = unsafe { _mm256_set1_epi64x(if leader { -1 } else { 0 }) };
        let mut i = 0;
        while i < main {
            // SAFETY: i + 4 <= main <= n and the caller asserted all input
            // slices are >= n words; unaligned load/store.
            unsafe {
                let dv = _mm256_loadu_si256(d.as_ptr().add(i).cast::<__m256i>());
                let ev = _mm256_loadu_si256(e.as_ptr().add(i).cast::<__m256i>());
                let av = _mm256_loadu_si256(a.as_ptr().add(i).cast::<__m256i>());
                let bv = _mm256_loadu_si256(b.as_ptr().add(i).cast::<__m256i>());
                let cv = _mm256_loadu_si256(c.as_ptr().add(i).cast::<__m256i>());
                let de = _mm256_and_si256(_mm256_and_si256(dv, ev), lead);
                let z = _mm256_xor_si256(
                    _mm256_xor_si256(de, _mm256_and_si256(dv, bv)),
                    _mm256_xor_si256(_mm256_and_si256(ev, av), cv),
                );
                _mm256_storeu_si256(out.as_mut_ptr().add(i).cast::<__m256i>(), z);
            }
            i += 4;
        }
        let lead_s = if leader { u64::MAX } else { 0 };
        for k in main..n {
            out[k] = (d[k] & e[k] & lead_s) ^ (d[k] & b[k]) ^ (e[k] & a[k]) ^ c[k];
        }
    }

    #[target_feature(enable = "avx2")]
    // SAFETY: caller contract — AVX2 support verified,
    // `src.len() >= out.len()`, and `s < 64`.
    pub(super) unsafe fn shl_mask_into(out: &mut [u64], src: &[u64], s: u32, mask: u64) {
        let n = out.len();
        let main = n - n % 4;
        // SAFETY: register-only lane ops; AVX2 verified by the caller.
        let (mv, sh) = unsafe { (_mm256_set1_epi64x(mask as i64), _mm_cvtsi64_si128(s as i64)) };
        let mut i = 0;
        while i < main {
            // SAFETY: i + 4 <= main <= n and the caller asserted
            // src.len() >= n; unaligned load/store.
            unsafe {
                let v = _mm256_loadu_si256(src.as_ptr().add(i).cast::<__m256i>());
                let shifted = _mm256_and_si256(_mm256_sll_epi64(v, sh), mv);
                _mm256_storeu_si256(out.as_mut_ptr().add(i).cast::<__m256i>(), shifted);
            }
            i += 4;
        }
        for k in main..n {
            out[k] = (src[k] << s) & mask;
        }
    }

    #[target_feature(enable = "avx2")]
    // SAFETY: caller contract — AVX2 support verified. All accesses are
    // in-bounds 4-word windows of the fixed `[u64; 64]`.
    pub(super) unsafe fn transpose64(a: &mut [u64; 64]) {
        // Butterfly passes s = 32, 16, 8, 4 (masks per Hacker's Delight
        // §7-3): the row indices with bit log2(s) clear come in runs of s
        // consecutive values, so each pass is 4-wide vectorizable.
        const PASSES: [(usize, u64); 4] = [
            (32, 0x0000_0000_FFFF_FFFF),
            (16, 0x0000_FFFF_0000_FFFF),
            (8, 0x00FF_00FF_00FF_00FF),
            (4, 0x0F0F_0F0F_0F0F_0F0F),
        ];
        for (s, m) in PASSES {
            // SAFETY: register-only lane ops; AVX2 verified by the caller.
            let (mv, sh) = unsafe { (_mm256_set1_epi64x(m as i64), _mm_cvtsi64_si128(s as i64)) };
            let mut base = 0usize;
            while base < 64 {
                let mut k = base;
                while k < base + s {
                    // SAFETY: k + 4 <= base + s and k + s + 4 <= base + 2s
                    // <= 64, so both 4-word windows are in-bounds; they are
                    // s >= 4 words apart, hence disjoint, and both loads
                    // happen before either store.
                    unsafe {
                        let pk = a.as_mut_ptr().add(k);
                        let ps = a.as_mut_ptr().add(k + s);
                        let hi = _mm256_loadu_si256(pk.cast::<__m256i>());
                        let lo = _mm256_loadu_si256(ps.cast::<__m256i>());
                        let t = _mm256_and_si256(
                            _mm256_xor_si256(_mm256_srl_epi64(hi, sh), lo),
                            mv,
                        );
                        _mm256_storeu_si256(ps.cast::<__m256i>(), _mm256_xor_si256(lo, t));
                        let back = _mm256_xor_si256(hi, _mm256_sll_epi64(t, sh));
                        _mm256_storeu_si256(pk.cast::<__m256i>(), back);
                    }
                    k += 4;
                }
                base += 2 * s;
            }
        }
        // Final passes s = 2, 1: butterflies interleave below register
        // width — scalar, same recurrence as the portable transpose.
        for (s, m) in [(2usize, 0x3333_3333_3333_3333u64), (1, 0x5555_5555_5555_5555)] {
            let mut k = 0usize;
            while k < 64 {
                let t = ((a[k] >> s) ^ a[k + s]) & m;
                a[k] ^= t << s;
                a[k + s] ^= t;
                k = (k + s + 1) & !s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::prg::Prg;
    use crate::gmw::bitsliced;

    /// The detection probe is cached and consistent; under Miri it is
    /// pinned `false` so interpreted runs stay on the portable arm.
    #[test]
    fn availability_is_stable() {
        assert_eq!(available(), available());
        #[cfg(miri)]
        assert!(!available(), "Miri must always take the scalar arm");
        #[cfg(not(target_arch = "x86_64"))]
        assert!(!available(), "non-x86 must always take the scalar arm");
    }

    /// Every wrapper either runs (and then must match the scalar
    /// reference bit-for-bit) or leaves the output untouched.
    #[test]
    fn wrappers_match_scalar_reference() {
        let mut prg = Prg::new(0xA2C2, 1);
        for n in [0usize, 1, 3, 4, 5, 7, 8, 33, 100] {
            let d = prg.vec_u64(n);
            let e = prg.vec_u64(n);
            let a = prg.vec_u64(n);
            let b = prg.vec_u64(n);
            let c = prg.vec_u64(n);

            let mut out = vec![0u64; n];
            let ran = xor_into(&mut out, &d, &e);
            assert_eq!(ran, available());
            if ran {
                let naive: Vec<u64> = d.iter().zip(&e).map(|(x, y)| x ^ y).collect();
                assert_eq!(out, naive, "xor n={n}");
            }

            for leader in [false, true] {
                let mut out = vec![0u64; n];
                if and_combine_into(&mut out, &d, &e, &a, &b, &c, leader) {
                    let naive: Vec<u64> = (0..n)
                        .map(|i| {
                            let mut z = (d[i] & b[i]) ^ (e[i] & a[i]) ^ c[i];
                            if leader {
                                z ^= d[i] & e[i];
                            }
                            z
                        })
                        .collect();
                    assert_eq!(out, naive, "and_combine n={n} leader={leader}");
                }
            }

            for (s, w) in [(1u32, 6u32), (2, 20), (16, 64)] {
                let mask = crate::ring::low_mask(w);
                let mut out = vec![0u64; n];
                if shl_mask_into(&mut out, &d, s, mask) {
                    let naive: Vec<u64> = d.iter().map(|x| (x << s) & mask).collect();
                    assert_eq!(out, naive, "shl n={n} s={s} w={w}");
                }
            }
        }
    }

    /// The AVX2 transpose agrees with the scalar Hacker's Delight
    /// transpose and stays an involution.
    #[test]
    fn transpose_matches_scalar_and_is_involution() {
        let mut prg = Prg::new(0x7A0, 5);
        for trial in 0..8 {
            let mut a = [0u64; 64];
            for v in a.iter_mut() {
                *v = prg.next_u64();
            }
            let mut simd = a;
            let mut scalar = a;
            bitsliced::transpose64(&mut scalar);
            if transpose64(&mut simd) {
                assert_eq!(simd, scalar, "trial {trial}");
                assert!(transpose64(&mut simd));
                assert_eq!(simd, a, "transpose must be an involution");
            } else {
                assert_eq!(simd, a, "a skipped dispatch must not touch the matrix");
            }
        }
    }

    /// Suffix-sliced inputs (the threaded kernels hand `&x[off..]` slices
    /// longer than `out`) are read from the front, like the scalar path.
    #[test]
    fn wrappers_accept_longer_inputs() {
        let x: Vec<u64> = (0..10).map(|i| i * 3 + 1).collect();
        let y: Vec<u64> = (0..10).map(|i| i * 7 + 5).collect();
        let mut out = vec![0u64; 6];
        if xor_into(&mut out, &x, &y) {
            for (i, o) in out.iter().enumerate() {
                assert_eq!(*o, x[i] ^ y[i]);
            }
        }
    }

    /// Miri-sized replica (PR 7 convention): under the interpreter the
    /// dispatch must *cleanly refuse* — outputs untouched, `false`
    /// returned — which is exactly the contract the scalar fallback in
    /// `gmw::kernels` relies on. Natively this doubles as a tiny
    /// smoke-run of every wrapper.
    #[test]
    fn dispatch_contract_miri_sized() {
        let x = [1u64, 2, 3, 4, 5];
        let y = [9u64, 8, 7, 6, 5];
        let mut out = [0u64; 5];
        let ran = xor_into(&mut out, &x, &y);
        assert_eq!(ran, available());
        if !ran {
            assert_eq!(out, [0u64; 5], "skipped dispatch must leave the output alone");
        }
        let mut m = [0u64; 64];
        m[0] = u64::MAX;
        let ran = transpose64(&mut m);
        assert_eq!(ran, available());
        if ran {
            // Row 0 all-ones transposes to column 0: every row = 1.
            assert!(m.iter().all(|v| *v == 1));
        } else {
            assert_eq!(m[0], u64::MAX);
        }
    }
}
