//! Offline stand-in for the [`loom`](https://docs.rs/loom) model checker.
//!
//! The dev containers build fully offline, so the real crates.io `loom`
//! cannot be added. This shim exposes the subset of loom's API that the
//! crate's `#[cfg(loom)]` models use (`model`, `thread`, `sync`), backed
//! directly by `std`: [`model`] runs its closure **once** with real OS
//! threads instead of exhaustively exploring interleavings.
//!
//! The models therefore degrade to deterministic concurrency smoke tests
//! offline while staying *source-compatible* with the real checker: on a
//! networked checkout, point the `[target.'cfg(loom)'.dependencies]` entry
//! in `rust/Cargo.toml` at crates.io (`loom = "0.7"`) and the very same
//! tests become exhaustive interleaving searches. Keep this shim's surface
//! in sync with what the models import — it compiles against the same
//! names loom 0.7 exports, and nothing else.

/// Run `f` under the "model": the real loom explores every interleaving of
/// the loom-typed operations inside; this shim executes it once.
pub fn model<F>(f: F)
where
    F: Fn() + Sync + Send + 'static,
{
    f();
}

/// Mirror of `loom::thread` (std-backed).
pub mod thread {
    pub use std::thread::{spawn, yield_now, JoinHandle};
}

/// Mirror of `loom::sync` (std-backed): the checked twins of the std
/// primitives the models exercise.
pub mod sync {
    pub use std::sync::{Arc, Condvar, Mutex, MutexGuard};

    /// Mirror of `loom::sync::atomic`.
    pub mod atomic {
        pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
    }
}

#[cfg(test)]
mod tests {
    /// The shim's `model` must actually run the closure (a no-op stub
    /// would silently turn every loom model green).
    #[test]
    fn model_executes_closure() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static RAN: AtomicUsize = AtomicUsize::new(0);
        super::model(|| {
            RAN.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(RAN.load(Ordering::SeqCst), 1);
    }
}
