//! Criterion-style micro/e2e benchmark harness (criterion is not available
//! offline). Used by the `[[bench]]` targets with `harness = false`.
//!
//! Features: warmup, adaptive iteration count targeting a measurement time,
//! mean/median/stddev/p95 reporting, throughput annotation, and machine-
//! readable JSON output so EXPERIMENTS.md numbers can be regenerated.

use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats;

/// One benchmark's collected samples and metadata.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Seconds per iteration, one entry per sample.
    pub samples: Vec<f64>,
    /// Optional elements-processed-per-iteration for throughput reporting.
    pub throughput_elems: Option<u64>,
    /// Optional bytes-processed-per-iteration for throughput reporting.
    pub throughput_bytes: Option<u64>,
}

impl BenchResult {
    pub fn mean(&self) -> f64 {
        stats::mean(&self.samples)
    }
    pub fn median(&self) -> f64 {
        stats::median(&self.samples)
    }
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::str(self.name.clone())),
            ("mean_s", Json::Num(self.mean())),
            ("median_s", Json::Num(self.median())),
            ("stddev_s", Json::Num(stats::stddev(&self.samples))),
            ("p95_s", Json::Num(stats::percentile(&self.samples, 95.0))),
            ("samples", Json::Int(self.samples.len() as i64)),
        ];
        if let Some(e) = self.throughput_elems {
            pairs.push(("elems_per_s", Json::Num(e as f64 / self.mean())));
        }
        if let Some(b) = self.throughput_bytes {
            pairs.push(("bytes_per_s", Json::Num(b as f64 / self.mean())));
        }
        Json::obj(pairs)
    }
}

/// Benchmark runner: collects results, prints a criterion-like report and
/// optionally dumps JSON (for EXPERIMENTS.md regeneration).
pub struct Bench {
    /// Target total measurement time per benchmark.
    pub measure_time: Duration,
    /// Warmup time per benchmark.
    pub warmup_time: Duration,
    /// Number of samples to split the measurement into.
    pub sample_count: usize,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        // Honor a quick mode for CI-ish runs: HB_BENCH_QUICK=1.
        let quick = std::env::var("HB_BENCH_QUICK").ok().as_deref() == Some("1");
        Bench {
            measure_time: if quick { Duration::from_millis(300) } else { Duration::from_secs(2) },
            warmup_time: if quick { Duration::from_millis(100) } else { Duration::from_millis(500) },
            sample_count: if quick { 10 } else { 30 },
            results: Vec::new(),
        }
    }

    /// Run one benchmark. `f` is invoked `iters` times per sample; the
    /// per-iteration time is recorded.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.bench_annotated(name, None, None, &mut f)
    }

    /// Benchmark with elements-per-iteration throughput annotation.
    pub fn bench_elems<F: FnMut()>(&mut self, name: &str, elems: u64, mut f: F) -> &BenchResult {
        self.bench_annotated(name, Some(elems), None, &mut f)
    }

    /// Benchmark with bytes-per-iteration throughput annotation.
    pub fn bench_bytes<F: FnMut()>(&mut self, name: &str, bytes: u64, mut f: F) -> &BenchResult {
        self.bench_annotated(name, None, Some(bytes), &mut f)
    }

    fn bench_annotated(
        &mut self,
        name: &str,
        elems: Option<u64>,
        bytes: Option<u64>,
        f: &mut dyn FnMut(),
    ) -> &BenchResult {
        // Warmup and calibration: find iters/sample so one sample is
        // measure_time / sample_count.
        let warmup_end = Instant::now() + self.warmup_time;
        let mut calib_iters: u64 = 0;
        let calib_start = Instant::now();
        while Instant::now() < warmup_end {
            f();
            calib_iters += 1;
        }
        let per_iter = calib_start.elapsed().as_secs_f64() / calib_iters.max(1) as f64;
        let per_sample = self.measure_time.as_secs_f64() / self.sample_count as f64;
        let iters = ((per_sample / per_iter.max(1e-9)).ceil() as u64).max(1);

        let mut samples = Vec::with_capacity(self.sample_count);
        for _ in 0..self.sample_count {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(t0.elapsed().as_secs_f64() / iters as f64);
        }

        let result = BenchResult {
            name: name.to_string(),
            samples,
            throughput_elems: elems,
            throughput_bytes: bytes,
        };
        Self::print_result(&result);
        self.results.push(result);
        self.results.last().unwrap()
    }

    fn print_result(r: &BenchResult) {
        let mut line = format!(
            "{:<44} time: [{} {} {}]",
            r.name,
            stats::fmt_secs(stats::percentile(&r.samples, 5.0)),
            stats::fmt_secs(r.median()),
            stats::fmt_secs(stats::percentile(&r.samples, 95.0)),
        );
        if let Some(e) = r.throughput_elems {
            line.push_str(&format!("  thrpt: {:.3e} elem/s", e as f64 / r.mean()));
        }
        if let Some(b) = r.throughput_bytes {
            line.push_str(&format!("  thrpt: {}/s", stats::fmt_bytes((b as f64 / r.mean()) as u64)));
        }
        println!("{line}");
    }

    /// Write all collected results as JSON: the historical per-run dump at
    /// `target/bench-results/<suite>.json`, plus the machine-readable
    /// trajectory file `BENCH_<suite>.json` at the repository root so PRs
    /// can commit before/after numbers and future sessions can diff them.
    pub fn dump_json(&self, suite: &str) {
        let results = Json::arr(self.results.iter().map(|r| r.to_json()));

        let dir = std::path::Path::new("target/bench-results");
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(format!("{suite}.json"));
        if std::fs::write(&path, results.to_string_pretty()).is_ok() {
            println!("(results written to {})", path.display());
        }

        // Trajectory file: results wrapped with enough environment metadata
        // to compare runs across machines and PRs. Destination resolves at
        // run time (HB_BENCH_DIR override, then the build-time repo root if
        // it still exists, then cwd) so a relocated binary still lands the
        // file somewhere visible — and failures are reported, not dropped.
        let doc = Json::obj(vec![
            ("suite", Json::str(suite)),
            ("quick", Json::Bool(std::env::var("HB_BENCH_QUICK").ok().as_deref() == Some("1"))),
            ("host_threads", Json::Int(crate::util::threadpool::default_threads() as i64)),
            ("sample_count", Json::Int(self.sample_count as i64)),
            ("results", results),
        ]);
        let root = std::env::var_os("HB_BENCH_DIR")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(|| {
                let manifest_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
                let repo = manifest_dir.parent().unwrap_or(manifest_dir);
                if repo.is_dir() {
                    repo.to_path_buf()
                } else {
                    std::path::PathBuf::from(".")
                }
            });
        let bench_path = root.join(format!("BENCH_{suite}.json"));
        match std::fs::write(&bench_path, doc.to_string_pretty()) {
            Ok(()) => println!("(trajectory written to {})", bench_path.display()),
            Err(e) => eprintln!("warning: could not write {}: {e}", bench_path.display()),
        }
    }
}

/// Shared `HB_THREADS` knob for the multi-threaded bench rows (default:
/// all cores). One definition so every suite's committed trajectory rows
/// stay consistent.
pub fn bench_threads() -> usize {
    std::env::var("HB_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|t| *t >= 1)
        .unwrap_or_else(crate::util::threadpool::default_threads)
}

/// Prevent the optimizer from eliding a computed value (stable-rust
/// equivalent of `std::hint::black_box`, which is stable since 1.66 —
/// re-exported here for a single import site).
pub use std::hint::black_box;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples_and_reports() {
        let mut b = Bench {
            measure_time: Duration::from_millis(20),
            warmup_time: Duration::from_millis(5),
            sample_count: 5,
            results: Vec::new(),
        };
        let mut acc = 0u64;
        let r = b.bench_elems("noop", 1, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert_eq!(r.samples.len(), 5);
        assert!(r.mean() > 0.0);
        let j = r.to_json();
        assert!(j.get_f64("mean_s").unwrap() > 0.0);
    }
}
