//! The serving coordinator: a batching private-inference service.
//!
//! Topology (single-process simulation mode — the default testbed; a
//! multi-process TCP mode exists via `hummingbird party`):
//!
//! ```text
//!   clients ──► request queue ──► batcher ──► party 0 thread ─┐
//!                                        └──► party 1 thread ─┼─ GMW over hub
//!                                        └──► party k thread ─┘
//!                       ◄── reconstructed logits / predictions
//! ```
//!
//! The batcher groups pending requests up to the model's artifact batch
//! (padding the tail), fans the secret shares out to the party threads,
//! and reconstructs the output shares. Party threads own their GmwParty +
//! PJRT runtime for the whole session (executable caches stay warm).
//!
//! Faults degrade gracefully (DESIGN.md §7): a party session that hits a
//! deadline, a dead link that reconnect couldn't cure, or an injected
//! crash fails *its* in-flight batch — the requests get error responses,
//! the [`Metrics`] fault counters tick, and the batcher respawns a fresh
//! party session for the next batch. The coordinator process never wedges
//! on a single bad session.
//!
//! The service above the sessions is overload-safe (DESIGN.md §9):
//! admission is bounded (`--queue-depth`), queued requests expire
//! (`--request-timeout-ms`), session respawn runs under a crash-loop
//! breaker (`--max-restarts` → `Degraded` + background probe), and
//! shutdown drains gracefully
//! ([`Coordinator::shutdown_with_deadline`]). The lifecycle
//! (`Serving → Degraded → Draining → Stopped`) and the per-request
//! disposition counters — whose identity the chaos soak pins exactly —
//! are surfaced by [`Metrics::snapshot`].

#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod batcher;
pub mod breaker;
pub mod metrics;

pub use batcher::{Coordinator, InferenceResult, ServeOptions, DEFAULT_DRAIN};
pub use breaker::{BreakerVerdict, Clock, ClockHandle, MockClock, RestartBreaker};
pub use metrics::{AdmissionCounters, LifecycleState, Metrics, MetricsSnapshot};
