//! In-process multi-party harness: runs `p` [`GmwParty`] instances on
//! threads over a [`local`](crate::net::local) hub. Used by tests, benches,
//! the figure generator and the single-binary demo mode (`--local-sim`).
//!
//! Kernel dispatch: the default-constructed backends resolve the `auto`
//! kernel choice (DESIGN.md §11), so every harness run exercises the AVX2
//! plane kernels on hardware that has them and the scalar reference
//! everywhere else — and `HB_KERNEL=scalar` pins the whole suite to the
//! reference arm. To force an arm per party, pass a factory built on
//! `RustKernels::with_kernel` / `scalar` to [`run_parties_with`].

use std::sync::Arc;

use super::kernels::{KernelBackend, RustKernels};
use super::GmwParty;
use crate::net::accounting::CommTrace;
use crate::net::local::{hub, LocalTransport};
use crate::net::Transport;

/// Output of a harness run: per-party results plus party 0's comm trace.
pub struct HarnessRun<R> {
    pub outputs: Vec<R>,
    pub trace: Arc<CommTrace>,
}

/// Run `f` on every party concurrently (Rust kernels, single-threaded
/// lanes) and collect results in party order.
pub fn run_parties<R, F>(parties: usize, session_seed: u64, f: F) -> HarnessRun<R>
where
    R: Send,
    F: Fn(&mut GmwParty<LocalTransport, RustKernels>) -> R + Send + Sync,
{
    run_parties_inner(parties, session_seed, 1, |_p| RustKernels::default(), f)
}

/// Like [`run_parties`] but with each party's lane-parallelism budget set
/// to `threads` (kernels + fused bitpack). Results are bit-identical to
/// the single-threaded run for any value.
pub fn run_parties_threaded<R, F>(
    parties: usize,
    session_seed: u64,
    threads: usize,
    f: F,
) -> HarnessRun<R>
where
    R: Send,
    F: Fn(&mut GmwParty<LocalTransport, RustKernels>) -> R + Send + Sync,
{
    run_parties_inner(parties, session_seed, threads, |_p| RustKernels::default(), f)
}

/// Run with a per-party kernel backend factory (e.g. to give each party its
/// own PJRT executable cache, or to select the bitsliced layout via
/// `|_| BitslicedKernels::default()`).
pub fn run_parties_with<R, F, K, KF>(
    parties: usize,
    session_seed: u64,
    kf: KF,
    f: F,
) -> HarnessRun<R>
where
    R: Send,
    K: KernelBackend,
    F: Fn(&mut GmwParty<LocalTransport, K>) -> R + Send + Sync,
    KF: Fn(usize) -> K + Send + Sync,
{
    run_parties_inner(parties, session_seed, 1, kf, f)
}

/// [`run_parties_with`] plus a per-party lane-parallelism budget — the
/// full knob surface (kernel backend / layout × thread count) used by the
/// layout-equivalence tests and the ablation bench.
pub fn run_parties_with_threaded<R, F, K, KF>(
    parties: usize,
    session_seed: u64,
    threads: usize,
    kf: KF,
    f: F,
) -> HarnessRun<R>
where
    R: Send,
    K: KernelBackend,
    F: Fn(&mut GmwParty<LocalTransport, K>) -> R + Send + Sync,
    KF: Fn(usize) -> K + Send + Sync,
{
    run_parties_inner(parties, session_seed, threads, kf, f)
}

fn run_parties_inner<R, F, K, KF>(
    parties: usize,
    session_seed: u64,
    threads: usize,
    kf: KF,
    f: F,
) -> HarnessRun<R>
where
    R: Send,
    K: KernelBackend,
    F: Fn(&mut GmwParty<LocalTransport, K>) -> R + Send + Sync,
    KF: Fn(usize) -> K + Send + Sync,
{
    let transports = hub(parties);
    let trace = transports[0].trace();
    // HOT-PATH-ALLOW: test harness setup — one slot per party.
    let mut outputs: Vec<Option<R>> = (0..parties).map(|_| None).collect();
    std::thread::scope(|s| {
        // HOT-PATH-ALLOW: test harness setup — one handle per party.
        let mut handles = Vec::new();
        for (pid, t) in transports.into_iter().enumerate() {
            let f = &f;
            let kf = &kf;
            handles.push(s.spawn(move || {
                let mut party = GmwParty::with_kernels(t, session_seed, kf(pid));
                party.set_threads(threads);
                f(&mut party)
            }));
        }
        for (pid, h) in handles.into_iter().enumerate() {
            // LINT-ALLOW: unwrap — the harness re-throws party panics so
            // the owning test fails with the original message.
            outputs[pid] = Some(h.join().expect("party thread panicked"));
        }
    });
    // HOT-PATH-ALLOW: harness teardown — collects per-party outputs once.
    // LINT-ALLOW: unwrap — every slot was filled by the join loop above.
    HarnessRun { outputs: outputs.into_iter().map(|o| o.unwrap()).collect(), trace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitpack;
    use crate::crypto::prg::Prg;
    use crate::gmw::{adder, ReluPlan};
    use crate::net::accounting::Phase;
    use crate::ring;
    use crate::sharing::{reconstruct_arith, reconstruct_binary, share_arith, share_binary};

    /// Secure AND of random words equals plaintext AND (2 and 3 parties).
    #[test]
    fn and_gates_correct() {
        for parties in [2usize, 3] {
            let mut prg = Prg::new(10, 0);
            let n = 64;
            let x: Vec<u64> = prg.vec_u64(n);
            let y: Vec<u64> = prg.vec_u64(n);
            let xs = share_binary(&mut prg, &x, parties);
            let ys = share_binary(&mut prg, &y, parties);
            let run = run_parties(parties, 42, |p| {
                let me = p.party();
                p.and_gates(Phase::Circuit, &xs[me], &ys[me], 64).unwrap()
            });
            let z = reconstruct_binary(&run.outputs);
            let expect: Vec<u64> = x.iter().zip(&y).map(|(a, b)| a & b).collect();
            assert_eq!(z, expect, "parties={parties}");
        }
    }

    /// ks_add on random w-bit lanes equals plaintext addition mod 2^w.
    #[test]
    fn ks_add_correct_all_widths() {
        for parties in [2usize, 3] {
            for w in [1u32, 2, 3, 5, 8, 13, 16, 21, 32, 48, 64] {
                let mut prg = Prg::new(w as u64, parties as u64);
                let n = 40;
                let mask = ring::low_mask(w);
                let x: Vec<u64> = (0..n).map(|_| prg.next_u64() & mask).collect();
                let y: Vec<u64> = (0..n).map(|_| prg.next_u64() & mask).collect();
                let xs = share_binary(&mut prg, &x, parties);
                let ys = share_binary(&mut prg, &y, parties);
                // Mask shares to lanes.
                let xs: Vec<Vec<u64>> =
                    xs.iter().map(|s| s.iter().map(|v| v & mask).collect()).collect();
                let ys: Vec<Vec<u64>> =
                    ys.iter().map(|s| s.iter().map(|v| v & mask).collect()).collect();
                let run = run_parties(parties, 7, |p| {
                    let me = p.party();
                    adder::ks_add(p, &xs[me], &ys[me], w).unwrap()
                });
                let z = reconstruct_binary(&run.outputs);
                let expect: Vec<u64> =
                    x.iter().zip(&y).map(|(a, b)| a.wrapping_add(*b) & mask).collect();
                assert_eq!(z, expect, "parties={parties} w={w}");
            }
        }
    }

    /// Binary-share masking bug guard: shares of w-bit lanes must not leak
    /// into high bits after re-sharing inside a2b.
    #[test]
    fn a2b_matches_plaintext_window() {
        for parties in [2usize, 3] {
            for w in [4u32, 9, 16, 33, 64] {
                let mut prg = Prg::new(100 + w as u64, 0);
                let n = 32;
                let x: Vec<u64> = prg.vec_u64(n);
                let xs = share_arith(&mut prg, &x, parties);
                let run = run_parties(parties, 1234, |p| {
                    let me = p.party();
                    p.a2b(&xs[me], w).unwrap()
                });
                let z = reconstruct_binary(&run.outputs);
                let mask = ring::low_mask(w);
                let expect: Vec<u64> = x.iter().map(|v| v & mask).collect();
                assert_eq!(z, expect, "parties={parties} w={w}");
            }
        }
    }

    /// Beaver mult equals plaintext ring multiplication.
    #[test]
    fn mul_correct() {
        for parties in [2usize, 3] {
            let mut prg = Prg::new(5, 5);
            let n = 50;
            let x: Vec<u64> = prg.vec_u64(n);
            let y: Vec<u64> = prg.vec_u64(n);
            let xs = share_arith(&mut prg, &x, parties);
            let ys = share_arith(&mut prg, &y, parties);
            let run = run_parties(parties, 99, |p| {
                let me = p.party();
                p.mul(&xs[me], &ys[me]).unwrap()
            });
            let z = reconstruct_arith(&run.outputs);
            let expect: Vec<u64> = x.iter().zip(&y).map(|(a, b)| a.wrapping_mul(*b)).collect();
            assert_eq!(z, expect);
        }
    }

    /// B2A of random bits.
    #[test]
    fn b2a_bit_correct() {
        for parties in [2usize, 3] {
            let mut prg = Prg::new(6, 6);
            let n = 128;
            let bits: Vec<u64> = prg.vec_bits(n);
            let bs = share_binary(&mut prg, &bits, parties);
            let bs: Vec<Vec<u64>> =
                bs.iter().map(|s| s.iter().map(|v| v & 1).collect()).collect();
            let run = run_parties(parties, 55, |p| {
                let me = p.party();
                p.b2a_bit(&bs[me]).unwrap()
            });
            let z = reconstruct_arith(&run.outputs);
            assert_eq!(z, bits);
        }
    }

    /// Baseline (full-ring) ReLU is exact for the whole representable range.
    #[test]
    fn relu_baseline_exact() {
        let parties = 2;
        let mut prg = Prg::new(8, 8);
        let n = 200;
        // Values spanning positive/negative, small/large.
        let x: Vec<u64> = (0..n)
            .map(|i| match i % 4 {
                0 => prg.next_u64() % (1 << 20),
                1 => (prg.next_u64() % (1 << 20)).wrapping_neg(),
                2 => prg.next_u64() % (1 << 44),
                _ => (prg.next_u64() % (1 << 44)).wrapping_neg(),
            })
            .collect();
        let xs = share_arith(&mut prg, &x, parties);
        let run = run_parties(parties, 77, |p| {
            let me = p.party();
            p.relu(&xs[me], ReluPlan::BASELINE).unwrap()
        });
        let z = reconstruct_arith(&run.outputs);
        let expect: Vec<u64> =
            x.iter().map(|v| if ring::is_negative(*v) { 0 } else { *v }).collect();
        assert_eq!(z, expect);
    }

    /// Theorem 1 end-to-end: k-window DReLU is exact while |x| < 2^(k−1),
    /// with m = 0 (HummingBird-eco).
    #[test]
    fn relu_eco_exact_within_range() {
        let parties = 2;
        let k = 20u32;
        let bound = 1u64 << (k - 1);
        let mut prg = Prg::new(9, 9);
        let n = 300;
        let x: Vec<u64> = (0..n)
            .map(|_| {
                let v = prg.next_u64() % (2 * bound); // [0, 2^k)
                v.wrapping_sub(bound) // [-2^(k-1), 2^(k-1))
            })
            .collect();
        let xs = share_arith(&mut prg, &x, parties);
        let plan = ReluPlan::new(k, 0).unwrap();
        let run = run_parties(parties, 31, |p| {
            let me = p.party();
            p.relu(&xs[me], plan).unwrap()
        });
        let z = reconstruct_arith(&run.outputs);
        let expect: Vec<u64> =
            x.iter().map(|v| if ring::is_negative(*v) { 0 } else { *v }).collect();
        assert_eq!(z, expect);
    }

    /// Theorem 2 end-to-end: with m > 0, outputs equal exact ReLU except
    /// that values in [0, 2^m) may be zeroed (magnitude pruning).
    #[test]
    fn relu_low_bit_drop_is_magnitude_pruning() {
        let parties = 2;
        let plan = ReluPlan::new(24, 8).unwrap();
        let thresh = 1u64 << plan.m;
        let mut prg = Prg::new(12, 3);
        let n = 400;
        let bound = 1u64 << (plan.k - 1);
        let x: Vec<u64> = (0..n)
            .map(|i| match i % 3 {
                0 => prg.next_u64() % thresh,                       // small positive
                1 => prg.next_u64() % bound,                        // any positive < 2^(k-1)
                _ => (prg.next_u64() % bound).wrapping_neg(),       // negative
            })
            .collect();
        let xs = share_arith(&mut prg, &x, parties);
        let run = run_parties(parties, 13, |p| {
            let me = p.party();
            p.relu(&xs[me], plan).unwrap()
        });
        let z = reconstruct_arith(&run.outputs);
        let mut pruned = 0;
        for (xi, zi) in x.iter().zip(&z) {
            let xi_s = *xi as i64;
            if xi_s < 0 {
                assert_eq!(*zi, 0, "negative must be zeroed, x={xi_s}");
            } else if (*xi as u64) >= thresh {
                assert_eq!(*zi, *xi, "large positive must pass, x={xi_s}");
            } else {
                // Theorem 2: small positives are either passed or pruned.
                assert!(*zi == 0 || zi == xi, "x={xi_s} z={}", *zi as i64);
                if *zi == 0 && xi_s > 0 {
                    pruned += 1;
                }
            }
        }
        assert!(pruned > 0, "expected some magnitude pruning to occur");
    }

    /// Identity plan (zero bits) passes values through with no comm.
    #[test]
    fn relu_identity_plan() {
        let parties = 2;
        let mut prg = Prg::new(21, 0);
        let x: Vec<u64> = prg.vec_u64(16);
        let xs = share_arith(&mut prg, &x, parties);
        let plan = ReluPlan::new(10, 10).unwrap();
        let run = run_parties(parties, 3, |p| {
            let me = p.party();
            p.relu(&xs[me], plan).unwrap()
        });
        assert_eq!(reconstruct_arith(&run.outputs), x);
        assert_eq!(run.trace.total_bytes(), 0);
    }

    /// Reduced-ring ReLU must communicate far less than baseline (the
    /// paper's headline mechanism).
    #[test]
    fn reduced_ring_communicates_less() {
        let parties = 2;
        let mut prg = Prg::new(30, 0);
        let n = 256;
        let x: Vec<u64> = (0..n).map(|_| prg.next_u64() % (1 << 16)).collect();
        let xs = share_arith(&mut prg, &x, parties);
        let mut bytes = Vec::new();
        let plans =
            [ReluPlan::BASELINE, ReluPlan::new(20, 0).unwrap(), ReluPlan::new(14, 8).unwrap()];
        for plan in plans {
            let run = run_parties(parties, 4, |p| {
                let me = p.party();
                p.relu(&xs[me], plan).unwrap()
            });
            bytes.push(run.trace.total_bytes());
        }
        assert!(bytes[0] > bytes[1] && bytes[1] > bytes[2], "{bytes:?}");
        // 6-bit window ≈ paper's HummingBird-6/64 regime: expect >4× total
        // reduction even though Mult is incompressible.
        assert!(bytes[0] as f64 / bytes[2] as f64 > 4.0, "{bytes:?}");
    }

    /// The zero-allocation claim, pinned: after one warmup ReLU has filled
    /// the scratch arena, the transport's send-payload pool and the
    /// session `RecvBufs`, further `relu_into` rounds check every buffer
    /// out of a pool (no allocation misses anywhere — engine *or*
    /// transport receive path) and return every buffer they check out.
    #[test]
    fn relu_steady_state_is_allocation_free() {
        let parties = 2;
        let mut prg = Prg::new(40, 0);
        let n = 512;
        let x: Vec<u64> = (0..n).map(|_| prg.next_u64() % (1 << 16)).collect();
        let xs = share_arith(&mut prg, &x, parties);
        let plan = ReluPlan::new(12, 4).unwrap();
        let run = run_parties(parties, 6, |p| {
            let me = p.party();
            let mut out = vec![0u64; n];
            // Warmup round populates the pools.
            p.relu_into(&xs[me], plan, &mut out).unwrap();
            let warm = p.arena_stats();
            let warm_net = p.transport.pool_stats();
            assert_eq!(warm.checkouts, warm.returns, "buffers leaked during warmup");
            assert_eq!(
                warm_net.checkouts, warm_net.returns,
                "transport payloads leaked during warmup"
            );
            // Steady-state rounds must not allocate.
            for round in 0..3 {
                p.relu_into(&xs[me], plan, &mut out).unwrap();
                let s = p.arena_stats();
                assert_eq!(
                    s.alloc_misses, warm.alloc_misses,
                    "steady-state relu allocated (round {round})"
                );
                assert_eq!(s.checkouts, s.returns, "unbalanced checkout (round {round})");
                let t = p.transport.pool_stats();
                assert_eq!(
                    t.alloc_misses, warm_net.alloc_misses,
                    "steady-state relu allocated a transport payload (round {round})"
                );
                assert_eq!(
                    t.checkouts, t.returns,
                    "unbalanced transport payload checkout (round {round})"
                );
            }
            out
        });
        // And it still computes ReLU.
        let z = reconstruct_arith(&run.outputs);
        for (xi, zi) in x.iter().zip(&z) {
            assert!(*zi == 0 || zi == xi);
        }
    }

    /// `relu_into` and multi-threaded lanes are bit-identical to the plain
    /// single-threaded `relu` (the knob must never change results).
    #[test]
    fn threaded_relu_matches_single_threaded() {
        let parties = 2;
        let mut prg = Prg::new(41, 0);
        let n = 1024;
        let x: Vec<u64> = (0..n)
            .map(|i| {
                let v = prg.next_u64() % (1 << 18);
                if i % 2 == 0 {
                    v
                } else {
                    v.wrapping_neg()
                }
            })
            .collect();
        let xs = share_arith(&mut prg, &x, parties);
        let plan = ReluPlan::new(20, 0).unwrap();
        let base = run_parties(parties, 9, |p| {
            let me = p.party();
            p.relu(&xs[me], plan).unwrap()
        });
        for threads in [2usize, 4] {
            let run = run_parties_threaded(parties, 9, threads, |p| {
                let me = p.party();
                assert_eq!(p.threads(), threads);
                p.relu(&xs[me], plan).unwrap()
            });
            assert_eq!(run.outputs, base.outputs, "threads={threads}");
            assert_eq!(run.trace.total_bytes(), base.trace.total_bytes());
            assert_eq!(run.trace.total_rounds(), base.trace.total_rounds());
        }
    }

    /// Wire accounting consistency: a binary opening of n lanes at width w
    /// puts exactly `bitpack::packed_bytes(n, w)` bytes per peer on the
    /// wire (the fused pack writes no padding beyond the final byte).
    #[test]
    fn open_wire_bytes_match_packed_bytes() {
        for w in [1u32, 5, 6, 8, 13, 64] {
            let n = 333usize;
            let mask = ring::low_mask(w);
            let mut prg = Prg::new(50 + w as u64, 0);
            let x: Vec<u64> = (0..n).map(|_| prg.next_u64() & mask).collect();
            let xs = share_binary(&mut prg, &x, 2);
            let xs: Vec<Vec<u64>> =
                xs.iter().map(|s| s.iter().map(|v| v & mask).collect()).collect();
            let run = run_parties(2, 8, |p| {
                let me = p.party();
                p.open_binary(Phase::Circuit, &xs[me], w).unwrap()
            });
            assert_eq!(run.outputs[0], run.outputs[1], "parties opened different values");
            assert_eq!(run.outputs[0], x, "opened value wrong w={w}");
            assert_eq!(
                run.trace.total_bytes(),
                bitpack::packed_bytes(n, w),
                "wire bytes w={w}"
            );
            assert_eq!(run.trace.total_rounds(), 1);
        }
    }
}
