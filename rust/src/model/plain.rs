//! Plaintext f32 executor: the "vanilla single-node inference" half of the
//! paper's MPC simulator (§4.1.1) and the verification oracle for MPC runs.
//!
//! Two interchangeable backends for the linear layers:
//! * `Backend::Naive` — portable Rust loops (always available; tests).
//! * `Backend::Xla`   — the AOT per-layer f32 artifacts via PJRT (fast path
//!   used by the search engine; same HLO the L2 model.py defines).
//!
//! Between linear layers the executor calls a caller-supplied ReLU hook, so
//! the search engine can inject HummingBird's approximate ReLU per group
//! and capture pre-activation ranges.

use crate::error::{Error, Result};
use crate::model::graph::{ModelConfig, Op};
use crate::model::weights::Archive;
use crate::runtime::{registry::ModelArtifacts, Runtime};

/// ReLU hook: `(node_index, group, pre_activations) -> activations`.
/// The default hook is exact ReLU.
pub type ReluHook<'a> = &'a mut dyn FnMut(usize, usize, &mut [f32]);

/// Linear-layer backend.
pub enum Backend {
    Naive,
    Xla { rt: Runtime, artifacts: ModelArtifacts, artifact_batch: usize, which: WhichPlain },
}

/// Which f32 artifact variant to use (they differ only in batch size).
#[derive(Clone, Copy, PartialEq, Eq)]
pub enum WhichPlain {
    /// `plain_*` artifacts (MPC batch).
    Plain,
    /// `search_*` artifacts (search batch).
    Search,
}

/// Plaintext model executor.
pub struct PlainExecutor {
    pub cfg: ModelConfig,
    /// f32 parameters keyed "w{i}" / "b{i}" (node index).
    weights: Archive,
    backend: Backend,
}

/// Look up a computed activation, reporting a graph-wiring error instead of
/// panicking when a node references a source that has not run yet.
fn act<'a>(acts: &'a [Option<Vec<f32>>], src: usize, node: usize) -> Result<&'a Vec<f32>> {
    acts[src].as_ref().ok_or_else(|| Error::Model(format!("node {node}: missing src {src}")))
}

impl PlainExecutor {
    pub fn new(cfg: ModelConfig, weights: Archive, backend: Backend) -> PlainExecutor {
        PlainExecutor { cfg, weights, backend }
    }

    /// Forward a batch with exact ReLU; returns logits [batch, classes].
    pub fn forward(&self, x: &[f32], batch: usize) -> Result<Vec<f32>> {
        let mut exact = |_i: usize, _g: usize, v: &mut [f32]| {
            for e in v.iter_mut() {
                if *e < 0.0 {
                    *e = 0.0;
                }
            }
        };
        self.forward_with(x, batch, &mut exact)
    }

    /// Forward with a custom ReLU hook.
    pub fn forward_with(&self, x: &[f32], batch: usize, relu: ReluHook) -> Result<Vec<f32>> {
        let outs = self.forward_from(0, &[(0, x.to_vec())], batch, relu)?;
        Ok(outs)
    }

    /// Forward starting at `start_node`, given the activations of all nodes
    /// with index < start_node that later nodes reference (checkpointing
    /// support for the DFS search; see search.rs).
    ///
    /// `seeds` maps node index -> activation buffer.
    pub fn forward_from(
        &self,
        start_node: usize,
        seeds: &[(usize, Vec<f32>)],
        batch: usize,
        relu: ReluHook,
    ) -> Result<Vec<f32>> {
        let shapes = self.cfg.shapes();
        let n_nodes = self.cfg.nodes.len();
        let mut acts: Vec<Option<Vec<f32>>> = vec![None; n_nodes];
        for (idx, buf) in seeds {
            acts[*idx] = Some(buf.clone());
        }
        for i in start_node..n_nodes {
            if acts[i].is_some() {
                continue; // seeded (checkpointed) node
            }
            let node = &self.cfg.nodes[i];
            let out = match node {
                Op::Input => {
                    if acts[0].is_none() {
                        return Err(Error::Model("input activation not seeded".into()));
                    }
                    continue;
                }
                Op::Conv { src, out_ch, k, stride, pad } => {
                    let xin = act(&acts, *src, i)?;
                    let in_shape = &shapes[*src];
                    self.conv(i, xin, batch, in_shape, *out_ch, *k, *stride, *pad)?
                }
                Op::Relu { src, group } => {
                    let mut v = act(&acts, *src, i)?.clone();
                    relu(i, *group, &mut v);
                    v
                }
                Op::Add { a, b } => {
                    let va = act(&acts, *a, i)?;
                    let vb = act(&acts, *b, i)?;
                    va.iter().zip(vb).map(|(x, y)| x + y).collect()
                }
                Op::Gap { src } => {
                    let v = act(&acts, *src, i)?;
                    let s = &shapes[*src];
                    let (c, h, w) = (s[0], s[1], s[2]);
                    let mut out = vec![0f32; batch * c];
                    for b_i in 0..batch {
                        for ci in 0..c {
                            let base = (b_i * c + ci) * h * w;
                            let sum: f32 = v[base..base + h * w].iter().sum();
                            out[b_i * c + ci] = sum / (h * w) as f32;
                        }
                    }
                    out
                }
                Op::Fc { src, out } => {
                    let v = act(&acts, *src, i)?;
                    self.fc(i, v, batch, *out)?
                }
            };
            acts[i] = Some(out);
        }
        acts[n_nodes - 1]
            .take()
            .ok_or_else(|| Error::Model("no output".into()))
    }

    /// Run nodes 0..boundary and return the activation seeds that a
    /// `forward_from(boundary, seeds, ...)` call needs: every computed act
    /// with index < boundary referenced by some node >= boundary.
    /// (DFS checkpointing — search.rs re-evaluates only the suffix.)
    pub fn prefix_acts(
        &self,
        x: &[f32],
        batch: usize,
        boundary: usize,
        relu: ReluHook,
    ) -> Result<Vec<(usize, Vec<f32>)>> {
        let shapes = self.cfg.shapes();
        let n_nodes = self.cfg.nodes.len();
        if boundary == 0 {
            return Ok(vec![(0, x.to_vec())]);
        }
        let mut acts: Vec<Option<Vec<f32>>> = vec![None; n_nodes];
        acts[0] = Some(x.to_vec());
        for i in 1..boundary {
            let node = &self.cfg.nodes[i];
            let out = match node {
                Op::Input => continue,
                Op::Conv { src, out_ch, k, stride, pad } => {
                    let xin = act(&acts, *src, i)?;
                    self.conv(i, xin, batch, &shapes[*src], *out_ch, *k, *stride, *pad)?
                }
                Op::Relu { src, group } => {
                    let mut v = act(&acts, *src, i)?.clone();
                    relu(i, *group, &mut v);
                    v
                }
                Op::Add { a, b } => {
                    let va = act(&acts, *a, i)?;
                    let vb = act(&acts, *b, i)?;
                    va.iter().zip(vb).map(|(x, y)| x + y).collect()
                }
                Op::Gap { src } => {
                    let v = act(&acts, *src, i)?;
                    let s = &shapes[*src];
                    let (c, h, w) = (s[0], s[1], s[2]);
                    let mut out = vec![0f32; batch * c];
                    for b_i in 0..batch {
                        for ci in 0..c {
                            let base = (b_i * c + ci) * h * w;
                            out[b_i * c + ci] =
                                v[base..base + h * w].iter().sum::<f32>() / (h * w) as f32;
                        }
                    }
                    out
                }
                Op::Fc { src, out } => {
                    let v = act(&acts, *src, i)?;
                    self.fc(i, v, batch, *out)?
                }
            };
            acts[i] = Some(out);
        }
        // Keep only acts referenced at or after the boundary.
        let mut needed = vec![false; n_nodes];
        for i in boundary..n_nodes {
            match &self.cfg.nodes[i] {
                Op::Conv { src, .. }
                | Op::Relu { src, .. }
                | Op::Gap { src }
                | Op::Fc { src, .. } => needed[*src] = true,
                Op::Add { a, b } => {
                    needed[*a] = true;
                    needed[*b] = true;
                }
                Op::Input => {}
            }
        }
        let mut seeds = Vec::new();
        for i in 0..boundary {
            if needed[i] {
                if let Some(v) = acts[i].take() {
                    seeds.push((i, v));
                }
            }
        }
        Ok(seeds)
    }

    // ------------------------------------------------------------------
    // Linear ops.
    // ------------------------------------------------------------------

    #[allow(clippy::too_many_arguments)]
    fn conv(
        &self,
        node: usize,
        x: &[f32],
        batch: usize,
        in_shape: &[usize],
        out_ch: usize,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> Result<Vec<f32>> {
        let w = self.weights.get(&format!("w{node}"))?.as_f32()?;
        let b = self.weights.get(&format!("b{node}"))?.as_f32()?;
        match &self.backend {
            Backend::Naive => Ok(conv_naive(
                x, batch, in_shape[0], in_shape[1], in_shape[2], w, b, out_ch, k, stride, pad,
            )),
            Backend::Xla { rt, artifacts, artifact_batch, which } => {
                let layer = artifacts
                    .layers
                    .get(&node)
                    .ok_or_else(|| Error::Model(format!("no artifact for node {node}")))?;
                let rel = match which {
                    WhichPlain::Plain => &layer.plain,
                    WhichPlain::Search => &layer.search,
                };
                let ab = *artifact_batch;
                let per = in_shape.iter().product::<usize>();
                let out_per = layer.out_shape.iter().product::<usize>();
                let mut out = Vec::with_capacity(batch * out_per);
                let mut start = 0usize;
                while start < batch {
                    let chunk = (batch - start).min(ab);
                    let mut xpad = vec![0f32; ab * per];
                    xpad[..chunk * per]
                        .copy_from_slice(&x[start * per..(start + chunk) * per]);
                    let xshape = [ab, in_shape[0], in_shape[1], in_shape[2]];
                    let results = rt.run_f32(
                        rel,
                        &[
                            (&xpad, &xshape[..]),
                            (w, &layer.w_shape[..]),
                            (b, &[layer.w_shape[0]][..]),
                        ],
                    )?;
                    out.extend_from_slice(&results[0].0[..chunk * out_per]);
                    start += chunk;
                }
                Ok(out)
            }
        }
    }

    fn fc(&self, node: usize, x: &[f32], batch: usize, out_dim: usize) -> Result<Vec<f32>> {
        let w = self.weights.get(&format!("w{node}"))?.as_f32()?;
        let b = self.weights.get(&format!("b{node}"))?.as_f32()?;
        let in_dim = x.len() / batch;
        match &self.backend {
            Backend::Naive => {
                let mut out = vec![0f32; batch * out_dim];
                for bi in 0..batch {
                    for o in 0..out_dim {
                        let mut acc = b[o];
                        for i in 0..in_dim {
                            acc += x[bi * in_dim + i] * w[i * out_dim + o];
                        }
                        out[bi * out_dim + o] = acc;
                    }
                }
                Ok(out)
            }
            Backend::Xla { rt, artifacts, artifact_batch, which } => {
                let layer = artifacts
                    .layers
                    .get(&node)
                    .ok_or_else(|| Error::Model(format!("no artifact for node {node}")))?;
                let rel = match which {
                    WhichPlain::Plain => &layer.plain,
                    WhichPlain::Search => &layer.search,
                };
                let ab = *artifact_batch;
                let mut out = Vec::with_capacity(batch * out_dim);
                let mut start = 0usize;
                while start < batch {
                    let chunk = (batch - start).min(ab);
                    let mut xpad = vec![0f32; ab * in_dim];
                    xpad[..chunk * in_dim]
                        .copy_from_slice(&x[start * in_dim..(start + chunk) * in_dim]);
                    let results = rt.run_f32(
                        rel,
                        &[
                            (&xpad, &[ab, in_dim][..]),
                            (w, &[in_dim, out_dim][..]),
                            (b, &[out_dim][..]),
                        ],
                    )?;
                    out.extend_from_slice(&results[0].0[..chunk * out_dim]);
                    start += chunk;
                }
                Ok(out)
            }
        }
    }

    /// Argmax per row (classification decision).
    pub fn argmax(logits: &[f32], classes: usize) -> Vec<usize> {
        logits
            .chunks(classes)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }
}

/// Naive NCHW convolution + bias (reference implementation).
#[allow(clippy::too_many_arguments)]
pub fn conv_naive(
    x: &[f32],
    batch: usize,
    cin: usize,
    h: usize,
    w: usize,
    weight: &[f32],
    bias: &[f32],
    cout: usize,
    k: usize,
    stride: usize,
    pad: usize,
) -> Vec<f32> {
    let ho = (h + 2 * pad - k) / stride + 1;
    let wo = (w + 2 * pad - k) / stride + 1;
    let mut out = vec![0f32; batch * cout * ho * wo];
    for b in 0..batch {
        for oc in 0..cout {
            for oy in 0..ho {
                for ox in 0..wo {
                    let mut acc = bias[oc];
                    for ic in 0..cin {
                        for ky in 0..k {
                            let iy = oy * stride + ky;
                            if iy < pad || iy - pad >= h {
                                continue;
                            }
                            for kx in 0..k {
                                let ix = ox * stride + kx;
                                if ix < pad || ix - pad >= w {
                                    continue;
                                }
                                let xi = ((b * cin + ic) * h + (iy - pad)) * w + (ix - pad);
                                let wi = ((oc * cin + ic) * k + ky) * k + kx;
                                acc += x[xi] * weight[wi];
                            }
                        }
                    }
                    out[((b * cout + oc) * ho + oy) * wo + ox] = acc;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_naive_identity_kernel() {
        // 1x1 conv with identity weight = passthrough + bias.
        let x: Vec<f32> = (0..8).map(|v| v as f32).collect(); // [1,2,2,2]
        let w = vec![1.0, 0.0, 0.0, 1.0]; // [2,2,1,1] identity across channels
        let b = vec![0.5, -0.5];
        let y = conv_naive(&x, 1, 2, 2, 2, &w, &b, 2, 1, 1, 0);
        assert_eq!(y[0], 0.5);
        assert_eq!(y[4], 3.5);
    }

    #[test]
    fn conv_naive_padding_and_stride() {
        // 3x3 sum kernel over a 2x2 input with pad 1, stride 2 -> 1x1 out? no:
        // ho = (2+2-3)/2+1 = 1... choose stride 1: ho=2.
        let x = vec![1.0, 2.0, 3.0, 4.0]; // [1,1,2,2]
        let w = vec![1.0; 9];
        let b = vec![0.0];
        let y = conv_naive(&x, 1, 1, 2, 2, &w, &b, 1, 3, 1, 1);
        // Each output = sum of in-bounds neighbors; top-left sees 1+2+3+4=10
        assert_eq!(y, vec![10.0, 10.0, 10.0, 10.0]);
    }

    #[test]
    fn argmax_rows() {
        let logits = vec![0.1, 0.9, 0.0, 2.0, -1.0, 1.0];
        assert_eq!(PlainExecutor::argmax(&logits, 3), vec![1, 0]);
    }
}
