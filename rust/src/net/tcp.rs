//! Real TCP transport for multi-process deployments (`hummingbird party`).
//!
//! Framing: each message is `[seq: u64 le][len: u64 le][payload]`. The
//! mesh is fully connected; party i listens for connections from parties
//! j > i and dials parties j < i, so an n-party mesh needs no coordinator.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use super::accounting::{CommTrace, Phase};
use super::Transport;
use crate::error::{Error, Result};

/// TCP endpoint for one party.
pub struct TcpTransport {
    party: usize,
    parties: usize,
    /// Peer streams indexed by party id (entry for self is None).
    streams: Vec<Option<TcpStream>>,
    seq: u64,
    trace: Arc<CommTrace>,
}

impl TcpTransport {
    /// Connect the mesh. `addrs[p]` is the listen address of party p
    /// (e.g. "127.0.0.1:9001"). Blocks until all links are up.
    pub fn connect(party: usize, addrs: &[String]) -> Result<TcpTransport> {
        let parties = addrs.len();
        if party >= parties || parties < 2 {
            return Err(Error::config(format!("bad party id {party} for {parties} parties")));
        }
        let mut streams: Vec<Option<TcpStream>> = (0..parties).map(|_| None).collect();

        // Accept from higher-ranked peers.
        let listener = TcpListener::bind(&addrs[party])
            .map_err(|e| Error::Transport(format!("bind {}: {e}", addrs[party])))?;
        // Dial lower-ranked peers (with retry while they come up).
        for (q, addr) in addrs.iter().enumerate().take(party) {
            let stream = dial_with_retry(addr)?;
            // Identify ourselves.
            let mut s = stream;
            s.write_all(&(party as u64).to_le_bytes())?;
            s.set_nodelay(true).ok();
            streams[q] = Some(s);
        }
        for _ in party + 1..parties {
            let (mut s, _) = listener
                .accept()
                .map_err(|e| Error::Transport(format!("accept: {e}")))?;
            let mut idbuf = [0u8; 8];
            s.read_exact(&mut idbuf)?;
            let q = u64::from_le_bytes(idbuf) as usize;
            if q >= parties || streams[q].is_some() || q == party {
                return Err(Error::Transport(format!("unexpected peer id {q}")));
            }
            s.set_nodelay(true).ok();
            streams[q] = Some(s);
        }
        Ok(TcpTransport { party, parties, streams, seq: 0, trace: Arc::new(CommTrace::new()) })
    }
}

fn dial_with_retry(addr: &str) -> Result<TcpStream> {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if std::time::Instant::now() > deadline {
                    return Err(Error::Transport(format!("connect {addr}: {e}")));
                }
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
        }
    }
}

fn write_frame(s: &mut TcpStream, seq: u64, payload: &[u8]) -> Result<()> {
    s.write_all(&seq.to_le_bytes())?;
    s.write_all(&(payload.len() as u64).to_le_bytes())?;
    s.write_all(payload)?;
    Ok(())
}

fn read_frame(s: &mut TcpStream, want_seq: u64) -> Result<Vec<u8>> {
    let mut hdr = [0u8; 16];
    s.read_exact(&mut hdr)?;
    let seq = u64::from_le_bytes(hdr[0..8].try_into().unwrap());
    if seq != want_seq {
        return Err(Error::Transport(format!("out-of-order frame: got {seq}, want {want_seq}")));
    }
    let len = u64::from_le_bytes(hdr[8..16].try_into().unwrap()) as usize;
    if len > (1 << 32) {
        return Err(Error::Transport(format!("frame too large: {len}")));
    }
    let mut payload = vec![0u8; len];
    s.read_exact(&mut payload)?;
    Ok(payload)
}

impl Transport for TcpTransport {
    fn party(&self) -> usize {
        self.party
    }
    fn parties(&self) -> usize {
        self.parties
    }

    fn exchange_all(&mut self, phase: Phase, data: &[u8]) -> Result<Vec<Vec<u8>>> {
        let t0 = std::time::Instant::now();
        let seq = self.seq;
        self.seq += 1;
        // Write to all peers, then read from all peers. Per-link frames are
        // small enough that the kernel buffers absorb the write side; a
        // full-duplex implementation with writer threads is unnecessary at
        // our message sizes (< 16 MiB) and socket buffer tuning.
        for q in 0..self.parties {
            if q == self.party {
                continue;
            }
            write_frame(self.streams[q].as_mut().unwrap(), seq, data)?;
        }
        let mut out: Vec<Vec<u8>> = vec![Vec::new(); self.parties];
        for q in 0..self.parties {
            if q == self.party {
                out[q] = data.to_vec();
            } else {
                out[q] = read_frame(self.streams[q].as_mut().unwrap(), seq)?;
            }
        }
        self.trace.record(phase, (data.len() * (self.parties - 1)) as u64);
        self.trace.record_wait(t0.elapsed());
        Ok(out)
    }

    fn trace(&self) -> Arc<CommTrace> {
        Arc::clone(&self.trace)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two parties over loopback sockets exchange several rounds.
    #[test]
    fn two_party_loopback() {
        let addrs = vec!["127.0.0.1:39411".to_string(), "127.0.0.1:39412".to_string()];
        let a0 = addrs.clone();
        let h = std::thread::spawn(move || {
            let mut t = TcpTransport::connect(0, &a0).unwrap();
            for r in 0..5u8 {
                let got = t.exchange_all(Phase::Circuit, &[r, 0]).unwrap();
                assert_eq!(got[1], vec![r, 1]);
            }
            t.trace().total_bytes()
        });
        let mut t = TcpTransport::connect(1, &addrs).unwrap();
        for r in 0..5u8 {
            let got = t.exchange_all(Phase::Circuit, &[r, 1]).unwrap();
            assert_eq!(got[0], vec![r, 0]);
        }
        assert_eq!(h.join().unwrap(), 10);
        assert_eq!(t.trace().total_rounds(), 5);
    }
}
