"""Layer-1 Pallas kernel: tiled ring matmul for linear layers on shares.

Linear layers in the shared-model setting are *local* per-party matmuls of
the party's int64 share against public (quantized) weights, with natural
mod-2^64 wraparound. This kernel is the compute hot spot of the non-ReLU
part of the pipeline; conv layers reach it through im2col (see model.py).

TPU mapping (what the BlockSpec grid expresses): classic (M/bm, N/bn, K/bk)
tiling with the K axis innermost ("arbitrary" semantics -> sequential), the
output tile accumulated in VMEM across K steps. Tile sizes 128x128x128 on
int64 = 3 x 128 KiB of VMEM per step. On real TPU hardware the MXU path
would want int32/bf16 splits of the 64-bit ring product (see DESIGN.md
§Hardware-Adaptation) - on the CPU interpret/HLO path int64 dot is native.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

I64 = jnp.int64
BM, BN, BK = 128, 128, 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=I64)


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def share_matmul(x, w):
    """(x @ w) mod 2^64 for int64 x:[M,K], w:[K,N] via the Pallas kernel.

    Shapes are padded up to the 128-tile grid and the result sliced back, so
    one lowering works for arbitrary layer shapes.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    xp = _pad_to(_pad_to(x, BM, 0), BK, 1)
    wp = _pad_to(_pad_to(w, BK, 0), BN, 1)
    mp, kp = xp.shape
    _, np_ = wp.shape
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // BM, np_ // BN, kp // BK),
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((BK, BN), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), I64),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]
