//! `hblint` wired into the tier-1 suite (DESIGN.md §8): the tree must be
//! lint-clean, and the seeded fixture must reproduce every violation — so
//! `cargo test -q` catches both a new violation and a rule going blind,
//! even before the dedicated CI step runs.

use std::path::Path;

use hummingbird::analysis;

#[test]
fn tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let findings = analysis::scan_tree(root).expect("hblint tree scan must succeed");
    assert!(
        findings.is_empty(),
        "hblint findings (fix or annotate per DESIGN.md §8):\n{}",
        findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
    );
}

#[test]
fn self_test_reproduces_seeded_violations() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let n = analysis::self_test(root).expect("hblint self-test must pass");
    assert!(n >= 8, "fixture should seed >= 8 violations across the five rules, got {n}");
}
