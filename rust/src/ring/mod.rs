//! Ring arithmetic over Z/2^N and fixed-point encoding (paper §2.2).
//!
//! Ring elements are stored as `u64` with wrapping arithmetic; "signed"
//! reads interpret the element in two's complement, matching the paper's
//! "an element in a ring of size 2^n is always in an n-bit signed integer
//! representation". Bit windows `x[k:m]` (paper notation: bits m..k-1,
//! k exclusive) produce elements of the smaller ring Z/2^(k-m) — the core
//! operation of HummingBird's reduced-ring DReLU.

/// Full ring width used by the runtime (CrypTen default).
pub const RING_BITS: u32 = 64;

/// Default fixed-point fractional bits (CrypTen uses 16).
pub const DEFAULT_SCALE_BITS: u32 = 16;

/// Fixed-point codec: float <-> ring element with `frac_bits` of fraction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FixedPoint {
    pub frac_bits: u32,
}

impl Default for FixedPoint {
    fn default() -> Self {
        FixedPoint { frac_bits: DEFAULT_SCALE_BITS }
    }
}

impl FixedPoint {
    pub fn new(frac_bits: u32) -> Self {
        assert!(frac_bits < RING_BITS, "frac_bits must be < {RING_BITS}");
        FixedPoint { frac_bits }
    }

    /// Scale factor D = 2^frac_bits as f64.
    #[inline]
    pub fn scale(&self) -> f64 {
        (1u64 << self.frac_bits) as f64
    }

    /// Encode x_f -> floor-rounded ring element: x = round(D * x_f) mod 2^64.
    #[inline]
    pub fn encode(&self, x: f64) -> u64 {
        let v = (x * self.scale()).round();
        // Saturate rather than UB-cast when wildly out of range; the model
        // layer keeps values far below this anyway.
        let v = v.clamp(-(2f64.powi(62)), 2f64.powi(62));
        (v as i64) as u64
    }

    /// Decode ring element -> f64 (signed two's-complement read).
    #[inline]
    pub fn decode(&self, x: u64) -> f64 {
        (x as i64) as f64 / self.scale()
    }

    /// Encode a slice.
    pub fn encode_vec(&self, xs: &[f64]) -> Vec<u64> {
        xs.iter().map(|x| self.encode(*x)).collect()
    }

    /// Decode a slice.
    pub fn decode_vec(&self, xs: &[u64]) -> Vec<f64> {
        xs.iter().map(|x| self.decode(*x)).collect()
    }
}

/// Signed two's-complement interpretation of a ring element.
#[inline]
pub fn to_signed(x: u64) -> i64 {
    x as i64
}

/// Is the element negative when read as an N-bit signed integer?
#[inline]
pub fn is_negative(x: u64) -> bool {
    (x >> (RING_BITS - 1)) & 1 == 1
}

/// DReLU on a plaintext ring element: 1 iff x >= 0 (paper treats 0 as
/// positive), else 0.
#[inline]
pub fn drelu_plain(x: u64) -> u64 {
    (!is_negative(x)) as u64
}

/// Extract the bit window x[k:m] (bits m..k-1 inclusive, k exclusive) as an
/// element of Z/2^(k-m), stored in the low k-m bits of the result.
///
/// Matches the paper's example: x = 0b11011101, x[5:1] = 0b1110.
#[inline]
pub fn bit_window(x: u64, k: u32, m: u32) -> u64 {
    debug_assert!(m < k && k <= RING_BITS, "invalid window [{m},{k})");
    let w = k - m;
    if w == RING_BITS {
        x
    } else {
        (x >> m) & ((1u64 << w) - 1)
    }
}

/// Sign (MSB) of a w-bit ring element stored in the low bits: bit w-1.
#[inline]
pub fn msb_w(x: u64, w: u32) -> u64 {
    debug_assert!(w >= 1 && w <= RING_BITS);
    (x >> (w - 1)) & 1
}

/// DReLU of a w-bit ring element stored in the low bits (1 iff non-negative
/// in the w-bit two's-complement reading).
#[inline]
pub fn drelu_w(x: u64, w: u32) -> u64 {
    1 ^ msb_w(x, w)
}

/// Mask keeping the low `w` bits (w in 1..=64).
#[inline]
pub fn low_mask(w: u32) -> u64 {
    if w >= 64 {
        u64::MAX
    } else {
        (1u64 << w) - 1
    }
}

/// Sign-extend a w-bit value (stored in low bits) to a full i64.
#[inline]
pub fn sign_extend(x: u64, w: u32) -> i64 {
    debug_assert!(w >= 1 && w <= 64);
    let shift = 64 - w;
    ((x << shift) as i64) >> shift
}

/// CrypTen-style local truncation of an arithmetic *share* by 2^f.
///
/// Party 0 computes `share >> f` (arithmetic shift on the signed read);
/// every other party computes `-((-share) >> f)`. For 2 parties this
/// reproduces CrypTen's `div` with at most 1 ulp of error and negligible
/// wrap-around probability while |x| << 2^(64-f).
#[inline]
pub fn trunc_share(share: u64, f: u32, party: usize) -> u64 {
    if f == 0 {
        return share;
    }
    if party == 0 {
        ((share as i64) >> f) as u64
    } else {
        let neg = (share as i64).wrapping_neg();
        (neg >> f).wrapping_neg() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::prg::Prg;

    #[test]
    fn fixed_point_roundtrip() {
        let fp = FixedPoint::new(16);
        for &x in &[0.0, 1.0, -1.0, 0.5, -0.5, 123.456, -9876.54321, 1e-4] {
            let e = fp.encode(x);
            let d = fp.decode(e);
            assert!((d - x).abs() <= 1.0 / fp.scale(), "{x} -> {d}");
        }
    }

    #[test]
    fn signed_reads() {
        assert_eq!(to_signed(u64::MAX), -1);
        assert!(is_negative(u64::MAX));
        assert!(!is_negative(0));
        assert_eq!(drelu_plain(0), 1); // paper: zero counts as positive
        assert_eq!(drelu_plain(5u64.wrapping_neg()), 0);
        assert_eq!(drelu_plain(7), 1);
    }

    #[test]
    fn bit_window_matches_paper_example() {
        // x = 11011101b, x[5:1] = 1110b
        let x = 0b1101_1101u64;
        assert_eq!(bit_window(x, 5, 1), 0b1110);
        assert_eq!(bit_window(x, 8, 0), x);
        assert_eq!(bit_window(u64::MAX, 64, 0), u64::MAX);
        assert_eq!(bit_window(u64::MAX, 64, 32), u32::MAX as u64);
    }

    #[test]
    fn msb_and_drelu_on_small_ring() {
        // w = 4: values 0..7 non-negative, 8..15 negative
        for v in 0u64..16 {
            let expect = if v < 8 { 1 } else { 0 };
            assert_eq!(drelu_w(v, 4), expect, "v={v}");
        }
    }

    #[test]
    fn sign_extend_works() {
        assert_eq!(sign_extend(0b1110, 4), -2);
        assert_eq!(sign_extend(0b0110, 4), 6);
        assert_eq!(sign_extend(u64::MAX, 64), -1);
    }

    /// Theorem-1 sanity on plaintext: for |x| < 2^(k-1), the k-bit window
    /// preserves the sign decision.
    #[test]
    fn theorem1_plaintext() {
        let fp = FixedPoint::new(8);
        for k in 10..20u32 {
            let bound = 1i64 << (k - 1);
            for &xi in &[-bound + 1, -5, -1, 0, 1, 5, bound - 1] {
                let x = xi as u64;
                let win = bit_window(x, k, 0);
                assert_eq!(drelu_w(win, k), drelu_plain(x), "k={k} x={xi}");
            }
        }
        let _ = fp;
    }

    /// Truncation of shares reconstructs to x/2^f within 1 ulp (2 parties).
    #[test]
    fn trunc_share_reconstructs() {
        let mut prg = Prg::new(99, 0);
        let f = 16u32;
        for _ in 0..2000 {
            // |x| < 2^40 so wrap-around probability is negligible
            let x = (prg.next_u64() % (1u64 << 40)) as i64 - (1i64 << 39);
            let x = x as u64;
            let r = prg.next_u64();
            let a0 = r;
            let a1 = x.wrapping_sub(r);
            let t = trunc_share(a0, f, 0).wrapping_add(trunc_share(a1, f, 1));
            let expect = (x as i64) >> f;
            let got = t as i64;
            assert!((got - expect).abs() <= 1, "x={} got={} expect={}", x as i64, got, expect);
        }
    }
}
