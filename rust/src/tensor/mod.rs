//! Minimal dense tensors used throughout the runtime.
//!
//! Two concrete element types cover every need: `TensorU64` for ring
//! elements / secret shares, and `TensorF32` for plaintext model math
//! (search engine, verification). Shapes are row-major `Vec<usize>`.

use crate::error::{Error, Result};

/// Dense row-major u64 tensor (ring elements, shares, packed bits).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorU64 {
    pub shape: Vec<usize>,
    pub data: Vec<u64>,
}

/// Dense row-major f32 tensor (plaintext activations / weights).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorF32 {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

macro_rules! tensor_common {
    ($name:ident, $elem:ty, $zero:expr) => {
        impl $name {
            /// Create from shape and data, checking element count.
            pub fn new(shape: Vec<usize>, data: Vec<$elem>) -> Result<Self> {
                if numel(&shape) != data.len() {
                    return Err(Error::shape(format!(
                        "shape {:?} needs {} elems, got {}",
                        shape,
                        numel(&shape),
                        data.len()
                    )));
                }
                Ok(Self { shape, data })
            }

            /// Zero-filled tensor.
            pub fn zeros(shape: Vec<usize>) -> Self {
                let n = numel(&shape);
                Self { shape, data: vec![$zero; n] }
            }

            /// Total element count.
            pub fn len(&self) -> usize {
                self.data.len()
            }

            pub fn is_empty(&self) -> bool {
                self.data.is_empty()
            }

            /// Reshape in place (element count must match).
            pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
                if numel(&shape) != self.data.len() {
                    return Err(Error::shape(format!(
                        "cannot reshape {:?} ({} elems) to {:?}",
                        self.shape,
                        self.data.len(),
                        shape
                    )));
                }
                self.shape = shape;
                Ok(self)
            }

            /// Rank-1 view constructor.
            pub fn from_vec(data: Vec<$elem>) -> Self {
                let n = data.len();
                Self { shape: vec![n], data }
            }
        }
    };
}

tensor_common!(TensorU64, u64, 0u64);
tensor_common!(TensorF32, f32, 0f32);

impl TensorU64 {
    /// Element-wise wrapping add (ring addition).
    pub fn wrapping_add(&self, other: &TensorU64) -> Result<TensorU64> {
        if self.shape != other.shape {
            return Err(Error::shape(format!("add {:?} vs {:?}", self.shape, other.shape)));
        }
        let data =
            self.data.iter().zip(&other.data).map(|(a, b)| a.wrapping_add(*b)).collect();
        Ok(TensorU64 { shape: self.shape.clone(), data })
    }

    /// Element-wise wrapping add into `self` (ring addition, no new
    /// buffer — the serving hot path's residual-add form).
    pub fn wrapping_add_assign(&mut self, other: &TensorU64) -> Result<()> {
        if self.shape != other.shape {
            return Err(Error::shape(format!("add {:?} vs {:?}", self.shape, other.shape)));
        }
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a = a.wrapping_add(*b);
        }
        Ok(())
    }

    /// Element-wise XOR (binary-share addition).
    pub fn xor(&self, other: &TensorU64) -> Result<TensorU64> {
        if self.shape != other.shape {
            return Err(Error::shape(format!("xor {:?} vs {:?}", self.shape, other.shape)));
        }
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a ^ b).collect();
        Ok(TensorU64 { shape: self.shape.clone(), data })
    }

    /// Reinterpret the data as i64 (two's complement), for PJRT transfer.
    pub fn as_i64_vec(&self) -> Vec<i64> {
        self.data.iter().map(|v| *v as i64).collect()
    }

    /// Build from an i64 vec (PJRT results come back as i64).
    pub fn from_i64(shape: Vec<usize>, data: Vec<i64>) -> Result<Self> {
        TensorU64::new(shape, data.into_iter().map(|v| v as u64).collect())
    }
}

impl TensorF32 {
    /// Max absolute value (used by the eco search to bound ranges).
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0f32, |m, v| m.max(v.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_shape() {
        assert!(TensorU64::new(vec![2, 3], vec![0; 6]).is_ok());
        assert!(TensorU64::new(vec![2, 3], vec![0; 5]).is_err());
    }

    #[test]
    fn reshape_checks_count() {
        let t = TensorU64::zeros(vec![4, 2]);
        assert!(t.clone().reshape(vec![8]).is_ok());
        assert!(t.reshape(vec![3, 3]).is_err());
    }

    #[test]
    fn ring_ops_wrap() {
        let a = TensorU64::from_vec(vec![u64::MAX, 1]);
        let b = TensorU64::from_vec(vec![1, 2]);
        assert_eq!(a.wrapping_add(&b).unwrap().data, vec![0, 3]);
        assert_eq!(a.xor(&b).unwrap().data, vec![u64::MAX - 1, 3]);
        assert!(a.wrapping_add(&TensorU64::zeros(vec![3])).is_err());
        // In-place form matches the allocating form and keeps the buffer.
        let mut c = a.clone();
        let ptr = c.data.as_ptr();
        c.wrapping_add_assign(&b).unwrap();
        assert_eq!(c.data, vec![0, 3]);
        assert_eq!(c.data.as_ptr(), ptr);
        assert!(c.wrapping_add_assign(&TensorU64::zeros(vec![3])).is_err());
    }

    #[test]
    fn i64_roundtrip() {
        let t = TensorU64::from_vec(vec![u64::MAX, 0, 42]);
        let back = TensorU64::from_i64(vec![3], t.as_i64_vec()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn f32_max_abs() {
        let t = TensorF32::from_vec(vec![-3.5, 2.0, 1.0]);
        assert_eq!(t.max_abs(), 3.5);
    }
}
