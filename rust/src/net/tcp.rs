//! Real TCP transport for multi-process deployments (`hummingbird party`).
//!
//! Framing: each message is `[seq: u64 le][len: u64 le][payload]`. The
//! mesh is fully connected; party i listens for connections from parties
//! j > i and dials parties j < i, so an n-party mesh needs no coordinator.
//!
//! The receive path reads frames directly into the caller's [`RecvBufs`]
//! slots (`read_frame_into`): once a session has seen its largest frame,
//! steady-state rounds perform zero receive-side allocations. The send
//! path writes the caller's payload straight to the socket and never
//! allocates.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use super::accounting::{CommTrace, Phase};
use super::{RecvBufs, Transport};
use crate::error::{Error, Result};

/// TCP endpoint for one party.
pub struct TcpTransport {
    party: usize,
    parties: usize,
    /// Peer streams indexed by party id (entry for self is None).
    streams: Vec<Option<TcpStream>>,
    seq: u64,
    trace: Arc<CommTrace>,
}

impl TcpTransport {
    /// Connect the mesh. `addrs[p]` is the listen address of party p
    /// (e.g. "127.0.0.1:9001"). Blocks until all links are up.
    pub fn connect(party: usize, addrs: &[String]) -> Result<TcpTransport> {
        let parties = addrs.len();
        if party >= parties || parties < 2 {
            return Err(Error::config(format!("bad party id {party} for {parties} parties")));
        }
        let mut streams: Vec<Option<TcpStream>> = (0..parties).map(|_| None).collect();

        // Accept from higher-ranked peers.
        let listener = TcpListener::bind(&addrs[party])
            .map_err(|e| Error::Transport(format!("bind {}: {e}", addrs[party])))?;
        // Dial lower-ranked peers (with retry while they come up).
        for (q, addr) in addrs.iter().enumerate().take(party) {
            let stream = dial_with_retry(addr)?;
            // Identify ourselves.
            let mut s = stream;
            s.write_all(&(party as u64).to_le_bytes())?;
            s.set_nodelay(true).ok();
            streams[q] = Some(s);
        }
        for _ in party + 1..parties {
            let (mut s, _) = listener
                .accept()
                .map_err(|e| Error::Transport(format!("accept: {e}")))?;
            let mut idbuf = [0u8; 8];
            s.read_exact(&mut idbuf)?;
            let q = u64::from_le_bytes(idbuf) as usize;
            if q >= parties || streams[q].is_some() || q == party {
                return Err(Error::Transport(format!("unexpected peer id {q}")));
            }
            s.set_nodelay(true).ok();
            streams[q] = Some(s);
        }
        Ok(TcpTransport { party, parties, streams, seq: 0, trace: Arc::new(CommTrace::new()) })
    }
}

fn dial_with_retry(addr: &str) -> Result<TcpStream> {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if std::time::Instant::now() > deadline {
                    return Err(Error::Transport(format!("connect {addr}: {e}")));
                }
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
        }
    }
}

fn write_frame(s: &mut TcpStream, seq: u64, payload: &[u8]) -> Result<()> {
    s.write_all(&seq.to_le_bytes())?;
    s.write_all(&(payload.len() as u64).to_le_bytes())?;
    s.write_all(payload)?;
    Ok(())
}

/// Read one frame into `out` without a memset (the `RecvBufs` fill
/// contract): overwrite the already-initialized prefix in place, then
/// append any remainder — `Take::read_to_end` fills spare capacity
/// directly, so growth within capacity neither allocates nor pre-zeroes.
fn read_frame_into(s: &mut TcpStream, want_seq: u64, out: &mut Vec<u8>) -> Result<()> {
    let mut hdr = [0u8; 16];
    s.read_exact(&mut hdr)?;
    let seq = u64::from_le_bytes(hdr[0..8].try_into().unwrap());
    if seq != want_seq {
        return Err(Error::Transport(format!("out-of-order frame: got {seq}, want {want_seq}")));
    }
    let len = u64::from_le_bytes(hdr[8..16].try_into().unwrap()) as usize;
    if len > (1 << 32) {
        return Err(Error::Transport(format!("frame too large: {len}")));
    }
    if out.len() > len {
        out.truncate(len);
    }
    let prefix = out.len();
    s.read_exact(&mut out[..prefix])?;
    if len > prefix {
        let appended = s.by_ref().take((len - prefix) as u64).read_to_end(out)?;
        if appended != len - prefix {
            return Err(Error::Transport(format!(
                "short frame: got {} of {len} bytes",
                prefix + appended
            )));
        }
    }
    Ok(())
}

impl Transport for TcpTransport {
    fn party(&self) -> usize {
        self.party
    }
    fn parties(&self) -> usize {
        self.parties
    }

    fn exchange_all_into(
        &mut self,
        phase: Phase,
        data: &[u8],
        recv: &mut RecvBufs,
    ) -> Result<()> {
        if recv.parties() != self.parties {
            return Err(Error::Transport(format!(
                "RecvBufs sized for {} parties, mesh has {}",
                recv.parties(),
                self.parties
            )));
        }
        let t0 = std::time::Instant::now();
        let seq = self.seq;
        self.seq += 1;
        // Write to all peers, then read from all peers. Per-link frames are
        // small enough that the kernel buffers absorb the write side; a
        // full-duplex implementation with writer threads is unnecessary at
        // our message sizes (< 16 MiB) and socket buffer tuning.
        for q in 0..self.parties {
            if q == self.party {
                continue;
            }
            write_frame(self.streams[q].as_mut().unwrap(), seq, data)?;
        }
        let slots = recv.slots_mut();
        for q in 0..self.parties {
            if q == self.party {
                continue;
            }
            read_frame_into(self.streams[q].as_mut().unwrap(), seq, &mut slots[q])?;
        }
        self.trace.record(phase, (data.len() * (self.parties - 1)) as u64);
        self.trace.record_wait(t0.elapsed());
        Ok(())
    }

    fn trace(&self) -> Arc<CommTrace> {
        Arc::clone(&self.trace)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two parties over loopback sockets exchange several rounds.
    #[test]
    fn two_party_loopback() {
        let addrs = vec!["127.0.0.1:39411".to_string(), "127.0.0.1:39412".to_string()];
        let a0 = addrs.clone();
        let h = std::thread::spawn(move || {
            let mut t = TcpTransport::connect(0, &a0).unwrap();
            for r in 0..5u8 {
                let got = t.exchange_all(Phase::Circuit, &[r, 0]).unwrap();
                assert_eq!(got[1], vec![r, 1]);
            }
            t.trace().total_bytes()
        });
        let mut t = TcpTransport::connect(1, &addrs).unwrap();
        for r in 0..5u8 {
            let got = t.exchange_all(Phase::Circuit, &[r, 1]).unwrap();
            assert_eq!(got[0], vec![r, 0]);
        }
        assert_eq!(h.join().unwrap(), 10);
        assert_eq!(t.trace().total_rounds(), 5);
    }

    /// The into-variant over loopback: slots are filled per round and the
    /// slot allocations stay put once warm (pointer-stable across rounds).
    #[test]
    fn loopback_exchange_into_reuses_slots() {
        let addrs = vec!["127.0.0.1:39413".to_string(), "127.0.0.1:39414".to_string()];
        let a0 = addrs.clone();
        let h = std::thread::spawn(move || {
            let mut t = TcpTransport::connect(0, &a0).unwrap();
            let mut recv = RecvBufs::new(2);
            for r in 0..6u8 {
                let payload = vec![r, 0, 0, 0];
                t.exchange_all_into(Phase::Circuit, &payload, &mut recv).unwrap();
                assert_eq!(recv.get(1), [r, 1, 1, 1]);
            }
        });
        let mut t = TcpTransport::connect(1, &addrs).unwrap();
        let mut recv = RecvBufs::new(2);
        let mut warm_ptr = None;
        for r in 0..6u8 {
            let payload = vec![r, 1, 1, 1];
            t.exchange_all_into(Phase::Circuit, &payload, &mut recv).unwrap();
            assert_eq!(recv.get(0), [r, 0, 0, 0]);
            let ptr = recv.get(0).as_ptr();
            match warm_ptr {
                None => warm_ptr = Some(ptr),
                Some(p) => assert_eq!(p, ptr, "warm slot must not reallocate (round {r})"),
            }
        }
        h.join().unwrap();
    }
}
