//! Bitpacking wire library (paper §4.2).
//!
//! HummingBird's online phase "efficiently packs and unpacks the subset of
//! bits into a 64-bit tensor before and after each communication". This
//! module is that library: `n` lanes of `w`-bit values (stored one value per
//! u64, low bits) are packed into `ceil(n*w/64)` dense u64 words for the
//! wire, and unpacked on receipt.
//!
//! # Fused wire path (the GMW hot path)
//!
//! The protocol engine never materializes an intermediate full-width lane
//! vector around a communication round. Instead it uses the fused pair:
//!
//! * [`pack_bytes_into`] — packs masked openings **directly into the wire
//!   byte buffer** (an arena-pooled `Vec<u8>`), computing each output word
//!   independently with [`packed_word`] so the work parallelizes across
//!   words and performs zero allocations when the buffer has capacity.
//! * [`unpack_bytes_xor_into`] — unpacks a peer's wire bytes and XOR-folds
//!   them **directly into the caller's lane buffer**, one independent read
//!   per lane, again allocation-free and parallel.
//!
//! Both are bit-exact with the classic [`pack`]/[`unpack`] pair (kept for
//! tests, benches and non-hot-path users) for every `w ∈ 1..=64`, every lane
//! count and every thread count — the round-trip tests below sweep all of it.
//! Threading: callers pass an explicit thread count (the engine's `--threads`
//! knob); small inputs always run inline (thresholds live in
//! [`crate::util::tuning`], env-overridable), so single-lane openings never
//! pay spawn overhead.
//!
//! Kernel dispatch note (DESIGN.md §11): this lane-layout pack is the one
//! wire path that deliberately stays scalar under `--kernel simd`. Each
//! output word gathers a *data-dependent* number of variably-shifted lanes
//! (`w ∤ 64` makes the lane/offset pattern aperiodic), which does not map
//! onto AVX2's uniform-shift lane ops the way the bitsliced transpose does
//! — and the loop is already word-parallel and memory-bound. The bitsliced
//! layout's wire path ([`crate::gmw::bitsliced::pack_planes_xor_into`]) is
//! the vectorized counterpart; both produce byte-identical wire streams.

use crate::ring::low_mask;
use crate::util::threadpool::{par_chunks, par_chunks_mut, SendPtr};
use crate::util::tuning;

/// Number of u64 words needed to pack `n` lanes of `w` bits.
#[inline]
pub fn packed_len(n: usize, w: u32) -> usize {
    ((n as u64 * w as u64).div_ceil(64)) as usize
}

/// Exact number of *bytes* on the wire for `n` lanes of `w` bits.
///
/// Byte-granular (not word-granular) so communication accounting matches
/// the paper's "bits communicated" model as closely as possible.
#[inline]
pub fn packed_bytes(n: usize, w: u32) -> u64 {
    (n as u64 * w as u64).div_ceil(8)
}

/// Compute output word `j` of the packed stream independently of all other
/// words: gathers the lanes overlapping bit range `[64j, 64j+64)`.
///
/// Lanes must have their high bits (above `w`) zero; `pack`/`pack_bytes_into`
/// debug-assert this before calling.
#[inline]
pub fn packed_word(src: &[u64], w: u32, j: usize) -> u64 {
    let w64 = w as u64;
    let start_bit = 64u64 * j as u64;
    let mut lane = (start_bit / w64) as usize;
    // How many low bits of the first lane were already emitted in word j-1.
    let mut lane_off = (start_bit % w64) as u32;
    let mut out = 0u64;
    let mut bit = 0u32;
    while bit < 64 && lane < src.len() {
        let avail = w - lane_off;
        // High bits above `avail` are zero by the lane-width invariant, and
        // bits spilling past the word boundary are dropped by the shift.
        out |= (src[lane] >> lane_off) << bit;
        bit += avail;
        lane += 1;
        lane_off = 0;
    }
    out
}

/// Extract lane `i` (a `w`-bit value) from a packed word stream, where
/// word `j` is provided by `word(j)` (zero for out-of-range `j`).
#[inline]
pub(crate) fn lane_from_words(word: impl Fn(usize) -> u64, w: u32, mask: u64, i: usize) -> u64 {
    let bit = i as u64 * w as u64;
    let j = (bit / 64) as usize;
    let off = (bit % 64) as u32;
    let lo = word(j) >> off;
    if w <= 64 - off {
        lo & mask
    } else {
        (lo | (word(j + 1) << (64 - off))) & mask
    }
}

/// Read word `j` from a little-endian byte stream, zero-padding past the end
/// (wire buffers are byte-granular, so the final word may be partial).
#[inline]
pub(crate) fn word_at(bytes: &[u8], j: usize) -> u64 {
    let lo = j * 8;
    if lo + 8 <= bytes.len() {
        let mut buf = [0u8; 8];
        buf.copy_from_slice(&bytes[lo..lo + 8]);
        u64::from_le_bytes(buf)
    } else if lo < bytes.len() {
        let mut buf = [0u8; 8];
        let n = bytes.len() - lo;
        buf[..n].copy_from_slice(&bytes[lo..]);
        u64::from_le_bytes(buf)
    } else {
        0
    }
}

#[inline]
fn debug_assert_lane_widths(src: &[u64], w: u32) {
    if cfg!(debug_assertions) && w < 64 {
        for &v in src {
            debug_assert_eq!(v >> w, 0, "lane has bits above width {w}");
        }
    }
}

/// Pack `src` (one w-bit value per u64 lane, low bits; high bits MUST be
/// zero) into dense u64 words, little-endian bit order.
pub fn pack(src: &[u64], w: u32, dst: &mut Vec<u64>) {
    debug_assert!(w >= 1 && w <= 64);
    debug_assert_lane_widths(src, w);
    dst.clear();
    dst.resize(packed_len(src.len(), w), 0);
    if w == 64 {
        dst.copy_from_slice(src);
        return;
    }
    for (j, d) in dst.iter_mut().enumerate() {
        *d = packed_word(src, w, j);
    }
}

/// Unpack `n` lanes of `w`-bit values from dense words (inverse of [`pack`]).
pub fn unpack(src: &[u64], w: u32, n: usize, dst: &mut Vec<u64>) {
    debug_assert!(w >= 1 && w <= 64);
    let needed = packed_len(n, w);
    assert!(src.len() >= needed, "packed buffer too short");
    dst.clear();
    dst.resize(n, 0);
    if w == 64 {
        dst.copy_from_slice(&src[..n]);
        return;
    }
    let mask = low_mask(w);
    for (i, d) in dst.iter_mut().enumerate() {
        *d = lane_from_words(|j| if j < src.len() { src[j] } else { 0 }, w, mask, i);
    }
}

/// Fused pack-to-wire: pack `src` directly into the byte buffer `dst`
/// (cleared and resized to exactly [`packed_bytes`]). No intermediate word
/// vector; zero allocations when `dst` already has capacity. `threads > 1`
/// splits the word range across OS threads for large inputs.
pub fn pack_bytes_into(src: &[u64], w: u32, dst: &mut Vec<u8>, threads: usize) {
    debug_assert!(w >= 1 && w <= 64);
    debug_assert_lane_widths(src, w);
    let nbytes = packed_bytes(src.len(), w) as usize;
    // The word writes below cover every byte of [0, nbytes), so a buffer
    // already at the right length (the warm arena path) needs no clearing
    // — resizing only when the length differs avoids a memset per round.
    if dst.len() != nbytes {
        dst.clear();
        dst.resize(nbytes, 0);
    }
    let nwords = packed_len(src.len(), w);
    let threads = if nwords >= tuning::par_min_words() { threads } else { 1 };
    // Each word j owns the disjoint byte range [8j, min(8j+8, nbytes)).
    let out = SendPtr(dst.as_mut_ptr());
    let out_ref = &out;
    par_chunks(nwords, threads, move |_, range| {
        for j in range {
            let word = packed_word(src, w, j).to_le_bytes();
            let lo = j * 8;
            let nb = (nbytes - lo).min(8);
            // SAFETY: word j writes only its own byte range (disjoint per j),
            // and lo + nb <= nbytes = dst.len().
            unsafe {
                std::ptr::copy_nonoverlapping(word.as_ptr(), out_ref.get().add(lo), nb);
            }
        }
    });
}

/// Fused unpack-and-fold: extract `n` lanes of `w`-bit values from the wire
/// bytes `src` and XOR each into `out[i]` in place. This is the receive side
/// of every binary opening: peers' packed shares fold directly into the
/// caller's (arena-owned) lane buffer with no intermediate vector.
pub fn unpack_bytes_xor_into(src: &[u8], w: u32, n: usize, out: &mut [u64], threads: usize) {
    debug_assert!(w >= 1 && w <= 64);
    debug_assert!(out.len() >= n, "output buffer too short");
    debug_assert!(
        src.len() as u64 >= packed_bytes(n, w),
        "wire buffer too short: {} < {}",
        src.len(),
        packed_bytes(n, w)
    );
    let mask = low_mask(w);
    let threads = if n >= tuning::par_min_lanes() { threads } else { 1 };
    par_chunks_mut(&mut out[..n], threads, |off, chunk| {
        for (i, o) in chunk.iter_mut().enumerate() {
            *o ^= lane_from_words(|j| word_at(src, j), w, mask, off + i);
        }
    });
}

/// Pack directly to a freshly-allocated byte buffer (the wire format).
/// Trailing partial byte is zero-padded. Non-hot-path convenience; the
/// engine uses [`pack_bytes_into`] with a pooled buffer.
pub fn pack_bytes(src: &[u64], w: u32) -> Vec<u8> {
    // HOT-PATH-ALLOW: by-value wrapper — engine uses `pack_bytes_into`.
    let mut out = Vec::new();
    pack_bytes_into(src, w, &mut out, 1);
    out
}

/// Unpack from a byte buffer produced by [`pack_bytes`]. Non-hot-path
/// convenience; the engine uses [`unpack_bytes_xor_into`].
pub fn unpack_bytes(src: &[u8], w: u32, n: usize) -> Vec<u64> {
    // HOT-PATH-ALLOW: by-value wrapper over `unpack_bytes_xor_into`.
    let mut out = vec![0u64; n];
    unpack_bytes_xor_into(src, w, n, &mut out, 1);
    out
}

/// Naive bit-at-a-time reference implementation (tests compare against it).
pub mod reference {
    use super::packed_len;

    pub fn pack_ref(src: &[u64], w: u32) -> Vec<u64> {
        // HOT-PATH-ALLOW: test-reference implementation, never on the path.
        let mut dst = vec![0u64; packed_len(src.len(), w)];
        let mut pos = 0u64;
        for &v in src {
            for b in 0..w {
                let bit = (v >> b) & 1;
                dst[(pos / 64) as usize] |= bit << (pos % 64);
                pos += 1;
            }
        }
        dst
    }

    pub fn unpack_ref(src: &[u64], w: u32, n: usize) -> Vec<u64> {
        // HOT-PATH-ALLOW: test-reference implementation, never on the path.
        let mut out = vec![0u64; n];
        let mut pos = 0u64;
        for v in out.iter_mut() {
            for b in 0..w {
                let bit = (src[(pos / 64) as usize] >> (pos % 64)) & 1;
                *v |= bit << b;
                pos += 1;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::prg::Prg;

    fn random_lanes(n: usize, w: u32, seed: u64) -> Vec<u64> {
        let mut prg = Prg::new(seed, w as u64);
        let mask = low_mask(w);
        (0..n).map(|_| prg.next_u64() & mask).collect()
    }

    #[test]
    fn roundtrip_all_widths() {
        for w in 1..=64u32 {
            for n in [0usize, 1, 7, 64, 129] {
                let src = random_lanes(n, w, 42);
                let mut packed = Vec::new();
                pack(&src, w, &mut packed);
                let mut back = Vec::new();
                unpack(&packed, w, n, &mut back);
                assert_eq!(src, back, "w={w} n={n}");
            }
        }
    }

    /// Exhaustive byte-path round trip: every width 1..=64 with odd lane
    /// counts chosen to hit every tail-word shape (partial final word,
    /// exactly-full final word, single-lane buffers, lanes straddling word
    /// boundaries), across thread counts.
    #[test]
    #[cfg_attr(miri, ignore = "64-width × tail-shape × thread sweep is too slow interpreted")]
    fn byte_roundtrip_exhaustive_widths_and_tails() {
        for w in 1..=64u32 {
            for n in [1usize, 3, 5, 7, 9, 63, 65, 127, 129] {
                let src = random_lanes(n, w, 1000 + w as u64);
                for threads in [1usize, 2, 4] {
                    let mut wire = Vec::new();
                    pack_bytes_into(&src, w, &mut wire, threads);
                    assert_eq!(
                        wire.len() as u64,
                        packed_bytes(n, w),
                        "wire size w={w} n={n}"
                    );
                    let mut out = vec![0u64; n];
                    unpack_bytes_xor_into(&wire, w, n, &mut out, threads);
                    assert_eq!(src, out, "roundtrip w={w} n={n} threads={threads}");
                    // XOR-fold semantics: folding the same wire again
                    // cancels back to zero.
                    unpack_bytes_xor_into(&wire, w, n, &mut out, threads);
                    assert!(out.iter().all(|v| *v == 0), "fold w={w} n={n}");
                }
            }
        }
    }

    /// `packed_bytes` vs `packed_len` consistency: the byte count the
    /// transport records (and `net::accounting` aggregates) must fit inside
    /// the word buffer and differ by less than one word of padding, for all
    /// widths and odd lane counts.
    #[test]
    fn packed_bytes_consistent_with_packed_len() {
        for w in 1..=64u32 {
            for n in [0usize, 1, 3, 7, 9, 63, 65, 127, 129, 1000, 4096] {
                let bytes = packed_bytes(n, w);
                let words = packed_len(n, w) as u64;
                assert!(bytes <= words * 8, "w={w} n={n}: {bytes} > {}", words * 8);
                assert!(
                    words * 8 < bytes + 8,
                    "w={w} n={n}: word padding exceeds 7 bytes ({bytes} vs {})",
                    words * 8
                );
                // Exact bit accounting.
                assert_eq!(bytes, (n as u64 * w as u64).div_ceil(8), "w={w} n={n}");
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "12 widths × 1000 lanes against the bit-by-bit reference is slow")]
    fn matches_reference() {
        for w in [1u32, 3, 5, 8, 13, 21, 31, 32, 33, 48, 63, 64] {
            let src = random_lanes(1000, w, 7);
            let mut fast = Vec::new();
            pack(&src, w, &mut fast);
            let slow = reference::pack_ref(&src, w);
            assert_eq!(fast, slow, "pack w={w}");
            let mut un = Vec::new();
            unpack(&fast, w, src.len(), &mut un);
            assert_eq!(un, reference::unpack_ref(&slow, w, src.len()), "unpack w={w}");
        }
    }

    /// Miri-sized replica of the exhaustive byte round trip + reference
    /// check: a handful of widths and tail shapes through the threaded
    /// path, so the interpreter still validates every pointer the packers
    /// take (DESIGN.md §8). The big sweeps above cover the full space
    /// natively.
    #[test]
    fn byte_roundtrip_miri_sized() {
        for w in [1u32, 6, 63] {
            for n in [1usize, 65] {
                let src = random_lanes(n, w, 1000 + w as u64);
                let mut wire = Vec::new();
                pack_bytes_into(&src, w, &mut wire, 2);
                assert_eq!(wire.len() as u64, packed_bytes(n, w), "wire size w={w} n={n}");
                assert_eq!(wire, reference_wire(&src, w), "reference w={w} n={n}");
                let mut out = vec![0u64; n];
                unpack_bytes_xor_into(&wire, w, n, &mut out, 2);
                assert_eq!(src, out, "roundtrip w={w} n={n}");
                unpack_bytes_xor_into(&wire, w, n, &mut out, 2);
                assert!(out.iter().all(|v| *v == 0), "fold w={w} n={n}");
            }
        }
    }

    /// The classic reference pack, dumped to wire bytes.
    fn reference_wire(src: &[u64], w: u32) -> Vec<u8> {
        let words = reference::pack_ref(src, w);
        let mut dump: Vec<u8> = Vec::new();
        for wd in &words {
            dump.extend_from_slice(&wd.to_le_bytes());
        }
        dump.truncate(packed_bytes(src.len(), w) as usize);
        dump
    }

    /// The fused byte path agrees bit-for-bit with the word path + LE dump.
    #[test]
    fn fused_bytes_match_word_pack() {
        for w in [1u32, 6, 12, 17, 33, 64] {
            let src = random_lanes(333, w, 3);
            let bytes = pack_bytes(&src, w);
            assert_eq!(bytes.len() as u64, packed_bytes(333, w));
            let mut words = Vec::new();
            pack(&src, w, &mut words);
            let mut dump: Vec<u8> = Vec::new();
            for wd in &words {
                dump.extend_from_slice(&wd.to_le_bytes());
            }
            dump.truncate(bytes.len());
            assert_eq!(bytes, dump, "w={w}");
            let back = unpack_bytes(&bytes, w, 333);
            assert_eq!(src, back, "w={w}");
        }
    }

    /// Multi-threaded pack/unpack is bit-identical to single-threaded on a
    /// buffer large enough to actually engage the thread pool.
    #[test]
    #[cfg_attr(miri, ignore = "65536-lane buffer is too large interpreted")]
    fn threading_is_bit_exact_above_thresholds() {
        let w = 6u32;
        let n = 64 * 1024; // 6144 words packed, 65536 lanes: above both thresholds
        let src = random_lanes(n, w, 11);
        let mut wire1 = Vec::new();
        pack_bytes_into(&src, w, &mut wire1, 1);
        for threads in [2usize, 4, 8] {
            let mut wire_t = Vec::new();
            pack_bytes_into(&src, w, &mut wire_t, threads);
            assert_eq!(wire1, wire_t, "pack threads={threads}");
            let mut out1 = vec![0u64; n];
            unpack_bytes_xor_into(&wire1, w, n, &mut out1, 1);
            let mut out_t = vec![0u64; n];
            unpack_bytes_xor_into(&wire1, w, n, &mut out_t, threads);
            assert_eq!(out1, out_t, "unpack threads={threads}");
            assert_eq!(out1, src);
        }
    }

    #[test]
    fn density_is_optimal() {
        // 100 lanes of 6 bits = 600 bits = 10 words (not 100).
        assert_eq!(packed_len(100, 6), 10);
        assert_eq!(packed_bytes(100, 6), 75);
        assert_eq!(packed_len(0, 17), 0);
    }

    #[test]
    fn compression_ratio_vs_full_ring() {
        // The paper's 8/64 budget: packing must be exactly 8x denser.
        let n = 4096;
        assert_eq!(packed_bytes(n, 64) / packed_bytes(n, 8), 8);
    }
}
