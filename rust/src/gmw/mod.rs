//! GMW protocol engine (paper §2.2) with HummingBird's reduced-ring
//! approximate ReLU (paper §3, Eq. 3).
//!
//! One [`GmwParty`] object per party drives the whole online protocol:
//!
//! * [`GmwParty::and_gates`] — Beaver-masked AND on w-bit lanes (1 round,
//!   2·w bits/elem, bit-packed).
//! * [`adder`] — the Kogge–Stone prefix adder used by A2B.
//! * [`GmwParty::a2b`] — arithmetic→binary conversion: free local
//!   re-sharing (PRG zero-sharing) + circuit addition.
//! * [`GmwParty::b2a_bit`] — 1-bit binary→arithmetic via daBits.
//! * [`GmwParty::drelu`] / [`GmwParty::relu`] — the paper's Equations 1–3;
//!   `ReluPlan { k, m }` selects the bit window (k=64, m=0 is the CrypTen
//!   baseline; anything else is HummingBird).
//! * [`GmwParty::mul`] — Beaver multiplication over Z/2^64 (the "Mult"
//!   phase HummingBird cannot shrink).
//! * [`pipeline`] — WAN-overlapped chunked drivers
//!   ([`GmwParty::relu_chunked_into`]): independent chunks' rounds are
//!   pipelined through the transport's split-phase API so wire latency is
//!   paid once per round wave instead of once per chunk, bit-identical to
//!   the serial schedule (DESIGN.md §10).
//!
//! Local tensor math is factored behind [`kernels::KernelBackend`] so the
//! same protocol can run on pure-Rust kernels or on the Pallas-lowered HLO
//! kernels through PJRT (see `runtime::XlaKernels`).
//!
//! # Zero-allocation hot path
//!
//! Every protocol step has a `*_into` form that writes into a
//! caller-provided buffer; the classic `Vec`-returning methods are thin
//! wrappers that allocate only the final output. Internally all per-round
//! temporaries — triple shares, masked openings, opened values, stage
//! operands, wire byte buffers — are checked out of the party's
//! [`arena::Arena`] and returned when the step completes, and every
//! opening routes through [`Transport::exchange_all_into`] into the
//! party's session-owned [`net::RecvBufs`], so once the pools are warm a
//! steady-state [`GmwParty::relu_into`] round performs **zero heap
//! allocations** in the engine *and* on the transport receive path (the
//! local hub's send payloads are pooled too — see `net::local`).
//! Ownership rules live in the [`arena`] module docs and the `net` module
//! docs (`RecvBufs`): buffers are checked out and returned by the
//! protocol step that needs them, owned as plain locals in between, and
//! never cross parties or threads.
//!
//! Masked openings are bit-packed **directly into the wire buffer**
//! ([`bitpack::pack_bytes_into`]) and peers' openings are unpacked and
//! folded **directly into the result lanes**
//! ([`bitpack::unpack_bytes_xor_into`]) — no intermediate full-width lane
//! vectors exist on either side of a round.
//!
//! # Lane layouts (`--layout`)
//!
//! Binary shares flow through the engine in one of two layouts, selected
//! by the kernel backend's [`kernels::KernelBackend::bin_layout`]:
//!
//! * **`lane` (lane-per-u64, default)** — one w-bit value in the low bits
//!   of each u64. The reference layout: simplest, required by the XLA
//!   backend, and fastest for very small batches (no transpose overhead).
//! * **`bitsliced`** — blocks of 64 lanes transposed into w bit-plane
//!   words ([`bitsliced`]). Every local AND/XOR of the adder processes 64
//!   lanes per word instead of one, so local compute stops scaling with
//!   the *lane count* and starts scaling with `n·w/64` — a multi-×
//!   advantage at the paper's windows (w ≈ 6–8) on wide batches. The wire
//!   format is **byte-for-byte identical** to the classic path: packing a
//!   plane block is a fused 64×64 bit-matrix transpose written straight
//!   into the pooled wire buffer.
//!
//! The Beaver triple stream is **plane-native** in both modes
//! ([`TtpDealer::bin_triples_planes_into`]): the dealer emits binary
//! triples directly in packed wire order, expanding only the `w` live
//! bit-planes per 64-lane block (~w/64 of the lane-form PRG material —
//! reported by `TripleUsage::prg_bytes`). Bit-permutations commute with
//! AND/XOR, so `c = a ∧ b` holds stream-wise in either view. The
//! bitsliced AND path consumes the stream as-is — its former three
//! per-round `lanes_to_planes` triple transposes are gone — while the
//! lane path unpacks each segment with [`bitsliced::planes_to_lanes`].
//! Both layouts draw with identical `(w, n_seg, segs)` shapes at every
//! AND round, so they hold the same logical triples and stay
//! wire-byte-identical.
//!
//! Provisioning itself is split offline/online (DESIGN.md §3): draws go
//! through the [`TripleSource`] trait — synchronous PRG expansion inside
//! the AND round by default, or, after [`GmwParty::enable_prefetch`], a
//! background [`PrefetchDealer`] that expands the same stream one round
//! ahead along a predicted [`TripleSchedule`] so the online round only
//! swaps in ready buffers. Outputs, wire bytes and
//! [`GmwParty::triple_usage`] are bit-identical either way.
//!
//! Ownership rules for plane buffers are the arena's usual ones — checked
//! out per protocol step, fully overwritten, returned on completion — with
//! two extra representational invariants documented in [`bitsliced`]:
//! planes at or above w don't exist (masking is free) and tail lanes of a
//! partial final block stay zero. Plane buffers are sized
//! [`bitsliced::plane_len`]`(n, w)` and come from the same size-classed
//! pool, so the bitsliced hot path is as allocation-free as the classic
//! one (same `relu_steady_state_is_allocation_free` pinning).
//!
//! Public entry points (`a2b`, `ks_add`, `drelu`, `relu`, …) always accept
//! and return lane-per-u64 data in both modes; the engine converts at the
//! narrowest possible boundary (the DReLU driver stays in plane form from
//! re-sharing to MSB extraction and never round-trips).
//!
//! # Threading
//!
//! [`GmwParty::set_threads`] sets the lane-parallelism budget for the local
//! kernels and the fused pack/unpack (CLI flag `--threads`, coordinator
//! `ServeOptions::threads`). Results are bit-identical for every thread
//! count; small batches always run inline (thresholds live in
//! `util::tuning`, env-overridable).

pub mod adder;
pub mod bitsliced;
pub mod harness;
pub mod kernels;
pub mod pipeline;
pub mod simd;

/// The scratch arena now lives in [`crate::util::arena`] (it also backs the
/// transport payload pool and the `ShareExecutor` activation pool); this
/// re-export keeps the original `gmw::arena` paths working.
pub use crate::util::arena;

use crate::beaver::prefetch::{PrefetchDealer, PrefetchStats};
use crate::beaver::schedule::TripleSchedule;
use crate::beaver::{TripleSource, TripleUsage, TtpDealer};
use crate::bitpack;
use crate::error::{Error, Result};
use crate::net::accounting::Phase;
use crate::net::{self, RecvBufs, Transport};
use crate::ring;
use crate::sharing::PairwisePrgs;

use arena::{Arena, ArenaStats};
use kernels::{BinLayout, KernelBackend, RustKernels};

/// Per-layer ReLU evaluation plan: use bits [m, k) of the secret share.
///
/// * `k = 64, m = 0` — exact CrypTen-equivalent baseline (Eq. 2).
/// * `k < 64, m = 0` — HummingBird-eco (error-free if |x| < 2^(k-1), Thm 1).
/// * `m > 0` — adds magnitude pruning below 2^m (Thm 2).
/// * `k == m` — zero bits: the ReLU degenerates to identity (paper §4.1.2,
///   the generalization of ReLU culling).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReluPlan {
    pub k: u32,
    pub m: u32,
}

impl ReluPlan {
    /// Full-ring exact baseline.
    pub const BASELINE: ReluPlan = ReluPlan { k: 64, m: 0 };

    pub fn new(k: u32, m: u32) -> Result<Self> {
        if k > 64 || m > k {
            return Err(Error::config(format!("invalid ReluPlan k={k} m={m}")));
        }
        Ok(ReluPlan { k, m })
    }

    /// Window width in bits (0 = identity layer).
    pub fn width(&self) -> u32 {
        self.k - self.m
    }

    pub fn is_identity(&self) -> bool {
        self.k == self.m
    }

    pub fn is_baseline(&self) -> bool {
        *self == Self::BASELINE
    }
}

/// One party's protocol engine.
pub struct GmwParty<T: Transport, K: KernelBackend = RustKernels> {
    pub transport: T,
    /// The party's correlation provider (offline/online split): the
    /// synchronous [`TtpDealer`] by default, or a
    /// [`PrefetchDealer`] installed via [`GmwParty::enable_prefetch`] /
    /// [`GmwParty::set_triple_source`].
    dealer: Box<dyn TripleSource>,
    pub pairwise: PairwisePrgs,
    kernels: K,
    arena: Arena,
    /// Session-owned receive buffers; every opening's exchange fills these
    /// (see `net` module docs for the ownership rules).
    recv: RecvBufs,
    threads: usize,
    session_seed: u64,
}

impl<T: Transport> GmwParty<T, RustKernels> {
    /// Engine with the portable Rust kernels.
    pub fn new(transport: T, session_seed: u64) -> Self {
        GmwParty::with_kernels(transport, session_seed, RustKernels::default())
    }
}

impl<T: Transport, K: KernelBackend> GmwParty<T, K> {
    pub fn with_kernels(transport: T, session_seed: u64, kernels: K) -> Self {
        let party = transport.party();
        let parties = transport.parties();
        GmwParty {
            transport,
            // HOT-PATH-ALLOW: constructor — one boxed dealer per session.
            dealer: Box::new(TtpDealer::new(session_seed, party, parties)),
            pairwise: PairwisePrgs::new(session_seed, party, parties),
            kernels,
            arena: Arena::new(),
            recv: RecvBufs::new(parties),
            threads: 1,
            session_seed,
        }
    }

    #[inline]
    pub fn party(&self) -> usize {
        self.transport.party()
    }
    #[inline]
    pub fn parties(&self) -> usize {
        self.transport.parties()
    }
    #[inline]
    pub fn is_leader(&self) -> bool {
        self.party() == 0
    }
    pub fn kernel_name(&self) -> &'static str {
        self.kernels.name()
    }
    /// Whether this party's kernel backend dispatches to the AVX2 plane
    /// kernels (DESIGN.md §11). Purely informational — both arms are
    /// bit-identical — but the selftest and serve banner report it.
    pub fn kernel_simd(&self) -> bool {
        self.kernels.simd()
    }
    /// Binary-share layout of this party's kernel backend (see the
    /// "Lane layouts" section of the module docs).
    pub fn bin_layout(&self) -> BinLayout {
        self.kernels.bin_layout()
    }
    pub(crate) fn kernels_mut(&mut self) -> &mut K {
        &mut self.kernels
    }

    /// Set the lane-parallelism budget for local compute (kernels and the
    /// fused bitpack). 0 and 1 both mean single-threaded. Bit-exact for
    /// every value.
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
        self.kernels.set_threads(self.threads);
    }

    /// Current lane-parallelism budget.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Snapshot of the scratch-arena counters (checkouts / returns /
    /// allocation misses). The zero-allocation property of the steady-state
    /// hot path is asserted against these in the harness tests.
    pub fn arena_stats(&self) -> ArenaStats {
        self.arena.stats()
    }

    /// Cumulative correlation usage of this party's triple source (the
    /// offline storage / PRG report; identical across parties and across
    /// sync-vs-prefetch provisioning).
    pub fn triple_usage(&self) -> TripleUsage {
        self.dealer.usage()
    }

    /// Prefetch traffic counters, if a [`PrefetchDealer`] is installed
    /// (`None` on the default synchronous dealer).
    pub fn prefetch_stats(&self) -> Option<PrefetchStats> {
        self.dealer.prefetch_stats()
    }

    /// Replace the party's correlation provider. Must be called **before
    /// any protocol step has drawn** from the current source: the new
    /// source starts the deterministic dealer stream from the beginning,
    /// so a partially-consumed stream would desynchronize this party from
    /// its peers.
    pub fn set_triple_source(&mut self, source: Box<dyn TripleSource>) {
        self.dealer = source;
    }

    /// Split the offline phase off the online critical path: install a
    /// [`PrefetchDealer`] that expands this party's dealer stream on a
    /// background thread along `schedule` (see
    /// [`TripleSchedule`]; `cycle` repeats it per serving batch), then
    /// block until the first buffers are ready. Call before the first
    /// protocol step. Prefetching is a local decision per party — peers
    /// may stay synchronous — and results, wire bytes and
    /// [`GmwParty::triple_usage`] are bit-identical either way.
    pub fn enable_prefetch(&mut self, schedule: TripleSchedule, cycle: bool) {
        assert_eq!(
            self.dealer.usage(),
            TripleUsage::default(),
            "enable_prefetch must run before any correlation draw: the prefetcher \
             restarts the dealer stream from the beginning"
        );
        let dealer = TtpDealer::new(self.session_seed, self.party(), self.parties());
        let mut pf = PrefetchDealer::spawn(dealer, schedule, cycle);
        pf.wait_warm();
        // HOT-PATH-ALLOW: session setup — dealer swapped once, pre-draw.
        self.dealer = Box::new(pf);
    }

    /// Check a lane buffer (contents unspecified) out of the party's arena
    /// (engine-internal and adder use; pair with
    /// [`GmwParty::recycle_words`]; callers fully overwrite it).
    pub(crate) fn scratch_words(&mut self, len: usize) -> Vec<u64> {
        self.arena.take_words(len)
    }

    /// Return a lane buffer to the party's arena.
    pub(crate) fn recycle_words(&mut self, buf: Vec<u64>) {
        self.arena.put_words(buf)
    }

    // ------------------------------------------------------------------
    // Openings (the only communication primitives).
    // ------------------------------------------------------------------

    /// Open binary shares of w-bit lanes into `out` (length = shares):
    /// pack straight into the wire buffer, exchange, XOR-fold peers'
    /// packed shares straight into `out`.
    pub fn open_binary_into(
        &mut self,
        phase: Phase,
        shares: &[u64],
        w: u32,
        out: &mut [u64],
    ) -> Result<()> {
        let n = shares.len();
        debug_assert_eq!(out.len(), n);
        let wire_len = bitpack::packed_bytes(n, w) as usize;
        let mut wire = self.arena.take_bytes(wire_len);
        bitpack::pack_bytes_into(shares, w, &mut wire, self.threads);
        self.transport.exchange_all_into(phase, &wire, &mut self.recv)?;
        self.arena.put_bytes(wire);
        out.copy_from_slice(shares);
        let me = self.transport.party();
        let threads = self.threads;
        for q in 0..self.recv.parties() {
            if q == me {
                continue;
            }
            let buf = self.recv.get(q);
            // Hard wire check (the symmetric protocol makes every party's
            // payload the same size): a short/long payload is truncation
            // or corruption and must not be zero-padded into shares.
            if buf.len() != wire_len {
                return Err(Error::wire(format!(
                    "binary opening from party {q}: expected {wire_len} bytes, got {}",
                    buf.len()
                )));
            }
            bitpack::unpack_bytes_xor_into(buf, w, n, out, threads);
        }
        Ok(())
    }

    /// Open binary shares of w-bit lanes (allocating wrapper).
    pub fn open_binary(&mut self, phase: Phase, shares: &[u64], w: u32) -> Result<Vec<u64>> {
        // HOT-PATH-ALLOW: by-value wrapper over `open_binary_into`.
        let mut out = vec![0u64; shares.len()];
        self.open_binary_into(phase, shares, w, &mut out)?;
        Ok(out)
    }

    /// Open binary shares held in bit-plane form: `shares` is the
    /// concatenation of `segs` plane-form segments of `n_seg` lanes each
    /// (segment `s` covers global lanes `[s·n_seg, (s+1)·n_seg)` of the
    /// wire stream). The wire bytes are identical to
    /// [`GmwParty::open_binary_into`] over the equivalent lane vector:
    /// each segment is packed with the transpose-fused
    /// [`bitsliced::pack_planes_xor_into`] straight into the pooled wire
    /// buffer, and peers' bytes fold back with
    /// [`bitsliced::unpack_bytes_xor_into_planes`] — no lane vector exists
    /// on either side of the round.
    pub(crate) fn open_planes_into(
        &mut self,
        phase: Phase,
        shares: &[u64],
        w: u32,
        n_seg: usize,
        segs: usize,
        out: &mut [u64],
    ) -> Result<()> {
        let pl = bitsliced::plane_len(n_seg, w);
        debug_assert!(shares.len() == segs * pl && out.len() == segs * pl);
        let total = segs * n_seg;
        let wire_len = bitpack::packed_bytes(total, w) as usize;
        let mut wire = self.arena.take_bytes(wire_len);
        // The fused pack XOR-merges segments, so the buffer must start
        // zeroed (unlike the lane pack, which overwrites every byte; the
        // memset is a small fraction of the transposes it enables).
        if wire.len() != wire_len {
            wire.clear();
            wire.resize(wire_len, 0);
        } else {
            wire.fill(0);
        }
        let threads = self.threads;
        let simd = self.kernels.simd();
        for s in 0..segs {
            bitsliced::pack_planes_xor_into_with(
                &shares[s * pl..(s + 1) * pl],
                w,
                n_seg,
                s * n_seg,
                &mut wire,
                threads,
                simd,
            );
        }
        self.transport.exchange_all_into(phase, &wire, &mut self.recv)?;
        self.arena.put_bytes(wire);
        out.copy_from_slice(shares);
        let me = self.transport.party();
        for q in 0..self.recv.parties() {
            if q == me {
                continue;
            }
            let buf = self.recv.get(q);
            if buf.len() != wire_len {
                return Err(Error::wire(format!(
                    "binary opening from party {q}: expected {wire_len} bytes, got {}",
                    buf.len()
                )));
            }
            for s in 0..segs {
                bitsliced::unpack_bytes_xor_into_planes_with(
                    buf,
                    w,
                    n_seg,
                    s * n_seg,
                    &mut out[s * pl..(s + 1) * pl],
                    threads,
                    simd,
                );
            }
        }
        Ok(())
    }

    /// Open arithmetic shares (full 64-bit words on the wire) into `out`.
    pub fn open_arith_into(&mut self, phase: Phase, shares: &[u64], out: &mut [u64]) -> Result<()> {
        let n = shares.len();
        debug_assert_eq!(out.len(), n);
        let mut wire = self.arena.take_bytes(n * 8);
        net::u64s_to_bytes_into(shares, &mut wire);
        self.transport.exchange_all_into(phase, &wire, &mut self.recv)?;
        self.arena.put_bytes(wire);
        out.copy_from_slice(shares);
        let me = self.transport.party();
        for q in 0..self.recv.parties() {
            if q == me {
                continue;
            }
            net::add_u64s_from_bytes(self.recv.get(q), out)?;
        }
        Ok(())
    }

    /// Open arithmetic shares (allocating wrapper).
    pub fn open_arith(&mut self, phase: Phase, shares: &[u64]) -> Result<Vec<u64>> {
        // HOT-PATH-ALLOW: by-value wrapper over `open_arith_into`.
        let mut out = vec![0u64; shares.len()];
        self.open_arith_into(phase, shares, &mut out)?;
        Ok(out)
    }

    // ------------------------------------------------------------------
    // Beaver AND on w-bit lanes.
    // ------------------------------------------------------------------

    /// Secure AND of two binary-shared vectors of w-bit lanes, written into
    /// `out` (length n). Cost: one round, 2·w bits per element on the wire.
    /// Allocation-free once the arena is warm.
    pub fn and_gates_into(
        &mut self,
        phase: Phase,
        u: &[u64],
        v: &[u64],
        w: u32,
        out: &mut [u64],
    ) -> Result<()> {
        let n = u.len();
        self.and_gates_lanes_seg_into(phase, u, v, w, n, 1, out)
    }

    /// Lane-layout Beaver AND over `segs` logical segments of `n_seg`
    /// lanes each (`u`/`v`/`out` are the flat concatenation). The segment
    /// shape exists purely to keep the **dealer stream** aligned with the
    /// bitsliced path: the plane-native triple stream is blocked per
    /// segment ([`TtpDealer::bin_triples_planes_into`]), so the lane
    /// reference must consume it with the same `(w, n_seg, segs)` at every
    /// AND round and unpack each segment with
    /// [`bitsliced::planes_to_lanes`] — the transposes the bitsliced
    /// engine no longer pays. Both layouts then hold identical triple lane
    /// values, which is what keeps the masked openings (and therefore the
    /// wire bytes) bit-identical across layouts.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn and_gates_lanes_seg_into(
        &mut self,
        phase: Phase,
        u: &[u64],
        v: &[u64],
        w: u32,
        n_seg: usize,
        segs: usize,
        out: &mut [u64],
    ) -> Result<()> {
        let n = u.len();
        debug_assert!(n == segs * n_seg && v.len() == n && out.len() == n);
        let pl = bitsliced::plane_len(n_seg, w);
        let threads = self.threads;
        let mut tap = self.arena.take_words(segs * pl);
        let mut tbp = self.arena.take_words(segs * pl);
        let mut tcp = self.arena.take_words(segs * pl);
        self.dealer.bin_triples_planes_into(w, n_seg, segs, &mut tap, &mut tbp, &mut tcp)?;
        let mut ta = self.arena.take_words(n);
        let mut tb = self.arena.take_words(n);
        let mut tc = self.arena.take_words(n);
        for s in 0..segs {
            let ln = s * n_seg..(s + 1) * n_seg;
            let pn = s * pl..(s + 1) * pl;
            // HOT-PATH-ALLOW: Range clone is a 16-byte stack copy, no heap.
            bitsliced::planes_to_lanes(&tap[pn.clone()], w, n_seg, &mut ta[ln.clone()], threads);
            bitsliced::planes_to_lanes(&tbp[pn.clone()], w, n_seg, &mut tb[ln.clone()], threads);
            bitsliced::planes_to_lanes(&tcp[pn], w, n_seg, &mut tc[ln], threads);
        }
        self.arena.put_words(tcp);
        self.arena.put_words(tbp);
        self.arena.put_words(tap);
        let mut de = self.arena.take_words(2 * n);
        self.kernels.and_open(u, v, &ta, &tb, &mut de);
        let mut opened = self.arena.take_words(2 * n);
        self.open_binary_into(phase, &de, w, &mut opened)?;
        self.arena.put_words(de);
        let leader = self.is_leader();
        let (d, e) = opened.split_at(n);
        self.kernels.and_combine(d, e, &ta, &tb, &tc, leader, out);
        self.arena.put_words(opened);
        self.arena.put_words(ta);
        self.arena.put_words(tb);
        self.arena.put_words(tc);
        Ok(())
    }

    /// Secure AND (allocating wrapper).
    pub fn and_gates(&mut self, phase: Phase, u: &[u64], v: &[u64], w: u32) -> Result<Vec<u64>> {
        // HOT-PATH-ALLOW: by-value wrapper over `and_gates_into`.
        let mut out = vec![0u64; u.len()];
        self.and_gates_into(phase, u, v, w, &mut out)?;
        Ok(out)
    }

    /// Secure AND over bit-plane buffers (`segs` plane-form segments of
    /// `n_seg` lanes each — see [`GmwParty::open_planes_into`] for the
    /// segment convention). The dealer's plane-native triple stream
    /// ([`TtpDealer::bin_triples_planes_into`]) is consumed **directly**
    /// — the triples arrive already in packed wire order, so the round
    /// boundary performs zero triple transposes (pinned by
    /// `bitsliced_and_path_performs_zero_triple_transposes`). The lane
    /// reference unpacks the same stream with the same `(w, n_seg, segs)`
    /// shape, so the masked openings — and therefore the wire bytes — are
    /// bit-identical to [`GmwParty::and_gates_into`] on the equivalent
    /// lane vectors. The AND/XOR work itself runs 64 lanes per word.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn and_gates_planes_into(
        &mut self,
        phase: Phase,
        u: &[u64],
        v: &[u64],
        w: u32,
        n_seg: usize,
        segs: usize,
        out: &mut [u64],
    ) -> Result<()> {
        let pl = bitsliced::plane_len(n_seg, w);
        debug_assert!(u.len() == segs * pl && v.len() == segs * pl && out.len() == segs * pl);
        let mut tap = self.arena.take_words(segs * pl);
        let mut tbp = self.arena.take_words(segs * pl);
        let mut tcp = self.arena.take_words(segs * pl);
        self.dealer.bin_triples_planes_into(w, n_seg, segs, &mut tap, &mut tbp, &mut tcp)?;
        let mut de = self.arena.take_words(2 * segs * pl);
        self.kernels.and_open(u, v, &tap, &tbp, &mut de);
        let mut opened = self.arena.take_words(2 * segs * pl);
        // d occupies global lanes [0, total), e occupies [total, 2·total) —
        // exactly the classic `d || e` stream, as 2·segs segments.
        self.open_planes_into(phase, &de, w, n_seg, 2 * segs, &mut opened)?;
        self.arena.put_words(de);
        let leader = self.is_leader();
        let (d, e) = opened.split_at(segs * pl);
        self.kernels.and_combine(d, e, &tap, &tbp, &tcp, leader, out);
        self.arena.put_words(opened);
        self.arena.put_words(tcp);
        self.arena.put_words(tbp);
        self.arena.put_words(tap);
        Ok(())
    }

    // ------------------------------------------------------------------
    // Conversions.
    // ------------------------------------------------------------------

    /// A2B into `out`: convert arithmetic shares of w-bit values (one lane
    /// per u64, high bits ignored) into binary shares of the same values.
    ///
    /// Step 1 is communication-free (PRG re-sharing); step 2 folds each
    /// party's operand in with a circuit addition ([`adder::ks_add_into`]).
    pub fn a2b_into(&mut self, arith: &[u64], w: u32, out: &mut [u64]) -> Result<()> {
        let n = arith.len();
        debug_assert_eq!(out.len(), n);
        if self.bin_layout() == BinLayout::Bitsliced {
            let mut planes = self.arena.take_words(bitsliced::plane_len(n, w));
            let r = self.a2b_planes_into(arith, w, &mut planes);
            if r.is_ok() {
                bitsliced::planes_to_lanes(&planes, w, n, out, self.threads);
            }
            self.arena.put_words(planes);
            return r;
        }
        let mask = ring::low_mask(w);
        let me = self.party();
        let parties = self.parties();
        let mut masked = self.arena.take_words(n);
        for (mi, x) in masked.iter_mut().zip(arith) {
            *mi = x & mask;
        }
        // Binary re-sharing of every party's arithmetic share (operand j
        // belongs to party j). All parties generate the same zero-sharing
        // streams, so no communication happens here; each operand folds
        // into the accumulator with one circuit addition.
        let mut acc = self.arena.take_words(n);
        let mut op = self.arena.take_words(n);
        for j in 0..parties {
            let value = if j == me { Some(&masked[..]) } else { None };
            let dst = if j == 0 { &mut acc } else { &mut op };
            self.pairwise.reshare_binary_into(value, dst);
            for s in dst.iter_mut() {
                *s &= mask;
            }
            if j > 0 {
                let mut next = self.arena.take_words(n);
                adder::ks_add_into(self, &acc, &op, w, &mut next)?;
                self.arena.put_words(std::mem::replace(&mut acc, next));
            }
        }
        out.copy_from_slice(&acc);
        self.arena.put_words(acc);
        self.arena.put_words(op);
        self.arena.put_words(masked);
        Ok(())
    }

    /// A2B (allocating wrapper).
    pub fn a2b(&mut self, arith: &[u64], w: u32) -> Result<Vec<u64>> {
        // HOT-PATH-ALLOW: by-value wrapper over `a2b_into`.
        let mut out = vec![0u64; arith.len()];
        self.a2b_into(arith, w, &mut out)?;
        Ok(out)
    }

    /// Plane-native A2B: like [`GmwParty::a2b_into`] but the result stays
    /// in bit-plane form (`out.len() == `[`bitsliced::plane_len`]`(n, w)`).
    /// The PRG re-sharing streams are consumed exactly as in the classic
    /// path; each party's lane-form operand is transposed once and the
    /// circuit additions never leave plane form (the DReLU driver then
    /// reads the sign plane directly — no back-transpose on the hot path).
    pub(crate) fn a2b_planes_into(&mut self, arith: &[u64], w: u32, out: &mut [u64]) -> Result<()> {
        let n = arith.len();
        let pl = bitsliced::plane_len(n, w);
        debug_assert_eq!(out.len(), pl);
        let mask = ring::low_mask(w);
        let me = self.party();
        let parties = self.parties();
        let threads = self.threads;
        let mut masked = self.arena.take_words(n);
        for (mi, x) in masked.iter_mut().zip(arith) {
            *mi = x & mask;
        }
        // Same zero-sharing streams as the classic path, staged in lane
        // form and transposed per operand; the transpose discards bits at
        // or above w, which is exactly the classic `&= mask` pass.
        let mut lanes = self.arena.take_words(n);
        let mut acc = self.arena.take_words(pl);
        let mut op = self.arena.take_words(pl);
        for j in 0..parties {
            let value = if j == me { Some(&masked[..]) } else { None };
            self.pairwise.reshare_binary_into(value, &mut lanes);
            let dst = if j == 0 { &mut acc } else { &mut op };
            bitsliced::lanes_to_planes(&lanes, w, dst, threads);
            if j > 0 {
                let mut next = self.arena.take_words(pl);
                adder::ks_add_planes_with_into(
                    self,
                    &acc,
                    &op,
                    w,
                    n,
                    adder::AdderOptions::default(),
                    &mut next,
                )?;
                self.arena.put_words(std::mem::replace(&mut acc, next));
            }
        }
        out.copy_from_slice(&acc);
        self.arena.put_words(acc);
        self.arena.put_words(op);
        self.arena.put_words(lanes);
        self.arena.put_words(masked);
        Ok(())
    }

    /// B2A of single-bit lanes via daBits into `out`: one round, 1 bit per
    /// element.
    pub fn b2a_bit_into(&mut self, bits: &[u64], out: &mut [u64]) -> Result<()> {
        let n = bits.len();
        debug_assert_eq!(out.len(), n);
        let mut r_bin = self.arena.take_words(n);
        let mut r_arith = self.arena.take_words(n);
        self.dealer.dabits_into(&mut r_bin, &mut r_arith)?;
        let mut masked = self.arena.take_words(n);
        for ((mi, b), r) in masked.iter_mut().zip(bits).zip(&r_bin) {
            *mi = (b ^ r) & 1;
        }
        let mut z = self.arena.take_words(n);
        self.open_binary_into(Phase::B2A, &masked, 1, &mut z)?;
        // ⟨b⟩^A = z + ⟨r⟩^A − 2·z·⟨r⟩^A  (z public)
        let leader = self.is_leader();
        for ((o, zi), ra) in out.iter_mut().zip(&z).zip(&r_arith) {
            let mut v = ra.wrapping_sub(ra.wrapping_mul(2).wrapping_mul(*zi));
            if leader {
                v = v.wrapping_add(*zi);
            }
            *o = v;
        }
        self.arena.put_words(z);
        self.arena.put_words(masked);
        self.arena.put_words(r_arith);
        self.arena.put_words(r_bin);
        Ok(())
    }

    /// B2A of single-bit lanes (allocating wrapper).
    pub fn b2a_bit(&mut self, bits: &[u64]) -> Result<Vec<u64>> {
        // HOT-PATH-ALLOW: by-value wrapper over `b2a_bit_into`.
        let mut out = vec![0u64; bits.len()];
        self.b2a_bit_into(bits, &mut out)?;
        Ok(out)
    }

    // ------------------------------------------------------------------
    // Arithmetic ops.
    // ------------------------------------------------------------------

    /// Beaver multiplication of two arithmetically-shared vectors into
    /// `out`. Cost: one round, 2×64 bits per element (HummingBird cannot
    /// shrink this — paper Fig 3 "Mult").
    pub fn mul_into(&mut self, x: &[u64], y: &[u64], out: &mut [u64]) -> Result<()> {
        debug_assert_eq!(x.len(), y.len());
        debug_assert_eq!(out.len(), x.len());
        let n = x.len();
        let mut ta = self.arena.take_words(n);
        let mut tb = self.arena.take_words(n);
        let mut tc = self.arena.take_words(n);
        self.dealer.arith_triples_into(&mut ta, &mut tb, &mut tc)?;
        let mut de = self.arena.take_words(2 * n);
        self.kernels.mult_open(x, y, &ta, &tb, &mut de);
        let mut opened = self.arena.take_words(2 * n);
        self.open_arith_into(Phase::Mult, &de, &mut opened)?;
        self.arena.put_words(de);
        let leader = self.is_leader();
        let (d, e) = opened.split_at(n);
        self.kernels.mult_combine(d, e, &ta, &tb, &tc, leader, out);
        self.arena.put_words(opened);
        self.arena.put_words(ta);
        self.arena.put_words(tb);
        self.arena.put_words(tc);
        Ok(())
    }

    /// Beaver multiplication (allocating wrapper).
    pub fn mul(&mut self, x: &[u64], y: &[u64]) -> Result<Vec<u64>> {
        // HOT-PATH-ALLOW: by-value wrapper over `mul_into`.
        let mut out = vec![0u64; x.len()];
        self.mul_into(x, y, &mut out)?;
        Ok(out)
    }

    /// Local truncation of shares by 2^f, in place (CrypTen-style; see
    /// [`ring::trunc_share`]). The serving hot path uses this form so a
    /// linear layer's output buffer is truncated without a copy.
    pub fn trunc_in_place(&self, shares: &mut [u64], f: u32) {
        let me = self.party();
        for s in shares.iter_mut() {
            *s = ring::trunc_share(*s, f, me);
        }
    }

    /// Local truncation of shares by 2^f (allocating wrapper).
    pub fn trunc(&self, shares: &[u64], f: u32) -> Vec<u64> {
        // HOT-PATH-ALLOW: by-value wrapper over `trunc_in_place`.
        let mut out = shares.to_vec();
        self.trunc_in_place(&mut out, f);
        out
    }

    /// Add a public constant vector (leader adds; others pass through).
    pub fn add_public(&self, shares: &[u64], consts: &[u64]) -> Vec<u64> {
        if self.is_leader() {
            // HOT-PATH-ALLOW: by-value helper — layers fold bias in place.
            shares.iter().zip(consts).map(|(s, c)| s.wrapping_add(*c)).collect()
        } else {
            // HOT-PATH-ALLOW: by-value helper — pass-through copy.
            shares.to_vec()
        }
    }

    // ------------------------------------------------------------------
    // DReLU / ReLU (Equations 1–3).
    // ------------------------------------------------------------------

    /// DReLU on the bit window [m, k) into `out`: arithmetic shares of
    /// 1{x ≥ 0} evaluated on the reduced ring Z/2^(k−m).
    pub fn drelu_into(&mut self, arith: &[u64], plan: ReluPlan, out: &mut [u64]) -> Result<()> {
        let w = plan.width();
        debug_assert!(w >= 1, "drelu needs at least one bit");
        let n = arith.len();
        debug_assert_eq!(out.len(), n);
        // Local bit extraction ⟨x⟩[k:m] (free).
        let mut windows = self.arena.take_words(n);
        for (wi, x) in windows.iter_mut().zip(arith) {
            *wi = ring::bit_window(*x, plan.k, plan.m);
        }
        if self.bin_layout() == BinLayout::Bitsliced {
            // Plane-form hot path: the adder runs 64 lanes per word and the
            // MSB read is one plane word per block — the only lane-form
            // data after the window extraction is the 1-bit B2A input.
            let mut sum_planes = self.arena.take_words(bitsliced::plane_len(n, w));
            let r = self.a2b_planes_into(&windows, w, &mut sum_planes);
            if let Err(e) = r {
                self.arena.put_words(sum_planes);
                self.arena.put_words(windows);
                return Err(e);
            }
            let leader = self.is_leader();
            let mut msb = self.arena.take_words(n);
            bitsliced::msb_lanes_from_planes(&sum_planes, w, n, &mut msb);
            if leader {
                for m in msb.iter_mut() {
                    *m ^= 1;
                }
            }
            let r = self.b2a_bit_into(&msb, out);
            self.arena.put_words(msb);
            self.arena.put_words(sum_planes);
            self.arena.put_words(windows);
            return r;
        }
        // A2B on the reduced ring.
        let mut sum_bits = self.arena.take_words(n);
        self.a2b_into(&windows, w, &mut sum_bits)?;
        // Sign bit (bit w−1) is a binary share of the MSB; DReLU = ¬MSB.
        let leader = self.is_leader();
        let mut msb = self.arena.take_words(n);
        for (mi, s) in msb.iter_mut().zip(&sum_bits) {
            let bit = (s >> (w - 1)) & 1;
            *mi = if leader { bit ^ 1 } else { bit };
        }
        // 1-bit B2A.
        self.b2a_bit_into(&msb, out)?;
        self.arena.put_words(msb);
        self.arena.put_words(sum_bits);
        self.arena.put_words(windows);
        Ok(())
    }

    /// DReLU (allocating wrapper).
    pub fn drelu(&mut self, arith: &[u64], plan: ReluPlan) -> Result<Vec<u64>> {
        // HOT-PATH-ALLOW: by-value wrapper over `drelu_into`.
        let mut out = vec![0u64; arith.len()];
        self.drelu_into(arith, plan, &mut out)?;
        Ok(out)
    }

    /// ReLU per the plan into `out`: Eq. 2 when baseline, Eq. 3 otherwise.
    /// The zero-allocation entry point: with a warm arena, no engine-side
    /// heap allocation happens per call.
    pub fn relu_into(&mut self, arith: &[u64], plan: ReluPlan, out: &mut [u64]) -> Result<()> {
        debug_assert_eq!(out.len(), arith.len());
        if plan.is_identity() {
            out.copy_from_slice(arith);
            return Ok(());
        }
        let mut d = self.arena.take_words(arith.len());
        self.drelu_into(arith, plan, &mut d)?;
        self.mul_into(arith, &d, out)?;
        self.arena.put_words(d);
        Ok(())
    }

    /// ReLU (allocating wrapper).
    pub fn relu(&mut self, arith: &[u64], plan: ReluPlan) -> Result<Vec<u64>> {
        // HOT-PATH-ALLOW: by-value wrapper over `relu_into`.
        let mut out = vec![0u64; arith.len()];
        self.relu_into(arith, plan, &mut out)?;
        Ok(out)
    }
}
