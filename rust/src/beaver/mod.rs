//! Beaver triple provisioning (paper §2.2, §5.1).
//!
//! The paper "does not model the overhead of generating Beaver triplets,
//! assuming they are generated and stored offline or sent by a trusted
//! third-party (TTP) asynchronously". We reproduce that accounting exactly:
//! a [`TtpDealer`] derives each party's share of every triple from a
//! deterministic dealer stream, so provisioning costs **zero protocol
//! communication** and is excluded from the timed online phase. The dealer
//! still *counts* what it hands out ([`TripleUsage`]) so the offline-storage
//! requirement — a real operational concern the paper mentions — can be
//! reported per run.
//!
//! Security note (see DESIGN.md §4): in a deployment the dealer streams
//! would be delivered per-party over private channels; this performance
//! testbed derives them from a session seed shared by the simulated
//! parties. The *online protocol* messages are identical either way.
//!
//! Three correlation types are produced:
//! * arithmetic triples  (⟨a⟩, ⟨b⟩, ⟨c⟩) with c = a·b  (ring mult / ReLU's Mult step)
//! * binary triples      (⟨a⟩, ⟨b⟩, ⟨c⟩) with c = a∧b  (AND gates in the adder circuit)
//! * daBits              (⟨r⟩^B, ⟨r⟩^A) for a random bit r (the 1-bit B2A conversion)
//!
//! # Plane-native binary triple streams
//!
//! Binary triples are emitted directly in **packed wire order** — the
//! bit-plane layout of [`crate::gmw::bitsliced`]: for a segment of `n`
//! w-bit lanes the dealer produces [`plane_len`](crate::gmw::bitsliced::plane_len)`(n, w)
//! = ceil(n/64)·w` words per share buffer, where plane `b` of block `k`
//! carries bit `b` of lanes `[64k, 64k+64)`. Because bit-permutations
//! commute with AND and XOR, `c = a ∧ b` holds plane-wise exactly as it
//! held lane-wise — so the *same* stream serves both engine layouts: the
//! bitsliced kernels consume it as-is (no per-round triple transposes) and
//! the lane-per-u64 reference transposes it back with
//! [`planes_to_lanes`](crate::gmw::bitsliced::planes_to_lanes).
//!
//! The payoff is PRG expansion cost: the old lane-form stream drew a full
//! 64-bit word per w-bit lane and masked 64−w bits away; the plane stream
//! draws only the `w` live bit-planes per 64-lane block — **~w/64 of the
//! PRG material** (exact when `n` is a block multiple). At the paper's
//! windows (w ≈ 6–8) that is a ~10× cut in ChaCha20 expansion *and* in
//! offline triple storage. [`TripleUsage::prg_bytes`] reports the actual
//! draw so the saving is testable.
//!
//! Both invariants of the plane representation are established at the
//! source: planes at or above `w` don't exist, and tail lanes of a
//! partial final block are zero in every share (shares and plaintext are
//! masked to the live lanes — every party masks identically, so XOR
//! reconstruction still satisfies `c = a ∧ b` on the live lanes).
//!
//! # Offline/online phase split
//!
//! The engine draws correlations through the [`TripleSource`] trait, with
//! two providers (DESIGN.md §3):
//!
//! * [`TtpDealer`] — synchronous: PRG expansion happens inline in the
//!   protocol step that needs the triples (simple, but the expansion cost
//!   sits on the online critical path).
//! * [`prefetch::PrefetchDealer`] — the offline phase proper: a background
//!   producer expands the same stream ahead of time along a predicted
//!   [`schedule::TripleSchedule`], double-buffered so the online path only
//!   swaps in ready buffers. Outputs, wire bytes and [`TripleUsage`] are
//!   bit-identical to the synchronous dealer because both expand the same
//!   deterministic stream in the same order.
//!
//! [`schedule::TripleSchedule`] predicts the per-round draw shapes of a
//! protocol run (one ReLU, or a whole model forward pass) and prices them
//! with [`TripleUsage`] accounting before anything is expanded.

use crate::crypto::prg::Prg;
use crate::gmw::bitsliced;

pub mod prefetch;
pub mod schedule;

/// A source of Beaver correlations for one party: the engine's only
/// provisioning interface (`GmwParty` draws through a boxed
/// `TripleSource`). Implementations must expand (or replay) the *same
/// deterministic dealer stream* in draw order — the per-party streams stay
/// synchronized purely through protocol determinism, so a source that
/// reorders or resamples draws would silently break reconstruction.
///
/// Implemented by the synchronous [`TtpDealer`], the background
/// [`prefetch::PrefetchDealer`] and the diagnostic
/// [`schedule::Recorder`].
pub trait TripleSource: Send {
    /// Fill `a`, `b`, `c` (equal lengths) with this party's shares of
    /// fresh arithmetic triples (c = a·b over Z/2^64).
    ///
    /// Draws are fallible: a source backed by a background producer or a
    /// remote dealer reports stream divergence or exhaustion as the fatal
    /// [`Error::Beaver`](crate::error::Error::Beaver) instead of
    /// panicking the party thread (DESIGN.md §7). The synchronous
    /// [`TtpDealer`] never fails.
    fn arith_triples_into(
        &mut self,
        a: &mut [u64],
        b: &mut [u64],
        c: &mut [u64],
    ) -> crate::error::Result<()>;

    /// Fill `a`, `b`, `c` with plane-native binary triple shares for
    /// `segs` segments of `n_seg` w-bit lanes each (see
    /// [`TtpDealer::bin_triples_planes_into`] for the exact layout).
    fn bin_triples_planes_into(
        &mut self,
        w: u32,
        n_seg: usize,
        segs: usize,
        a: &mut [u64],
        b: &mut [u64],
        c: &mut [u64],
    ) -> crate::error::Result<()>;

    /// Fill `r_bin`/`r_arith` (equal lengths) with daBit shares.
    fn dabits_into(&mut self, r_bin: &mut [u64], r_arith: &mut [u64])
        -> crate::error::Result<()>;

    /// Cumulative usage as observed at the *consumer*: between protocol
    /// steps this must equal what a synchronous dealer would report at the
    /// same stream position, regardless of how far ahead an offline
    /// producer has run.
    fn usage(&self) -> TripleUsage;

    /// Prefetch traffic counters, for sources that split the offline
    /// phase off ([`prefetch::PrefetchDealer`]); `None` for synchronous
    /// sources.
    fn prefetch_stats(&self) -> Option<prefetch::PrefetchStats> {
        None
    }
}

/// This party's slice of a batch of arithmetic triples.
#[derive(Debug, Clone)]
pub struct ArithTriples {
    pub a: Vec<u64>,
    pub b: Vec<u64>,
    pub c: Vec<u64>,
}

/// This party's slice of a batch of binary (AND) triples in lane-per-u64
/// form (each u64 carries one w-bit lane; [`TtpDealer::bin_triples`] uses
/// w = 64, i.e. 64 independent bit-triples per word). Unpacked from the
/// plane-native dealer stream.
#[derive(Debug, Clone)]
pub struct BinTriples {
    pub a: Vec<u64>,
    pub b: Vec<u64>,
    pub c: Vec<u64>,
}

/// This party's slice of a batch of daBits.
#[derive(Debug, Clone)]
pub struct DaBits {
    /// Binary share of r (one bit in the LSB of each u64 lane).
    pub r_bin: Vec<u64>,
    /// Arithmetic share of the same r.
    pub r_arith: Vec<u64>,
}

/// Cumulative count of correlations consumed (offline storage report) plus
/// the PRG material the dealer expanded to produce them.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct TripleUsage {
    pub arith_triples: u64,
    /// Binary-triple material in bit-plane u64 *words per share buffer*
    /// (`w` plane words cover one 64-lane block of w-bit triples). This is
    /// what a party stores: 3 u64 per plane word.
    pub bin_plane_words: u64,
    /// Total w-bit AND lanes served. The legacy lane-form stream stored
    /// (and drew) one u64 per lane, so `bin_plane_words / bin_triple_lanes`
    /// is the plane-native storage/PRG savings ratio (~w/64).
    pub bin_triple_lanes: u64,
    pub dabits: u64,
    /// Total u64 words drawn from the dealer PRG across all correlation
    /// types (plaintexts + share randomness). Snapshot of the underlying
    /// [`Prg::u64s_drawn`] counter.
    pub prg_words: u64,
}

impl TripleUsage {
    /// Bytes a party would need to store for this usage (3 u64 per arith
    /// triple, 3 u64 per binary plane word, 2 u64 + 1 bit per daBit — we
    /// round the daBit binary part up to a word per 64).
    pub fn storage_bytes(&self) -> u64 {
        self.arith_triples * 24 + self.bin_plane_words * 24 + self.dabits * 9
    }

    /// Bytes of PRG output the dealer expanded for this usage.
    pub fn prg_bytes(&self) -> u64 {
        self.prg_words * 8
    }
}

/// Deterministic TTP dealer: every party constructs one with the same
/// session seed and its own party id, then pulls correlations in protocol
/// order. Stream synchronization is guaranteed by protocol determinism.
pub struct TtpDealer {
    party: usize,
    parties: usize,
    prg: Prg,
    usage: TripleUsage,
}

impl TtpDealer {
    pub fn new(session_seed: u64, party: usize, parties: usize) -> Self {
        assert!(parties >= 2 && party < parties);
        TtpDealer {
            party,
            parties,
            prg: Prg::new(session_seed ^ DEALER_DOMAIN, 0),
            usage: TripleUsage::default(),
        }
    }

    pub fn usage(&self) -> TripleUsage {
        TripleUsage { prg_words: self.prg.u64s_drawn(), ..self.usage }
    }

    /// Draw arithmetic triples into caller-provided buffers (all the same
    /// length). Allocation-free: the zero-allocation hot path hands in
    /// arena-pooled buffers. Stream consumption is identical to
    /// [`TtpDealer::arith_triples`].
    pub fn arith_triples_into(&mut self, a: &mut [u64], b: &mut [u64], c: &mut [u64]) {
        let n = a.len();
        debug_assert!(b.len() == n && c.len() == n);
        self.usage.arith_triples += n as u64;
        for i in 0..n {
            // Dealer samples plaintext a, b and all share randomness from
            // the common stream; every party runs this same loop and keeps
            // only its own column.
            let pa = self.prg.next_u64();
            let pb = self.prg.next_u64();
            let pc = pa.wrapping_mul(pb);
            a[i] = self.split_arith(pa);
            b[i] = self.split_arith(pb);
            c[i] = self.split_arith(pc);
        }
    }

    /// Draw `n` arithmetic triples; returns this party's shares.
    pub fn arith_triples(&mut self, n: usize) -> ArithTriples {
        let mut out = ArithTriples { a: vec![0; n], b: vec![0; n], c: vec![0; n] };
        self.arith_triples_into(&mut out.a, &mut out.b, &mut out.c);
        out
    }

    /// Draw binary-triple shares **in bit-plane form** for `segs`
    /// independent segments of `n_seg` w-bit lanes each (the engine's
    /// round-buffer shape — e.g. the adder's batched stage is two segments
    /// of `n` lanes). Each of `a`, `b`, `c` must be
    /// `segs ·`[`bitsliced::plane_len`]`(n_seg, w)` words; segment `s`
    /// occupies the word range `[s·plane_len, (s+1)·plane_len)`.
    ///
    /// This is the *primary* correlation stream: only the `w` live
    /// bit-planes of each 64-lane block are expanded (~w/64 of the
    /// lane-form PRG material), `c = a ∧ b` is computed plane-wise, and
    /// both plane-layout invariants hold on every share buffer (no planes
    /// at or above `w`; zero tail lanes in a partial final block). The
    /// lane-form view ([`TtpDealer::bin_triples_into`]) unpacks this same
    /// stream, so both engine layouts stay stream-synchronized.
    ///
    /// Allocation-free: the engine hands in arena-pooled buffers.
    pub fn bin_triples_planes_into(
        &mut self,
        w: u32,
        n_seg: usize,
        segs: usize,
        a: &mut [u64],
        b: &mut [u64],
        c: &mut [u64],
    ) {
        debug_assert!(w >= 1 && w <= 64);
        let wu = w as usize;
        let nblocks = bitsliced::blocks(n_seg);
        let pl = nblocks * wu;
        debug_assert!(a.len() == segs * pl && b.len() == segs * pl && c.len() == segs * pl);
        self.usage.bin_plane_words += (segs * pl) as u64;
        self.usage.bin_triple_lanes += (segs * n_seg) as u64;
        for s in 0..segs {
            for k in 0..nblocks {
                // Live lanes of this block (the final block of a segment
                // may be partial); shares are masked to them so the
                // zero-tail-lanes invariant holds at the source.
                let live = (n_seg - k * bitsliced::LANES_PER_BLOCK).min(64);
                let tm = crate::ring::low_mask(live as u32);
                let base = s * pl + k * wu;
                for plane in 0..wu {
                    let pa = self.prg.next_u64() & tm;
                    let pb = self.prg.next_u64() & tm;
                    let pc = pa & pb;
                    a[base + plane] = self.split_binary_masked(pa, tm);
                    b[base + plane] = self.split_binary_masked(pb, tm);
                    c[base + plane] = self.split_binary_masked(pc, tm);
                }
            }
        }
    }

    /// Draw binary triples as **lane-per-u64** shares of `a.len()` w-bit
    /// lanes (one segment), by unpacking the plane-native stream — stream
    /// consumption is identical to [`TtpDealer::bin_triples_planes_into`]
    /// with `segs = 1`, so lane-form and plane-form consumers stay
    /// synchronized. Allocates plane scratch internally; the engine hot
    /// path draws planes straight into arena buffers and transposes them
    /// itself instead of calling this.
    pub fn bin_triples_into(&mut self, w: u32, a: &mut [u64], b: &mut [u64], c: &mut [u64]) {
        let n = a.len();
        debug_assert!(b.len() == n && c.len() == n);
        let pl = bitsliced::plane_len(n, w);
        let mut ap = vec![0u64; pl];
        let mut bp = vec![0u64; pl];
        let mut cp = vec![0u64; pl];
        self.bin_triples_planes_into(w, n, 1, &mut ap, &mut bp, &mut cp);
        bitsliced::planes_to_lanes(&ap, w, n, a, 1);
        bitsliced::planes_to_lanes(&bp, w, n, b, 1);
        bitsliced::planes_to_lanes(&cp, w, n, c, 1);
    }

    /// Draw `n` full-width binary-triple words (64 bit-triples per word).
    pub fn bin_triples(&mut self, n: usize) -> BinTriples {
        let mut out = BinTriples { a: vec![0; n], b: vec![0; n], c: vec![0; n] };
        self.bin_triples_into(64, &mut out.a, &mut out.b, &mut out.c);
        out
    }

    /// Draw daBits into caller-provided buffers. Stream consumption is
    /// identical to [`TtpDealer::dabits`].
    pub fn dabits_into(&mut self, r_bin: &mut [u64], r_arith: &mut [u64]) {
        let n = r_bin.len();
        debug_assert_eq!(r_arith.len(), n);
        self.usage.dabits += n as u64;
        for i in 0..n {
            let r = self.prg.next_u64() & 1;
            r_bin[i] = self.split_binary_masked(r, 1);
            r_arith[i] = self.split_arith(r);
        }
    }

    /// Draw `n` daBits.
    pub fn dabits(&mut self, n: usize) -> DaBits {
        let mut out = DaBits { r_bin: vec![0; n], r_arith: vec![0; n] };
        self.dabits_into(&mut out.r_bin, &mut out.r_arith);
        out
    }

    /// Split a dealer-known value arithmetically; return my share.
    /// Consumes `parties - 1` stream values regardless of `self.party` so
    /// all parties stay synchronized.
    #[inline]
    fn split_arith(&mut self, x: u64) -> u64 {
        let mut acc = 0u64;
        let mut mine = 0u64;
        for p in 0..self.parties - 1 {
            let r = self.prg.next_u64();
            acc = acc.wrapping_add(r);
            if p == self.party {
                mine = r;
            }
        }
        if self.party == self.parties - 1 {
            x.wrapping_sub(acc)
        } else {
            mine
        }
    }

    /// XOR-domain split with share randomness restricted to `mask` (for
    /// plane words of a partial block: the live-lane mask; for daBits: the
    /// LSB). Every party masks identically, so reconstruction matches the
    /// masked plaintext.
    #[inline]
    fn split_binary_masked(&mut self, x: u64, mask: u64) -> u64 {
        let mut acc = 0u64;
        let mut mine = 0u64;
        for p in 0..self.parties - 1 {
            let r = self.prg.next_u64() & mask;
            acc ^= r;
            if p == self.party {
                mine = r;
            }
        }
        if self.party == self.parties - 1 {
            x ^ acc
        } else {
            mine
        }
    }
}

/// The synchronous provider: every draw expands the PRG inline and can
/// never fail (the `Ok` wrapping is the whole trait impl).
impl TripleSource for TtpDealer {
    fn arith_triples_into(
        &mut self,
        a: &mut [u64],
        b: &mut [u64],
        c: &mut [u64],
    ) -> crate::error::Result<()> {
        TtpDealer::arith_triples_into(self, a, b, c);
        Ok(())
    }

    fn bin_triples_planes_into(
        &mut self,
        w: u32,
        n_seg: usize,
        segs: usize,
        a: &mut [u64],
        b: &mut [u64],
        c: &mut [u64],
    ) -> crate::error::Result<()> {
        TtpDealer::bin_triples_planes_into(self, w, n_seg, segs, a, b, c);
        Ok(())
    }

    fn dabits_into(
        &mut self,
        r_bin: &mut [u64],
        r_arith: &mut [u64],
    ) -> crate::error::Result<()> {
        TtpDealer::dabits_into(self, r_bin, r_arith);
        Ok(())
    }

    fn usage(&self) -> TripleUsage {
        TtpDealer::usage(self)
    }
}

/// Domain-separation constant (vs. pairwise zero-sharing streams).
const DEALER_DOMAIN: u64 = 0xbea7_e270_5eed_0002;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmw::bitsliced::{plane_len, planes_to_lanes};
    use crate::ring::low_mask;

    fn dealers(parties: usize) -> Vec<TtpDealer> {
        (0..parties).map(|p| TtpDealer::new(999, p, parties)).collect()
    }

    #[test]
    fn arith_triples_satisfy_c_eq_ab() {
        for parties in 2..=4 {
            let mut ds = dealers(parties);
            let batches: Vec<ArithTriples> = ds.iter_mut().map(|d| d.arith_triples(32)).collect();
            for i in 0..32 {
                let a: u64 = batches.iter().fold(0, |s, t| s.wrapping_add(t.a[i]));
                let b: u64 = batches.iter().fold(0, |s, t| s.wrapping_add(t.b[i]));
                let c: u64 = batches.iter().fold(0, |s, t| s.wrapping_add(t.c[i]));
                assert_eq!(c, a.wrapping_mul(b), "parties={parties} i={i}");
            }
        }
    }

    #[test]
    fn bin_triples_satisfy_c_eq_a_and_b() {
        for parties in 2..=4 {
            let mut ds = dealers(parties);
            let batches: Vec<BinTriples> = ds.iter_mut().map(|d| d.bin_triples(32)).collect();
            for i in 0..32 {
                let a: u64 = batches.iter().fold(0, |s, t| s ^ t.a[i]);
                let b: u64 = batches.iter().fold(0, |s, t| s ^ t.b[i]);
                let c: u64 = batches.iter().fold(0, |s, t| s ^ t.c[i]);
                assert_eq!(c, a & b, "parties={parties} i={i}");
            }
        }
    }

    /// Plane-form stream: c = a ∧ b plane-wise, zero tail lanes in every
    /// share of a partial final block, and no planes at or above w —
    /// across party counts, segment shapes and widths.
    #[test]
    fn plane_triples_satisfy_c_eq_a_and_b_planewise() {
        for parties in 2..=4 {
            for w in [1u32, 6, 18, 64] {
                for (n_seg, segs) in [(64usize, 1usize), (100, 2), (1, 3), (129, 1)] {
                    let pl = plane_len(n_seg, w);
                    let mut ds = dealers(parties);
                    let batches: Vec<(Vec<u64>, Vec<u64>, Vec<u64>)> = ds
                        .iter_mut()
                        .map(|d| {
                            let mut a = vec![0u64; segs * pl];
                            let mut b = vec![0u64; segs * pl];
                            let mut c = vec![0u64; segs * pl];
                            d.bin_triples_planes_into(w, n_seg, segs, &mut a, &mut b, &mut c);
                            (a, b, c)
                        })
                        .collect();
                    let tail_live = n_seg - (n_seg - 1) / 64 * 64;
                    let tail_mask = low_mask(tail_live as u32);
                    for i in 0..segs * pl {
                        let a: u64 = batches.iter().fold(0, |s, t| s ^ t.0[i]);
                        let b: u64 = batches.iter().fold(0, |s, t| s ^ t.1[i]);
                        let c: u64 = batches.iter().fold(0, |s, t| s ^ t.2[i]);
                        assert_eq!(c, a & b, "parties={parties} w={w} n={n_seg} word={i}");
                        // Tail lanes of each segment's final block are zero
                        // in every *share*, not just the reconstruction.
                        if (i % pl) / w as usize == pl / w as usize - 1 {
                            for (p, t) in batches.iter().enumerate() {
                                assert_eq!(t.0[i] & !tail_mask, 0, "dirty tail (a) party {p}");
                                assert_eq!(t.1[i] & !tail_mask, 0, "dirty tail (b) party {p}");
                                assert_eq!(t.2[i] & !tail_mask, 0, "dirty tail (c) party {p}");
                            }
                        }
                    }
                }
            }
        }
    }

    /// The lane-form view is the exact transpose of the plane-form stream
    /// (same dealer state ⇒ same draw), so mixed-layout sessions stay
    /// synchronized.
    #[test]
    fn lane_view_is_transpose_of_plane_stream() {
        let w = 6u32;
        let n = 130usize;
        let mut d1 = TtpDealer::new(77, 0, 2);
        let mut d2 = TtpDealer::new(77, 0, 2);
        let mut la = vec![0u64; n];
        let mut lb = vec![0u64; n];
        let mut lc = vec![0u64; n];
        d1.bin_triples_into(w, &mut la, &mut lb, &mut lc);
        let pl = plane_len(n, w);
        let (mut pa, mut pb, mut pc) = (vec![0u64; pl], vec![0u64; pl], vec![0u64; pl]);
        d2.bin_triples_planes_into(w, n, 1, &mut pa, &mut pb, &mut pc);
        let mut back = vec![0u64; n];
        planes_to_lanes(&pa, w, n, &mut back, 1);
        assert_eq!(back, la);
        planes_to_lanes(&pc, w, n, &mut back, 1);
        assert_eq!(back, lc);
        assert!(la.iter().all(|v| *v <= low_mask(w)), "lane shares exceed width");
        assert_eq!(d1.usage(), d2.usage(), "views must consume identical streams");
    }

    /// The headline regression pin: PRG material drawn for binary triples
    /// scales with the window width w, not with the 64-bit word — w=1
    /// draws 1/64 of the w=64 material, and w=64 matches the lane-form
    /// cost of one word per lane.
    #[test]
    fn plane_stream_prg_draw_scales_with_width() {
        let n = 4096usize; // 64 full blocks: ratios are exact
        let parties = 2;
        let draw = |w: u32| -> u64 {
            let mut d = TtpDealer::new(5, 0, parties);
            let pl = plane_len(n, w);
            let (mut a, mut b, mut c) = (vec![0u64; pl], vec![0u64; pl], vec![0u64; pl]);
            d.bin_triples_planes_into(w, n, 1, &mut a, &mut b, &mut c);
            d.usage().prg_words
        };
        let d1 = draw(1);
        let d6 = draw(6);
        let d64 = draw(64);
        assert_eq!(d6, 6 * d1, "draw must be linear in w");
        assert_eq!(d64, 64 * d1, "draw must be linear in w");
        // Per plane word: 2 plaintext draws + 3 splits × (parties−1).
        let per_word = 2 + 3 * (parties as u64 - 1);
        assert_eq!(d64, n as u64 * per_word, "w=64 must equal the lane-form draw");
        // The lane-form *view* inherits the savings (satellite fix: no more
        // draw-64-mask-to-w): at w=1 it draws 1/64 of the lane-count words.
        let mut d = TtpDealer::new(5, 0, parties);
        let (mut a, mut b, mut c) = (vec![0u64; n], vec![0u64; n], vec![0u64; n]);
        d.bin_triples_into(1, &mut a, &mut b, &mut c);
        assert_eq!(d.usage().prg_words, d1);
        assert_eq!(d.usage().prg_words, n as u64 * per_word / 64);
    }

    #[test]
    fn dabits_are_consistent_bits() {
        for parties in 2..=3 {
            let mut ds = dealers(parties);
            let batches: Vec<DaBits> = ds.iter_mut().map(|d| d.dabits(64)).collect();
            for i in 0..64 {
                let r_b: u64 = batches.iter().fold(0, |s, t| s ^ t.r_bin[i]) & 1;
                let r_a: u64 = batches.iter().fold(0u64, |s, t| s.wrapping_add(t.r_arith[i]));
                assert_eq!(r_a, r_b, "daBit arith/binary mismatch i={i}");
            }
        }
    }

    #[test]
    fn usage_accounting() {
        let mut d = TtpDealer::new(1, 0, 2);
        d.arith_triples(10);
        d.bin_triples(5);
        d.dabits(3);
        let u = d.usage();
        assert_eq!(u.arith_triples, 10);
        // 5 lanes at w=64: one partial block ⇒ 64 plane words per buffer.
        assert_eq!(u.bin_plane_words, 64);
        assert_eq!(u.bin_triple_lanes, 5);
        assert_eq!(u.dabits, 3);
        assert!(u.storage_bytes() > 0);
        assert!(u.prg_bytes() > 0);
        // Reduced-width triples store ~w/64 of the lane-form material.
        let mut d = TtpDealer::new(1, 0, 2);
        let pl = plane_len(640, 6);
        let (mut a, mut b, mut c) = (vec![0u64; pl], vec![0u64; pl], vec![0u64; pl]);
        d.bin_triples_planes_into(6, 640, 1, &mut a, &mut b, &mut c);
        let u = d.usage();
        assert_eq!(u.bin_plane_words, 60); // 10 blocks × 6 planes
        assert_eq!(u.bin_triple_lanes, 640);
        assert!(u.bin_plane_words < u.bin_triple_lanes);
    }

    #[test]
    fn streams_differ_between_sessions() {
        let mut d1 = TtpDealer::new(1, 0, 2);
        let mut d2 = TtpDealer::new(2, 0, 2);
        assert_ne!(d1.arith_triples(4).a, d2.arith_triples(4).a);
    }
}
