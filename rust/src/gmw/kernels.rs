//! Local-compute kernels of the GMW engine.
//!
//! Every *local* tensor computation the protocol performs between
//! communication rounds is factored behind [`KernelBackend`], with two
//! implementations:
//!
//! * [`RustKernels`] — portable Rust (this file). The reference
//!   implementation every test validates against. It splits large lane
//!   ranges across OS threads via `util::threadpool` (the engine's
//!   `--threads` knob); small tensors always run inline, so dispatch
//!   overhead never dominates.
//! * `runtime::XlaKernels` — the same five primitives lowered from the
//!   Layer-1 **Pallas kernels** (`python/compile/kernels/bitops.py`) to HLO
//!   and executed on the PJRT CPU client. This is the path that proves the
//!   three-layer composition, and the one a TPU/GPU deployment would use.
//!
//! The five primitives map 1:1 onto the Pallas kernels and onto the
//! protocol's communication structure: each `*_open` produces exactly the
//! masked values that go on the wire, and each `*_combine` consumes exactly
//! what came back.
//!
//! # Buffer discipline (zero-allocation hot path)
//!
//! Every primitive writes into a caller-provided `&mut [u64]` instead of
//! returning a `Vec`. The protocol engine checks those buffers out of its
//! [`Arena`](super::arena::Arena) and returns them when the round
//! completes, so steady-state ReLU evaluation allocates nothing per round.
//! Output layouts:
//!
//! * `and_open` / `mult_open`: `out.len() == 2n`, `d` in `out[..n]`,
//!   `e` in `out[n..]`.
//! * `and_combine` / `mult_combine`: `out.len() == n`.
//! * `ks_stage_operands`: `u_out.len() == v_out.len() == halves·n` where
//!   `halves = if last { 1 } else { 2 }`.

use crate::util::threadpool::par_chunks_mut;

/// Lane count below which the Rust kernels stay single-threaded (spawn
/// overhead would swamp the arithmetic; keeps small-`n` latency unchanged).
pub const PAR_MIN_LANES: usize = 8192;

/// Masked-open / combine primitives for one party.
///
/// Deliberately NOT `Send`: the PJRT client (XLA backend) is thread-local,
/// so each party thread constructs its own backend in-thread (see
/// `gmw::harness::run_parties_with`).
#[allow(clippy::too_many_arguments)]
pub trait KernelBackend {
    /// Beaver-AND open: given share vectors u, v and triple shares a, b
    /// (all w-bit lanes), write the concatenated masked opening
    /// `d || e` = `(u ⊕ a) || (v ⊕ b)` into `out` (length 2n).
    fn and_open(&mut self, u: &[u64], v: &[u64], a: &[u64], b: &[u64], out: &mut [u64]);

    /// Beaver-AND combine: given *public* opened d, e and triple shares
    /// a, b, c, write this party's share of u ∧ v into `out` (length n):
    /// `z = [leader] d∧e ⊕ d∧b ⊕ e∧a ⊕ c`.
    fn and_combine(
        &mut self,
        d: &[u64],
        e: &[u64],
        a: &[u64],
        b: &[u64],
        c: &[u64],
        leader: bool,
        out: &mut [u64],
    );

    /// One Kogge–Stone stage's local prep: from prefix state (g, p) write
    /// the two AND operand vectors for this stage into `u_out` / `v_out`:
    /// `u = p || p`, `v = (g ≪ s) || (p ≪ s)` (all masked to w bits).
    /// `last` skips the `p` half (the final stage only needs g), halving
    /// the operand lengths.
    fn ks_stage_operands(
        &mut self,
        g: &[u64],
        p: &[u64],
        s: u32,
        w: u32,
        last: bool,
        u_out: &mut [u64],
        v_out: &mut [u64],
    );

    /// Beaver arithmetic-multiply open: write `d || e` = `(x − a) || (y − b)`
    /// over Z/2^64 into `out` (length 2n).
    fn mult_open(&mut self, x: &[u64], y: &[u64], a: &[u64], b: &[u64], out: &mut [u64]);

    /// Beaver arithmetic-multiply combine: write
    /// `z = c + d·b + e·a + [leader] d·e` over Z/2^64 into `out` (length n).
    fn mult_combine(
        &mut self,
        d: &[u64],
        e: &[u64],
        a: &[u64],
        b: &[u64],
        c: &[u64],
        leader: bool,
        out: &mut [u64],
    );

    /// Thread-count knob for backends that parallelize across lanes
    /// (no-op by default; the XLA backend parallelizes inside PJRT).
    fn set_threads(&mut self, _threads: usize) {}

    /// Human-readable backend name (for metrics / bench labels).
    fn name(&self) -> &'static str;
}

/// Portable Rust implementation, optionally multi-threaded across lanes.
#[derive(Debug, Clone)]
pub struct RustKernels {
    threads: usize,
}

impl Default for RustKernels {
    fn default() -> Self {
        RustKernels { threads: 1 }
    }
}

impl RustKernels {
    /// Kernels that split lane ranges across up to `threads` OS threads
    /// (only engaged above [`PAR_MIN_LANES`] lanes).
    pub fn with_threads(threads: usize) -> Self {
        RustKernels { threads: threads.max(1) }
    }

    #[inline]
    fn eff_threads(&self, n: usize) -> usize {
        if n >= PAR_MIN_LANES {
            self.threads
        } else {
            1
        }
    }
}

#[allow(clippy::too_many_arguments)]
impl KernelBackend for RustKernels {
    fn and_open(&mut self, u: &[u64], v: &[u64], a: &[u64], b: &[u64], out: &mut [u64]) {
        let n = u.len();
        debug_assert!(v.len() == n && a.len() == n && b.len() == n && out.len() == 2 * n);
        let t = self.eff_threads(n);
        let (d_out, e_out) = out.split_at_mut(n);
        par_chunks_mut(d_out, t, |off, chunk| {
            for (i, o) in chunk.iter_mut().enumerate() {
                *o = u[off + i] ^ a[off + i];
            }
        });
        par_chunks_mut(e_out, t, |off, chunk| {
            for (i, o) in chunk.iter_mut().enumerate() {
                *o = v[off + i] ^ b[off + i];
            }
        });
    }

    fn and_combine(
        &mut self,
        d: &[u64],
        e: &[u64],
        a: &[u64],
        b: &[u64],
        c: &[u64],
        leader: bool,
        out: &mut [u64],
    ) {
        let n = d.len();
        debug_assert!(e.len() == n && a.len() == n && b.len() == n && c.len() == n);
        debug_assert_eq!(out.len(), n);
        par_chunks_mut(out, self.eff_threads(n), |off, chunk| {
            for (i, o) in chunk.iter_mut().enumerate() {
                let j = off + i;
                let mut z = (d[j] & b[j]) ^ (e[j] & a[j]) ^ c[j];
                if leader {
                    z ^= d[j] & e[j];
                }
                *o = z;
            }
        });
    }

    fn ks_stage_operands(
        &mut self,
        g: &[u64],
        p: &[u64],
        s: u32,
        w: u32,
        last: bool,
        u_out: &mut [u64],
        v_out: &mut [u64],
    ) {
        let mask = crate::ring::low_mask(w);
        let n = g.len();
        let halves = if last { 1 } else { 2 };
        debug_assert!(p.len() == n && u_out.len() == halves * n && v_out.len() == halves * n);
        let t = self.eff_threads(n);
        par_chunks_mut(&mut u_out[..n], t, |off, chunk| {
            chunk.copy_from_slice(&p[off..off + chunk.len()]);
        });
        par_chunks_mut(&mut v_out[..n], t, |off, chunk| {
            for (i, o) in chunk.iter_mut().enumerate() {
                *o = (g[off + i] << s) & mask;
            }
        });
        if !last {
            par_chunks_mut(&mut u_out[n..], t, |off, chunk| {
                chunk.copy_from_slice(&p[off..off + chunk.len()]);
            });
            par_chunks_mut(&mut v_out[n..], t, |off, chunk| {
                for (i, o) in chunk.iter_mut().enumerate() {
                    *o = (p[off + i] << s) & mask;
                }
            });
        }
    }

    fn mult_open(&mut self, x: &[u64], y: &[u64], a: &[u64], b: &[u64], out: &mut [u64]) {
        let n = x.len();
        debug_assert!(y.len() == n && a.len() == n && b.len() == n && out.len() == 2 * n);
        let t = self.eff_threads(n);
        let (d_out, e_out) = out.split_at_mut(n);
        par_chunks_mut(d_out, t, |off, chunk| {
            for (i, o) in chunk.iter_mut().enumerate() {
                *o = x[off + i].wrapping_sub(a[off + i]);
            }
        });
        par_chunks_mut(e_out, t, |off, chunk| {
            for (i, o) in chunk.iter_mut().enumerate() {
                *o = y[off + i].wrapping_sub(b[off + i]);
            }
        });
    }

    fn mult_combine(
        &mut self,
        d: &[u64],
        e: &[u64],
        a: &[u64],
        b: &[u64],
        c: &[u64],
        leader: bool,
        out: &mut [u64],
    ) {
        let n = d.len();
        debug_assert!(e.len() == n && a.len() == n && b.len() == n && c.len() == n);
        debug_assert_eq!(out.len(), n);
        par_chunks_mut(out, self.eff_threads(n), |off, chunk| {
            for (i, o) in chunk.iter_mut().enumerate() {
                let j = off + i;
                let mut z = c[j]
                    .wrapping_add(d[j].wrapping_mul(b[j]))
                    .wrapping_add(e[j].wrapping_mul(a[j]));
                if leader {
                    z = z.wrapping_add(d[j].wrapping_mul(e[j]));
                }
                *o = z;
            }
        });
    }

    fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    fn name(&self) -> &'static str {
        "rust"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::prg::Prg;

    /// One-party-world sanity: with "shares" equal to plaintext and a zero
    /// triple, open/combine reduce to plain AND / MUL.
    #[test]
    fn degenerate_open_combine_is_plain_and() {
        let mut k = RustKernels::default();
        let u = vec![0b1100u64];
        let v = vec![0b1010u64];
        let zero = vec![0u64];
        let mut de = vec![0u64; 2];
        k.and_open(&u, &v, &zero, &zero, &mut de);
        assert_eq!(de, vec![0b1100, 0b1010]);
        let mut z = vec![0u64; 1];
        k.and_combine(&de[..1], &de[1..], &zero, &zero, &zero, true, &mut z);
        assert_eq!(z, vec![0b1000]);
    }

    #[test]
    fn degenerate_mult_is_plain_mul() {
        let mut k = RustKernels::default();
        let x = vec![7u64];
        let y = vec![6u64.wrapping_neg()]; // -6
        let zero = vec![0u64];
        let mut de = vec![0u64; 2];
        k.mult_open(&x, &y, &zero, &zero, &mut de);
        let mut z = vec![0u64; 1];
        k.mult_combine(&de[..1], &de[1..], &zero, &zero, &zero, true, &mut z);
        assert_eq!(z[0] as i64, -42);
    }

    #[test]
    fn stage_operands_shift_and_mask() {
        let mut k = RustKernels::default();
        let g = vec![0b1000u64];
        let p = vec![0b1111u64];
        let (mut u, mut v) = (vec![0u64; 2], vec![0u64; 2]);
        k.ks_stage_operands(&g, &p, 1, 4, false, &mut u, &mut v);
        assert_eq!(u, vec![0b1111, 0b1111]);
        assert_eq!(v, vec![0b0000, 0b1110]); // g<<1 overflows the 4-bit lane
        let (mut u, mut v) = (vec![0u64; 1], vec![0u64; 1]);
        k.ks_stage_operands(&g, &p, 2, 6, true, &mut u, &mut v);
        assert_eq!(u, vec![0b1111]);
        assert_eq!(v, vec![0b100000]);
    }

    /// Multi-threaded kernels are bit-identical to single-threaded for every
    /// primitive, at a lane count that actually engages the thread pool.
    #[test]
    fn parallel_kernels_match_scalar_reference() {
        let n = PAR_MIN_LANES + 1000;
        let mut prg = Prg::new(17, 0);
        let u = prg.vec_u64(n);
        let v = prg.vec_u64(n);
        let a = prg.vec_u64(n);
        let b = prg.vec_u64(n);
        let c = prg.vec_u64(n);
        let mut scalar = RustKernels::default();
        for threads in [2usize, 4, crate::util::threadpool::default_threads()] {
            let mut par = RustKernels::with_threads(threads);

            let mut de1 = vec![0u64; 2 * n];
            let mut de2 = vec![0u64; 2 * n];
            scalar.and_open(&u, &v, &a, &b, &mut de1);
            par.and_open(&u, &v, &a, &b, &mut de2);
            assert_eq!(de1, de2, "and_open threads={threads}");

            for leader in [true, false] {
                let mut z1 = vec![0u64; n];
                let mut z2 = vec![0u64; n];
                scalar.and_combine(&u, &v, &a, &b, &c, leader, &mut z1);
                par.and_combine(&u, &v, &a, &b, &c, leader, &mut z2);
                assert_eq!(z1, z2, "and_combine threads={threads}");
                scalar.mult_combine(&u, &v, &a, &b, &c, leader, &mut z1);
                par.mult_combine(&u, &v, &a, &b, &c, leader, &mut z2);
                assert_eq!(z1, z2, "mult_combine threads={threads}");
            }

            scalar.mult_open(&u, &v, &a, &b, &mut de1);
            par.mult_open(&u, &v, &a, &b, &mut de2);
            assert_eq!(de1, de2, "mult_open threads={threads}");

            let w = 20u32;
            let mask = crate::ring::low_mask(w);
            let g: Vec<u64> = u.iter().map(|x| x & mask).collect();
            let p: Vec<u64> = v.iter().map(|x| x & mask).collect();
            for (s, last) in [(1u32, false), (4, true)] {
                let halves = if last { 1 } else { 2 };
                let mut u1 = vec![0u64; halves * n];
                let mut v1 = vec![0u64; halves * n];
                let mut u2 = vec![0u64; halves * n];
                let mut v2 = vec![0u64; halves * n];
                scalar.ks_stage_operands(&g, &p, s, w, last, &mut u1, &mut v1);
                par.ks_stage_operands(&g, &p, s, w, last, &mut u2, &mut v2);
                assert_eq!(u1, u2, "stage u threads={threads} last={last}");
                assert_eq!(v1, v2, "stage v threads={threads} last={last}");
            }
        }
    }
}
