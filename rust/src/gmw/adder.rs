//! Kogge–Stone prefix adder on binary shares (paper §2.2: "the addition …
//! is performed using a series of AND and XOR operations, as it would be
//! done by an adder circuit (e.g., carry-lookahead adder)").
//!
//! Lane layout: with the classic kernels each element is an independent
//! w-bit value stored in the low bits of a u64; the adder is vectorized
//! across elements, and the AND gates of all elements in a stage are
//! opened in **one** round. With `--layout bitsliced` the same circuit
//! runs over bit-plane buffers ([`ks_add_planes_with_into`], see
//! [`super::bitsliced`]): every XOR/AND below processes 64 lanes per word,
//! lane shifts become plane-index shifts, and the `& mask` disappears
//! (planes at or above w don't exist). The round structure, byte counts
//! and results are identical in both layouts. The adder itself never
//! branches on the kernel arm: the word-level XOR/AND/shift loops it
//! drives dispatch to AVX2 inside [`super::kernels`] (DESIGN.md §11), and
//! both arms are bit-identical, so everything pinned here holds for
//! `--kernel scalar|simd|auto` alike.
//!
//! Cost model (the paper's O(N·logN) → O(w·log w) claim):
//!   * 1 initial AND round  (G₀ = x∧y)            — tagged `Phase::OtherAnd`
//!   * ⌈log₂ w⌉ stage rounds, 2 ANDs each batched — tagged `Phase::Circuit`
//!     (the final stage only updates G: 1 AND)
//! Per round each party sends 2·w bits per element per AND, bit-packed.
//!
//! Buffer discipline: all prefix state (G, P) and per-stage operands live
//! in buffers checked out of the party's scratch arena and returned before
//! the call completes — [`ks_add_into`] allocates nothing once the arena is
//! warm. See `gmw::arena` for the ownership rules.

use super::bitsliced;
use super::kernels::{BinLayout, KernelBackend};
use super::GmwParty;
use crate::error::Result;
use crate::net::accounting::Phase;
use crate::net::Transport;
use crate::ring;

/// Number of communication rounds `ks_add` will use for width `w`
/// (initial AND + prefix stages). Used by cost estimators and tests.
pub fn rounds_for_width(w: u32) -> u32 {
    if w <= 1 {
        0
    } else {
        1 + (32 - (w - 1).leading_zeros()) // 1 + ceil(log2(w))
    }
}

/// Bytes each party sends during one `ks_add` over `n` elements of width
/// `w` (exact, matching the bit-packed wire format).
pub fn bytes_for_add(n: usize, w: u32) -> u64 {
    if w <= 1 {
        return 0;
    }
    let mut total = crate::bitpack::packed_bytes(2 * n, w); // initial AND: d||e
    let stages = ceil_log2(w);
    for idx in 0..stages {
        let last = idx + 1 == stages;
        let ands = if last { 1 } else { 2 };
        total += crate::bitpack::packed_bytes(2 * ands * n, w);
    }
    total
}

fn ceil_log2(w: u32) -> u32 {
    if w <= 1 {
        0
    } else {
        32 - (w - 1).leading_zeros()
    }
}

/// Adder design knobs (defaults = the optimized protocol). The ablation
/// bench (`benches/ablation.rs`) measures what each optimization buys;
/// DESIGN.md §5.2 documents the choices.
#[derive(Debug, Clone, Copy)]
pub struct AdderOptions {
    /// Batch a stage's two ANDs (G and P updates) into one opening round.
    /// Off: two rounds per stage (the naive circuit-walker layout).
    pub batch_stage_ands: bool,
    /// Skip the P update on the final stage (its output is never read),
    /// halving the last round's bytes.
    pub skip_last_p: bool,
}

impl Default for AdderOptions {
    fn default() -> Self {
        AdderOptions { batch_stage_ands: true, skip_last_p: true }
    }
}

/// Secure addition of two binary-shared vectors of w-bit lanes; returns
/// binary shares of (x + y) mod 2^w.
pub fn ks_add<T: Transport, K: KernelBackend>(
    party: &mut GmwParty<T, K>,
    x: &[u64],
    y: &[u64],
    w: u32,
) -> Result<Vec<u64>> {
    // HOT-PATH-ALLOW: by-value wrapper — the engine uses `ks_add_into`.
    let mut out = vec![0u64; x.len()];
    ks_add_with_into(party, x, y, w, AdderOptions::default(), &mut out)?;
    Ok(out)
}

/// [`ks_add`] writing into a caller-provided buffer (the zero-allocation
/// hot path used by `GmwParty::a2b_into`).
pub fn ks_add_into<T: Transport, K: KernelBackend>(
    party: &mut GmwParty<T, K>,
    x: &[u64],
    y: &[u64],
    w: u32,
    out: &mut [u64],
) -> Result<()> {
    ks_add_with_into(party, x, y, w, AdderOptions::default(), out)
}

/// [`ks_add`] with explicit design knobs (ablations).
pub fn ks_add_with<T: Transport, K: KernelBackend>(
    party: &mut GmwParty<T, K>,
    x: &[u64],
    y: &[u64],
    w: u32,
    opts: AdderOptions,
) -> Result<Vec<u64>> {
    // HOT-PATH-ALLOW: by-value wrapper — ablations only; see `_into` form.
    let mut out = vec![0u64; x.len()];
    ks_add_with_into(party, x, y, w, opts, &mut out)?;
    Ok(out)
}

/// [`ks_add_with`] writing into a caller-provided buffer.
pub fn ks_add_with_into<T: Transport, K: KernelBackend>(
    party: &mut GmwParty<T, K>,
    x: &[u64],
    y: &[u64],
    w: u32,
    opts: AdderOptions,
    out: &mut [u64],
) -> Result<()> {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(out.len(), x.len());
    let n = x.len();
    let mask = ring::low_mask(w);

    // w == 1: addition mod 2 is XOR; no carries, no communication.
    if w == 1 {
        for ((o, a), b) in out.iter_mut().zip(x).zip(y) {
            *o = (a ^ b) & 1;
        }
        return Ok(());
    }

    // Bitsliced engine: transpose the lane operands into bit-plane form,
    // run the plane-native circuit, transpose the sum back. Callers on the
    // DReLU hot path avoid the boundary transposes entirely by staying in
    // plane form (`GmwParty::a2b_planes_into`).
    if party.bin_layout() == BinLayout::Bitsliced {
        let pl = bitsliced::plane_len(n, w);
        let threads = party.threads();
        let mut xp = party.scratch_words(pl);
        let mut yp = party.scratch_words(pl);
        bitsliced::lanes_to_planes(x, w, &mut xp, threads);
        bitsliced::lanes_to_planes(y, w, &mut yp, threads);
        let mut sum = party.scratch_words(pl);
        let r = ks_add_planes_with_into(party, &xp, &yp, w, n, opts, &mut sum);
        if r.is_ok() {
            bitsliced::planes_to_lanes(&sum, w, n, out, threads);
        }
        party.recycle_words(sum);
        party.recycle_words(yp);
        party.recycle_words(xp);
        return r;
    }

    // P = x ⊕ y (local), G = x ∧ y (one AND round, "Others" in Fig 3).
    let mut p = party.scratch_words(n);
    for ((pi, a), b) in p.iter_mut().zip(x).zip(y) {
        *pi = (a ^ b) & mask;
    }
    let mut g = party.scratch_words(n);
    party.and_gates_into(Phase::OtherAnd, x, y, w, &mut g)?;

    // Prefix stages ("Circuit" in Fig 3).
    let stages = ceil_log2(w);
    let mut s = 1u32;
    for idx in 0..stages {
        let last = opts.skip_last_p && idx + 1 == stages;
        if opts.batch_stage_ands || last {
            let halves = if last { 1 } else { 2 };
            let mut u = party.scratch_words(halves * n);
            let mut v = party.scratch_words(halves * n);
            party.kernels_stage_operands(&g, &p, s, w, last, &mut u, &mut v);
            let mut z = party.scratch_words(halves * n);
            // Segment shape (n, halves) mirrors the bitsliced circuit's
            // `and_gates_planes_into` call so both layouts consume the
            // plane-native dealer stream identically.
            party.and_gates_lanes_seg_into(Phase::Circuit, &u, &v, w, n, halves, &mut z)?;
            if last {
                // z = P ∧ (G ≪ s)
                for (gi, zi) in g.iter_mut().zip(&z) {
                    *gi ^= *zi;
                }
            } else {
                let (zg, zp) = z.split_at(n);
                for (((gi, pi), zgi), zpi) in g.iter_mut().zip(p.iter_mut()).zip(zg).zip(zp) {
                    *gi ^= *zgi;
                    *pi = *zpi;
                }
            }
            party.recycle_words(z);
            party.recycle_words(v);
            party.recycle_words(u);
        } else {
            // Naive layout: one opening round per AND.
            let mut gv = party.scratch_words(n);
            let mut pv = party.scratch_words(n);
            for ((gvi, gi), (pvi, pi)) in
                gv.iter_mut().zip(&g).zip(pv.iter_mut().zip(&p))
            {
                *gvi = (gi << s) & mask;
                *pvi = (pi << s) & mask;
            }
            let mut zg = party.scratch_words(n);
            party.and_gates_into(Phase::Circuit, &p, &gv, w, &mut zg)?;
            let mut zp = party.scratch_words(n);
            party.and_gates_into(Phase::Circuit, &p, &pv, w, &mut zp)?;
            for (((gi, pi), zgi), zpi) in g.iter_mut().zip(p.iter_mut()).zip(&zg).zip(&zp) {
                *gi ^= *zgi;
                *pi = *zpi;
            }
            party.recycle_words(zp);
            party.recycle_words(zg);
            party.recycle_words(pv);
            party.recycle_words(gv);
        }
        s <<= 1;
    }

    // Sum = x ⊕ y ⊕ (carries ≪ 1); carries into bit i are G[i−1].
    for (((o, a), b), gi) in out.iter_mut().zip(x).zip(y).zip(&g) {
        *o = (a ^ b ^ (gi << 1)) & mask;
    }
    party.recycle_words(g);
    party.recycle_words(p);
    Ok(())
}

/// Plane-native Kogge–Stone addition: `xp`, `yp` and `out` are bit-plane
/// buffers of `n` lanes at width `w` ([`bitsliced::plane_len`]`(n, w)`
/// words each). Same round structure, triple consumption and wire bytes
/// as the classic circuit — only the local-compute layout differs: every
/// XOR below touches 64 lanes per word and the lane mask is implicit.
pub(crate) fn ks_add_planes_with_into<T: Transport, K: KernelBackend>(
    party: &mut GmwParty<T, K>,
    xp: &[u64],
    yp: &[u64],
    w: u32,
    n: usize,
    opts: AdderOptions,
    out: &mut [u64],
) -> Result<()> {
    let pl = bitsliced::plane_len(n, w);
    debug_assert!(xp.len() == pl && yp.len() == pl && out.len() == pl);

    // w == 1: addition mod 2 is XOR (the single plane word per block).
    if w == 1 {
        for ((o, a), b) in out.iter_mut().zip(xp).zip(yp) {
            *o = a ^ b;
        }
        return Ok(());
    }

    // P = x ⊕ y (local, mask-free in plane form), G = x ∧ y (one AND round).
    let mut p = party.scratch_words(pl);
    for ((pi, a), b) in p.iter_mut().zip(xp).zip(yp) {
        *pi = a ^ b;
    }
    let mut g = party.scratch_words(pl);
    party.and_gates_planes_into(Phase::OtherAnd, xp, yp, w, n, 1, &mut g)?;

    // Prefix stages.
    let stages = ceil_log2(w);
    let mut s = 1u32;
    for idx in 0..stages {
        let last = opts.skip_last_p && idx + 1 == stages;
        if opts.batch_stage_ands || last {
            let halves = if last { 1 } else { 2 };
            let mut u = party.scratch_words(halves * pl);
            let mut v = party.scratch_words(halves * pl);
            party.kernels_stage_operands(&g, &p, s, w, last, &mut u, &mut v);
            let mut z = party.scratch_words(halves * pl);
            party.and_gates_planes_into(Phase::Circuit, &u, &v, w, n, halves, &mut z)?;
            if last {
                for (gi, zi) in g.iter_mut().zip(&z) {
                    *gi ^= *zi;
                }
            } else {
                let (zg, zp) = z.split_at(pl);
                for (((gi, pi), zgi), zpi) in g.iter_mut().zip(p.iter_mut()).zip(zg).zip(zp) {
                    *gi ^= *zgi;
                    *pi = *zpi;
                }
            }
            party.recycle_words(z);
            party.recycle_words(v);
            party.recycle_words(u);
        } else {
            // Naive layout: one opening round per AND.
            let mut gv = party.scratch_words(pl);
            let mut pv = party.scratch_words(pl);
            let threads = party.threads();
            bitsliced::plane_shl_into(&g, w, s, &mut gv, threads);
            bitsliced::plane_shl_into(&p, w, s, &mut pv, threads);
            let mut zg = party.scratch_words(pl);
            party.and_gates_planes_into(Phase::Circuit, &p, &gv, w, n, 1, &mut zg)?;
            let mut zp = party.scratch_words(pl);
            party.and_gates_planes_into(Phase::Circuit, &p, &pv, w, n, 1, &mut zp)?;
            for (((gi, pi), zgi), zpi) in g.iter_mut().zip(p.iter_mut()).zip(&zg).zip(&zp) {
                *gi ^= *zgi;
                *pi = *zpi;
            }
            party.recycle_words(zp);
            party.recycle_words(zg);
            party.recycle_words(pv);
            party.recycle_words(gv);
        }
        s <<= 1;
    }

    // Sum = x ⊕ y ⊕ (carries ≪ 1): the lane shift-by-1 is a plane-index
    // shift — plane b of the sum folds in carry plane b − 1.
    let wu = w as usize;
    for (k, ob) in out.chunks_exact_mut(wu).enumerate() {
        let base = k * wu;
        ob[0] = xp[base] ^ yp[base];
        for b in 1..wu {
            ob[b] = xp[base + b] ^ yp[base + b] ^ g[base + b - 1];
        }
    }
    party.recycle_words(g);
    party.recycle_words(p);
    Ok(())
}

impl<T: Transport, K: KernelBackend> GmwParty<T, K> {
    /// Expose the kernel's stage-operand builder to the adder (keeps the
    /// `kernels` field private to `gmw::mod`).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn kernels_stage_operands(
        &mut self,
        g: &[u64],
        p: &[u64],
        s: u32,
        w: u32,
        last: bool,
        u_out: &mut [u64],
        v_out: &mut [u64],
    ) {
        self.kernels_mut().ks_stage_operands(g, p, s, w, last, u_out, v_out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_counts() {
        assert_eq!(rounds_for_width(1), 0);
        assert_eq!(rounds_for_width(2), 2); // init + 1 stage
        assert_eq!(rounds_for_width(8), 4); // init + 3
        assert_eq!(rounds_for_width(64), 7); // init + 6
        // The paper's round-reduction claim: 6 bits vs 64 bits
        assert!(rounds_for_width(6) < rounds_for_width(64));
    }

    #[test]
    fn byte_costs_scale_superlinearly_in_width() {
        let n = 1000;
        let b64 = bytes_for_add(n, 64);
        let b8 = bytes_for_add(n, 8);
        // O(w log w): 64→8 bits should shrink bytes by more than 8×.
        assert!(b64 / b8 >= 8, "b64={b64} b8={b8}");
        assert_eq!(bytes_for_add(n, 1), 0);
    }
}
