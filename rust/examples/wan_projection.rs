//! Network sensitivity study (the paper's Fig 9 methodology, exposed as a
//! library example): sweep bandwidth/latency over several orders of
//! magnitude and show where HummingBird's advantage saturates.
//!
//! Run: `cargo run --release --example wan_projection`

use hummingbird::crypto::prg::Prg;
use hummingbird::gmw::harness::run_parties;
use hummingbird::gmw::ReluPlan;
use hummingbird::net::profile::NetworkProfile;
use hummingbird::sharing::share_arith;
use hummingbird::util::stats;

fn main() {
    // Measure one ReLU layer's trace for baseline and HummingBird windows.
    let n = 16384;
    let mut prg = Prg::new(1, 0);
    let x: Vec<u64> = (0..n).map(|_| prg.next_u64() % (1 << 16)).collect();
    let shares = share_arith(&mut prg, &x, 2);

    let mut traces = Vec::new();
    for (name, plan) in [
        ("baseline-64", ReluPlan::BASELINE),
        ("eco-18", ReluPlan::new(18, 0).unwrap()),
        ("hb-8", ReluPlan::new(12, 4).unwrap()),
        ("hb-6", ReluPlan::new(10, 4).unwrap()),
    ] {
        let shares = shares.clone();
        let run = run_parties(2, 7, move |p| {
            let me = p.party();
            p.relu(&shares[me], plan).unwrap();
        });
        let rounds: Vec<u64> = run.trace.rounds().iter().map(|r| r.bytes_sent).collect();
        println!(
            "{name:<12} {:>10} in {} rounds",
            stats::fmt_bytes(run.trace.total_bytes()),
            rounds.len()
        );
        traces.push((name, rounds));
    }

    // Sweep: NVLink-class to congested-WAN-class links.
    let profiles = [
        NetworkProfile::new("NVLink", 5e-6, 16e12),
        NetworkProfile::new("100GbE", 10e-6, 100e9),
        NetworkProfile::lan(),
        NetworkProfile::new("1GbE", 100e-6, 1e9),
        NetworkProfile::wan(),
        NetworkProfile::new("slow-WAN", 50e-3, 50e6),
    ];
    println!("\nprojected time per ReLU layer ({n} elements) and speedup vs baseline:");
    println!(
        "{:<10} {:>12} {:>14} {:>14} {:>14}",
        "network", "baseline-64", "eco-18", "hb-8", "hb-6"
    );
    for net in &profiles {
        let times: Vec<f64> = traces
            .iter()
            .map(|(_, rounds)| rounds.iter().map(|b| net.round_time(*b)).sum())
            .collect();
        println!(
            "{:<10} {:>12} {:>8} ({:4.2}x) {:>7} ({:4.2}x) {:>7} ({:4.2}x)",
            net.name,
            stats::fmt_secs(times[0]),
            stats::fmt_secs(times[1]),
            times[0] / times[1],
            stats::fmt_secs(times[2]),
            times[0] / times[2],
            stats::fmt_secs(times[3]),
            times[0] / times[3],
        );
    }
    println!(
        "\nAs bandwidth shrinks, byte volume dominates round latency and the\n\
         speedup approaches the raw communication reduction — the paper's\n\
         High-BW < LAN < WAN ordering (Fig 9)."
    );
}
